"""Multi-chip fabric: chip tier, hierarchy tables, and sharded sessions.

Covers the PR acceptance criteria:
  * a ``chips=1`` config is bit-identical to the pre-existing flat-core
    path across all five arbiter schemes and all three NoC schemes
    (property-style via `tests/_hypothesis_compat.py`),
  * currents are invariant under chip partitioning (the chip tier changes
    transport accounting, never the CAM-match semantics),
  * ``run(shard="chips")`` on a chips=4 x cores_per_chip=4 config is
    bit-identical to the unsharded oracle (vmap fallback in-process; the
    real `shard_map` mesh path runs on 8 fake devices in a slow
    subprocess test),
  * chips/cores/cores_per_chip reconciliation and stale-tables validation.
"""

import dataclasses
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.core import fabric, ppa
from repro.interface import Interface, InterfaceConfig, StepStats, ppa_report
from repro.interface import pipeline as interface_pipeline
from repro.noc import hierarchy, topology
from tests._hypothesis_compat import given, settings, strategies as st

KEY = jax.random.PRNGKey(0)
NOC_SCHEMES = ("broadcast", "unicast", "multicast_tree")
ARBITER_SCHEMES = ("binary_tree", "greedy_tree", "token_ring", "hier_ring",
                   "hier_tree")


def _cfg(chips=1, cores=8, n=16, entries=32, arbiter="hier_tree",
         noc="multicast_tree"):
    return InterfaceConfig(cores=cores, neurons_per_core=n,
                           cam_entries_per_core=entries, scheme=arbiter,
                           noc=topology.NocConfig(noc), chips=chips)


# ---- chips=1 == pre-existing flat-core path ---------------------------------


@pytest.mark.slow
@settings(max_examples=2, deadline=None)
@given(st.integers(0, 2**16), st.floats(0.05, 0.6))
def test_chips1_bit_identical_to_flat_path(seed, rate):
    """chips=1 sessions reproduce the flat fabric.step path, tick for
    tick, across all five arbiter schemes and all three NoC schemes."""
    for arbiter in ARBITER_SCHEMES:
        for noc in NOC_SCHEMES:
            cfg = _cfg(chips=1, cores=4, arbiter=arbiter, noc=noc)
            params = fabric.random_connectivity(jax.random.PRNGKey(seed), cfg)
            spikes = jax.random.bernoulli(
                jax.random.PRNGKey(seed + 1), rate,
                (2, cfg.cores, cfg.neurons_per_core))
            currents, acc = Interface(cfg).compile(params).run(spikes)

            tables = fabric.noc_tables(params, cfg)
            ref = StepStats.zeros()
            for i in range(2):
                with warnings.catch_warnings():
                    warnings.simplefilter("ignore", DeprecationWarning)
                    cur_i, st_i = fabric.step(params, spikes[i], cfg, tables)
                assert bool(jnp.all(currents[i] == cur_i)), (arbiter, noc, i)
                ref = ref.accumulate(st_i)
            for name in StepStats._fields:
                assert float(getattr(acc, name)) == pytest.approx(
                    float(getattr(ref, name)), rel=1e-6), (arbiter, noc, name)
            # a flat fabric has no chip tier to pay for
            assert float(acc.chip_hops) == 0.0
            assert float(acc.chip_latency) == 0.0
            assert float(acc.chip_energy) == 0.0


# ---- chip partitioning ------------------------------------------------------


def test_currents_invariant_under_chip_partitioning():
    """Splitting 16 cores into 1/2/4 chips never changes the currents:
    the chip tier re-routes delivery, not the CAM-match semantics."""
    flat = _cfg(chips=1, cores=16)
    params = fabric.random_connectivity(KEY, flat)
    spikes = jax.random.bernoulli(jax.random.PRNGKey(1), 0.3,
                                  (3, 16, flat.neurons_per_core))
    ref, ref_acc = Interface(flat).compile(params).run(spikes)
    for chips in (2, 4):
        cfg = _cfg(chips=chips, cores=16)
        cur, acc = Interface(cfg).compile(params).run(spikes)
        assert bool(jnp.all(cur == ref)), chips
        # CAM accounting is delivery-independent too
        assert float(acc.events) == float(ref_acc.events)
        assert float(acc.cam_searches) == float(ref_acc.cam_searches)
        # cross-chip subscriptions exist at this density: the tier is paid
        assert float(acc.chip_hops) > 0.0
        assert float(acc.chip_energy) == pytest.approx(
            float(acc.chip_hops) * ppa.CHIP_HOP_ENERGY)


def test_event_driven_tick_matches_oracle_with_chips():
    """The dense-sweep + DES oracle and the event-driven path agree on
    every StepStats field (chip tier included) on a multi-chip fabric."""
    cfg = _cfg(chips=4, cores=16)
    params = fabric.random_connectivity(KEY, cfg)
    spikes = jax.random.bernoulli(jax.random.PRNGKey(2), 0.3,
                                  (cfg.cores, cfg.neurons_per_core))
    cur, st = interface_pipeline.interface_tick(params, spikes, cfg)
    ref_cur, ref_st = interface_pipeline.interface_tick(params, spikes, cfg,
                                                        oracle=True)
    assert bool(jnp.all(cur == ref_cur))
    for name in StepStats._fields:
        assert float(getattr(st, name)) == float(getattr(ref_st, name)), name


# ---- sharded execution ------------------------------------------------------


def test_sharded_run_matches_unsharded_oracle():
    """Acceptance: chips=4 x cores_per_chip=4, run(shard="chips") currents
    bit-identical to the unsharded oracle (vmap fallback on one device)."""
    cfg = InterfaceConfig(chips=4, cores_per_chip=4, neurons_per_core=16,
                          cam_entries_per_core=32)
    assert cfg.cores == 16
    params = fabric.random_connectivity(KEY, cfg)
    spikes = jax.random.bernoulli(jax.random.PRNGKey(3), 0.3,
                                  (4, cfg.cores, cfg.neurons_per_core))
    session = Interface(cfg).compile(params)
    cur, acc = session.run(spikes)
    cur_s, acc_s = session.run(spikes, shard="chips")
    assert bool(jnp.all(cur == cur_s))
    # oracle reference too, not just the event-driven unsharded path
    cur_o, _ = interface_pipeline.interface_tick(params, spikes[0], cfg,
                                                 oracle=True)
    assert bool(jnp.all(cur_s[0] == cur_o))
    for name in StepStats._fields:
        assert float(getattr(acc_s, name)) == pytest.approx(
            float(getattr(acc, name)), rel=1e-5), name


def test_sharded_run_batched_matches():
    cfg = InterfaceConfig(chips=2, cores_per_chip=4, neurons_per_core=16,
                          cam_entries_per_core=32)
    params = fabric.random_connectivity(KEY, cfg)
    spikes = jax.random.bernoulli(jax.random.PRNGKey(4), 0.3,
                                  (2, 3, cfg.cores, cfg.neurons_per_core))
    session = Interface(cfg).compile(params)
    cur, acc = session.run_batched(spikes)
    cur_s, acc_s = session.run_batched(spikes, shard="chips")
    assert bool(jnp.all(cur == cur_s))
    assert acc_s.events.shape == (2,)
    assert bool(jnp.all(acc.events == acc_s.events))


def test_sharded_pallas_session_matches_xla():
    """shard="chips" always takes the XLA gather match; a pallas-impl
    session stays bit-identical under sharding."""
    cfg = InterfaceConfig(chips=2, cores_per_chip=2, neurons_per_core=16,
                          cam_entries_per_core=32, impl="pallas")
    params = fabric.random_connectivity(KEY, cfg)
    spikes = jax.random.bernoulli(jax.random.PRNGKey(5), 0.3,
                                  (2, cfg.cores, cfg.neurons_per_core))
    session = Interface(cfg).compile(params)
    cur, _ = session.run(spikes)
    cur_s, _ = session.run(spikes, shard="chips")
    assert bool(jnp.all(cur == cur_s))


def test_shard_on_flat_config_falls_back():
    cfg = _cfg(chips=1, cores=4)
    params = fabric.random_connectivity(KEY, cfg)
    spikes = jax.random.bernoulli(jax.random.PRNGKey(6), 0.3,
                                  (2, cfg.cores, cfg.neurons_per_core))
    session = Interface(cfg).compile(params)
    cur, _ = session.run(spikes)
    cur_s, _ = session.run(spikes, shard="chips")
    assert bool(jnp.all(cur == cur_s))
    with pytest.raises(ValueError, match="shard"):
        session.run(spikes, shard="cores")


@pytest.mark.slow
def test_shard_map_mesh_path_matches_on_fake_devices():
    """The real shard_map route (8 fake CPU devices, one per chip) keeps
    currents bit-identical; stats agree to float tolerance."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = src
    body = textwrap.dedent("""
        import jax, jax.numpy as jnp
        assert len(jax.devices()) == 8, jax.devices()
        from repro.core import fabric
        from repro.interface import Interface, InterfaceConfig, StepStats
        cfg = InterfaceConfig(chips=4, cores_per_chip=4, neurons_per_core=16,
                              cam_entries_per_core=32)
        params = fabric.random_connectivity(jax.random.PRNGKey(0), cfg)
        sp = jax.random.bernoulli(jax.random.PRNGKey(1), 0.25, (3, 16, 16))
        s = Interface(cfg).compile(params)
        cur, acc = s.run(sp)
        cur_s, acc_s = s.run(sp, shard="chips")
        assert bool(jnp.all(cur == cur_s)), "sharded currents drifted"
        for f in StepStats._fields:
            a, b = float(getattr(acc, f)), float(getattr(acc_s, f))
            assert abs(a - b) <= 1e-4 * max(1.0, abs(a)), (f, a, b)
        spb = jax.random.bernoulli(jax.random.PRNGKey(2), 0.25, (2, 3, 16, 16))
        cb, _ = s.run_batched(spb)
        cbs, _ = s.run_batched(spb, shard="chips")
        assert bool(jnp.all(cb == cbs))
        print("MESH_OK")
    """)
    r = subprocess.run([sys.executable, "-c", body], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "MESH_OK" in r.stdout


# ---- hierarchy tables & routing index ---------------------------------------


def test_build_tables_dispatches_on_chips():
    cfg = _cfg(chips=4, cores=16)
    params = fabric.random_connectivity(KEY, cfg)
    tables = interface_pipeline.build_tables(params, cfg)
    assert isinstance(tables, hierarchy.HierTables)
    assert tables.chips == 4 and tables.cores_per_chip == 4
    flat = interface_pipeline.build_tables(
        params, dataclasses.replace(cfg, chips=1))
    assert not isinstance(flat, hierarchy.HierTables)
    # the subscription matrix is tier-independent
    assert bool(jnp.all(tables.subs == flat.subs))
    assert bool(jnp.all(tables.dest_counts == flat.dest_counts))


def test_stale_chip_tables_raise():
    cfg = _cfg(chips=4, cores=16)
    params = fabric.random_connectivity(KEY, cfg)
    stale = interface_pipeline.build_tables(
        params, dataclasses.replace(cfg, chips=2))
    spikes = jnp.zeros((cfg.cores, cfg.neurons_per_core), bool)
    with pytest.raises(ValueError, match="chips"):
        interface_pipeline.interface_tick(params, spikes, cfg, stale)


def test_routing_index_resolves_chip_core_neuron():
    cfg = _cfg(chips=4, cores=16)
    params = fabric.random_connectivity(KEY, cfg)
    idx = interface_pipeline.build_routing_index(params, cfg)
    n = cfg.neurons_per_core
    core_g = idx.src_idx // n
    assert bool(jnp.all(idx.src_chip == core_g // cfg.cores_per_chip))
    assert bool(jnp.all(idx.src_core == core_g % cfg.cores_per_chip))
    assert int(jnp.max(idx.src_chip)) < cfg.chips
    # flat config: everything lives on chip 0
    flat_idx = interface_pipeline.build_routing_index(
        params, dataclasses.replace(cfg, chips=1))
    assert int(jnp.max(flat_idx.src_chip)) == 0


def test_local_only_connectivity_pays_no_chip_hops():
    """When every CAM entry subscribes to a source on its own chip, the
    inter-chip tier is free (mesh schemes; broadcast still floods)."""
    cfg = _cfg(chips=2, cores=8, entries=16)
    n, cpc = cfg.neurons_per_core, cfg.cores_per_chip
    local_per_chip = cpc * n
    core = jnp.arange(cfg.cores)
    chip = core // cpc
    # each core's entries point at neuron 0 of its chip's first core
    src = jnp.broadcast_to((chip * local_per_chip)[:, None],
                           (cfg.cores, 16))
    params = fabric.FabricParams(
        tags=fabric.int_to_bits(src, cfg.tag_bits),
        valid=jnp.ones((cfg.cores, 16), bool),
        weights=jnp.ones((cfg.cores, 16), jnp.float32),
        targets=jnp.zeros((cfg.cores, 16), jnp.int32))
    spikes = jnp.ones((cfg.cores, n), bool)
    _, st = Interface(cfg).compile(params).step(spikes)
    assert float(st.chip_hops) == 0.0
    assert float(st.chip_latency) == 0.0


# ---- config reconciliation --------------------------------------------------


@pytest.mark.parametrize("make", [fabric.FabricConfig, InterfaceConfig])
def test_chips_config_reconciliation(make):
    cfg = make(chips=4, cores_per_chip=4, neurons_per_core=16)
    assert cfg.cores == 16 and cfg.cores_per_chip == 4
    cfg = make(cores=16, chips=4, neurons_per_core=16)
    assert cfg.cores_per_chip == 4
    assert make(cores=16, neurons_per_core=16).chips == 1
    with pytest.raises(ValueError, match="divide"):
        make(cores=10, chips=4)
    with pytest.raises(ValueError, match="chips"):
        make(chips=0)
    with pytest.raises(ValueError, match="conflicts"):
        make(cores=10, chips=4, cores_per_chip=4)
    with pytest.raises(ValueError, match="stale"):
        make(cores=16, cores_per_chip=5, neurons_per_core=16)
    # replace() with a stale derived cores_per_chip re-derives from cores
    multi = make(chips=4, cores_per_chip=4, neurons_per_core=16)
    flat = dataclasses.replace(multi, chips=1)
    assert flat.cores == 16 and flat.cores_per_chip == 16
    # ... including on a default-sized config (cores resolves to 4, so
    # replace splits those 4 cores instead of growing the fabric)
    split = dataclasses.replace(make(neurons_per_core=16), chips=2)
    assert split.cores == 4 and split.cores_per_chip == 2


def test_from_fabric_roundtrip_carries_chips():
    fab = fabric.FabricConfig(chips=2, cores_per_chip=4, neurons_per_core=16)
    cfg = InterfaceConfig.from_fabric(fab)
    assert cfg.chips == 2 and cfg.cores == 8 and cfg.cores_per_chip == 4
    back = cfg.fabric()
    assert back.chips == 2 and back.cores == 8


def test_ppa_report_hierarchy_section():
    rep = ppa_report(_cfg(chips=4, cores=16))
    assert rep["config"]["chips"] == 4
    assert rep["config"]["cores_per_chip"] == 4
    h = rep["hierarchy"]
    assert h["chip_mesh_dims"] == topology.mesh_dims(4)
    assert h["chip_links"] == topology.num_links(4)
    assert h["chip_hop_latency_ns"] > rep["noc"]["hop_latency_ns"]
    assert h["chip_hop_energy"] > rep["noc"]["hop_energy"]
    # per-chip local mesh, chips x local links in total
    assert rep["noc"]["mesh_dims"] == topology.mesh_dims(4)
    assert rep["noc"]["links"] == 4 * topology.num_links(4)
