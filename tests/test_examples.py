"""Smoke tests for the runnable examples.

Each example runs as a real subprocess (``PYTHONPATH=src``, CPU-pinned)
with tiny configs injected via the examples' documented env knobs, so a
broken import, API drift, or a renamed config fails CI instead of
rotting silently.  The assertions check the examples' own success
markers, not just the exit code.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

EXAMPLES = {
    "quickstart.py": {
        "env": {
            "QUICKSTART_STEPS": "2",
            "QUICKSTART_GEN_STEPS": "4",
        },
        "markers": ("model:", "checkpointed:", "generated:"),
    },
    "snn_multicore.py": {
        "env": {
            "SNN_STEPS": "2",
            "SNN_EVAL_BATCH": "16",
        },
        "markers": ("[snn] accuracy", "[interface]", "[ppa]", "[noc]"),
    },
}


def _run_example(script: str, extra_env: dict) -> subprocess.CompletedProcess:
    env = {
        **os.environ,
        "PYTHONPATH": os.path.join(REPO, "src"),
        "JAX_PLATFORMS": "cpu",
        **extra_env,
    }
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", script)],
        env=env,
        cwd=REPO,
        capture_output=True,
        text=True,
        timeout=600,
    )


@pytest.mark.parametrize(
    "script",
    [pytest.param(s, marks=(pytest.mark.slow,) if s == "snn_multicore.py" else ())
     for s in sorted(EXAMPLES)],
)
def test_example_runs_end_to_end(script, tmp_path):
    spec = EXAMPLES[script]
    env = dict(spec["env"])
    if script == "quickstart.py":
        env["QUICKSTART_CKPT_DIR"] = str(tmp_path / "ckpt")
    proc = _run_example(script, env)
    assert proc.returncode == 0, (
        f"{script} exited {proc.returncode}\n--- stdout ---\n{proc.stdout[-2000:]}"
        f"\n--- stderr ---\n{proc.stderr[-2000:]}"
    )
    for marker in spec["markers"]:
        assert marker in proc.stdout, f"{script}: {marker!r} missing from output"
