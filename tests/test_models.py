"""Model zoo behaviour: family forwards, decode==train, WKV/SSM equivalence."""

import jax
import jax.numpy as jnp
import pytest

from repro.models import lm, mamba, rwkv6
from repro.models.config import (MLAConfig, MambaConfig, ModelConfig,
                                 MoEConfig, RWKVConfig)

KEY = jax.random.PRNGKey(0)
B, T = 2, 16


def _dense():
    return ModelConfig(name="d", family="dense", n_layers=3, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab=100,
                       head_dim=16, qk_norm=True, compute_dtype="float32")


def _gemma():
    return ModelConfig(name="g", family="dense", n_layers=6, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab=100,
                       head_dim=16, sliding_window=8, local_global_ratio=2,
                       post_norms=True, scan_group=3, compute_dtype="float32")


def _mla_moe():
    return ModelConfig(
        name="m", family="moe", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=100, head_dim=16,
        compute_dtype="float32",
        mla=MLAConfig(kv_lora=32, q_lora=48, qk_nope_dim=16, qk_rope_dim=8,
                      v_head_dim=16),
        moe=MoEConfig(num_experts=8, num_shared=1, top_k=2, d_expert=32,
                      first_k_dense=1, d_ff_dense=128, capacity_factor=8.0))


def _rwkv():
    return ModelConfig(name="r", family="rwkv", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=4, d_ff=224, vocab=100,
                       rwkv=RWKVConfig(head_dim=16), compute_dtype="float32")


def _jamba():
    return ModelConfig(
        name="j", family="hybrid", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=100, head_dim=16,
        compute_dtype="float32", mamba=MambaConfig(d_state=8),
        attn_layer_period=4, attn_layer_offset=3,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64, every=2,
                      capacity_factor=8.0), scan_group=4)


FAMILIES = {"dense": _dense, "gemma": _gemma, "mla_moe": _mla_moe,
            "rwkv": _rwkv, "jamba": _jamba}


@pytest.mark.parametrize("fam", list(FAMILIES))
def test_forward_finite(fam):
    cfg = FAMILIES[fam]()
    p = lm.init_model(KEY, cfg)
    toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
    out = lm.forward(p, {"tokens": toks}, cfg, mode="train", remat=False)
    assert out["logits"].shape == (B, T, cfg.vocab)
    assert bool(jnp.isfinite(out["logits"]).all())


@pytest.mark.parametrize("fam", list(FAMILIES))
def test_decode_matches_train(fam):
    """Prefill + token-by-token decode == parallel forward (serving oracle)."""
    cfg = FAMILIES[fam]()
    p = lm.init_model(KEY, cfg)
    toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
    ref = lm.forward(p, {"tokens": toks}, cfg, mode="train", remat=False)
    tp = T - 4
    cache = lm.init_cache(cfg, B, T + 8)
    out = lm.forward(p, {"tokens": toks[:, :tp]}, cfg, mode="prefill",
                     cache=cache, remat=False)
    logits, cache, clen = [out["logits"]], out["cache"], jnp.int32(tp)
    for i in range(tp, T):
        o = lm.forward(p, {"tokens": toks[:, i:i + 1]}, cfg, mode="decode",
                       cache=cache, cache_len=clen, remat=False)
        cache, clen = o["cache"], clen + 1
        logits.append(o["logits"])
    dec = jnp.concatenate(logits, axis=1)
    assert float(jnp.abs(dec - ref["logits"]).max()) < 2e-2


def test_remat_does_not_change_values():
    cfg = _dense()
    p = lm.init_model(KEY, cfg)
    toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
    a = lm.forward(p, {"tokens": toks}, cfg, mode="train", remat=False)
    b = lm.forward(p, {"tokens": toks}, cfg, mode="train", remat=True)
    assert jnp.allclose(a["logits"], b["logits"], atol=1e-5)


def test_wkv_chunked_equals_recurrent():
    B_, T_, H, D = 2, 64, 4, 16
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B_, T_, H, D))
    k = jax.random.normal(ks[1], (B_, T_, H, D))
    v = jax.random.normal(ks[2], (B_, T_, H, D))
    w = jnp.exp(-jnp.minimum(jnp.exp(jax.random.normal(ks[3],
                                                       (B_, T_, H, D)) * .5),
                             4.0))
    u = jax.random.normal(ks[4], (H, D)) * 0.2
    s0 = jnp.zeros((B_, H, D, D))
    o1, s1 = rwkv6.wkv_recurrent(r, k, v, w, u, s0)
    o2, s2 = rwkv6.wkv_chunked(r, k, v, w, u, s0)
    assert jnp.allclose(o1, o2, atol=1e-3)
    assert jnp.allclose(s1, s2, atol=1e-3)


def test_mamba_decode_equals_scan():
    cfg = ModelConfig(name="t", family="hybrid", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=10,
                      mamba=MambaConfig(d_state=8), compute_dtype="float32")
    p = mamba.init_mamba(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, 32)) * 0.3
    import repro.models.mamba as M
    old = M.SCAN_CHUNK
    M.SCAN_CHUNK = 8
    try:
        y, _ = mamba.mamba_apply(p, x, cfg)
    finally:
        M.SCAN_CHUNK = old
    s = {"conv": jnp.zeros((2, 3, 64)), "ssm": jnp.zeros((2, 64, 8))}
    outs = []
    for t in range(16):
        o, s = mamba.mamba_apply(p, x[:, t:t + 1], cfg, state=s)
        outs.append(o)
    assert jnp.allclose(jnp.concatenate(outs, 1), y, atol=1e-3)


def test_flash_attention_equals_naive():
    from repro.models.blocks import flash_attention
    b, t, kh, r, d = 2, 64, 2, 3, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, t, kh, r, d))
    k = jax.random.normal(ks[1], (b, t, kh, d))
    v = jax.random.normal(ks[2], (b, t, kh, d))
    got = flash_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    s = jnp.einsum("bqhrd,bkhd->bhrqk", q, k) / jnp.sqrt(d)
    mask = jnp.tril(jnp.ones((t, t), bool))
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    want = jnp.einsum("bhrqk,bkhd->bqhrd", jax.nn.softmax(s, -1), v)
    assert jnp.allclose(got, want, atol=1e-4)


def test_banded_equals_masked_full():
    from repro.models.blocks import banded_attention, flash_attention
    b, t, kh, r, d, w = 1, 64, 2, 2, 8, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, t, kh, r, d))
    k = jax.random.normal(ks[1], (b, t, kh, d))
    v = jax.random.normal(ks[2], (b, t, kh, d))
    got = banded_attention(q, k, v, window=w)
    want = flash_attention(q, k, v, causal=True, window=w, q_chunk=32,
                           kv_chunk=32)
    assert jnp.allclose(got, want, atol=1e-4)
