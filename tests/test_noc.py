"""NoC subsystem: topology, multicast trees, link loads, placement, fabric."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cam as cam_mod
from repro.core import fabric
from repro.noc import multicast, placement, router, topology
from tests._hypothesis_compat import given, settings, strategies as st

KEY = jax.random.PRNGKey(0)


def _cfg(cores=4, n=16, entries=32, scheme="multicast_tree"):
    return fabric.FabricConfig(cores=cores, neurons_per_core=n,
                               cam_entries_per_core=entries,
                               noc=topology.NocConfig(scheme))


# ---- topology ---------------------------------------------------------------

def test_mesh_dims_cover_cores():
    for cores in (1, 2, 3, 4, 5, 16, 48, 64):
        w, h = topology.mesh_dims(cores)
        assert w * h >= cores and w >= h


def test_hop_matrix_is_manhattan():
    hm = np.asarray(topology.hop_matrix(4))           # 2x2 mesh
    assert np.array_equal(hm, [[0, 1, 1, 2], [1, 0, 2, 1],
                               [1, 2, 0, 1], [2, 1, 1, 0]])
    hm16 = np.asarray(topology.hop_matrix(16))
    assert np.array_equal(hm16, hm16.T)
    assert np.all(np.diag(hm16) == 0)
    assert hm16.max() == 6                            # corner to corner, 4x4


def test_bad_scheme_rejected():
    with pytest.raises(ValueError):
        topology.NocConfig("warp_drive")


# ---- multicast trees --------------------------------------------------------

def test_single_destination_multicast_equals_unicast():
    """One destination -> the tree is the XY path: hops AND link loads match."""
    cores = 16
    s = 64
    src = jax.random.randint(KEY, (s,), 0, cores)
    dest = jax.random.randint(jax.random.PRNGKey(1), (s,), 0, cores)
    mask = jax.nn.one_hot(dest, cores, dtype=jnp.bool_)
    uni = multicast.unicast_hops(mask, src, cores)
    tree = multicast.multicast_tree_hops(mask, src, cores)
    assert bool(jnp.all(uni == tree))
    lu = router.link_loads(mask, src, cores, "unicast")
    lm = router.link_loads(mask, src, cores, "multicast_tree")
    assert bool(jnp.all(lu == lm))


def test_multicast_never_exceeds_unicast():
    cores = 16
    mask = jax.random.bernoulli(KEY, 0.3, (128, cores))
    src = jax.random.randint(jax.random.PRNGKey(2), (128,), 0, cores)
    uni = multicast.unicast_hops(mask, src, cores)
    tree = multicast.multicast_tree_hops(mask, src, cores)
    assert bool(jnp.all(tree <= uni))
    # per physical link too: the tree counts each link at most once
    lu = router.link_loads(mask, src, cores, "unicast")
    lm = router.link_loads(mask, src, cores, "multicast_tree")
    assert bool(jnp.all(lm <= lu))
    assert bool(jnp.all(lm <= 1.0))


def test_link_loads_sum_to_hop_counts():
    """Per-link tables and closed-form hop counts are the same model."""
    cores = 16
    mask = jax.random.bernoulli(KEY, 0.4, (64, cores))
    src = jax.random.randint(jax.random.PRNGKey(3), (64,), 0, cores)
    for scheme, hop_fn in [
        ("unicast", lambda: multicast.unicast_hops(mask, src, cores)),
        ("multicast_tree",
         lambda: multicast.multicast_tree_hops(mask, src, cores)),
        ("broadcast", lambda: multicast.broadcast_tree_hops(src, cores)),
    ]:
        loads = router.link_loads(mask, src, cores, scheme)
        assert loads.shape[1] == topology.num_links(cores)
        assert bool(jnp.all(jnp.sum(loads, axis=1) == hop_fn()))


def test_subscription_matrix_bruteforce():
    cfg = _cfg()
    params = fabric.random_connectivity(KEY, cfg)
    subs = np.asarray(multicast.subscription_matrix(
        params.tags, params.valid, cfg.cores, cfg.neurons_per_core,
        cfg.tag_bits))
    tags = np.asarray(params.tags)
    valid = np.asarray(params.valid)
    w = 1 << np.arange(cfg.tag_bits - 1, -1, -1)
    srcs = (tags * w).sum(-1)                         # (cores, entries)
    total = cfg.cores * cfg.neurons_per_core
    want = np.zeros((cfg.cores, total), bool)
    for c in range(cfg.cores):
        for e in range(cfg.cam.entries):
            if valid[c, e]:
                want[c, srcs[c, e]] = True
    assert np.array_equal(subs, want)


# ---- fabric rewrite ---------------------------------------------------------

def test_currents_bit_identical_across_schemes():
    """Delivery scheme changes accounting only - never the computation."""
    cfg = _cfg()
    params = fabric.random_connectivity(KEY, cfg)
    spikes = jax.random.bernoulli(jax.random.PRNGKey(4), 0.25,
                                  (cfg.cores, cfg.neurons_per_core))
    outs = {}
    for scheme in ("broadcast", "unicast", "multicast_tree"):
        c = dataclasses.replace(cfg, noc=topology.NocConfig(scheme))
        outs[scheme], _ = fabric.step(params, spikes, c)
    assert bool(jnp.all(outs["broadcast"] == outs["unicast"]))
    assert bool(jnp.all(outs["broadcast"] == outs["multicast_tree"]))


def test_broadcast_stats_match_seed_accounting():
    """`scheme="broadcast"` reproduces the seed flood model exactly."""
    cfg = _cfg(scheme="broadcast")
    params = fabric.random_connectivity(KEY, cfg)
    spikes = jax.random.bernoulli(jax.random.PRNGKey(5), 0.25,
                                  (cfg.cores, cfg.neurons_per_core))
    _, st = fabric.step(params, spikes, cfg)
    events = float(jnp.sum(spikes))
    assert float(st.events) == events
    assert float(st.cam_searches) == events * cfg.cores
    # recompute the seed energy formula from first principles
    w = 1 << np.arange(cfg.tag_bits - 1, -1, -1)
    srcs = (np.asarray(params.tags) * w).sum(-1)
    spiking = set(np.flatnonzero(np.asarray(spikes).reshape(-1)))
    hits = sum(int(srcs[c, e] in spiking)
               for c in range(cfg.cores)
               for e in np.flatnonzero(np.asarray(params.valid)[c]))
    searches = events * cfg.cores
    match = hits / searches
    mismatch = float(np.asarray(params.valid).sum(1).mean()) - match
    want = searches * float(cam_mod._energy_jnp(cfg.cam, match, mismatch))
    assert float(st.cam_energy) == pytest.approx(want, rel=1e-5)
    assert float(st.cam_time_ns) == pytest.approx(
        searches * cam_mod.cycle_time_ns(cfg.cam), rel=1e-6)


def test_mesh_accounting_never_exceeds_broadcast():
    cfg = _cfg(cores=16)
    params = fabric.random_connectivity(KEY, cfg, fan_in=0.5)
    spikes = jax.random.bernoulli(jax.random.PRNGKey(6), 0.2,
                                  (cfg.cores, cfg.neurons_per_core))
    _, st_b = fabric.step(params, spikes, dataclasses.replace(
        cfg, noc=topology.NocConfig("broadcast")))
    _, st_m = fabric.step(params, spikes, cfg)
    assert float(st_m.cam_searches) < float(st_b.cam_searches)
    assert float(st_m.noc_hops) < float(st_b.noc_hops)
    assert float(st_m.cam_energy) < float(st_b.cam_energy)
    assert float(st_m.noc_energy) < float(st_b.noc_energy)


def test_prebuilt_tables_match_inline():
    cfg = _cfg()
    params = fabric.random_connectivity(KEY, cfg)
    spikes = jax.random.bernoulli(jax.random.PRNGKey(7), 0.3,
                                  (cfg.cores, cfg.neurons_per_core))
    tables = fabric.noc_tables(params, cfg)
    cur_a, st_a = fabric.step(params, spikes, cfg)
    cur_b, st_b = fabric.step(params, spikes, cfg, tables=tables)
    assert bool(jnp.all(cur_a == cur_b))
    for a, b in zip(st_a, st_b):
        assert bool(jnp.all(a == b))


def test_snn_accounting_reports_noc_stats():
    from repro.models import snn
    cfg = snn.SNNConfig(fabric=_cfg(cores=2, entries=32), d_in=8, d_out=4,
                        t_steps=4)
    params, topo = snn.init_snn(KEY, cfg)
    x = jnp.ones((2, cfg.t_steps, cfg.d_in)) * 3.0
    _, _, stats = snn.snn_forward(params, topo, x, cfg, account=True)
    assert stats is not None
    for field in ("noc_hops", "noc_latency", "noc_energy"):
        assert float(getattr(stats, field)) > 0.0


# ---- placement --------------------------------------------------------------

def test_optimized_placement_not_worse_than_random():
    """On fixed connectivity, greedy never loses to random/identity."""
    cores, n = 16, 16
    cfg = _cfg(cores=cores, n=n, entries=4 * n)
    params = placement.clustered_connectivity(0, cfg, cluster_size=n, fan_in=4)
    a = placement.fanout_adjacency(params, cfg)
    total = cores * n
    greedy = placement.greedy_overlap_placement(a, cores, n)
    c_greedy = placement.traffic_cost(a, greedy, cores, n)
    for seed in (1, 2, 3):
        rand = placement.random_placement(seed, total)
        assert c_greedy <= placement.traffic_cost(a, rand, cores, n)
        assert (placement.cam_search_count(a, greedy, cores, n)
                <= placement.cam_search_count(a, rand, cores, n))
    assert c_greedy <= placement.traffic_cost(
        a, placement.identity_placement(total), cores, n)


def test_greedy_recovers_hidden_clusters():
    """Cluster-per-core workloads collapse to zero inter-core traffic."""
    cores, n = 4, 16
    cfg = _cfg(cores=cores, n=n, entries=4 * n)
    params = placement.clustered_connectivity(3, cfg, cluster_size=n, fan_in=4)
    a = placement.fanout_adjacency(params, cfg)
    greedy = placement.greedy_overlap_placement(a, cores, n)
    assert placement.traffic_cost(a, greedy, cores, n) == 0.0


def test_placement_is_a_permutation():
    cores, n = 4, 8
    cfg = _cfg(cores=cores, n=n, entries=2 * n)
    params = fabric.random_connectivity(KEY, cfg)
    a = placement.fanout_adjacency(params, cfg)
    perm = placement.greedy_overlap_placement(a, cores, n)
    assert sorted(perm.tolist()) == list(range(cores * n))


def test_apply_placement_preserves_currents():
    """Re-placing neurons permutes the current vector, nothing else."""
    cores, n = 4, 8
    cfg = _cfg(cores=cores, n=n, entries=2 * n)
    params = fabric.random_connectivity(KEY, cfg, fan_in=0.7)
    total = cores * n
    spikes = jax.random.bernoulli(jax.random.PRNGKey(8), 0.3, (cores, n))
    cur0, _ = fabric.step(params, spikes, cfg)

    perm = placement.random_placement(11, total)
    p2, cfg2 = placement.apply_placement(params, cfg, perm)
    flat = np.asarray(spikes).reshape(-1)
    sp2 = np.zeros(total, dtype=bool)
    sp2[perm] = flat
    cur2, _ = fabric.step(p2, jnp.asarray(sp2.reshape(cores, n)), cfg2)
    want = np.zeros(total, np.float32)
    want[perm] = np.asarray(cur0).reshape(-1)
    assert np.allclose(np.asarray(cur2).reshape(-1), want, atol=1e-5)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**16))
def test_greedy_placement_is_valid_permutation_property(seed):
    """Optimizer output is a bijection onto [0, total) for any wiring."""
    cores, n = 4, 8
    cfg = _cfg(cores=cores, n=n, entries=2 * n)
    params = fabric.random_connectivity(jax.random.PRNGKey(seed), cfg)
    a = placement.fanout_adjacency(params, cfg)
    perm = placement.greedy_overlap_placement(a, cores, n)
    assert np.array_equal(np.sort(perm), np.arange(cores * n))


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**16))
def test_optimized_cost_not_worse_than_identity_property(seed):
    cores, n = 4, 8
    cfg = _cfg(cores=cores, n=n, entries=4 * n)
    params = placement.clustered_connectivity(seed, cfg, cluster_size=n,
                                              fan_in=3)
    a = placement.fanout_adjacency(params, cfg)
    greedy = placement.greedy_overlap_placement(a, cores, n)
    ident = placement.identity_placement(cores * n)
    assert (placement.traffic_cost(a, greedy, cores, n)
            <= placement.traffic_cost(a, ident, cores, n))


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**16))
def test_traffic_cost_invariant_under_relabeling(seed):
    """Costs depend on where neurons are placed, never on their labels:
    relabeling the input wiring (cluster ids included) and transporting
    the placement through the relabeling leaves every objective fixed."""
    cores, n = 4, 8
    total = cores * n
    rng = np.random.RandomState(seed)
    cfg = _cfg(cores=cores, n=n, entries=4 * n)
    params = placement.clustered_connectivity(seed, cfg, cluster_size=n,
                                              fan_in=3)
    a = placement.fanout_adjacency(params, cfg)
    perm = placement.random_placement(seed + 1, total)

    q = rng.permutation(total)               # old label -> new label
    inv = np.argsort(q)
    a_rel = a[inv][:, inv]                   # a_rel[q[s], q[d]] == a[s, d]
    perm_rel = np.empty(total, dtype=np.int64)
    perm_rel[q] = perm                       # same physical placement
    assert placement.traffic_cost(a_rel, perm_rel, cores, n) == \
        placement.traffic_cost(a, perm, cores, n)
    assert placement.cam_search_count(a_rel, perm_rel, cores, n) == \
        placement.cam_search_count(a, perm, cores, n)


def test_identity_placement_preserves_entry_content():
    cores, n = 2, 8
    cfg = _cfg(cores=cores, n=n, entries=2 * n)
    params = fabric.random_connectivity(KEY, cfg, fan_in=1.0)  # all valid
    p2, cfg2 = placement.apply_placement(
        params, cfg, placement.identity_placement(cores * n))
    assert cfg2.cam.entries == cfg.cam.entries
    assert bool(jnp.all(p2.tags == params.tags))
    assert bool(jnp.all(p2.valid == params.valid))
    assert bool(jnp.all(p2.targets == params.targets))
    assert bool(jnp.all(p2.weights == params.weights))
