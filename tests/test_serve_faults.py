"""Graceful degradation of the serving engine (`repro.serve` + `repro.ft`).

The hardened-engine contract, layer by layer:

* `submit` validates frames host-side: NaN, wrong dtype, wrong rank, and
  wrong fabric shape raise typed `FrameValidationError` (also a
  `ValueError`, so legacy handlers keep working) before any device work;
* `QueueOverflowError` bounds pending work per group at submit time and
  clears once the engine drains - backpressure, not data loss;
* requests older than ``shed_deadline_s`` are shed at flush time as
  typed `DeadlineExceededError`s, and shed ticks keep the accounting
  identity submitted == served + shed + pending closed;
* transient transfer/execute faults retry under the bounded-backoff
  `RetryPolicy` and the served results stay BIT-IDENTICAL to an
  undisturbed engine (commit-after-success: replays cannot
  double-count);
* when retries exhaust, unserved chunks restage onto the backlog before
  `RetriesExhaustedError` propagates - the ledger still closes, and a
  later pump serves the work;
* repeated lane faults walk healthy -> degraded -> quarantined; a
  quarantined lane is masked out of the shared batched step WITHOUT
  recompiling, probes back after its cooldown, and recovers - while the
  other lanes keep serving throughout;
* a tenant carrying a fabric-level `FaultModel` lands in its own group
  (the compat key includes the fault), so clean tenants' results are
  untouched by a faulted neighbor.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ft import (
    ChaosInjector,
    FaultEvent,
    FaultModel,
    FaultPlan,
    RetriesExhaustedError,
)
from repro.interface import Interface
from repro.serve import (
    AdmissionError,
    AdmissionPolicy,
    DeadlineExceededError,
    FrameValidationError,
    QueueOverflowError,
    RetryPolicy,
    ServeEngine,
    ServeError,
    TenantSpec,
    default_connectivity,
)
from tests.conformance.paths import small_config

TICKS = 8


class _FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now


def _engine(**kw):
    kw.setdefault("flush_ticks", TICKS)
    kw.setdefault("flush_deadline_s", 0.0)
    kw.setdefault("sleep", lambda s: None)
    return ServeEngine(**kw)


def _frames(cfg, ticks=TICKS, fill=False):
    return np.full((ticks, cfg.cores, cfg.neurons_per_core), fill, bool)


# ---- typed error hierarchy --------------------------------------------------


def test_error_hierarchy():
    assert issubclass(AdmissionError, ServeError)
    assert issubclass(QueueOverflowError, AdmissionError)
    assert issubclass(DeadlineExceededError, AdmissionError)
    assert issubclass(FrameValidationError, ServeError)
    assert issubclass(FrameValidationError, ValueError)


# ---- frame validation at submit ---------------------------------------------


def test_submit_rejects_malformed_frames():
    cfg = small_config("binary_tree", "broadcast")
    engine = _engine()
    engine.register(TenantSpec("t0", cfg))
    good = _frames(cfg).astype(np.float32)
    nan = good.copy()
    nan[0, 0, 0] = np.nan
    with pytest.raises(FrameValidationError, match="non-finite"):
        engine.submit("t0", nan)
    with pytest.raises(FrameValidationError, match="dtype"):
        engine.submit("t0", good.astype(np.complex64))
    with pytest.raises(FrameValidationError, match="ticks >= 1"):
        engine.submit("t0", good[0])  # rank 2
    with pytest.raises(FrameValidationError, match="ticks >= 1"):
        engine.submit("t0", good[:0])  # empty stream
    with pytest.raises(FrameValidationError, match="do not match the group"):
        engine.submit("t0", np.zeros((TICKS, cfg.cores + 1, cfg.neurons_per_core)))
    assert engine.ticks_submitted("t0") == 0, "rejected frames must not be counted"
    # finite floats are accepted and cast to bool
    engine.submit("t0", good)
    assert engine.drain() == TICKS


def test_queue_overflow_backpressure_clears_after_drain():
    cfg = small_config("binary_tree", "broadcast")
    engine = _engine(policy=AdmissionPolicy(max_pending_frames=2 * TICKS))
    engine.register(TenantSpec("t0", cfg))
    engine.submit("t0", _frames(cfg))
    engine.submit("t0", _frames(cfg))
    with pytest.raises(QueueOverflowError, match="max_pending_frames"):
        engine.submit("t0", _frames(cfg))
    acct = engine.accounting()
    assert acct["closes"] and acct["tenants"]["t0"]["pending"] == 2 * TICKS
    engine.drain()
    engine.submit("t0", _frames(cfg))  # capacity restored
    assert engine.drain() == TICKS
    assert engine.accounting()["closes"]


# ---- deadline shedding ------------------------------------------------------


def test_deadline_shedding_is_typed_and_accounted():
    cfg = small_config("binary_tree", "broadcast")
    clock = _FakeClock()
    engine = _engine(
        policy=AdmissionPolicy(shed_deadline_s=1.0),
        clock=clock,
        keep_currents=True,
    )
    engine.register(TenantSpec("t0", cfg))
    engine.submit("t0", _frames(cfg, fill=True))
    clock.now = 2.0  # the queued request ages past the shed deadline
    engine.submit("t0", _frames(cfg, fill=True))
    assert engine.drain() == TICKS, "only the fresh request is served"
    assert engine.ticks_shed("t0") == TICKS
    errors = engine.shed_errors()
    assert len(errors) == 1 and isinstance(errors[0], DeadlineExceededError)
    assert "t0" in str(errors[0])
    acct = engine.accounting()
    assert acct["closes"]
    assert acct["tenants"]["t0"] == {
        "submitted": 2 * TICKS,
        "served": TICKS,
        "shed": TICKS,
        "pending": 0,
    }
    assert engine.registry.counter("serve.shed_ticks").value == TICKS
    rec = engine.serve_report()[0]
    assert rec["shed_ticks"] == TICKS and rec["submitted"] == 2 * TICKS


# ---- transient-fault retries ------------------------------------------------


def _mirrored_engines(cfg, specs, **chaos_kw):
    """One chaotic engine and one undisturbed twin over the same specs."""
    chaotic = _engine(keep_currents=True, **chaos_kw)
    calm = _engine(keep_currents=True)
    for spec in specs:
        chaotic.register(spec)
        calm.register(spec)
    return chaotic, calm


def test_retried_faults_stay_bit_identical_to_calm_engine():
    cfg = small_config("binary_tree", "multicast_tree")
    specs = [
        TenantSpec("t0", cfg, scenario="sparse_poisson", seed=0),
        TenantSpec("t1", cfg, scenario="hotspot_core", seed=1),
    ]
    plan = FaultPlan(
        events=(
            FaultEvent(round=1, kind="transfer_fail", times=2),
            FaultEvent(round=2, kind="execute_fail", times=2),
            FaultEvent(round=2, kind="slow_device", times=1, delay_s=0.0),
        )
    )
    chaotic, calm = _mirrored_engines(
        cfg,
        specs,
        chaos=ChaosInjector(plan, sleep=lambda s: None),
        retry=RetryPolicy(max_retries=3, backoff_base_s=0.0),
    )
    for round_ in range(3):
        for engine in (chaotic, calm):
            for spec in specs:
                engine.submit_scenario(spec.name, TICKS)
            engine.pump(force=True)
    assert chaotic.chaos.exhausted()
    assert chaotic.registry.counter("serve.retries").value == 4
    assert chaotic.registry.counter("serve.retry_recoveries").value == 2
    for spec in specs:
        assert np.array_equal(chaotic.currents(spec.name), calm.currents(spec.name)), (
            f"{spec.name}: retried currents drifted from the calm engine"
        )
        a, b = chaotic.tenant_stats(spec.name), calm.tenant_stats(spec.name)
        for field, va in a._asdict().items():
            assert float(np.asarray(va)) == float(np.asarray(getattr(b, field)))
    assert chaotic.accounting()["closes"]


def test_retries_exhausted_restages_then_recovers():
    cfg = small_config("binary_tree", "broadcast")
    plan = FaultPlan(events=(FaultEvent(round=1, kind="transfer_fail", times=6),))
    engine = _engine(
        chaos=ChaosInjector(plan, sleep=lambda s: None),
        retry=RetryPolicy(max_retries=1, backoff_base_s=0.0),
    )
    engine.register(TenantSpec("t0", cfg))
    engine.submit("t0", _frames(cfg, fill=True))
    hard = 0
    while True:  # 6 charges / 2 attempts per pump: fails thrice, then heals
        try:
            engine.drain()
            break
        except RetriesExhaustedError:
            hard += 1
            acct = engine.accounting()
            assert acct["closes"], "ledger must close at every failure point"
            assert acct["tenants"]["t0"]["pending"] == TICKS, "work restaged"
    assert hard == 3
    assert engine.chaos.exhausted()
    assert engine.ticks_served("t0") == TICKS
    assert engine.registry.counter("serve.retries_exhausted").value == 3
    assert engine.accounting()["closes"]


# ---- lane health machine ----------------------------------------------------


def test_quarantine_masks_lane_without_recompile_then_recovers():
    cfg = small_config("binary_tree", "multicast_tree")
    specs = [
        TenantSpec("t0", cfg, scenario="sparse_poisson", seed=0),
        TenantSpec("t1", cfg, scenario="hotspot_core", seed=1),
        TenantSpec("t2", cfg, scenario="mixture", seed=2),
    ]
    plan = FaultPlan(events=(FaultEvent(round=1, kind="lane_fault", tenant="t1", times=2),))
    from repro.serve import HealthPolicy

    engine = _engine(
        chaos=ChaosInjector(plan, sleep=lambda s: None),
        health=HealthPolicy(quarantine_after=2, quarantine_rounds=2, recover_after=1),
    )
    for spec in specs:
        engine.register(spec)
    assert len(engine.groups) == 1
    group = next(iter(engine.groups.values()))

    states = []
    for _ in range(6):
        for spec in specs:
            engine.submit_scenario(spec.name, TICKS)
        engine.pump(force=True)
        states.append(engine.lane_health("t1"))
        # healthy lanes never stall behind the sick one
        assert engine.ticks_served("t0") == engine.ticks_submitted("t0")
    # round 1: first fault degrades; round 2: second fault quarantines
    # (masked the same pump); round 3: cooldown (still masked, backlog
    # retained); round 4: cooldown expires at the pump's advance - the
    # lane probes, serves cleanly, and recovers; rounds 5-6: healthy
    assert states == ["degraded", "quarantined", "quarantined", "healthy", "healthy", "healthy"]
    assert engine.registry.counter("serve.quarantines").value == 1
    assert engine.registry.counter("serve.probes").value == 1
    assert engine.registry.counter("serve.recoveries").value == 1
    engine.drain()  # quarantine-era backlog finally served
    assert engine.ticks_served("t1") == engine.ticks_submitted("t1")
    assert engine.accounting()["closes"]
    batched = group.session._masked_cache["run_batched"]
    assert batched._cache_size() == 1, "quarantine masking must not recompile"
    fleet = engine.serve_report()[-1]
    assert fleet["faults"]["quarantines"] == 1
    assert fleet["faults"]["injected"] >= 2
    assert "recovery_ms_p50" in fleet


def test_lane_fault_on_unknown_tenant_is_counted_not_fatal():
    cfg = small_config("binary_tree", "broadcast")
    plan = FaultPlan(events=(FaultEvent(round=1, kind="lane_fault", tenant="ghost"),))
    engine = _engine(chaos=ChaosInjector(plan, sleep=lambda s: None))
    engine.register(TenantSpec("t0", cfg))
    engine.submit("t0", _frames(cfg))
    assert engine.drain() == TICKS
    assert engine.registry.counter("serve.faults.unknown_lane").value == 1
    assert engine.lane_health("t0") == "healthy"
    with pytest.raises(KeyError, match="unknown tenant"):
        engine.lane_health("ghost")


# ---- fabric faults inside the serving tier ----------------------------------


def test_fabric_faulted_tenant_gets_own_group_and_clean_stay_identical():
    cfg = small_config("binary_tree", "multicast_tree")
    fault = FaultModel(drop_rate=0.3, seed=7)
    specs = [
        TenantSpec("clean0", cfg, scenario="sparse_poisson", seed=0),
        TenantSpec("clean1", cfg, scenario="hotspot_core", seed=1),
        TenantSpec("lossy", cfg, scenario="sparse_poisson", seed=0, fault=fault),
    ]
    engine = _engine(keep_currents=True)
    for spec in specs:
        engine.register(spec)
    assert len(engine.groups) == 2, "the fault must be part of the compat key"
    for spec in specs:
        engine.submit_scenario(spec.name, TICKS)
        engine.submit_scenario(spec.name, TICKS)
    engine.drain()
    # clean tenants: bit-identical to their solo sessions, untouched by
    # the lossy neighbor; the lossy tenant matches its own faulted solo
    params = default_connectivity(cfg, 0)
    for name, solo_fault in (("clean0", None), ("lossy", fault)):
        spec = next(s for s in specs if s.name == name)
        stream = jnp.concatenate([spec.stream(TICKS, round=r) for r in range(2)])
        solo = Interface(cfg).compile(params, fault=solo_fault)
        kw = {"fault_tick0": 0} if solo_fault is not None else {}
        cur, _ = solo.run(stream, **kw)
        assert np.array_equal(engine.currents(name), np.asarray(cur)), name
    # the drop actually bit: lossy serves fewer events than its clean twin
    lossy = float(np.asarray(engine.tenant_stats("lossy").events))
    clean = float(np.asarray(engine.tenant_stats("clean0").events))
    assert lossy < clean
    rec = next(r for r in engine.serve_report() if r.get("tenant") == "lossy")
    assert rec["fault"]["drop_rate"] == pytest.approx(0.3)
