"""Arbitration architectures: closed forms, DES, paper tables, properties."""

import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import ppa
from repro.core.arbiter import (Arbiter, ArbiterConfig, SCHEMES,
                                batched_tick_latency, burst_latency_units,
                                encode_energy_units, sparse_latency_units,
                                area_units)

KEY = jax.random.PRNGKey(0)


def _des_frame_latency(arb: Arbiter, frame) -> float:
    """Reference: completion time of a frame via the event-loop simulator."""
    req = jnp.where(frame, 0.0, jnp.inf).astype(jnp.float32)
    grants = arb.simulate(req)
    return float(jnp.where(jnp.any(frame),
                           jnp.max(jnp.where(jnp.isfinite(grants), grants,
                                             0.0)), 0.0))


# ---- paper Table I/II/III closed forms -------------------------------------

@pytest.mark.parametrize("n,expected", [(64, 10), (256, 14)])
def test_table1_binary_sparse(n, expected):
    assert sparse_latency_units("binary_tree", n) == expected


@pytest.mark.parametrize("n,expected", [(64, 6), (256, 8)])
def test_table1_hat_sparse(n, expected):
    assert sparse_latency_units("hier_tree", n) == expected


@pytest.mark.parametrize("n,expected", [(64, 32.5), (256, 128.5)])
def test_table1_token_ring_sparse(n, expected):
    assert sparse_latency_units("token_ring", n) == expected


@pytest.mark.parametrize("n,expected", [(64, 71), (256, 275)])
def test_table2_hat_burst(n, expected):
    assert burst_latency_units("hier_tree", n) == pytest.approx(expected)


@pytest.mark.parametrize("n,expected", [(64, 9), (256, 12)])
def test_table3_hat_area(n, expected):
    assert area_units("hier_tree", n) == pytest.approx(expected)


def test_measured_ns_reproduced_at_design_points():
    """The affine calibration reproduces every published ns/area value."""
    for scheme, (m64, m256) in ppa.MEASURED_SPARSE_NS.items():
        assert ppa.sparse_latency_ns(scheme, 64) == pytest.approx(m64)
        assert ppa.sparse_latency_ns(scheme, 256) == pytest.approx(m256)
    for scheme, (m64, m256) in ppa.MEASURED_BURST_NS.items():
        assert ppa.burst_latency_ns(scheme, 64) == pytest.approx(m64)
    for scheme, (m64, m256) in ppa.MEASURED_AREA_NORM.items():
        assert ppa.area_normalized(scheme, 256) == pytest.approx(m256)


def test_headline_claim_sparse_latency_reduction():
    """'up to 78.3% lower latency': HAT 2.0ns vs HTR 9.2ns at N=256."""
    hat = ppa.sparse_latency_ns("hier_tree", 256)
    htr = ppa.sparse_latency_ns("hier_ring", 256)
    assert 1 - hat / htr == pytest.approx(0.783, abs=0.005)


# ---- discrete-event simulation ---------------------------------------------

@pytest.mark.parametrize("scheme", SCHEMES)
@pytest.mark.parametrize("n", [64, 256])
def test_des_sparse_matches_theory(scheme, n):
    arb = Arbiter(ArbiterConfig(scheme=scheme, n=n))
    sim = float(arb.sparse_event_latency(KEY, num_trials=n))
    theory = sparse_latency_units(scheme, n)
    # ring schemes: random-position sampling noise; trees: exact
    tol = 0.12 if "ring" in scheme else 1e-6
    assert sim == pytest.approx(theory, rel=tol)


@pytest.mark.parametrize("scheme", SCHEMES)
@pytest.mark.parametrize("n", [64, 256])
def test_des_burst_matches_theory(scheme, n):
    arb = Arbiter(ArbiterConfig(scheme=scheme, n=n))
    sim = float(arb.burst_latency())
    theory = burst_latency_units(scheme, n)
    assert sim == pytest.approx(theory, rel=0.08)


def test_hat_wins_sparse_and_competitive_burst():
    """The paper's central comparison at N=256."""
    sparse = {s: sparse_latency_units(s, 256) for s in SCHEMES}
    assert min(sparse, key=sparse.get) == "hier_tree"
    burst = {s: burst_latency_units(s, 256) for s in SCHEMES}
    assert burst["hier_tree"] < 1.1 * burst["token_ring"]
    area = {s: area_units(s, 256) for s in SCHEMES}
    assert min(area, key=area.get) == "hier_tree"


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 63), st.integers(0, 63))
def test_des_deterministic(a, b):
    """Same request set -> identical grants (no analog nondeterminism)."""
    arb = Arbiter(ArbiterConfig(scheme="hier_tree", n=64))
    req = jnp.full((64,), jnp.inf).at[a].set(0.0).at[b].set(0.0)
    g1, g2 = arb.simulate(req), arb.simulate(req)
    assert bool(jnp.all(g1 == g2))


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 63), min_size=1, max_size=20, unique=True))
def test_all_requests_served_exactly_once(reqs):
    for scheme in ("hier_tree", "token_ring", "binary_tree"):
        arb = Arbiter(ArbiterConfig(scheme=scheme, n=64))
        req = jnp.full((64,), jnp.inf)
        for r in reqs:
            req = req.at[r].set(0.0)
        grants = arb.simulate(req)
        served = jnp.isfinite(grants)
        assert bool(jnp.all(served[jnp.array(reqs)])), scheme
        inactive = jnp.delete(served, jnp.array(reqs))
        assert not bool(jnp.any(inactive)), scheme


# ---- vectorized tick-latency policies vs. the simulator ---------------------

@pytest.mark.parametrize("scheme", SCHEMES)
@pytest.mark.parametrize("n", [2, 4, 16, 64, 256])
def test_tick_latency_matches_des_sparse_and_burst(scheme, n):
    """The per-tick policy is bit-exact with the event loop on the frames
    the paper characterizes: isolated sparse events and a full burst."""
    cfg = ArbiterConfig(scheme=scheme, n=n)
    arb = Arbiter(cfg)
    frames = [jnp.zeros((n,), bool).at[p].set(True)
              for p in sorted({0, 1, n // 2, n - 1})]
    frames += [jnp.ones((n,), bool), jnp.zeros((n,), bool)]
    fast = batched_tick_latency(cfg, jnp.stack(frames))
    for i, frame in enumerate(frames):
        assert float(fast[i]) == _des_frame_latency(arb, frame), (scheme, i)


@pytest.mark.parametrize("scheme", SCHEMES)
def test_tick_latency_falls_back_to_des_on_non_square_n(scheme):
    """n=8: sqrt(8) is not integral, so hier_ring's closed form does not
    apply and the dispatcher must fall back to the simulator."""
    cfg = ArbiterConfig(scheme=scheme, n=8)
    arb = Arbiter(cfg)
    frames = [jnp.zeros((8,), bool).at[p].set(True) for p in range(8)]
    frames.append(jnp.ones((8,), bool))
    fast = batched_tick_latency(cfg, jnp.stack(frames))
    for i, frame in enumerate(frames):
        assert float(fast[i]) == _des_frame_latency(arb, frame), (scheme, i)


@settings(max_examples=12, deadline=None)
@given(st.lists(st.integers(0, 63), min_size=0, max_size=48, unique=True))
def test_tick_latency_matches_des_random_frames(reqs):
    frame = jnp.zeros((64,), bool)
    for r in reqs:
        frame = frame.at[r].set(True)
    for scheme in SCHEMES:
        cfg = ArbiterConfig(scheme=scheme, n=64)
        fast = batched_tick_latency(cfg, frame[None, :])
        assert float(fast[0]) == _des_frame_latency(Arbiter(cfg), frame), scheme


def test_hat_encode_energy_below_flat():
    """HAT re-encodes higher levels only on cluster switch (paper §III-B)."""
    seq = jnp.arange(64)  # address-ordered drain
    hat = float(encode_energy_units("hier_tree", 64, seq))
    flat = float(encode_energy_units("binary_tree", 64, seq))
    assert hat < flat  # 6 lines always vs ~2.6 expected
    assert hat == pytest.approx(2 + 2 / 4 + 2 / 16, rel=0.2)
