"""CAM model: functional search semantics + behavioural PPA calibration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import cam, ppa

KEY = jax.random.PRNGKey(0)


# ---- functional semantics ---------------------------------------------------

@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(st.integers(1, 32), st.integers(2, 12), st.integers(0, 2 ** 31 - 1))
def test_search_matches_bruteforce(entries, bits, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    tags = jax.random.bernoulli(k1, 0.5, (entries, bits)).astype(jnp.int32)
    valid = jax.random.bernoulli(k2, 0.8, (entries,))
    query = jax.random.bernoulli(k3, 0.5, (bits,)).astype(jnp.int32)
    got = cam.search(tags, valid, query)
    want = np.array([bool(v) and bool((np.array(t) == np.array(query)).all())
                     for t, v in zip(tags, valid)])
    assert np.array_equal(np.array(got), want)


def test_first_match_and_write():
    arr = cam.CamArray(cam.CamConfig(entries=8, bits=4))
    arr = arr.write(3, [1, 0, 1, 1]).write(5, [1, 0, 1, 1])
    assert int(arr.first_match(jnp.array([1, 0, 1, 1]))) == 3
    assert int(arr.first_match(jnp.array([0, 0, 0, 0]))) == 8  # no match
    m = arr.search(jnp.array([1, 0, 1, 1]))
    assert int(m.sum()) == 2  # multi-match fan-out (synapse semantics)


def test_mismatch_bit_counts():
    tags = jnp.array([[0, 0, 0], [1, 1, 1], [1, 0, 0]])
    q = jnp.array([1, 0, 0])
    counts = cam.mismatch_bit_counts(tags, q)
    assert counts.tolist() == [1, 2, 0]


# ---- paper-calibrated PPA ----------------------------------------------------

@pytest.mark.parametrize("entries", [16, 512])
def test_cycle_time_improvement_matches_paper(entries):
    assert cam.cycle_improvement(entries) == pytest.approx(
        ppa.CAM_CYCLE_IMPROVEMENT[entries], abs=1e-3)


def test_cscd_monotonic_mechanism_stack():
    """Each mechanism must strictly reduce cycle time (Fig. 10 ordering)."""
    e = 512
    t_conv = cam.cycle_time_ns(cam.CamConfig(e, cscd=False, feedback=False,
                                             speculative=False))
    t_cscd = cam.cycle_time_ns(cam.CamConfig(e, feedback=False,
                                             speculative=False))
    t_fb = cam.cycle_time_ns(cam.CamConfig(e, speculative=False))
    t_full = cam.cycle_time_ns(cam.CamConfig(e))
    assert t_conv > t_cscd > t_fb > t_full


def test_energy_savings_match_paper_endpoints():
    assert cam.energy_saving("all_match") == pytest.approx(0.358, abs=2e-3)
    assert cam.energy_saving("all_mismatch") == pytest.approx(0.402, abs=2e-3)


def test_energy_random_documented_gap():
    """Reproduction finding (DESIGN.md/cam.py): the paper's 46.7% random-
    search saving is not consistent with its own endpoint numbers under a
    linear energy model; the calibrated model lands at ~40%."""
    s = cam.energy_saving("random")
    assert 0.38 < s < 0.42
    assert s < ppa.CAM_ENERGY_SAVING["random"]


@pytest.mark.parametrize("entries", [16, 512])
def test_area_matches_paper(entries):
    base, prop = ppa.CAM_AREA_UM2[entries]
    assert cam.area_um2(cam.CamConfig(entries, cscd=False, feedback=False,
                                      speculative=False)) == pytest.approx(base, rel=1e-3)
    assert cam.area_um2(cam.CamConfig(entries)) == pytest.approx(prop, rel=1e-3)


def test_area_overhead_shrinks_with_scale():
    """+8.9% at 16 entries -> +5.2% at 512 (paper §IV-D 'Area')."""
    def ovh(e):
        b = cam.area_um2(cam.CamConfig(e, cscd=False, feedback=False,
                                       speculative=False))
        p = cam.area_um2(cam.CamConfig(e))
        return p / b - 1
    assert ovh(16) == pytest.approx(0.089, abs=0.005)
    assert ovh(512) == pytest.approx(0.052, abs=0.005)
    assert ovh(512) < ovh(16)


def test_spec_sense_probability_formula():
    """Paper §IV-B: last 3 of 10 bits -> 87.6%."""
    assert ppa.spec_sense_close_probability(10, 3) == pytest.approx(0.876,
                                                                    abs=5e-4)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 16), st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
def test_spec_sense_probability_monte_carlo(bits, sense, seed):
    """Empirical frequency matches the EXACT conditional closed form.

    (The paper's published expression approximates it: equal to within
    2^-N, i.e. indistinguishable at the paper's N=10 design point but
    visibly different at toy widths - a documented repro finding.)"""
    if sense >= bits:
        sense = bits - 1
    rng = np.random.default_rng(seed)
    stored = rng.integers(0, 2, (4000, bits))
    query = rng.integers(0, 2, (4000, bits))
    mism = (stored != query)
    is_mismatch = mism.any(axis=1)
    closed = mism[:, -sense:].any(axis=1)
    if is_mismatch.sum() == 0:
        return
    emp = (closed & is_mismatch).sum() / is_mismatch.sum()
    pred = ppa.spec_sense_close_probability_exact(bits, sense)
    assert emp == pytest.approx(pred, abs=0.05)
    # paper formula agrees with the exact one at the paper's design point
    assert ppa.spec_sense_close_probability(10, 3) == pytest.approx(
        ppa.spec_sense_close_probability_exact(10, 3), abs=1e-3)
