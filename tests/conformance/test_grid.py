"""Differential conformance grid: scenarios x arbiters x NoC x paths.

Fast run: every registered scenario exercises all six execution paths on
a deterministically sampled pair of (arbiter, NoC) grid cells, plus a
`_hypothesis_compat`-sampled oracle-vs-event sweep over the 5x3 cell
grid.  The full grid (every cell, every scenario) runs under ``-m slow``.
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import traffic
from repro.core import fabric
from tests._hypothesis_compat import given, settings, strategies as st
from tests.conformance import paths

TICKS = 3
SEED = 17
SCENARIOS = traffic.scenario_names()


def _sampled_cells(index: int, count: int = 2):
    """Deterministic per-scenario grid cells; together they cover most of
    the 15-cell grid across the scenario list (full coverage under slow)."""
    return [paths.GRID[(count * index + 7 * k) % len(paths.GRID)] for k in range(count)]


def _setup(arb_scheme, noc_scheme, scenario, ticks=TICKS):
    cfg = paths.small_config(arb_scheme, noc_scheme)
    params = fabric.random_connectivity(jax.random.PRNGKey(SEED), cfg)
    spikes = traffic.generate(scenario, SEED + 1, ticks, cfg)
    return cfg, params, spikes


# The heavyweight scenarios (10-35s each: dense or clustered streams hit
# the sparse paths' worst case) conform under ``-m slow``; the fast lane
# keeps the cheap ones for per-commit path coverage.
_SLOW_SCENARIOS = {"clustered", "mixture", "dvs_trace", "hotspot_core",
                   "synchronized_burst"}


@pytest.mark.parametrize(
    "scenario",
    [pytest.param(s, marks=(pytest.mark.slow,) if s in _SLOW_SCENARIOS else ())
     for s in SCENARIOS],
)
def test_scenario_conforms_across_all_paths(scenario):
    """Acceptance: currents bit-identical across oracle / event / pallas /
    pallas_sparse / chips>1 / sharded-vmap for every registered scenario."""
    index = SCENARIOS.index(scenario)
    for arb_scheme, noc_scheme in _sampled_cells(index):
        cfg, params, spikes = _setup(arb_scheme, noc_scheme, scenario)
        results = paths.run_paths(cfg, params, spikes)
        paths.assert_conformant(results, label=f"{scenario}/{arb_scheme}/{noc_scheme}")


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**16))
def test_sampled_grid_oracle_vs_event(sample):
    """Sampled 5x3 grid cells: oracle and event paths agree on every
    StepStats field (the cheap pair, so the sampler can range widely) -
    `assert_conformant` covers the transport fields too, since both
    paths share the flat partitioning."""
    arb_scheme, noc_scheme = paths.GRID[sample % len(paths.GRID)]
    scenario = SCENARIOS[sample % len(SCENARIOS)]
    cfg, params, spikes = _setup(arb_scheme, noc_scheme, scenario, ticks=2)
    results = paths.run_paths(cfg, params, spikes, names=("oracle", "event"))
    paths.assert_conformant(results, label=f"{scenario}/{arb_scheme}/{noc_scheme}")


@pytest.mark.slow
@pytest.mark.parametrize("noc_scheme", paths.NOC_SCHEMES)
def test_full_grid(noc_scheme):
    """The full conformance grid: every scenario through every arbiter
    for this NoC scheme, all six paths.  Sessions are compiled once per
    grid cell and reused across scenarios (spikes are data, not trace)."""
    from repro.interface import Interface

    for arb_scheme in paths.ARBITER_SCHEMES:
        cfg = paths.small_config(arb_scheme, noc_scheme)
        params = fabric.random_connectivity(jax.random.PRNGKey(SEED), cfg)
        session = Interface(cfg).compile(params)
        session_p = Interface(dataclasses.replace(cfg, impl="pallas")).compile(params)
        session_s = Interface(dataclasses.replace(cfg, impl="pallas_sparse")).compile(params)
        session_c = Interface(dataclasses.replace(cfg, chips=2)).compile(params)
        for scenario in SCENARIOS:
            spikes = traffic.generate(scenario, SEED + 1, TICKS, cfg)
            results = {
                "oracle": paths.run_oracle(cfg, params, spikes),
                "event": session.run(spikes),
                "pallas": session_p.run(spikes),
                "pallas_sparse": session_s.run(spikes),
                "chips2": session_c.run(spikes),
                "chips2_sharded": session_c.run(spikes, shard="chips"),
            }
            paths.assert_conformant(results, label=f"{scenario}/{arb_scheme}/{noc_scheme}")


def test_traffic_matches_expected_rate():
    """Scenario rate metadata is honest: empirical rate within 5 sigma."""
    cores, n, ticks = 4, 16, 256
    for scenario in SCENARIOS:
        spikes = traffic.generate(scenario, 3, ticks, (cores, n))
        rate = traffic.expected_rate(scenario, cores, n)
        emp = float(jnp.mean(spikes))
        # mixture/burst frames are correlated within a tick; widen by the
        # per-tick worst case instead of assuming independent samples
        sigma = max((rate * (1.0 - rate) / (ticks * cores * n)) ** 0.5, 0.5 / ticks**0.5 * 0.1)
        assert abs(emp - rate) < 5.0 * sigma + 0.02, (scenario, emp, rate)


def test_generators_are_jit_able():
    for scenario in SCENARIOS:
        spec = traffic.get_scenario(scenario)
        fn = jax.jit(lambda key, s=spec: s.generate(key, 4, 4, 16, **s.defaults))
        out = fn(jax.random.PRNGKey(0))
        assert out.shape == (4, 4, 16) and out.dtype == jnp.bool_


def test_scenario_registry_validation():
    with pytest.raises(KeyError, match="sparse_poisson"):
        traffic.get_scenario("no_such_scenario")
    with pytest.raises(ValueError, match="valid"):
        traffic.generate("sparse_poisson", 0, 2, (4, 16), bogus=1)
    with pytest.raises(ValueError, match="leaf"):
        traffic.generate("mixture", 0, 2, (4, 16), components=(("mixture", 1.0),))
    with pytest.raises(ValueError, match="does not match"):
        traffic.register_scenario(
            "misnamed", dataclasses.replace(traffic.get_scenario("sparse_poisson"))
        )
    spec = traffic.get_scenario("sparse_poisson")
    with pytest.raises(ValueError, match="already registered"):
        traffic.register_scenario("sparse_poisson", spec)
