"""Cross-path conformance grid: every registered traffic scenario through
every execution path of the fabric (dense oracle, event-driven session,
pallas kernels, chips>1 flat, sharded vmap), asserted equivalent under
the documented tolerance contract (see `tests.conformance.paths`)."""
