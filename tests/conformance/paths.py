"""Execution-path runners and the stats tolerance contract.

One fabric, six ways to execute it:

  oracle          dense tag-vs-every-source CAM sweep + per-core DES
                  arbiter (`interface_tick(oracle=True)`), eager per tick
  event           the event-driven `InterfaceSession.run` scan
  pallas          same session with ``impl="pallas"`` (cam_search /
                  hat_encode kernels, interpret mode off-TPU)
  pallas_sparse   same session with ``impl="pallas_sparse"`` (the fused
                  `repro.kernels.sparse_tick` event path; the grid's
                  burst scenarios overflow its event buffers and so also
                  exercise the dense fallback branch)
  chips2          the same fabric partitioned into 2 chips
                  (`HierTables` two-tier NoC), unsharded scan
  chips2_sharded  ``run(shard="chips")`` - per-chip tick mapped under
                  vmap on single-device hosts (shard_map on real meshes)

Conformance contract (asserted by `assert_conformant`):

  * currents are BIT-IDENTICAL across all six paths, for every
    scenario, arbiter scheme, and NoC scheme;
  * partition-independent stats (`PATH_INVARIANT_FIELDS`: events,
    encode latency/energy, CAM searches/energy/time) agree across all
    paths - counts exactly, energies within `REL_TOL`;
  * NoC/chip transport stats (`TRANSPORT_FIELDS`) agree within each
    partitioning (flat paths with flat paths, chip paths with chip
    paths) but legitimately differ across partitionings: chips>1 moves
    traffic from the core mesh onto the inter-chip tier by design.
"""

from __future__ import annotations

import dataclasses
import itertools

import jax
import pytest

from repro.core import fabric
from repro.interface import Interface, StepStats
from repro.interface import pipeline as interface_pipeline
from repro.noc import topology

ARBITER_SCHEMES = ("binary_tree", "greedy_tree", "token_ring", "hier_ring", "hier_tree")
NOC_SCHEMES = ("broadcast", "unicast", "multicast_tree")
GRID = tuple(itertools.product(ARBITER_SCHEMES, NOC_SCHEMES))

# Stats that do not depend on how the fabric is partitioned or executed.
PATH_INVARIANT_FIELDS = (
    "events",
    "encode_latency",
    "encode_energy",
    "cam_searches",
    "cam_energy",
    "cam_time_ns",
)
# Transport stats: comparable only within one chip partitioning.
TRANSPORT_FIELDS = (
    "noc_hops",
    "noc_latency",
    "noc_energy",
    "chip_hops",
    "chip_latency",
    "chip_energy",
)
EXACT_FIELDS = ("events", "cam_searches", "noc_hops", "chip_hops")
REL_TOL = 1e-6

FLAT_PATHS = ("oracle", "event", "pallas", "pallas_sparse")
CHIP_PATHS = ("chips2", "chips2_sharded")


def small_config(arb_scheme, noc_scheme, cores=4, n=16, entries=32):
    return fabric.FabricConfig(
        cores=cores,
        neurons_per_core=n,
        cam_entries_per_core=entries,
        scheme=arb_scheme,
        noc=topology.NocConfig(noc_scheme),
    )


def run_oracle(cfg, params, spikes):
    """Eager per-tick reference: dense CAM sweep + DES arbiter."""
    tables = interface_pipeline.build_tables(params, cfg)
    acc, currents = StepStats.zeros(), []
    for t in range(spikes.shape[0]):
        cur, st = interface_pipeline.interface_tick(params, spikes[t], cfg, tables, oracle=True)
        acc = acc.accumulate(st)
        currents.append(cur)
    return jax.numpy.stack(currents), acc


def run_event(cfg, params, spikes):
    return Interface(cfg).compile(params).run(spikes)


def run_pallas(cfg, params, spikes):
    return Interface(dataclasses.replace(cfg, impl="pallas")).compile(params).run(spikes)


def run_pallas_sparse(cfg, params, spikes):
    return Interface(dataclasses.replace(
        cfg, impl="pallas_sparse")).compile(params).run(spikes)


def run_chips2(cfg, params, spikes):
    return Interface(dataclasses.replace(cfg, chips=2)).compile(params).run(spikes)


def run_chips2_sharded(cfg, params, spikes):
    session = Interface(dataclasses.replace(cfg, chips=2)).compile(params)
    return session.run(spikes, shard="chips")


PATHS = {
    "oracle": run_oracle,
    "event": run_event,
    "pallas": run_pallas,
    "pallas_sparse": run_pallas_sparse,
    "chips2": run_chips2,
    "chips2_sharded": run_chips2_sharded,
}


def run_paths(cfg, params, spikes, names=tuple(PATHS)):
    return {name: PATHS[name](cfg, params, spikes) for name in names}


def _assert_field(a: StepStats, b: StepStats, field: str, label: str) -> None:
    va, vb = float(getattr(a, field)), float(getattr(b, field))
    if field in EXACT_FIELDS:
        assert va == vb, f"{label}: {field} {va} != {vb}"
    else:
        assert va == pytest.approx(vb, rel=REL_TOL), f"{label}: {field} {va} != {vb}"


def assert_conformant(results: dict, label: str = "") -> None:
    """Apply the conformance contract to `run_paths` output."""
    ref_name = "oracle" if "oracle" in results else next(iter(results))
    ref_cur, ref_st = results[ref_name]
    for name, (cur, st) in results.items():
        where = f"{label}[{ref_name} vs {name}]"
        assert bool(jax.numpy.all(cur == ref_cur)), f"{where}: currents differ"
        for field in PATH_INVARIANT_FIELDS:
            _assert_field(ref_st, st, field, where)
    for group in (FLAT_PATHS, CHIP_PATHS):
        present = [n for n in group if n in results]
        for name in present[1:]:
            where = f"{label}[{present[0]} vs {name}]"
            for field in TRANSPORT_FIELDS:
                _assert_field(results[present[0]][1], results[name][1], field, where)
