"""Guards for the §Perf hillclimb variants (EXPERIMENTS.md §Perf).

Each optimization must be value-preserving: the variants change layout /
precision / schedule, never the math (int8 experts excepted - quantized
by design, checked for sanity).
"""

import dataclasses as dc

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig, MoEConfig, RWKVConfig
from repro.launch.dryrun import VARIANTS

KEY = jax.random.PRNGKey(0)


def _rwkv_cfg(**kw):
    return ModelConfig(name="t", family="rwkv", n_layers=2, d_model=80,
                       n_heads=5, n_kv_heads=5, d_ff=224, vocab=100,
                       rwkv=RWKVConfig(head_dim=16), compute_dtype="float32",
                       **kw)


def test_rwkv_pad_heads_is_inert():
    """rwkv48 variant: zero-padded WKV heads change nothing numerically."""
    cfg = _rwkv_cfg()
    p = lm.init_model(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 32), 0, 100)
    base = lm.forward(p, {"tokens": toks}, cfg, mode="train",
                      remat=False)["logits"]
    pad = lm.forward(p, {"tokens": toks}, dc.replace(cfg, rwkv_pad_heads=8),
                     mode="train", remat=False)["logits"]
    assert jnp.allclose(base, pad, atol=1e-5)


def test_rwkv_chunk_size_invariant():
    """rwkv48_c64 variant: WKV chunk length is a pure scheduling knob."""
    cfg = _rwkv_cfg()
    p = lm.init_model(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 32), 0, 100)
    base = lm.forward(p, {"tokens": toks}, cfg, mode="train",
                      remat=False)["logits"]
    c8 = dc.replace(cfg, rwkv=RWKVConfig(head_dim=16, chunk=8))
    got = lm.forward(p, {"tokens": toks}, c8, mode="train",
                     remat=False)["logits"]
    assert jnp.allclose(base, got, atol=1e-4)


def test_int8_moe_close_to_fp():
    """serve_tp32 variant: int8 weight-only experts approximate fp well."""
    moe = MoEConfig(num_experts=8, num_shared=1, top_k=2, d_expert=32,
                    first_k_dense=1, d_ff_dense=128, capacity_factor=8.0)
    cfg = ModelConfig(name="q", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab=100,
                      head_dim=16, compute_dtype="float32", moe=moe)
    toks = jax.random.randint(KEY, (2, 16), 0, 100)
    p_fp = lm.init_model(KEY, cfg)
    out_fp = lm.forward(p_fp, {"tokens": toks}, cfg, mode="train",
                        remat=False)["logits"]
    cfg_q = dc.replace(cfg, moe=dc.replace(moe, quant_int8=True))
    p_q = lm.init_model(KEY, cfg_q)
    out_q = lm.forward(p_q, {"tokens": toks}, cfg_q, mode="train",
                       remat=False)["logits"]
    assert bool(jnp.isfinite(out_q).all())
    # same init stream, quantization error only
    rel = float(jnp.abs(out_q - out_fp).max()
                / jnp.maximum(jnp.abs(out_fp).max(), 1e-6))
    assert rel < 0.15, rel


def test_remat_policy_value_preserving():
    cfg = ModelConfig(name="d", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=100,
                      head_dim=16, compute_dtype="float32")
    p = lm.init_model(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, 100)
    a = lm.forward(p, {"tokens": toks}, cfg, mode="train",
                   remat=True)["logits"]
    b = lm.forward(p, {"tokens": toks}, cfg, mode="train", remat=True,
                   remat_policy="dots")["logits"]
    assert jnp.allclose(a, b, atol=1e-5)


def test_variant_registry_wellformed():
    from repro import configs
    for name, spec in VARIANTS.items():
        assert set(spec) <= {"cfg_fn", "train_kwargs", "mesh_shape"}, name
        if "cfg_fn" in spec and name.startswith("rwkv"):
            cfg = spec["cfg_fn"](configs.get_config("rwkv6-3b"))
            assert cfg.rwkv_pad_heads == 48
        if "cfg_fn" in spec and name.startswith("serve"):
            cfg = spec["cfg_fn"](configs.get_config("deepseek-v2-236b"))
            assert cfg.serve_tp_only
        if "mesh_shape" in spec:
            assert spec["mesh_shape"] == (8, 32)
