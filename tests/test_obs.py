"""Observability subsystem (`repro.obs`): telemetry, tracing, metrics, report.

The contract under test, in the order the layers stack:

* ``telemetry="off"`` is exactly today's path - currents AND accumulated
  stats bit-identical across the conformance grid and execution paths
  (event / pallas / multichip); richer modes never change them either.
* ``"ticks"`` per-tick series sums back to the accumulated `StepStats`
  (exactly for integer-valued counts, to float tolerance for energies).
* ``"cores"`` per-core breakdowns sum (max, for latency) to the per-tick
  totals, and attribute inter-chip hops only when chips > 1.
* `repro.obs.trace` spans record nested Chrome-trace events, are exact
  no-ops when no tracer is active, and wrap session compile/run.
* `repro.obs.metrics` percentiles track numpy within the documented
  bucket error; the JSONL sink feeds ``python -m repro.obs.report``.
* `StepStats.mean`/``summary(ticks=0)`` raises instead of silently
  reporting inf/nan.
"""

import dataclasses
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fabric
from repro.interface import Interface, StepStats
from repro.obs import metrics as obs_metrics
from repro.obs import report as obs_report
from repro.obs import telemetry as obs_telemetry
from repro.obs import trace as obs_trace
from tests._hypothesis_compat import given, settings, strategies as st
from tests.conformance.paths import ARBITER_SCHEMES, EXACT_FIELDS, GRID, NOC_SCHEMES, small_config

REL = 1e-6
TICKS = 5


def _session(cfg, seed=0):
    params = fabric.random_connectivity(jax.random.PRNGKey(seed), cfg)
    return Interface(cfg).compile(params)


def _spikes(cfg, ticks=TICKS, seed=3, lead=()):
    shape = lead + (ticks, cfg.cores, cfg.neurons_per_core)
    return jax.random.bernoulli(jax.random.PRNGKey(seed), 0.25, shape)


def _assert_stats_equal(a: StepStats, b: StepStats, label: str) -> None:
    for field in StepStats._fields:
        va, vb = getattr(a, field), getattr(b, field)
        assert bool(jnp.all(va == vb)), f"{label}: {field} differs"


def _assert_sums_back(acc: StepStats, series: StepStats, label: str) -> None:
    """Summing the tick axis reproduces the accumulated record (also batched)."""
    for field in StepStats._fields:
        total = np.asarray(getattr(acc, field))
        summed = np.asarray(jnp.sum(getattr(series, field), axis=-1))
        if field in EXACT_FIELDS:
            assert np.array_equal(summed, total), f"{label}: {field} {summed} != {total}"
        else:
            np.testing.assert_allclose(summed, total, rtol=REL, err_msg=f"{label}: {field}")


# ---- telemetry: "off" identical, series sums back --------------------------


@pytest.mark.parametrize("arb_scheme,noc_scheme", GRID)
def test_telemetry_preserves_off_path_across_grid(arb_scheme, noc_scheme):
    """Currents and accumulated stats are bit-identical with telemetry on."""
    cfg = small_config(arb_scheme, noc_scheme)
    session = _session(cfg)
    spikes = _spikes(cfg)
    cur_off, acc_off = session.run(spikes)
    cur_t, acc_t, telem = session.run(spikes, telemetry="ticks")
    assert bool(jnp.all(cur_off == cur_t)), f"{arb_scheme}/{noc_scheme}: currents differ"
    _assert_stats_equal(acc_off, acc_t, f"{arb_scheme}/{noc_scheme}")
    _assert_sums_back(acc_off, telem.per_tick, f"{arb_scheme}/{noc_scheme}")
    assert telem.ticks == TICKS


@pytest.mark.parametrize("variant", ["pallas", "chips2"], ids=["impl=pallas", "chips=2"])
def test_telemetry_preserves_off_path_on_alt_paths(variant):
    cfg = small_config(ARBITER_SCHEMES[0], NOC_SCHEMES[1])
    if variant == "pallas":
        cfg = dataclasses.replace(cfg, impl="pallas")
    else:
        cfg = dataclasses.replace(cfg, chips=2)
    session = _session(cfg)
    spikes = _spikes(cfg)
    cur_off, acc_off = session.run(spikes)
    for mode in ("ticks", "cores"):
        cur_t, acc_t, _ = session.run(spikes, telemetry=mode)
        assert bool(jnp.all(cur_off == cur_t)), f"{variant}/{mode}: currents differ"
        _assert_stats_equal(acc_off, acc_t, f"{variant}/{mode}")


def test_tick_series_percentiles_and_records():
    cfg = small_config(ARBITER_SCHEMES[0], NOC_SCHEMES[0])
    session = _session(cfg)
    _, _, telem = session.run(_spikes(cfg), telemetry="ticks")
    series = np.asarray(telem.series("events"))
    pcts = telem.percentiles("events")
    assert pcts["p50"] == pytest.approx(float(np.percentile(series, 50)))
    assert pcts["p99"] == pytest.approx(float(np.percentile(series, 99)))
    records = telem.to_records()
    assert len(records) == TICKS
    assert records[0]["events"] == float(series[0])
    assert set(records[0]) == set(StepStats._fields)


# ---- telemetry: per-core attribution ---------------------------------------


@pytest.mark.parametrize("arb_scheme", ARBITER_SCHEMES)
def test_core_breakdowns_sum_to_tick_totals(arb_scheme):
    cfg = small_config(arb_scheme, "unicast")
    session = _session(cfg)
    _, _, telem = session.run(_spikes(cfg), telemetry="cores")
    per_tick, per_core = telem.per_tick, telem.per_core
    assert per_core.events.shape == (TICKS, cfg.cores)
    assert bool(jnp.all(jnp.sum(per_core.events, axis=-1) == per_tick.events))
    assert bool(jnp.all(jnp.sum(per_core.noc_hops, axis=-1) == per_tick.noc_hops))
    assert bool(jnp.all(jnp.max(per_core.encode_latency, axis=-1) == per_tick.encode_latency))
    np.testing.assert_allclose(
        np.asarray(jnp.sum(per_core.encode_energy, axis=-1)),
        np.asarray(per_tick.encode_energy),
        rtol=REL,
    )
    totals = telem.core_totals()
    assert totals.events.shape == (cfg.cores,)
    assert float(jnp.sum(totals.events)) == float(jnp.sum(per_tick.events))


def test_chip_hops_attributed_only_on_multichip():
    flat = small_config(ARBITER_SCHEMES[0], "unicast")
    chips = dataclasses.replace(flat, chips=2)
    _, _, telem_flat = _session(flat).run(_spikes(flat), telemetry="cores")
    _, acc, telem_chips = _session(chips).run(_spikes(chips), telemetry="cores")
    assert float(jnp.sum(telem_flat.per_core.chip_hops)) == 0.0
    chip_sums = jnp.sum(telem_chips.per_core.chip_hops, axis=-1)
    assert bool(jnp.all(chip_sums == telem_chips.per_tick.chip_hops))
    assert float(acc.chip_hops) > 0, "2-chip random fabric should cross chips"
    assert float(jnp.sum(telem_chips.per_core.chip_hops)) == float(acc.chip_hops)


def test_run_batched_telemetry_shapes_and_sums():
    cfg = small_config(ARBITER_SCHEMES[1], NOC_SCHEMES[2])
    session = _session(cfg)
    spikes = _spikes(cfg, lead=(3,))
    cur, acc, telem = session.run_batched(spikes, telemetry="ticks")
    assert cur.shape == spikes.shape[:2] + (cfg.cores, cfg.neurons_per_core)
    assert telem.per_tick.events.shape == (3, TICKS)
    assert acc.events.shape == (3,)
    _assert_sums_back(acc, telem.per_tick, "batched")
    _, _, core_telem = session.run_batched(spikes, telemetry="cores")
    assert core_telem.per_core.events.shape == (3, TICKS, cfg.cores)
    core_sums = jnp.sum(core_telem.per_core.events, axis=-1)
    assert bool(jnp.all(core_sums == core_telem.per_tick.events))


# ---- telemetry: validation -------------------------------------------------


def test_unknown_telemetry_mode_raises():
    cfg = small_config(ARBITER_SCHEMES[0], NOC_SCHEMES[0])
    session = _session(cfg)
    with pytest.raises(ValueError, match="unknown telemetry mode"):
        session.run(_spikes(cfg), telemetry="bogus")
    with pytest.raises(ValueError, match="unknown telemetry mode"):
        obs_telemetry.validate_mode("per_neuron")


def test_telemetry_rejects_sharded_runs():
    cfg = dataclasses.replace(small_config(ARBITER_SCHEMES[0], "unicast"), chips=2)
    session = _session(cfg)
    with pytest.raises(ValueError, match="shard"):
        session.run(_spikes(cfg), shard="chips", telemetry="ticks")


def test_stepstats_mean_rejects_degenerate_ticks():
    acc = StepStats.zeros()
    for bad in (0, -3, 0.0, float("nan")):
        with pytest.raises(ValueError, match="positive tick count"):
            acc.mean(bad)
    with pytest.raises(ValueError, match="positive tick count"):
        acc.summary(ticks=0)
    assert acc.summary(ticks=4)["events"] == 0.0
    assert acc.summary()["events"] == 0.0  # totals need no tick count


# ---- trace -----------------------------------------------------------------


def test_tracer_records_nested_spans(tmp_path):
    tracer = obs_trace.Tracer("test-proc")
    with tracer:
        with obs_trace.span("outer", cores=4):
            with obs_trace.span("inner"):
                pass
        tracer.instant("marker", tick=7)
    names = [e["name"] for e in tracer.events]
    assert names == ["inner", "outer", "marker"]  # completion order
    by_name = {e["name"]: e for e in tracer.events}
    assert by_name["outer"]["args"] == {"cores": 4, "depth": 0}
    assert by_name["inner"]["args"] == {"depth": 1}
    assert by_name["outer"]["dur"] >= by_name["inner"]["dur"]
    path = tmp_path / "trace.json"
    tracer.save(str(path))
    payload = json.loads(path.read_text())
    assert payload["traceEvents"][0]["ph"] == "M"
    assert payload["traceEvents"][0]["args"]["name"] == "test-proc"
    assert {e["name"] for e in payload["traceEvents"][1:]} == {"outer", "inner", "marker"}
    assert all(e["ph"] in ("X", "i") for e in payload["traceEvents"][1:])


def test_span_is_noop_without_active_tracer():
    assert obs_trace.active_tracer() is None
    with obs_trace.span("nobody-listening") as t:
        assert t is None


def test_tracer_deactivates_on_exit():
    tracer = obs_trace.Tracer()
    with tracer:
        assert obs_trace.active_tracer() is tracer
    assert obs_trace.active_tracer() is None
    with obs_trace.span("after"):
        pass
    assert tracer.events == []


def test_session_compile_and_run_emit_spans():
    cfg = small_config(ARBITER_SCHEMES[0], NOC_SCHEMES[0])
    tracer = obs_trace.Tracer()
    with tracer:
        session = _session(cfg)
        session.run(_spikes(cfg))
        session.run(_spikes(cfg), telemetry="ticks")
    names = [e["name"] for e in tracer.events]
    assert names.count("interface.compile") == 1
    assert names.count("interface.run") == 2
    compile_ev = next(e for e in tracer.events if e["name"] == "interface.compile")
    assert compile_ev["args"]["cores"] == cfg.cores
    telem_ev = [e for e in tracer.events if e["args"].get("telemetry") == "ticks"]
    assert len(telem_ev) == 1


# ---- metrics ---------------------------------------------------------------


def test_exact_percentiles_match_numpy():
    values = [5.0, 1.0, 9.0, 3.0, 7.0, 2.0, 8.0]
    got = obs_metrics.percentiles(values, qs=(0, 50, 95, 100))
    for q in (0, 50, 95, 100):
        assert got[f"p{q:g}"] == pytest.approx(float(np.percentile(values, q)))
    with pytest.raises(ValueError, match="empty"):
        obs_metrics.percentiles([])
    with pytest.raises(ValueError, match="outside"):
        obs_metrics.percentiles([1.0], qs=(101,))


def test_histogram_percentiles_within_bucket_error():
    rng = np.random.default_rng(0)
    sample = rng.lognormal(mean=0.0, sigma=1.0, size=4000)
    hist = obs_metrics.Histogram("t")
    for v in sample:
        hist.add(v)
    # documented bound: one geometric bucket, ~10**(1/64) - 1 < 4% headroom
    for q in (50, 95, 99):
        exact = float(np.percentile(sample, q))
        assert hist.percentile(q) == pytest.approx(exact, rel=0.04)
    assert hist.count == len(sample)
    assert hist.min == pytest.approx(sample.min())
    assert hist.max == pytest.approx(sample.max())
    assert hist.mean == pytest.approx(sample.mean(), rel=1e-9)
    summary = hist.summary()
    assert set(summary) == {"count", "mean", "min", "max", "p50", "p95", "p99"}


def test_histogram_edge_cases():
    hist = obs_metrics.Histogram("edge")
    with pytest.raises(ValueError, match="empty"):
        hist.percentile(50)
    with pytest.raises(ValueError, match="empty"):
        hist.mean
    hist.add(0.0)  # at/below lo clamps into the lowest bucket, never raises
    hist.add(1e12)  # above hi clamps into the highest bucket
    assert hist.count == 2
    assert hist.min <= hist.percentile(0) <= hist.percentile(100) <= hist.max
    with pytest.raises(ValueError, match="outside"):
        hist.percentile(-1)
    with pytest.raises(ValueError, match="lo"):
        obs_metrics.Histogram("bad", lo=1.0, hi=0.5)


def test_histogram_nonfinite_counted_without_poisoning():
    """Regression: NaN crashed `_bin` (math.log10 ValueError) and Inf
    raised OverflowError - one bad measured duration killed the serve
    path.  Non-finite adds are now counted aside and excluded from every
    statistic."""
    hist = obs_metrics.Histogram("nf")
    hist.add(2.0)
    hist.add(float("nan"))
    hist.add(float("inf"))
    hist.add(float("-inf"))
    assert hist.count == 1 and hist.nonfinite == 3
    assert hist.min == 2.0 and hist.max == 2.0 and hist.mean == 2.0
    summary = hist.summary()
    assert summary["nonfinite"] == 3 and summary["count"] == 1
    assert math.isfinite(summary["p99"])
    other = obs_metrics.Histogram("nf2")
    other.add(float("nan"))
    other.add(3.0)
    merged = hist.merge(other)
    assert merged.nonfinite == 4 and merged.count == 2 and merged.max == 3.0
    clean = obs_metrics.Histogram("clean")
    clean.add(1.0)
    assert "nonfinite" not in clean.summary()


@settings(max_examples=25, deadline=None)
@given(
    st.lists(st.floats(1e-4, 1e4), min_size=1, max_size=64),
    st.lists(st.floats(1e-4, 1e4), min_size=0, max_size=64),
)
def test_histogram_merge_matches_pooled_sample(a, b):
    """merge(h1, h2) == the histogram fed both sample streams.

    Bucket counts, count, min, max (and therefore every percentile, which
    is a pure function of those) must match the pooled histogram exactly;
    totals to float tolerance (summation order legitimately differs).
    The serving tier relies on this to roll per-tenant latency histograms
    into fleet percentiles without retaining samples.
    """
    h1, h2, pooled = (obs_metrics.Histogram(n) for n in ("a", "b", "pooled"))
    for v in a:
        h1.add(v)
        pooled.add(v)
    for v in b:
        h2.add(v)
        pooled.add(v)
    merged = h1.merge(h2)
    assert merged._counts == pooled._counts
    assert merged.count == pooled.count == len(a) + len(b)
    assert merged.min == pooled.min and merged.max == pooled.max
    for q in (0, 50, 95, 99, 100):
        assert merged.percentile(q) == pooled.percentile(q)
    assert merged.mean == pytest.approx(pooled.mean, rel=1e-12)
    # originals are untouched
    assert h1.count == len(a) and h2.count == len(b)


def test_histogram_merge_rejects_mismatched_bucketing():
    base = obs_metrics.Histogram("base")
    for other in (
        obs_metrics.Histogram("lo", lo=1e-3),
        obs_metrics.Histogram("hi", hi=1e3),
        obs_metrics.Histogram("bins", bins_per_decade=32),
    ):
        with pytest.raises(ValueError, match="bucketing"):
            base.merge(other)


def test_counter_registry_and_snapshot():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("ticks").inc()
    reg.counter("ticks").inc(4)
    assert reg.counter("ticks") is reg.counters["ticks"]
    h = reg.histogram("lat_ms")
    assert reg.histogram("lat_ms") is h
    h.add(2.0)
    snap = reg.snapshot()
    assert snap["ticks"] == 5.0
    assert snap["lat_ms"]["count"] == 1
    empty = obs_metrics.MetricsRegistry()
    empty.histogram("unused")
    assert empty.snapshot() == {}  # empty histograms stay out of snapshots


def test_jsonl_sink_roundtrips_through_report_loader(tmp_path):
    path = tmp_path / "metrics.jsonl"
    with obs_metrics.JsonlSink(str(path)) as sink:
        sink.write({"scenario": "sparse_poisson", "new_tick_ms": 0.5})
        sink.write({"scenario": "hotspot_core", "new_tick_ms": 0.9})
    records = obs_report.load_records(str(path))
    assert [r["scenario"] for r in records] == ["sparse_poisson", "hotspot_core"]


# ---- report CLI ------------------------------------------------------------


def _bench_payload():
    stats = {
        "events": 84.5,
        "encode_latency": 18.4,
        "encode_energy": 16.0,
        "cam_searches": 41.0,
        "cam_energy": 23193.4,
        "cam_time_ns": 103.9,
        "noc_hops": 91.4,
        "noc_latency": 12.7,
        "noc_energy": 3198.1,
        "chip_hops": 0.0,
        "chip_latency": 0.0,
        "chip_energy": 0.0,
    }
    record = {
        "cores": 16,
        "neurons_per_core": 256,
        "cam_entries_per_core": 128,
        "ticks": 8,
        "scenario": "sparse_poisson",
        "new_tick_ms": 0.712,
        "tick_ms_p50": 0.82,
        "tick_ms_p95": 0.99,
        "tick_ms_p99": 1.0,
        "stats_per_tick": stats,
    }
    return {
        "benchmark": "interface_session_tick",
        "schema_version": 2,
        "platform": "cpu",
        "jax_version": "0.0-test",
        "git_sha": "cafe" * 10,
        "records": [record],
    }


def test_report_renders_tier_breakdown(tmp_path, capsys):
    path = tmp_path / "bench.json"
    path.write_text(json.dumps(_bench_payload()))
    assert obs_report.main([str(path)]) == 0
    out = capsys.readouterr().out
    for tier in ("arbiter", "cam", "noc", "chip"):
        assert tier in out
    assert "sparse_poisson" in out
    assert "platform cpu" in out
    assert "p99 1.000 ms" in out
    # CAM dominates this record's summed latency: the share column says so
    rows = obs_report.tier_rows(_bench_payload()["records"][0]["stats_per_tick"])
    shares = {tier: share for tier, _, _, _, _, share in rows}
    assert max(shares, key=shares.get) == "cam"
    assert sum(shares.values()) == pytest.approx(1.0)


def test_report_scenario_filter(tmp_path, capsys):
    path = tmp_path / "bench.json"
    path.write_text(json.dumps(_bench_payload()))
    assert obs_report.main([str(path), "--scenario", "sparse_poisson"]) == 0
    assert "sparse_poisson" in capsys.readouterr().out
    assert obs_report.main([str(path), "--scenario", "not_a_scenario"]) == 0
    assert "no reportable records" in capsys.readouterr().out


def test_report_rejects_malformed_input(tmp_path, capsys):
    bad = tmp_path / "bad.txt"
    bad.write_text("definitely { not json\nnor jsonl ]")
    assert obs_report.main([str(bad)]) == 1
    assert "error:" in capsys.readouterr().out
    assert obs_report.main([str(tmp_path / "missing.json")]) == 1
