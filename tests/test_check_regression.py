"""`benchmarks/check_regression.py` gate semantics.

Malformed records fail with an explicit message (not a KeyError), the
optional ``scenario`` tag keys records independently while pre-scenario
payloads keep matching, and a shrunken sweep (baseline keys with no
candidate counterpart) fails instead of silently going ungated.
"""

import json

import pytest

from benchmarks import check_regression as cr


def _record(tick_ms, scenario=None, **over):
    rec = {
        "cores": 16,
        "neurons_per_core": 256,
        "cam_entries_per_core": 128,
        "ticks": 8,
        "new_tick_ms": tick_ms,
    }
    if scenario is not None:
        rec["scenario"] = scenario
    rec.update(over)
    return rec


def _payload(records):
    return {"benchmark": "interface_session_tick", "git_sha": "testsha", "records": records}


def _run(tmp_path, monkeypatch, capsys, current, baseline):
    monkeypatch.delenv("BENCH_BASELINE_SKIP", raising=False)
    cur = tmp_path / "cur.json"
    base = tmp_path / "base.json"
    cur.write_text(json.dumps(current))
    base.write_text(json.dumps(baseline))
    rc = cr.main([str(cur), "--baseline", str(base)])
    return rc, capsys.readouterr().out


def test_gate_passes_on_matching_records(tmp_path, monkeypatch, capsys):
    rc, out = _run(
        tmp_path,
        monkeypatch,
        capsys,
        _payload([_record(1.0), _record(2.0, scenario="sparse_poisson")]),
        _payload([_record(1.1), _record(2.1, scenario="sparse_poisson")]),
    )
    assert rc == 0
    assert "gate passed" in out


def test_missing_sweep_key_fails_with_clear_message(tmp_path, monkeypatch, capsys):
    bad = _record(1.0)
    del bad["cores"]
    rc, out = _run(tmp_path, monkeypatch, capsys, _payload([bad]), _payload([_record(1.0)]))
    assert rc == 1
    assert "missing sweep key" in out
    assert "cores" in out
    assert "Traceback" not in out


def test_missing_value_field_fails_with_clear_message(tmp_path, monkeypatch, capsys):
    bad = _record(1.0)
    del bad["new_tick_ms"]
    rc, out = _run(tmp_path, monkeypatch, capsys, _payload([bad]), _payload([_record(1.0)]))
    assert rc == 1
    assert "new_tick_ms" in out


def test_index_raises_record_format_error_not_key_error():
    with pytest.raises(cr.RecordFormatError, match="ticks"):
        bad = _record(1.0)
        del bad["ticks"]
        cr._index(_payload([bad]), "current")


def test_scenario_records_gate_independently(tmp_path, monkeypatch, capsys):
    baseline = _payload(
        [_record(1.0, scenario="sparse_poisson"), _record(1.0, scenario="synchronized_burst")]
    )
    current = _payload(
        [_record(1.0, scenario="sparse_poisson"), _record(9.0, scenario="synchronized_burst")]
    )
    rc, out = _run(tmp_path, monkeypatch, capsys, current, baseline)
    assert rc == 1
    assert "REGRESSED" in out
    assert "synchronized_burst" in out


def test_shrunken_sweep_fails(tmp_path, monkeypatch, capsys):
    baseline = _payload([_record(1.0), _record(1.0, scenario="dvs_trace")])
    current = _payload([_record(1.0)])
    rc, out = _run(tmp_path, monkeypatch, capsys, current, baseline)
    assert rc == 1
    assert "no candidate record" in out
    assert "dvs_trace" in out


def test_new_records_are_report_only(tmp_path, monkeypatch, capsys):
    baseline = _payload([_record(1.0)])
    current = _payload([_record(1.0), _record(5.0, scenario="hotspot_core")])
    rc, out = _run(tmp_path, monkeypatch, capsys, current, baseline)
    assert rc == 0
    assert "new" in out


def test_pre_scenario_baseline_still_gates(tmp_path, monkeypatch, capsys):
    """Old payloads (no scenario tags anywhere) keep working unchanged."""
    current = _payload([_record(9.0)])
    baseline = _payload([_record(1.0)])
    rc, out = _run(tmp_path, monkeypatch, capsys, current, baseline)
    assert rc == 1
    assert "regressed beyond the threshold" in out


def test_p99_only_regression_gates(tmp_path, monkeypatch, capsys):
    """A tail regression fails even when the best-of-N minimum is healthy."""
    baseline = _payload([_record(1.0, tick_ms_p99=1.2)])
    current = _payload([_record(1.0, tick_ms_p99=9.0)])
    rc, out = _run(tmp_path, monkeypatch, capsys, current, baseline)
    assert rc == 1
    assert "REGRESSED" in out
    assert "tick_ms_p99" in out
    assert "regressed beyond the threshold" in out


def test_p99_within_threshold_passes(tmp_path, monkeypatch, capsys):
    baseline = _payload([_record(1.0, tick_ms_p99=1.2)])
    current = _payload([_record(1.0, tick_ms_p99=1.3)])
    rc, out = _run(tmp_path, monkeypatch, capsys, current, baseline)
    assert rc == 0
    assert "tick_ms_p99" in out
    assert "gate passed" in out


def test_pre_percentile_baseline_skips_p99_gate(tmp_path, monkeypatch, capsys):
    """Old baselines without percentiles keep gating on new_tick_ms alone."""
    baseline = _payload([_record(1.0)])
    current = _payload([_record(1.0, tick_ms_p99=99.0)])
    rc, out = _run(tmp_path, monkeypatch, capsys, current, baseline)
    assert rc == 0
    assert "gate passed" in out
    assert "REGRESSED" not in out


def test_platform_mismatch_warns_instead_of_gating(tmp_path, monkeypatch, capsys):
    baseline = {**_payload([_record(1.0)]), "platform": "tpu"}
    current = {**_payload([_record(9.0)]), "platform": "cpu"}
    rc, out = _run(tmp_path, monkeypatch, capsys, current, baseline)
    assert rc == 0
    assert "platform mismatch" in out
    assert "gate not enforced" in out


def test_matching_platforms_still_gate(tmp_path, monkeypatch, capsys):
    baseline = {**_payload([_record(1.0)]), "platform": "cpu"}
    current = {**_payload([_record(9.0)]), "platform": "cpu"}
    rc, out = _run(tmp_path, monkeypatch, capsys, current, baseline)
    assert rc == 1
    assert "regressed beyond the threshold" in out


def _async_record(ratio, **over):
    fields = {"events_per_sec": 1e5, "async_vs_sync": ratio,
              "serve_bit_identical": True, "pump_threads": 1, **over}
    return _record(1.0, scenario="__serve_async__", **fields)


def test_async_pump_floor_passes_at_parity(tmp_path, monkeypatch, capsys):
    payload = _payload([_record(1.0), _async_record(0.98)])
    rc, out = _run(tmp_path, monkeypatch, capsys, payload, payload)
    assert rc == 0
    assert "background pump 0.98x" in out
    assert "gate passed" in out


def test_async_pump_below_floor_fails(tmp_path, monkeypatch, capsys):
    payload = _payload([_record(1.0), _async_record(0.5)])
    rc, out = _run(tmp_path, monkeypatch, capsys, payload, payload)
    assert rc == 1
    assert "below the in-run throughput floor" in out
    assert "0.50x" in out


def test_async_pump_floor_gates_on_platform_mismatch(tmp_path, monkeypatch, capsys):
    """The ratio is in-run, so it is enforced even when wall clocks are
    not baseline-comparable."""
    baseline = {**_payload([_record(1.0)]), "platform": "tpu"}
    current = {**_payload([_record(1.0), _async_record(0.5)]), "platform": "cpu"}
    rc, out = _run(tmp_path, monkeypatch, capsys, current, baseline)
    assert rc == 1
    assert "below the in-run throughput floor" in out


def test_async_pump_missing_ratio_fails(tmp_path, monkeypatch, capsys):
    rec = _async_record(0.9)
    del rec["async_vs_sync"]
    payload = _payload([_record(1.0), rec])
    rc, out = _run(tmp_path, monkeypatch, capsys, payload, payload)
    assert rc == 1
    assert "lacks async_vs_sync" in out


def test_async_pump_bit_identity_false_fails(tmp_path, monkeypatch, capsys):
    rec = _async_record(0.9, serve_bit_identical=False)
    payload = _payload([_record(1.0), rec])
    rc, out = _run(tmp_path, monkeypatch, capsys, payload, payload)
    assert rc == 1
    assert "serve_bit_identical=false" in out


def test_payload_without_async_record_passes(tmp_path, monkeypatch, capsys):
    payload = _payload([_record(1.0)])
    rc, out = _run(tmp_path, monkeypatch, capsys, payload, payload)
    assert rc == 0
    assert "background pump" not in out
