"""Fault injection (`repro.ft`): fabric fault models and host chaos plans.

Fabric layer (`repro.ft.faults.FaultModel`, compiled into a session):

* a null model compiles as fault-free (bit-identical to a clean session);
* dead cores neither emit nor receive: their currents are exactly zero
  and fleet events can only shrink;
* ``drop_rate=1`` silences the fabric entirely; intermediate rates are
  deterministic in (seed, lane, global tick) - a stream served in chunks
  with running ``fault_tick0`` offsets draws EXACTLY the same faults as
  one uninterrupted run (the chaos soak's bit-identity hinges on this);
* vmapped lanes fold their index into the drop stream, so identical
  spikes on different lanes draw independent faults;
* corrupted CAM entries misroute (finite degradation), never crash;
* faults are data, not control flow: the jitted fault transform holds
  ONE cache entry across chunk offsets, and ``fault_tick0`` is rejected
  on sessions without a spike-perturbing fault.

Host layer (`repro.ft.chaos`):

* `FaultPlan.mixed` is deterministic in (tenants, rounds, seed), covers
  every fault kind, and schedules every event inside [1, rounds];
* a `ChaosInjector` fires every charge exactly once regardless of retry
  interleaving, and reports exhaustion;
* `FaultEvent` validates kinds/rounds/targets with explicit errors.

Satellite: the seed-era `repro.ft.runner` Watchdog/FailureInjector now
count onto `repro.obs.metrics` while keeping their legacy interface.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fabric
from repro.ft import (
    FAULT_KINDS,
    ChaosInjector,
    ExecuteFault,
    FaultEvent,
    FaultModel,
    FaultPlan,
    TransferFault,
    TransientFaultError,
)
from repro.ft.runner import FailureInjector, Watchdog
from repro.interface import Interface
from repro.obs import metrics as obs_metrics
from tests.conformance.paths import small_config

TICKS = 12


def _fabric(cfg, seed=0):
    return fabric.random_connectivity(jax.random.PRNGKey(seed), cfg)


def _spikes(cfg, ticks=TICKS, seed=3, lead=()):
    shape = lead + (ticks, cfg.cores, cfg.neurons_per_core)
    return jax.random.bernoulli(jax.random.PRNGKey(seed), 0.3, shape)


# ---- FaultModel validation --------------------------------------------------


def test_fault_model_validation():
    with pytest.raises(ValueError, match="drop_rate"):
        FaultModel(drop_rate=1.5)
    with pytest.raises(ValueError, match="duplicates"):
        FaultModel(dead_cores=(1, 1))
    with pytest.raises(ValueError, match="non-negative"):
        FaultModel(dead_cores=(-1,))
    with pytest.raises(ValueError, match="corrupt_cam_entries"):
        FaultModel(corrupt_cam_entries=-2)
    cfg = small_config("binary_tree", "broadcast")
    with pytest.raises(ValueError, match="out of range"):
        FaultModel(dead_cores=(cfg.cores,)).validate(cfg)
    with pytest.raises(ValueError, match="CAM slots"):
        FaultModel(corrupt_cam_entries=10**6).validate(cfg)
    # fits: no raise, and compile accepts it end to end
    model = FaultModel(dead_cores=(0,), drop_rate=0.25, corrupt_cam_entries=2)
    model.validate(cfg)
    assert not model.is_null and model.perturbs_spikes
    assert model.describe()["dead_cores"] == [0]


def test_null_fault_compiles_as_fault_free():
    cfg = small_config("binary_tree", "multicast_tree")
    params = _fabric(cfg)
    sp = _spikes(cfg)
    clean = Interface(cfg).compile(params)
    nulled = Interface(cfg).compile(params, fault=FaultModel())
    assert FaultModel().is_null
    assert nulled.fault is None  # null model normalized away at compile
    cur_a, acc_a = clean.run(sp)
    cur_b, acc_b = nulled.run(sp)
    assert jnp.array_equal(cur_a, cur_b)
    assert float(acc_a.events) == float(acc_b.events)
    with pytest.raises(ValueError, match="fault_tick0"):
        nulled.run(sp, fault_tick0=4)


# ---- fabric-layer semantics -------------------------------------------------


def test_dead_core_emits_and_receives_nothing():
    cfg = small_config("binary_tree", "multicast_tree")
    params = _fabric(cfg)
    sp = _spikes(cfg)
    dead = 1
    cur_clean, acc_clean = Interface(cfg).compile(params).run(sp)
    session = Interface(cfg).compile(params, fault=FaultModel(dead_cores=(dead,)))
    cur, acc = session.run(sp)
    assert np.asarray(cur)[:, dead, :].max() == 0.0, "dead core received current"
    assert float(acc.events) <= float(acc_clean.events)
    assert np.isfinite(np.asarray(cur)).all()


def test_drop_rate_one_silences_the_fabric():
    cfg = small_config("binary_tree", "broadcast")
    session = Interface(cfg).compile(_fabric(cfg), fault=FaultModel(drop_rate=1.0))
    cur, acc = session.run(_spikes(cfg))
    assert float(jnp.abs(cur).max()) == 0.0
    assert float(acc.events) == 0.0


def test_chunked_drops_bit_identical_to_one_run():
    cfg = small_config("binary_tree", "multicast_tree")
    params = _fabric(cfg)
    sp = _spikes(cfg)
    session = Interface(cfg).compile(params, fault=FaultModel(drop_rate=0.4, seed=5))
    cur_full, acc_full = session.run(sp)
    t_split = TICKS // 2
    cur_a, acc_a = session.run(sp[:t_split], fault_tick0=0)
    cur_b, _ = session.run(sp[t_split:], fault_tick0=t_split)
    assert jnp.array_equal(cur_full, jnp.concatenate([cur_a, cur_b]))
    # sanity: the fault actually dropped something
    _, acc_clean = Interface(cfg).compile(params).run(sp)
    assert float(acc_full.events) < float(acc_clean.events)
    assert float(acc_a.events) <= float(acc_full.events)


def test_lanes_draw_independent_drop_streams():
    cfg = small_config("binary_tree", "broadcast")
    session = Interface(cfg).compile(_fabric(cfg), fault=FaultModel(drop_rate=0.5, seed=2))
    one = _spikes(cfg, seed=7)
    batched = jnp.stack([one, one, one])  # identical spikes per lane
    cur, acc = session.run_batched(batched)
    events = np.asarray(acc.events)
    assert len({float(e) for e in events}) > 1, (
        "identical lanes drew identical faults; lane index is not folded in"
    )
    # lane 0 of the batch == the solo run at the same offset
    cur_solo, _ = session.run(one, fault_tick0=0)
    assert jnp.array_equal(cur[0], cur_solo)


def test_fault_jit_cache_stable_across_offsets():
    cfg = small_config("binary_tree", "broadcast")
    session = Interface(cfg).compile(_fabric(cfg), fault=FaultModel(drop_rate=0.3))
    sp = _spikes(cfg, ticks=6)
    for offset in (0, 6, 12, 99):
        session.run(sp, fault_tick0=offset)
    assert session._fault_cache["run"]._cache_size() == 1, (
        "fault_tick0 must be a dynamic argument, not a recompile trigger"
    )


def test_fault_tick0_rejected_on_clean_sessions():
    cfg = small_config("binary_tree", "broadcast")
    session = Interface(cfg).compile(_fabric(cfg))
    with pytest.raises(ValueError, match="fault_tick0"):
        session.run(_spikes(cfg), fault_tick0=0)
    # CAM corruption perturbs params, not spikes: still no tick offset
    corrupted = Interface(cfg).compile(_fabric(cfg), fault=FaultModel(corrupt_cam_entries=4))
    with pytest.raises(ValueError, match="fault_tick0"):
        corrupted.run(_spikes(cfg), fault_tick0=0)


def test_corrupt_cam_degrades_without_crashing():
    cfg = small_config("binary_tree", "multicast_tree")
    params = _fabric(cfg)
    sp = _spikes(cfg)
    session = Interface(cfg).compile(params, fault=FaultModel(corrupt_cam_entries=8, seed=9))
    cur, acc = session.run(sp)
    assert np.isfinite(np.asarray(cur)).all()
    assert float(acc.events) >= 0.0
    # determinism: same seed, same misroutes
    redo = Interface(cfg).compile(params, fault=FaultModel(corrupt_cam_entries=8, seed=9))
    again, _ = redo.run(sp)
    assert jnp.array_equal(cur, again)


# ---- host-layer chaos plans -------------------------------------------------


def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(round=1, kind="meteor_strike")
    with pytest.raises(ValueError, match="round"):
        FaultEvent(round=0, kind="transfer_fail")
    with pytest.raises(ValueError, match="times"):
        FaultEvent(round=1, kind="transfer_fail", times=0)
    with pytest.raises(ValueError, match="tenant"):
        FaultEvent(round=1, kind="lane_fault")  # needs a target
    with pytest.raises(ValueError, match="tenant"):
        FaultEvent(round=1, kind="slow_device", tenant="t0")  # must not have one
    with pytest.raises(TypeError, match="FaultEvent"):
        FaultPlan(events=("not an event",))


def test_mixed_plan_deterministic_and_in_range():
    tenants = [f"t{i}" for i in range(4)]
    plan = FaultPlan.mixed(tenants, rounds=20, seed=3)
    again = FaultPlan.mixed(tenants, rounds=20, seed=3)
    assert plan.events == again.events, "mixed plan must be seed-deterministic"
    assert plan.events != FaultPlan.mixed(tenants, rounds=20, seed=4).events
    assert set(ev.kind for ev in plan.events) == set(FAULT_KINDS)
    assert all(1 <= ev.round <= 20 for ev in plan.events)
    assert plan.total_charges() == sum(plan.kinds().values()) >= len(FAULT_KINDS)
    # the minimum round budget still covers every kind, in range
    tiny = FaultPlan.mixed(tenants, rounds=4, seed=0)
    assert set(ev.kind for ev in tiny.events) == set(FAULT_KINDS)
    assert all(ev.round <= 4 for ev in tiny.events)
    with pytest.raises(ValueError, match="rounds"):
        FaultPlan.mixed(tenants, rounds=3)
    with pytest.raises(ValueError, match="tenant"):
        FaultPlan.mixed([], rounds=8)


def test_injector_fires_every_charge_exactly_once():
    plan = FaultPlan(
        events=(
            FaultEvent(round=1, kind="transfer_fail", times=2),
            FaultEvent(round=2, kind="execute_fail", times=1),
            FaultEvent(round=2, kind="slow_device", times=2, delay_s=0.5),
            FaultEvent(round=3, kind="lane_fault", tenant="t1", times=2),
        )
    )
    slept = []
    injector = ChaosInjector(plan, sleep=slept.append)
    lane_hits = []
    for round_ in range(1, 6):
        for ev in injector.lane_faults(round_):
            lane_hits.append((round_, ev.tenant))
        # retry loop: keep attempting until the round's charges heal
        for hook, err in (
            (injector.on_transfer, TransferFault),
            (injector.on_execute, ExecuteFault),
        ):
            for _ in range(8):
                try:
                    hook(round_)
                    break
                except err:
                    continue
    assert injector.exhausted()
    assert injector.injected_total() == plan.total_charges() == 7
    assert injector.injected == {
        "transfer_fail": 2,
        "execute_fail": 1,
        "slow_device": 2,
        "lane_fault": 2,
    }
    assert slept == [0.5, 0.5]
    # one lane charge per pump: the times=2 event spans two rounds
    assert lane_hits == [(3, "t1"), (4, "t1")]
    # replays after exhaustion are clean no-ops
    injector.on_transfer(9)
    injector.on_execute(9)
    assert injector.lane_faults(9) == []
    assert injector.injected_total() == 7


def test_chaos_error_ladder():
    assert issubclass(TransferFault, TransientFaultError)
    assert issubclass(ExecuteFault, TransientFaultError)
    # before an event's round, nothing fires
    injector = ChaosInjector(FaultPlan(events=(FaultEvent(round=5, kind="transfer_fail"),)))
    injector.on_transfer(4)
    assert not injector.injected
    with pytest.raises(TransferFault):
        injector.on_transfer(5)
    assert injector.exhausted()


# ---- satellite: runner counters on obs.metrics ------------------------------


def test_watchdog_counts_onto_metrics_registry():
    reg = obs_metrics.MetricsRegistry()
    w = Watchdog(straggler_factor=3.0, registry=reg, prefix="ft")
    for _ in range(6):
        assert not w.observe(0.01)
    assert w.observe(0.5), "a 50x step must flag as straggler"
    assert w.stragglers == 1  # legacy attribute, now registry-backed
    assert reg.counters["ft.stragglers"].value == 1
    assert reg.histograms["ft.step_ms"].count == 7
    # registry looked up per call: survives a warmup-style clear
    reg.counters.clear()
    reg.histograms.clear()
    w.observe(0.9)
    assert w.stragglers == 1 and reg.counters["ft.stragglers"].value == 1


def test_failure_injector_counts_onto_metrics_registry():
    reg = obs_metrics.MetricsRegistry()
    injector = FailureInjector(fail_at_steps=(3,), registry=reg)
    injector.check(2)
    with pytest.raises(RuntimeError, match="injected failure at step 3"):
        injector.check(3)
    injector.check(3)  # fires once, then the drill is over
    assert reg.counters["ft.injected_failures"].value == 1
    # registry-less injectors (the seed-era interface) still work
    bare = FailureInjector(fail_at_steps=(1,))
    with pytest.raises(RuntimeError):
        bare.check(1)
