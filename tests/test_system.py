"""End-to-end system behaviour: train -> checkpoint -> serve."""

import jax
import jax.numpy as jnp

from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.serve.lm_engine import ServeEngine
from repro.train import step as ts

KEY = jax.random.PRNGKey(0)


def _cfg(name):
    return ModelConfig(name=name, family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                       head_dim=16, param_dtype="float32",
                       compute_dtype="float32")


def test_train_checkpoint_serve_roundtrip(tmp_path):
    """The full lifecycle on one device: a few training steps, checkpoint,
    restore into a serving engine, generate deterministically."""
    cfg = _cfg("sys")
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    state = ts.init_state(KEY, cfg, opt)
    step = jax.jit(ts.make_train_step(cfg, opt))
    pipe = Pipeline(cfg, DataConfig(global_batch=4, seq_len=32, seed=0))
    for i in range(5):
        state, metrics = step(state, pipe.batch(i))
    assert bool(jnp.isfinite(metrics["loss"]))

    mgr = CheckpointManager(str(tmp_path), every=1, async_save=False)
    mgr.maybe_save(5, state)
    back, meta = mgr.restore_latest(state)
    assert meta["step"] == 5

    engine = ServeEngine(cfg=cfg, params=back.params, max_len=64)
    prompts = jnp.array([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    out1 = engine.generate(prompts, num_steps=8)
    out2 = engine.generate(prompts, num_steps=8)
    assert out1.shape == (2, 8)
    assert bool((out1 == out2).all())  # greedy decoding is deterministic
    assert bool((out1 >= 0).all()) and bool((out1 < cfg.vocab).all())


def test_generate_respects_prompt_conditioning():
    """Different prompts -> (almost surely) different continuations."""
    cfg = _cfg("sys2")
    params = lm.init_model(KEY, cfg)
    engine = ServeEngine(cfg=cfg, params=params, max_len=64)
    a = engine.generate(jnp.array([[1, 2, 3, 4]], jnp.int32), num_steps=12)
    b = engine.generate(jnp.array([[9, 10, 11, 12]], jnp.int32), num_steps=12)
    assert not bool((a == b).all())
