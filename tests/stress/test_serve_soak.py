"""Sustained-load soak of the serving engine (``slow`` tier).

Drives a mixed-scenario tenant fleet through a few thousand ticks of
round-based load and asserts the properties that only show up under
sustained operation, not in one flush:

* **no queue-depth divergence**: the engine keeps up with the offered
  load round after round - queues return to empty after every drain and
  the sampled ``serve.queue_depth`` histogram never exceeds the
  per-round offered request count;
* **stable jit cache**: chunk shapes are fixed (lanes x flush_ticks), so
  the masked batched step compiles exactly once for the whole soak - a
  shape leak (recompile per round) would show up here long before it
  shows up as a latency cliff in production;
* **stable memory**: host-side bookkeeping (backlogs, queues, retained
  currents) does not grow with rounds served; python object growth per
  round stays bounded;
* **accounting closes**: per-tenant served ticks and the fleet tick
  counter agree with the offered load exactly, events keep flowing, and
  the final report is well-formed.
"""

import gc

import pytest

from repro.serve import ServeEngine, TenantSpec
from tests.conformance.paths import small_config

ROUNDS = 40
TICKS_PER_ROUND = 16  # x 5 tenants x 40 rounds = 3200 lane-ticks
SCENARIOS = ("sparse_poisson", "hotspot_core", "synchronized_burst", "mixture", "clustered")


@pytest.mark.slow
def test_serve_soak_sustained_mixed_load():
    cfg = small_config("binary_tree", "multicast_tree")
    engine = ServeEngine(flush_ticks=TICKS_PER_ROUND, flush_deadline_s=0.0)
    specs = [
        TenantSpec(f"t{i}", cfg, scenario=sc, seed=i) for i, sc in enumerate(SCENARIOS)
    ]
    for spec in specs:
        engine.register(spec)
    assert len(engine.groups) == 1
    group = next(iter(engine.groups.values()))

    # warm round: pays compilation, then measure cache/memory stability
    for spec in specs:
        engine.submit_scenario(spec.name, TICKS_PER_ROUND)
    engine.drain()
    batched_fn = group.session._masked_cache["run_batched"]
    assert batched_fn._cache_size() == 1

    gc.collect()
    objects_before = len(gc.get_objects())

    for _ in range(ROUNDS - 1):
        for spec in specs:
            engine.submit_scenario(spec.name, TICKS_PER_ROUND)
        served = engine.drain()
        assert served == len(specs) * TICKS_PER_ROUND
        # no divergence: drained queues and backlogs return to empty
        assert engine.queue_depth() == 0
        assert group.backlog_ticks() == 0

    # fixed chunk shapes: the whole soak ran on ONE compiled batched step
    assert batched_fn._cache_size() == 1, "chunk shape leak: masked step recompiled"

    gc.collect()
    growth = len(gc.get_objects()) - objects_before
    assert growth < 50_000, f"host object growth over {ROUNDS} rounds: {growth}"

    # accounting closes exactly
    total = ROUNDS * TICKS_PER_ROUND
    for spec in specs:
        assert engine.ticks_served(spec.name) == total
    assert engine.ticks_served() == len(specs) * total
    assert engine.registry.counter("serve.ticks").value == len(specs) * total
    depth_hist = engine.registry.histograms["serve.queue_depth"]
    assert depth_hist.max <= len(specs), "queue depth diverged beyond one round's load"

    records = engine.emit_report()
    fleet = records[-1]
    assert fleet["ticks"] == len(specs) * total
    assert fleet["events"] > 0 and fleet["events_per_sec"] > 0
    assert fleet["tick_ms_p99"] >= fleet["tick_ms_p50"] > 0
