"""Multi-producer concurrency stress of the background pump.

N submitter threads race one another - and the background pump thread -
into the same engine, interleaving submits with mid-stream accounting
reads.  The properties only concurrency can violate:

* **no lost or duplicated frames**: every submitted tick is served
  exactly once - per-tenant served totals equal what each producer
  recorded submitting (no deadline, so nothing may shed);
* **accounting closes at every observable point**: `accounting()` taken
  mid-race (it serializes against the pump) always satisfies
  submitted == served + shed + pending, per tenant;
* **stable jit cache**: racing producers never perturb chunk shapes -
  the masked batched step compiles exactly once for the whole run;
* **clean shutdown**: `stop(drain=True)` leaves no pending work, no
  survivable pump errors, and no fatal.
"""

import threading

import numpy as np
import pytest

from repro.serve import ServeEngine, TenantSpec
from tests.conformance.paths import small_config

PRODUCERS = 4
SUBMITS_PER_PRODUCER = 25
MAX_TICKS_PER_SUBMIT = 7


@pytest.mark.slow
def test_multi_producer_pump_accounting_closes():
    cfg = small_config("binary_tree", "broadcast")
    engine = ServeEngine(flush_ticks=8, flush_deadline_s=0.0)
    names = [f"p{i}" for i in range(PRODUCERS)]
    for i, name in enumerate(names):
        engine.register(TenantSpec(name, cfg, seed=i))
    group = next(iter(engine.groups.values()))

    submitted = {name: 0 for name in names}
    errors: list = []
    start_gate = threading.Barrier(PRODUCERS)

    def producer(name: str, seed: int) -> None:
        rng = np.random.default_rng(seed)
        try:
            start_gate.wait(timeout=30)
            for k in range(SUBMITS_PER_PRODUCER):
                t = int(rng.integers(1, MAX_TICKS_PER_SUBMIT + 1))
                frames = rng.random((t, cfg.cores, cfg.neurons_per_core)) < 0.05
                engine.submit(name, frames)
                submitted[name] += t
                if k % 5 == 0:
                    acct = engine.accounting()
                    assert acct["closes"], f"mid-race ledger violation: {acct}"
        except BaseException as e:  # noqa: BLE001 - re-raised on the main thread
            errors.append(e)

    engine.start(poll_interval_s=0.001)
    threads = [
        threading.Thread(target=producer, args=(name, 100 + i), daemon=True)
        for i, name in enumerate(names)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
        assert not t.is_alive(), "producer thread hung"
    if errors:
        raise errors[0]
    engine.stop(drain=True)

    assert engine.pump_errors() == []
    acct = engine.accounting()
    assert acct["closes"]
    for name in names:
        row = acct["tenants"][name]
        assert row["pending"] == 0 and row["shed"] == 0
        # exactly-once: every submitted tick served, none lost or duplicated
        assert row["submitted"] == submitted[name]
        assert engine.ticks_served(name) == submitted[name]
    assert engine.ticks_served() == sum(submitted.values())
    # racing producers never perturbed chunk shapes
    assert group.jit_cache_entries() == 1, "concurrency-induced recompile"
    assert engine.queue_depth() == 0 and group.backlog_ticks() == 0
