"""Chaos soak of the serving engine (``slow`` tier) - PR 8's capstone.

Drives a mixed-scenario tenant fleet (one tenant additionally carrying a
fabric-level `FaultModel`) through ~40 rounds of load while a seeded
`FaultPlan.mixed` fires transfer failures, execute failures, slow
devices, and repeated lane faults at it, and asserts the graceful-
degradation contract end to end:

* **the engine recovers every time**: every chaos charge is delivered,
  every hard failure (retry budget spent) restages its work and a later
  pump serves it, and every lane ends the soak healthy;
* **accounting closes exactly**: submitted == served + shed + pending
  per tenant at every failure point and at the end (nothing shed here,
  nothing lost);
* **the jit cache never grows**: quarantine masking, retry replays, and
  the faulted tenant's drop stream are all data - each group's masked
  batched step stays at ONE compiled entry for the whole soak;
* **clean tenants are undisturbed**: their currents are BIT-IDENTICAL
  to the same fleet served by a chaos-free twin engine;
* **host memory stays bounded** and the final report carries the fault
  counters and recovery percentiles the obs CLI renders.
"""

import gc

import numpy as np
import pytest

from repro.ft import ChaosInjector, FaultModel, FaultPlan, RetriesExhaustedError
from repro.serve import HealthPolicy, RetryPolicy, ServeEngine, TenantSpec
from tests.conformance.paths import small_config

ROUNDS = 40
TICKS_PER_ROUND = 16
SCENARIOS = ("sparse_poisson", "hotspot_core", "synchronized_burst", "mixture", "clustered")
FAULT = FaultModel(drop_rate=0.1, seed=13)  # the last tenant's lossy fabric


def _specs(cfg):
    specs = [TenantSpec(f"t{i}", cfg, scenario=sc, seed=i) for i, sc in enumerate(SCENARIOS)]
    specs[-1] = TenantSpec(
        specs[-1].name,
        cfg,
        scenario=specs[-1].scenario,
        seed=len(SCENARIOS) - 1,
        fault=FAULT,
    )
    return specs


@pytest.mark.slow
def test_chaos_soak_recovers_every_time():
    cfg = small_config("binary_tree", "multicast_tree")
    specs = _specs(cfg)
    names = [s.name for s in specs]
    plan = FaultPlan.mixed(names, rounds=ROUNDS, seed=11)
    injector = ChaosInjector(plan, sleep=lambda s: None)
    engine = ServeEngine(
        flush_ticks=TICKS_PER_ROUND,
        flush_deadline_s=0.0,
        chaos=injector,
        retry=RetryPolicy(max_retries=3, backoff_base_s=0.0),
        health=HealthPolicy(quarantine_after=2, quarantine_rounds=2),
        sleep=lambda s: None,
        keep_currents=True,
    )
    calm = ServeEngine(flush_ticks=TICKS_PER_ROUND, flush_deadline_s=0.0, keep_currents=True)
    for spec in specs:
        engine.register(spec)
        calm.register(spec)
    assert len(engine.groups) == 2, "the faulted tenant gets its own group"
    batched_fns = [
        g.session._masked_cache["run_batched"]
        for g in list(engine.groups.values()) + list(calm.groups.values())
        if g.session._masked_cache is not None
    ]

    # warm round on both engines: pays compilation before the gc baseline
    for e in (engine, calm):
        for spec in specs:
            e.submit_scenario(spec.name, TICKS_PER_ROUND)
        e.drain()
    gc.collect()
    objects_before = len(gc.get_objects())

    hard_failures = 0
    for _ in range(ROUNDS - 1):
        for e in (engine, calm):
            for spec in specs:
                e.submit_scenario(spec.name, TICKS_PER_ROUND)
        calm.pump(force=True)
        try:
            engine.pump(force=True)
        except RetriesExhaustedError:
            hard_failures += 1
            acct = engine.accounting()
            assert acct["closes"], "ledger must close at every failure point"
    # leftover charges (events scheduled at rounds the loop already
    # passed but that found no work to hit) fire during the drain
    while True:
        try:
            engine.drain()
            break
        except RetriesExhaustedError:
            hard_failures += 1
    calm.drain()

    # -- the engine recovered every time -----------------------------------
    assert injector.exhausted(), (
        f"undelivered chaos charges: injected {injector.injected_total()} "
        f"of {plan.total_charges()}"
    )
    assert injector.injected_total() == plan.total_charges()
    for name in names:
        assert engine.lane_health(name) == "healthy", name
    total = ROUNDS * TICKS_PER_ROUND
    for name in names:
        assert engine.ticks_served(name) == total, name
    acct = engine.accounting()
    assert acct["closes"]
    for name in names:
        assert acct["tenants"][name] == {
            "submitted": total,
            "served": total,
            "shed": 0,
            "pending": 0,
        }, name

    # -- the jit cache never grew ------------------------------------------
    for fn in batched_fns:
        assert fn._cache_size() == 1, "chaos must not leak compiled entries"

    # -- clean tenants bit-identical to the undisturbed twin ----------------
    for name in names:
        assert np.array_equal(engine.currents(name), calm.currents(name)), (
            f"{name}: chaos perturbed a tenant's served currents"
        )
        a = engine.tenant_stats(name)._asdict()
        b = calm.tenant_stats(name)._asdict()
        for field, va in a.items():
            assert float(np.asarray(va)) == float(np.asarray(b[field])), (name, field)

    # -- host memory stays bounded -----------------------------------------
    gc.collect()
    growth = len(gc.get_objects()) - objects_before
    assert growth < 50_000, f"host object growth over {ROUNDS} rounds: {growth}"

    # -- the report carries the fault story ---------------------------------
    fleet = engine.serve_report()[-1]
    faults = fleet["faults"]
    # slow_device charges stall rather than raise, so they count in the
    # per-kind chaos tallies but not in the engine's fault counter
    assert faults["injected"] == injector.injected_total() - injector.injected.get(
        "slow_device", 0
    )
    for kind, fired in injector.injected.items():
        assert faults[f"chaos_{kind}"] == fired
    if faults.get("retry_recoveries"):
        assert "recovery_ms_p50" in fleet
    if hard_failures:
        assert faults["retries_exhausted"] == hard_failures
    lossy = next(r for r in engine.serve_report() if r.get("tenant") == specs[-1].name)
    assert lossy["fault"] == FAULT.describe()
