"""`tools/check_test_budget.py` gate semantics.

The budget gate sums junit testcase times, names the slowest offenders,
and fails only when the sum blows the budget; an empty or wrong file
fails loudly instead of passing vacuously.
"""

import importlib.util
import os

_SPEC = importlib.util.spec_from_file_location(
    "check_test_budget",
    os.path.join(os.path.dirname(__file__), "..", "tools", "check_test_budget.py"),
)
budget = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(budget)


def _junit(tmp_path, times):
    cases = "".join(
        f'<testcase classname="tests.test_x" name="t{i}" time="{t}"/>'
        for i, t in enumerate(times)
    )
    path = tmp_path / "junit.xml"
    path.write_text(f"<testsuites><testsuite>{cases}</testsuite></testsuites>")
    return str(path)


def test_under_budget_passes(tmp_path, capsys):
    rc = budget.main([_junit(tmp_path, [1.0, 2.0, 3.0]), "--budget-s", "10"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "budget ok" in out
    assert "6.0s summed over 3 tests" in out


def test_over_budget_fails_and_names_offenders(tmp_path, capsys):
    rc = budget.main([_junit(tmp_path, [1.0, 50.0, 2.0]), "--budget-s", "10"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "FAIL" in out and "blew its 10s budget" in out
    # slowest first, named
    assert out.index("t1") < out.index("t0")
    assert "@pytest.mark.slow" in out


def test_env_var_sets_default_budget(tmp_path, capsys, monkeypatch):
    monkeypatch.setenv("TEST_BUDGET_S", "2")
    rc = budget.main([_junit(tmp_path, [3.0])])
    assert rc == 1
    monkeypatch.setenv("TEST_BUDGET_S", "9")
    rc = budget.main([_junit(tmp_path, [3.0])])
    assert rc == 0
    capsys.readouterr()


def test_empty_junit_fails(tmp_path, capsys):
    path = tmp_path / "junit.xml"
    path.write_text("<testsuites><testsuite/></testsuites>")
    rc = budget.main([str(path), "--budget-s", "10"])
    assert rc == 1
    assert "no testcases" in capsys.readouterr().out


def test_missing_time_attribute_counts_as_zero(tmp_path):
    path = tmp_path / "junit.xml"
    path.write_text(
        "<testsuites><testsuite>"
        '<testcase classname="c" name="n"/>'
        "</testsuite></testsuites>"
    )
    assert budget.load_times(str(path)) == [(0.0, "c::n")]
