"""The fused sparse event tick: compaction, policies, fallback, kernel.

`tests/conformance` already holds the whole ``impl="pallas_sparse"``
session bit-identical to the dense oracle across the grid; this file
covers the pieces in isolation - the sort-free event compaction, the
sparse arbiter/encode policies against their dense counterparts, the
event-indexed accounting, the overflow-to-dense `lax.cond`, and the
dispatch-layer validation errors.
"""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import arbiter as arb
from repro.core import fabric
from repro.interface import Interface, pipeline
from repro.interface.config import InterfaceConfig
from repro.interface.registry import get_arbiter
from repro.kernels.sparse_tick import ops as sparse_ops
from repro.kernels.sparse_tick import ref as sparse_ref
from repro.noc import topology

KEY = jax.random.PRNGKey(0)
SPARSE_SCHEMES = ("binary_tree", "greedy_tree", "token_ring", "hier_ring",
                  "hier_tree")

# Same contract as tests/conformance: per-tick stats are bit-identical,
# but across differently-jitted scans XLA may fuse the accumulate chain
# differently (FMA), so accumulated counts are exact and energies agree
# to the conformance tolerance.
EXACT_FIELDS = ("events", "cam_searches", "noc_hops", "chip_hops")


def _assert_stats_close(a, b):
    for f in a._fields:
        va, vb = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        if f in EXACT_FIELDS:
            np.testing.assert_array_equal(va, vb, err_msg=f)
        else:
            np.testing.assert_allclose(va, vb, rtol=1e-6, err_msg=f)


def _frame(key, cores=4, n=64, p=0.1):
    return jax.random.bernoulli(key, p, (cores, n))


# ---- compaction --------------------------------------------------------------

def test_compact_events_matches_nonzero():
    spikes = _frame(KEY, p=0.2)
    buf, counts = sparse_ops.compact_events(spikes, capacity=32)
    for c in range(spikes.shape[0]):
        want = np.flatnonzero(np.asarray(spikes[c]))
        got = np.asarray(buf[c])
        assert int(counts[c]) == want.size
        np.testing.assert_array_equal(got[: want.size], want)
        assert (got[want.size:] == spikes.shape[1]).all()  # pad value is n


def test_compact_events_edge_counts():
    n = 16
    empty = jnp.zeros((1, n), bool)
    buf, counts = sparse_ops.compact_events(empty, capacity=4)
    assert int(counts[0]) == 0 and bool((buf == n).all())

    # exactly-capacity frame still carries one trailing pad slot
    exact = jnp.zeros((1, n), bool).at[0, :4].set(True)
    buf, counts = sparse_ops.compact_events(exact, capacity=4)
    assert buf.shape == (1, 5)
    assert int(counts[0]) == 4 and int(buf[0, -1]) == n

    # overflow: counts exceed capacity, buffer is truncated
    full = jnp.ones((1, n), bool)
    buf, counts = sparse_ops.compact_events(full, capacity=4)
    assert int(counts[0]) == n and bool((buf[0] == jnp.arange(5)).all())


def test_event_indices_weights_and_bases():
    spikes = jnp.array([[0, 1, 0, 1], [1, 0, 0, 0]], bool)
    buf, _ = sparse_ops.compact_events(spikes, capacity=2)
    ev_idx, ev_w = sparse_ops.event_indices(buf, 4)
    np.testing.assert_array_equal(np.asarray(ev_w), [1, 1, 1, 0])
    np.testing.assert_array_equal(np.asarray(ev_idx), [1, 3, 4, 0])


def test_resolve_capacity():
    assert sparse_ops.resolve_capacity(None, 256) == 32
    assert sparse_ops.resolve_capacity(None, 16) == sparse_ops.MIN_CAPACITY
    assert sparse_ops.resolve_capacity(100, 16) == 15   # clamped to n - 1
    assert sparse_ops.resolve_capacity(3, 256) == 3
    with pytest.raises(ValueError, match="positive"):
        sparse_ops.resolve_capacity(0, 256)


# ---- sparse policies vs dense policies ---------------------------------------

@pytest.mark.parametrize("scheme", SPARSE_SCHEMES)
def test_sparse_policies_match_dense(scheme):
    n = 64
    cfg = arb.ArbiterConfig(scheme, n)
    ctx = arb.make_context(cfg)
    entry = get_arbiter(scheme)
    lat_fn = entry.sparse_tick_latency(ctx)
    enc_fn = entry.sparse_encode_energy(ctx)
    assert lat_fn is not None and enc_fn is not None
    for seed, p in ((1, 0.02), (2, 0.1), (3, 0.4)):
        spikes = _frame(jax.random.PRNGKey(seed), cores=8, n=n, p=p)
        buf, counts = sparse_ops.compact_events(spikes, capacity=n - 1)
        dense_lat = arb.batched_tick_latency(cfg, spikes)
        assert bool((lat_fn(buf, counts) == dense_lat).all()), (scheme, p)
        dense_enc = jax.vmap(lambda s: arb.encode_energy_units(
            scheme, n, pipeline._hat_order(s, n)[0]))(spikes)
        assert bool((enc_fn(buf, counts) == dense_enc).all()), (scheme, p)


def test_unsupported_schemes_return_none():
    # greedy_tree at n=2 has no backlog closed form; hier_ring needs a
    # square address space - both refuse rather than approximate
    ctx = arb.make_context(arb.ArbiterConfig("greedy_tree", 2))
    assert get_arbiter("greedy_tree").sparse_tick_latency(ctx) is None
    ctx = arb.make_context(arb.ArbiterConfig("hier_ring", 8))
    assert get_arbiter("hier_ring").sparse_tick_latency(ctx) is None


# ---- fused tick: ref vs kernel -----------------------------------------------

def _tick_operands(cores=4, n=32, entries=64, p=0.15, scheme="hier_tree"):
    cfg = InterfaceConfig(cores=cores, neurons_per_core=n,
                          cam_entries_per_core=entries, scheme=scheme)
    params = fabric.random_connectivity(KEY, cfg)
    routing = pipeline.build_routing_index(params, cfg)
    spikes = _frame(jax.random.PRNGKey(5), cores, n, p)
    lat_fn, enc_fn, _, capacity = pipeline.resolve_sparse_plan(cfg)
    buf, counts = sparse_ops.compact_events(spikes, capacity)
    return (spikes.reshape(-1), buf, counts, routing.src_idx, routing.active,
            params.weights, params.targets), dict(
                n=n, latency_fn=lat_fn, encode_fn=enc_fn)


def test_kernel_matches_ref():
    operands, kw = _tick_operands()
    want = sparse_ops.sparse_tick(*operands, impl="xla", **kw)
    got = sparse_ops.sparse_tick(*operands, impl="pallas", interpret=True,
                                 **kw)
    for w, g in zip(want, got):
        assert w.shape == g.shape and bool((w == g).all())


def test_sparse_tick_validation():
    operands, kw = _tick_operands()
    with pytest.raises(ValueError, match="impl"):
        sparse_ops.sparse_tick(*operands, impl="cuda", **kw)
    bad = (operands[0][:-1],) + operands[1:]
    with pytest.raises(ValueError, match="spikes_flat"):
        sparse_ops.sparse_tick(*bad, **kw)
    bad = operands[:1] + (operands[1][:-1],) + operands[2:]
    with pytest.raises(ValueError, match="cores"):
        sparse_ops.sparse_tick(*bad, **kw)
    bad = operands[:4] + (operands[4][:, :-1],) + operands[5:]
    with pytest.raises(ValueError, match="disagree"):
        sparse_ops.sparse_tick(*bad, **kw)


# ---- overflow fallback and config plumbing -----------------------------------

def test_overflow_falls_back_to_dense():
    cfg = InterfaceConfig(cores=4, neurons_per_core=16,
                          cam_entries_per_core=32, sparse_capacity=2)
    params = fabric.random_connectivity(KEY, cfg)
    dense = Interface(cfg).compile(params)
    sparse = Interface(dataclasses.replace(
        cfg, impl="pallas_sparse")).compile(params)
    # ticks alternate under and over the 2-event budget: the lax.cond
    # takes both branches inside one scan, results identical throughout
    spikes = jnp.stack([
        jnp.zeros((4, 16), bool).at[0, 3].set(True),
        jnp.ones((4, 16), bool),
        jnp.zeros((4, 16), bool),
        jax.random.bernoulli(jax.random.PRNGKey(9), 0.5, (4, 16)),
    ])
    cd, sd = dense.run(spikes)
    cs, ss = sparse.run(spikes)
    assert bool((cd == cs).all())
    _assert_stats_close(sd, ss)


def test_empty_frame_zero_stats():
    cfg = InterfaceConfig(cores=4, neurons_per_core=16,
                          cam_entries_per_core=32, impl="pallas_sparse")
    params = fabric.random_connectivity(KEY, cfg)
    currents, stats = Interface(cfg).compile(params).run(
        jnp.zeros((2, 4, 16), bool))
    assert not currents.any()
    for f in stats._fields:
        assert float(getattr(stats, f)) == 0.0, f


def test_config_validation():
    with pytest.raises(ValueError, match="impl"):
        InterfaceConfig(impl="pallas_dense")
    with pytest.raises(ValueError, match="sparse_capacity"):
        InterfaceConfig(sparse_capacity=0)
    with pytest.raises(ValueError, match="sparse_capacity"):
        fabric.FabricConfig(sparse_capacity=-1)
    # legacy round-trip preserves the knob
    cfg = InterfaceConfig(sparse_capacity=7, impl="pallas_sparse")
    assert InterfaceConfig.from_fabric(cfg.fabric()).sparse_capacity == 7


def test_session_refuses_unsupported_scheme():
    cfg = InterfaceConfig(cores=4, neurons_per_core=8,
                          cam_entries_per_core=16, scheme="hier_ring",
                          impl="pallas_sparse")
    params = fabric.random_connectivity(KEY, cfg)
    with pytest.raises(ValueError, match="hier_ring"):
        Interface(cfg).compile(params)


def test_masked_batched_composition():
    cfg = InterfaceConfig(cores=4, neurons_per_core=16,
                          cam_entries_per_core=32, impl="pallas_sparse")
    params = fabric.random_connectivity(KEY, cfg)
    session = Interface(cfg).compile(params)
    dense = Interface(dataclasses.replace(cfg, impl="xla")).compile(params)
    batch = jax.random.bernoulli(jax.random.PRNGKey(11), 0.15, (2, 5, 4, 16))
    mask = jnp.array([[1, 1, 1, 0, 0], [1, 1, 1, 1, 1]], bool)
    cs, ss = session.run_batched(batch, mask=mask)
    cd, sd = dense.run_batched(batch, mask=mask)
    assert bool((cs == cd).all())
    _assert_stats_close(sd, ss)


def test_hat_pad_boundary_at_exact_capacity():
    # a frame holding exactly `capacity` events exercises the trailing
    # pad slot the HAT encode-energy boundary toggle depends on
    n, cap = 16, 4
    cfg = arb.ArbiterConfig("hier_tree", n)
    ctx = arb.make_context(cfg)
    enc_fn = get_arbiter("hier_tree").sparse_encode_energy(ctx)
    spikes = jnp.zeros((1, n), bool).at[0, jnp.array([1, 5, 9, 13])].set(True)
    buf, counts = sparse_ops.compact_events(spikes, cap)
    assert int(counts[0]) == cap
    dense = arb.encode_energy_units(
        "hier_tree", n, pipeline._hat_order(spikes[0], n)[0])
    assert float(enc_fn(buf, counts)[0]) == float(dense)


def test_flat_scatter_matches_vmapped_scatter():
    # the bit-identity claim the ref docstring makes, asserted directly
    operands, kw = _tick_operands(p=0.3)
    _, _, _, src_idx, active, weights, targets = operands
    spikes_flat = operands[0]
    drive = (spikes_flat[src_idx] & active).astype(jnp.float32)
    contrib = drive * weights
    n = kw["n"]
    want = jax.vmap(
        lambda c, t: jnp.zeros((n,), jnp.float32).at[t].add(c)
    )(contrib, targets)
    got = sparse_ref.sparse_tick_ref(*operands, **kw)[0]
    assert bool((want == got).all())


def test_default_capacity_heuristic():
    assert sparse_ops.default_capacity(256) == 32
    assert sparse_ops.default_capacity(64) == sparse_ops.MIN_CAPACITY
    assert math.log2(sparse_ops.CAPACITY_DIVISOR).is_integer()
