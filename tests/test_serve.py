"""Serving tier (`repro.serve`) + masked/ragged session batching.

The contract under test, bottom layer first:

* ``InterfaceSession.run_batched(spikes, mask=...)``: every masked lane's
  currents AND accumulated `StepStats` are BIT-IDENTICAL to a solo
  ``session.run`` over just its live ticks - sampled across the full
  5-arbiter x 3-NoC conformance grid, ragged lengths included, with an
  all-padding lane staying exactly zero.
* ``stats0`` threads the accumulator through chunked calls: a stream
  served in chunks accumulates bit-identically to one uninterrupted run.
* `IngestQueue` flushes on the size trigger, the deadline trigger
  (injectable clock), or ``force`` - and not before.
* `AdmissionController` bounds lanes/groups/request size with
  `AdmissionError`, before any device work.
* `ServeEngine` end-to-end: mixed-scenario tenants on one shared session
  serve bit-identically to their solo runs, report records carry the
  percentile + ``stats_per_tick`` fields the report CLI renders, and
  incompatible configs land on separate groups.
* The LM reference loop still imports from `repro.serve.lm_engine`.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fabric
from repro.ft.chaos import RetriesExhaustedError, TransientFaultError
from repro.interface import Interface, InterfaceConfig, StepStats
from repro.noc import topology
from repro.serve import (
    AdmissionController,
    AdmissionError,
    AdmissionPolicy,
    AutoscalePolicy,
    CompositionError,
    IngestQueue,
    RateLimitedError,
    ServeEngine,
    ServeError,
    TenantSpec,
    TokenBucket,
    compat_key,
    default_connectivity,
)
from tests.conformance.paths import GRID, small_config

TICKS = 6


def _session(cfg, seed=0):
    params = fabric.random_connectivity(jax.random.PRNGKey(seed), cfg)
    return Interface(cfg).compile(params)


def _spikes(cfg, ticks=TICKS, seed=3, lead=()):
    shape = lead + (ticks, cfg.cores, cfg.neurons_per_core)
    return jax.random.bernoulli(jax.random.PRNGKey(seed), 0.25, shape)


def _assert_stats_equal(a: StepStats, b: StepStats, label: str) -> None:
    for field in StepStats._fields:
        va, vb = np.asarray(getattr(a, field)), np.asarray(getattr(b, field))
        assert np.array_equal(va, vb), f"{label}: {field} {va} != {vb}"


# ---- masked / ragged batched stepping --------------------------------------


@pytest.mark.parametrize("arb_scheme,noc_scheme", GRID)
def test_masked_lanes_bit_identical_to_solo_across_grid(arb_scheme, noc_scheme):
    """Ragged lanes == solo runs, on every arbiter x NoC path."""
    cfg = small_config(arb_scheme, noc_scheme)
    session = _session(cfg)
    lengths = (TICKS, TICKS // 2, 0)  # full, ragged, all-padding
    spikes = _spikes(cfg, lead=(len(lengths),))
    mask = np.zeros((len(lengths), TICKS), bool)
    for lane, t in enumerate(lengths):
        mask[lane, :t] = True
    currents, acc = session.run_batched(spikes, mask=jnp.asarray(mask))
    for lane, t in enumerate(lengths):
        label = f"{arb_scheme}/{noc_scheme} lane{lane} t={t}"
        if t == 0:
            _assert_stats_equal(
                jax.tree.map(lambda x: x[lane], acc), StepStats.zeros(), label
            )
            assert not np.asarray(currents[lane]).any(), f"{label}: currents leaked"
            continue
        cur_solo, acc_solo = session.run(spikes[lane, :t])
        assert np.array_equal(
            np.asarray(currents[lane, :t]), np.asarray(cur_solo)
        ), f"{label}: currents differ"
        _assert_stats_equal(jax.tree.map(lambda x: x[lane], acc), acc_solo, label)


def test_masked_solo_run_matches_truncated():
    cfg = small_config("binary_tree", "multicast_tree")
    session = _session(cfg)
    spikes = _spikes(cfg)
    mask = jnp.arange(TICKS) < 4
    cur_m, acc_m = session.run(spikes, mask=mask)
    cur_t, acc_t = session.run(spikes[:4])
    assert np.array_equal(np.asarray(cur_m[:4]), np.asarray(cur_t))
    _assert_stats_equal(acc_m, acc_t, "masked solo vs truncated")


def test_stats0_carry_chunked_equals_one_shot():
    """Chunk-streamed serving accumulates bit-identically to one run."""
    cfg = small_config("greedy_tree", "unicast")
    session = _session(cfg)
    spikes = _spikes(cfg, ticks=8, lead=(2,))
    full_mask = jnp.ones((2, 8), bool)
    cur_full, acc_full = session.run_batched(spikes, mask=full_mask)
    acc = None
    chunks = []
    for lo in (0, 4):
        cur, acc = session.run_batched(
            spikes[:, lo : lo + 4], mask=full_mask[:, lo : lo + 4], stats0=acc
        )
        chunks.append(np.asarray(cur))
    assert np.array_equal(np.concatenate(chunks, axis=1), np.asarray(cur_full))
    _assert_stats_equal(acc, acc_full, "chunked stats0 carry")


def test_mask_validation():
    cfg = small_config("binary_tree", "broadcast")
    session = _session(cfg)
    spikes = _spikes(cfg, lead=(2,))
    good = jnp.ones((2, TICKS), bool)
    with pytest.raises(ValueError, match="mask"):
        session.run_batched(spikes, mask=jnp.ones((2, TICKS + 1), bool))
    with pytest.raises(ValueError, match="stats0"):
        session.run(spikes[0], stats0=StepStats.zeros())
    with pytest.raises(ValueError, match="shard"):
        session.run_batched(spikes, mask=good, shard="dies")
    with pytest.raises(CompositionError, match="telemetry"):
        session.run_batched(spikes, mask=good, telemetry="ticks")
    # mask + shard="chips" composes now (one-chip configs run flat)
    cur, _ = session.run_batched(spikes, mask=good, shard="chips")
    cur_flat, _ = session.run_batched(spikes, mask=good)
    assert np.array_equal(np.asarray(cur), np.asarray(cur_flat))


# ---- ingest queue ----------------------------------------------------------


class _FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now


def _frames(n, cfg):
    return np.zeros((n, cfg.cores, cfg.neurons_per_core), bool)


def test_queue_size_trigger():
    cfg = small_config("binary_tree", "broadcast")
    q = IngestQueue(flush_frames=8, flush_deadline_s=60.0, clock=_FakeClock())
    q.submit("a", _frames(5, cfg))
    assert not q.ready() and q.poll() == []
    q.submit("b", _frames(3, cfg))  # 8 frames total: size trigger fires
    assert q.ready() and q.pending_frames() == 8
    out = q.poll()
    assert [r.tenant for r in out] == ["a", "b"]
    assert q.depth() == 0 and q.pending_frames() == 0


def test_queue_deadline_trigger_and_force():
    cfg = small_config("binary_tree", "broadcast")
    clock = _FakeClock()
    q = IngestQueue(flush_frames=100, flush_deadline_s=0.5, clock=clock)
    q.submit("a", _frames(2, cfg))
    clock.now = 0.4
    assert not q.ready()
    clock.now = 0.5  # oldest request hits its latency deadline
    assert q.ready() and len(q.poll()) == 1
    q.submit("b", _frames(1, cfg))
    assert len(q.poll(force=True)) == 1  # drain semantics ignore triggers
    with pytest.raises(ValueError, match="frames"):
        q.submit("c", np.zeros((0, cfg.cores, cfg.neurons_per_core), bool))


# ---- admission -------------------------------------------------------------


def test_admission_bounds():
    cfg = small_config("binary_tree", "broadcast")
    ctrl = AdmissionController(AdmissionPolicy(max_tenants_per_group=2, max_groups=1))
    spec = TenantSpec("t0", cfg)
    key = ctrl.admit(spec, {})
    assert key == compat_key(spec)
    with pytest.raises(AdmissionError, match="capacity"):
        ctrl.admit(spec, {key: 2})
    other = TenantSpec("t1", cfg, connectivity_seed=9)  # needs a new group
    with pytest.raises(AdmissionError, match="max_groups"):
        ctrl.admit(other, {key: 1})
    with pytest.raises(AdmissionError, match="max_frames_per_request"):
        ctrl.validate_request("t0", 5000)
    with pytest.raises(ValueError, match=">= 1"):
        AdmissionPolicy(max_groups=0)


def test_tenant_spec_validation_and_streams():
    cfg = small_config("binary_tree", "broadcast")
    with pytest.raises(ValueError, match="non-empty"):
        TenantSpec("", cfg)
    with pytest.raises(ValueError, match="unknown scenario parameter"):
        TenantSpec("t", cfg, scenario="sparse_poisson", scenario_params={"nope": 1})
    spec = TenantSpec("t", cfg, scenario="sparse_poisson", seed=5)
    a, b = spec.stream(4, round=0), spec.stream(4, round=0)
    assert np.array_equal(np.asarray(a), np.asarray(b)), "streams must be deterministic"
    c = spec.stream(4, round=1)
    assert not np.array_equal(np.asarray(a), np.asarray(c)), "rounds must draw fresh traffic"
    assert 0.0 < spec.expected_rate() < 1.0


# ---- serve engine ----------------------------------------------------------


def _engine(cfg, scenarios, **kw):
    kw.setdefault("flush_ticks", 4)
    kw.setdefault("flush_deadline_s", 0.0)
    engine = ServeEngine(**kw)
    specs = [
        TenantSpec(f"t{i}", cfg, scenario=sc, seed=i) for i, sc in enumerate(scenarios)
    ]
    for spec in specs:
        engine.register(spec)
    return engine, specs


def test_engine_serves_bit_identical_to_solo():
    cfg = small_config("binary_tree", "multicast_tree")
    engine, specs = _engine(
        cfg, ["sparse_poisson", "hotspot_core", "synchronized_burst"], keep_currents=True
    )
    assert len(engine.groups) == 1, "same (config, connectivity) must share a session"
    ticks = (7, 4, 9)  # ragged across tenants, none a flush multiple
    for spec, t in zip(specs, ticks):
        engine.submit_scenario(spec.name, t)
    assert engine.drain() == sum(ticks)

    session = _session(cfg)  # same seed-0 connectivity as the group
    for spec, t in zip(specs, ticks):
        cur_solo, acc_solo = session.run(spec.stream(t, round=0))
        assert np.array_equal(engine.currents(spec.name), np.asarray(cur_solo)), spec.name
        _assert_stats_equal(engine.tenant_stats(spec.name), acc_solo, spec.name)
        assert engine.ticks_served(spec.name) == t


def test_engine_report_records_and_metrics():
    cfg = small_config("binary_tree", "broadcast")
    engine, specs = _engine(cfg, ["sparse_poisson", "mixture"])
    for spec in specs:
        engine.submit_scenario(spec.name, 6)
    engine.drain()
    records = engine.serve_report()
    assert [r["tenant"] for r in records] == ["t0", "t1", "__fleet__"]
    for rec in records[:-1]:
        assert rec["ticks"] == 6
        assert {"tick_ms_p50", "tick_ms_p95", "tick_ms_p99", "stats_per_tick"} <= set(rec)
        assert rec["stats_per_tick"]["events"] > 0
    fleet = records[-1]
    assert fleet["tenants"] == 2 and fleet["ticks"] == 12
    assert fleet["events_per_sec"] > 0
    # fleet percentiles come from Histogram.merge over the tenant hists
    assert fleet["tick_ms_p99"] >= fleet["tick_ms_p50"] > 0
    assert engine.registry.counter("serve.ticks").value == 12
    snapshot = engine.registry.snapshot()
    assert "tenant.t0.tick_ms" in snapshot and "serve.queue_depth" in snapshot


def test_engine_grouping_and_errors():
    cfg_a = small_config("binary_tree", "broadcast")
    cfg_b = small_config("binary_tree", "broadcast", cores=8)
    engine = ServeEngine(flush_ticks=4, policy=AdmissionPolicy(max_groups=2))
    engine.register(TenantSpec("a0", cfg_a))
    engine.register(TenantSpec("b0", cfg_b))  # incompatible shape: new group
    assert len(engine.groups) == 2
    with pytest.raises(ValueError, match="already registered"):
        engine.register(TenantSpec("a0", cfg_a))
    with pytest.raises(ValueError, match="conflict"):
        engine.register(
            TenantSpec("a1", cfg_a), params=default_connectivity(cfg_a, 0)
        )
    with pytest.raises(KeyError, match="unknown tenant"):
        engine.submit("ghost", np.zeros((1, cfg_a.cores, cfg_a.neurons_per_core), bool))
    with pytest.raises(ValueError, match="do not match"):
        engine.submit("a0", np.zeros((1, cfg_b.cores, cfg_b.neurons_per_core), bool))
    with pytest.raises(ValueError, match="keep_currents"):
        engine.currents("a0")


def test_engine_deadline_holds_partial_batches():
    """Under the deadline, a partial batch waits; force flushes it."""
    cfg = small_config("binary_tree", "broadcast")
    clock = _FakeClock()
    engine = ServeEngine(flush_ticks=8, flush_deadline_s=1.0, clock=clock)
    engine.register(TenantSpec("t0", cfg))
    engine.submit_scenario("t0", 3)  # 3 < 8 frames and inside the deadline
    assert engine.pump() == 0 and engine.queue_depth() == 1
    clock.now = 1.0
    assert engine.pump() == 3  # deadline trigger fires the partial flush
    engine.submit_scenario("t0", 2)
    clock.now = 1.5
    assert engine.drain() == 2  # force path ignores triggers entirely


def test_lm_engine_relocated():
    from repro.serve import lm_engine

    assert hasattr(lm_engine, "ServeEngine") and hasattr(lm_engine, "make_decode_step")


# ---- serving tier v2: pump / rate limit / autoscale / sharding --------------


def _await_drained(engine, names, timeout_s=120.0):
    deadline = time.monotonic() + timeout_s
    while True:
        acct = engine.accounting()
        if all(acct["tenants"][n]["pending"] == 0 for n in names):
            return
        assert time.monotonic() < deadline, f"pump never drained: {acct}"
        time.sleep(0.002)


def test_background_pump_serves_bit_identical_to_solo():
    cfg = small_config("binary_tree", "broadcast")
    engine, specs = _engine(cfg, ["sparse_poisson", "hotspot_core"], keep_currents=True)
    streams = {s.name: np.asarray(s.stream(9, round=0)) for s in specs}
    engine.start(poll_interval_s=0.001)
    assert engine.running
    for name, frames in streams.items():
        engine.submit(name, frames)
    _await_drained(engine, streams)
    engine.stop(drain=True)
    assert not engine.running and engine.pump_errors() == []
    assert engine.accounting()["closes"]
    session = _session(cfg)
    for spec in specs:
        cur_solo, acc_solo = session.run(streams[spec.name])
        assert np.array_equal(engine.currents(spec.name), np.asarray(cur_solo)), spec.name
        _assert_stats_equal(engine.tenant_stats(spec.name), acc_solo, spec.name)
    # the engine is restartable: the context manager runs a second burst
    with engine:
        engine.submit_scenario("t0", 5)
        _await_drained(engine, ["t0"])
    assert engine.ticks_served("t0") == 14


def test_pump_fatal_error_surfaces_on_submit(monkeypatch):
    cfg = small_config("binary_tree", "broadcast")
    engine, _ = _engine(cfg, ["sparse_poisson"])

    def boom(force=False):
        raise RuntimeError("pump exploded")

    monkeypatch.setattr(engine, "pump", boom)
    engine.start(poll_interval_s=0.001)
    deadline = time.monotonic() + 30
    while engine.running:
        assert time.monotonic() < deadline
        time.sleep(0.002)
    with pytest.raises(ServeError, match="pump exploded"):
        engine.submit_scenario("t0", 2)
    assert engine.registry.counter("serve.pump.fatal").value == 1
    engine.stop()  # fatal already surfaced; stop is a clean no-op join


def test_pump_survives_retries_exhausted(monkeypatch):
    cfg = small_config("binary_tree", "broadcast")
    engine, _ = _engine(cfg, ["sparse_poisson"])
    real_pump, tripped = engine.pump, []

    def flaky(force=False):
        if not tripped:
            tripped.append(1)
            raise RetriesExhaustedError("transfer still failing")
        return real_pump(force=force)

    monkeypatch.setattr(engine, "pump", flaky)
    engine.start(poll_interval_s=0.001)
    engine.submit_scenario("t0", 6)
    _await_drained(engine, ["t0"])
    engine.stop(drain=True)
    errors = engine.pump_errors()
    assert len(errors) == 1 and isinstance(errors[0], RetriesExhaustedError)
    assert engine.ticks_served("t0") == 6 and engine.accounting()["closes"]


def test_rate_limit_typed_rejection_and_refill():
    cfg = small_config("binary_tree", "broadcast")
    clock = _FakeClock()
    engine = ServeEngine(
        flush_ticks=4,
        flush_deadline_s=0.0,
        clock=clock,
        policy=AdmissionPolicy(rate_limit_per_s=8.0, rate_limit_burst=8.0),
    )
    engine.register(TenantSpec("t0", cfg))
    engine.submit("t0", _frames(8, cfg))  # drains the full burst
    with pytest.raises(RateLimitedError, match="rate-limited"):
        engine.submit("t0", _frames(1, cfg))
    assert engine.registry.counter("serve.rate_limited").value == 1
    assert engine.registry.counter("serve.rate_limited_ticks").value == 1
    # rejected ticks never entered the ledger
    assert engine.ticks_submitted("t0") == 8
    clock.now += 0.5  # refills 4 tokens
    engine.submit("t0", _frames(4, cfg))
    with pytest.raises(RateLimitedError, match="never be admitted"):
        engine.submit("t0", _frames(9, cfg))  # larger than the burst
    assert engine.drain() == 12
    assert engine.accounting()["closes"]
    fleet = engine.serve_report()[-1]
    assert fleet["faults"]["rate_limited"] == 2


def test_token_bucket_semantics():
    clock = _FakeClock()
    bucket = TokenBucket(rate=10.0, capacity=5.0, clock=clock)
    assert bucket.take(5) and not bucket.take(1)  # starts full; all-or-nothing
    clock.now += 0.25
    assert bucket.tokens() == pytest.approx(2.5)
    assert not bucket.take(3) and bucket.take(2.5)
    clock.now += 100.0
    assert bucket.tokens() == pytest.approx(5.0)  # capped at capacity
    with pytest.raises(ValueError, match="rate"):
        TokenBucket(rate=0.0, capacity=5.0)
    with pytest.raises(ValueError, match="burst"):
        AdmissionPolicy(rate_limit_burst=4.0)  # burst without a rate


def test_quarantined_backlog_sheds_past_deadline():
    """Regression: staged backlog frames never aged against the shed
    deadline - a quarantined lane's work could wait forever instead of
    shedding, violating what shed_deadline_s promises."""
    cfg = small_config("binary_tree", "broadcast")
    clock = _FakeClock()
    engine = ServeEngine(
        flush_ticks=4,
        flush_deadline_s=0.0,
        clock=clock,
        policy=AdmissionPolicy(shed_deadline_s=1.0),
    )
    engine.register(TenantSpec("t0", cfg))
    engine.submit_scenario("t0", 6)
    for _ in range(engine.health.policy.quarantine_after):
        engine.health.record_failure("t0")
    assert engine.lane_health("t0") == "quarantined"
    assert engine.pump(force=True) == 0  # staged but skipped, age 0: kept
    group = engine._tenant_group["t0"]
    assert group.backlog_ticks_of("t0") == 6
    clock.now = 5.0
    assert engine.pump(force=True) == 0  # aged out: shed, not served
    assert group.backlog_ticks_of("t0") == 0
    assert engine.ticks_shed("t0") == 6
    acct = engine.accounting()
    assert acct["closes"] and acct["tenants"]["t0"]["pending"] == 0
    assert any("backlog" in str(e) for e in engine.shed_errors())


def test_retry_recovery_clock_starts_at_first_attempt():
    """Regression: serve.recovery_ms used to start after the first failed
    attempt *returned*, so the failed attempt's own wall time - most of a
    real outage - was silently excluded."""
    clock = _FakeClock()
    engine = ServeEngine(flush_ticks=4, clock=clock, sleep=lambda s: None)
    tripped = []

    def flaky():
        if not tripped:
            tripped.append(1)
            clock.now += 2.0  # the failing attempt itself takes 2s
            raise TransientFaultError("transient")
        clock.now += 1.0
        return "ok"

    assert engine._with_retries("execute", flaky) == "ok"
    hist = engine.registry.histograms["serve.recovery_ms"]
    assert hist.count == 1
    assert hist.total == pytest.approx(3000.0)  # 2s failed attempt + 1s retry


def test_autoscale_policy_targets():
    exact = AutoscalePolicy()
    assert exact.target(3, 8) == 3 and exact.target(0, 0) == 1
    geo = AutoscalePolicy(grow_factor=2.0, shrink_at=0.5)
    assert geo.target(3, 2) == 4 and geo.target(5, 4) == 8
    assert geo.target(3, 8) == 4  # 3 > 4 * 0.5: hysteresis holds at 4
    assert geo.target(2, 8) == 2  # 2 <= 4 * 0.5: shrinks through to the floor
    floor = AutoscalePolicy(min_lanes=4)
    assert floor.target(1, 0) == 4
    with pytest.raises(ValueError, match="grow_factor"):
        AutoscalePolicy(grow_factor=0.5)
    with pytest.raises(ValueError, match="shrink_at"):
        AutoscalePolicy(shrink_at=0.0)


def test_autoscale_grow_shrink_preserves_solo_bit_identity():
    cfg = small_config("binary_tree", "multicast_tree")
    engine = ServeEngine(flush_ticks=4, flush_deadline_s=0.0, keep_currents=True)
    engine.register(TenantSpec("t0", cfg, scenario="sparse_poisson", seed=0))
    engine.submit_scenario("t0", 6)
    assert engine.drain() == 6
    engine.register(TenantSpec("t1", cfg, scenario="hotspot_core", seed=1))
    group = engine._tenant_group["t0"]
    assert group.capacity == 2 and group.capacities_seen == {1, 2}
    engine.submit_scenario("t0", 5)
    engine.submit_scenario("t1", 7)
    assert engine.drain() == 12
    assert engine.accounting()["closes"]
    engine.submit_scenario("t1", 3)
    with pytest.raises(ServeError, match="pending"):
        engine.deregister("t1")  # a lane with queued work cannot retire
    assert engine.drain() == 3
    spec0 = group.specs["t0"]
    engine.deregister("t1")
    assert group.capacity == 1 and "t1" not in group.lanes
    engine.submit_scenario("t0", 4)
    assert engine.drain() == 4
    # t0's chunks crossed capacities 1 -> 2 -> 1; its cumulative stream
    # must still equal one uninterrupted solo run, stats included
    session = _session(cfg)
    full = np.concatenate(
        [np.asarray(spec0.stream(t, round=r)) for r, t in enumerate((6, 5, 4))]
    )
    cur, acc = session.run(full)
    assert np.array_equal(engine.currents("t0"), np.asarray(cur))
    _assert_stats_equal(engine.tenant_stats("t0"), acc, "t0 across resizes")
    acct = engine.accounting()
    assert acct["closes"] and acct["tenants"]["t1"]["pending"] == 0  # retired row
    assert engine.registry.counter("serve.autoscale.grow").value == 2
    assert engine.registry.counter("serve.autoscale.shrink").value == 1
    assert engine.serve_report()[-1]["lane_capacity"] == 1


def _chip_cfg(chips=2, cores=8, n=16, entries=32):
    return InterfaceConfig(cores=cores, neurons_per_core=n,
                           cam_entries_per_core=entries, scheme="hier_tree",
                           noc=topology.NocConfig("multicast_tree"), chips=chips)


def test_sharded_group_bit_identical_and_separate_from_flat():
    cfg = _chip_cfg()
    engine = ServeEngine(flush_ticks=4, flush_deadline_s=0.0, keep_currents=True)
    engine.register(TenantSpec("s0", cfg, shard="chips", seed=0))
    engine.register(TenantSpec("s1", cfg, shard="chips", scenario="hotspot_core", seed=1))
    engine.register(TenantSpec("f0", cfg, seed=0))
    # sharded and flat tenants of the SAME config land in different groups
    assert len(engine.groups) == 2
    group = engine._tenant_group["s0"]
    assert group.shard == "chips" and engine._tenant_group["f0"] is not group
    for name, t in (("s0", 7), ("s1", 5), ("f0", 7)):
        engine.submit_scenario(name, t)
    assert engine.drain() == 19
    # each sharded lane is bit-identical to the flat unsharded oracle
    session = _session(cfg)
    for name, t in (("s0", 7), ("s1", 5)):
        spec = group.specs[name]
        cur, acc = session.run(spec.stream(t, round=0))
        assert np.array_equal(engine.currents(name), np.asarray(cur)), name
        _assert_stats_equal(engine.tenant_stats(name), acc, name)
    assert group.jit_cache_entries() == 1
    assert engine.accounting()["closes"]
    # rejected composition is a typed error at spec construction
    with pytest.raises(CompositionError, match="one-chip"):
        TenantSpec("bad", small_config("binary_tree", "broadcast"), shard="chips")
    with pytest.raises(ValueError, match="unknown shard"):
        TenantSpec("bad", cfg, shard="dies")
    # the package-level ServeEngine is the fabric streaming engine now
    assert hasattr(ServeEngine, "register") and hasattr(ServeEngine, "drain")
