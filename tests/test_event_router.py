"""HAT-style MoE event router: capacity semantics + combine correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import event_router as er

KEY = jax.random.PRNGKey(0)


def test_no_drop_combine_is_weighted_identity():
    logits = jax.random.normal(KEY, (32, 8))
    r = er.hat_route(logits, k=2, capacity=64)
    assert bool(r.kept.all())
    x = jax.random.normal(KEY, (32, 16))
    y = er.combine(er.dispatch(x, r), r, 32)
    assert jnp.allclose(y, x, atol=1e-5)


def test_capacity_drops_are_fifo_by_token():
    """Earlier tokens win slots - the AER arbitration order."""
    t, e = 16, 2
    logits = jnp.stack([jnp.ones((t,)) * 5.0, jnp.zeros((t,))], axis=1)
    r = er.hat_route(logits, k=1, capacity=4)  # all want expert 0
    kept_tokens = np.nonzero(np.array(r.kept[:, 0]))[0]
    assert list(kept_tokens) == [0, 1, 2, 3]


def test_load_counts():
    logits = jax.random.normal(KEY, (64, 8))
    r = er.hat_route(logits, k=2, capacity=64)
    assert int(r.load.sum()) == 64 * 2
    ids = np.array(r.expert_ids).reshape(-1)
    want = np.bincount(ids, minlength=8)
    assert np.array_equal(np.array(r.load), want)


def test_buffer_rows_consistent_with_event_slot():
    logits = jax.random.normal(KEY, (32, 4))
    r = er.hat_route(logits, k=2, capacity=8)
    buf = np.array(r.buffer_rows)
    ids = np.array(r.expert_ids)
    slots = np.array(r.event_slot)
    kept = np.array(r.kept)
    for tkn in range(32):
        for j in range(2):
            if kept[tkn, j]:
                assert buf[ids[tkn, j], slots[tkn, j]] == tkn


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64), st.integers(2, 16), st.integers(1, 4),
       st.integers(0, 2 ** 31 - 1))
def test_positions_never_exceed_capacity(t, e, k, seed):
    k = min(k, e)
    logits = jax.random.normal(jax.random.PRNGKey(seed), (t, e))
    cap = max(1, (t * k) // e)
    r = er.hat_route(logits, k=k, capacity=cap)
    slots = np.array(r.event_slot)
    kept = np.array(r.kept)
    assert (slots[kept] < cap).all()
    assert (slots[kept] >= 0).all()
    # per-expert kept count <= capacity
    buf = np.array(r.buffer_rows)
    assert ((buf >= 0).sum(axis=1) <= cap).all()


def test_hierarchical_scan_matches_flat():
    logits = jax.random.normal(KEY, (64, 16))
    r1 = er.hat_route(logits, k=2, capacity=16, use_hierarchical_scan=False)
    r2 = er.hat_route(logits, k=2, capacity=16, use_hierarchical_scan=True)
    assert bool((r1.event_slot == r2.event_slot).all())
    assert bool((r1.buffer_rows == r2.buffer_rows).all())
