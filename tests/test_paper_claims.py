"""Golden tests for the paper's abstract-level claims.

The abstract (arxiv 2308.04171) states three headline numbers; these
tests pin the reproduction to them with explicit tolerances:

  * "reduces the latency by more than 70% in sparse-event mode,
    compared to the state-of-the-art arbitration architectures" - the
    hierarchical arbiter tree (HAT) vs the hierarchical token ring, in
    the calibrated 22FDX ns domain (Table I derives 78.3%);
  * the CSCD CAM "saves approximately 46% energy ... against
    conventional asynchronous CAM using configurable delay lines"
    (delay-line CAM = the ``conventional`` variant);
  * "achieves a 40% increase in throughput" - the cycle-time cut of the
    full proposed CAM at the 512-entry design point (Fig. 10: 40.4%).

Each claim is asserted both from the closed-form/report layer and, for
the latency claim, re-derived from generated `repro.traffic` rasters so
the number comes out of simulated workloads, not formulas alone.
"""

import pytest

from benchmarks import paper_tables
from repro.core import cam, ppa
from repro.interface import ppa_report


def test_sparse_mode_latency_reduction_at_least_70_percent():
    rows, derived = paper_tables.table1_sparse_latency()
    # abstract: ">70%"; Table I at N=256: 1 - 2.0/9.2 = 0.783
    assert derived["hat_vs_htr_sparse_reduction"] >= 0.70
    assert derived["hat_vs_htr_sparse_reduction"] == pytest.approx(0.783, abs=0.02)


def test_sparse_mode_reduction_reproduces_from_generated_traffic():
    """The >=70% claim from scenario traffic, not closed-form inputs."""
    rows, derived = paper_tables.traffic_arbiter_latency(ticks=32)
    assert derived["sparse_reduction_vs_hier_ring"] >= 0.70
    assert derived["sparse_reduction_vs_token_ring"] >= 0.90
    # Table II: HAT's full-frame burst completion within ~10% of the
    # token ring (the burst-optimal scheme) - sparse wins are not bought
    # with a burst collapse
    assert derived["burst_ratio_vs_token_ring"] == pytest.approx(1.07, abs=0.08)


def test_hat_sparse_latency_via_ppa_report():
    hat = ppa_report_sparse_ns("hier_tree")
    htr = ppa_report_sparse_ns("hier_ring")
    assert 1.0 - hat / htr >= 0.70


def ppa_report_sparse_ns(scheme: str) -> float:
    from repro.interface import InterfaceConfig

    rep = ppa_report(InterfaceConfig(cores=4, neurons_per_core=256, scheme=scheme))
    return rep["arbiter"]["sparse_latency_ns"]


def test_cam_energy_saving_approximately_46_percent():
    # abstract: "saves approximately 46% energy"; paper Fig. 11 random
    # case reports 46.7%, encoded as the calibration constant
    assert ppa.CAM_ENERGY_SAVING["random"] == pytest.approx(0.467, abs=0.005)
    # the behavioural model reproduces the paper's endpoint cases...
    assert cam.energy_saving("all_match") == pytest.approx(
        ppa.CAM_ENERGY_SAVING["all_match"], abs=0.02
    )
    assert cam.energy_saving("all_mismatch") == pytest.approx(
        ppa.CAM_ENERGY_SAVING["all_mismatch"], abs=0.02
    )
    # ...while the random case lands at ~40%: the paper's 46.7% is not
    # simultaneously consistent with its endpoints under a linear energy
    # model (documented repro finding, see cam.py / fig11_cam_energy)
    assert 0.35 <= cam.energy_saving("random") <= 0.47


def test_cam_throughput_gain_approximately_40_percent():
    # abstract: "a 40% increase in throughput"; Fig. 10 at 512 entries
    # reports a 40.4% search-cycle-time cut vs the delay-line CAM
    assert cam.cycle_improvement(512) == pytest.approx(0.404, abs=0.02)
    assert cam.cycle_improvement(512) >= 0.35
    rows, derived = paper_tables.fig10_cam_cycle()
    assert derived["improvement_512"] == pytest.approx(derived["paper_512"], abs=0.02)
    assert derived["improvement_16"] == pytest.approx(derived["paper_16"], abs=0.02)
