"""Training loop, checkpointing, fault tolerance, data pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager, restore, save
from repro.data.pipeline import DataConfig, Pipeline
from repro.ft.runner import (FailureInjector, Watchdog,
                             run_with_restarts)
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.train import step as ts

KEY = jax.random.PRNGKey(0)


def _cfg():
    return ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                       head_dim=16, param_dtype="float32",
                       compute_dtype="float32")


def _opt():
    return AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=100)


def test_loss_decreases():
    cfg, opt = _cfg(), _opt()
    state = ts.init_state(KEY, cfg, opt)
    step = jax.jit(ts.make_train_step(cfg, opt))
    pipe = Pipeline(cfg, DataConfig(global_batch=8, seq_len=64, seed=0))
    losses = []
    for i in range(25):
        state, m = step(state, pipe.batch(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.85


@pytest.mark.slow
def test_microbatch_equivalent_to_full_batch():
    cfg, opt = _cfg(), _opt()
    state = ts.init_state(KEY, cfg, opt)
    pipe = Pipeline(cfg, DataConfig(global_batch=8, seq_len=32, seed=0))
    batch = pipe.batch(0)
    s1, m1 = jax.jit(ts.make_train_step(cfg, opt, microbatch=1))(state, batch)
    s2, m2 = jax.jit(ts.make_train_step(cfg, opt, microbatch=4))(state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                     s1.params, s2.params)
    assert max(jax.tree.leaves(d)) < 5e-4


def test_adamw_schedule():
    opt = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(adamw.schedule(opt, jnp.int32(5))) == pytest.approx(0.5)
    assert float(adamw.schedule(opt, jnp.int32(10))) == pytest.approx(1.0)
    assert float(adamw.schedule(opt, jnp.int32(100))) == pytest.approx(0.1)


def test_bf16_moments():
    cfg = _cfg()
    opt = AdamWConfig(moment_dtype="bfloat16")
    state = ts.init_state(KEY, cfg, opt)
    assert all(m.dtype == jnp.bfloat16 for m in jax.tree.leaves(state.opt.mu))
    step = jax.jit(ts.make_train_step(cfg, opt))
    pipe = Pipeline(cfg, DataConfig(global_batch=4, seq_len=32, seed=0))
    state, m = step(state, pipe.batch(0))
    assert bool(jnp.isfinite(m["loss"]))


# ---- checkpointing -----------------------------------------------------------

def test_save_restore_bitexact(tmp_path):
    cfg, opt = _cfg(), _opt()
    state = ts.init_state(KEY, cfg, opt)
    path = str(tmp_path / "c.npz")
    save(path, state, step=7, extra={"data_step": 7})
    back = restore(path, state)
    same = jax.tree.map(lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()),
                        state, back)
    assert all(jax.tree.leaves(same))


def test_manager_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1, keep=2, async_save=False)
    tree = {"w": jnp.arange(4.0)}
    for s in (1, 2, 3, 4):
        mgr.maybe_save(s, {"w": jnp.arange(4.0) * s})
    assert mgr.latest_step() == 4
    files = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    assert len(files) == 2  # keep-k GC
    back, meta = mgr.restore_latest(tree)
    assert meta["step"] == 4
    assert bool((back["w"] == jnp.arange(4.0) * 4).all())


# ---- fault tolerance -----------------------------------------------------------

def test_injected_failure_resume_matches_uninterrupted(tmp_path):
    """Crash at step 7, restart, final params == uninterrupted run."""
    cfg, opt = _cfg(), _opt()
    pipe = Pipeline(cfg, DataConfig(global_batch=4, seq_len=32, seed=0))
    step_fn = jax.jit(ts.make_train_step(cfg, opt))

    # uninterrupted reference
    ref_state = ts.init_state(KEY, cfg, opt)
    for i in range(10):
        ref_state, _ = step_fn(ref_state, pipe.batch(i))

    mgr = CheckpointManager(str(tmp_path / "ft"), every=2, keep=5,
                            async_save=False)
    injector = FailureInjector(fail_at_steps=(7,))
    state, _ = run_with_restarts(
        lambda: ts.init_state(KEY, cfg, opt), step_fn, pipe, num_steps=10,
        manager=mgr, injector=injector, logger=lambda *a: None)
    d = jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                - b.astype(jnp.float32)).max()),
                     ref_state.params, state.params)
    # resume from step 6 checkpoint replays steps 6-9 bit-identically
    assert max(jax.tree.leaves(d)) < 1e-6


def test_watchdog_flags_stragglers():
    w = Watchdog(straggler_factor=3.0)
    for _ in range(10):
        assert not w.observe(0.1)
    assert w.observe(1.0)
    assert w.stragglers == 1


def test_elastic_restore_roundtrip(tmp_path):
    """Checkpoint is mesh-agnostic: restore works onto a fresh state tree."""
    cfg, opt = _cfg(), _opt()
    state = ts.init_state(KEY, cfg, opt)
    path = str(tmp_path / "e.npz")
    save(path, state, step=1)
    # new process / new mesh: rebuild abstract state, restore into it
    state2 = ts.init_state(jax.random.PRNGKey(42), cfg, opt)
    back = restore(path, state2)
    assert bool((np.asarray(back.params["embed"])
                 == np.asarray(state.params["embed"])).all())


# ---- data pipeline ---------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    cfg = _cfg()
    p1 = Pipeline(cfg, DataConfig(global_batch=4, seq_len=16, seed=3))
    p2 = Pipeline(cfg, DataConfig(global_batch=4, seq_len=16, seed=3))
    b1, b2 = p1.batch(11), p2.batch(11)
    assert bool((b1["tokens"] == b2["tokens"]).all())
    b3 = p1.batch(12)
    assert not bool((b1["tokens"] == b3["tokens"]).all())


def test_labels_are_shifted_tokens():
    cfg = _cfg()
    p = Pipeline(cfg, DataConfig(global_batch=2, seq_len=16, seed=0))
    b = p.batch(0)
    assert bool((b["labels"][:, :-1] == b["tokens"][:, 1:]).all())
    assert bool((b["labels"][:, -1] == -100).all())
