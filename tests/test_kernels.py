"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.cam_search import ops as cam_ops, ref as cam_ref
from repro.kernels.hat_encode import ops as hat_ops
from repro.kernels.lif_step import ops as lif_ops
from repro.kernels.moe_dispatch import ops as moe_ops

KEY = jax.random.PRNGKey(0)


# ---- cam_search --------------------------------------------------------------

@pytest.mark.parametrize("b,e,bits", [(8, 16, 11), (128, 128, 11),
                                      (256, 64, 33), (64, 512, 44)])
def test_cam_search_sweep(b, e, bits):
    k1, k2, k3 = jax.random.split(KEY, 3)
    tags = jax.random.bernoulli(k1, 0.5, (e, bits)).astype(jnp.int32)
    # force some matches by copying tags into queries
    qbits = jax.random.bernoulli(k2, 0.5, (b, bits)).astype(jnp.int32)
    qbits = qbits.at[: min(b, e)].set(tags[: min(b, e)])
    valid = jax.random.bernoulli(k3, 0.9, (e,))
    t_p, q_p = cam_ref.pack_bits(tags), cam_ref.pack_bits(qbits)
    want = cam_ops.cam_search(q_p, t_p, valid, impl="xla")
    got = cam_ops.cam_search(q_p, t_p, valid, impl="pallas", interpret=True)
    assert bool((want == got).all())
    assert int(want.sum()) > 0  # the sweep actually exercises matches


def test_cam_first_match_and_speculative():
    tags = jax.random.bernoulli(KEY, 0.5, (64, 11)).astype(jnp.int32)
    t_p = cam_ref.pack_bits(tags)
    q_p = t_p[:16]
    valid = jnp.ones((64,), bool)
    fm = cam_ops.cam_first_match(q_p, t_p, valid, impl="pallas",
                                 interpret=True)
    assert bool((fm[:16] <= jnp.arange(16)).all())
    spec = cam_ops.cam_search_speculative(q_p, t_p, valid)
    full = cam_ops.cam_search(q_p, t_p, valid)
    assert bool((spec == full).all())


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
def test_cam_pack_bits_roundtrip_words(words, seed):
    bits = words * 32
    x = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.5, (5, bits))
    packed = cam_ref.pack_bits(x.astype(jnp.int32))
    assert packed.shape == (5, words)
    # unpack manually and compare
    unpacked = ((packed[..., :, None].astype(jnp.uint32)
                 >> jnp.arange(32, dtype=jnp.uint32)) & 1)
    unpacked = unpacked.reshape(5, bits)
    assert bool((unpacked == x.astype(jnp.uint32)).all())


# ---- hat_encode ---------------------------------------------------------------

@pytest.mark.parametrize("n,row", [(256, 256), (1024, 256), (4096, 128),
                                   (65536, 256)])
@pytest.mark.parametrize("rate", [0.0, 0.05, 1.0])
def test_hat_encode_sweep(n, row, rate):
    spk = jax.random.bernoulli(KEY, rate, (n,))
    rx, cx, ccx = hat_ops.hat_encode(spk, row=row, impl="xla")
    rp, cp, ccp = hat_ops.hat_encode(spk, row=row, impl="pallas",
                                     interpret=True)
    assert bool((rx == rp).all()) and int(cx) == int(cp)
    assert bool((ccx == ccp).all())


def test_hat_encode_stream_is_sorted_actives():
    spk = jax.random.bernoulli(KEY, 0.1, (1024,))
    stream, cnt = hat_ops.encode_stream(spk, impl="pallas", interpret=True)
    active = np.nonzero(np.array(spk))[0]
    assert int(cnt) == len(active)
    assert np.array_equal(np.array(stream[: len(active)]), active)
    assert bool((stream[len(active):] == 1024).all())


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.0, 1.0))
def test_hat_encode_property(seed, rate):
    spk = jax.random.bernoulli(jax.random.PRNGKey(seed), rate, (512,))
    ranks, count, ccounts = hat_ops.hat_encode(spk, row=128, impl="pallas",
                                               interpret=True)
    n_active = int(spk.sum())
    assert int(count) == n_active
    assert int(ccounts.sum()) == n_active
    r = np.array(ranks)
    # active ranks are a permutation of 0..count-1, ascending in address
    act = r[r >= 0]
    assert sorted(act) == list(range(n_active))
    assert list(act) == sorted(act)


# ---- moe_dispatch ---------------------------------------------------------------

@pytest.mark.parametrize("m,e", [(256, 16), (2048, 160), (512, 64),
                                 (4096, 128)])
def test_moe_dispatch_sweep(m, e):
    ids = jax.random.randint(KEY, (m,), 0, e)
    px, lx = moe_ops.dispatch_positions(ids, num_experts=e, impl="xla")
    pp, lp = moe_ops.dispatch_positions(ids, num_experts=e, impl="pallas",
                                        interpret=True)
    assert bool((px == pp).all()) and bool((lx == lp).all())


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 32))
def test_moe_dispatch_property(seed, e):
    ids = jax.random.randint(jax.random.PRNGKey(seed), (256,), 0, e)
    pos, load = moe_ops.dispatch_positions(ids, num_experts=e,
                                           impl="pallas", interpret=True)
    ids_n, pos_n = np.array(ids), np.array(pos)
    # (expert, position) pairs are unique and dense per expert
    for ex in range(e):
        p = np.sort(pos_n[ids_n == ex])
        assert list(p) == list(range(len(p)))
    assert int(load.sum()) == 256


# ---- lif_step --------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 512), (16, 1024), (8, 4096), (32, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lif_step_sweep(shape, dtype):
    v = jax.random.normal(KEY, shape).astype(dtype)
    i = jax.random.normal(jax.random.PRNGKey(1), shape).astype(dtype)
    vx, sx = lif_ops.lif_step(v, i, decay=0.9, threshold=1.0, impl="xla")
    vp, sp = lif_ops.lif_step(v, i, decay=0.9, threshold=1.0, impl="pallas",
                              interpret=True)
    np.testing.assert_allclose(np.array(vx, np.float32),
                               np.array(vp, np.float32), rtol=1e-2, atol=1e-2)
    assert bool((sx == sp).all())


def test_lif_step_semantics():
    v = jnp.array([[0.5, 2.0, -1.0, 0.95]])
    i = jnp.zeros((1, 4))
    vn, s = lif_ops.lif_step(v, i, decay=1.0, threshold=1.0)
    assert s.tolist() == [[0.0, 1.0, 0.0, 0.0]]
    np.testing.assert_allclose(np.array(vn), [[0.5, 0.0, -1.0, 0.95]],
                               rtol=1e-6)
