"""SNN on the simulated fabric + AER encode/decode + PPA accounting."""

import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import aer, fabric
from repro.data.pipeline import snn_batch
from repro.models import snn

KEY = jax.random.PRNGKey(0)


def _cfg():
    return snn.SNNConfig(
        fabric=fabric.FabricConfig(cores=2, neurons_per_core=64,
                                   cam_entries_per_core=64),
        d_in=16, d_out=4, t_steps=8)


def test_aer_roundtrip():
    raster = jax.random.bernoulli(KEY, 0.1, (5, 64))
    enc = aer.encode_raster(raster)
    dec = aer.decode_events(enc["addresses"], enc["counts"], 64)
    assert bool(jnp.all(dec == raster))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_pack_unpack_address(seed):
    addrs = jax.random.randint(jax.random.PRNGKey(seed), (32,), 0, 256)
    fields = aer.pack_address(addrs, 256)
    assert fields.shape == (32, 4)  # log4(256) levels
    assert bool(jnp.all(aer.unpack_address(fields) == addrs))


def test_routing_matrix_equals_fabric_step():
    cfg = _cfg()
    params, topo = snn.init_snn(KEY, cfg)
    fab = snn.fabric_params(params, topo)
    spikes = jax.random.bernoulli(KEY, 0.1, (2, 64))
    cur_fab, _ = fabric.step(fab, spikes, cfg.fabric)
    r = snn.routing_matrix(fab, cfg.fabric)
    cur_mat = (spikes.reshape(-1).astype(jnp.float32) @ r).reshape(2, 64)
    assert jnp.allclose(cur_fab, cur_mat, atol=1e-4)


def test_snn_trains():
    cfg = _cfg()
    params, topo = snn.init_snn(KEY, cfg)
    batch = snn_batch(KEY, 32, cfg.t_steps, cfg.d_in, cfg.d_out)
    loss_g = jax.jit(jax.value_and_grad(
        lambda p: snn.snn_loss(p, topo, batch, cfg)))
    from repro.optim import adamw
    opt_cfg = adamw.AdamWConfig(lr=5e-3, warmup_steps=1, total_steps=60,
                                weight_decay=0.0)
    opt = adamw.init(opt_cfg, params)
    losses = []
    for _ in range(40):
        loss, grads = loss_g(params)
        params, opt, _ = adamw.update(opt_cfg, grads, opt, params)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9
    assert all(jnp.isfinite(jnp.asarray(losses)))


def test_surrogate_gradient_flows():
    v = jnp.linspace(-2, 2, 9)
    g = jax.vmap(jax.grad(snn.spike_fn))(v)
    assert float(g[4]) > 0.5          # steep near threshold
    assert float(g[0]) < 0.1          # flat far away
    y = snn.spike_fn(v)
    assert bool(jnp.all((y == 0) | (y == 1)))


def test_ppa_accounting_scales_with_activity():
    cfg = _cfg()
    params, topo = snn.init_snn(KEY, cfg)
    quiet = jnp.zeros((2, cfg.t_steps, cfg.d_in))
    loud = jnp.ones((2, cfg.t_steps, cfg.d_in)) * 3.0
    _, _, s_quiet = snn.snn_forward(params, topo, quiet, cfg, account=True)
    _, _, s_loud = snn.snn_forward(params, topo, loud, cfg, account=True)
    assert float(s_loud.events) > float(s_quiet.events)
    assert float(s_loud.cam_energy) >= float(s_quiet.cam_energy)


def test_interface_area_report():
    cfg = _cfg()
    rep = fabric.interface_area_um2(cfg.fabric)
    assert rep["arbiter_units"] == pytest.approx(9.0)  # 3*log4(64)
    assert rep["cam_um2"] > rep["cam_um2_baseline"]    # CSCD adds a bit


def test_lif_kernel_path_matches_surrogate_forward():
    cfg = _cfg()
    params, topo = snn.init_snn(KEY, cfg)
    x = jax.random.bernoulli(KEY, 0.3, (2, cfg.t_steps, cfg.d_in)
                             ).astype(jnp.float32)
    l1, r1, _ = snn.snn_forward(params, topo, x, cfg, impl="xla")
    l2, r2, _ = snn.snn_forward(params, topo, x, cfg, impl="pallas")
    assert jnp.allclose(l1, l2, atol=1e-5)
    assert jnp.allclose(r1, r2, atol=1e-5)
