"""`hypothesis` import with a deterministic fallback sampler.

The test suite uses a small slice of hypothesis (`@given` over integer /
float / list strategies).  When the real library is installed (see
requirements-dev.txt) it is used unchanged; otherwise this shim replays
each property over `max_examples` pseudo-random samples from a fixed seed -
no shrinking, but the properties still execute instead of erroring at
collection time.
"""

from __future__ import annotations

try:  # pragma: no cover - prefer the real thing
    from hypothesis import given, settings, strategies  # noqa: F401
except ImportError:
    import functools
    import random

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample  # rng -> value

    class strategies:  # noqa: N801 - mimics `hypothesis.strategies`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def lists(elements, min_size=0, max_size=10, unique=False):
            def sample(rng):
                size = rng.randint(min_size, max_size)
                if not unique:
                    return [elements.sample(rng) for _ in range(size)]
                out: list = []
                for _ in range(100 * max(size, 1)):
                    v = elements.sample(rng)
                    if v not in out:
                        out.append(v)
                    if len(out) == size:
                        break
                return out if len(out) >= min_size else out + [
                    elements.sample(rng)]
            return _Strategy(sample)

    def settings(max_examples=100, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(0)
                for _ in range(getattr(wrapper, "_max_examples", 100)):
                    fn(*args, *(s.sample(rng) for s in strats), **kwargs)
            # keep pytest from treating the wrapped signature's parameters
            # as fixtures: present a bare (*args, **kwargs) callable
            del wrapper.__wrapped__
            return wrapper
        return deco
