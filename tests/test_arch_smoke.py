"""Per-assigned-architecture smoke tests (deliverable f).

Each instantiates the REDUCED config of the same family and runs one
forward + one train step on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised only by the dry-run (ShapeDtypeStruct)."""

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.data.pipeline import DataConfig, Pipeline
from repro.optim.adamw import AdamWConfig
from repro.train import step as ts

KEY = jax.random.PRNGKey(0)

# One small arch stays in the fast lane as the smoke representative; the
# heavyweights (10-80s of CPU compile+step each) run under ``-m slow``.
_FAST_ARCHS = {"internlm2-1.8b"}


@pytest.mark.parametrize(
    "arch",
    [pytest.param(a, marks=() if a in _FAST_ARCHS else (pytest.mark.slow,))
     for a in sorted(configs.ARCHS)],
)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    state = ts.init_state(KEY, cfg, opt)
    pipe = Pipeline(cfg, DataConfig(global_batch=2, seq_len=16, seed=0))
    batch = pipe.batch(0)

    # forward
    from repro.models import lm
    out = lm.forward(state.params, batch, cfg, mode="train", remat=False)
    t_expect = 16 + (cfg.frontend.max_prefix
                     if cfg.frontend.kind == "vision" else 0)
    assert out["logits"].shape == (2, t_expect, cfg.vocab)
    assert bool(jnp.isfinite(out["logits"]).all()), f"{arch}: NaN logits"

    # one train step
    step_fn = jax.jit(ts.make_train_step(cfg, opt))
    state2, metrics = step_fn(state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: NaN loss"
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0.0
    # params actually changed
    delta = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         state.params, state2.params)
    assert max(jax.tree.leaves(delta)) > 0.0


@pytest.mark.parametrize("arch", sorted(configs.ARCHS))
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact assigned dimensions."""
    cfg = configs.get_config(arch)
    expect = {
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab)
    assert got == expect


def test_cell_matrix():
    cells = configs.all_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    assert len(runnable) == 31
    # encoder-only skips
    skips = {(a, s): w for a, s, ok, w in cells if not ok}
    assert ("hubert-xlarge", "decode_32k") in skips
    assert ("hubert-xlarge", "long_500k") in skips
    # long_500k runs only for rwkv + jamba
    long_ok = [a for a, s, ok, _ in cells if s == "long_500k" and ok]
    assert sorted(long_ok) == ["jamba-1.5-large-398b", "rwkv6-3b"]
