"""Distributed behaviour on 8 fake host devices (subprocess: device count
must be set before jax initializes; the main pytest process stays at 1)."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(body: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_sharded_train_matches_single_device():
    """Same seed/batch: 2x4-mesh loss == single-device loss."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models.config import ModelConfig
        from repro.optim.adamw import AdamWConfig, AdamWState
        from repro.train import step as ts
        from repro.data.pipeline import Pipeline, DataConfig
        from repro.parallel import sharding as shd
        from repro.launch.mesh import make_host_mesh, set_mesh, shard_map

        cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                          n_heads=8, n_kv_heads=4, d_ff=128, vocab=128,
                          head_dim=8, param_dtype="float32",
                          compute_dtype="float32")
        opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
        pipe = Pipeline(cfg, DataConfig(global_batch=8, seq_len=32, seed=0))
        batch = pipe.batch(0)

        state = ts.init_state(jax.random.PRNGKey(0), cfg, opt)
        _, m_ref = jax.jit(ts.make_train_step(cfg, opt))(state, batch)
        ref = float(m_ref["loss"])

        mesh = make_host_mesh(data=2, model=4)
        ctx = shd.make_shard_ctx(mesh, cfg)
        with set_mesh(mesh):
            specs = shd.params_pspecs(state.params, cfg, ctx)
            sh = shd.to_named(specs, mesh)
            params = jax.device_put(state.params, sh)
            st = ts.TrainState(params=params,
                               opt=AdamWState(step=state.opt.step,
                                              mu=jax.device_put(state.opt.mu, sh),
                                              nu=jax.device_put(state.opt.nu, sh)),
                               step=state.step)
            bsh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                               shd.batch_pspecs(batch, cfg, ctx))
            b = jax.device_put(batch, bsh)
            _, m = jax.jit(ts.make_train_step(cfg, opt, ctx=ctx))(st, b)
            dist = float(m["loss"])
        print("REF", ref, "DIST", dist)
        assert abs(ref - dist) < 1e-3, (ref, dist)
    """)
    assert "REF" in out


@pytest.mark.slow
def test_sequence_parallel_attention_matches():
    """SP attention (llama-style) == local attention values."""
    run_py("""
        import jax, jax.numpy as jnp
        from repro.models.config import ModelConfig
        from repro.models import lm
        from repro.models.blocks import ShardCtx
        from repro.parallel import sharding as shd
        from repro.launch.mesh import make_host_mesh, set_mesh, shard_map

        cfg = ModelConfig(name="sp", family="dense", n_layers=2, d_model=48,
                          n_heads=6, n_kv_heads=2, d_ff=96, vocab=64,
                          head_dim=8, attn_shard="sequence",
                          param_dtype="float32", compute_dtype="float32")
        p = lm.init_model(jax.random.PRNGKey(1), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, 64)
        ref = lm.forward(p, {"tokens": toks}, cfg, mode="train",
                         remat=False)["logits"]
        mesh = make_host_mesh(data=2, model=4)
        ctx = shd.make_shard_ctx(mesh, cfg)
        with set_mesh(mesh):
            got = jax.jit(lambda pp, tt: lm.forward(
                pp, {"tokens": tt}, cfg, mode="train", ctx=ctx,
                remat=False)["logits"])(p, toks)
        err = float(jnp.abs(ref - got).max())
        print("ERR", err)
        assert err < 1e-3
    """)


@pytest.mark.slow
def test_seq_sharded_decode_matches_local():
    run_py("""
        import jax, jax.numpy as jnp
        from repro.models.blocks import decode_attention, ShardCtx
        from repro.launch.mesh import make_host_mesh, set_mesh, shard_map
        from jax.sharding import NamedSharding, PartitionSpec as P

        key = jax.random.PRNGKey(0)
        b, s, kh, r, d = 2, 64, 2, 3, 16
        q = jax.random.normal(key, (b, 1, kh, r, d))
        k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kh, d))
        v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kh, d))
        clen = jnp.int32(50)
        ref = decode_attention(q, k, v, clen)
        mesh = make_host_mesh(data=2, model=4)
        ctx = ShardCtx(data_axes=("data",), model_axis="model",
                       model_size=4, enabled=True)
        with set_mesh(mesh):
            ks = jax.device_put(k, NamedSharding(mesh, P("data", "model")))
            vs = jax.device_put(v, NamedSharding(mesh, P("data", "model")))
            got = jax.jit(lambda q_, k_, v_: decode_attention(
                q_, k_, v_, clen, ctx=ctx))(q, ks, vs)
        err = float(jnp.abs(ref - got).max())
        print("ERR", err)
        assert err < 1e-4
    """)


@pytest.mark.slow
def test_compressed_psum_and_error_feedback():
    run_py("""
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.parallel import collectives as C
        from repro.launch.mesh import make_host_mesh, set_mesh, shard_map

        mesh = make_host_mesh(data=8, model=1)
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 1024))
        with set_mesh(mesh):
            exact = shard_map(
                lambda a: jax.lax.psum(a, "data"),
                in_specs=P("data", None), out_specs=P(None, None))(x)
            approx = shard_map(
                lambda a: C.compressed_psum_exact_scales(a, "data"),
                in_specs=P("data", None), out_specs=P(None, None))(x)
        rel = float(jnp.abs(exact - approx).max() / jnp.abs(exact).max())
        print("REL", rel)
        assert rel < 0.02  # int8 per-block quantization error bound

        # error feedback: accumulated mean of compressed syncs converges
        with set_mesh(mesh):
            def step(res, g):
                sync = C.make_ef_sync("data")
                return sync(g, res)
            g = jax.random.normal(jax.random.PRNGKey(1), (8, 512)) * 0.1
            res = jnp.zeros((8, 512))      # residual is per shard
            f = shard_map(step, in_specs=(P("data", None), P("data", None)),
                              out_specs=(P(None, None), P("data", None)))
            acc = jnp.zeros((1, 512))
            for i in range(20):
                s, res = f(res, g)
                acc = acc + s[:1]
            want = jnp.mean(g, axis=0, keepdims=True) * 20
            err = float(jnp.abs(acc - want).max() / jnp.abs(want).max())
            print("EF_ERR", err)
            assert err < 0.01  # EF keeps long-run bias ~0
    """)


@pytest.mark.slow
def test_quantize_roundtrip_bounds():
    from repro.parallel import collectives as C
    import jax, jax.numpy as jnp
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, s = C.quantize_int8(x)
    back = C.dequantize_int8(q, s, 1000)
    err = float(jnp.abs(back - x).max())
    assert err <= float(s.max()) * 0.5 + 1e-6
