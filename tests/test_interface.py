"""The unified `repro.interface` API: registries, sessions, invariants.

Covers the PR acceptance criteria:
  * `InterfaceSession.run` currents are bit-identical to the deprecated
    `fabric.step` for all three NoC schemes (property-style over random
    connectivity/spike draws via `tests/_hypothesis_compat.py`),
  * all scheme lookups go through the registries (unknown names fail with
    the registered list; new schemes plug in without touching the fabric),
  * `fabric.step` survives as a deprecated shim,
  * config validation catches cam-entries mismatches and stale NoC tables.
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.core import cam as cam_mod
from repro.core import fabric
from repro.interface import (
    Interface,
    InterfaceConfig,
    StepStats,
    build_routing_index,
    build_tables,
    ppa_report,
    registry,
)
from repro.interface import pipeline as interface_pipeline
from repro.noc import topology
from tests._hypothesis_compat import given, settings, strategies as st

KEY = jax.random.PRNGKey(0)
SCHEMES = ("broadcast", "unicast", "multicast_tree")


def _cfg(cores=4, n=16, entries=32, scheme="multicast_tree"):
    return fabric.FabricConfig(cores=cores, neurons_per_core=n,
                               cam_entries_per_core=entries,
                               noc=topology.NocConfig(scheme))


def _old_step(params, spikes, cfg, tables=None):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return fabric.step(params, spikes, cfg, tables)


# ---- cross-scheme / cross-API invariants ------------------------------------


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**16), st.floats(0.05, 0.6))
def test_session_bit_identical_to_fabric_step(seed, rate):
    """session.run == old fabric.step, tick for tick, for every scheme."""
    for scheme in SCHEMES:
        cfg = _cfg(scheme=scheme)
        params = fabric.random_connectivity(jax.random.PRNGKey(seed), cfg)
        t = 3
        spikes = jax.random.bernoulli(jax.random.PRNGKey(seed + 1), rate,
                                      (t, cfg.cores, cfg.neurons_per_core))
        session = Interface(cfg).compile(params)
        currents, acc = session.run(spikes)

        tables = fabric.noc_tables(params, cfg)
        ref_stats = StepStats.zeros()
        for i in range(t):
            cur_i, st_i = _old_step(params, spikes[i], cfg, tables)
            assert bool(jnp.all(currents[i] == cur_i)), \
                f"tick {i} currents differ from fabric.step under {scheme!r}"
            ref_stats = ref_stats.accumulate(st_i)
        for name in StepStats._fields:
            assert float(getattr(acc, name)) == pytest.approx(
                float(getattr(ref_stats, name)), rel=1e-5), (scheme, name)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**16), st.floats(0.05, 0.6))
def test_currents_bit_identical_across_schemes(seed, rate):
    """Transport scheme changes accounting only - never the currents."""
    base = _cfg()
    params = fabric.random_connectivity(jax.random.PRNGKey(seed), base)
    spikes = jax.random.bernoulli(jax.random.PRNGKey(seed + 1), rate,
                                  (2, base.cores, base.neurons_per_core))
    outs = {}
    for scheme in SCHEMES:
        cfg = dataclasses.replace(base, noc=topology.NocConfig(scheme))
        outs[scheme], _ = Interface(cfg).compile(params).run(spikes)
    assert bool(jnp.all(outs["broadcast"] == outs["unicast"]))
    assert bool(jnp.all(outs["broadcast"] == outs["multicast_tree"]))


ARBITER_SCHEMES = ("binary_tree", "greedy_tree", "token_ring", "hier_ring",
                   "hier_tree")


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 2**16), st.floats(0.05, 0.6))
def test_event_driven_tick_matches_dense_oracle(seed, rate):
    """Gather/scatter tick == dense-sweep + DES oracle, every StepStats
    field bit-for-bit, across all registered arbiter x NoC schemes and
    non-power-uniform spike patterns (one bursting core, one silent)."""
    for arb_scheme in ARBITER_SCHEMES:
        for noc_scheme in SCHEMES:
            cfg = _cfg(scheme=noc_scheme)
            cfg = dataclasses.replace(cfg, scheme=arb_scheme)
            params = fabric.random_connectivity(jax.random.PRNGKey(seed), cfg)
            spikes = jax.random.bernoulli(
                jax.random.PRNGKey(seed + 1), rate,
                (cfg.cores, cfg.neurons_per_core))
            spikes = spikes.at[0].set(True).at[-1].set(False)  # non-uniform
            cur, st = interface_pipeline.interface_tick(params, spikes, cfg)
            ref_cur, ref_st = interface_pipeline.interface_tick(
                params, spikes, cfg, oracle=True)
            key = (arb_scheme, noc_scheme)
            assert bool(jnp.all(cur == ref_cur)), key
            assert float(st.events) == float(ref_st.events), key
            assert float(st.cam_searches) == float(ref_st.cam_searches), key
            for name in StepStats._fields:
                assert float(getattr(st, name)) == float(
                    getattr(ref_st, name)), key + (name,)


def test_session_reuses_precompiled_routing_index():
    cfg = _cfg()
    params = fabric.random_connectivity(KEY, cfg)
    session = Interface(cfg).compile(params)
    ref = build_routing_index(params, session.config)
    assert bool(jnp.all(session.routing.src_idx == ref.src_idx))
    assert bool(jnp.all(session.routing.active == ref.active))
    # out-of-range tags are masked out, in-range indices reproduce the tags
    total = cfg.cores * cfg.neurons_per_core
    assert int(jnp.max(session.routing.src_idx)) < total


def test_impl_pallas_session_matches_xla():
    """The cam_search/hat_encode kernel route (interpret mode on CPU) is
    bit-identical to the XLA gather path, stats included."""
    cfg = InterfaceConfig(cores=4, neurons_per_core=16,
                          cam_entries_per_core=32)
    cfg_p = dataclasses.replace(cfg, impl="pallas")
    params = fabric.random_connectivity(KEY, cfg)
    spikes = jax.random.bernoulli(jax.random.PRNGKey(5), 0.3,
                                  (3, cfg.cores, cfg.neurons_per_core))
    cur_x, acc_x = Interface(cfg).compile(params).run(spikes)
    cur_p, acc_p = Interface(cfg_p).compile(params).run(spikes)
    assert bool(jnp.all(cur_x == cur_p))
    for name in StepStats._fields:
        assert float(getattr(acc_x, name)) == float(getattr(acc_p, name)), name


def test_impl_pallas_hat_kernel_path_matches_xla():
    """n=256 engages the hat_encode Pallas kernel (row=256) under vmap."""
    cfg = InterfaceConfig(cores=4, neurons_per_core=256,
                          cam_entries_per_core=64)
    cfg_p = dataclasses.replace(cfg, impl="pallas")
    params = fabric.random_connectivity(KEY, cfg)
    spikes = jax.random.bernoulli(jax.random.PRNGKey(6), 0.2,
                                  (cfg.cores, cfg.neurons_per_core))
    cur_x, st_x = Interface(cfg).compile(params).step(spikes)
    cur_p, st_p = Interface(cfg_p).compile(params).step(spikes)
    assert bool(jnp.all(cur_x == cur_p))
    assert float(st_x.encode_energy) == float(st_p.encode_energy)


@pytest.mark.parametrize("make", [fabric.FabricConfig, InterfaceConfig])
def test_config_rejects_unknown_impl(make):
    with pytest.raises(ValueError, match="impl"):
        make(impl="cuda")


def test_run_batched_matches_run():
    cfg = _cfg()
    params = fabric.random_connectivity(KEY, cfg)
    spikes = jax.random.bernoulli(jax.random.PRNGKey(1), 0.3,
                                  (2, 3, cfg.cores, cfg.neurons_per_core))
    session = Interface(cfg).compile(params)
    cur_b, acc_b = session.run_batched(spikes)
    assert cur_b.shape == spikes.shape[:2] + (cfg.cores, cfg.neurons_per_core)
    assert acc_b.events.shape == (2,)
    for b in range(2):
        cur, acc = session.run(spikes[b])
        assert bool(jnp.all(cur_b[b] == cur))
        assert float(acc_b.events[b]) == float(acc.events)


def test_step_stats_streaming_accumulation():
    z = StepStats.zeros()
    assert all(float(v) == 0.0 for v in z)
    one = StepStats(*[jnp.float32(i + 1) for i in range(len(StepStats._fields))])
    acc = z.accumulate(one).accumulate(one)
    assert float(acc.events) == 2.0 and float(acc.noc_energy) == 18.0
    means = acc.summary(ticks=2)
    assert means["events"] == 1.0 and means["noc_energy"] == 9.0
    totals = acc.summary()
    assert totals["cam_searches"] == 8.0


# ---- deprecated shim --------------------------------------------------------


def test_fabric_step_emits_deprecation_warning():
    cfg = _cfg()
    params = fabric.random_connectivity(KEY, cfg)
    spikes = jnp.zeros((cfg.cores, cfg.neurons_per_core), bool)
    with pytest.warns(DeprecationWarning, match="repro.interface"):
        fabric.step(params, spikes, cfg)


def test_mismatched_tables_raise_value_error():
    """Stale tables fail loudly (formerly an `assert`, gone under -O)."""
    cfg = _cfg(scheme="multicast_tree")
    params = fabric.random_connectivity(KEY, cfg)
    spikes = jnp.zeros((cfg.cores, cfg.neurons_per_core), bool)
    stale = build_tables(params, dataclasses.replace(
        cfg, noc=topology.NocConfig("unicast")))
    with pytest.raises(ValueError) as ei:
        _old_step(params, spikes, cfg, tables=stale)
    assert "unicast" in str(ei.value) and "multicast_tree" in str(ei.value)


# ---- config validation ------------------------------------------------------


@pytest.mark.parametrize("make", [fabric.FabricConfig, InterfaceConfig])
def test_cam_entries_mismatch_rejected(make):
    with pytest.raises(ValueError, match="cam_entries_per_core"):
        make(cam_entries_per_core=64, cam=cam_mod.CamConfig(entries=32))


@pytest.mark.parametrize("make", [fabric.FabricConfig, InterfaceConfig])
def test_cam_entries_agreement_accepted(make):
    cfg = make(cam_entries_per_core=64, cam=cam_mod.CamConfig(entries=64))
    assert cfg.cam.entries == 64 and cfg.cam_entries_per_core == 64
    assert make().cam.entries == 512          # default unchanged
    assert make(cam_entries_per_core=128).cam.entries == 128


def test_interface_config_rejects_unknown_schemes():
    with pytest.raises(ValueError, match="registered"):
        InterfaceConfig(scheme="quantum_arbiter")
    with pytest.raises(ValueError, match="registered"):
        InterfaceConfig(noc=topology.NocConfig("wormhole"))


# ---- registries -------------------------------------------------------------


def test_registries_list_builtins():
    assert set(registry.ARBITERS.names()) >= {
        "binary_tree", "greedy_tree", "token_ring", "hier_ring", "hier_tree"}
    assert set(registry.NOC_SCHEMES.names()) >= set(SCHEMES)
    assert set(registry.CAM_VARIANTS.names()) >= {
        "conventional", "cscd", "cscd+fb", "cscd+ss", "cscd+fb+ss"}


def test_duplicate_registration_rejected():
    entry = registry.NOC_SCHEMES.get("unicast")
    with pytest.raises(ValueError, match="already registered"):
        registry.register_noc_scheme("unicast", entry)
    registry.register_noc_scheme("unicast", entry, overwrite=True)  # explicit


def test_unknown_lookup_names_registered_schemes():
    with pytest.raises(KeyError, match="multicast_tree"):
        registry.get_noc_scheme("no_such_scheme")


def test_new_noc_scheme_plugs_in_without_fabric_edits():
    """A registered scheme flows through NocConfig -> session -> stats."""
    from repro.noc import router as noc_router

    unicast = registry.get_noc_scheme("unicast")
    entry = dataclasses.replace(unicast, name="unicast_copy")
    registry.register_noc_scheme("unicast_copy", entry)
    try:
        cfg = _cfg(scheme="unicast_copy")
        params = fabric.random_connectivity(KEY, cfg)
        spikes = jax.random.bernoulli(jax.random.PRNGKey(2), 0.3,
                                      (1, cfg.cores, cfg.neurons_per_core))
        cur, acc = Interface(cfg).compile(params).run(spikes)
        ref, ref_st = Interface(_cfg(scheme="unicast")).compile(params).run(spikes)
        assert bool(jnp.all(cur == ref))
        assert float(acc.noc_hops) == float(ref_st.noc_hops)
        tables = noc_router.build_tables(
            params.tags, params.valid, cores=cfg.cores,
            neurons_per_core=cfg.neurons_per_core, tag_bits=cfg.tag_bits,
            scheme="unicast_copy")
        assert tables.scheme == "unicast_copy"
    finally:
        registry.NOC_SCHEMES.unregister("unicast_copy")


def test_new_arbiter_plugs_in_and_reports_gracefully():
    """A runtime-registered arbiter simulates, runs, and reports (None
    closed forms) without edits to the simulator, fabric, or report."""
    from repro.core import arbiter as arb

    base = registry.get_arbiter("binary_tree")
    registry.register_arbiter(
        "binary_tree_copy", dataclasses.replace(base, name="binary_tree_copy"))
    try:
        cfg = dataclasses.replace(_cfg(), scheme="binary_tree_copy")
        params = fabric.random_connectivity(KEY, cfg)
        spikes = jax.random.bernoulli(jax.random.PRNGKey(3), 0.3,
                                      (1, cfg.cores, cfg.neurons_per_core))
        cur, _ = Interface(cfg).compile(params).run(spikes)
        ref, _ = Interface(dataclasses.replace(cfg, scheme="binary_tree")
                           ).compile(params).run(spikes)
        assert bool(jnp.all(cur == ref))
        rep = ppa_report(cfg)
        assert rep["arbiter"]["sparse_latency_units"] is None
        assert rep["cam"]["cycle_time_ns"] > 0
        grants = arb.Arbiter(arb.ArbiterConfig("binary_tree_copy", 16)
                             ).simulate(jnp.zeros(16))
        assert bool(jnp.all(jnp.isfinite(grants)))
    finally:
        registry.ARBITERS.unregister("binary_tree_copy")


def test_arbiter_overwrite_does_not_serve_stale_traces():
    """The jit cache is keyed on the entry, not the scheme name."""
    from repro.core import arbiter as arb

    cfg = arb.ArbiterConfig("binary_tree", 16)
    before = arb.Arbiter(cfg).simulate(jnp.zeros(16))
    original = registry.get_arbiter("binary_tree")
    slow = dataclasses.replace(
        original,
        grant_delay=lambda ctx, sel, backlog, th, tl, pa, ga:
            jnp.float32(1000.0))
    registry.register_arbiter("binary_tree", slow, overwrite=True)
    try:
        after = arb.Arbiter(cfg).simulate(jnp.zeros(16))
        assert float(jnp.min(after)) >= 1000.0, "stale trace served"
    finally:
        registry.register_arbiter("binary_tree", original, overwrite=True)
    restored = arb.Arbiter(cfg).simulate(jnp.zeros(16))
    assert bool(jnp.all(restored == before))


def test_custom_cam_variant_via_variant_name():
    base = registry.get_cam_variant("cscd+fb+ss")
    registry.register_cam_variant(
        "slow_cam", dataclasses.replace(base, name="slow_cam",
                                        settle_frac=0.95))
    try:
        fast = cam_mod.CamConfig(entries=64)
        slow = cam_mod.CamConfig(entries=64, variant_name="slow_cam")
        assert cam_mod.cycle_time_ns(slow) > cam_mod.cycle_time_ns(fast)
        # energy model follows the registered entry's flags, not the literal
        assert cam_mod.search_energy(slow, 1.0, 63.0) == pytest.approx(
            cam_mod.search_energy(fast, 1.0, 63.0))
    finally:
        registry.CAM_VARIANTS.unregister("slow_cam")


def test_no_string_scheme_dispatch_in_hot_paths():
    """Acceptance guard: fabric/router/pipeline contain no scheme string-ifs."""
    import inspect
    import re

    from repro.interface import pipeline as pipeline_mod
    from repro.noc import router as noc_router

    pattern = re.compile(
        r"if\s+[^\n]*scheme\s*(==|!=|\bin\b)[^\n]*"
        r"(\"|')(broadcast|unicast|multicast_tree|hier_tree|binary_tree)")
    for mod in (fabric, noc_router, pipeline_mod):
        src = inspect.getsource(mod)
        assert not pattern.search(src), f"string scheme dispatch in {mod.__name__}"


# ---- ppa report -------------------------------------------------------------


def test_ppa_report_unifies_area_latency_energy():
    cfg = _cfg()
    rep = ppa_report(cfg)
    assert rep["config"]["arbiter"] == "hier_tree"
    assert rep["arbiter"]["sparse_latency_units"] == pytest.approx(4.0)  # log2(16)
    assert rep["cam"]["cycle_time_ns"] > 0
    assert rep["cam"]["area_um2"] != rep["cam"]["area_um2_conventional"]
    assert rep["noc"]["links"] == topology.num_links(cfg.cores)
    # the legacy per-core area keys survive inside the unified report
    legacy = fabric.interface_area_um2(cfg)
    assert rep["arbiter"]["area_units"] == legacy["arbiter_units"]
    assert rep["cam"]["area_um2"] == legacy["cam_um2"]
