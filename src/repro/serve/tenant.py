"""Tenant specifications for the fabric serving tier.

A *tenant* is one independent user of the interface fabric: it owns an
`InterfaceConfig`, a `repro.traffic` scenario (its tick-stream workload),
and a seed.  Tenants do not own a compiled session - the engine packs
*compatible* tenants (same fabric configuration and connectivity, see
`compat_key`) onto one precompiled `InterfaceSession` and steps them as
lanes of a single masked `run_batched` call, the software analogue of the
DYNAPs fabric multiplexing many cores over one shared interface.

The spec is deliberately declarative (name + config + scenario + seeds):
everything heavy - connectivity, tables, jit - lives with the group, so
registering a tenant on an existing group is cheap.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax

import repro.core  # noqa: F401  (initialize core first: breaks the config<->core cycle)
from repro import traffic
from repro.ft.faults import FaultModel
from repro.interface.config import InterfaceConfig, as_interface_config
from repro.interface.session import CompositionError


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant: a fabric config plus the traffic it will stream.

    name:               unique tenant id (the metrics/report label).
    config:             `InterfaceConfig` (legacy `FabricConfig` accepted
                        and lifted at construction).
    scenario:           registered `repro.traffic` scenario driving this
                        tenant's tick stream.
    scenario_params:    overrides merged into the scenario's defaults.
    seed:               tenant-private PRNG seed for the tick stream.
    connectivity_seed:  seed of the shared fabric connectivity; part of
                        the compatibility key - tenants only share a
                        session when they share (config, connectivity).
    fault:              optional `repro.ft.faults.FaultModel` compiled
                        into this tenant's session (fault-injection
                        studies).  Part of the compatibility key, so
                        faulted tenants never share a session with clean
                        ones - which is what keeps non-faulted tenants
                        bit-identical to a fault-free run.
    shard:              optional execution placement: ``"chips"`` steps
                        this tenant's group through the per-chip mapped
                        tick (shard_map over the `launch.mesh` device
                        mesh, or the single-device vmap fallback), so
                        the group's lanes spread over devices.  Requires
                        ``config.chips > 1`` - requesting it on a
                        one-chip config raises the typed
                        `CompositionError` instead of silently running
                        flat.  Part of the compatibility key.
    """

    name: str
    config: InterfaceConfig
    scenario: str = "sparse_poisson"
    scenario_params: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    seed: int = 0
    connectivity_seed: int = 0
    fault: FaultModel | None = None
    shard: str | None = None

    def __post_init__(self):
        if not self.name:
            raise ValueError("tenant name must be non-empty")
        object.__setattr__(self, "config", as_interface_config(self.config))
        if self.shard is not None:
            if self.shard != "chips":
                raise ValueError(
                    f"tenant {self.name!r}: unknown shard mode {self.shard!r}; "
                    f"expected None or 'chips'"
                )
            if self.config.chips == 1:
                raise CompositionError(
                    f"tenant {self.name!r}: shard='chips' on a one-chip config would "
                    f"silently run the flat path; use a config with chips > 1 or omit "
                    f"shard"
                )
        if self.fault is not None:
            if not isinstance(self.fault, FaultModel):
                raise ValueError(
                    f"tenant {self.name!r}: fault must be a FaultModel, "
                    f"got {type(self.fault).__name__}"
                )
            self.fault.validate(self.config)
        # fail at registration, not first flush, on unknown scenarios/params
        spec = traffic.get_scenario(self.scenario)
        unknown = sorted(set(self.scenario_params) - set(spec.defaults))
        if unknown:
            raise ValueError(
                f"tenant {self.name!r}: unknown scenario parameter(s) "
                f"{', '.join(unknown)} for {self.scenario!r}; valid: "
                f"{', '.join(sorted(spec.defaults))}"
            )

    def stream(self, ticks: int, round: int = 0):
        """(ticks, cores, neurons_per_core) bool tick stream for one round.

        Successive ``round`` values fold into the tenant seed, so a tenant
        streaming in chunks draws fresh (but deterministic) traffic each
        round instead of replaying the same frames.
        """
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), round)
        return traffic.generate(
            self.scenario, key, ticks, self.config, **dict(self.scenario_params)
        )

    def expected_rate(self) -> float:
        """Analytic mean spike probability of this tenant's stream."""
        return traffic.expected_rate(
            self.scenario,
            self.config.cores,
            self.config.neurons_per_core,
            **dict(self.scenario_params),
        )


def compat_key(spec: TenantSpec) -> tuple:
    """Hashable session-compatibility key.

    Tenants mapping to the same key are guaranteed steppable as lanes of
    one `InterfaceSession.run_batched` call: the session binds (config,
    connectivity) - and, when set, the compiled-in `FaultModel` - so all
    three are pinned here, plus the ``shard`` placement (a sharded and a
    flat group execute different mapped programs and must not share
    lanes).  Scenario/seed stay out - a group legitimately mixes
    workloads.
    """
    return (spec.config, spec.connectivity_seed, spec.fault, spec.shard)


def default_connectivity(config: InterfaceConfig, connectivity_seed: int):
    """The deterministic shared connectivity a group compiles against."""
    from repro.interface.types import random_connectivity

    return random_connectivity(jax.random.PRNGKey(connectivity_seed), config)
