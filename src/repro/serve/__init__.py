"""`repro.serve`: multi-tenant streaming over the interface fabric.

The serving tier the ROADMAP names: tenants (`TenantSpec`) each bring an
`InterfaceConfig` and a `repro.traffic` tick stream; the `ServeEngine`
packs compatible tenants onto shared precompiled `InterfaceSession`s and
steps each group under a single jit (masked `run_batched` over the lane
axis), with micro-batched ingest (`IngestQueue`), capacity limits
(`AdmissionPolicy`), and per-tenant `repro.obs` metrics.

The prefill/decode LM reference loop lives in `repro.serve.lm_engine`.
"""

from repro.serve.admission import AdmissionController, AdmissionError, AdmissionPolicy
from repro.serve.engine import ServeEngine, TenantGroup, group_key
from repro.serve.queue import IngestQueue, TickRequest
from repro.serve.tenant import TenantSpec, compat_key, default_connectivity

__all__ = [
    "AdmissionController",
    "AdmissionError",
    "AdmissionPolicy",
    "IngestQueue",
    "ServeEngine",
    "TenantGroup",
    "TenantSpec",
    "TickRequest",
    "compat_key",
    "default_connectivity",
    "group_key",
]
