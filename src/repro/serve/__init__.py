"""`repro.serve`: multi-tenant streaming over the interface fabric.

The serving tier the ROADMAP names: tenants (`TenantSpec`) each bring an
`InterfaceConfig` and a `repro.traffic` tick stream; the `ServeEngine`
packs compatible tenants onto shared precompiled `InterfaceSession`s and
steps each group under a single jit (masked `run_batched` over the lane
axis), with micro-batched ingest (`IngestQueue`), capacity limits and
typed rejection errors (`AdmissionPolicy`), per-tenant `repro.obs`
metrics, and - since PR 8 - graceful degradation: bounded retries
(`RetryPolicy`), a per-lane health state machine (`HealthPolicy` /
`HealthTracker`), deadline shedding, and `repro.ft` fault injection at
both the fabric (`TenantSpec.fault`) and host (`ServeEngine(chaos=...)`)
layers.

The prefill/decode LM reference loop lives in `repro.serve.lm_engine`.
"""

from repro.serve.admission import (
    AdmissionController,
    AdmissionError,
    AdmissionPolicy,
    DeadlineExceededError,
    FrameValidationError,
    QueueOverflowError,
    ServeError,
    validate_frames,
)
from repro.serve.engine import ServeEngine, TenantGroup, group_key
from repro.serve.health import HealthPolicy, HealthTracker, LaneState, RetryPolicy
from repro.serve.queue import IngestQueue, TickRequest
from repro.serve.tenant import TenantSpec, compat_key, default_connectivity

__all__ = [
    "AdmissionController",
    "AdmissionError",
    "AdmissionPolicy",
    "DeadlineExceededError",
    "FrameValidationError",
    "HealthPolicy",
    "HealthTracker",
    "IngestQueue",
    "LaneState",
    "QueueOverflowError",
    "RetryPolicy",
    "ServeEngine",
    "ServeError",
    "TenantGroup",
    "TenantSpec",
    "TickRequest",
    "compat_key",
    "default_connectivity",
    "group_key",
    "validate_frames",
]
