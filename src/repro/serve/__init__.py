"""`repro.serve`: multi-tenant streaming over the interface fabric.

The serving tier the ROADMAP names: tenants (`TenantSpec`) each bring an
`InterfaceConfig` and a `repro.traffic` tick stream; the `ServeEngine`
packs compatible tenants onto shared precompiled `InterfaceSession`s and
steps each group under a single jit (masked `run_batched` over the lane
axis), with micro-batched ingest (`IngestQueue`), capacity limits and
typed rejection errors (`AdmissionPolicy`), per-tenant `repro.obs`
metrics, and - since PR 8 - graceful degradation: bounded retries
(`RetryPolicy`), a per-lane health state machine (`HealthPolicy` /
`HealthTracker`), deadline shedding, and `repro.ft` fault injection at
both the fabric (`TenantSpec.fault`) and host (`ServeEngine(chaos=...)`)
layers.

Serving tier v2 adds the concurrency/scale axes: a background pump
(`ServeEngine.start`/`stop`), cross-device tenant groups
(``TenantSpec(shard="chips")``, rejected compositions raising the typed
`CompositionError`), autoscaling lane capacities (`AutoscalePolicy`),
and per-tenant token-bucket rate limiting
(``AdmissionPolicy.rate_limit_per_s`` / `RateLimitedError`).

The prefill/decode LM reference loop lives in `repro.serve.lm_engine`.
"""

from repro.interface.session import CompositionError
from repro.serve.admission import (
    AdmissionController,
    AdmissionError,
    AdmissionPolicy,
    DeadlineExceededError,
    FrameValidationError,
    QueueOverflowError,
    RateLimitedError,
    ServeError,
    TokenBucket,
    validate_frames,
)
from repro.serve.engine import AutoscalePolicy, ServeEngine, TenantGroup, group_key
from repro.serve.health import HealthPolicy, HealthTracker, LaneState, RetryPolicy
from repro.serve.queue import IngestQueue, TickRequest
from repro.serve.tenant import TenantSpec, compat_key, default_connectivity

__all__ = [
    "AdmissionController",
    "AdmissionError",
    "AdmissionPolicy",
    "AutoscalePolicy",
    "CompositionError",
    "DeadlineExceededError",
    "FrameValidationError",
    "HealthPolicy",
    "HealthTracker",
    "IngestQueue",
    "LaneState",
    "QueueOverflowError",
    "RateLimitedError",
    "RetryPolicy",
    "ServeEngine",
    "ServeError",
    "TenantGroup",
    "TenantSpec",
    "TickRequest",
    "TokenBucket",
    "compat_key",
    "default_connectivity",
    "group_key",
    "validate_frames",
]
