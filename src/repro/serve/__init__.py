"""serve subsystem."""
