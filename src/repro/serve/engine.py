"""`repro.serve.engine`: multi-tenant streaming over the interface fabric.

The ROADMAP's serving tier: many independent tenants - each an
`InterfaceConfig` plus a `repro.traffic` tick stream (`TenantSpec`) -
served concurrently through precompiled `InterfaceSession`s instead of
one offline ``session.run`` at a time.  The moving parts:

  admission   `AdmissionController` bounds groups/lanes/request size and
              assigns each tenant a session-compatibility key; frames are
              validated (shape/dtype/finite) before any device work.
  grouping    tenants sharing (config, connectivity, fault) become
              *lanes* of a `TenantGroup`, which owns one precompiled
              session; the whole group steps under a single jit via the
              masked ``run_batched`` (vmap over the lane axis).
  queueing    per-group `IngestQueue` with size-/deadline-triggered
              micro-batching (`repro.serve.queue`).
  batching    flushed requests pack into fixed-shape (lanes, flush_ticks)
              chunks - ragged/short streams right-padded with an explicit
              mask, so every lane stays *bit-identical* to its solo
              ``session.run`` (currents and stats; the per-lane
              accumulator is threaded through chunks as the scan carry).
  transfer    double-buffered `jax.device_put`: chunk t+1's host->device
              copy is issued while chunk t computes (with buffer donation
              on accelerators, skipped on CPU).
  metrics     per-tenant `repro.obs.metrics` histograms/counters
              (events/sec, tick-latency p50/p99, queue depth), fleet-wide
              percentiles via `Histogram.merge`, JSONL sink + records
              shaped for ``python -m repro.obs.report``.

Graceful degradation (PR 8): the engine survives a hostile environment
instead of assuming the happy path -

  faults      an optional `repro.ft.chaos.ChaosInjector` fires a seeded
              `FaultPlan` at configured pump rounds; tenants may also
              compile a fabric-level `repro.ft.faults.FaultModel` into
              their session (via ``TenantSpec.fault``).
  retries     transient transfer/execute faults retry under a bounded
              exponential-backoff `RetryPolicy`; the per-lane accumulator
              commits only after a successful step, so a replayed chunk
              can never double-count, and `RetriesExhaustedError`
              restages unserved work back onto the backlog first - the
              accounting identity submitted == served + shed + pending
              holds through every failure.
  health      a per-lane `HealthTracker` walks healthy -> degraded ->
              quarantined; quarantined lanes are masked out of the shared
              batched step *without recompiling* (mask rows, not shapes)
              and probe back in after a cooldown.
  shedding    queued requests older than ``AdmissionPolicy.shed_deadline_s``
              are dropped at flush time as typed `DeadlineExceededError`s
              (`shed_errors()`), and `QueueOverflowError` bounds pending
              work at submit time.
  watchdog    the `repro.ft.runner.Watchdog` observes per-flush wall time
              on the engine registry (``serve.flush_ms`` /
              ``serve.stragglers``), one telemetry substrate with
              training.

Minimal use:

    from repro.serve import ServeEngine, TenantSpec

    engine = ServeEngine(flush_ticks=16)
    engine.register(TenantSpec("t0", cfg, scenario="sparse_poisson"))
    engine.register(TenantSpec("t1", cfg, scenario="hotspot_core"))
    engine.submit_scenario("t0", ticks=64)   # or engine.submit(name, frames)
    engine.submit_scenario("t1", ticks=48)
    engine.drain()
    records = engine.serve_report()

Serving tier v2 adds the concurrency/scale axes:

  async pump  `start()`/`stop()` run the pump on background thread(s),
              draining the thread-safe `IngestQueue` off the caller's
              thread.  Shutdown is clean (signal + join), fatal pump
              errors surface on the next `submit`/`stop`, and the
              accounting identity holds at every observable
              interleaving: `accounting()` serializes against the pump,
              so no reader ever sees ticks mid-flight between backlog
              and served.
  sharding    tenants with ``TenantSpec(shard="chips")`` land in groups
              whose masked batched step runs the per-chip mapped tick
              (`InterfaceSession` composes mask with ``shard="chips"``),
              spreading one group over the `launch.mesh` devices -
              bit-identical to solo runs on the vmap fallback.
  autoscale   groups own a *capacity* (the padded lane axis) grown and
              shrunk by `AutoscalePolicy`; resizes preserve every
              occupied lane's `StepStats` accumulator row exactly
              (recompiles are accumulator-preserving) and the jit cache
              stays bounded by the set of capacities seen.
              `deregister` frees a lane with swap-with-last compaction.
  rate limit  `AdmissionPolicy.rate_limit_per_s` token buckets bound
              each tenant's ingress; rejected submits raise the typed
              `RateLimitedError` before anything is queued and count in
              ``serve.rate_limited`` / ``serve.rate_limited_ticks``.

The prefill/decode LM engine that previously lived in this module moved
to `repro.serve.lm_engine`.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import threading
import time
from typing import Callable

import jax
import numpy as np

from repro.ft.chaos import RetriesExhaustedError, TransientFaultError
from repro.ft.runner import Watchdog
from repro.interface import Interface
from repro.interface.stats import StepStats
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.admission import (
    AdmissionController,
    AdmissionPolicy,
    DeadlineExceededError,
    RateLimitedError,
    ServeError,
    validate_frames,
)
from repro.serve.health import HealthPolicy, HealthTracker, RetryPolicy
from repro.serve.queue import IngestQueue
from repro.serve.tenant import TenantSpec, default_connectivity
from repro.serve.tenant import compat_key as _compat_key


@dataclasses.dataclass
class _Chunk:
    """One fixed-shape batched step: left-aligned frames plus lane mask."""

    spikes: np.ndarray  # (capacity, flush_ticks, cores, neurons_per_core) bool
    mask: np.ndarray  # (capacity, flush_ticks) bool
    took: np.ndarray  # (capacity,) int: live ticks packed into each lane


@dataclasses.dataclass
class _Staged:
    """Backlogged frames plus the submit timestamp their deadline ages from."""

    frames: np.ndarray  # (T_i, cores, neurons_per_core) bool
    enqueued_at: float


@dataclasses.dataclass(frozen=True)
class AutoscalePolicy:
    """How a group's lane *capacity* tracks its tenant occupancy.

    Capacity is the padded lane axis of the batched step: chunks are
    shaped ``(capacity, flush_ticks, ...)`` with free lanes all-masked,
    so each distinct capacity is one jit cache entry.

    min_lanes:    capacity floor (headroom for tenants yet to arrive).
    grow_factor:  1.0 (default) is exact fit - capacity ==
                  max(occupancy, min_lanes), one recompile per resize,
                  zero padded compute.  > 1.0 grows geometrically
                  (amortized recompiles under churn, padded lanes as the
                  cost) and shrinks by the same factor.
    shrink_at:    utilization at or below which a grown capacity steps
                  back down (hysteresis; only meaningful with
                  ``grow_factor > 1``).
    """

    min_lanes: int = 1
    grow_factor: float = 1.0
    shrink_at: float = 0.5

    def __post_init__(self):
        if self.min_lanes < 1:
            raise ValueError(f"min_lanes must be >= 1, got {self.min_lanes}")
        if self.grow_factor < 1.0:
            raise ValueError(f"grow_factor must be >= 1, got {self.grow_factor}")
        if not 0.0 < self.shrink_at <= 1.0:
            raise ValueError(f"shrink_at must be in (0, 1], got {self.shrink_at}")

    def target(self, occupancy: int, capacity: int) -> int:
        """The capacity this policy wants for ``occupancy`` tenants."""
        floor = max(self.min_lanes, occupancy, 1)
        if self.grow_factor <= 1.0:
            return floor
        cap = max(capacity, 1)
        while cap < occupancy:
            cap = max(cap + 1, math.ceil(cap * self.grow_factor))
        while cap > floor:
            if occupancy > cap * self.shrink_at:
                break
            cap = max(floor, math.ceil(cap / self.grow_factor))
        return cap


class TenantGroup:
    """Tenants sharing one precompiled session, stepped as vmap lanes.

    Lanes are *dense*: occupied lane indices are always ``0..len(lanes)-1``
    (`remove` compacts with swap-with-last), and ``capacity >= len(lanes)``
    is the padded batch axis the chunks and the per-lane accumulator are
    shaped to.  Resizes preserve occupied accumulator rows exactly.
    """

    def __init__(self, key, config, params, queue: IngestQueue, fault=None,
                 shard=None, autoscale: AutoscalePolicy | None = None):
        """Compile the shared session for ``key`` = (config, connectivity,
        fault, shard) and start with zero lanes; tenants join via `add`."""
        self.key = key
        self.config = config
        self.params = params
        self.queue = queue
        self.fault = fault
        self.shard = shard
        self.autoscale = autoscale or AutoscalePolicy()
        with obs_trace.span("serve.group_compile", cores=config.cores):
            self.session = Interface(config).compile(params, fault=fault)
        self.specs: dict = {}  # name -> TenantSpec
        self.lanes: dict = {}  # name -> lane index (dense, < capacity)
        self._backlog: dict = {}  # name -> deque of _Staged entries
        self._acc = None  # per-lane StepStats carry ((capacity,) leaves)
        self.capacity = 0  # padded lane axis of chunks + accumulator
        self.capacities_seen: set = set()  # one jit cache entry each
        # per-lane global tick offset of the compiled fault's drop stream
        self._lane_ticks = np.zeros((0,), np.int32)

    def add(self, spec: TenantSpec) -> int:
        """Assign ``spec`` the lowest free lane index and return it.

        Occupancy beyond the current capacity triggers an autoscale grow
        (the accumulator pads with zero rows - running totals of every
        existing lane are preserved); reusing a previously freed slot
        restarts that slot's carry at zero.
        """
        lane = len(self.lanes)
        self.specs[spec.name] = spec
        self.lanes[spec.name] = lane
        self._backlog[spec.name] = collections.deque()
        if lane >= self.capacity:
            self.resize(self.autoscale.target(lane + 1, self.capacity))
        else:
            # reusing a freed slot: its carry restarts from zero
            self._lane_ticks[lane] = 0
            if self._acc is not None:
                def zero_row(x):
                    x = np.asarray(x).copy()
                    x[lane] = 0
                    return x
                self._acc = self._commit(jax.tree.map(zero_row, self._acc))
        return lane

    def remove(self, name: str) -> None:
        """Free a lane with swap-with-last compaction, then maybe shrink.

        The tenant occupying the highest lane moves into the freed slot -
        its accumulator row and fault-tick offset move with it, so every
        surviving tenant's running stats stay bit-identical across the
        removal.  Lanes stay dense, which is what lets a shrink truncate
        only free trailing rows.
        """
        lane = self.lanes.pop(name)
        self.specs.pop(name)
        self._backlog.pop(name)
        last = len(self.lanes)  # index the ex-last tenant held before the pop
        if lane != last:
            mover = next(n for n, i in self.lanes.items() if i == last)
            self.lanes[mover] = lane
            self._lane_ticks[lane] = self._lane_ticks[last]
            if self._acc is not None:
                def move_row(x):
                    x = np.asarray(x).copy()
                    x[lane] = x[last]
                    return x
                self._acc = self._commit(jax.tree.map(move_row, self._acc))
        self._lane_ticks[last] = 0
        self.resize(self.autoscale.target(len(self.lanes), self.capacity))

    def resize(self, new_capacity: int) -> None:
        """Re-pad the lane axis to ``new_capacity``, preserving rows.

        Occupied rows (always the leading ones - lanes are dense) carry
        over exactly; growth pads zero rows, shrink truncates free
        trailing rows.  A no-op at the current capacity, so the jit
        cache grows only with the set of distinct capacities seen.
        """
        if new_capacity == self.capacity:
            return
        if new_capacity < len(self.lanes):
            raise ValueError(
                f"cannot resize to {new_capacity} lanes below occupancy {len(self.lanes)}"
            )
        keep = min(self.capacity, new_capacity)
        lane_ticks = np.zeros((new_capacity,), np.int32)
        lane_ticks[:keep] = self._lane_ticks[:keep]
        self._lane_ticks = lane_ticks
        if self._acc is not None:
            def fit_rows(x):
                x = np.asarray(x)
                out = np.zeros((new_capacity,), x.dtype)
                out[:keep] = x[:keep]
                return out
            self._acc = self._commit(jax.tree.map(fit_rows, self._acc))
        self.capacity = new_capacity
        self.capacities_seen.add(new_capacity)

    def jit_cache_entries(self) -> int:
        """Compiled entries of this group's masked batched step."""
        session = self.session
        fns = (session._masked_sharded_cache if self.shard is not None
               else session._masked_cache)
        if not fns:
            return 0
        return fns["run_batched"]._cache_size()

    @staticmethod
    def _commit(tree):
        """Place host-built accumulators on the device, committed.

        Uncommitted numpy inputs and committed jit outputs hash to
        different fast-path cache entries; committing here keeps the
        masked batched step on ONE cache entry for the engine's lifetime
        (the stability the soak test asserts).
        """
        dev = jax.devices()[0]
        return jax.tree.map(lambda x: jax.device_put(np.asarray(x), dev), tree)

    def lane_names(self) -> list:
        """Tenant names in lane order (index 0 first)."""
        return sorted(self.lanes, key=self.lanes.get)

    def lane_stats(self):
        """Per-lane cumulative `StepStats` carry ((capacity,) leaves)."""
        if self._acc is None:
            b = self.capacity
            self._acc = self._commit(
                jax.tree.map(lambda x: np.zeros((b,), x.dtype), StepStats.zeros())
            )
        return self._acc

    def fault_tick0(self) -> np.ndarray:
        """(capacity,) global tick offsets for the compiled fault stream."""
        return self._lane_ticks

    def advance_fault_ticks(self, flush_ticks: int) -> None:
        """One chunk executed: every lane's fault window moved forward."""
        self._lane_ticks = self._lane_ticks + np.int32(flush_ticks)

    def stage(self, requests) -> None:
        """Append flushed requests to the per-lane host backlog.

        Each entry keeps its request's submit timestamp, so backlogged
        frames stay age-checkable against the shed deadline (a slow pump
        must not let staged work escape its deadline).
        """
        cfg = self.config
        for req in requests:
            frames = np.asarray(req.frames)
            if frames.shape[1:] != (cfg.cores, cfg.neurons_per_core):
                raise ValueError(
                    f"tenant {req.tenant!r} frames shaped {frames.shape[1:]} do not match the "
                    f"group fabric ({cfg.cores}, {cfg.neurons_per_core})"
                )
            self._backlog[req.tenant].append(
                _Staged(frames.astype(bool), enqueued_at=req.enqueued_at)
            )

    def backlog_ticks(self) -> int:
        """Staged-but-unserved ticks across every lane of this group."""
        return sum(s.frames.shape[0] for q in self._backlog.values() for s in q)

    def backlog_ticks_of(self, name: str) -> int:
        """Staged-but-unserved ticks for one tenant."""
        return sum(s.frames.shape[0] for s in self._backlog[name])

    def take_chunk(self, flush_ticks: int, skip=frozenset()) -> _Chunk | None:
        """Pack up to ``flush_ticks`` backlog ticks per lane, left-aligned.

        Shapes are fixed at (capacity, flush_ticks, ...) regardless of
        how much backlog exists, so the jitted batched step compiles once
        per capacity - partial chunks ride the mask, not a new shape, and
        free lanes stay all-False padding.

        skip: lane names (quarantined tenants) left out of this chunk -
        their backlog is retained untouched and their mask row stays
        all-False, so degradation never changes shapes or the jit cache.
        """
        b = self.capacity
        cfg = self.config
        took = np.zeros((b,), np.int64)
        spikes = np.zeros((b, flush_ticks, cfg.cores, cfg.neurons_per_core), bool)
        mask = np.zeros((b, flush_ticks), bool)
        for name, lane in self.lanes.items():
            if name in skip:
                continue
            queue = self._backlog[name]
            t = 0
            while queue and t < flush_ticks:
                staged = queue.popleft()
                frames = staged.frames
                take = min(frames.shape[0], flush_ticks - t)
                spikes[lane, t : t + take] = frames[:take]
                t += take
                if take < frames.shape[0]:
                    queue.appendleft(
                        _Staged(frames[take:], enqueued_at=staged.enqueued_at)
                    )
            mask[lane, :t] = True
            took[lane] = t
        if not took.any():
            return None
        return _Chunk(spikes=spikes, mask=mask, took=took)


class ServeEngine:
    """Multi-tenant streaming engine over precompiled interface sessions.

    flush_ticks:       time extent of one batched step; also the ingest
                       queue's size trigger (in tick frames).  Fixed, so
                       chunk shapes - and the jit cache - stay stable.
    flush_deadline_s:  max age of the oldest queued request before a
                       partial batch flushes anyway (0 = always ready).
    policy:            `AdmissionPolicy` capacity limits (now including
                       ``max_pending_frames`` backpressure and the
                       ``shed_deadline_s`` shed bound).
    registry:          `MetricsRegistry` receiving per-tenant counters and
                       histograms (a private one by default).
    sink:              optional `JsonlSink`; `emit_report()` appends one
                       record per tenant plus the fleet record.
    keep_currents:     retain every served tick's currents per tenant
                       (tests/benchmarks; unbounded memory under real
                       sustained load, so off by default).
    clock:             injectable monotonic clock (deadline tests).
    chaos:             optional `repro.ft.chaos.ChaosInjector` firing a
                       seeded `FaultPlan` at this engine's pump rounds.
    retry:             `RetryPolicy` for transient transfer/execute
                       faults (bounded exponential backoff).
    health:            `HealthPolicy` thresholds of the per-lane state
                       machine (quarantine/probe/recover).
    watchdog:          optional `repro.ft.runner.Watchdog`; by default
                       one is created on this engine's registry with the
                       ``serve`` prefix (flush wall-time histogram +
                       straggler counter).
    sleep:             injectable backoff sleep (fake-clock tests).
    autoscale:         `AutoscalePolicy` governing every group's lane
                       capacity (exact fit by default).

    Threading (v2): the engine is safe to drive from producer threads
    concurrent with a background pump.  Two locks, always taken in this
    order:

      _pump_mutex   serializes whole pump iterations (and accounting /
                    register / deregister against them), so the ledger
                    is never observed with a chunk's ticks in flight.
      _state_lock   guards the ledger dicts, queue polls, and backlog
                    mutation; `submit` takes only this one, so producers
                    never block behind a full pump iteration.
    """

    def __init__(
        self,
        *,
        flush_ticks: int = 16,
        flush_deadline_s: float = 0.005,
        policy: AdmissionPolicy | None = None,
        registry: obs_metrics.MetricsRegistry | None = None,
        sink: obs_metrics.JsonlSink | None = None,
        keep_currents: bool = False,
        clock: Callable[[], float] = time.monotonic,
        chaos=None,
        retry: RetryPolicy | None = None,
        health: HealthPolicy | None = None,
        watchdog: Watchdog | None = None,
        sleep: Callable[[float], None] = time.sleep,
        autoscale: AutoscalePolicy | None = None,
    ):
        if flush_ticks < 1:
            raise ValueError(f"flush_ticks must be >= 1, got {flush_ticks}")
        self.flush_ticks = flush_ticks
        self.flush_deadline_s = flush_deadline_s
        self.admission = AdmissionController(policy, clock=clock)
        self.registry = registry or obs_metrics.MetricsRegistry()
        self.sink = sink
        self.keep_currents = keep_currents
        self.clock = clock
        self.chaos = chaos
        self.retry = retry or RetryPolicy()
        self.health = HealthTracker(health, registry=self.registry, clock=clock)
        self.watchdog = watchdog or Watchdog(registry=self.registry, prefix="serve")
        self._sleep = sleep
        self.autoscale = autoscale or AutoscalePolicy()
        self.groups: dict = {}  # compat key -> TenantGroup
        self._tenant_group: dict = {}  # tenant name -> TenantGroup
        self._rounds: dict = {}  # tenant name -> scenario round counter
        self._served: dict = {}  # tenant name -> ticks served
        self._submitted: dict = {}  # tenant name -> ticks submitted
        self._shed: dict = {}  # tenant name -> ticks shed past deadline
        self._events_seen: dict = {}  # tenant name -> cumulative events read
        self._currents: dict = {}  # tenant name -> list of (t_i, C, N) arrays
        self._retired: set = set()  # deregistered tenants (ledger retained)
        self._shed_log: collections.deque = collections.deque(maxlen=256)
        self._round = 0  # pump round counter (the chaos plan's time axis)
        self._faulted_this_round: set = set()  # lanes faulted in this pump
        self._busy_s = 0.0
        self._ticks = 0
        self._events = 0.0
        # -- threading (see class docstring for the lock order) --
        self._pump_mutex = threading.RLock()
        self._state_lock = threading.RLock()
        self._pump_threads: list = []
        self._stop_event = threading.Event()
        self._pump_fatal: BaseException | None = None
        self._pump_error_log: collections.deque = collections.deque(maxlen=64)

    # ---- registration / ingest -------------------------------------------

    def register(self, spec: TenantSpec, params=None) -> TenantSpec:
        """Admit a tenant; compile its group's session on first use.

        params: optional explicit fabric connectivity for a *new* group
        (defaults to `default_connectivity(spec.config,
        spec.connectivity_seed)`).  Ignored for an existing group - the
        compatibility key pins connectivity to the seed, so passing a
        conflicting params object for an occupied key is an error.
        """
        with self._pump_mutex, self._state_lock:
            if spec.name in self._tenant_group:
                raise ValueError(f"tenant {spec.name!r} is already registered")
            occupancy = {k: len(g.lanes) for k, g in self.groups.items()}
            key = self.admission.admit(spec, occupancy)
            group = self.groups.get(key)
            if group is None:
                if params is None:
                    params = default_connectivity(spec.config, spec.connectivity_seed)
                queue = IngestQueue(
                    flush_frames=self.flush_ticks,
                    flush_deadline_s=self.flush_deadline_s,
                    clock=self.clock,
                    frame_shape=(spec.config.cores, spec.config.neurons_per_core),
                )
                group = TenantGroup(
                    key, spec.config, params, queue,
                    fault=spec.fault, shard=spec.shard, autoscale=self.autoscale,
                )
                self.groups[key] = group
            elif params is not None:
                raise ValueError(
                    f"tenant {spec.name!r}: explicit params conflict with the already-compiled "
                    f"group for this (config, connectivity_seed); omit params to join it"
                )
            before = group.capacity
            group.add(spec)
            self._note_resize(before, group.capacity)
            self._tenant_group[spec.name] = group
            self._retired.discard(spec.name)
            self._rounds[spec.name] = 0
            self._served[spec.name] = 0
            self._submitted[spec.name] = 0
            self._shed[spec.name] = 0
            self._events_seen[spec.name] = 0.0
            self._currents[spec.name] = []
            self.health.add(spec.name)
            return spec

    def deregister(self, tenant: str) -> None:
        """Retire a tenant, freeing its lane (autoscale may shrink).

        Requires the tenant to be fully drained - deregistering with
        pending work raises `ServeError` (serve or shed it first, the
        ledger must close).  The tenant's submitted/served/shed columns
        are retained so `accounting()` keeps closing fleet-wide; its
        group is torn down when the last lane leaves.
        """
        with self._pump_mutex, self._state_lock:
            group = self._group_of(tenant)
            pending = group.queue.pending_by_tenant().get(tenant, 0)
            pending += group.backlog_ticks_of(tenant)
            if pending:
                raise ServeError(
                    f"tenant {tenant!r} still has {pending} pending ticks; "
                    f"drain or shed before deregistering"
                )
            before = group.capacity
            group.remove(tenant)
            self._note_resize(before, group.capacity)
            del self._tenant_group[tenant]
            self._retired.add(tenant)
            self.health.remove(tenant)
            if not group.lanes:
                del self.groups[group.key]

    def _note_resize(self, before: int, after: int) -> None:
        """Count a group capacity change on the autoscale counters."""
        if after > before:
            self.registry.counter("serve.autoscale.grow").inc()
        elif after < before:
            self.registry.counter("serve.autoscale.shrink").inc()

    def submit(self, tenant: str, frames) -> None:
        """Enqueue a spike stream for one tenant.

        Args:
          tenant: a name previously passed to `register` (KeyError with
            the registered names otherwise).
          frames: a (ticks, cores, neurons_per_core) bool spike stream;
            anything array-like is accepted and validated host-side.

        Nothing runs yet - frames sit in the tenant's micro-batch queue
        until the next `pump` / `drain` flushes them through the group's
        shared `InterfaceSession`.

        Raises:
          FrameValidationError: wrong shape/dtype or non-finite values
            (nothing malformed ever reaches the jitted step).
          AdmissionError: the request exceeds the tenant's per-request
            or in-flight tick budget.
          RateLimitedError: the tenant's token bucket is empty
            (``AdmissionPolicy.rate_limit_per_s``); nothing is queued.
          QueueOverflowError: the group's bounded queue is full.
          ServeError: a background pump thread died; the original
            exception is chained (`start`/`stop`).
        """
        self._raise_pump_fatal()
        group = self._group_of(tenant)
        cfg = group.config
        frames = validate_frames(
            frames, shape=(cfg.cores, cfg.neurons_per_core), tenant=tenant
        )
        ticks = int(frames.shape[0])
        with self._state_lock:
            self.admission.validate_request(
                tenant,
                ticks,
                pending_frames=group.queue.pending_frames() + group.backlog_ticks(),
            )
            try:
                self.admission.check_rate(tenant, ticks)
            except RateLimitedError:
                self.registry.counter("serve.rate_limited").inc()
                self.registry.counter("serve.rate_limited_ticks").inc(ticks)
                raise
            group.queue.submit(tenant, frames)
            self._submitted[tenant] += ticks

    def submit_scenario(self, tenant: str, ticks: int) -> None:
        """Generate and enqueue one round of the tenant's traffic scenario."""
        spec = self._group_of(tenant).specs[tenant]
        frames = np.asarray(spec.stream(ticks, round=self._rounds[tenant]))
        self._rounds[tenant] += 1
        self.submit(tenant, frames)

    def _group_of(self, tenant: str) -> TenantGroup:
        try:
            return self._tenant_group[tenant]
        except KeyError:
            raise KeyError(
                f"unknown tenant {tenant!r}; registered: "
                f"{', '.join(sorted(self._tenant_group)) or '(none)'}"
            ) from None

    # ---- serving loop -----------------------------------------------------

    def pump(self, force: bool = False) -> int:
        """One engine iteration: flush ready queues, step their groups.

        Returns the number of live ticks served.  ``force`` flushes
        regardless of the micro-batch triggers (drain semantics).

        Each pump is one *round* of the chaos clock: quarantine cooldowns
        age first, then this round's scheduled lane faults land, then
        expired requests are shed (from the queue *and* the staged
        backlog), and finally every group steps with its quarantined
        lanes masked out.

        Thread-safe: the whole iteration holds ``_pump_mutex``, so pumps
        (foreground or background) never interleave, and `accounting()`
        never observes a chunk's ticks in flight.
        """
        with self._pump_mutex:
            self._round += 1
            self.health.advance()
            self._faulted_this_round.clear()
            if self.chaos is not None:
                for ev in self.chaos.lane_faults(self._round):
                    self._lane_fault(ev)
            ticks_done = 0
            depth_hist = self.registry.histogram("serve.queue_depth")
            for group in list(self.groups.values()):
                with self._state_lock:
                    depth_hist.add(group.queue.depth())
                    group.stage(self._shed_expired(group.queue.poll(force=force)))
                    self._shed_backlog(group)
                    skip = {n for n in group.lanes if not self.health.usable(n)}
                    chunks = []
                    while True:
                        chunk = group.take_chunk(self.flush_ticks, skip=skip)
                        if chunk is None:
                            break
                        chunks.append(chunk)
                ticks_done += self._execute(group, chunks)
            return ticks_done

    def drain(self) -> int:
        """Serve until every queue and backlog is empty; returns ticks.

        Quarantined lanes hold their backlog, so a drain keeps pumping -
        aging cooldowns - until every lane has recovered and served; it
        terminates because quarantine is always finite.
        """
        total = 0
        while True:
            served = self.pump(force=True)
            total += served
            with self._state_lock:
                idle = not any(
                    g.queue.depth() or g.backlog_ticks() for g in self.groups.values()
                )
            if served == 0 and idle:
                return total

    # ---- background pump (v2) --------------------------------------------

    def start(self, poll_interval_s: float = 0.001, threads: int = 1) -> None:
        """Run the pump on background daemon thread(s).

        Producers keep calling `submit`/`submit_scenario` from any
        thread; the pump drains the queues concurrently.  With several
        threads, whole pump iterations still serialize on
        ``_pump_mutex`` - extra threads buy responsiveness when one
        thread is sleeping, not parallel device work.

        A `RetriesExhaustedError` inside a background pump is survivable
        by design (the failed work was restaged): it lands in
        `pump_errors()` and the loop continues.  Any other exception is
        fatal - the thread stops and the error re-raises (wrapped in
        `ServeError`) from the next `submit`/`stop`.
        """
        if poll_interval_s <= 0:
            raise ValueError(f"poll_interval_s must be > 0, got {poll_interval_s}")
        if threads < 1:
            raise ValueError(f"threads must be >= 1, got {threads}")
        if self._pump_threads:
            raise ServeError("pump threads already running; call stop() first")
        self._raise_pump_fatal()
        self._stop_event.clear()
        for i in range(threads):
            t = threading.Thread(
                target=self._pump_loop,
                args=(poll_interval_s,),
                name=f"serve-pump-{i}",
                daemon=True,
            )
            t.start()
            self._pump_threads.append(t)

    def stop(self, drain: bool = False) -> None:
        """Stop the background pump; join every thread; surface fatals.

        drain: serve everything still queued (on the caller's thread)
        after the pump threads exit.  Idempotent when nothing runs.
        """
        self._stop_event.set()
        for t in self._pump_threads:
            t.join()
        self._pump_threads.clear()
        if drain:
            self.drain()
        self._raise_pump_fatal()

    @property
    def running(self) -> bool:
        """True while background pump threads are live."""
        return any(t.is_alive() for t in self._pump_threads)

    def pump_errors(self) -> list:
        """Recent survivable background-pump errors (bounded, oldest first)."""
        return list(self._pump_error_log)

    def __enter__(self) -> "ServeEngine":
        self.start()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # don't mask an in-flight exception with a drain that may re-raise
        self.stop(drain=exc_type is None)

    def _pump_loop(self, poll_interval_s: float) -> None:
        """Body of one background pump thread."""
        while not self._stop_event.is_set():
            try:
                served = self.pump(force=True)
            except RetriesExhaustedError as e:
                # unserved work was restaged by _execute; record and go on
                self._pump_error_log.append(e)
                served = 0
            except BaseException as e:  # noqa: BLE001 - surfaced via _raise_pump_fatal
                self._pump_fatal = e
                self.registry.counter("serve.pump.fatal").inc()
                return
            if served == 0:
                self._stop_event.wait(poll_interval_s)

    def _raise_pump_fatal(self) -> None:
        """Re-raise a background pump thread's fatal error, chained."""
        fatal = self._pump_fatal
        if fatal is not None:
            self._pump_fatal = None
            raise ServeError(
                f"background pump thread died: {type(fatal).__name__}: {fatal}"
            ) from fatal

    def _shed_expired(self, requests) -> list:
        """Drop queued requests older than the policy's shed deadline.

        Each shed is recorded as a typed `DeadlineExceededError` (see
        `shed_errors`) and counted - shed ticks stay part of the
        accounting identity, they just move to the ``shed`` column.
        """
        limit = self.admission.policy.shed_deadline_s
        if limit is None or not requests:
            return requests
        now = self.clock()
        kept = []
        for req in requests:
            age = now - req.enqueued_at
            if age <= limit:
                kept.append(req)
                continue
            err = DeadlineExceededError(
                f"tenant {req.tenant!r}: request aged {age:.4f}s in queue "
                f"(shed_deadline_s={limit}); {req.ticks} tick frames shed"
            )
            self._shed_log.append(err)
            self._shed[req.tenant] = self._shed.get(req.tenant, 0) + req.ticks
            self.registry.counter("serve.shed").inc()
            self.registry.counter("serve.shed_ticks").inc(req.ticks)
        return kept

    def _shed_backlog(self, group: TenantGroup) -> None:
        """Shed staged backlog frames older than the policy deadline.

        `_shed_expired` only ages requests still in the ingest queue;
        this is the other half - frames already staged on the backlog
        (a slow pump, a quarantined lane) age against the same
        ``shed_deadline_s`` from their submit time, so the deadline
        means what it says regardless of where the work waits.
        """
        limit = self.admission.policy.shed_deadline_s
        if limit is None:
            return
        now = self.clock()
        for name, queue in group._backlog.items():
            if not queue:
                continue
            kept: collections.deque = collections.deque()
            shed_ticks = 0
            for staged in queue:
                age = now - staged.enqueued_at
                if age <= limit:
                    kept.append(staged)
                    continue
                ticks = int(staged.frames.shape[0])
                shed_ticks += ticks
                self._shed_log.append(DeadlineExceededError(
                    f"tenant {name!r}: staged frames aged {age:.4f}s in backlog "
                    f"(shed_deadline_s={limit}); {ticks} tick frames shed"
                ))
                self.registry.counter("serve.shed").inc()
                self.registry.counter("serve.shed_ticks").inc(ticks)
            if shed_ticks:
                self._shed[name] = self._shed.get(name, 0) + shed_ticks
                group._backlog[name] = kept

    def _lane_fault(self, ev) -> None:
        """One injected lane fault: advance the tenant's health machine."""
        if ev.tenant not in self._tenant_group:
            self.registry.counter("serve.faults.unknown_lane").inc()
            return
        self.registry.counter("serve.faults").inc()
        self._faulted_this_round.add(ev.tenant)
        self.health.record_failure(ev.tenant)

    def _with_retries(self, what: str, fn):
        """Run ``fn`` with bounded exponential backoff on transient faults.

        Only `TransientFaultError`s are retried; anything else (a real
        bug) propagates immediately.  After the budget is spent a
        `RetriesExhaustedError` chains the last fault.  A successful
        retry records the episode in ``serve.recovery_ms``, measured
        from when the *first attempt began* - the failed attempt's own
        wall time is part of the outage, not free.
        """
        policy = self.retry
        delay = policy.backoff_base_s
        t_start = self.clock()
        failed = False
        for attempt in range(policy.max_retries + 1):
            try:
                out = fn()
            except TransientFaultError as e:
                self.registry.counter("serve.faults").inc()
                self.registry.counter("serve.retries").inc()
                self.registry.counter(f"serve.retries.{what}").inc()
                failed = True
                if attempt >= policy.max_retries:
                    self.registry.counter("serve.retries_exhausted").inc()
                    raise RetriesExhaustedError(
                        f"{what} still failing after {policy.max_retries} "
                        f"retries (backoff from {policy.backoff_base_s}s)"
                    ) from e
                self._sleep(delay)
                delay *= policy.backoff_factor
                continue
            if failed:
                self.registry.counter("serve.retry_recoveries").inc()
                self.registry.histogram("serve.recovery_ms").add(
                    max(self.clock() - t_start, 0.0) * 1e3
                )
            return out
        raise AssertionError("unreachable")  # loop always returns or raises

    def _restage(self, group: TenantGroup, chunks: list) -> None:
        """Return unserved chunks to the front of the backlog, in order.

        Called before a `RetriesExhaustedError` propagates: the ticks a
        failed chunk carried go back to ``pending``, keeping
        submitted == served + shed + pending true even across hard
        failures (and letting a later pump serve them).  Restaged frames
        take a fresh submit timestamp - a chunk packs frames from many
        requests, so the original per-request ages are gone; the shed
        deadline restarts rather than guessing.
        """
        now = self.clock()
        with self._state_lock:
            for chunk in reversed(chunks):
                for name, lane in group.lanes.items():
                    took = int(chunk.took[lane])
                    if took:
                        group._backlog[name].appendleft(_Staged(
                            np.asarray(chunk.spikes[lane, :took]), enqueued_at=now
                        ))

    def _step(self, group: TenantGroup, spikes, mask):
        """One batched masked step (the unit a retry replays)."""
        if self.chaos is not None:
            self.chaos.on_execute(self._round)
        kw = {}
        if group.session.fault is not None and group.session.fault.perturbs_spikes:
            kw["fault_tick0"] = group.fault_tick0()
        return group.session.run_batched(
            spikes, mask=mask, stats0=group.lane_stats(), shard=group.shard, **kw
        )

    def _execute(self, group: TenantGroup, chunks: list) -> int:
        """Step one group through its chunks with double-buffered transfer.

        Chunk t+1's `jax.device_put` is issued after chunk t's batched
        step is dispatched but before its results are blocked on, so the
        host->device copy overlaps device compute; on accelerators the
        masked jit additionally donates the spike/accumulator buffers.

        Fault handling: every transfer and step runs under
        `_with_retries`; the group accumulator commits only *after* a
        successful step (a replayed chunk can never double-count), and on
        `RetriesExhaustedError` the unserved chunks are restaged before
        the error propagates.
        """
        if not chunks:
            return 0
        ticks_done = 0
        try:
            staged = self._with_retries("transfer", lambda: self._transfer(chunks[0]))
        except RetriesExhaustedError:
            self._restage(group, chunks)
            raise
        for i, chunk in enumerate(chunks):
            spikes, mask = staged
            t0 = self.clock()
            transfer_err = None
            with obs_trace.span("serve.step", lanes=len(group.lanes)):
                try:
                    currents, acc = self._with_retries(
                        "execute", lambda: self._step(group, spikes, mask)
                    )
                except RetriesExhaustedError:
                    self._restage(group, chunks[i:])
                    raise
                if i + 1 < len(chunks):
                    try:
                        staged = self._with_retries(
                            "transfer", lambda: self._transfer(chunks[i + 1])
                        )
                    except RetriesExhaustedError as e:
                        transfer_err = e
                jax.block_until_ready((currents, acc))
            wall_s = self.clock() - t0
            group._acc = acc
            group.advance_fault_ticks(self.flush_ticks)
            self.watchdog.observe(wall_s)
            self._record(group, chunk, currents, acc, wall_s)
            ticks_done += int(chunk.took.sum())
            if transfer_err is not None:
                # chunk i is fully recorded; only i+1.. go back to pending
                self._restage(group, chunks[i + 1 :])
                raise transfer_err
        return ticks_done

    def _transfer(self, chunk: _Chunk):
        if self.chaos is not None:
            self.chaos.on_transfer(self._round)
        with obs_trace.span("serve.device_transfer"):
            return jax.device_put((chunk.spikes, chunk.mask))

    # ---- metrics ----------------------------------------------------------

    def _record(self, group, chunk: _Chunk, currents, acc, wall_s: float) -> None:
        with self._state_lock:
            self._record_locked(group, chunk, currents, acc, wall_s)

    def _record_locked(self, group, chunk: _Chunk, currents, acc, wall_s: float) -> None:
        tick_ms = wall_s * 1e3 / self.flush_ticks
        fleet_events = 0.0
        events_now = np.asarray(acc.events)
        for name, lane in group.lanes.items():
            took = int(chunk.took[lane])
            if took == 0:
                continue
            self._served[name] += took
            delta = float(events_now[lane]) - self._events_seen[name]
            self._events_seen[name] = float(events_now[lane])
            fleet_events += delta
            self.registry.counter(f"tenant.{name}.events").inc(delta)
            self.registry.histogram(f"tenant.{name}.tick_ms").add(tick_ms)
            if name not in self._faulted_this_round:
                # a lane that faulted *this* round doesn't get recovery
                # credit for also serving in it - its streak must survive
                # a clean round first
                self.health.record_success(name)
            if self.keep_currents:
                self._currents[name].append(np.asarray(currents[lane, :took]))
        self.registry.counter("serve.flushes").inc()
        self.registry.counter("serve.ticks").inc(int(chunk.took.sum()))
        self._busy_s += wall_s
        self._ticks += int(chunk.took.sum())
        self._events += fleet_events

    def reset_metrics(self) -> None:
        """Zero served-work counters/histograms (warmup-then-measure).

        Benchmarks warm the jit caches with a throwaway round, then reset
        so compile time never lands in the latency percentiles.  The
        per-lane device accumulators are NOT reset - they carry the
        bit-identity contract - only the host-side bookkeeping is.
        Accounting columns (submitted/shed) reset together with served,
        so the closure identity restarts from zero; reset with pending
        work still queued and it will read as over-served until drained.
        """
        with self._pump_mutex, self._state_lock:
            self.registry.counters.clear()
            self.registry.histograms.clear()
            for name in self._served:
                self._served[name] = 0
                self._submitted[name] = 0
                self._shed[name] = 0
            for chunks in self._currents.values():
                chunks.clear()
            self._shed_log.clear()
            self._pump_error_log.clear()
            self._busy_s = 0.0
            self._ticks = 0
            self._events = 0.0

    def queue_depth(self) -> int:
        """Requests currently queued across all groups."""
        return sum(g.queue.depth() for g in self.groups.values())

    def ticks_served(self, tenant: str | None = None) -> int:
        """Ticks served for ``tenant``, or live (fabric) ticks fleet-wide."""
        if tenant is not None:
            return self._served[tenant]
        return self._ticks

    def ticks_submitted(self, tenant: str | None = None) -> int:
        """Ticks submitted by ``tenant``, or summed across all tenants."""
        if tenant is not None:
            return self._submitted[tenant]
        return sum(self._submitted.values())

    def ticks_shed(self, tenant: str | None = None) -> int:
        """Ticks shed (deadline-expired) for ``tenant``, or fleet total."""
        if tenant is not None:
            return self._shed.get(tenant, 0)
        return sum(self._shed.values())

    def shed_errors(self) -> list:
        """The typed `DeadlineExceededError`s of recent sheds (bounded)."""
        return list(self._shed_log)

    def lane_health(self, tenant: str) -> str:
        """The tenant's health state (``healthy``/``degraded``/``quarantined``)."""
        self._group_of(tenant)  # raise the canonical unknown-tenant error
        return self.health.state(tenant).value

    def accounting(self) -> dict:
        """Per-tenant work ledger and whether it closes exactly.

        For every tenant, ``submitted == served + shed + pending`` must
        hold at any quiescent point - through retries, quarantines, and
        sheds.  The chaos soak asserts ``closes`` after every drain.

        Thread-safe against a running background pump: both engine locks
        are held, so the ledger is read between pump iterations - a
        chunk's ticks are never observed mid-flight between backlog and
        served.  Retired (deregistered) tenants keep their closed rows
        with ``pending == 0``.
        """
        with self._pump_mutex, self._state_lock:
            per: dict = {}
            for name in self._retired:
                per[name] = {
                    "submitted": self._submitted.get(name, 0),
                    "served": self._served.get(name, 0),
                    "shed": self._shed.get(name, 0),
                    "pending": 0,
                }
            for group in self.groups.values():
                queued = group.queue.pending_by_tenant()
                for name in group.lanes:
                    pending = queued.get(name, 0) + group.backlog_ticks_of(name)
                    per[name] = {
                        "submitted": self._submitted[name],
                        "served": self._served[name],
                        "shed": self._shed.get(name, 0),
                        "pending": int(pending),
                    }
            closes = all(
                v["submitted"] == v["served"] + v["shed"] + v["pending"]
                for v in per.values()
            )
            return {"tenants": per, "closes": closes}

    def events_per_sec(self) -> float:
        """Sustained routed events/sec over engine step wall clock."""
        return self._events / max(self._busy_s, 1e-12)

    def currents(self, tenant: str) -> np.ndarray:
        """(ticks_served, cores, neurons_per_core) currents (keep_currents)."""
        if not self.keep_currents:
            raise ValueError("construct ServeEngine(keep_currents=True) to retain currents")
        cfg = self._group_of(tenant).config
        chunks = self._currents[tenant]
        if not chunks:
            return np.zeros((0, cfg.cores, cfg.neurons_per_core), np.float32)
        return np.concatenate(chunks, axis=0)

    def tenant_stats(self, tenant: str) -> StepStats:
        """Cumulative `StepStats` for one tenant (scalar leaves)."""
        group = self._group_of(tenant)
        lane = group.lanes[tenant]
        return jax.tree.map(lambda x: np.asarray(x)[lane], group.lane_stats())

    def _fault_summary(self) -> dict:
        """Non-zero fault/degradation counters, report-shaped."""
        names = {
            "injected": "serve.faults",
            "retries": "serve.retries",
            "retries_exhausted": "serve.retries_exhausted",
            "retry_recoveries": "serve.retry_recoveries",
            "shed_requests": "serve.shed",
            "shed_ticks": "serve.shed_ticks",
            "degraded": "serve.degraded",
            "quarantines": "serve.quarantines",
            "probes": "serve.probes",
            "recoveries": "serve.recoveries",
            "stragglers": "serve.stragglers",
            "rate_limited": "serve.rate_limited",
            "rate_limited_ticks": "serve.rate_limited_ticks",
            "autoscale_grow": "serve.autoscale.grow",
            "autoscale_shrink": "serve.autoscale.shrink",
            "pump_fatal": "serve.pump.fatal",
        }
        out = {}
        for label, counter in names.items():
            c = self.registry.counters.get(counter)
            if c is not None and c.value:
                out[label] = int(c.value)
        if self.chaos is not None:
            for kind, n in sorted(self.chaos.injected.items()):
                out[f"chaos_{kind}"] = int(n)
        return out

    def serve_report(self) -> list:
        """Per-tenant records plus one fleet record, report-CLI shaped.

        Tenant records carry ``stats_per_tick`` (so ``python -m
        repro.obs.report`` renders the per-tier breakdown per tenant) and
        tick-latency percentiles; the fleet record merges every tenant's
        latency histogram (`Histogram.merge`), reports sustained
        ``events_per_sec``, and - when any fault machinery fired - a
        ``faults`` counter dict plus recovery-time percentiles.
        """
        records = []
        fleet_hist = None
        for name in sorted(self._tenant_group):
            group = self._tenant_group[name]
            spec = group.specs[name]
            served = self._served[name]
            rec = {
                "tenant": name,
                "scenario": spec.scenario,
                "cores": group.config.cores,
                "neurons_per_core": group.config.neurons_per_core,
                "ticks": served,
                "submitted": self._submitted[name],
                "shed_ticks": self._shed.get(name, 0),
                "health": self.health.state(name).value,
                "events": self._events_seen[name],
                "queue_depth": group.queue.depth(),
            }
            if spec.fault is not None:
                rec["fault"] = spec.fault.describe()
            hist = self.registry.histograms.get(f"tenant.{name}.tick_ms")
            if hist is not None and hist.count:
                summary = hist.summary()
                rec.update(
                    tick_ms_p50=summary["p50"],
                    tick_ms_p95=summary["p95"],
                    tick_ms_p99=summary["p99"],
                )
                fleet_hist = hist if fleet_hist is None else fleet_hist.merge(hist)
            if served:
                stats = self.tenant_stats(name)._asdict()
                rec["stats_per_tick"] = {k: float(v) / served for k, v in stats.items()}
            records.append(rec)
        fleet = {
            "tenant": "__fleet__",
            "tenants": len(self._tenant_group),
            "groups": len(self.groups),
            "lane_capacity": sum(g.capacity for g in self.groups.values()),
            "ticks": self._ticks,
            "events": self._events,
            "events_per_sec": self.events_per_sec(),
            "busy_s": self._busy_s,
        }
        if fleet_hist is not None and fleet_hist.count:
            summary = fleet_hist.summary()
            fleet.update(
                tick_ms_p50=summary["p50"],
                tick_ms_p95=summary["p95"],
                tick_ms_p99=summary["p99"],
            )
        faults = self._fault_summary()
        if faults:
            fleet["faults"] = faults
        recovery = self.registry.histograms.get("serve.recovery_ms")
        if recovery is not None and recovery.count:
            summary = recovery.summary()
            fleet.update(
                recovery_ms_p50=summary["p50"],
                recovery_ms_p99=summary["p99"],
            )
        records.append(fleet)
        return records

    def emit_report(self) -> list:
        """`serve_report()`, appended to the JSONL sink when one is set."""
        records = self.serve_report()
        if self.sink is not None:
            for rec in records:
                self.sink.write(rec)
        return records


def group_key(spec: TenantSpec) -> tuple:
    """Public alias of the tenant session-compatibility key."""
    return _compat_key(spec)
