"""`repro.serve.engine`: multi-tenant streaming over the interface fabric.

The ROADMAP's serving tier: many independent tenants - each an
`InterfaceConfig` plus a `repro.traffic` tick stream (`TenantSpec`) -
served concurrently through precompiled `InterfaceSession`s instead of
one offline ``session.run`` at a time.  The moving parts:

  admission   `AdmissionController` bounds groups/lanes/request size and
              assigns each tenant a session-compatibility key.
  grouping    tenants sharing (config, connectivity) become *lanes* of a
              `TenantGroup`, which owns one precompiled session; the
              whole group steps under a single jit via the masked
              ``run_batched`` (vmap over the lane axis).
  queueing    per-group `IngestQueue` with size-/deadline-triggered
              micro-batching (`repro.serve.queue`).
  batching    flushed requests pack into fixed-shape (lanes, flush_ticks)
              chunks - ragged/short streams right-padded with an explicit
              mask, so every lane stays *bit-identical* to its solo
              ``session.run`` (currents and stats; the per-lane
              accumulator is threaded through chunks as the scan carry).
  transfer    double-buffered `jax.device_put`: chunk t+1's host->device
              copy is issued while chunk t computes (with buffer donation
              on accelerators, skipped on CPU).
  metrics     per-tenant `repro.obs.metrics` histograms/counters
              (events/sec, tick-latency p50/p99, queue depth), fleet-wide
              percentiles via `Histogram.merge`, JSONL sink + records
              shaped for ``python -m repro.obs.report``.

Minimal use:

    from repro.serve import ServeEngine, TenantSpec

    engine = ServeEngine(flush_ticks=16)
    engine.register(TenantSpec("t0", cfg, scenario="sparse_poisson"))
    engine.register(TenantSpec("t1", cfg, scenario="hotspot_core"))
    engine.submit_scenario("t0", ticks=64)   # or engine.submit(name, frames)
    engine.submit_scenario("t1", ticks=48)
    engine.drain()
    records = engine.serve_report()

The prefill/decode LM engine that previously lived in this module moved
to `repro.serve.lm_engine`.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.interface import Interface
from repro.interface.stats import StepStats
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.admission import AdmissionController, AdmissionPolicy
from repro.serve.queue import IngestQueue
from repro.serve.tenant import TenantSpec, default_connectivity
from repro.serve.tenant import compat_key as _compat_key


@dataclasses.dataclass
class _Chunk:
    """One fixed-shape batched step: left-aligned frames plus lane mask."""

    spikes: np.ndarray  # (lanes, flush_ticks, cores, neurons_per_core) bool
    mask: np.ndarray  # (lanes, flush_ticks) bool
    took: np.ndarray  # (lanes,) int: live ticks packed into each lane


class TenantGroup:
    """Tenants sharing one precompiled session, stepped as vmap lanes."""

    def __init__(self, key, config, params, queue: IngestQueue):
        self.key = key
        self.config = config
        self.params = params
        self.queue = queue
        with obs_trace.span("serve.group_compile", cores=config.cores):
            self.session = Interface(config).compile(params)
        self.specs: dict = {}  # name -> TenantSpec
        self.lanes: dict = {}  # name -> lane index
        self._backlog: dict = {}  # name -> deque of host frame arrays
        self._acc = None  # per-lane StepStats carry ((lanes,) leaves)

    def add(self, spec: TenantSpec) -> int:
        lane = len(self.lanes)
        self.specs[spec.name] = spec
        self.lanes[spec.name] = lane
        self._backlog[spec.name] = collections.deque()
        if self._acc is not None:
            # new lane: its accumulator row starts at zero
            self._acc = self._commit(
                jax.tree.map(
                    lambda x: np.concatenate([np.asarray(x), np.zeros((1,), x.dtype)]),
                    self._acc,
                )
            )
        return lane

    @staticmethod
    def _commit(tree):
        """Place host-built accumulators on the device, committed.

        Uncommitted numpy inputs and committed jit outputs hash to
        different fast-path cache entries; committing here keeps the
        masked batched step on ONE cache entry for the engine's lifetime
        (the stability the soak test asserts).
        """
        dev = jax.devices()[0]
        return jax.tree.map(lambda x: jax.device_put(np.asarray(x), dev), tree)

    def lane_names(self) -> list:
        return sorted(self.lanes, key=self.lanes.get)

    def lane_stats(self):
        """Per-lane cumulative `StepStats` carry ((lanes,) leaves)."""
        if self._acc is None:
            b = len(self.lanes)
            self._acc = self._commit(
                jax.tree.map(lambda x: np.zeros((b,), x.dtype), StepStats.zeros())
            )
        return self._acc

    def stage(self, requests) -> None:
        """Append flushed requests to the per-lane host backlog."""
        cfg = self.config
        for req in requests:
            frames = np.asarray(req.frames)
            if frames.shape[1:] != (cfg.cores, cfg.neurons_per_core):
                raise ValueError(
                    f"tenant {req.tenant!r} frames shaped {frames.shape[1:]} do not match the "
                    f"group fabric ({cfg.cores}, {cfg.neurons_per_core})"
                )
            self._backlog[req.tenant].append(frames.astype(bool))

    def backlog_ticks(self) -> int:
        return sum(f.shape[0] for q in self._backlog.values() for f in q)

    def take_chunk(self, flush_ticks: int) -> _Chunk | None:
        """Pack up to ``flush_ticks`` backlog ticks per lane, left-aligned.

        Shapes are fixed at (lanes, flush_ticks, ...) regardless of how
        much backlog exists, so the jitted batched step compiles once per
        lane count - partial chunks ride the mask, not a new shape.
        """
        b = len(self.lanes)
        cfg = self.config
        took = np.zeros((b,), np.int64)
        spikes = np.zeros((b, flush_ticks, cfg.cores, cfg.neurons_per_core), bool)
        mask = np.zeros((b, flush_ticks), bool)
        for name, lane in self.lanes.items():
            queue = self._backlog[name]
            t = 0
            while queue and t < flush_ticks:
                frames = queue.popleft()
                take = min(frames.shape[0], flush_ticks - t)
                spikes[lane, t : t + take] = frames[:take]
                t += take
                if take < frames.shape[0]:
                    queue.appendleft(frames[take:])
            mask[lane, :t] = True
            took[lane] = t
        if not took.any():
            return None
        return _Chunk(spikes=spikes, mask=mask, took=took)


class ServeEngine:
    """Multi-tenant streaming engine over precompiled interface sessions.

    flush_ticks:       time extent of one batched step; also the ingest
                       queue's size trigger (in tick frames).  Fixed, so
                       chunk shapes - and the jit cache - stay stable.
    flush_deadline_s:  max age of the oldest queued request before a
                       partial batch flushes anyway (0 = always ready).
    policy:            `AdmissionPolicy` capacity limits.
    registry:          `MetricsRegistry` receiving per-tenant counters and
                       histograms (a private one by default).
    sink:              optional `JsonlSink`; `emit_report()` appends one
                       record per tenant plus the fleet record.
    keep_currents:     retain every served tick's currents per tenant
                       (tests/benchmarks; unbounded memory under real
                       sustained load, so off by default).
    clock:             injectable monotonic clock (deadline tests).
    """

    def __init__(
        self,
        *,
        flush_ticks: int = 16,
        flush_deadline_s: float = 0.005,
        policy: AdmissionPolicy | None = None,
        registry: obs_metrics.MetricsRegistry | None = None,
        sink: obs_metrics.JsonlSink | None = None,
        keep_currents: bool = False,
        clock: Callable[[], float] = time.monotonic,
    ):
        if flush_ticks < 1:
            raise ValueError(f"flush_ticks must be >= 1, got {flush_ticks}")
        self.flush_ticks = flush_ticks
        self.flush_deadline_s = flush_deadline_s
        self.admission = AdmissionController(policy)
        self.registry = registry or obs_metrics.MetricsRegistry()
        self.sink = sink
        self.keep_currents = keep_currents
        self.clock = clock
        self.groups: dict = {}  # compat key -> TenantGroup
        self._tenant_group: dict = {}  # tenant name -> TenantGroup
        self._rounds: dict = {}  # tenant name -> scenario round counter
        self._served: dict = {}  # tenant name -> ticks served
        self._events_seen: dict = {}  # tenant name -> cumulative events read
        self._currents: dict = {}  # tenant name -> list of (t_i, C, N) arrays
        self._busy_s = 0.0
        self._ticks = 0
        self._events = 0.0

    # ---- registration / ingest -------------------------------------------

    def register(self, spec: TenantSpec, params=None) -> TenantSpec:
        """Admit a tenant; compile its group's session on first use.

        params: optional explicit fabric connectivity for a *new* group
        (defaults to `default_connectivity(spec.config,
        spec.connectivity_seed)`).  Ignored for an existing group - the
        compatibility key pins connectivity to the seed, so passing a
        conflicting params object for an occupied key is an error.
        """
        if spec.name in self._tenant_group:
            raise ValueError(f"tenant {spec.name!r} is already registered")
        occupancy = {k: len(g.lanes) for k, g in self.groups.items()}
        key = self.admission.admit(spec, occupancy)
        group = self.groups.get(key)
        if group is None:
            if params is None:
                params = default_connectivity(spec.config, spec.connectivity_seed)
            queue = IngestQueue(
                flush_frames=self.flush_ticks,
                flush_deadline_s=self.flush_deadline_s,
                clock=self.clock,
            )
            group = TenantGroup(key, spec.config, params, queue)
            self.groups[key] = group
        elif params is not None:
            raise ValueError(
                f"tenant {spec.name!r}: explicit params conflict with the already-compiled "
                f"group for this (config, connectivity_seed); omit params to join it"
            )
        group.add(spec)
        self._tenant_group[spec.name] = group
        self._rounds[spec.name] = 0
        self._served[spec.name] = 0
        self._events_seen[spec.name] = 0.0
        self._currents[spec.name] = []
        return spec

    def submit(self, tenant: str, frames) -> None:
        """Enqueue (ticks, cores, neurons_per_core) bool frames."""
        group = self._group_of(tenant)
        frames = np.asarray(frames)
        cfg = group.config
        if frames.ndim != 3 or frames.shape[1:] != (cfg.cores, cfg.neurons_per_core):
            raise ValueError(
                f"tenant {tenant!r}: frames shaped {frames.shape} do not match the group "
                f"fabric (ticks, {cfg.cores}, {cfg.neurons_per_core})"
            )
        self.admission.validate_request(tenant, int(frames.shape[0]))
        group.queue.submit(tenant, frames)

    def submit_scenario(self, tenant: str, ticks: int) -> None:
        """Generate and enqueue one round of the tenant's traffic scenario."""
        spec = self._group_of(tenant).specs[tenant]
        frames = np.asarray(spec.stream(ticks, round=self._rounds[tenant]))
        self._rounds[tenant] += 1
        self.submit(tenant, frames)

    def _group_of(self, tenant: str) -> TenantGroup:
        try:
            return self._tenant_group[tenant]
        except KeyError:
            raise KeyError(
                f"unknown tenant {tenant!r}; registered: "
                f"{', '.join(sorted(self._tenant_group)) or '(none)'}"
            ) from None

    # ---- serving loop -----------------------------------------------------

    def pump(self, force: bool = False) -> int:
        """One engine iteration: flush ready queues, step their groups.

        Returns the number of live ticks served.  ``force`` flushes
        regardless of the micro-batch triggers (drain semantics).
        """
        ticks_done = 0
        depth_hist = self.registry.histogram("serve.queue_depth")
        for group in self.groups.values():
            depth_hist.add(group.queue.depth())
            group.stage(group.queue.poll(force=force))
            chunks = []
            while True:
                chunk = group.take_chunk(self.flush_ticks)
                if chunk is None:
                    break
                chunks.append(chunk)
            ticks_done += self._execute(group, chunks)
        return ticks_done

    def drain(self) -> int:
        """Serve until every queue and backlog is empty; returns ticks."""
        total = 0
        while True:
            served = self.pump(force=True)
            total += served
            if served == 0 and not any(
                g.queue.depth() or g.backlog_ticks() for g in self.groups.values()
            ):
                return total

    def _execute(self, group: TenantGroup, chunks: list) -> int:
        """Step one group through its chunks with double-buffered transfer.

        Chunk t+1's `jax.device_put` is issued after chunk t's batched
        step is dispatched but before its results are blocked on, so the
        host->device copy overlaps device compute; on accelerators the
        masked jit additionally donates the spike/accumulator buffers.
        """
        if not chunks:
            return 0
        ticks_done = 0
        staged = self._transfer(chunks[0])
        for i, chunk in enumerate(chunks):
            spikes, mask = staged
            t0 = self.clock()
            with obs_trace.span("serve.step", lanes=len(group.lanes)):
                currents, acc = group.session.run_batched(
                    spikes, mask=mask, stats0=group.lane_stats()
                )
                if i + 1 < len(chunks):
                    staged = self._transfer(chunks[i + 1])
                jax.block_until_ready((currents, acc))
            wall_s = self.clock() - t0
            group._acc = acc
            self._record(group, chunk, currents, acc, wall_s)
            ticks_done += int(chunk.took.sum())
        return ticks_done

    def _transfer(self, chunk: _Chunk):
        with obs_trace.span("serve.device_transfer"):
            return jax.device_put((chunk.spikes, chunk.mask))

    # ---- metrics ----------------------------------------------------------

    def _record(self, group, chunk: _Chunk, currents, acc, wall_s: float) -> None:
        tick_ms = wall_s * 1e3 / self.flush_ticks
        fleet_events = 0.0
        events_now = np.asarray(acc.events)
        for name, lane in group.lanes.items():
            took = int(chunk.took[lane])
            if took == 0:
                continue
            self._served[name] += took
            delta = float(events_now[lane]) - self._events_seen[name]
            self._events_seen[name] = float(events_now[lane])
            fleet_events += delta
            self.registry.counter(f"tenant.{name}.events").inc(delta)
            self.registry.histogram(f"tenant.{name}.tick_ms").add(tick_ms)
            if self.keep_currents:
                self._currents[name].append(np.asarray(currents[lane, :took]))
        self.registry.counter("serve.flushes").inc()
        self.registry.counter("serve.ticks").inc(int(chunk.took.sum()))
        self._busy_s += wall_s
        self._ticks += int(chunk.took.sum())
        self._events += fleet_events

    def reset_metrics(self) -> None:
        """Zero served-work counters/histograms (warmup-then-measure).

        Benchmarks warm the jit caches with a throwaway round, then reset
        so compile time never lands in the latency percentiles.  The
        per-lane device accumulators are NOT reset - they carry the
        bit-identity contract - only the host-side bookkeeping is.
        """
        self.registry.counters.clear()
        self.registry.histograms.clear()
        for name in self._served:
            self._served[name] = 0
            self._currents[name].clear()
        self._busy_s = 0.0
        self._ticks = 0
        self._events = 0.0

    def queue_depth(self) -> int:
        """Requests currently queued across all groups."""
        return sum(g.queue.depth() for g in self.groups.values())

    def ticks_served(self, tenant: str | None = None) -> int:
        if tenant is not None:
            return self._served[tenant]
        return self._ticks

    def events_per_sec(self) -> float:
        """Sustained routed events/sec over engine step wall clock."""
        return self._events / max(self._busy_s, 1e-12)

    def currents(self, tenant: str) -> np.ndarray:
        """(ticks_served, cores, neurons_per_core) currents (keep_currents)."""
        if not self.keep_currents:
            raise ValueError("construct ServeEngine(keep_currents=True) to retain currents")
        cfg = self._group_of(tenant).config
        chunks = self._currents[tenant]
        if not chunks:
            return np.zeros((0, cfg.cores, cfg.neurons_per_core), np.float32)
        return np.concatenate(chunks, axis=0)

    def tenant_stats(self, tenant: str) -> StepStats:
        """Cumulative `StepStats` for one tenant (scalar leaves)."""
        group = self._group_of(tenant)
        lane = group.lanes[tenant]
        return jax.tree.map(lambda x: np.asarray(x)[lane], group.lane_stats())

    def serve_report(self) -> list:
        """Per-tenant records plus one fleet record, report-CLI shaped.

        Tenant records carry ``stats_per_tick`` (so ``python -m
        repro.obs.report`` renders the per-tier breakdown per tenant) and
        tick-latency percentiles; the fleet record merges every tenant's
        latency histogram (`Histogram.merge`) and reports sustained
        ``events_per_sec``.
        """
        records = []
        fleet_hist = None
        for name in sorted(self._tenant_group):
            group = self._tenant_group[name]
            spec = group.specs[name]
            served = self._served[name]
            rec = {
                "tenant": name,
                "scenario": spec.scenario,
                "cores": group.config.cores,
                "neurons_per_core": group.config.neurons_per_core,
                "ticks": served,
                "events": self._events_seen[name],
                "queue_depth": group.queue.depth(),
            }
            hist = self.registry.histograms.get(f"tenant.{name}.tick_ms")
            if hist is not None and hist.count:
                summary = hist.summary()
                rec.update(
                    tick_ms_p50=summary["p50"],
                    tick_ms_p95=summary["p95"],
                    tick_ms_p99=summary["p99"],
                )
                fleet_hist = hist if fleet_hist is None else fleet_hist.merge(hist)
            if served:
                stats = self.tenant_stats(name)._asdict()
                rec["stats_per_tick"] = {k: float(v) / served for k, v in stats.items()}
            records.append(rec)
        fleet = {
            "tenant": "__fleet__",
            "tenants": len(self._tenant_group),
            "groups": len(self.groups),
            "ticks": self._ticks,
            "events": self._events,
            "events_per_sec": self.events_per_sec(),
            "busy_s": self._busy_s,
        }
        if fleet_hist is not None and fleet_hist.count:
            summary = fleet_hist.summary()
            fleet.update(
                tick_ms_p50=summary["p50"],
                tick_ms_p95=summary["p95"],
                tick_ms_p99=summary["p99"],
            )
        records.append(fleet)
        return records

    def emit_report(self) -> list:
        """`serve_report()`, appended to the JSONL sink when one is set."""
        records = self.serve_report()
        if self.sink is not None:
            for rec in records:
                self.sink.write(rec)
        return records


def group_key(spec: TenantSpec) -> tuple:
    """Public alias of the tenant session-compatibility key."""
    return _compat_key(spec)
