"""`repro.serve.engine`: multi-tenant streaming over the interface fabric.

The ROADMAP's serving tier: many independent tenants - each an
`InterfaceConfig` plus a `repro.traffic` tick stream (`TenantSpec`) -
served concurrently through precompiled `InterfaceSession`s instead of
one offline ``session.run`` at a time.  The moving parts:

  admission   `AdmissionController` bounds groups/lanes/request size and
              assigns each tenant a session-compatibility key; frames are
              validated (shape/dtype/finite) before any device work.
  grouping    tenants sharing (config, connectivity, fault) become
              *lanes* of a `TenantGroup`, which owns one precompiled
              session; the whole group steps under a single jit via the
              masked ``run_batched`` (vmap over the lane axis).
  queueing    per-group `IngestQueue` with size-/deadline-triggered
              micro-batching (`repro.serve.queue`).
  batching    flushed requests pack into fixed-shape (lanes, flush_ticks)
              chunks - ragged/short streams right-padded with an explicit
              mask, so every lane stays *bit-identical* to its solo
              ``session.run`` (currents and stats; the per-lane
              accumulator is threaded through chunks as the scan carry).
  transfer    double-buffered `jax.device_put`: chunk t+1's host->device
              copy is issued while chunk t computes (with buffer donation
              on accelerators, skipped on CPU).
  metrics     per-tenant `repro.obs.metrics` histograms/counters
              (events/sec, tick-latency p50/p99, queue depth), fleet-wide
              percentiles via `Histogram.merge`, JSONL sink + records
              shaped for ``python -m repro.obs.report``.

Graceful degradation (PR 8): the engine survives a hostile environment
instead of assuming the happy path -

  faults      an optional `repro.ft.chaos.ChaosInjector` fires a seeded
              `FaultPlan` at configured pump rounds; tenants may also
              compile a fabric-level `repro.ft.faults.FaultModel` into
              their session (via ``TenantSpec.fault``).
  retries     transient transfer/execute faults retry under a bounded
              exponential-backoff `RetryPolicy`; the per-lane accumulator
              commits only after a successful step, so a replayed chunk
              can never double-count, and `RetriesExhaustedError`
              restages unserved work back onto the backlog first - the
              accounting identity submitted == served + shed + pending
              holds through every failure.
  health      a per-lane `HealthTracker` walks healthy -> degraded ->
              quarantined; quarantined lanes are masked out of the shared
              batched step *without recompiling* (mask rows, not shapes)
              and probe back in after a cooldown.
  shedding    queued requests older than ``AdmissionPolicy.shed_deadline_s``
              are dropped at flush time as typed `DeadlineExceededError`s
              (`shed_errors()`), and `QueueOverflowError` bounds pending
              work at submit time.
  watchdog    the `repro.ft.runner.Watchdog` observes per-flush wall time
              on the engine registry (``serve.flush_ms`` /
              ``serve.stragglers``), one telemetry substrate with
              training.

Minimal use:

    from repro.serve import ServeEngine, TenantSpec

    engine = ServeEngine(flush_ticks=16)
    engine.register(TenantSpec("t0", cfg, scenario="sparse_poisson"))
    engine.register(TenantSpec("t1", cfg, scenario="hotspot_core"))
    engine.submit_scenario("t0", ticks=64)   # or engine.submit(name, frames)
    engine.submit_scenario("t1", ticks=48)
    engine.drain()
    records = engine.serve_report()

The prefill/decode LM engine that previously lived in this module moved
to `repro.serve.lm_engine`.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.ft.chaos import RetriesExhaustedError, TransientFaultError
from repro.ft.runner import Watchdog
from repro.interface import Interface
from repro.interface.stats import StepStats
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.admission import (
    AdmissionController,
    AdmissionPolicy,
    DeadlineExceededError,
    validate_frames,
)
from repro.serve.health import HealthPolicy, HealthTracker, RetryPolicy
from repro.serve.queue import IngestQueue
from repro.serve.tenant import TenantSpec, default_connectivity
from repro.serve.tenant import compat_key as _compat_key


@dataclasses.dataclass
class _Chunk:
    """One fixed-shape batched step: left-aligned frames plus lane mask."""

    spikes: np.ndarray  # (lanes, flush_ticks, cores, neurons_per_core) bool
    mask: np.ndarray  # (lanes, flush_ticks) bool
    took: np.ndarray  # (lanes,) int: live ticks packed into each lane


class TenantGroup:
    """Tenants sharing one precompiled session, stepped as vmap lanes."""

    def __init__(self, key, config, params, queue: IngestQueue, fault=None):
        """Compile the shared session for ``key`` = (config, connectivity,
        fault) and start with zero lanes; tenants join via `add`."""
        self.key = key
        self.config = config
        self.params = params
        self.queue = queue
        self.fault = fault
        with obs_trace.span("serve.group_compile", cores=config.cores):
            self.session = Interface(config).compile(params, fault=fault)
        self.specs: dict = {}  # name -> TenantSpec
        self.lanes: dict = {}  # name -> lane index
        self._backlog: dict = {}  # name -> deque of host frame arrays
        self._acc = None  # per-lane StepStats carry ((lanes,) leaves)
        # per-lane global tick offset of the compiled fault's drop stream
        self._lane_ticks = np.zeros((0,), np.int32)

    def add(self, spec: TenantSpec) -> int:
        """Assign ``spec`` the next lane index and return it; an existing
        accumulator grows a zero row so running totals are preserved."""
        lane = len(self.lanes)
        self.specs[spec.name] = spec
        self.lanes[spec.name] = lane
        self._backlog[spec.name] = collections.deque()
        self._lane_ticks = np.concatenate(
            [self._lane_ticks, np.zeros((1,), np.int32)]
        )
        if self._acc is not None:
            # new lane: its accumulator row starts at zero
            self._acc = self._commit(
                jax.tree.map(
                    lambda x: np.concatenate([np.asarray(x), np.zeros((1,), x.dtype)]),
                    self._acc,
                )
            )
        return lane

    @staticmethod
    def _commit(tree):
        """Place host-built accumulators on the device, committed.

        Uncommitted numpy inputs and committed jit outputs hash to
        different fast-path cache entries; committing here keeps the
        masked batched step on ONE cache entry for the engine's lifetime
        (the stability the soak test asserts).
        """
        dev = jax.devices()[0]
        return jax.tree.map(lambda x: jax.device_put(np.asarray(x), dev), tree)

    def lane_names(self) -> list:
        """Tenant names in lane order (index 0 first)."""
        return sorted(self.lanes, key=self.lanes.get)

    def lane_stats(self):
        """Per-lane cumulative `StepStats` carry ((lanes,) leaves)."""
        if self._acc is None:
            b = len(self.lanes)
            self._acc = self._commit(
                jax.tree.map(lambda x: np.zeros((b,), x.dtype), StepStats.zeros())
            )
        return self._acc

    def fault_tick0(self) -> np.ndarray:
        """(lanes,) global tick offsets for the compiled fault stream."""
        return self._lane_ticks

    def advance_fault_ticks(self, flush_ticks: int) -> None:
        """One chunk executed: every lane's fault window moved forward."""
        self._lane_ticks = self._lane_ticks + np.int32(flush_ticks)

    def stage(self, requests) -> None:
        """Append flushed requests to the per-lane host backlog."""
        cfg = self.config
        for req in requests:
            frames = np.asarray(req.frames)
            if frames.shape[1:] != (cfg.cores, cfg.neurons_per_core):
                raise ValueError(
                    f"tenant {req.tenant!r} frames shaped {frames.shape[1:]} do not match the "
                    f"group fabric ({cfg.cores}, {cfg.neurons_per_core})"
                )
            self._backlog[req.tenant].append(frames.astype(bool))

    def backlog_ticks(self) -> int:
        """Staged-but-unserved ticks across every lane of this group."""
        return sum(f.shape[0] for q in self._backlog.values() for f in q)

    def backlog_ticks_of(self, name: str) -> int:
        """Staged-but-unserved ticks for one tenant."""
        return sum(f.shape[0] for f in self._backlog[name])

    def take_chunk(self, flush_ticks: int, skip=frozenset()) -> _Chunk | None:
        """Pack up to ``flush_ticks`` backlog ticks per lane, left-aligned.

        Shapes are fixed at (lanes, flush_ticks, ...) regardless of how
        much backlog exists, so the jitted batched step compiles once per
        lane count - partial chunks ride the mask, not a new shape.

        skip: lane names (quarantined tenants) left out of this chunk -
        their backlog is retained untouched and their mask row stays
        all-False, so degradation never changes shapes or the jit cache.
        """
        b = len(self.lanes)
        cfg = self.config
        took = np.zeros((b,), np.int64)
        spikes = np.zeros((b, flush_ticks, cfg.cores, cfg.neurons_per_core), bool)
        mask = np.zeros((b, flush_ticks), bool)
        for name, lane in self.lanes.items():
            if name in skip:
                continue
            queue = self._backlog[name]
            t = 0
            while queue and t < flush_ticks:
                frames = queue.popleft()
                take = min(frames.shape[0], flush_ticks - t)
                spikes[lane, t : t + take] = frames[:take]
                t += take
                if take < frames.shape[0]:
                    queue.appendleft(frames[take:])
            mask[lane, :t] = True
            took[lane] = t
        if not took.any():
            return None
        return _Chunk(spikes=spikes, mask=mask, took=took)


class ServeEngine:
    """Multi-tenant streaming engine over precompiled interface sessions.

    flush_ticks:       time extent of one batched step; also the ingest
                       queue's size trigger (in tick frames).  Fixed, so
                       chunk shapes - and the jit cache - stay stable.
    flush_deadline_s:  max age of the oldest queued request before a
                       partial batch flushes anyway (0 = always ready).
    policy:            `AdmissionPolicy` capacity limits (now including
                       ``max_pending_frames`` backpressure and the
                       ``shed_deadline_s`` shed bound).
    registry:          `MetricsRegistry` receiving per-tenant counters and
                       histograms (a private one by default).
    sink:              optional `JsonlSink`; `emit_report()` appends one
                       record per tenant plus the fleet record.
    keep_currents:     retain every served tick's currents per tenant
                       (tests/benchmarks; unbounded memory under real
                       sustained load, so off by default).
    clock:             injectable monotonic clock (deadline tests).
    chaos:             optional `repro.ft.chaos.ChaosInjector` firing a
                       seeded `FaultPlan` at this engine's pump rounds.
    retry:             `RetryPolicy` for transient transfer/execute
                       faults (bounded exponential backoff).
    health:            `HealthPolicy` thresholds of the per-lane state
                       machine (quarantine/probe/recover).
    watchdog:          optional `repro.ft.runner.Watchdog`; by default
                       one is created on this engine's registry with the
                       ``serve`` prefix (flush wall-time histogram +
                       straggler counter).
    sleep:             injectable backoff sleep (fake-clock tests).
    """

    def __init__(
        self,
        *,
        flush_ticks: int = 16,
        flush_deadline_s: float = 0.005,
        policy: AdmissionPolicy | None = None,
        registry: obs_metrics.MetricsRegistry | None = None,
        sink: obs_metrics.JsonlSink | None = None,
        keep_currents: bool = False,
        clock: Callable[[], float] = time.monotonic,
        chaos=None,
        retry: RetryPolicy | None = None,
        health: HealthPolicy | None = None,
        watchdog: Watchdog | None = None,
        sleep: Callable[[float], None] = time.sleep,
    ):
        if flush_ticks < 1:
            raise ValueError(f"flush_ticks must be >= 1, got {flush_ticks}")
        self.flush_ticks = flush_ticks
        self.flush_deadline_s = flush_deadline_s
        self.admission = AdmissionController(policy)
        self.registry = registry or obs_metrics.MetricsRegistry()
        self.sink = sink
        self.keep_currents = keep_currents
        self.clock = clock
        self.chaos = chaos
        self.retry = retry or RetryPolicy()
        self.health = HealthTracker(health, registry=self.registry, clock=clock)
        self.watchdog = watchdog or Watchdog(registry=self.registry, prefix="serve")
        self._sleep = sleep
        self.groups: dict = {}  # compat key -> TenantGroup
        self._tenant_group: dict = {}  # tenant name -> TenantGroup
        self._rounds: dict = {}  # tenant name -> scenario round counter
        self._served: dict = {}  # tenant name -> ticks served
        self._submitted: dict = {}  # tenant name -> ticks submitted
        self._shed: dict = {}  # tenant name -> ticks shed past deadline
        self._events_seen: dict = {}  # tenant name -> cumulative events read
        self._currents: dict = {}  # tenant name -> list of (t_i, C, N) arrays
        self._shed_log: collections.deque = collections.deque(maxlen=256)
        self._round = 0  # pump round counter (the chaos plan's time axis)
        self._faulted_this_round: set = set()  # lanes faulted in this pump
        self._busy_s = 0.0
        self._ticks = 0
        self._events = 0.0

    # ---- registration / ingest -------------------------------------------

    def register(self, spec: TenantSpec, params=None) -> TenantSpec:
        """Admit a tenant; compile its group's session on first use.

        params: optional explicit fabric connectivity for a *new* group
        (defaults to `default_connectivity(spec.config,
        spec.connectivity_seed)`).  Ignored for an existing group - the
        compatibility key pins connectivity to the seed, so passing a
        conflicting params object for an occupied key is an error.
        """
        if spec.name in self._tenant_group:
            raise ValueError(f"tenant {spec.name!r} is already registered")
        occupancy = {k: len(g.lanes) for k, g in self.groups.items()}
        key = self.admission.admit(spec, occupancy)
        group = self.groups.get(key)
        if group is None:
            if params is None:
                params = default_connectivity(spec.config, spec.connectivity_seed)
            queue = IngestQueue(
                flush_frames=self.flush_ticks,
                flush_deadline_s=self.flush_deadline_s,
                clock=self.clock,
                frame_shape=(spec.config.cores, spec.config.neurons_per_core),
            )
            group = TenantGroup(key, spec.config, params, queue, fault=spec.fault)
            self.groups[key] = group
        elif params is not None:
            raise ValueError(
                f"tenant {spec.name!r}: explicit params conflict with the already-compiled "
                f"group for this (config, connectivity_seed); omit params to join it"
            )
        group.add(spec)
        self._tenant_group[spec.name] = group
        self._rounds[spec.name] = 0
        self._served[spec.name] = 0
        self._submitted[spec.name] = 0
        self._shed[spec.name] = 0
        self._events_seen[spec.name] = 0.0
        self._currents[spec.name] = []
        self.health.add(spec.name)
        return spec

    def submit(self, tenant: str, frames) -> None:
        """Enqueue a spike stream for one tenant.

        Args:
          tenant: a name previously passed to `register` (KeyError with
            the registered names otherwise).
          frames: a (ticks, cores, neurons_per_core) bool spike stream;
            anything array-like is accepted and validated host-side.

        Nothing runs yet - frames sit in the tenant's micro-batch queue
        until the next `pump` / `drain` flushes them through the group's
        shared `InterfaceSession`.

        Raises:
          FrameValidationError: wrong shape/dtype or non-finite values
            (nothing malformed ever reaches the jitted step).
          AdmissionError: the request exceeds the tenant's per-request
            or in-flight tick budget.
          QueueOverflowError: the group's bounded queue is full.
        """
        group = self._group_of(tenant)
        cfg = group.config
        frames = validate_frames(
            frames, shape=(cfg.cores, cfg.neurons_per_core), tenant=tenant
        )
        self.admission.validate_request(
            tenant,
            int(frames.shape[0]),
            pending_frames=group.queue.pending_frames() + group.backlog_ticks(),
        )
        group.queue.submit(tenant, frames)
        self._submitted[tenant] += int(frames.shape[0])

    def submit_scenario(self, tenant: str, ticks: int) -> None:
        """Generate and enqueue one round of the tenant's traffic scenario."""
        spec = self._group_of(tenant).specs[tenant]
        frames = np.asarray(spec.stream(ticks, round=self._rounds[tenant]))
        self._rounds[tenant] += 1
        self.submit(tenant, frames)

    def _group_of(self, tenant: str) -> TenantGroup:
        try:
            return self._tenant_group[tenant]
        except KeyError:
            raise KeyError(
                f"unknown tenant {tenant!r}; registered: "
                f"{', '.join(sorted(self._tenant_group)) or '(none)'}"
            ) from None

    # ---- serving loop -----------------------------------------------------

    def pump(self, force: bool = False) -> int:
        """One engine iteration: flush ready queues, step their groups.

        Returns the number of live ticks served.  ``force`` flushes
        regardless of the micro-batch triggers (drain semantics).

        Each pump is one *round* of the chaos clock: quarantine cooldowns
        age first, then this round's scheduled lane faults land, then
        expired requests are shed, and finally every group steps with its
        quarantined lanes masked out.
        """
        self._round += 1
        self.health.advance()
        self._faulted_this_round.clear()
        if self.chaos is not None:
            for ev in self.chaos.lane_faults(self._round):
                self._lane_fault(ev)
        ticks_done = 0
        depth_hist = self.registry.histogram("serve.queue_depth")
        for group in self.groups.values():
            depth_hist.add(group.queue.depth())
            group.stage(self._shed_expired(group.queue.poll(force=force)))
            skip = {n for n in group.lanes if not self.health.usable(n)}
            chunks = []
            while True:
                chunk = group.take_chunk(self.flush_ticks, skip=skip)
                if chunk is None:
                    break
                chunks.append(chunk)
            ticks_done += self._execute(group, chunks)
        return ticks_done

    def drain(self) -> int:
        """Serve until every queue and backlog is empty; returns ticks.

        Quarantined lanes hold their backlog, so a drain keeps pumping -
        aging cooldowns - until every lane has recovered and served; it
        terminates because quarantine is always finite.
        """
        total = 0
        while True:
            served = self.pump(force=True)
            total += served
            if served == 0 and not any(
                g.queue.depth() or g.backlog_ticks() for g in self.groups.values()
            ):
                return total

    def _shed_expired(self, requests) -> list:
        """Drop queued requests older than the policy's shed deadline.

        Each shed is recorded as a typed `DeadlineExceededError` (see
        `shed_errors`) and counted - shed ticks stay part of the
        accounting identity, they just move to the ``shed`` column.
        """
        limit = self.admission.policy.shed_deadline_s
        if limit is None or not requests:
            return requests
        now = self.clock()
        kept = []
        for req in requests:
            age = now - req.enqueued_at
            if age <= limit:
                kept.append(req)
                continue
            err = DeadlineExceededError(
                f"tenant {req.tenant!r}: request aged {age:.4f}s in queue "
                f"(shed_deadline_s={limit}); {req.ticks} tick frames shed"
            )
            self._shed_log.append(err)
            self._shed[req.tenant] = self._shed.get(req.tenant, 0) + req.ticks
            self.registry.counter("serve.shed").inc()
            self.registry.counter("serve.shed_ticks").inc(req.ticks)
        return kept

    def _lane_fault(self, ev) -> None:
        """One injected lane fault: advance the tenant's health machine."""
        if ev.tenant not in self._tenant_group:
            self.registry.counter("serve.faults.unknown_lane").inc()
            return
        self.registry.counter("serve.faults").inc()
        self._faulted_this_round.add(ev.tenant)
        self.health.record_failure(ev.tenant)

    def _with_retries(self, what: str, fn):
        """Run ``fn`` with bounded exponential backoff on transient faults.

        Only `TransientFaultError`s are retried; anything else (a real
        bug) propagates immediately.  After the budget is spent a
        `RetriesExhaustedError` chains the last fault.  A successful
        retry records the episode in ``serve.recovery_ms``.
        """
        policy = self.retry
        delay = policy.backoff_base_s
        t_first = None
        for attempt in range(policy.max_retries + 1):
            try:
                out = fn()
            except TransientFaultError as e:
                self.registry.counter("serve.faults").inc()
                self.registry.counter("serve.retries").inc()
                self.registry.counter(f"serve.retries.{what}").inc()
                if t_first is None:
                    t_first = self.clock()
                if attempt >= policy.max_retries:
                    self.registry.counter("serve.retries_exhausted").inc()
                    raise RetriesExhaustedError(
                        f"{what} still failing after {policy.max_retries} "
                        f"retries (backoff from {policy.backoff_base_s}s)"
                    ) from e
                self._sleep(delay)
                delay *= policy.backoff_factor
                continue
            if t_first is not None:
                self.registry.counter("serve.retry_recoveries").inc()
                self.registry.histogram("serve.recovery_ms").add(
                    max(self.clock() - t_first, 0.0) * 1e3
                )
            return out
        raise AssertionError("unreachable")  # loop always returns or raises

    def _restage(self, group: TenantGroup, chunks: list) -> None:
        """Return unserved chunks to the front of the backlog, in order.

        Called before a `RetriesExhaustedError` propagates: the ticks a
        failed chunk carried go back to ``pending``, keeping
        submitted == served + shed + pending true even across hard
        failures (and letting a later pump serve them).
        """
        for chunk in reversed(chunks):
            for name, lane in group.lanes.items():
                took = int(chunk.took[lane])
                if took:
                    group._backlog[name].appendleft(
                        np.asarray(chunk.spikes[lane, :took])
                    )

    def _step(self, group: TenantGroup, spikes, mask):
        """One batched masked step (the unit a retry replays)."""
        if self.chaos is not None:
            self.chaos.on_execute(self._round)
        kw = {}
        if group.session.fault is not None and group.session.fault.perturbs_spikes:
            kw["fault_tick0"] = group.fault_tick0()
        return group.session.run_batched(
            spikes, mask=mask, stats0=group.lane_stats(), **kw
        )

    def _execute(self, group: TenantGroup, chunks: list) -> int:
        """Step one group through its chunks with double-buffered transfer.

        Chunk t+1's `jax.device_put` is issued after chunk t's batched
        step is dispatched but before its results are blocked on, so the
        host->device copy overlaps device compute; on accelerators the
        masked jit additionally donates the spike/accumulator buffers.

        Fault handling: every transfer and step runs under
        `_with_retries`; the group accumulator commits only *after* a
        successful step (a replayed chunk can never double-count), and on
        `RetriesExhaustedError` the unserved chunks are restaged before
        the error propagates.
        """
        if not chunks:
            return 0
        ticks_done = 0
        try:
            staged = self._with_retries("transfer", lambda: self._transfer(chunks[0]))
        except RetriesExhaustedError:
            self._restage(group, chunks)
            raise
        for i, chunk in enumerate(chunks):
            spikes, mask = staged
            t0 = self.clock()
            transfer_err = None
            with obs_trace.span("serve.step", lanes=len(group.lanes)):
                try:
                    currents, acc = self._with_retries(
                        "execute", lambda: self._step(group, spikes, mask)
                    )
                except RetriesExhaustedError:
                    self._restage(group, chunks[i:])
                    raise
                if i + 1 < len(chunks):
                    try:
                        staged = self._with_retries(
                            "transfer", lambda: self._transfer(chunks[i + 1])
                        )
                    except RetriesExhaustedError as e:
                        transfer_err = e
                jax.block_until_ready((currents, acc))
            wall_s = self.clock() - t0
            group._acc = acc
            group.advance_fault_ticks(self.flush_ticks)
            self.watchdog.observe(wall_s)
            self._record(group, chunk, currents, acc, wall_s)
            ticks_done += int(chunk.took.sum())
            if transfer_err is not None:
                # chunk i is fully recorded; only i+1.. go back to pending
                self._restage(group, chunks[i + 1 :])
                raise transfer_err
        return ticks_done

    def _transfer(self, chunk: _Chunk):
        if self.chaos is not None:
            self.chaos.on_transfer(self._round)
        with obs_trace.span("serve.device_transfer"):
            return jax.device_put((chunk.spikes, chunk.mask))

    # ---- metrics ----------------------------------------------------------

    def _record(self, group, chunk: _Chunk, currents, acc, wall_s: float) -> None:
        tick_ms = wall_s * 1e3 / self.flush_ticks
        fleet_events = 0.0
        events_now = np.asarray(acc.events)
        for name, lane in group.lanes.items():
            took = int(chunk.took[lane])
            if took == 0:
                continue
            self._served[name] += took
            delta = float(events_now[lane]) - self._events_seen[name]
            self._events_seen[name] = float(events_now[lane])
            fleet_events += delta
            self.registry.counter(f"tenant.{name}.events").inc(delta)
            self.registry.histogram(f"tenant.{name}.tick_ms").add(tick_ms)
            if name not in self._faulted_this_round:
                # a lane that faulted *this* round doesn't get recovery
                # credit for also serving in it - its streak must survive
                # a clean round first
                self.health.record_success(name)
            if self.keep_currents:
                self._currents[name].append(np.asarray(currents[lane, :took]))
        self.registry.counter("serve.flushes").inc()
        self.registry.counter("serve.ticks").inc(int(chunk.took.sum()))
        self._busy_s += wall_s
        self._ticks += int(chunk.took.sum())
        self._events += fleet_events

    def reset_metrics(self) -> None:
        """Zero served-work counters/histograms (warmup-then-measure).

        Benchmarks warm the jit caches with a throwaway round, then reset
        so compile time never lands in the latency percentiles.  The
        per-lane device accumulators are NOT reset - they carry the
        bit-identity contract - only the host-side bookkeeping is.
        Accounting columns (submitted/shed) reset together with served,
        so the closure identity restarts from zero; reset with pending
        work still queued and it will read as over-served until drained.
        """
        self.registry.counters.clear()
        self.registry.histograms.clear()
        for name in self._served:
            self._served[name] = 0
            self._submitted[name] = 0
            self._shed[name] = 0
            self._currents[name].clear()
        self._shed_log.clear()
        self._busy_s = 0.0
        self._ticks = 0
        self._events = 0.0

    def queue_depth(self) -> int:
        """Requests currently queued across all groups."""
        return sum(g.queue.depth() for g in self.groups.values())

    def ticks_served(self, tenant: str | None = None) -> int:
        """Ticks served for ``tenant``, or live (fabric) ticks fleet-wide."""
        if tenant is not None:
            return self._served[tenant]
        return self._ticks

    def ticks_submitted(self, tenant: str | None = None) -> int:
        """Ticks submitted by ``tenant``, or summed across all tenants."""
        if tenant is not None:
            return self._submitted[tenant]
        return sum(self._submitted.values())

    def ticks_shed(self, tenant: str | None = None) -> int:
        """Ticks shed (deadline-expired) for ``tenant``, or fleet total."""
        if tenant is not None:
            return self._shed.get(tenant, 0)
        return sum(self._shed.values())

    def shed_errors(self) -> list:
        """The typed `DeadlineExceededError`s of recent sheds (bounded)."""
        return list(self._shed_log)

    def lane_health(self, tenant: str) -> str:
        """The tenant's health state (``healthy``/``degraded``/``quarantined``)."""
        self._group_of(tenant)  # raise the canonical unknown-tenant error
        return self.health.state(tenant).value

    def accounting(self) -> dict:
        """Per-tenant work ledger and whether it closes exactly.

        For every tenant, ``submitted == served + shed + pending`` must
        hold at any quiescent point - through retries, quarantines, and
        sheds.  The chaos soak asserts ``closes`` after every drain.
        """
        per: dict = {}
        for group in self.groups.values():
            queued = group.queue.pending_by_tenant()
            for name in group.lanes:
                pending = queued.get(name, 0) + group.backlog_ticks_of(name)
                per[name] = {
                    "submitted": self._submitted[name],
                    "served": self._served[name],
                    "shed": self._shed.get(name, 0),
                    "pending": int(pending),
                }
        closes = all(
            v["submitted"] == v["served"] + v["shed"] + v["pending"]
            for v in per.values()
        )
        return {"tenants": per, "closes": closes}

    def events_per_sec(self) -> float:
        """Sustained routed events/sec over engine step wall clock."""
        return self._events / max(self._busy_s, 1e-12)

    def currents(self, tenant: str) -> np.ndarray:
        """(ticks_served, cores, neurons_per_core) currents (keep_currents)."""
        if not self.keep_currents:
            raise ValueError("construct ServeEngine(keep_currents=True) to retain currents")
        cfg = self._group_of(tenant).config
        chunks = self._currents[tenant]
        if not chunks:
            return np.zeros((0, cfg.cores, cfg.neurons_per_core), np.float32)
        return np.concatenate(chunks, axis=0)

    def tenant_stats(self, tenant: str) -> StepStats:
        """Cumulative `StepStats` for one tenant (scalar leaves)."""
        group = self._group_of(tenant)
        lane = group.lanes[tenant]
        return jax.tree.map(lambda x: np.asarray(x)[lane], group.lane_stats())

    def _fault_summary(self) -> dict:
        """Non-zero fault/degradation counters, report-shaped."""
        names = {
            "injected": "serve.faults",
            "retries": "serve.retries",
            "retries_exhausted": "serve.retries_exhausted",
            "retry_recoveries": "serve.retry_recoveries",
            "shed_requests": "serve.shed",
            "shed_ticks": "serve.shed_ticks",
            "degraded": "serve.degraded",
            "quarantines": "serve.quarantines",
            "probes": "serve.probes",
            "recoveries": "serve.recoveries",
            "stragglers": "serve.stragglers",
        }
        out = {}
        for label, counter in names.items():
            c = self.registry.counters.get(counter)
            if c is not None and c.value:
                out[label] = int(c.value)
        if self.chaos is not None:
            for kind, n in sorted(self.chaos.injected.items()):
                out[f"chaos_{kind}"] = int(n)
        return out

    def serve_report(self) -> list:
        """Per-tenant records plus one fleet record, report-CLI shaped.

        Tenant records carry ``stats_per_tick`` (so ``python -m
        repro.obs.report`` renders the per-tier breakdown per tenant) and
        tick-latency percentiles; the fleet record merges every tenant's
        latency histogram (`Histogram.merge`), reports sustained
        ``events_per_sec``, and - when any fault machinery fired - a
        ``faults`` counter dict plus recovery-time percentiles.
        """
        records = []
        fleet_hist = None
        for name in sorted(self._tenant_group):
            group = self._tenant_group[name]
            spec = group.specs[name]
            served = self._served[name]
            rec = {
                "tenant": name,
                "scenario": spec.scenario,
                "cores": group.config.cores,
                "neurons_per_core": group.config.neurons_per_core,
                "ticks": served,
                "submitted": self._submitted[name],
                "shed_ticks": self._shed.get(name, 0),
                "health": self.health.state(name).value,
                "events": self._events_seen[name],
                "queue_depth": group.queue.depth(),
            }
            if spec.fault is not None:
                rec["fault"] = spec.fault.describe()
            hist = self.registry.histograms.get(f"tenant.{name}.tick_ms")
            if hist is not None and hist.count:
                summary = hist.summary()
                rec.update(
                    tick_ms_p50=summary["p50"],
                    tick_ms_p95=summary["p95"],
                    tick_ms_p99=summary["p99"],
                )
                fleet_hist = hist if fleet_hist is None else fleet_hist.merge(hist)
            if served:
                stats = self.tenant_stats(name)._asdict()
                rec["stats_per_tick"] = {k: float(v) / served for k, v in stats.items()}
            records.append(rec)
        fleet = {
            "tenant": "__fleet__",
            "tenants": len(self._tenant_group),
            "groups": len(self.groups),
            "ticks": self._ticks,
            "events": self._events,
            "events_per_sec": self.events_per_sec(),
            "busy_s": self._busy_s,
        }
        if fleet_hist is not None and fleet_hist.count:
            summary = fleet_hist.summary()
            fleet.update(
                tick_ms_p50=summary["p50"],
                tick_ms_p95=summary["p95"],
                tick_ms_p99=summary["p99"],
            )
        faults = self._fault_summary()
        if faults:
            fleet["faults"] = faults
        recovery = self.registry.histograms.get("serve.recovery_ms")
        if recovery is not None and recovery.count:
            summary = recovery.summary()
            fleet.update(
                recovery_ms_p50=summary["p50"],
                recovery_ms_p99=summary["p99"],
            )
        records.append(fleet)
        return records

    def emit_report(self) -> list:
        """`serve_report()`, appended to the JSONL sink when one is set."""
        records = self.serve_report()
        if self.sink is not None:
            for rec in records:
                self.sink.write(rec)
        return records


def group_key(spec: TenantSpec) -> tuple:
    """Public alias of the tenant session-compatibility key."""
    return _compat_key(spec)
