"""Per-lane health tracking and retry policy for the serving tier.

Graceful degradation, not crash: when a tenant's lane faults (a
`ChaosInjector` lane event, or any caller-reported lane failure) the lane
walks a three-state machine —

    healthy ──fault──▶ degraded ──N consecutive faults──▶ quarantined
       ▲                  │  ▲                                │
       └───M successes────┘  └──────cooldown expires──────────┘

A *quarantined* lane is masked out of the shared ``run_batched`` (its
chunk rows are all-padding, so shapes — and the jit cache — never
change); its backlog is retained and served once the lane recovers, so
per-tenant accounting still closes exactly.  After ``quarantine_rounds``
pumps the lane re-enters *degraded* on probation; the next successful
flush takes it back to *healthy* and records the episode's recovery time.

`RetryPolicy` bounds the engine's transient-fault retries (exponential
backoff, injectable sleep).  Both integrate with `repro.obs.metrics`:
quarantine/recovery counters and a ``serve.recovery_ms`` histogram land
in the engine's registry and render through ``repro.obs.report``.
"""

from __future__ import annotations

import dataclasses
import enum
import time
from typing import Callable

from repro.obs import metrics as obs_metrics


class LaneState(str, enum.Enum):
    HEALTHY = "healthy"
    DEGRADED = "degraded"
    QUARANTINED = "quarantined"


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff for transient transfer/execute faults.

    max_retries:    retries after the first failure (0 = fail fast).
    backoff_base_s: sleep before the first retry.
    backoff_factor: multiplier applied per subsequent retry.
    """

    max_retries: int = 3
    backoff_base_s: float = 0.01
    backoff_factor: float = 2.0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_base_s < 0:
            raise ValueError(f"backoff_base_s must be >= 0, got {self.backoff_base_s}")
        if self.backoff_factor < 1.0:
            raise ValueError(f"backoff_factor must be >= 1, got {self.backoff_factor}")


@dataclasses.dataclass(frozen=True)
class HealthPolicy:
    """Thresholds of the lane health state machine.

    quarantine_after:  consecutive lane faults before quarantine.
    quarantine_rounds: pumps a quarantined lane sits out before probing.
    recover_after:     consecutive successful flushes (from degraded)
                       before the lane is healthy again.
    """

    quarantine_after: int = 3
    quarantine_rounds: int = 2
    recover_after: int = 1

    def __post_init__(self):
        for field in dataclasses.fields(self):
            if getattr(self, field.name) < 1:
                raise ValueError(
                    f"{field.name} must be >= 1, got {getattr(self, field.name)}"
                )


class HealthTracker:
    """The lane health state machine over every registered tenant."""

    def __init__(
        self,
        policy: HealthPolicy | None = None,
        registry: obs_metrics.MetricsRegistry | None = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.policy = policy or HealthPolicy()
        self.registry = registry or obs_metrics.MetricsRegistry()
        self.clock = clock
        self._states: dict = {}  # name -> LaneState
        self._fails: dict = {}  # name -> consecutive faults
        self._successes: dict = {}  # name -> consecutive successes (degraded)
        self._cooldown: dict = {}  # name -> pumps left in quarantine
        self._failed_at: dict = {}  # name -> episode start timestamp

    def add(self, name: str) -> None:
        self._states.setdefault(name, LaneState.HEALTHY)
        self._fails.setdefault(name, 0)
        self._successes.setdefault(name, 0)

    def remove(self, name: str) -> None:
        """Forget a deregistered lane (autoscale shrink path)."""
        for table in (self._states, self._fails, self._successes,
                      self._cooldown, self._failed_at):
            table.pop(name, None)

    def state(self, name: str) -> LaneState:
        return self._states[name]

    def usable(self, name: str) -> bool:
        """False while the lane must be masked out of the batched step."""
        return self._states[name] is not LaneState.QUARANTINED

    def quarantined(self) -> set:
        return {n for n, s in self._states.items() if s is LaneState.QUARANTINED}

    def snapshot(self) -> dict:
        """name -> state value, report-shaped."""
        return {n: s.value for n, s in sorted(self._states.items())}

    # ---- transitions ------------------------------------------------------

    def record_failure(self, name: str) -> LaneState:
        """One lane fault; returns the (possibly new) state."""
        self.add(name)
        self._fails[name] += 1
        self._successes[name] = 0
        if name not in self._failed_at:
            self._failed_at[name] = self.clock()
        state = self._states[name]
        if state is LaneState.HEALTHY:
            state = LaneState.DEGRADED
            self.registry.counter("serve.degraded").inc()
        if (
            state is LaneState.DEGRADED
            and self._fails[name] >= self.policy.quarantine_after
        ):
            state = LaneState.QUARANTINED
            self._cooldown[name] = self.policy.quarantine_rounds
            self.registry.counter("serve.quarantines").inc()
        self._states[name] = state
        return state

    def record_success(self, name: str) -> LaneState:
        """One successful served flush; may close a recovery episode."""
        self.add(name)
        state = self._states[name]
        if state is LaneState.QUARANTINED:
            return state  # masked lanes cannot really serve; ignore
        if state is LaneState.DEGRADED:
            self._successes[name] += 1
            if self._successes[name] >= self.policy.recover_after:
                state = LaneState.HEALTHY
                self._fails[name] = 0
                self._successes[name] = 0
                started = self._failed_at.pop(name, None)
                self.registry.counter("serve.recoveries").inc()
                if started is not None:
                    self.registry.histogram("serve.recovery_ms").add(
                        max(self.clock() - started, 0.0) * 1e3
                    )
        self._states[name] = state
        return state

    def advance(self) -> None:
        """One pump elapsed: age quarantine cooldowns; expired lanes probe."""
        for name in list(self._cooldown):
            self._cooldown[name] -= 1
            if self._cooldown[name] <= 0:
                del self._cooldown[name]
                self._states[name] = LaneState.DEGRADED
                self._fails[name] = 0
                self._successes[name] = 0
                self.registry.counter("serve.probes").inc()
