"""Admission control: which tenants may enter, and onto which session.

The engine's capacity axes are *groups* (each group owns one precompiled
`InterfaceSession` - compile time and device tables) and *lanes* (the
vmapped tenant axis of that session's batched step - device memory and
per-flush compute).  `AdmissionController` enforces both, plus a
per-request frame bound so one tenant cannot monopolize a flush.

Rejections raise `AdmissionError` with the exhausted axis spelled out;
the engine surfaces them unchanged at `register`/`submit` time, before
any device work happens.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.serve.tenant import TenantSpec, compat_key


class AdmissionError(RuntimeError):
    """A tenant or request exceeds the configured serving capacity."""


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Static capacity limits of one engine.

    max_tenants_per_group:  lanes per shared session (the vmapped batch
                            axis; lane count changes recompile the group).
    max_groups:             distinct (config, connectivity) sessions the
                            engine will precompile.
    max_frames_per_request: largest single `submit` chunk, in tick frames.
    """

    max_tenants_per_group: int = 32
    max_groups: int = 4
    max_frames_per_request: int = 4096

    def __post_init__(self):
        for field in dataclasses.fields(self):
            if getattr(self, field.name) < 1:
                raise ValueError(f"{field.name} must be >= 1, got {getattr(self, field.name)}")


class AdmissionController:
    """Stateless checks over the engine's group occupancy."""

    def __init__(self, policy: AdmissionPolicy | None = None):
        self.policy = policy or AdmissionPolicy()

    def admit(self, spec: TenantSpec, occupancy: Mapping[tuple, int]) -> tuple:
        """Validate `spec` against current occupancy; return its group key.

        occupancy: group key -> current tenant count.  Raises
        `AdmissionError` when the target group is full, or when the spec
        needs a new group and the group budget is spent.
        """
        key = compat_key(spec)
        if key in occupancy:
            if occupancy[key] >= self.policy.max_tenants_per_group:
                raise AdmissionError(
                    f"tenant {spec.name!r} rejected: group for {spec.scenario!r}-compatible "
                    f"config is at capacity ({self.policy.max_tenants_per_group} lanes)"
                )
        elif len(occupancy) >= self.policy.max_groups:
            raise AdmissionError(
                f"tenant {spec.name!r} rejected: would need a new session group but the "
                f"engine already serves {len(occupancy)} "
                f"(max_groups={self.policy.max_groups}); reuse an existing "
                f"(config, connectivity_seed) to share a session"
            )
        return key

    def validate_request(self, tenant: str, ticks: int) -> None:
        """Bound one submit chunk (called before the queue accepts it)."""
        if ticks > self.policy.max_frames_per_request:
            raise AdmissionError(
                f"tenant {tenant!r} submitted {ticks} tick frames in one request "
                f"(max_frames_per_request={self.policy.max_frames_per_request}); "
                f"split the stream into smaller chunks"
            )
