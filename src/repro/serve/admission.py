"""Admission control: which tenants may enter, and onto which session.

The engine's capacity axes are *groups* (each group owns one precompiled
`InterfaceSession` - compile time and device tables) and *lanes* (the
vmapped tenant axis of that session's batched step - device memory and
per-flush compute).  `AdmissionController` enforces both, plus a
per-request frame bound so one tenant cannot monopolize a flush, an
optional per-group pending-frame bound (queue overflow backpressure), and
an optional request deadline past which the engine sheds queued work.

Everything here raises *typed* errors before any device work happens:

    ServeError (RuntimeError)
    ├── AdmissionError            capacity exceeded at register/submit
    │   ├── QueueOverflowError    per-group pending-frame bound hit
    │   ├── DeadlineExceededError queued request aged past the shed
    │   │                         deadline (raised per shed, surfaced via
    │   │                         `ServeEngine.shed_errors()`)
    │   └── RateLimitedError      the tenant's token bucket is empty
    │                             (per-tenant ingress rate bound)
    └── FrameValidationError      malformed frames (also a ValueError,
                                  so legacy shape-mismatch handlers keep
                                  working)

Rate limiting (serving tier v2): ``AdmissionPolicy.rate_limit_per_s``
bounds each tenant's *sustained* ingress in tick frames per second via a
classic token bucket - the bucket refills continuously at the rate and
caps at ``rate_limit_burst`` tokens, so short bursts up to the burst size are
admitted instantly while the long-run average can never exceed the rate.
An empty bucket raises `RateLimitedError` *before* anything is queued, so
rate-limited work never enters the accounting identity.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Mapping

import numpy as np

from repro.serve.tenant import TenantSpec, compat_key


class ServeError(RuntimeError):
    """Base of every typed serving-tier error."""


class AdmissionError(ServeError):
    """A tenant or request exceeds the configured serving capacity."""


class QueueOverflowError(AdmissionError):
    """A group's pending-frame bound is exhausted (backpressure signal)."""


class DeadlineExceededError(AdmissionError):
    """A queued request aged past the shed deadline and was dropped."""


class RateLimitedError(AdmissionError):
    """The tenant's token bucket is empty (ingress rate bound hit)."""


class FrameValidationError(ServeError, ValueError):
    """Submitted frames are malformed (shape/dtype/non-finite values)."""


def validate_frames(frames, shape: tuple | None = None, tenant: str = "?") -> np.ndarray:
    """Validate one submitted frame chunk before any device work.

    Rejects wrong rank, empty streams, wrong (cores, neurons) shape when
    ``shape`` is known, non-numeric dtypes, and non-finite float values
    (a NaN silently casts to True under ``astype(bool)``, which would
    poison the fabric inside the jitted step where nothing can diagnose
    it).  Returns the frames as a host bool array.
    """
    arr = np.asarray(frames)
    if arr.dtype.kind not in "biuf":
        raise FrameValidationError(
            f"tenant {tenant!r}: frames dtype {arr.dtype} is not a bool/int/float "
            f"spike raster"
        )
    if arr.ndim != 3 or arr.shape[0] < 1:
        raise FrameValidationError(
            f"frames must be (ticks >= 1, cores, neurons_per_core), got shape {arr.shape}"
        )
    if shape is not None and arr.shape[1:] != tuple(shape):
        raise FrameValidationError(
            f"tenant {tenant!r}: frames shaped {arr.shape} do not match the group "
            f"fabric (ticks, {shape[0]}, {shape[1]})"
        )
    if arr.dtype.kind == "f" and not np.isfinite(arr).all():
        raise FrameValidationError(
            f"tenant {tenant!r}: frames contain non-finite values (NaN/Inf); "
            f"a NaN casts to True and would silently poison the fabric"
        )
    return arr.astype(bool)


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Static capacity limits of one engine.

    max_tenants_per_group:  lanes per shared session (the vmapped batch
                            axis; lane count changes recompile the group).
    max_groups:             distinct (config, connectivity) sessions the
                            engine will precompile.
    max_frames_per_request: largest single `submit` chunk, in tick frames.
    max_pending_frames:     per-group bound on queued + backlogged tick
                            frames; `submit` raises `QueueOverflowError`
                            beyond it (None = unbounded, the legacy
                            behavior).
    shed_deadline_s:        max age of a queued request at flush time;
                            older requests are shed with
                            `DeadlineExceededError` instead of served
                            (None = never shed).
    rate_limit_per_s:       per-tenant sustained ingress bound in tick
                            frames per second; an empty token bucket
                            raises `RateLimitedError` at submit (None =
                            unlimited).
    rate_limit_burst:       token-bucket capacity - the largest burst a
                            full bucket admits at once (defaults to one
                            second's worth, i.e. ``rate_limit_per_s``).
    """

    max_tenants_per_group: int = 32
    max_groups: int = 4
    max_frames_per_request: int = 4096
    max_pending_frames: int | None = None
    shed_deadline_s: float | None = None
    rate_limit_per_s: float | None = None
    rate_limit_burst: float | None = None

    def __post_init__(self):
        for name in ("max_tenants_per_group", "max_groups", "max_frames_per_request"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name} must be >= 1, got {getattr(self, name)}")
        if self.max_pending_frames is not None and self.max_pending_frames < 1:
            raise ValueError(
                f"max_pending_frames must be >= 1 or None, got {self.max_pending_frames}"
            )
        if self.shed_deadline_s is not None and self.shed_deadline_s < 0:
            raise ValueError(
                f"shed_deadline_s must be >= 0 or None, got {self.shed_deadline_s}"
            )
        if self.rate_limit_per_s is not None and self.rate_limit_per_s <= 0:
            raise ValueError(
                f"rate_limit_per_s must be > 0 or None, got {self.rate_limit_per_s}"
            )
        if self.rate_limit_burst is not None:
            if self.rate_limit_per_s is None:
                raise ValueError("rate_limit_burst is only meaningful with rate_limit_per_s")
            if self.rate_limit_burst < 1:
                raise ValueError(
                    f"rate_limit_burst must be >= 1 or None, got {self.rate_limit_burst}"
                )

    @property
    def burst(self) -> float | None:
        """Effective bucket capacity (burst, or one second's worth)."""
        if self.rate_limit_per_s is None:
            return None
        return self.rate_limit_burst or self.rate_limit_per_s


class TokenBucket:
    """One tenant's ingress token bucket (thread-safe).

    Starts full; refills continuously at ``rate`` tokens/sec up to
    ``capacity``.  `take` is all-or-nothing: a request either fits the
    current balance or is rejected whole - partial admission would split
    a validated frame stream.
    """

    def __init__(self, rate: float, capacity: float, clock: Callable[[], float] = time.monotonic):
        if rate <= 0 or capacity <= 0:
            raise ValueError(f"need rate > 0 and capacity > 0, got {rate}, {capacity}")
        self.rate = float(rate)
        self.capacity = float(capacity)
        self.clock = clock
        self._tokens = self.capacity
        self._last = clock()
        self._lock = threading.Lock()

    def _refill_locked(self, now: float) -> None:
        elapsed = max(now - self._last, 0.0)
        self._tokens = min(self.capacity, self._tokens + elapsed * self.rate)
        self._last = now

    def take(self, n: float) -> bool:
        """Admit ``n`` tokens if the refilled balance covers them."""
        with self._lock:
            self._refill_locked(self.clock())
            if n > self._tokens:
                return False
            self._tokens -= n
            return True

    def tokens(self) -> float:
        """Current (refilled) balance - diagnostics only."""
        with self._lock:
            self._refill_locked(self.clock())
            return self._tokens


class AdmissionController:
    """Capacity checks over the engine's group occupancy, plus the
    per-tenant rate-limit buckets (the only stateful part, and only when
    the policy sets ``rate_limit_per_s``)."""

    def __init__(
        self,
        policy: AdmissionPolicy | None = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.policy = policy or AdmissionPolicy()
        self.clock = clock
        self._buckets: dict = {}  # tenant name -> TokenBucket
        self._buckets_lock = threading.Lock()

    def rate_bucket(self, tenant: str) -> TokenBucket | None:
        """The tenant's token bucket (created full on first use), or None
        when the policy sets no rate limit."""
        if self.policy.rate_limit_per_s is None:
            return None
        with self._buckets_lock:
            if tenant not in self._buckets:
                self._buckets[tenant] = TokenBucket(
                    self.policy.rate_limit_per_s, self.policy.burst, clock=self.clock
                )
            return self._buckets[tenant]

    def check_rate(self, tenant: str, ticks: int) -> None:
        """Charge ``ticks`` against the tenant's bucket; typed rejection.

        Raises `RateLimitedError` when the bucket cannot cover the
        request - *before* anything is queued, so rate-limited work never
        enters the accounting ledger.
        """
        bucket = self.rate_bucket(tenant)
        if bucket is None or bucket.take(ticks):
            return
        if ticks > bucket.capacity:
            raise RateLimitedError(
                f"tenant {tenant!r} submitted {ticks} tick frames but the rate-limit "
                f"burst is {bucket.capacity:g}; a request larger than the burst can "
                f"never be admitted - split the stream or raise rate_limit_burst"
            )
        raise RateLimitedError(
            f"tenant {tenant!r} rate-limited: {ticks} tick frames exceed the current "
            f"token balance ({bucket.tokens():.1f} of {bucket.capacity:g}; refill "
            f"{bucket.rate:g}/s) - back off and retry"
        )

    def admit(self, spec: TenantSpec, occupancy: Mapping[tuple, int]) -> tuple:
        """Validate `spec` against current occupancy; return its group key.

        occupancy: group key -> current tenant count.  Raises
        `AdmissionError` when the target group is full, or when the spec
        needs a new group and the group budget is spent.
        """
        key = compat_key(spec)
        if key in occupancy:
            if occupancy[key] >= self.policy.max_tenants_per_group:
                raise AdmissionError(
                    f"tenant {spec.name!r} rejected: group for {spec.scenario!r}-compatible "
                    f"config is at capacity ({self.policy.max_tenants_per_group} lanes)"
                )
        elif len(occupancy) >= self.policy.max_groups:
            raise AdmissionError(
                f"tenant {spec.name!r} rejected: would need a new session group but the "
                f"engine already serves {len(occupancy)} "
                f"(max_groups={self.policy.max_groups}); reuse an existing "
                f"(config, connectivity_seed) to share a session"
            )
        return key

    def validate_request(self, tenant: str, ticks: int, pending_frames: int | None = None) -> None:
        """Bound one submit chunk (called before the queue accepts it).

        pending_frames: the target group's queued + backlogged tick
        frames; when given and `max_pending_frames` is set, a request
        that would overflow the bound raises `QueueOverflowError`.
        """
        if ticks > self.policy.max_frames_per_request:
            raise AdmissionError(
                f"tenant {tenant!r} submitted {ticks} tick frames in one request "
                f"(max_frames_per_request={self.policy.max_frames_per_request}); "
                f"split the stream into smaller chunks"
            )
        cap = self.policy.max_pending_frames
        if cap is not None and pending_frames is not None and pending_frames + ticks > cap:
            raise QueueOverflowError(
                f"tenant {tenant!r} rejected: group already holds {pending_frames} "
                f"pending tick frames and {ticks} more would exceed "
                f"max_pending_frames={cap}; pump the engine (or wait) and retry"
            )
