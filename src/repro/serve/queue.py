"""Async ingest queue with size- or deadline-triggered micro-batching.

Tenants push tick frames (`submit`) from any thread; the engine polls
(`poll`) and receives either nothing - the batch is still filling and the
oldest request is inside its latency deadline - or every queued request at
once (a *flush*).  Two triggers end the filling phase:

  * **size**: at least ``flush_frames`` total tick frames are queued
    (enough work to fill the jitted batch), or
  * **deadline**: the oldest queued request has waited
    ``flush_deadline_s`` (tail-latency bound under trickle load).

``flush_deadline_s=0`` makes any non-empty queue ready - the synchronous
mode benchmarks use.  The clock is injectable so tests can drive the
deadline deterministically.

Robustness (PR 8): `submit` validates frames up front - wrong
rank/shape, non-numeric dtype, and non-finite values raise a typed
`FrameValidationError` (also a ValueError) *before* anything reaches the
device; an optional ``max_pending_frames`` bound raises
`QueueOverflowError` instead of queueing unboundedly under backpressure.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable

from repro.serve.admission import QueueOverflowError, validate_frames


@dataclasses.dataclass(frozen=True)
class TickRequest:
    """One tenant's submitted chunk of tick frames."""

    tenant: str
    frames: Any  # (T_i, cores, neurons_per_core) bool array
    enqueued_at: float

    @property
    def ticks(self) -> int:
        return int(self.frames.shape[0])


class IngestQueue:
    """Thread-safe FIFO of `TickRequest`s with micro-batch flush triggers."""

    def __init__(
        self,
        flush_frames: int = 64,
        flush_deadline_s: float = 0.005,
        clock: Callable[[], float] = time.monotonic,
        max_pending_frames: int | None = None,
        frame_shape: tuple | None = None,
    ):
        if flush_frames < 1:
            raise ValueError(f"flush_frames must be >= 1, got {flush_frames}")
        if flush_deadline_s < 0:
            raise ValueError(f"flush_deadline_s must be >= 0, got {flush_deadline_s}")
        if max_pending_frames is not None and max_pending_frames < 1:
            raise ValueError(
                f"max_pending_frames must be >= 1 or None, got {max_pending_frames}"
            )
        self.flush_frames = flush_frames
        self.flush_deadline_s = flush_deadline_s
        self.max_pending_frames = max_pending_frames
        self.frame_shape = tuple(frame_shape) if frame_shape is not None else None
        self.clock = clock
        self._lock = threading.Lock()
        self._items: collections.deque = collections.deque()
        self._frames = 0

    def submit(self, tenant: str, frames) -> TickRequest:
        """Enqueue one validated chunk of tick frames for a tenant.

        Raises `FrameValidationError` on malformed frames and
        `QueueOverflowError` when ``max_pending_frames`` would be
        exceeded - both *before* the request is queued or anything
        touches the device.
        """
        frames = validate_frames(frames, shape=self.frame_shape, tenant=tenant)
        req = TickRequest(tenant=tenant, frames=frames, enqueued_at=self.clock())
        with self._lock:
            if (
                self.max_pending_frames is not None
                and self._frames + req.ticks > self.max_pending_frames
            ):
                raise QueueOverflowError(
                    f"tenant {tenant!r} rejected: queue holds {self._frames} pending "
                    f"tick frames and {req.ticks} more would exceed "
                    f"max_pending_frames={self.max_pending_frames}"
                )
            self._items.append(req)
            self._frames += req.ticks
        return req

    def pending_by_tenant(self) -> dict:
        """tenant -> queued tick frames (accounting-closure bookkeeping)."""
        with self._lock:
            out: dict = {}
            for req in self._items:
                out[req.tenant] = out.get(req.tenant, 0) + req.ticks
            return out

    def depth(self) -> int:
        """Queued requests (the queue-depth metric the engine samples)."""
        with self._lock:
            return len(self._items)

    def pending_frames(self) -> int:
        """Total queued tick frames across all requests."""
        with self._lock:
            return self._frames

    def ready(self) -> bool:
        """True when a flush trigger (size or deadline) has fired."""
        with self._lock:
            return self._ready_locked()

    def _ready_locked(self) -> bool:
        if not self._items:
            return False
        if self._frames >= self.flush_frames:
            return True
        return self.clock() - self._items[0].enqueued_at >= self.flush_deadline_s

    def poll(self, force: bool = False) -> list:
        """All queued requests if a trigger fired (or ``force``), else []."""
        with self._lock:
            if not self._items or not (force or self._ready_locked()):
                return []
            out = list(self._items)
            self._items.clear()
            self._frames = 0
            return out

    def drain(self) -> list:
        """Unconditionally flush everything queued."""
        return self.poll(force=True)
