"""Async ingest queue with size- or deadline-triggered micro-batching.

Tenants push tick frames (`submit`) from any thread; the engine polls
(`poll`) and receives either nothing - the batch is still filling and the
oldest request is inside its latency deadline - or every queued request at
once (a *flush*).  Two triggers end the filling phase:

  * **size**: at least ``flush_frames`` total tick frames are queued
    (enough work to fill the jitted batch), or
  * **deadline**: the oldest queued request has waited
    ``flush_deadline_s`` (tail-latency bound under trickle load).

``flush_deadline_s=0`` makes any non-empty queue ready - the synchronous
mode benchmarks use.  The clock is injectable so tests can drive the
deadline deterministically.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable


@dataclasses.dataclass(frozen=True)
class TickRequest:
    """One tenant's submitted chunk of tick frames."""

    tenant: str
    frames: Any  # (T_i, cores, neurons_per_core) bool array
    enqueued_at: float

    @property
    def ticks(self) -> int:
        return int(self.frames.shape[0])


class IngestQueue:
    """Thread-safe FIFO of `TickRequest`s with micro-batch flush triggers."""

    def __init__(
        self,
        flush_frames: int = 64,
        flush_deadline_s: float = 0.005,
        clock: Callable[[], float] = time.monotonic,
    ):
        if flush_frames < 1:
            raise ValueError(f"flush_frames must be >= 1, got {flush_frames}")
        if flush_deadline_s < 0:
            raise ValueError(f"flush_deadline_s must be >= 0, got {flush_deadline_s}")
        self.flush_frames = flush_frames
        self.flush_deadline_s = flush_deadline_s
        self.clock = clock
        self._lock = threading.Lock()
        self._items: collections.deque = collections.deque()
        self._frames = 0

    def submit(self, tenant: str, frames) -> TickRequest:
        """Enqueue one chunk of tick frames for a tenant."""
        if frames.ndim != 3 or frames.shape[0] < 1:
            raise ValueError(
                f"frames must be (ticks >= 1, cores, neurons_per_core), got shape {frames.shape}"
            )
        req = TickRequest(tenant=tenant, frames=frames, enqueued_at=self.clock())
        with self._lock:
            self._items.append(req)
            self._frames += req.ticks
        return req

    def depth(self) -> int:
        """Queued requests (the queue-depth metric the engine samples)."""
        with self._lock:
            return len(self._items)

    def pending_frames(self) -> int:
        """Total queued tick frames across all requests."""
        with self._lock:
            return self._frames

    def ready(self) -> bool:
        """True when a flush trigger (size or deadline) has fired."""
        with self._lock:
            return self._ready_locked()

    def _ready_locked(self) -> bool:
        if not self._items:
            return False
        if self._frames >= self.flush_frames:
            return True
        return self.clock() - self._items[0].enqueued_at >= self.flush_deadline_s

    def poll(self, force: bool = False) -> list:
        """All queued requests if a trigger fired (or ``force``), else []."""
        with self._lock:
            if not self._items or not (force or self._ready_locked()):
                return []
            out = list(self._items)
            self._items.clear()
            self._frames = 0
            return out

    def drain(self) -> list:
        """Unconditionally flush everything queued."""
        return self.poll(force=True)
