"""Serving engine: prefill + decode steps and a batched request loop.

`make_prefill_step` / `make_decode_step` build the jit-able step functions
lowered by the dry-run (`decode_32k` / `long_500k` cells lower
`decode_step`, i.e. one new token against a seq_len cache).

`ServeEngine` is the runnable single-host reference loop used by
examples/serve_lm.py: batches requests, prefills each, then decodes all
lanes in lock-step with per-lane stop handling - the minimal continuous-
batching pattern.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.blocks import LOCAL, ShardCtx
from repro.models.config import ModelConfig


def make_prefill_step(cfg: ModelConfig, ctx: ShardCtx = LOCAL,
                      remat: bool = True):
    def prefill_step(params, batch, cache):
        out = lm.forward(params, batch, cfg, mode="prefill", cache=cache,
                         ctx=ctx, remat=remat)
        # next-token logits from the last position
        return out["logits"][:, -1], out["cache"]
    return prefill_step


def make_decode_step(cfg: ModelConfig, ctx: ShardCtx = LOCAL):
    def decode_step(params, cache, tokens, cache_len):
        """tokens (B, 1) -> (logits (B, V), new cache)."""
        out = lm.forward(params, {"tokens": tokens}, cfg, mode="decode",
                         cache=cache, cache_len=cache_len, ctx=ctx,
                         remat=False)
        return out["logits"][:, -1], out["cache"]
    return decode_step


@dataclasses.dataclass
class ServeEngine:
    """Minimal batched-serving loop (single host, greedy or sampled)."""

    cfg: ModelConfig
    params: dict
    max_len: int = 256
    temperature: float = 0.0

    def __post_init__(self):
        self._prefill = jax.jit(make_prefill_step(self.cfg, remat=False))
        self._decode = jax.jit(make_decode_step(self.cfg))

    def generate(self, prompts: jnp.ndarray, num_steps: int,
                 eos_id: int = -1, key=None):
        """prompts (B, Tp) int32 -> (B, num_steps) generated tokens."""
        b, tp = prompts.shape
        cache = lm.init_cache(self.cfg, b, self.max_len)
        logits, cache = self._prefill(self.params, {"tokens": prompts}, cache)
        cache_len = jnp.int32(tp)
        toks = []
        done = jnp.zeros((b,), bool)
        for i in range(num_steps):
            if self.temperature > 0.0 and key is not None:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits / self.temperature)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            nxt = nxt.astype(jnp.int32)
            nxt = jnp.where(done, 0, nxt)
            done = done | (nxt == eos_id)
            toks.append(nxt)
            logits, cache = self._decode(self.params, cache, nxt[:, None],
                                         cache_len)
            cache_len = cache_len + 1
        return jnp.stack(toks, axis=1)
