"""Per-tier breakdown reporting over benchmark / telemetry records.

    PYTHONPATH=src python -m repro.obs.report BENCH_interface.json
    PYTHONPATH=src python -m repro.obs.report metrics.jsonl --scenario sparse_poisson

The paper's argument is a per-tier PPA accounting exercise - arbiter vs
CAM vs NoC vs inter-chip - so this CLI renders exactly that split.  Input
is either a ``benchmarks/noc_bench.py --json`` payload (records live
under ``"records"``) or a JSONL stream (one record per line, e.g. from
`repro.obs.metrics.JsonlSink`).  Every record carrying a
``stats_per_tick`` dict (the per-tick-mean `StepStats` summary) gets one
table: latency, energy, and traffic per tier, with each tier's share of
the summed latency.  Tick wall-clock percentiles (``tick_ms_p50/p95/p99``,
from the benchmark's streaming histograms) are appended when present.
"""

from __future__ import annotations

import argparse
import json
import sys

# tier -> (latency field, energy field, traffic field, traffic unit)
TIERS = (
    ("arbiter", "encode_latency", "encode_energy", "events", "events"),
    ("cam", "cam_time_ns", "cam_energy", "cam_searches", "searches"),
    ("noc", "noc_latency", "noc_energy", "noc_hops", "hops"),
    ("chip", "chip_latency", "chip_energy", "chip_hops", "hops"),
)


def load_records(path: str) -> list:
    """Records from a noc_bench --json payload or a JSONL stream."""
    with open(path) as f:
        text = f.read()
    try:
        payload = json.loads(text)
    except json.JSONDecodeError:
        payload = None
    if isinstance(payload, dict):
        records = payload.get("records", [])
        meta = {k: v for k, v in payload.items() if k != "records"}
        return [{**meta, **r} for r in records]
    if isinstance(payload, list):
        return payload
    records = []
    for i, line in enumerate(text.splitlines()):
        line = line.strip()
        if not line:
            continue
        try:
            records.append(json.loads(line))
        except json.JSONDecodeError as e:
            raise ValueError(f"{path}:{i + 1}: neither a JSON payload nor JSONL ({e})")
    return records


def tier_rows(stats: dict) -> list:
    """(tier, latency, energy, traffic, unit, latency share) per tier."""
    total_latency = sum(float(stats.get(lat, 0.0)) for _, lat, _, _, _ in TIERS)
    rows = []
    for tier, lat, en, traffic, unit in TIERS:
        latency = float(stats.get(lat, 0.0))
        energy = float(stats.get(en, 0.0))
        volume = float(stats.get(traffic, 0.0))
        share = latency / total_latency if total_latency > 0 else 0.0
        rows.append((tier, latency, energy, volume, unit, share))
    return rows


def _record_title(rec: dict) -> str:
    bits = [str(rec.get("scenario") or rec.get("benchmark") or rec.get("tenant") or "record")]
    if "cores" in rec and "neurons_per_core" in rec:
        bits.append(f"{rec['cores']} cores x {rec['neurons_per_core']} n/core")
    if "cam_entries_per_core" in rec:
        bits.append(f"{rec['cam_entries_per_core']} CAM entries")
    if "ticks" in rec:
        bits.append(f"{rec['ticks']} ticks")
    return " - ".join(bits)


def format_record(rec: dict) -> str:
    lines = [_record_title(rec)]
    stats = rec.get("stats_per_tick")
    if stats:
        lines.append(
            f"  {'tier':>8} {'latency/tick':>14} {'energy/tick':>13} "
            f"{'traffic/tick':>20} {'lat share':>9}"
        )
        for tier, latency, energy, traffic, unit, share in tier_rows(stats):
            lines.append(
                f"  {tier:>8} {latency:>14.2f} {energy:>13.1f} "
                f"{traffic:>12.1f} {unit:>7} {share:>8.1%}"
            )
    else:
        lines.append("  (no stats_per_tick in this record - tier table skipped)")
    pcts = [(k, rec[k]) for k in ("tick_ms_p50", "tick_ms_p95", "tick_ms_p99") if k in rec]
    if pcts:
        wall = "  ".join(f"{k.split('_')[-1]} {v:.3f} ms" for k, v in pcts)
        if "new_tick_ms" in rec:
            wall += f"  (min {rec['new_tick_ms']:.3f} ms)"
        lines.append(f"  tick wall clock: {wall}")
    elif "new_tick_ms" in rec:
        lines.append(f"  tick wall clock: min {rec['new_tick_ms']:.3f} ms")
    faults = rec.get("faults")
    if faults:
        counts = ", ".join(f"{k} {int(v)}" for k, v in sorted(faults.items()))
        lines.append(f"  faults: {counts}")
        rec_pcts = [(k, rec[k]) for k in ("recovery_ms_p50", "recovery_ms_p99") if k in rec]
        if rec_pcts:
            rendered = "  ".join(f"{k.split('_')[-1]} {v:.3f} ms" for k, v in rec_pcts)
            lines.append(f"  fault recovery: {rendered}")
    if rec.get("health") and rec["health"] != "healthy":
        lines.append(f"  health: {rec['health']}")
    return "\n".join(lines)


def format_report(records: list, scenario: str | None = None) -> str:
    chosen = [r for r in records if scenario is None or r.get("scenario") == scenario]
    with_stats = [
        r
        for r in chosen
        if r.get("stats_per_tick") or "new_tick_ms" in r or r.get("faults")
    ]
    if not with_stats:
        return "no reportable records" + (f" for scenario {scenario!r}" if scenario else "")
    head = []
    meta = chosen[0]
    if meta.get("platform") or meta.get("git_sha"):
        head.append(
            f"platform {meta.get('platform', 'unknown')}"
            f" - jax {meta.get('jax_version', 'unknown')}"
            f" - sha {str(meta.get('git_sha', 'unknown'))[:12]}"
        )
    return "\n\n".join(["\n".join(head)] * bool(head) + [format_record(r) for r in with_stats])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report", description=__doc__.splitlines()[0]
    )
    ap.add_argument("path", help="noc_bench --json payload or JSONL record stream")
    ap.add_argument("--scenario", default=None, help="only records with this scenario tag")
    args = ap.parse_args(argv)
    try:
        records = load_records(args.path)
    except (OSError, ValueError) as e:
        print(f"error: {e}")
        return 1
    print(format_report(records, scenario=args.scenario))
    return 0


if __name__ == "__main__":
    sys.exit(main())
