"""Host-side span tracing with Chrome-trace (Perfetto) JSON output.

A `Tracer` collects *complete* events (``ph: "X"``) from context-manager
spans and serialises them in the Chrome trace-event format, so a run can
be dropped straight into ``chrome://tracing`` / https://ui.perfetto.dev
and read next to a device profile:

    from repro.obs import trace

    tracer = trace.Tracer()
    with tracer:                               # activates the tracer
        with trace.span("compile", cores=16):
            session = Interface(cfg).compile(params)
        with trace.span("run"):
            out = session.run(spikes)
        with trace.span("block_until_ready"):
            jax.block_until_ready(out)
    tracer.save("trace.json")

``trace.span(...)`` is the module-level entry point the instrumented code
paths use (`InterfaceSession.compile`/``run``, ``benchmarks/noc_bench.py
--trace``): it records into the innermost *active* tracer, and is a
zero-allocation no-op when none is active - instrumentation can stay in
library code permanently.  While a tracer is active every span also opens
a `jax.profiler.TraceAnnotation`, so when a device profile is being
captured (``jax.profiler.trace``) the host spans show up on its timeline
under the same names and the two traces align.

Spans nest: each event records its depth so stack-track UIs lay them out;
`Tracer.instant` adds zero-duration marker events.  Timestamps are
microseconds from the tracer's creation (the Chrome format's native
unit).
"""

from __future__ import annotations

import contextlib
import json
import os
import threading
import time

import jax

_STACK: list = []  # innermost active tracer last; module-level by design


def active_tracer():
    """The innermost active `Tracer`, or None."""
    return _STACK[-1] if _STACK else None


class Tracer:
    """Collects span events; context-manager activation; Chrome JSON out."""

    def __init__(self, process_name: str = "repro"):
        self.process_name = process_name
        self.events: list = []
        self._origin_ns = time.perf_counter_ns()
        self._depth = 0

    # ---- activation ------------------------------------------------------

    def __enter__(self) -> "Tracer":
        _STACK.append(self)
        return self

    def __exit__(self, *exc) -> None:
        # remove this tracer even if spans misnested around activation
        for i in range(len(_STACK) - 1, -1, -1):
            if _STACK[i] is self:
                del _STACK[i]
                break

    # ---- recording -------------------------------------------------------

    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self._origin_ns) / 1e3

    @contextlib.contextmanager
    def span(self, name: str, **args):
        """Record a complete event around the body (plus a jax annotation)."""
        start = self._now_us()
        self._depth += 1
        try:
            with jax.profiler.TraceAnnotation(name):
                yield self
        finally:
            self._depth -= 1
            self.events.append(
                {
                    "name": name,
                    "ph": "X",
                    "ts": start,
                    "dur": self._now_us() - start,
                    "pid": os.getpid(),
                    "tid": threading.get_ident(),
                    "args": {**args, "depth": self._depth},
                }
            )

    def instant(self, name: str, **args) -> None:
        """Zero-duration marker event."""
        self.events.append(
            {
                "name": name,
                "ph": "i",
                "s": "t",
                "ts": self._now_us(),
                "pid": os.getpid(),
                "tid": threading.get_ident(),
                "args": args,
            }
        )

    # ---- output ----------------------------------------------------------

    def to_chrome_trace(self) -> dict:
        """The full payload in Chrome trace-event format."""
        meta = {
            "name": "process_name",
            "ph": "M",
            "pid": os.getpid(),
            "tid": 0,
            "args": {"name": self.process_name},
        }
        # ts-sorted: Perfetto tolerates disorder but diffing the JSON is nicer
        events = sorted(self.events, key=lambda e: e["ts"])
        return {"traceEvents": [meta, *events], "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=1)
        return path


@contextlib.contextmanager
def span(name: str, **args):
    """Span on the active tracer; exact no-op when tracing is inactive."""
    tracer = active_tracer()
    if tracer is None:
        yield None
        return
    with tracer.span(name, **args) as t:
        yield t


__all__ = ["Tracer", "span", "active_tracer"]
