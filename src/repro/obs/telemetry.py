"""In-jit telemetry records for `InterfaceSession` runs.

The session's scan normally carries only an accumulated `StepStats` - one
scalar record for the whole run.  That is the right default (nothing extra
crosses the device boundary), but it cannot say *which tier* dominated a
given scenario or whether a regression was a mean shift or a tail event.
The ``telemetry=`` knob on ``run`` / ``run_batched`` swaps the scan ys for
richer records, all still under one jit:

``"off"``
    today's path, byte for byte: ``(currents, accumulated StepStats)``.
``"ticks"``
    additionally stacks the per-tick `StepStats` as scan ys:
    ``(currents, accumulated, TickTelemetry)`` where every leaf of
    ``TickTelemetry.per_tick`` has a leading ``(T,)`` axis (``(B, T)``
    under ``run_batched``).  Summing the series over ticks reproduces the
    accumulated record (tested in ``tests/test_obs.py``).
``"cores"``
    also stacks per-core breakdowns (`CoreStats`): events, arbiter grant
    latency, AER encode energy, and NoC/chip hop attribution per source
    core, each ``(T, cores)``.  Per-core values sum (or max, for latency)
    back to the per-tick totals.

Currents are bit-identical in every mode: telemetry only adds outputs, it
never changes the tick computation.  The containers here are plain
NamedTuples (pytrees), so they flow through jit/scan/vmap unchanged; the
summarising helpers (`percentiles`, `to_records`) are host-side.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.interface.stats import StepStats
from repro.obs import metrics as obs_metrics

TELEMETRY_MODES = ("off", "ticks", "cores")


def validate_mode(mode: str) -> str:
    if mode not in TELEMETRY_MODES:
        raise ValueError(
            f"unknown telemetry mode {mode!r}; expected one of "
            f"{', '.join(repr(m) for m in TELEMETRY_MODES)}"
        )
    return mode


class CoreStats(NamedTuple):
    """Per-core slice of one tick's accounting (leaves ``(cores,)``).

    Stacked under the session scan the leaves become ``(T, cores)``.
    Invariants against the per-tick `StepStats` (tested):

      * ``events.sum(-1)`` equals ``StepStats.events`` exactly;
      * ``encode_latency.max(-1)`` equals ``StepStats.encode_latency``
        (the tick's completion time is the slowest core's grant);
      * ``encode_energy`` / ``noc_hops`` / ``chip_hops`` sum to their
        ``StepStats`` counterparts (hops are attributed to the *source*
        core of each event, the core whose arbiter emitted it).
    """

    events: jnp.ndarray          # (cores,) spikes serviced per core
    encode_latency: jnp.ndarray  # (cores,) arbiter grant completion (units)
    encode_energy: jnp.ndarray   # (cores,) address-line toggles
    noc_hops: jnp.ndarray        # (cores,) mesh links used by this core's events
    chip_hops: jnp.ndarray       # (cores,) inter-chip links (zero when chips=1)


class TickTelemetry(NamedTuple):
    """Per-tick `StepStats` time series (every leaf carries a ``(T,)`` axis)."""

    per_tick: StepStats

    @property
    def ticks(self) -> int:
        return int(self.per_tick.events.shape[-1])

    def series(self, field: str):
        """One field's per-tick series as a host numpy-compatible array."""
        return jnp.asarray(getattr(self.per_tick, field))

    def percentiles(self, field: str, qs=(50, 95, 99)) -> dict:
        """p50/p95/p99 (by default) of one field across ticks."""
        values = [float(v) for v in jnp.ravel(self.series(field))]
        return obs_metrics.percentiles(values, qs)

    def to_records(self) -> list:
        """JSONL-ready dicts, one per tick (batched runs flatten B x T)."""
        flat = {k: jnp.ravel(v) for k, v in self.per_tick._asdict().items()}
        ticks = flat["events"].shape[0]
        return [{k: float(v[t]) for k, v in flat.items()} for t in range(ticks)]


class CoreTelemetry(NamedTuple):
    """`TickTelemetry` plus per-core breakdowns (`CoreStats`, ``(T, cores)``)."""

    per_tick: StepStats
    per_core: CoreStats

    @property
    def ticks(self) -> TickTelemetry:
        return TickTelemetry(per_tick=self.per_tick)

    def core_totals(self) -> CoreStats:
        """Per-core sums over the run (latency: per-core max, not sum)."""
        return CoreStats(
            events=jnp.sum(self.per_core.events, axis=-2),
            encode_latency=jnp.max(self.per_core.encode_latency, axis=-2),
            encode_energy=jnp.sum(self.per_core.encode_energy, axis=-2),
            noc_hops=jnp.sum(self.per_core.noc_hops, axis=-2),
            chip_hops=jnp.sum(self.per_core.chip_hops, axis=-2),
        )


__all__ = [
    "TELEMETRY_MODES",
    "validate_mode",
    "CoreStats",
    "TickTelemetry",
    "CoreTelemetry",
]
