"""Counters, streaming percentile histograms, and a JSONL metrics sink.

The perf story of this repo is tail-sensitive - a p99 tick-latency
regression with an unchanged mean is exactly the failure mode the paper's
arbiter comparison is about - so the benchmark layer records *streaming*
percentiles, not just best-of-N minima:

    hist = Histogram()
    for t in tick_wall_clocks_ms:
        hist.add(t)
    hist.summary()          # {"count", "mean", "min", "max", "p50", ...}

`Histogram` is a fixed-memory log-bucketed histogram (`bins_per_decade`
geometric buckets per decade over ``[lo, hi)``, out-of-range values
clamped into the edge buckets): adds are O(1), percentile queries
interpolate geometrically inside the winning bucket, and the relative
quantile error is bounded by one bucket width (~``10**(1/bins_per_decade)``,
<2% at the default 64/decade).  Exact percentiles over a small retained
sample are available as the module-level `percentiles` helper (used where
the sample is only repeat-count sized anyway).

`JsonlSink` appends one JSON object per line - the format
``python -m repro.obs.report`` and external log shippers both consume.
"""

from __future__ import annotations

import json
import math
import threading


def percentiles(values, qs=(50, 95, 99)) -> dict:
    """Exact linear-interpolated percentiles of a small in-memory sample."""
    if not values:
        raise ValueError("percentiles of an empty sample are undefined")
    ordered = sorted(float(v) for v in values)
    n = len(ordered)
    out = {}
    for q in qs:
        if not 0 <= q <= 100:
            raise ValueError(f"percentile {q} outside [0, 100]")
        pos = (n - 1) * q / 100.0
        lo = math.floor(pos)
        hi = min(lo + 1, n - 1)
        out[f"p{q:g}"] = ordered[lo] + (ordered[hi] - ordered[lo]) * (pos - lo)
    return out


class Counter:
    """A named monotonic counter (thread-safe: the serving tier's pump
    thread and submitter threads increment concurrently)."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n


class Histogram:
    """Fixed-memory streaming histogram with geometric buckets.

    Values are expected positive (wall clocks, energies); values at or
    below zero land in the lowest bucket so `add` never raises mid-run.
    Non-finite values (NaN/±Inf) are counted in ``nonfinite`` and
    otherwise ignored - they enter no bucket and cannot poison
    ``min``/``max``/``mean``, so one bad measured duration never kills
    the serve path or skews its percentiles.
    """

    def __init__(
        self, name: str = "", lo: float = 1e-6, hi: float = 1e6, bins_per_decade: int = 64
    ):
        if not (0 < lo < hi):
            raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
        self.name = name
        self.lo = lo
        self.hi = hi
        self.bins_per_decade = bins_per_decade
        self._log_lo = math.log10(lo)
        self._nbins = max(1, math.ceil((math.log10(hi) - self._log_lo) * bins_per_decade))
        self._counts = [0] * self._nbins
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.nonfinite = 0
        self._lock = threading.Lock()

    def _bin(self, value: float) -> int:
        if value <= self.lo:
            return 0
        i = int((math.log10(value) - self._log_lo) * self.bins_per_decade)
        return min(i, self._nbins - 1)

    def _bin_edges(self, i: int) -> tuple:
        lo = 10.0 ** (self._log_lo + i / self.bins_per_decade)
        hi = 10.0 ** (self._log_lo + (i + 1) / self.bins_per_decade)
        return lo, hi

    def add(self, value: float) -> None:
        value = float(value)
        with self._lock:
            if not math.isfinite(value):
                self.nonfinite += 1
                return
            self._counts[self._bin(value)] += 1
            self.count += 1
            self.total += value
            self.min = min(self.min, value)
            self.max = max(self.max, value)

    @property
    def mean(self) -> float:
        if self.count == 0:
            raise ValueError(f"histogram {self.name!r} is empty")
        return self.total / self.count

    def percentile(self, q: float) -> float:
        """Geometric interpolation inside the winning bucket; clamped to
        the observed [min, max] so tiny samples stay exact-ish."""
        if self.count == 0:
            raise ValueError(f"histogram {self.name!r} is empty")
        if not 0 <= q <= 100:
            raise ValueError(f"percentile {q} outside [0, 100]")
        target = q / 100.0 * self.count
        seen = 0
        for i, c in enumerate(self._counts):
            if c == 0:
                continue
            if seen + c >= target:
                lo, hi = self._bin_edges(i)
                frac = (target - seen) / c
                value = lo * (hi / lo) ** frac
                return min(max(value, self.min), self.max)
            seen += c
        return self.max

    def merge(self, other: "Histogram") -> "Histogram":
        """New histogram equivalent to pooling both samples.

        Requires identical bucketing: merging is exact at the bucket
        level, so pooled percentiles match a histogram fed the combined
        sample stream (within the usual one-bucket resolution).  The
        serving tier uses this to roll per-tenant latency histograms into
        fleet-wide percentiles without retaining samples.
        """
        shape = (self.lo, self.hi, self.bins_per_decade)
        if shape != (other.lo, other.hi, other.bins_per_decade):
            raise ValueError(
                f"cannot merge histograms with different bucketing: "
                f"{shape} vs {(other.lo, other.hi, other.bins_per_decade)}"
            )
        out = Histogram(
            self.name or other.name, lo=self.lo, hi=self.hi, bins_per_decade=self.bins_per_decade
        )
        out._counts = [a + b for a, b in zip(self._counts, other._counts)]
        out.count = self.count + other.count
        out.total = self.total + other.total
        out.min = min(self.min, other.min)
        out.max = max(self.max, other.max)
        out.nonfinite = self.nonfinite + other.nonfinite
        return out

    def summary(self, qs=(50, 95, 99)) -> dict:
        out = {
            "count": self.count,
            "mean": self.mean,
            "min": self.min,
            "max": self.max,
        }
        for q in qs:
            out[f"p{q:g}"] = self.percentile(q)
        if self.nonfinite:
            out["nonfinite"] = self.nonfinite
        return out


class MetricsRegistry:
    """Get-or-create registry of counters and histograms.

    Get-or-create is locked: the serving tier's submit and pump threads
    may race to create the same metric, and both must get one object.
    """

    def __init__(self):
        self.counters: dict = {}
        self.histograms: dict = {}
        self._lock = threading.Lock()

    def counter(self, name: str) -> Counter:
        with self._lock:
            if name not in self.counters:
                self.counters[name] = Counter(name)
            return self.counters[name]

    def histogram(self, name: str, **kwargs) -> Histogram:
        with self._lock:
            if name not in self.histograms:
                self.histograms[name] = Histogram(name, **kwargs)
            return self.histograms[name]

    def snapshot(self) -> dict:
        """Plain-dict view of every metric (JSONL-ready)."""
        out = {name: c.value for name, c in self.counters.items()}
        for name, h in self.histograms.items():
            if h.count:
                out[name] = h.summary()
        return out


class JsonlSink:
    """Append-one-JSON-object-per-line sink (the report CLI's input)."""

    def __init__(self, path: str):
        self.path = path
        self._f = None

    def write(self, record: dict) -> None:
        if self._f is None:
            self._f = open(self.path, "a")
        json.dump(record, self._f)
        self._f.write("\n")
        self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


__all__ = ["percentiles", "Counter", "Histogram", "MetricsRegistry", "JsonlSink"]
