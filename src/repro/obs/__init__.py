"""`repro.obs` - observability for the interface fabric.

Three layers, one import:

  `repro.obs.telemetry`   in-jit per-tick / per-core `StepStats` series
                          (the ``telemetry=`` knob on `InterfaceSession`)
  `repro.obs.trace`       host-side span tracing -> Chrome-trace JSON,
                          aligned with device profiles via
                          `jax.profiler.TraceAnnotation`
  `repro.obs.metrics`     counters, streaming p50/p95/p99 histograms,
                          JSONL sink
  `repro.obs.report`      ``python -m repro.obs.report`` per-tier
                          (arbiter/CAM/NoC/chip) breakdown tables

See each module's docstring for the contract; ``tests/test_obs.py`` pins
the telemetry invariants (off-mode bit-identity, series-sums-to-total,
per-core-sums-to-per-tick).
"""

from __future__ import annotations

# `report` is deliberately NOT imported eagerly: it is a ``python -m``
# entry point, and importing it from the package would make runpy warn
# about the module already being in sys.modules when invoked as a CLI.
from repro.obs import metrics, telemetry, trace  # noqa: F401
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Histogram,
    JsonlSink,
    MetricsRegistry,
    percentiles,
)
from repro.obs.telemetry import (  # noqa: F401
    TELEMETRY_MODES,
    CoreStats,
    CoreTelemetry,
    TickTelemetry,
)
from repro.obs.trace import Tracer, active_tracer, span  # noqa: F401

__all__ = [
    "metrics",
    "telemetry",
    "trace",
    "Counter",
    "Histogram",
    "JsonlSink",
    "MetricsRegistry",
    "percentiles",
    "TELEMETRY_MODES",
    "CoreStats",
    "CoreTelemetry",
    "TickTelemetry",
    "Tracer",
    "active_tracer",
    "span",
]
