"""JAX version-compatibility shims (single home, import from here).

The codebase targets the newer ambient-mesh API (`jax.set_mesh`,
top-level `jax.shard_map`, `jax.sharding.AxisType`); older jax (< 0.5)
lacks all three.  These shims fall back to the legacy global-mesh context
and `jax.experimental.shard_map`, threading the active mesh in manually.
"""

from __future__ import annotations

import contextlib

import jax

try:  # jax >= 0.5: explicit axis types on the mesh
    from jax.sharding import AxisType

    def mesh_axis_kwargs(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:  # older jax: meshes are implicitly Auto on every axis
    AxisType = None

    def mesh_axis_kwargs(n: int) -> dict:
        return {}


# Both shims key off ONE capability check (`jax.set_mesh`).  jax versions
# with top-level `jax.shard_map` but no `jax.set_mesh` exist; gating the two
# independently would pair our mesh-tracking set_mesh with a native shard_map
# that never reads it, breaking every mesh-less shard_map call.
if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
    shard_map = jax.shard_map
else:
    _ACTIVE_MESHES: list = []

    @contextlib.contextmanager
    def set_mesh(mesh):
        _ACTIVE_MESHES.append(mesh)
        try:
            with mesh:      # legacy global-mesh context
                yield mesh
        finally:
            _ACTIVE_MESHES.pop()

    if hasattr(jax, "shard_map"):
        _shard_map_impl = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as _shard_map_impl

    def shard_map(f, mesh=None, *, in_specs, out_specs, **kw):
        if mesh is None:
            if not _ACTIVE_MESHES:
                raise ValueError("no ambient mesh: pass mesh= or use set_mesh")
            mesh = _ACTIVE_MESHES[-1]
        return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, **kw)
