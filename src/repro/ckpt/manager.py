"""Checkpointing: atomic, keep-k, async, elastic-restore.

Format: one .npz per checkpoint holding the flattened pytree (msgpack-free,
numpy-native) + a JSON sidecar with step / data-iterator state / config
fingerprint.  Writes go to a temp path and are os.rename'd - a crashed
writer never corrupts the latest checkpoint (the fault-tolerance contract
of ft/runner.py).

Elastic restore: arrays are stored *unsharded* (host numpy); restoring
onto a different mesh just means passing different shardings to
`restore(..., shardings=...)` - device_put re-lays the same logical
arrays, so scaling a run from 256 to 512 chips (or to 1 CPU for a smoke
test) is a restore-time decision, not a format change.
"""

from __future__ import annotations

import json
import os
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, tree, *, step: int, extra: dict | None = None):
    """Atomic checkpoint write."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(jax.device_get(l))
              for i, l in enumerate(leaves)}
    meta = {"step": step, "num_leaves": len(leaves),
            "treedef": str(treedef), "extra": extra or {},
            "time": time.time()}
    tmp = path + ".tmp.npz"   # ends in .npz so np.savez keeps the name
    np.savez(tmp, **arrays)
    os.replace(tmp, path)
    with open(path + ".json.tmp", "w") as f:
        json.dump(meta, f)
    os.replace(path + ".json.tmp", path + ".json")


def restore(path: str, tree_like, *, shardings=None):
    """Restore into the structure of `tree_like` (values ignored).

    shardings: optional pytree of jax.sharding.Sharding for elastic
    re-mesh restore; defaults to host-local arrays.
    """
    leaves_like, treedef = _flatten(tree_like)
    with np.load(path) as z:
        leaves = [z[f"leaf_{i}"] for i in range(len(leaves_like))]
    leaves = [np.asarray(l, dtype=ll.dtype) if hasattr(ll, "dtype") else l
              for l, ll in zip(leaves, leaves_like)]
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree


def load_meta(path: str) -> dict:
    with open(path + ".json") as f:
        return json.load(f)


class CheckpointManager:
    """save-every-N, keep-last-k, optional async writer, auto-resume."""

    def __init__(self, directory: str, *, every: int = 100, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.every = every
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}.npz")

    def latest_step(self) -> int | None:
        steps = sorted(int(f[5:13]) for f in os.listdir(self.dir)
                       if f.startswith("ckpt_") and f.endswith(".npz"))
        return steps[-1] if steps else None

    def maybe_save(self, step: int, tree, extra: dict | None = None,
                   force: bool = False):
        if not force and (step == 0 or step % self.every):
            return False
        self.wait()
        # device_get on the caller thread (arrays may be donated next step)
        leaves, treedef = _flatten(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        host_tree = jax.tree_util.tree_unflatten(treedef, host)

        def _do():
            save(self._path(step), host_tree, step=step, extra=extra)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        else:
            _do()
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, tree_like, *, shardings=None):
        self.wait()
        step = self.latest_step()
        if step is None:
            return None, None
        path = self._path(step)
        return restore(path, tree_like, shardings=shardings), load_meta(path)

    def _gc(self):
        steps = sorted(int(f[5:13]) for f in os.listdir(self.dir)
                       if f.startswith("ckpt_") and f.endswith(".npz"))
        for s in steps[:-self.keep]:
            for suffix in (".npz", ".npz.json"):
                p = os.path.join(self.dir, f"ckpt_{s:08d}{suffix}")
                if os.path.exists(p):
                    os.remove(p)
