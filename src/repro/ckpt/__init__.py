"""ckpt subsystem."""
