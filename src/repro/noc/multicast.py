"""Multicast routing: destination masks from CAM tables + spanning-tree costs.

The CAM routing LUTs already encode the network's fan-out: core c holds an
entry with tag t iff some synapse in c subscribes to source neuron t.  The
subscription matrix derived here is exactly the per-source destination
bitmask a mesh multicast router needs - and its row-wise population count
is the number of CAM searches an event actually triggers (the quantity the
seed fabric over-counted by broadcasting to every core).

Hop-count models per source neuron:
  unicast        one routed copy per destination core: sum of Manhattan
                 distances (replication at the source).
  multicast tree one copy forwarded along the union of the XY paths, which
                 under dimension-order routing is always a tree: a row trunk
                 spanning the destination columns plus one column branch per
                 destination column (closed form, no search).
  broadcast      multicast tree whose destination set is every core.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.noc import topology

_INF = jnp.int32(1 << 20)


def subscription_matrix(tags: jnp.ndarray, valid: jnp.ndarray,
                        cores: int, neurons_per_core: int,
                        tag_bits: int) -> jnp.ndarray:
    """(cores, total) bool: core c holds >=1 valid CAM entry for source s.

    tags: (cores, entries, tag_bits) {0,1}; valid: (cores, entries) bool.
    Packs each stored tag back to its integer source id and scatters, so
    memory is O(cores * entries + cores * total) - never the
    (cores, entries, total, tag_bits) comparison tensor, which reaches GBs
    at DYNAPs scale.
    """
    total = cores * neurons_per_core
    bit_w = jnp.left_shift(1, jnp.arange(tag_bits - 1, -1, -1))  # big-endian
    src_int = jnp.sum(tags * bit_w, axis=-1)                     # (C, E)
    # tag values outside the populated address space never match a source
    hit = valid & (src_int < total)
    core_idx = jnp.broadcast_to(jnp.arange(cores)[:, None], src_int.shape)
    return jnp.zeros((cores, total), bool).at[
        core_idx, jnp.minimum(src_int, total - 1)].max(hit)


def dest_core_mask(tags, valid, cores, neurons_per_core, tag_bits) -> jnp.ndarray:
    """(total, cores) bool: destination-core bitmask of each source neuron."""
    return subscription_matrix(tags, valid, cores, neurons_per_core,
                               tag_bits).T


def unicast_hops(dest_mask: jnp.ndarray, src_core: jnp.ndarray,
                 cores: int) -> jnp.ndarray:
    """(S,) total mesh hops when each destination gets its own copy.

    dest_mask: (S, cores) bool; src_core: (S,) int core id of each source.
    """
    hops = topology.hop_matrix(cores)                            # (C, C)
    return jnp.sum(dest_mask * hops[src_core], axis=-1).astype(jnp.int32)


def multicast_tree_hops(dest_mask: jnp.ndarray, src_core: jnp.ndarray,
                        cores: int) -> jnp.ndarray:
    """(S,) edge count of the XY multicast spanning tree per source.

    Closed form: the union of XY paths from one source is a tree made of a
    horizontal trunk on the source row spanning [min(sx, min dx),
    max(sx, max dx)] plus, in every destination column, a vertical branch
    spanning [min(sy, min dy), max(sy, max dy)] over that column's
    destinations.  For a single destination this degenerates to the plain
    Manhattan path, so single-destination multicast == unicast by
    construction (tested).
    """
    w, _ = topology.mesh_dims(cores)
    xy = topology.core_coords(cores)                             # (C, 2)
    dx, dy = xy[:, 0], xy[:, 1]
    sx, sy = xy[src_core, 0][:, None], xy[src_core, 1][:, None]  # (S, 1)

    m = dest_mask.astype(bool)                                   # (S, C)
    any_dest = jnp.any(m, axis=-1)

    minx = jnp.min(jnp.where(m, dx[None, :], _INF), axis=-1, keepdims=True)
    maxx = jnp.max(jnp.where(m, dx[None, :], -_INF), axis=-1, keepdims=True)
    trunk = (jnp.maximum(sx, maxx) - jnp.minimum(sx, minx))[:, 0]

    col = (dx[None, :, None] == jnp.arange(w)[None, None, :])    # (1, C, W)
    in_col = m[:, :, None] & col                                 # (S, C, W)
    miny = jnp.min(jnp.where(in_col, dy[None, :, None], _INF), axis=1)
    maxy = jnp.max(jnp.where(in_col, dy[None, :, None], -_INF), axis=1)
    has_col = jnp.any(in_col, axis=1)                            # (S, W)
    branch = jnp.where(has_col,
                       jnp.maximum(sy, maxy) - jnp.minimum(sy, miny), 0)
    edges = trunk + jnp.sum(branch, axis=-1)
    return jnp.where(any_dest, edges, 0).astype(jnp.int32)


def broadcast_tree_hops(src_core: jnp.ndarray, cores: int) -> jnp.ndarray:
    """(S,) spanning-tree edges to flood every core from each source."""
    all_cores = jnp.ones((src_core.shape[0], cores), bool)
    return multicast_tree_hops(all_cores, src_core, cores)
