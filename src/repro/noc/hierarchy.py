"""Hierarchical two-tier NoC: chip-local meshes + an inter-chip router level.

The paper's core interface scales a *multi-core* processor; its hardware
lineage (DYNAPs, Moradi et al., arXiv:1708.04198) extends the same fabric
across *chips* with a hierarchical router tier: each chip keeps its own
2D core mesh, and a top-level (R3-style) router grid carries events
between chips.  This module models that second tier.

Fabric model (``chips x cores_per_chip`` total cores):

  * every chip runs the configured transport scheme (broadcast / unicast
    / multicast_tree, via the usual registry entry) over its *own*
    ``cores_per_chip``-core mesh;
  * chips sit on their own near-square grid, and an event whose
    subscribers span chips travels an XY multicast spanning tree over
    that grid (the same closed form as the core-level tree - the chip
    grid is just another mesh);
  * on a remote chip the event enters at the chip's router port (core 0)
    and is delivered over the local mesh from there.

`HierTables` is attribute-compatible with `repro.noc.router.NocTables`
(``subs`` / ``dest_counts`` / ``hops`` / ``depth`` / ``link_table`` keep
their flat-fabric semantics, with the local fields aggregated over chip-
local meshes), so `noc_router.noc_step_costs` and every registered
``cam_accounting`` policy consume it unchanged.  The inter-chip tier adds
``chip_hops`` / ``chip_depth`` / ``chip_link_table``, costed by
`chip_step_costs` with its own PPA constants (`repro.core.ppa`:
``CHIP_HOP_LATENCY_NS`` / ``CHIP_LINK_SERIALIZATION_NS`` /
``CHIP_HOP_ENERGY``) and surfaced through `StepStats.chip_*` and
`ppa_report`.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import ppa
from repro.interface import registry as interface_registry
from repro.noc import multicast, topology
from repro.noc.router import NocScheme, _multicast_link_loads


class HierTables(NamedTuple):
    """Precomputed two-tier routing tables (compile once, reuse per tick).

    The first five data fields mirror `NocTables` semantics so existing
    per-tick consumers (``noc_step_costs``, ``cam_accounting``) work by
    attribute access; the ``chip_*`` fields are the inter-chip tier.
    """

    scheme: str
    chips: int
    cores_per_chip: int
    subs: jnp.ndarray            # (cores_total, S) bool subscription matrix
    dest_counts: jnp.ndarray     # (S,) int32 subscribed-core count
    hops: jnp.ndarray            # (S,) int32 chip-local link traversals
    depth: jnp.ndarray           # (S,) int32 deepest chip-local path
    link_table: jnp.ndarray      # (S, chips*L_local) per-local-link events
    chip_hops: jnp.ndarray       # (S,) int32 inter-chip link traversals
    chip_depth: jnp.ndarray      # (S,) int32 deepest inter-chip path
    chip_link_table: jnp.ndarray  # (S, L_chip) per-chip-link events


def chip_of_core(core: jnp.ndarray, cores_per_chip: int) -> jnp.ndarray:
    """Global core id -> (chip, local core) under the row-major chip split."""
    return core // cores_per_chip, core % cores_per_chip


def build_hier_tables(tags: jnp.ndarray, valid: jnp.ndarray, *, chips: int,
                      cores_per_chip: int, neurons_per_core: int,
                      tag_bits: int,
                      scheme: str = "multicast_tree") -> HierTables:
    """Two-tier routing tables from the CAM state (cf. `router.build_tables`).

    The configured transport scheme governs each chip-local mesh; the
    inter-chip tier always routes one copy along the XY spanning tree over
    the destination chips (remote replication happens at chip routers, so
    even ``unicast`` pays each chip link once per event).
    """
    entry: NocScheme = interface_registry.get_noc_scheme(scheme)
    cores_total = chips * cores_per_chip
    subs = multicast.subscription_matrix(tags, valid, cores_total,
                                         neurons_per_core, tag_bits)
    dmask = subs.T                                             # (S, C_total)
    total = cores_total * neurons_per_core
    src_core = jnp.arange(total, dtype=jnp.int32) // neurons_per_core
    src_chip, src_local = chip_of_core(src_core, cores_per_chip)

    # physically-routed destinations (broadcast widens to every core)
    routed = entry.expand_dests(dmask, cores_total)            # (S, C_total)
    routed_c = routed.reshape(-1, chips, cores_per_chip)

    # ---- inter-chip tier: XY tree over the chip grid ----------------------
    chip_mask = jnp.any(routed_c, axis=-1)                     # (S, chips)
    remote = chip_mask & (jnp.arange(chips)[None, :] != src_chip[:, None])
    chip_hops = multicast.multicast_tree_hops(remote, src_chip, chips)
    chip_link_table = _multicast_link_loads(remote, src_chip, chips)
    chip_hopmat = topology.hop_matrix(chips)
    chip_depth = jnp.max(jnp.where(remote, chip_hopmat[src_chip], 0),
                         axis=-1).astype(jnp.int32)

    # ---- chip-local tier: the configured scheme on every chip's mesh ------
    # On a remote chip the event is re-injected at the router port (local
    # core 0); on the source chip it starts at the source core itself.
    mask_k = jnp.moveaxis(routed_c, 1, 0)                      # (chips, S, c)
    is_src = jnp.arange(chips)[:, None] == src_chip[None, :]   # (chips, S)
    local_src = jnp.where(is_src, src_local[None, :], 0).astype(jnp.int32)
    local_hopmat = topology.hop_matrix(cores_per_chip)

    def one_chip(mask, src):
        hops_k = entry.hops(mask, src, cores_per_chip)
        loads_k = entry.link_loads(mask, src, cores_per_chip)
        routed_k = entry.expand_dests(mask, cores_per_chip)
        depth_k = jnp.max(jnp.where(routed_k, local_hopmat[src], 0),
                          axis=-1).astype(jnp.int32)
        return hops_k, loads_k, depth_k

    hops_k, loads_k, depth_k = jax.vmap(one_chip)(mask_k, local_src)
    link_table = jnp.moveaxis(loads_k, 0, 1)                   # (S, chips, L)
    link_table = link_table.reshape(link_table.shape[0], -1)

    return HierTables(
        scheme=scheme, chips=chips, cores_per_chip=cores_per_chip,
        subs=subs, dest_counts=jnp.sum(dmask, axis=-1).astype(jnp.int32),
        hops=jnp.sum(hops_k, axis=0).astype(jnp.int32),
        depth=jnp.max(depth_k, axis=0),
        link_table=link_table,
        chip_hops=chip_hops, chip_depth=chip_depth,
        chip_link_table=chip_link_table)


def chip_step_costs(tables, spikes_flat: jnp.ndarray):
    """Per-tick inter-chip cost from a flat (S,) spike vector.

    Returns (chip_hops, chip_latency_ns, chip_energy); all zeros for flat
    single-chip tables (`NocTables`), so callers need not branch on the
    fabric shape inside a trace.
    """
    if not isinstance(tables, HierTables):
        z = jnp.zeros((), jnp.float32)
        return z, z, z
    ev = spikes_flat.astype(jnp.float32)
    hops = jnp.sum(ev * tables.chip_hops)
    loads = ev @ tables.chip_link_table                        # (L_chip,)
    depth = jnp.max(jnp.where(spikes_flat > 0, tables.chip_depth, 0))
    latency = (depth.astype(jnp.float32) * ppa.CHIP_HOP_LATENCY_NS +
               jnp.max(loads, initial=0.0) * ppa.CHIP_LINK_SERIALIZATION_NS)
    energy = hops * ppa.CHIP_HOP_ENERGY
    return hops, latency, energy


def chip_step_costs_events(tables, ev_idx: jnp.ndarray, ev_w: jnp.ndarray):
    """Event-indexed `chip_step_costs` for the sparse tick.

    Gathers the per-source chip-tier columns at this tick's events
    (``ev_idx``/``ev_w`` as in `repro.noc.router.noc_step_costs_events`)
    instead of multiplying the full spike vector through them; exact
    integer sums keep the float32 results bit-identical to the dense
    form.  Zeros for flat single-chip tables, like `chip_step_costs`.
    """
    if not isinstance(tables, HierTables):
        z = jnp.zeros((), jnp.float32)
        return z, z, z
    hops = jnp.sum(ev_w * tables.chip_hops[ev_idx])
    loads = ev_w @ tables.chip_link_table[ev_idx]              # (L_chip,)
    depth = jnp.max(ev_w * tables.chip_depth[ev_idx].astype(jnp.float32))
    latency = (depth * ppa.CHIP_HOP_LATENCY_NS +
               jnp.max(loads, initial=0.0) * ppa.CHIP_LINK_SERIALIZATION_NS)
    energy = hops * ppa.CHIP_HOP_ENERGY
    return hops, latency, energy


__all__ = ["HierTables", "build_hier_tables", "chip_step_costs",
           "chip_step_costs_events", "chip_of_core"]
