"""2D-mesh topology: coordinates, XY dimension-order routing, hop matrices.

Cores are laid out on a W x H grid, row-major: core c sits at
(x, y) = (c % W, c // W).  Routing is XY dimension-order (DYNAPs-style
deadlock-free DOR): an event first travels along x to the destination
column, then along y.  A key property this package exploits: the union of
the XY paths from ONE source to ANY destination set is a tree (paths can
only branch where they turn from the row into a column), so the multicast
spanning tree used by `multicast.py` has a closed form - no search needed.

Link indexing convention (used by `router.py`):
  horizontal link (y, x) connects (x, y) <-> (x+1, y),   x in [0, W-2]
  vertical   link (y, x) connects (x, y) <-> (x, y+1),   y in [0, H-2]
Links are bidirectional; loads count events traversing in either direction.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class NocConfig:
    """Inter-core transport configuration for the fabric.

    scheme:
      "broadcast"      every event is flooded to all cores (seed behaviour:
                       CAM searches = events x cores); NoC cost = spanning
                       tree over the full mesh per event.
      "unicast"        mesh with one routed copy per subscribed core.
      "multicast_tree" mesh with one XY spanning tree per event covering
                       exactly the subscribed cores.

    Any further scheme registered through
    `repro.interface.register_noc_scheme` is accepted by name.
    """
    scheme: str = "multicast_tree"

    def __post_init__(self):
        # Deferred import: `router` registers the built-in schemes on import
        # and itself imports this module, so the cycle must break here.
        from repro.interface import registry as interface_registry
        from repro.noc import router  # noqa: F401  (registers built-ins)
        if self.scheme not in interface_registry.NOC_SCHEMES:
            raise ValueError(
                f"unknown NoC scheme: {self.scheme!r}; registered: "
                f"{', '.join(interface_registry.NOC_SCHEMES.names())}")


def mesh_dims(cores: int) -> tuple[int, int]:
    """Near-square (W, H) factorization with W * H >= cores, W >= H."""
    w = max(1, math.ceil(math.sqrt(cores)))
    h = math.ceil(cores / w)
    return w, h


def core_coords(cores: int) -> jnp.ndarray:
    """(cores, 2) int32 grid coordinates (x, y), row-major placement."""
    w, _ = mesh_dims(cores)
    c = jnp.arange(cores, dtype=jnp.int32)
    return jnp.stack([c % w, c // w], axis=-1)


def hop_matrix(cores: int) -> jnp.ndarray:
    """(cores, cores) Manhattan hop distances under XY routing."""
    xy = core_coords(cores)
    d = jnp.abs(xy[:, None, :] - xy[None, :, :])
    return jnp.sum(d, axis=-1).astype(jnp.int32)


def num_links(cores: int) -> int:
    w, h = mesh_dims(cores)
    return h * (w - 1) + (h - 1) * w
