"""Mesh router model: per-link event loads, contention latency, energy.

`build_tables` precomputes, from the CAM routing tables alone, everything
the per-tick fabric step needs as plain matmuls against the spike vector:

  dest_counts (S,)    cores subscribed to each source  -> CAM search count
  hops        (S,)    mesh links traversed per event under the NoC scheme
  depth       (S,)    deepest source->destination path -> traversal latency
  link_table  (S, L)  events injected on each physical link per source spike

All tables depend only on the routing state (tags/valid), not on spikes, so
the hot path (`noc_step_costs`, called from `fabric.step`) is O(S * L).

Latency model (constants in `repro.core.ppa`): an event pays one router
traversal per hop (`NOC_HOP_LATENCY_NS`); concurrent events contend for
links, so a tick's completion time adds the serialization backlog of the
most loaded link (`NOC_LINK_SERIALIZATION_NS` per event).  Energy is
`NOC_HOP_ENERGY` model units per link traversal, the same unit domain as
the CAM energy model so the two can be summed into a system number.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import ppa
from repro.noc import multicast, topology


class NocTables(NamedTuple):
    scheme: str
    subs: jnp.ndarray          # (cores, S) bool subscription matrix
    dest_counts: jnp.ndarray   # (S,) int32 subscribed-core count
    hops: jnp.ndarray          # (S,) int32 link traversals per event
    depth: jnp.ndarray         # (S,) int32 deepest path per event
    link_table: jnp.ndarray    # (S, L) float32 per-link events per spike


def _flatten_links(h_inc: jnp.ndarray, v_inc: jnp.ndarray) -> jnp.ndarray:
    """(S, H, W-1) + (S, H-1, W) -> (S, L) in topology link order."""
    s = h_inc.shape[0]
    return jnp.concatenate([h_inc.reshape(s, -1), v_inc.reshape(s, -1)],
                           axis=-1)


def link_loads(dest_mask: jnp.ndarray, src_core: jnp.ndarray, cores: int,
               scheme: str) -> jnp.ndarray:
    """(S, L) events per physical link per source spike.

    Unicast counts one copy per destination on every link of its XY path;
    multicast counts each tree link once.  Broadcast is the multicast tree
    over every core.  Closed forms via prefix sums - no path enumeration.
    """
    w, h = topology.mesh_dims(cores)
    xy = topology.core_coords(cores)
    dx, dy = xy[:, 0], xy[:, 1]
    sx, sy = xy[src_core, 0], xy[src_core, 1]                  # (S,)
    s_count = src_core.shape[0]

    if scheme == "broadcast":
        dest_mask = jnp.ones((s_count, cores), bool)
        scheme = "multicast_tree"
    m = dest_mask.astype(jnp.float32)                          # (S, C)

    rows = jnp.arange(h)
    cols_h = jnp.arange(max(w - 1, 0))
    rows_v = jnp.arange(max(h - 1, 0))
    cols = jnp.arange(w)

    if scheme == "unicast":
        # dests per column / per (column, row)
        cnt_w = m @ (dx[:, None] == cols[None, :]).astype(jnp.float32)
        at = ((dx[:, None] == cols[None, :])[:, :, None] &
              (dy[:, None] == rows[None, :])[:, None, :])      # (C, W, H)
        cnt_wy = jnp.einsum("sc,cwh->swh", m, at.astype(jnp.float32))
        pre_w = jnp.cumsum(cnt_w, axis=-1)                     # (S, W)
        tot_w = pre_w[:, -1:]
        # horizontal link j on the source row: crossed by dests right/left
        crossings = jnp.where(cols_h[None, :] >= sx[:, None],
                              tot_w - pre_w[:, :-1],           # dx > j
                              pre_w[:, :-1])                   # dx <= j
        h_inc = (rows[None, :, None] == sy[:, None, None]) * \
            crossings[:, None, :]                              # (S, H, W-1)
        pre_y = jnp.cumsum(cnt_wy, axis=-1)                    # (S, W, H)
        tot_y = pre_y[:, :, -1:]
        v_cross = jnp.where(rows_v[None, None, :] >= sy[:, None, None],
                            tot_y - pre_y[:, :, :-1],          # dy > i
                            pre_y[:, :, :-1])                  # (S, W, H-1)
        v_inc = jnp.moveaxis(v_cross, 1, 2)                    # (S, H-1, W)
        return _flatten_links(h_inc, v_inc)

    # multicast spanning tree: row trunk + one column branch per dest column
    big = jnp.int32(1 << 20)
    has = jnp.any(dest_mask, axis=-1, keepdims=True)
    minx = jnp.min(jnp.where(dest_mask, dx[None, :], big), axis=-1)
    maxx = jnp.max(jnp.where(dest_mask, dx[None, :], -big), axis=-1)
    lo = jnp.minimum(sx, minx)[:, None]
    hi = jnp.maximum(sx, maxx)[:, None]
    h_span = has & (cols_h[None, :] >= lo) & (cols_h[None, :] < hi)
    h_inc = ((rows[None, :, None] == sy[:, None, None]) &
             h_span[:, None, :]).astype(jnp.float32)

    in_col = dest_mask[:, :, None] & (dx[None, :, None] == cols[None, None, :])
    miny = jnp.min(jnp.where(in_col, dy[None, :, None], big), axis=1)
    maxy = jnp.max(jnp.where(in_col, dy[None, :, None], -big), axis=1)
    has_col = jnp.any(in_col, axis=1)                          # (S, W)
    vlo = jnp.minimum(sy[:, None], miny)[:, None, :]           # (S, 1, W)
    vhi = jnp.maximum(sy[:, None], maxy)[:, None, :]
    v_inc = (has_col[:, None, :] & (rows_v[None, :, None] >= vlo) &
             (rows_v[None, :, None] < vhi)).astype(jnp.float32)
    return _flatten_links(h_inc, v_inc)


def build_tables(tags: jnp.ndarray, valid: jnp.ndarray, *, cores: int,
                 neurons_per_core: int, tag_bits: int,
                 scheme: str = "multicast_tree") -> NocTables:
    """Precompute routing tables for `fabric.step` from the CAM state."""
    subs = multicast.subscription_matrix(tags, valid, cores,
                                         neurons_per_core, tag_bits)
    dmask = subs.T                                             # (S, C)
    total = cores * neurons_per_core
    src_core = jnp.arange(total, dtype=jnp.int32) // neurons_per_core
    hopmat = topology.hop_matrix(cores)

    if scheme == "broadcast":
        hops = multicast.broadcast_tree_hops(src_core, cores)
        depth = jnp.max(hopmat[src_core], axis=-1).astype(jnp.int32)
    elif scheme == "unicast":
        hops = multicast.unicast_hops(dmask, src_core, cores)
        depth = jnp.max(jnp.where(dmask, hopmat[src_core], 0),
                        axis=-1).astype(jnp.int32)
    else:
        hops = multicast.multicast_tree_hops(dmask, src_core, cores)
        depth = jnp.max(jnp.where(dmask, hopmat[src_core], 0),
                        axis=-1).astype(jnp.int32)

    return NocTables(scheme=scheme, subs=subs,
                     dest_counts=jnp.sum(dmask, axis=-1).astype(jnp.int32),
                     hops=hops, depth=depth,
                     link_table=link_loads(dmask, src_core, cores, scheme))


def noc_step_costs(tables: NocTables, spikes_flat: jnp.ndarray):
    """Per-tick NoC cost from a flat (S,) spike vector.

    Returns (hops, latency_ns, energy, per-link loads).
    """
    ev = spikes_flat.astype(jnp.float32)
    hops = jnp.sum(ev * tables.hops)
    loads = ev @ tables.link_table                             # (L,)
    depth = jnp.max(jnp.where(spikes_flat > 0, tables.depth, 0))
    latency = (depth.astype(jnp.float32) * ppa.NOC_HOP_LATENCY_NS +
               jnp.max(loads, initial=0.0) * ppa.NOC_LINK_SERIALIZATION_NS)
    energy = hops * ppa.NOC_HOP_ENERGY
    return hops, latency, energy, loads
