"""Mesh router model: per-link event loads, contention latency, energy.

`build_tables` precomputes, from the CAM routing tables alone, everything
the per-tick interface step needs as plain matmuls against the spike
vector:

  dest_counts (S,)    cores subscribed to each source  -> CAM search count
  hops        (S,)    mesh links traversed per event under the NoC scheme
  depth       (S,)    deepest source->destination path -> traversal latency
  link_table  (S, L)  events injected on each physical link per source spike

All tables depend only on the routing state (tags/valid), not on spikes, so
the hot path (`noc_step_costs`, called from the interface tick) is O(S * L).

Scheme dispatch goes through `repro.interface.registry`: each transport
scheme registers a :class:`NocScheme` bundle (destination expansion, hop
counts, per-link loads, CAM search accounting) and both `build_tables` and
the fabric cost accounting are generic over the entry - a new transport
plugs in with ``register_noc_scheme(name, NocScheme(...))``.

Latency model (constants in `repro.core.ppa`): an event pays one router
traversal per hop (`NOC_HOP_LATENCY_NS`); concurrent events contend for
links, so a tick's completion time adds the serialization backlog of the
most loaded link (`NOC_LINK_SERIALIZATION_NS` per event).  Energy is
`NOC_HOP_ENERGY` model units per link traversal, the same unit domain as
the CAM energy model so the two can be summed into a system number.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax.numpy as jnp

from repro.core import ppa
from repro.interface import registry as interface_registry
from repro.noc import multicast, topology


class NocTables(NamedTuple):
    scheme: str
    subs: jnp.ndarray          # (cores, S) bool subscription matrix
    dest_counts: jnp.ndarray   # (S,) int32 subscribed-core count
    hops: jnp.ndarray          # (S,) int32 link traversals per event
    depth: jnp.ndarray         # (S,) int32 deepest path per event
    link_table: jnp.ndarray    # (S, L) float32 per-link events per spike


@dataclasses.dataclass(frozen=True)
class NocScheme:
    """Registry entry: the transport policy of one NoC scheme.

    expand_dests(dest_mask, cores) -> (S, C) bool
        the cores an event is physically delivered to (broadcast widens the
        subscription mask to every core; mesh schemes keep it).
    hops(dest_mask, src_core, cores) -> (S,) int32 link traversals/event.
    link_loads(dest_mask, src_core, cores) -> (S, L) per-link events/spike.
    cam_accounting(tables, spikes_flat, valid_cnt, total_events, cores)
        -> (searches, entries_per_search): how many CAM searches a tick's
        events trigger and how many entries each sweeps on average.
    sparse_cam_accounting(tables, ev_idx, ev_w, valid_cnt, total_events,
        cores) -> (searches, entries_per_search): the event-indexed form
        of ``cam_accounting`` for the ``impl="pallas_sparse"`` tick -
        ``ev_idx`` (events,) flat source indices and ``ev_w`` (events,)
        float32 live-event weights replace the dense spike vector.  Must
        return bit-identical float32 values (exact integer sums either
        way).  Optional: schemes without it cannot run the sparse tick.
    """

    name: str
    expand_dests: Callable
    hops: Callable
    link_loads: Callable
    cam_accounting: Callable
    sparse_cam_accounting: Callable | None = None


def _flatten_links(h_inc: jnp.ndarray, v_inc: jnp.ndarray) -> jnp.ndarray:
    """(S, H, W-1) + (S, H-1, W) -> (S, L) in topology link order."""
    s = h_inc.shape[0]
    return jnp.concatenate([h_inc.reshape(s, -1), v_inc.reshape(s, -1)],
                           axis=-1)


def _unicast_link_loads(dest_mask: jnp.ndarray, src_core: jnp.ndarray,
                        cores: int) -> jnp.ndarray:
    """One routed copy per destination on every link of its XY path.

    Closed forms via prefix sums - no path enumeration.
    """
    w, h = topology.mesh_dims(cores)
    xy = topology.core_coords(cores)
    dx, dy = xy[:, 0], xy[:, 1]
    sx, sy = xy[src_core, 0], xy[src_core, 1]                  # (S,)
    m = dest_mask.astype(jnp.float32)                          # (S, C)

    rows = jnp.arange(h)
    cols_h = jnp.arange(max(w - 1, 0))
    rows_v = jnp.arange(max(h - 1, 0))
    cols = jnp.arange(w)

    # dests per column / per (column, row)
    cnt_w = m @ (dx[:, None] == cols[None, :]).astype(jnp.float32)
    at = ((dx[:, None] == cols[None, :])[:, :, None] &
          (dy[:, None] == rows[None, :])[:, None, :])          # (C, W, H)
    cnt_wy = jnp.einsum("sc,cwh->swh", m, at.astype(jnp.float32))
    pre_w = jnp.cumsum(cnt_w, axis=-1)                         # (S, W)
    tot_w = pre_w[:, -1:]
    # horizontal link j on the source row: crossed by dests right/left
    crossings = jnp.where(cols_h[None, :] >= sx[:, None],
                          tot_w - pre_w[:, :-1],               # dx > j
                          pre_w[:, :-1])                       # dx <= j
    h_inc = (rows[None, :, None] == sy[:, None, None]) * \
        crossings[:, None, :]                                  # (S, H, W-1)
    pre_y = jnp.cumsum(cnt_wy, axis=-1)                        # (S, W, H)
    tot_y = pre_y[:, :, -1:]
    v_cross = jnp.where(rows_v[None, None, :] >= sy[:, None, None],
                        tot_y - pre_y[:, :, :-1],              # dy > i
                        pre_y[:, :, :-1])                      # (S, W, H-1)
    v_inc = jnp.moveaxis(v_cross, 1, 2)                        # (S, H-1, W)
    return _flatten_links(h_inc, v_inc)


def _multicast_link_loads(dest_mask: jnp.ndarray, src_core: jnp.ndarray,
                          cores: int) -> jnp.ndarray:
    """XY spanning tree: row trunk + one column branch per dest column."""
    w, h = topology.mesh_dims(cores)
    xy = topology.core_coords(cores)
    dx, dy = xy[:, 0], xy[:, 1]
    sx, sy = xy[src_core, 0], xy[src_core, 1]                  # (S,)

    rows = jnp.arange(h)
    cols_h = jnp.arange(max(w - 1, 0))
    rows_v = jnp.arange(max(h - 1, 0))
    cols = jnp.arange(w)

    big = jnp.int32(1 << 20)
    has = jnp.any(dest_mask, axis=-1, keepdims=True)
    minx = jnp.min(jnp.where(dest_mask, dx[None, :], big), axis=-1)
    maxx = jnp.max(jnp.where(dest_mask, dx[None, :], -big), axis=-1)
    lo = jnp.minimum(sx, minx)[:, None]
    hi = jnp.maximum(sx, maxx)[:, None]
    h_span = has & (cols_h[None, :] >= lo) & (cols_h[None, :] < hi)
    h_inc = ((rows[None, :, None] == sy[:, None, None]) &
             h_span[:, None, :]).astype(jnp.float32)

    in_col = dest_mask[:, :, None] & (dx[None, :, None] == cols[None, None, :])
    miny = jnp.min(jnp.where(in_col, dy[None, :, None], big), axis=1)
    maxy = jnp.max(jnp.where(in_col, dy[None, :, None], -big), axis=1)
    has_col = jnp.any(in_col, axis=1)                          # (S, W)
    vlo = jnp.minimum(sy[:, None], miny)[:, None, :]           # (S, 1, W)
    vhi = jnp.maximum(sy[:, None], maxy)[:, None, :]
    v_inc = (has_col[:, None, :] & (rows_v[None, :, None] >= vlo) &
             (rows_v[None, :, None] < vhi)).astype(jnp.float32)
    return _flatten_links(h_inc, v_inc)


def _all_cores_mask(dest_mask: jnp.ndarray, cores: int) -> jnp.ndarray:
    return jnp.ones((dest_mask.shape[0], cores), bool)


def _broadcast_link_loads(dest_mask, src_core, cores):
    """Broadcast floods the multicast tree over every core."""
    return _multicast_link_loads(_all_cores_mask(dest_mask, cores), src_core,
                                 cores)


def link_loads(dest_mask: jnp.ndarray, src_core: jnp.ndarray, cores: int,
               scheme: str) -> jnp.ndarray:
    """(S, L) events per physical link per source spike (registry dispatch)."""
    entry: NocScheme = interface_registry.get_noc_scheme(scheme)
    return entry.link_loads(dest_mask, src_core, cores)


def build_tables(tags: jnp.ndarray, valid: jnp.ndarray, *, cores: int,
                 neurons_per_core: int, tag_bits: int,
                 scheme: str = "multicast_tree") -> NocTables:
    """Precompute routing tables for the interface tick from the CAM state."""
    entry: NocScheme = interface_registry.get_noc_scheme(scheme)
    subs = multicast.subscription_matrix(tags, valid, cores,
                                         neurons_per_core, tag_bits)
    dmask = subs.T                                             # (S, C)
    total = cores * neurons_per_core
    src_core = jnp.arange(total, dtype=jnp.int32) // neurons_per_core
    hopmat = topology.hop_matrix(cores)

    routed = entry.expand_dests(dmask, cores)
    hops = entry.hops(dmask, src_core, cores)
    depth = jnp.max(jnp.where(routed, hopmat[src_core], 0),
                    axis=-1).astype(jnp.int32)

    return NocTables(scheme=scheme, subs=subs,
                     dest_counts=jnp.sum(dmask, axis=-1).astype(jnp.int32),
                     hops=hops, depth=depth,
                     link_table=entry.link_loads(dmask, src_core, cores))


def noc_step_costs(tables: NocTables, spikes_flat: jnp.ndarray):
    """Per-tick NoC cost from a flat (S,) spike vector.

    Returns (hops, latency_ns, energy, per-link loads).
    """
    ev = spikes_flat.astype(jnp.float32)
    hops = jnp.sum(ev * tables.hops)
    loads = ev @ tables.link_table                             # (L,)
    depth = jnp.max(jnp.where(spikes_flat > 0, tables.depth, 0))
    latency = (depth.astype(jnp.float32) * ppa.NOC_HOP_LATENCY_NS +
               jnp.max(loads, initial=0.0) * ppa.NOC_LINK_SERIALIZATION_NS)
    energy = hops * ppa.NOC_HOP_ENERGY
    return hops, latency, energy, loads


def noc_step_costs_events(tables: NocTables, ev_idx: jnp.ndarray,
                          ev_w: jnp.ndarray):
    """Event-indexed `noc_step_costs` for the sparse tick.

    ev_idx: (events,) int32 flat source indices of this tick's events
    (pad slots pointing anywhere); ev_w: (events,) float32 1.0/0.0 live
    weights (`repro.kernels.sparse_tick.event_indices`).  Gathers the
    per-source table columns at the events instead of multiplying the
    full (S,) spike vector through them, so cost scales with events, not
    fabric size.  Every reduction sums the same exact small integers as
    the dense form, so the float32 results are bit-identical.
    """
    hops = jnp.sum(ev_w * tables.hops[ev_idx])
    loads = ev_w @ tables.link_table[ev_idx]                   # (L,)
    depth = jnp.max(ev_w * tables.depth[ev_idx].astype(jnp.float32))
    latency = (depth * ppa.NOC_HOP_LATENCY_NS +
               jnp.max(loads, initial=0.0) * ppa.NOC_LINK_SERIALIZATION_NS)
    energy = hops * ppa.NOC_HOP_ENERGY
    return hops, latency, energy, loads


# ---------------------------------------------------------------------------
# CAM search accounting policies.
# ---------------------------------------------------------------------------


def _flood_cam_accounting(tables, spikes_flat, valid_cnt, total_events, cores):
    """Flood: every event is searched in every core (seed accounting)."""
    searches = total_events * cores
    entries_per_search = jnp.mean(valid_cnt)
    return searches, entries_per_search


def _flood_sparse_cam_accounting(tables, ev_idx, ev_w, valid_cnt,
                                 total_events, cores):
    """Flood accounting never reads the spike vector; same closed form."""
    return _flood_cam_accounting(tables, None, valid_cnt, total_events, cores)


def _subscribed_cam_accounting(tables, spikes_flat, valid_cnt, total_events,
                               cores):
    """Mesh: an event is searched only where some CAM entry subscribes."""
    searches = jnp.sum(spikes_flat * tables.dest_counts).astype(jnp.float32)
    swept = jnp.sum(valid_cnt[:, None] * tables.subs *
                    spikes_flat[None, :])
    entries_per_search = swept / jnp.maximum(searches, 1.0)
    return searches, entries_per_search


def _subscribed_sparse_cam_accounting(tables, ev_idx, ev_w, valid_cnt,
                                      total_events, cores):
    """Event-indexed `_subscribed_cam_accounting` (bit-identical).

    ``valid_cnt @ subs`` is the per-source swept-entry total; it depends
    only on routing state, so XLA hoists it out of the per-tick scan.
    """
    searches = jnp.sum(ev_w * tables.dest_counts[ev_idx])
    swept = jnp.sum(ev_w * (valid_cnt @ tables.subs)[ev_idx])
    entries_per_search = swept / jnp.maximum(searches, 1.0)
    return searches, entries_per_search


# ---------------------------------------------------------------------------
# Built-in transport schemes.
# ---------------------------------------------------------------------------

for _entry in (
    NocScheme("broadcast",
              expand_dests=_all_cores_mask,
              hops=lambda m, src, cores: multicast.broadcast_tree_hops(
                  src, cores),
              link_loads=_broadcast_link_loads,
              cam_accounting=_flood_cam_accounting,
              sparse_cam_accounting=_flood_sparse_cam_accounting),
    NocScheme("unicast",
              expand_dests=lambda m, cores: m,
              hops=lambda m, src, cores: multicast.unicast_hops(
                  m, src, cores),
              link_loads=_unicast_link_loads,
              cam_accounting=_subscribed_cam_accounting,
              sparse_cam_accounting=_subscribed_sparse_cam_accounting),
    NocScheme("multicast_tree",
              expand_dests=lambda m, cores: m,
              hops=lambda m, src, cores: multicast.multicast_tree_hops(
                  m, src, cores),
              link_loads=_multicast_link_loads,
              cam_accounting=_subscribed_cam_accounting,
              sparse_cam_accounting=_subscribed_sparse_cam_accounting),
):
    if _entry.name not in interface_registry.NOC_SCHEMES:
        interface_registry.register_noc_scheme(_entry.name, _entry)
del _entry
