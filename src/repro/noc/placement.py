"""Neuron-to-core placement: greedy hyperedge-overlap optimizer + baselines.

Where neurons live determines how hard the NoC and the CAMs work: a source
whose fan-out is spread over many cores multicasts to all of them and
triggers one CAM search per core.  Modelling the network as a *hypergraph*
(one hyperedge per source neuron, spanning its destinations - Ronzani &
Silvano) exposes the lever: co-locating destinations that share sources
collapses hyperedges onto few cores, cutting both link traffic and search
count.  The greedy optimizer here places nodes in descending-degree order
onto the core whose current members share the most hyperedges with them.

This is an OFFLINE host-side pass (numpy, data-dependent control flow);
its output - a permutation of global neuron ids - feeds the pure-JAX
fabric via `apply_placement`, which rewrites the CAM tables accordingly.

Conventions: `perm[old_global_id] = new_global_id`; the core of a neuron
is `new_global_id // neurons_per_core`.
"""

from __future__ import annotations

import numpy as np

from repro.noc.topology import mesh_dims


# ---------------------------------------------------------------------------
# Connectivity extraction
# ---------------------------------------------------------------------------


def _bits_to_int(bits: np.ndarray) -> np.ndarray:
    weights = 1 << np.arange(bits.shape[-1] - 1, -1, -1)
    return (bits * weights).sum(axis=-1)


def fanout_adjacency(params, cfg) -> np.ndarray:
    """(S, S) bool: A[s, d] = source neuron s drives destination neuron d.

    Decoded from the CAM tables of a `fabric.FabricParams`; each row of A
    is one hyperedge (a source and the sinks it spans).
    """
    n = cfg.neurons_per_core
    tags = np.asarray(params.tags)
    valid = np.asarray(params.valid)
    targets = np.asarray(params.targets)
    total = cfg.cores * n
    a = np.zeros((total, total), dtype=bool)
    src = _bits_to_int(tags)                                   # (C, E)
    for c in range(cfg.cores):
        e = np.flatnonzero(valid[c])
        a[src[c, e], c * n + targets[c, e]] = True
    return a


# ---------------------------------------------------------------------------
# Placements
# ---------------------------------------------------------------------------


def identity_placement(total: int) -> np.ndarray:
    return np.arange(total, dtype=np.int64)


def random_placement(seed: int, total: int) -> np.ndarray:
    return np.random.RandomState(seed).permutation(total).astype(np.int64)


def greedy_overlap_placement(a: np.ndarray, cores: int,
                             neurons_per_core: int) -> np.ndarray:
    """Greedy hyperedge-overlap partitioning (deterministic).

    Cores are grown one at a time: seed with the highest-degree unplaced
    node, then repeatedly pull in the unplaced node with the highest
    affinity to the growing core until it is full.  Affinity counts
        |{hyperedges covering the candidate that the core already touches}|
      + 0.5 * direct adjacency to core members
    i.e. primarily synaptic reuse (a source already delivered to this core
    serves a new co-located sink for free - one multicast delivery + one
    CAM search amortized over more synapses), secondarily keeping sources
    next to their own sinks (fewer mesh hops).  Growing core-by-core keeps
    each hyperedge's sinks together instead of scattering cold-start seeds
    across every core.
    """
    total = a.shape[0]
    assert cores * neurons_per_core >= total
    deg = (a.sum(0) + a.sum(1)).astype(np.float64)
    tiebreak = 1e-6 * deg
    unplaced = np.ones(total, dtype=bool)
    perm = np.empty(total, dtype=np.int64)
    for c in range(cores):
        if not unplaced.any():
            break
        cov = np.zeros(total, dtype=bool)       # hyperedges this core touches
        aff = np.zeros(total, dtype=np.float64)
        for slot in range(neurons_per_core):
            if not unplaced.any():
                break
            score = np.where(unplaced, aff + tiebreak, -np.inf)
            m = int(np.argmax(score))
            perm[m] = c * neurons_per_core + slot
            unplaced[m] = False
            new_srcs = a[:, m] & ~cov
            cov |= a[:, m]
            if new_srcs.any():                  # newly covered hyperedges
                aff += a[new_srcs].sum(axis=0)
            aff += 0.5 * (a[m] + a[:, m])       # adjacency to m itself
    return perm


# ---------------------------------------------------------------------------
# Traffic-cost objective (numpy mirror of the JAX closed forms)
# ---------------------------------------------------------------------------


def _tree_edges(sx, sy, dmask, dx, dy, w) -> np.ndarray:
    """(S,) XY multicast spanning-tree edge counts (numpy)."""
    big = 1 << 20
    has = dmask.any(axis=1)
    minx = np.where(dmask, dx[None, :], big).min(axis=1)
    maxx = np.where(dmask, dx[None, :], -big).max(axis=1)
    trunk = np.maximum(sx, maxx) - np.minimum(sx, minx)
    branch = np.zeros_like(trunk)
    for col in range(w):
        in_col = dmask & (dx[None, :] == col)
        has_col = in_col.any(axis=1)
        miny = np.where(in_col, dy[None, :], big).min(axis=1)
        maxy = np.where(in_col, dy[None, :], -big).max(axis=1)
        branch += np.where(has_col, np.maximum(sy, maxy) -
                           np.minimum(sy, miny), 0)
    return np.where(has, trunk + branch, 0)


def placement_dest_cores(a: np.ndarray, perm: np.ndarray,
                         neurons_per_core: int, cores: int) -> np.ndarray:
    """(S, cores) bool: destination-core mask of each source under perm."""
    total = a.shape[0]
    core_of = perm // neurons_per_core                         # (S,)
    dmask = np.zeros((total, cores), dtype=bool)
    srcs, dsts = np.nonzero(a)
    dmask[srcs, core_of[dsts]] = True
    return dmask


def traffic_cost(a: np.ndarray, perm: np.ndarray, cores: int,
                 neurons_per_core: int, rates: np.ndarray | None = None
                 ) -> float:
    """Expected multicast-tree link traversals per tick under a placement.

    rates: optional (S,) per-source spike rates (uniform if omitted).
    Lower is better; single objective shared by optimizer and benchmarks.
    """
    w, _ = mesh_dims(cores)
    x = np.arange(cores) % w
    y = np.arange(cores) // w
    dmask = placement_dest_cores(a, perm, neurons_per_core, cores)
    src_core = perm // neurons_per_core
    edges = _tree_edges(x[src_core], y[src_core], dmask, x, y, w)
    r = np.ones(a.shape[0]) if rates is None else np.asarray(rates)
    return float((edges * r).sum())


def cam_search_count(a: np.ndarray, perm: np.ndarray, cores: int,
                     neurons_per_core: int) -> float:
    """CAM searches per tick if every source fired once: sum of dest cores."""
    dmask = placement_dest_cores(a, perm, neurons_per_core, cores)
    return float(dmask.sum())


# ---------------------------------------------------------------------------
# Applying a placement to the fabric
# ---------------------------------------------------------------------------


def apply_placement(params, cfg, perm: np.ndarray):
    """Rewrite CAM tables so neuron `g` now lives at global id `perm[g]`.

    Returns (new_params, new_cfg): each synapse entry moves to its target's
    new core, its stored tag is relabelled to the source's new id, and the
    per-core entry count grows to the most loaded core (padded invalid) -
    placement concentrates synapses, so cores may hold more entries than
    the uniform seed layout.
    """
    import dataclasses

    import jax.numpy as jnp

    from repro.core import fabric as fabric_mod

    n = cfg.neurons_per_core
    tags = np.asarray(params.tags)
    valid = np.asarray(params.valid)
    weights = np.asarray(params.weights)
    targets = np.asarray(params.targets)
    src_old = _bits_to_int(tags)

    per_core: list[list[tuple[int, float, int]]] = [[] for _ in range(cfg.cores)]
    for c in range(cfg.cores):
        for e in np.flatnonzero(valid[c]):
            new_dest = int(perm[c * n + targets[c, e]])
            new_src = int(perm[src_old[c, e]])
            per_core[new_dest // n].append(
                (new_src, float(weights[c, e]), new_dest % n))

    entries = max(cfg.cam.entries, max((len(p) for p in per_core), default=1))
    new_tags = np.zeros((cfg.cores, entries, cfg.tag_bits), np.int32)
    new_valid = np.zeros((cfg.cores, entries), bool)
    new_weights = np.zeros((cfg.cores, entries), np.float32)
    new_targets = np.zeros((cfg.cores, entries), np.int32)
    bit_w = 1 << np.arange(cfg.tag_bits - 1, -1, -1)
    for c, items in enumerate(per_core):
        for e, (src, wgt, tgt) in enumerate(items):
            new_tags[c, e] = (src & bit_w) > 0
            new_valid[c, e] = True
            new_weights[c, e] = wgt
            new_targets[c, e] = tgt

    new_cfg = dataclasses.replace(
        cfg, cam_entries_per_core=entries,
        cam=dataclasses.replace(cfg.cam, entries=entries))
    new_params = fabric_mod.FabricParams(
        tags=jnp.asarray(new_tags), valid=jnp.asarray(new_valid),
        weights=jnp.asarray(new_weights), targets=jnp.asarray(new_targets))
    return new_params, new_cfg


# ---------------------------------------------------------------------------
# Structured workload generator (benchmarks/tests)
# ---------------------------------------------------------------------------


def clustered_connectivity(seed: int, cfg, cluster_size: int,
                           fan_in: int | None = None):
    """Cluster-structured fabric wiring, scrambled across cores.

    Neurons form clusters of `cluster_size` in a hidden "virtual" id space;
    every destination draws its `fan_in` sources from its own cluster.
    Virtual ids are then randomly scrambled onto physical ids, so the
    locality exists but no layout exposes it until a placement optimizer
    recovers it.  Returns a `fabric.FabricParams`.
    """
    import jax.numpy as jnp

    from repro.core import fabric as fabric_mod

    rng = np.random.RandomState(seed)
    n = cfg.neurons_per_core
    total = cfg.cores * n
    fan_in = fan_in if fan_in is not None else max(1, cfg.cam.entries // n)
    assert n * fan_in <= cfg.cam.entries, "fan_in overflows the CAM"
    scramble = rng.permutation(total)          # virtual -> physical id

    tags = np.zeros((cfg.cores, cfg.cam.entries, cfg.tag_bits), np.int32)
    valid = np.zeros((cfg.cores, cfg.cam.entries), bool)
    weights = rng.randn(cfg.cores, cfg.cam.entries).astype(np.float32) * 0.5 + 1.0
    targets = np.zeros((cfg.cores, cfg.cam.entries), np.int32)
    bit_w = 1 << np.arange(cfg.tag_bits - 1, -1, -1)

    fill = np.zeros(cfg.cores, dtype=np.int64)
    for vd in range(total):
        d = scramble[vd]
        base = (vd // cluster_size) * cluster_size
        vsrcs = base + rng.choice(min(cluster_size, total - base),
                                  size=fan_in, replace=False)
        for vs in vsrcs:
            s = scramble[vs]
            c, e = d // n, fill[d // n]
            tags[c, e] = (s & bit_w) > 0
            valid[c, e] = True
            targets[c, e] = d % n
            fill[c] += 1
    return fabric_mod.FabricParams(
        tags=jnp.asarray(tags), valid=jnp.asarray(valid),
        weights=jnp.asarray(weights), targets=jnp.asarray(targets))
