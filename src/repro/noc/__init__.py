"""Behavioural 2D-mesh Network-on-Chip between neuromorphic cores.

The core interface (arbiter out, CAM in) is modelled in `repro.core`; this
package adds the transport fabric between the cores:

  topology.py   mesh coordinates, XY dimension-order routing, hop matrices
  multicast.py  per-source destination masks from the CAM tables; hop counts
                for unicast replication vs. a single multicast spanning tree
  router.py     per-link event loads, contention latency and energy
  hierarchy.py  two-tier fabric: chip-local meshes + the DYNAPs-style
                inter-chip router level (chips x cores_per_chip cores)
  placement.py  neuron-to-core placement (greedy hyperedge-overlap optimizer
                vs. random/identity baselines) + traffic-cost objective

Everything that runs inside the `repro.interface` tick is pure-functional
JAX; the placement optimizer is an offline host-side pass (numpy) whose
*output* feeds the JAX fabric.  Transport schemes are registered in
`repro.interface.registry` (see `router.NocScheme`).
"""

from repro.noc.topology import NocConfig, mesh_dims, core_coords, hop_matrix
from repro.noc.multicast import (subscription_matrix, dest_core_mask,
                                 unicast_hops, multicast_tree_hops,
                                 broadcast_tree_hops)
from repro.noc.router import NocTables, build_tables, link_loads, noc_step_costs
from repro.noc.hierarchy import (HierTables, build_hier_tables,
                                 chip_step_costs, chip_of_core)
from repro.noc.placement import (identity_placement, random_placement,
                                 greedy_overlap_placement, traffic_cost,
                                 apply_placement, fanout_adjacency,
                                 clustered_connectivity)

__all__ = [
    "NocConfig", "mesh_dims", "core_coords", "hop_matrix",
    "subscription_matrix", "dest_core_mask", "unicast_hops",
    "multicast_tree_hops", "broadcast_tree_hops",
    "NocTables", "build_tables", "link_loads", "noc_step_costs",
    "HierTables", "build_hier_tables", "chip_step_costs", "chip_of_core",
    "identity_placement", "random_placement", "greedy_overlap_placement",
    "traffic_cost", "apply_placement", "fanout_adjacency",
    "clustered_connectivity",
]
