"""Fault-tolerant training runner: watchdog, auto-resume, failure drills.

Large-scale contract (DESIGN.md; exercised at small scale in tests):

  * every step is a pure function of (state, step_index) - the data
    pipeline regenerates any batch from its step, so a restart resumes
    bit-exactly from the last checkpoint;
  * `CheckpointManager` writes atomically; a crash mid-save never corrupts
    the resume point;
  * the watchdog tracks per-step wall time and flags stragglers (steps
    slower than `straggler_factor` x the running median).  On real fleets
    this signal feeds the scheduler; here it is logged and counted;
  * `FailureInjector` deterministically raises at configured steps so the
    resume path is tested, not just designed.
"""

from __future__ import annotations

import dataclasses
import time

import jax

from repro.ckpt.manager import CheckpointManager
from repro.obs import metrics as obs_metrics


@dataclasses.dataclass
class FailureInjector:
    """Raises once at each configured step; counts onto `obs.metrics`.

    registry/prefix: optional `MetricsRegistry` receiving a
    ``<prefix>.injected_failures`` counter, so training-time fault drills
    share one telemetry substrate with the serving tier.
    """

    fail_at_steps: tuple = ()
    registry: obs_metrics.MetricsRegistry | None = None
    prefix: str = "ft"
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            if self.registry is not None:
                self.registry.counter(f"{self.prefix}.injected_failures").inc()
            raise RuntimeError(f"injected failure at step {step}")


class Watchdog:
    """Flags steps slower than `straggler_factor` x the running median.

    Counters/histograms live on a `repro.obs.metrics` registry (a
    private one by default): every observation lands in
    ``<prefix>.step_ms``, stragglers increment ``<prefix>.stragglers``.
    The `stragglers` attribute and `times` list keep the seed-era
    interface working; the registry is looked up per call so an engine
    that clears its registry (warmup reset) keeps counting correctly.
    """

    def __init__(self, straggler_factor: float = 3.0,
                 registry: obs_metrics.MetricsRegistry | None = None,
                 prefix: str = "ft"):
        self.times: list[float] = []
        self.factor = straggler_factor
        self.registry = registry or obs_metrics.MetricsRegistry()
        self.prefix = prefix

    def observe(self, dt: float) -> bool:
        self.times.append(dt)
        self.registry.histogram(f"{self.prefix}.step_ms").add(dt * 1e3)
        hist = sorted(self.times[-50:])
        median = hist[len(hist) // 2]
        is_straggler = len(self.times) > 5 and dt > self.factor * median
        if is_straggler:
            self.registry.counter(f"{self.prefix}.stragglers").inc()
        return is_straggler

    @property
    def stragglers(self) -> int:
        counter = self.registry.counters.get(f"{self.prefix}.stragglers")
        return int(counter.value) if counter is not None else 0


def run_training(train_step, state, pipeline, *, num_steps: int,
                 manager: CheckpointManager, injector: FailureInjector | None
                 = None, watchdog: Watchdog | None = None,
                 log_every: int = 10, logger=print):
    """Drive training with checkpoint/resume.  Returns (state, history).

    On any exception the caller can re-invoke with a fresh `state`
    template; we auto-resume from the manager's latest checkpoint.
    """
    watchdog = watchdog or Watchdog()
    restored, meta = manager.restore_latest(state)
    start = 0
    if restored is not None:
        state = restored
        start = int(meta["step"]) if meta else 0
        logger(f"[ft] resumed from step {start}")

    history = []
    for step in range(start, num_steps):
        if injector is not None:
            injector.check(step)
        t0 = time.perf_counter()
        batch = pipeline.batch(step)
        state, metrics = train_step(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        if watchdog.observe(dt):
            logger(f"[ft] straggler step {step}: {dt:.3f}s")
        history.append({k: float(v) for k, v in metrics.items()})
        if step % log_every == 0:
            logger(f"step {step}: loss={history[-1]['loss']:.4f} "
                   f"({dt*1000:.0f} ms)")
        manager.maybe_save(step + 1, state, extra={"data_step": step + 1})
    manager.maybe_save(num_steps, state, force=True,
                       extra={"data_step": num_steps})
    manager.wait()
    return state, history


def run_with_restarts(make_state, train_step, pipeline, *, num_steps: int,
                      manager: CheckpointManager, injector: FailureInjector,
                      max_restarts: int = 5, logger=print):
    """Crash-loop harness: restart after injected/real failures."""
    attempts = 0
    while True:
        try:
            state = make_state()
            return run_training(train_step, state, pipeline,
                                num_steps=num_steps, manager=manager,
                                injector=injector, logger=logger)
        except RuntimeError as e:
            attempts += 1
            logger(f"[ft] failure ({e}); restart {attempts}")
            if attempts > max_restarts:
                raise
