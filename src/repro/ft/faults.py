"""`repro.ft.faults`: deterministic fabric-layer fault models.

The paper's core argument is that the asynchronous core interface must
stay live under adverse event traffic — CAM mis-matches, dropped AER
events, dead cores.  `FaultModel` expresses those hardware faults as
*pure transforms* so a faulted run stays inside the one compiled step and
degrades predictably instead of crashing:

  compile time  `apply_params` perturbs the routing state before the
                session builds its tables/`RoutingIndex`: dead cores have
                every CAM entry invalidated (they receive nothing), and
                ``corrupt_cam_entries`` randomly chosen CAM slots get
                their stored tags re-randomized — the classic CAM
                mis-match, which silently misroutes those synapses.
  run time      `apply_spikes` is jit-compatible: dead cores' spikes are
                masked (they also emit nothing) and events are dropped
                with probability ``drop_rate`` per (tick, core, neuron).
                The drop mask is keyed by ``fold_in(seed, lane, global
                tick index)``, so a stream served in chunks draws exactly
                the same faults as one uninterrupted run — the property
                that lets the chaos soak assert bit-identical currents.

Faults are *data*, not control flow: a session compiled with a
`FaultModel` has the same jit cache footprint as a clean one (one entry
per entry point; the tick offset is a dynamic argument).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Deterministic, seeded fabric faults as pure transforms.

    dead_cores:          core indices that neither emit nor receive events
                         (their spikes are masked and their CAM rows
                         invalidated).
    drop_rate:           per-event Bernoulli drop probability in [0, 1]
                         (lossy AER link).
    corrupt_cam_entries: number of CAM slots whose stored tags are
                         re-randomized at compile time (mis-match /
                         misroute, not a crash).
    seed:                PRNG seed for both the corruption choice and the
                         per-tick drop masks.
    """

    dead_cores: tuple = ()
    drop_rate: float = 0.0
    corrupt_cam_entries: int = 0
    seed: int = 0

    def __post_init__(self):
        cores = tuple(sorted(int(c) for c in self.dead_cores))
        if len(set(cores)) != len(cores):
            raise ValueError(f"dead_cores has duplicates: {cores}")
        if cores and cores[0] < 0:
            raise ValueError(f"dead_cores must be non-negative, got {cores}")
        object.__setattr__(self, "dead_cores", cores)
        if not 0.0 <= float(self.drop_rate) <= 1.0:
            raise ValueError(f"drop_rate must be in [0, 1], got {self.drop_rate}")
        object.__setattr__(self, "drop_rate", float(self.drop_rate))
        if int(self.corrupt_cam_entries) < 0:
            raise ValueError(f"corrupt_cam_entries must be >= 0, got {self.corrupt_cam_entries}")
        object.__setattr__(self, "corrupt_cam_entries", int(self.corrupt_cam_entries))
        object.__setattr__(self, "seed", int(self.seed))

    # ---- introspection ----------------------------------------------------

    @property
    def is_null(self) -> bool:
        """True when this model perturbs nothing (compiles as fault-free)."""
        return not self.dead_cores and self.drop_rate == 0.0 and self.corrupt_cam_entries == 0

    @property
    def perturbs_spikes(self) -> bool:
        """True when the run-time spike transform is non-trivial."""
        return bool(self.dead_cores) or self.drop_rate > 0.0

    def validate(self, cfg) -> None:
        """Check the model fits a fabric config; raise ValueError if not."""
        if self.dead_cores and max(self.dead_cores) >= cfg.cores:
            raise ValueError(
                f"dead core {max(self.dead_cores)} out of range for a "
                f"{cfg.cores}-core fabric"
            )
        total = cfg.cores * cfg.cam.entries
        if self.corrupt_cam_entries > total:
            raise ValueError(
                f"corrupt_cam_entries={self.corrupt_cam_entries} exceeds the "
                f"fabric's {total} CAM slots"
            )

    def describe(self) -> dict:
        """Small JSON-able summary for reports."""
        return {
            "dead_cores": list(self.dead_cores),
            "drop_rate": self.drop_rate,
            "corrupt_cam_entries": self.corrupt_cam_entries,
            "seed": self.seed,
        }

    # ---- compile-time transform ------------------------------------------

    def apply_params(self, params, cfg):
        """Perturbed copy of the routing state (host-time, pure).

        Corruption happens *before* dead-core invalidation, so a corrupt
        slot landing on a dead core is still silenced — dead means dead.
        """
        from repro.interface.types import int_to_bits

        tags, valid = params.tags, params.valid
        if self.corrupt_cam_entries:
            cores, entries = valid.shape
            k_pick, k_src = jax.random.split(jax.random.PRNGKey(self.seed))
            flat = jax.random.choice(
                k_pick, cores * entries, (self.corrupt_cam_entries,), replace=False
            )
            bad_src = jax.random.randint(
                k_src,
                (self.corrupt_cam_entries,),
                0,
                cfg.cores * cfg.neurons_per_core,
            )
            tag_bits = tags.shape[-1]
            tags = (
                tags.reshape(cores * entries, tag_bits)
                .at[flat]
                .set(int_to_bits(bad_src, tag_bits))
                .reshape(tags.shape)
            )
        if self.dead_cores:
            valid = valid.at[jnp.array(self.dead_cores), :].set(False)
        return params._replace(tags=tags, valid=valid)

    # ---- run-time transform ----------------------------------------------

    def apply_spikes(self, spikes_tcn, tick0=0, lane=0):
        """Faulted copy of a (T, cores, neurons) spike stream (jit-safe).

        tick0: global tick index of ``spikes_tcn[0]`` — a *dynamic* scalar
        (chunked callers pass their running offset without recompiling).
        lane:  batch-lane index folded into the drop stream so vmapped
        lanes draw independent faults.
        """
        spikes = spikes_tcn
        if spikes.dtype != jnp.bool_:
            spikes = spikes > 0
        cores = spikes.shape[-2]
        if self.dead_cores:
            alive = jnp.ones((cores,), bool).at[jnp.array(self.dead_cores)].set(False)
            spikes = spikes & alive[:, None]
        if self.drop_rate > 0.0:
            shape = spikes.shape[-2:]
            base = jax.random.fold_in(jax.random.PRNGKey(self.seed), jnp.asarray(lane, jnp.int32))
            tick0 = jnp.asarray(tick0, jnp.int32)

            def keep(t):
                key = jax.random.fold_in(base, tick0 + t)
                return jax.random.bernoulli(key, 1.0 - self.drop_rate, shape)

            keeps = jax.vmap(keep)(jnp.arange(spikes.shape[0], dtype=jnp.int32))
            spikes = spikes & keeps
        return spikes
