"""`repro.ft.chaos`: host-layer fault plans and the chaos injector.

Where `repro.ft.faults` perturbs the *fabric* (inside the compiled step),
this module perturbs the *host serving loop* around it: transfer
failures, slow devices, and per-tenant lane faults, all scheduled by pump
round so a chaos run is exactly reproducible.

  `FaultEvent`     one scheduled fault: a kind, the pump round it arms
                   at, how many times it fires (consecutive charges — the
                   knob retry-bound tests turn), and for ``slow_device``
                   a stall duration / for ``lane_fault`` a target tenant.
  `FaultPlan`      an immutable set of events; `FaultPlan.mixed` builds
                   the deterministic mixed plan the chaos soak and
                   ``noc_bench --chaos`` use.
  `ChaosInjector`  consumes the plan from inside `ServeEngine` hooks:
                   ``on_transfer``/``on_execute`` raise typed transient
                   errors (or sleep) while charges remain,
                   ``lane_faults`` reports which tenants fault this
                   round.  Every charge fires exactly once; ``exhausted``
                   is the soak's "all faults delivered" check.

The typed error ladder mirrors what the hardened engine handles:
`TransientFaultError` subclasses are retried with backoff;
`RetriesExhaustedError` is what the engine raises once the retry budget
is spent (the caller's signal to intervene).
"""

from __future__ import annotations

import dataclasses
import random as _random
import time
from typing import Callable

FAULT_KINDS = ("transfer_fail", "execute_fail", "slow_device", "lane_fault")


class ChaosError(RuntimeError):
    """Base of every injected-fault error."""


class TransientFaultError(ChaosError):
    """A fault the engine may retry (transfer/execute hiccups)."""


class TransferFault(TransientFaultError):
    """Host->device transfer failed for one chunk."""


class ExecuteFault(TransientFaultError):
    """The batched device step failed for one chunk."""


class RetriesExhaustedError(ChaosError):
    """A transient fault outlived the engine's retry budget."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    round:   first pump round at which the event is armed (charges that
             cannot fire that round — e.g. nothing to transfer — stay
             armed and fire at the next opportunity).
    kind:    one of `FAULT_KINDS`.
    tenant:  target lane, required for (and only for) ``lane_fault``.
    times:   consecutive charges; a transfer_fail with ``times=2`` makes
             the first two transfer attempts of its round fail, then
             heals — which is how tests exercise the retry bound.
    delay_s: stall injected per ``slow_device`` charge.
    """

    round: int
    kind: str
    tenant: str | None = None
    times: int = 1
    delay_s: float = 0.02

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of "
                f"{', '.join(FAULT_KINDS)}"
            )
        if self.round < 1:
            raise ValueError(f"round must be >= 1, got {self.round}")
        if self.times < 1:
            raise ValueError(f"times must be >= 1, got {self.times}")
        if self.delay_s < 0:
            raise ValueError(f"delay_s must be >= 0, got {self.delay_s}")
        if (self.kind == "lane_fault") != (self.tenant is not None):
            raise ValueError("tenant is required for lane_fault events (and only those)")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, fully deterministic schedule of `FaultEvent`s."""

    events: tuple = ()

    def __post_init__(self):
        events = tuple(self.events)
        for ev in events:
            if not isinstance(ev, FaultEvent):
                raise TypeError(f"FaultPlan events must be FaultEvent, got {type(ev)}")
        object.__setattr__(self, "events", events)

    def __len__(self) -> int:
        return len(self.events)

    def total_charges(self) -> int:
        return sum(ev.times for ev in self.events)

    def kinds(self) -> dict:
        out: dict = {}
        for ev in self.events:
            out[ev.kind] = out.get(ev.kind, 0) + ev.times
        return out

    @classmethod
    def mixed(
        cls,
        tenants,
        rounds: int,
        seed: int = 0,
        intensity: float = 0.3,
        max_times: int = 2,
        start_round: int = 2,
        delay_s: float = 0.01,
    ) -> "FaultPlan":
        """The default mixed plan: every kind, spread over ``rounds``.

        Deterministic in (tenants, rounds, seed).  ``max_times`` is kept
        at or below the engine's default retry budget so a mixed soak is
        guaranteed recoverable; one event of every kind is always
        included even at low intensity.
        """
        tenants = list(tenants)
        if not tenants:
            raise ValueError("mixed plan needs at least one tenant for lane faults")
        if rounds < len(FAULT_KINDS):
            raise ValueError(f"need rounds >= {len(FAULT_KINDS)}, got {rounds}")
        start_round = min(start_round, rounds)
        rng = _random.Random(seed)
        events = []
        seen_kinds = set()
        # every event lands inside [start_round, rounds]: a driver that
        # pumps `rounds` times with work present sees the full plan fire
        for r in range(start_round, rounds + 1):
            if rng.random() >= intensity:
                continue
            kind = rng.choice(FAULT_KINDS)
            seen_kinds.add(kind)
            events.append(
                FaultEvent(
                    round=r,
                    kind=kind,
                    tenant=rng.choice(tenants) if kind == "lane_fault" else None,
                    times=rng.randint(1, max_times),
                    delay_s=delay_s,
                )
            )
        # guarantee full kind coverage at deterministic in-range rounds
        for i, kind in enumerate(FAULT_KINDS):
            if kind not in seen_kinds:
                events.append(
                    FaultEvent(
                        round=min(start_round + i, rounds),
                        kind=kind,
                        tenant=tenants[i % len(tenants)] if kind == "lane_fault" else None,
                        times=1,
                        delay_s=delay_s,
                    )
                )
        events.sort(key=lambda ev: (ev.round, FAULT_KINDS.index(ev.kind)))
        return cls(events=tuple(events))


class ChaosInjector:
    """Consumes a `FaultPlan` from inside the serving loop's hooks.

    Each event carries ``times`` charges; a charge fires at most once and
    only at/after its event's round, so a full run delivers exactly
    ``plan.total_charges()`` faults regardless of retry interleaving.

    sleep: injectable stall (tests pass a fake that advances their fake
    clock instead of blocking the suite).
    """

    def __init__(self, plan: FaultPlan, sleep: Callable[[float], None] = time.sleep):
        self.plan = plan
        self.sleep = sleep
        self._charges = [ev.times for ev in plan.events]
        self.injected: dict = {}  # kind -> charges fired

    # ---- bookkeeping ------------------------------------------------------

    def _armed(self, round_: int, kind: str):
        for i, ev in enumerate(self.plan.events):
            if ev.kind == kind and self._charges[i] > 0 and round_ >= ev.round:
                return i, ev
        return None

    def _fire(self, i: int, ev: FaultEvent) -> None:
        self._charges[i] -= 1
        self.injected[ev.kind] = self.injected.get(ev.kind, 0) + 1

    def exhausted(self) -> bool:
        """True once every scheduled charge has fired."""
        return not any(self._charges)

    def injected_total(self) -> int:
        return sum(self.injected.values())

    # ---- engine hooks -----------------------------------------------------

    def on_transfer(self, round_: int) -> None:
        """Called before each host->device transfer; may raise."""
        hit = self._armed(round_, "transfer_fail")
        if hit is not None:
            i, ev = hit
            self._fire(i, ev)
            raise TransferFault(
                f"injected transfer failure (round {ev.round}, "
                f"{self._charges[i]} charge(s) left)"
            )

    def on_execute(self, round_: int) -> None:
        """Called before each batched device step; may raise or stall."""
        hit = self._armed(round_, "execute_fail")
        if hit is not None:
            i, ev = hit
            self._fire(i, ev)
            raise ExecuteFault(
                f"injected execute failure (round {ev.round}, "
                f"{self._charges[i]} charge(s) left)"
            )
        hit = self._armed(round_, "slow_device")
        if hit is not None:
            i, ev = hit
            self._fire(i, ev)
            self.sleep(ev.delay_s)

    def lane_faults(self, round_: int) -> list:
        """Lane-fault events firing this round (one charge each per pump)."""
        out = []
        for i, ev in enumerate(self.plan.events):
            if ev.kind == "lane_fault" and self._charges[i] > 0 and round_ >= ev.round:
                self._fire(i, ev)
                out.append(ev)
        return out
