"""ft subsystem."""
