"""`repro.ft`: fault injection and fault-tolerant execution.

Two layers, one seed-deterministic story:

  fabric  `repro.ft.faults.FaultModel` - dead cores, dropped events,
          corrupted CAM entries as pure transforms compiled into an
          `InterfaceSession` (`Interface.compile(params, fault=...)`),
          so faulted runs stay inside the one jitted step.
  host    `repro.ft.chaos` - `FaultPlan`/`ChaosInjector` raising/stalling
          at configured `ServeEngine` pump rounds (transfer failures,
          slow devices, per-tenant lane faults), with the typed error
          ladder the hardened engine retries/surfaces.

The seed-era training runner (checkpoint/resume, `Watchdog`,
`FailureInjector`) lives in `repro.ft.runner`, its counters now on
`repro.obs.metrics`.
"""

from repro.ft.chaos import (
    FAULT_KINDS,
    ChaosError,
    ChaosInjector,
    ExecuteFault,
    FaultEvent,
    FaultPlan,
    RetriesExhaustedError,
    TransferFault,
    TransientFaultError,
)
from repro.ft.faults import FaultModel

__all__ = [
    "FAULT_KINDS",
    "ChaosError",
    "ChaosInjector",
    "ExecuteFault",
    "FaultEvent",
    "FaultModel",
    "FaultPlan",
    "RetriesExhaustedError",
    "TransferFault",
    "TransientFaultError",
]
