"""AdamW with schedules, global-norm clipping and low-precision moments.

Pure-JAX (no optax).  Moments inherit each parameter's sharding, so with
FSDP param specs the optimizer state is ZeRO-sharded for free.  The
`moment_dtype` option (bf16 for the 236B/398B configs) halves optimizer
HBM - the 8-bit-Adam-style practice noted in DESIGN.md §4.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: str = "float32"


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(cfg: AdamWConfig, params) -> AdamWState:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1 - cfg.b2)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), metrics
