"""optim subsystem."""
