"""`repro.traffic` - registered spike-traffic scenarios.

See `repro.traffic.scenarios` for the catalog and the registry contract;
the pattern mirrors `repro.interface.registry` (named entries registered
at import, new scenarios plug in via `register_scenario` without editing
consumers).
"""

from repro.traffic.scenarios import (  # noqa: F401
    SCENARIOS,
    ScenarioSpec,
    expected_rate,
    generate,
    get_scenario,
    register_scenario,
    scenario_names,
)

__all__ = [
    "SCENARIOS",
    "ScenarioSpec",
    "expected_rate",
    "generate",
    "get_scenario",
    "register_scenario",
    "scenario_names",
]
