"""Registered spike-traffic scenarios for driving the core-interface fabric.

The paper's headline claims are workload-dependent: the hierarchical
arbiter tree wins in *sparse-event* mode while ring schemes favor
full-frame bursts, and the NoC/CAM accounting depends on how spatially
concentrated the traffic is.  Every benchmark and test used to drive the
fabric with one i.i.d. Bernoulli pattern; this module makes the workload
a first-class, registered axis instead.

A scenario is a jit-able generator ``(key, ticks, cores,
neurons_per_core, **params) -> (ticks, cores, neurons_per_core) bool``
plus expected-rate metadata, bundled in a :class:`ScenarioSpec` and
registered under a name (same pattern as `repro.interface.registry`):

    from repro import traffic

    spikes = traffic.generate("sparse_poisson", seed=0, ticks=64, shape=cfg)
    traffic.expected_rate("sparse_poisson", cfg.cores, cfg.neurons_per_core)

Built-ins (registered at import, like the arbiter/CAM/NoC schemes):

  sparse_poisson      i.i.d. low-rate Bernoulli - the paper's sparse mode
  synchronized_burst  near-silent frames punctuated by full-fabric bursts
  hotspot_core        a few hot cores against a cold background
  clustered           rate-coded cluster gating aligned with the
                      `noc.placement` hidden-cluster structure
  dvs_trace           thinned DVS-like replay: a moving edge sweeping the
                      flat neuron space over sensor background noise
  mixture             per-tick categorical mix of registered scenarios

Generators are pure functions of the PRNG key with static shapes, so they
can be called under ``jax.jit`` (shape arguments static) or composed into
scan-based harnesses.  ``expected_rate`` returns the analytic mean spike
probability for the merged parameters - the conformance and benchmark
layers use it to sanity-check generated traffic and to label sweeps.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from repro.interface.registry import SchemeRegistry

SCENARIOS = SchemeRegistry("traffic scenario")


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One registered traffic scenario.

    generate:      ``(key, ticks, cores, neurons_per_core, **params)`` ->
                   (ticks, cores, neurons_per_core) bool spike raster.
                   Pure jax function of ``key``; shapes and params are
                   static, so it is jit-able.
    expected_rate: ``(params, cores, neurons_per_core)`` -> analytic mean
                   spike probability of the raster those params produce.
    defaults:      full parameter set; `generate(...)` overrides merge
                   into (and are validated against) these keys.
    """

    name: str
    generate: Callable[..., jnp.ndarray]
    expected_rate: Callable[[Mapping[str, Any], int, int], float]
    defaults: Mapping[str, Any]
    description: str = ""


def register_scenario(name: str, spec: ScenarioSpec, *, overwrite: bool = False) -> ScenarioSpec:
    """Register a traffic scenario (see :class:`ScenarioSpec`)."""
    if not isinstance(spec, ScenarioSpec):
        raise TypeError(f"expected a ScenarioSpec, got {type(spec).__name__}")
    if spec.name != name:
        raise ValueError(f"spec.name {spec.name!r} does not match registration name {name!r}")
    return SCENARIOS.register(name, spec, overwrite=overwrite)


def get_scenario(name: str) -> ScenarioSpec:
    """Resolve a scenario name (KeyError lists the registered names)."""
    return SCENARIOS.get(name)


def scenario_names() -> tuple[str, ...]:
    """All registered scenario names, sorted."""
    return SCENARIOS.names()


def _shape_of(shape) -> tuple[int, int]:
    """Accept (cores, neurons_per_core) or any config exposing those fields."""
    if hasattr(shape, "cores") and hasattr(shape, "neurons_per_core"):
        return int(shape.cores), int(shape.neurons_per_core)
    cores, n = shape
    return int(cores), int(n)


def _resolve_params(spec: ScenarioSpec, overrides: Mapping[str, Any]) -> dict:
    unknown = sorted(set(overrides) - set(spec.defaults))
    if unknown:
        raise ValueError(
            f"unknown parameter(s) {', '.join(unknown)} for scenario {spec.name!r}; "
            f"valid: {', '.join(sorted(spec.defaults))}"
        )
    return {**spec.defaults, **overrides}


def generate(name: str, seed, ticks: int, shape, **overrides) -> jnp.ndarray:
    """Generate a (ticks, cores, neurons_per_core) bool spike raster.

    seed:  int or a `jax.random` PRNG key.
    shape: (cores, neurons_per_core) or a config exposing those fields.
    """
    spec = get_scenario(name)
    params = _resolve_params(spec, overrides)
    cores, n = _shape_of(shape)
    key = jax.random.PRNGKey(seed) if isinstance(seed, int) else seed
    out = spec.generate(key, int(ticks), cores, n, **params)
    if out.shape != (ticks, cores, n) or out.dtype != jnp.bool_:
        raise ValueError(
            f"scenario {name!r} produced {out.dtype} array of shape {out.shape}; "
            f"expected bool ({ticks}, {cores}, {n})"
        )
    return out


def expected_rate(name: str, cores: int, neurons_per_core: int, **overrides) -> float:
    """Analytic mean spike probability for the merged parameters."""
    spec = get_scenario(name)
    params = _resolve_params(spec, overrides)
    return float(spec.expected_rate(params, int(cores), int(neurons_per_core)))


# ---------------------------------------------------------------------------
# Built-in generators
# ---------------------------------------------------------------------------


def sparse_poisson(key, ticks, cores, neurons_per_core, *, rate=0.02):
    """i.i.d. Bernoulli(rate): the paper's sparse-event operating mode."""
    return jax.random.bernoulli(key, rate, (ticks, cores, neurons_per_core))


def synchronized_burst(
    key, ticks, cores, neurons_per_core, *, period=4, duty=1, burst_rate=0.9, background=0.005
):
    """Near-silent frames punctuated by fabric-wide synchronized bursts.

    Every ``period`` ticks, ``duty`` consecutive ticks are burst frames in
    which each neuron fires with ``burst_rate``; the remaining frames fire
    at ``background``.  This is the frame-coded regime where token rings
    amortize a full sweep and tree arbiters pay their worst case.
    """
    if not 1 <= duty <= period:
        raise ValueError(f"duty={duty} must be in [1, period={period}]")
    k_b, k_q = jax.random.split(key)
    bursting = (jnp.arange(ticks) % period) < duty
    p = jnp.where(bursting, burst_rate, background)[:, None, None]
    return jax.random.uniform(k_q, (ticks, cores, neurons_per_core), minval=0.0, maxval=1.0) < p


def hotspot_core(key, ticks, cores, neurons_per_core, *, hot_cores=1, hot_rate=0.5, cold_rate=0.01):
    """A few saturated cores against a cold fabric (seed-chosen hot set).

    Stresses single-arbiter backlog and the NoC links around the hotspot
    while the rest of the fabric idles.
    """
    if not 1 <= hot_cores <= cores:
        raise ValueError(f"hot_cores={hot_cores} must be in [1, cores={cores}]")
    k_h, k_q = jax.random.split(key)
    hot_idx = jax.random.permutation(k_h, cores)[:hot_cores]
    hot = jnp.zeros((cores,), bool).at[hot_idx].set(True)
    p = jnp.where(hot, hot_rate, cold_rate)[None, :, None]
    return jax.random.uniform(k_q, (ticks, cores, neurons_per_core), minval=0.0, maxval=1.0) < p


def clustered(key, ticks, cores, neurons_per_core, *, cluster_size=16, active_prob=0.25, rate=0.5):
    """Rate-coded cluster gating over the flat global neuron space.

    Neurons form contiguous clusters of ``cluster_size`` global ids - the
    same hidden-cluster structure `noc.placement.clustered_connectivity`
    wires (unscrambled), so cluster-local wiring sees correlated sources.
    Each tick every cluster is independently gated on with
    ``active_prob``; neurons in an active cluster fire with ``rate``.
    """
    if cluster_size < 1:
        raise ValueError(f"cluster_size={cluster_size} must be >= 1")
    total = cores * neurons_per_core
    num_clusters = -(-total // cluster_size)  # ceil
    k_g, k_q = jax.random.split(key)
    gates = jax.random.bernoulli(k_g, active_prob, (ticks, num_clusters))
    cluster_of = jnp.arange(total) // cluster_size
    gate_per_neuron = gates[:, cluster_of]  # (ticks, total)
    fire = jax.random.bernoulli(k_q, rate, (ticks, total))
    return (gate_per_neuron & fire).reshape(ticks, cores, neurons_per_core)


def dvs_trace(
    key,
    ticks,
    cores,
    neurons_per_core,
    *,
    edge_frac=0.08,
    drift=0.05,
    edge_rate=0.8,
    noise_rate=0.005,
    thin=0.5,
):
    """Thinned DVS-like trace replay: a moving edge over sensor noise.

    A contiguous window of ``edge_frac`` of the flat neuron space (the
    moving contrast edge of a DVS recording) sweeps ``drift`` of the space
    per tick, firing at ``edge_rate``; everything else emits
    ``noise_rate`` background events.  The whole trace is then *thinned* -
    every event kept independently with probability ``thin`` - the
    standard trick for replaying a recorded event stream at a reduced
    load.  Deterministic in the key, spatially correlated, non-stationary.
    """
    total = cores * neurons_per_core
    width = max(1, int(round(edge_frac * total)))
    stride = max(1, int(round(drift * total)))
    start = (jnp.arange(ticks) * stride) % total  # (ticks,) window start
    offset = (jnp.arange(total)[None, :] - start[:, None]) % total
    on_edge = offset < width  # (ticks, total)
    p = jnp.where(on_edge, edge_rate, noise_rate) * thin
    raw = jax.random.uniform(key, (ticks, total), minval=0.0, maxval=1.0) < p
    return raw.reshape(ticks, cores, neurons_per_core)


def _burst_expected_rate(params, cores, neurons_per_core):
    frac = params["duty"] / params["period"]
    return frac * params["burst_rate"] + (1.0 - frac) * params["background"]


def _hotspot_expected_rate(params, cores, neurons_per_core):
    hot = params["hot_cores"]
    return (hot * params["hot_rate"] + (cores - hot) * params["cold_rate"]) / cores


def _dvs_expected_rate(params, cores, neurons_per_core):
    total = cores * neurons_per_core
    w = max(1, int(round(params["edge_frac"] * total))) / total
    return params["thin"] * (w * params["edge_rate"] + (1.0 - w) * params["noise_rate"])


def mixture(
    key,
    ticks,
    cores,
    neurons_per_core,
    *,
    components=(("sparse_poisson", 0.7), ("synchronized_burst", 0.3)),
):
    """Per-tick categorical mixture of registered scenarios.

    components: ((name, weight), ...) - each tick is drawn from one
    component (chosen with probability proportional to its weight) using
    that component's registered defaults.  Nested mixtures are rejected.
    """
    names, weights = _mixture_components(components)
    k_sel, *k_parts = jax.random.split(key, 1 + len(names))
    frames = jnp.stack(
        [
            get_scenario(name).generate(
                k, ticks, cores, neurons_per_core, **get_scenario(name).defaults
            )
            for name, k in zip(names, k_parts)
        ]
    )
    p = jnp.asarray(weights) / sum(weights)
    choice = jax.random.choice(k_sel, len(names), shape=(ticks,), p=p)
    return frames[choice, jnp.arange(ticks)]


def _mixture_components(components) -> tuple[tuple[str, ...], tuple[float, ...]]:
    if not components:
        raise ValueError("mixture needs at least one (name, weight) component")
    names, weights = [], []
    for name, weight in components:
        if name == "mixture":
            raise ValueError("mixture components must be leaf scenarios, not 'mixture'")
        get_scenario(name)  # raises with the registered list on unknown names
        if not weight > 0:
            raise ValueError(f"component {name!r} weight must be > 0, got {weight}")
        names.append(name)
        weights.append(float(weight))
    return tuple(names), tuple(weights)


def _mixture_expected_rate(params, cores, neurons_per_core):
    names, weights = _mixture_components(params["components"])
    total_w = sum(weights)
    return sum(
        w / total_w * expected_rate(name, cores, neurons_per_core)
        for name, w in zip(names, weights)
    )


# ---------------------------------------------------------------------------
# Registration (at import, like the arbiter/CAM/NoC built-ins)
# ---------------------------------------------------------------------------

register_scenario(
    "sparse_poisson",
    ScenarioSpec(
        name="sparse_poisson",
        generate=sparse_poisson,
        expected_rate=lambda p, c, n: p["rate"],
        defaults={"rate": 0.02},
        description="i.i.d. low-rate Bernoulli (the paper's sparse-event mode)",
    ),
)

register_scenario(
    "synchronized_burst",
    ScenarioSpec(
        name="synchronized_burst",
        generate=synchronized_burst,
        expected_rate=_burst_expected_rate,
        defaults={"period": 4, "duty": 1, "burst_rate": 0.9, "background": 0.005},
        description="near-silent frames punctuated by fabric-wide bursts",
    ),
)

register_scenario(
    "hotspot_core",
    ScenarioSpec(
        name="hotspot_core",
        generate=hotspot_core,
        expected_rate=_hotspot_expected_rate,
        defaults={"hot_cores": 1, "hot_rate": 0.5, "cold_rate": 0.01},
        description="a few saturated cores against a cold fabric",
    ),
)

register_scenario(
    "clustered",
    ScenarioSpec(
        name="clustered",
        generate=clustered,
        expected_rate=lambda p, c, n: p["active_prob"] * p["rate"],
        defaults={"cluster_size": 16, "active_prob": 0.25, "rate": 0.5},
        description="rate-coded cluster gating aligned with noc.placement clusters",
    ),
)

register_scenario(
    "dvs_trace",
    ScenarioSpec(
        name="dvs_trace",
        generate=dvs_trace,
        expected_rate=_dvs_expected_rate,
        defaults={
            "edge_frac": 0.08,
            "drift": 0.05,
            "edge_rate": 0.8,
            "noise_rate": 0.005,
            "thin": 0.5,
        },
        description="thinned DVS-like replay: a moving edge over sensor noise",
    ),
)

register_scenario(
    "mixture",
    ScenarioSpec(
        name="mixture",
        generate=mixture,
        expected_rate=_mixture_expected_rate,
        defaults={"components": (("sparse_poisson", 0.7), ("synchronized_burst", 0.3))},
        description="per-tick categorical mixture of registered scenarios",
    ),
)