"""Distributed-optimization collectives: compressed gradient all-reduce.

int8 block-quantized psum with error feedback - the cross-pod gradient
sync trick for multi-pod training, where the pod-to-pod links are the
scarce resource.  4x fewer bytes on the wire; error feedback keeps the
quantization noise from biasing convergence (residual carried between
steps, standard EF-SGD analysis applies).

Usage (multi-pod): grads within a pod reduce in full precision (cheap ICI);
`compressed_psum(..., axis="pod")` handles the expensive hop.  Tests
verify (a) exactness bounds per call and (b) EF residual convergence.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

BLOCK = 256


def quantize_int8(x: jnp.ndarray):
    """Per-block symmetric int8 quantization.  x: flat f32 (N,)."""
    n = x.shape[0]
    pad = (-n) % BLOCK
    xp = jnp.pad(x, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xp), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xp / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q, scale, n):
    x = q.astype(jnp.float32) * scale
    return x.reshape(-1)[:n]


def compressed_psum(x: jnp.ndarray, axis: str):
    """int8-compressed psum over a named axis (inside shard_map)."""
    n = x.size
    flat = x.reshape(-1).astype(jnp.float32)
    q, scale = quantize_int8(flat)
    # psum int8 payloads in int32 to avoid overflow across shards
    qsum = jax.lax.psum(q.astype(jnp.int32), axis)
    ssum = jax.lax.psum(scale, axis)  # conservative shared scale path
    nshards = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    # each shard contributed q_i * scale_i; approximating scale_i ~ mean
    mean_scale = ssum / nshards
    out = dequantize_int8(qsum, mean_scale, n)
    return out.reshape(x.shape)


def compressed_psum_exact_scales(x: jnp.ndarray, axis: str):
    """All-gather per-shard scales for exact per-block dequantization.

    Wire carries int8 payloads + f32 block scales (~4x less than f32).
    The final pmean re-establishes replicated typing for shard_map (the
    summed gather is already shard-invariant; the pmean is a no-op on
    values)."""
    n = x.size
    flat = x.reshape(-1).astype(jnp.float32)
    q, scale = quantize_int8(flat)
    qg = jax.lax.all_gather(q, axis)            # (S, blocks, BLOCK)
    sg = jax.lax.all_gather(scale, axis)        # (S, blocks, 1)
    out = jnp.sum(qg.astype(jnp.float32) * sg, axis=0)
    out = jax.lax.pmean(out, axis)  # values already equal; fixes vma typing
    return out.reshape(-1)[:n].reshape(x.shape)


def make_ef_sync(axis: str, exact: bool = True):
    """Error-feedback compressed sync: (grad, residual) -> (synced, new_res)."""
    psum_fn = compressed_psum_exact_scales if exact else compressed_psum

    def sync(g: jnp.ndarray, residual: jnp.ndarray):
        corrected = g + residual
        synced = psum_fn(corrected, axis)
        nshards = jax.lax.psum(jnp.ones((), jnp.float32), axis)
        synced = synced / nshards
        # local quantization error -> carried to the next step
        q, s = quantize_int8(corrected.reshape(-1).astype(jnp.float32))
        sent = dequantize_int8(q, s, corrected.size).reshape(corrected.shape)
        new_res = corrected - sent
        return synced, new_res

    return sync


def pod_sync_grads(grads, residuals, axis: str = "pod", exact: bool = True):
    """Compress-sync a gradient pytree across `axis` (call inside shard_map).

    Returns (synced_grads, new_residuals).
    """
    sync = make_ef_sync(axis, exact)
    pairs = jax.tree.map(sync, grads, residuals)
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2
    synced = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
    new_res = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)
    return synced, new_res
