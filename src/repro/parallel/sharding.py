"""Logical-axis sharding rules: parameter/batch/cache PartitionSpecs per arch.

Mesh contract (launch/mesh.py):
  single-pod  (data=16, model=16)            - 256 chips
  multi-pod   (pod=2, data=16, model=16)     - 512 chips; `pod` is pure DP

Parameter placement = TP over `model` + FSDP over `data` (GSPMD inserts
the use-site all-gathers; optimizer state inherits the same sharding, so
ZeRO-1/3 falls out of the specs).  Per-family rules:

  dense/moe/hybrid attention   column-TP wq/wk/wv, row-TP wo over `model`
                               (kv heads < model size -> kv replicated at
                               compute time, see blocks.attention_apply)
  attn_shard == "sequence"     weights replicated over `model`; activations
                               sequence-sharded (llama3.2: 24 heads % 16)
  MoE experts                  EP: leading expert dim over `model`
  mamba                        d_inner over `model`
  rwkv time-mix                replicated over `model` (40 heads), FSDP
                               over `data`; channel-mix FFN + vocab TP
  embed / lm_head              vocab over `model`, d_model over `data`

Caches: KV/latent caches are sequence-sharded over `model` (uniform rule -
kv-head counts rarely divide the axis); SSM/RWKV states shard d_inner /
replicate per DESIGN.md §4.
"""

from __future__ import annotations

import re

import jax
from jax.sharding import PartitionSpec as P

from repro.models.blocks import ShardCtx
from repro.models.config import ModelConfig


def make_shard_ctx(mesh, cfg: ModelConfig | None = None) -> ShardCtx:
    axes = mesh.axis_names
    data_axes = ("pod", "data") if "pod" in axes else ("data",)
    return ShardCtx(data_axes=data_axes, model_axis="model",
                    model_size=mesh.shape["model"], enabled=True,
                    axis_sizes=tuple(mesh.shape.items()))


def sanitize_spec(spec: P, shape, ctx: ShardCtx) -> P:
    """Drop axis assignments whose size doesn't divide the dimension.

    Keeps the rules table simple: hubert's 504-entry unit vocabulary, tiny
    smoke dims, etc. silently fall back to replication per-dimension."""
    sizes = dict(ctx.axis_sizes)
    new = []
    for d, ax in enumerate(spec):
        if ax is None:
            new.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        prod = 1
        for a in axes:
            prod *= sizes.get(a, 1)
        new.append(ax if shape[d] % prod == 0 else None)
    return P(*new)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_spec(path_s: str, ndim: int, cfg: ModelConfig, dp) -> P:
    """PartitionSpec for one parameter (without the layer-stack dim)."""
    seq = cfg.attn_shard == "sequence"
    name = path_s.rsplit("/", 1)[-1]
    in_mix = "/mix/" in path_s or path_s.endswith("mix")
    in_ffn = "/ffn/" in path_s
    rwkv_tm = cfg.family == "rwkv" and in_mix
    rwkv_cm = cfg.family == "rwkv" and in_ffn

    # ---- top-level ---------------------------------------------------------
    if name == "embed":
        return P("model", dp)
    if name == "lm_head":
        return P(dp, "model")
    if name in ("frontend_proj", "mask_embed"):
        return P(dp, None) if ndim == 2 else P(None)

    # ---- rwkv --------------------------------------------------------------
    if rwkv_tm:
        if ndim == 2 and name in ("wr", "wk", "wv", "wg", "wo"):
            return P(dp, None)
        if name in ("maa_w1", "decay_w1"):
            return P(dp, None)
        if name == "maa_w2":
            return P(None, None, None)
        if name == "decay_w2":
            return P(None, dp)
        return P(*([None] * ndim))
    if rwkv_cm:
        if name == "wk":
            return P(dp, "model")
        if name == "wv":
            return P("model", dp)
        if name == "wr":
            return P(dp, None)
        return P(*([None] * ndim))

    # ---- mamba -------------------------------------------------------------
    if name == "w_in":
        return P(dp, "model")
    if name == "conv_w":
        return P(None, "model")
    if name in ("conv_b", "dt_bias", "d_skip"):
        return P("model")
    if name == "w_bc" or name == "w_dt_a":
        return P("model", None)
    if name == "w_dt_b":
        return P(None, "model")
    if name == "a_log":
        return P("model", None)
    if name == "w_out":
        return P("model", dp)

    # ---- MoE (3D expert weights; 2D shared/dense fall through to MLP) ------
    if name == "router":
        return P(dp, None)
    if name.endswith("_scale"):
        return P("model", None, None)
    if ndim == 3 and name in ("w_gate", "w_up"):
        return P("model", dp, None)
    if ndim == 3 and name == "w_down":
        return P("model", None, dp)

    # ---- MLP ----------------------------------------------------------------
    if name in ("w_gate", "w_up"):
        return P(dp, None) if seq else P(dp, "model")
    if name == "w_down":
        return P(None, dp) if seq else P("model", dp)

    # ---- attention / MLA ----------------------------------------------------
    if name in ("wq", "wk", "wv", "wq_b", "wk_b", "wv_b"):
        return P(dp, None) if seq else P(dp, "model")
    if name in ("wq_a", "wkv_a"):
        return P(dp, None)
    if name == "wo":
        return P(None, dp) if seq else P("model", dp)

    # ---- norms & everything small ------------------------------------------
    return P(*([None] * ndim))


def params_pspecs(params, cfg: ModelConfig, ctx: ShardCtx):
    """Pytree of PartitionSpecs matching `params` (layer-stacked aware)."""
    dp = ctx.batch_spec

    def one(path, leaf):
        s = _path_str(path)
        stacked = s.startswith("groups/")
        ndim = leaf.ndim - (1 if stacked else 0)
        spec = param_spec(s, ndim, cfg, dp)
        if cfg.serve_tp_only:
            # serving: drop the FSDP (data) dimension from weight specs so
            # no per-step weight all-gathers are needed (params must fit
            # the TP shard - pair with a wider model axis and/or int8)
            spec = P(*(None if a == dp else a for a in spec))
        if stacked:
            spec = P(None, *spec)
        return sanitize_spec(spec, leaf.shape, ctx)

    return jax.tree_util.tree_map_with_path(one, params)


def batch_pspecs(batch_shapes: dict, cfg: ModelConfig, ctx: ShardCtx):
    """Input batch PartitionSpecs (tokens/labels/frames/...)."""
    dp = ctx.batch_spec
    specs = {}
    for k, v in batch_shapes.items():
        if hasattr(v, "ndim"):
            nd = v.ndim
        else:
            nd = len(v)
        specs[k] = P(dp, *([None] * (nd - 1)))
    return specs


def cache_pspecs(cache, cfg: ModelConfig, ctx: ShardCtx):
    """Decode-cache PartitionSpecs: sequence-sharded KV, sharded SSM state."""
    dp = ctx.batch_spec

    def one(path, leaf):
        s = _path_str(path)
        name = s.rsplit("/", 1)[-1]
        # leading dim is the layer stack
        if name in ("k", "v"):          # (L, B, S, KH, D) -> shard S
            spec = P(None, dp, "model", None, None)
        elif name in ("ckv", "kr"):     # (L, B, S, d) -> shard S
            spec = P(None, dp, "model", None)
        elif name == "ssm":             # (L, B, di, N) -> shard di
            spec = P(None, dp, "model", None)
        elif name == "conv":            # (L, B, K-1, di) -> shard di
            spec = P(None, dp, None, "model")
        elif name == "wkv":             # (L, B, H, D, D) - replicate heads
            spec = P(None, dp, None, None, None)
        else:
            spec = P(None, dp, *([None] * (leaf.ndim - 2)))
        return sanitize_spec(spec, leaf.shape, ctx)

    return jax.tree_util.tree_map_with_path(one, cache)


def leading_axis_specs(tree, axis: str):
    """PartitionSpec pytree sharding every leaf's leading dim over `axis`.

    The shard-by-leading-dim rule used by the interface session's chip
    sharding (`InterfaceSession.run(shard="chips")`): every per-chip
    operand is stacked ``(chips, ...)`` and split across the 1D chip mesh.
    """
    return jax.tree.map(lambda _: P(axis), tree)


def to_named(specs_tree, mesh):
    from jax.sharding import NamedSharding
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs_tree,
                        is_leaf=lambda x: isinstance(x, P))
