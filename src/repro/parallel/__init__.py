"""parallel subsystem."""
