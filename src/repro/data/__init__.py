"""data subsystem."""
