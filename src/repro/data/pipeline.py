"""Deterministic synthetic data pipeline: sharded, resumable, per-arch.

Produces the right batch structure for every architecture family (tokens /
audio frames + mask / text + image embeds / SNN event rasters).  The
stream is a pure function of (seed, step), so:

  * any worker can regenerate any step - restart/elastic-rescale safe;
  * the iterator "state" checkpointed with the model is just the step
    counter (`ckpt/manager.py` stores it alongside params);
  * per-host sharding falls out of slicing the step's global batch by
    host id (single-host here, but the indexing is global-first).

Synthetic text is a mixture of Zipfian unigrams and copy runs, so the CE
loss has learnable structure (quickstart shows it dropping) without any
external dataset.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    copy_frac: float = 0.5      # fraction of positions in copy runs
    zipf_alpha: float = 1.1


def _zipf_logits(vocab: int, alpha: float):
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    return -alpha * jnp.log(ranks)


def synth_tokens(key, batch: int, seq: int, vocab: int,
                 cfg: DataConfig) -> jnp.ndarray:
    k1, k2, k3 = jax.random.split(key, 3)
    base = jax.random.categorical(
        k1, _zipf_logits(vocab, cfg.zipf_alpha)[None, None, :],
        shape=(batch, seq))
    # copy structure: with prob copy_frac, token = token 8 positions back
    copy_mask = jax.random.bernoulli(k2, cfg.copy_frac, (batch, seq))
    shifted = jnp.roll(base, 8, axis=1)
    toks = jnp.where(copy_mask, shifted, base)
    return toks.astype(jnp.int32)


class Pipeline:
    """step -> batch dict for the given architecture."""

    def __init__(self, model_cfg: ModelConfig, data_cfg: DataConfig):
        self.mc = model_cfg
        self.dc = data_cfg
        self._make = jax.jit(self._build, static_argnums=())

    def _key(self, step):
        return jax.random.fold_in(jax.random.PRNGKey(self.dc.seed), step)

    def _build(self, step):
        mc, dc = self.mc, self.dc
        key = self._key(step)
        b, s = dc.global_batch, dc.seq_len
        if mc.frontend.kind == "audio":
            k1, k2, k3 = jax.random.split(key, 3)
            frames = jax.random.normal(k1, (b, s, mc.frontend.d_in),
                                       jnp.float32)
            mask = jax.random.bernoulli(k2, 0.08, (b, s))
            units = jax.random.randint(k3, (b, s), 0, mc.vocab)
            labels = jnp.where(mask, units, -100)   # HuBERT: masked only
            return {"frames": frames, "mask": mask, "labels": labels}
        if mc.frontend.kind == "vision":
            k1, k2 = jax.random.split(key)
            p = max(mc.frontend.max_prefix, 1)
            toks = synth_tokens(k1, b, s, mc.vocab, dc)
            img = jax.random.normal(k2, (b, p, mc.frontend.d_in), jnp.float32)
            labels = jnp.concatenate([toks[:, 1:],
                                      jnp.full((b, 1), -100, jnp.int32)], 1)
            return {"tokens": toks, "image_embeds": img, "labels": labels}
        toks = synth_tokens(key, b, s, mc.vocab, dc)
        labels = jnp.concatenate([toks[:, 1:],
                                  jnp.full((b, 1), -100, jnp.int32)], 1)
        return {"tokens": toks, "labels": labels}

    def batch(self, step: int):
        return self._make(jnp.int32(step))

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def snn_batch(key, batch: int, t_steps: int, d_in: int, n_classes: int,
              rate: float = 0.3):
    """Rate-coded event rasters with class-dependent firing patterns."""
    k1, k2 = jax.random.split(key)
    y = jax.random.randint(k1, (batch,), 0, n_classes)
    proto = jax.random.bernoulli(
        jax.random.PRNGKey(7), 0.5, (n_classes, d_in)).astype(jnp.float32)
    rates = rate * (0.4 + proto[y])                       # (B, d_in)
    x = jax.random.bernoulli(k2, rates[:, None, :],
                             (batch, t_steps, d_in)).astype(jnp.float32)
    return {"x": x, "y": y}
