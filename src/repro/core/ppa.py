"""PPA (Power-Performance-Area) calibration constants for the core-interface models.

The paper (Su et al., 2023) reports closed-form *unit-domain* costs (latency in
two-input-arbiter delays, area in two-input-arbiter equivalents) next to measured
22FDX pre-layout numbers (ns / normalized area) at N = 64 and N = 256.  We treat
the closed forms as ground truth of the *algorithm* and fit a two-point affine
map ``measured = a * units + b`` per (scheme, mode) so the model reproduces the
paper's measured values exactly at the published design points and extrapolates
smoothly elsewhere (Fig. 5).

Everything here is a calibration input, not a claim: see DESIGN.md §2/§7.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Tuple

# ---------------------------------------------------------------------------
# Closed-form unit-domain costs (paper Tables I-III).
# Latency unit = one two-input arbiter delay; area unit = one two-input arbiter.
# ---------------------------------------------------------------------------

SCHEMES = ("binary_tree", "greedy_tree", "token_ring", "hier_ring", "hier_tree")


def sparse_latency_units(scheme: str, n: int) -> float:
    """Average sparse-event latency in arbiter-delay units (Table I)."""
    lg = math.log2(n)
    return {
        "binary_tree": 2.0 * (lg - 1.0),
        "greedy_tree": 2.0 * (lg - 1.0),
        "token_ring": (n + 1) / 2.0,
        "hier_ring": math.sqrt(n),
        "hier_tree": lg,
    }[scheme]


def burst_latency_units(scheme: str, n: int) -> float:
    """Full-frame burst completion latency in arbiter-delay units (Table II)."""
    lg = math.log2(n)
    return {
        "binary_tree": 2.0 * n * (lg - 1.0),
        "greedy_tree": 3.0 * n - 6.0,
        "token_ring": float(n),
        "hier_ring": n + 2.0 * math.sqrt(n),
        "hier_tree": (17.0 / 16.0) * n + 3.0,
    }[scheme]


def area_units(scheme: str, n: int) -> float:
    """Number of two-input arbiters (Table III)."""
    return {
        "binary_tree": n - 1.0,
        "greedy_tree": n - 1.0,
        "token_ring": float(n),
        "hier_ring": n + 2.0 * math.sqrt(n),
        "hier_tree": 3.0 * math.log(n, 4),
    }[scheme]


# ---------------------------------------------------------------------------
# Measured 22FDX pre-layout values at (N=64, N=256) from the paper.
# latency entries are ns; area entries are normalized to one arbiter cell.
# ``None`` = not reported (greedy burst depends on neuron response time).
# ---------------------------------------------------------------------------

MEASURED_SPARSE_NS: Dict[str, Tuple[float, float]] = {
    "binary_tree": (1.7, 2.1),
    "greedy_tree": (1.8, 2.3),
    "token_ring": (25.3, 102.7),
    "hier_ring": (5.7, 9.2),
    "hier_tree": (1.7, 2.0),
}

MEASURED_BURST_NS: Dict[str, Tuple[float, float]] = {
    "binary_tree": (83.7, 436.9),
    "token_ring": (40.5, 178.4),
    "hier_ring": (48.9, 192.9),
    "hier_tree": (47.2, 194.4),
}

MEASURED_AREA_NORM: Dict[str, Tuple[float, float]] = {
    "binary_tree": (72.3, 277.4),
    "greedy_tree": (83.4, 286.7),
    "token_ring": (79.1, 272.5),
    "hier_ring": (89.2, 296.3),
    "hier_tree": (59.4, 192.4),
}

_DESIGN_POINTS = (64, 256)


@dataclasses.dataclass(frozen=True)
class AffineFit:
    """measured = a * units + b, fitted exactly through the two design points."""

    a: float
    b: float

    def __call__(self, units: float) -> float:
        return self.a * units + self.b


def _fit(units_fn: Callable[[str, int], float], scheme: str,
         measured: Dict[str, Tuple[float, float]]) -> AffineFit:
    u0, u1 = (units_fn(scheme, n) for n in _DESIGN_POINTS)
    m0, m1 = measured[scheme]
    if u1 == u0:  # degenerate; fall back to pure scaling
        return AffineFit(a=m0 / u0, b=0.0)
    a = (m1 - m0) / (u1 - u0)
    return AffineFit(a=a, b=m0 - a * u0)


def sparse_ns_fit(scheme: str) -> AffineFit:
    return _fit(sparse_latency_units, scheme, MEASURED_SPARSE_NS)


def burst_ns_fit(scheme: str) -> AffineFit:
    return _fit(burst_latency_units, scheme, MEASURED_BURST_NS)


def area_norm_fit(scheme: str) -> AffineFit:
    return _fit(area_units, scheme, MEASURED_AREA_NORM)


def sparse_latency_ns(scheme: str, n: int) -> float:
    return sparse_ns_fit(scheme)(sparse_latency_units(scheme, n))


def burst_latency_ns(scheme: str, n: int) -> float:
    if scheme == "greedy_tree":
        raise ValueError("paper does not report greedy-tree burst ns "
                         "(depends on neuron response time)")
    return burst_ns_fit(scheme)(burst_latency_units(scheme, n))


def area_normalized(scheme: str, n: int) -> float:
    return area_norm_fit(scheme)(area_units(scheme, n))


# ---------------------------------------------------------------------------
# CAM design points (paper §IV-D).  11-bit entries; arrays of 16 and 512.
# Areas in µm² (post-layout, summed cell areas).
# ---------------------------------------------------------------------------

CAM_BITS = 11
CAM_SPEC_SENSE_BITS = 3  # "last three CAM cells" extracted for speculative sense

CAM_AREA_UM2 = {
    # entries: (baseline, proposed)
    16: (225.3, 245.5),
    512: (7242.1, 7620.6),
}

# Paper-reported relative improvements the behavioural model must reproduce.
CAM_CYCLE_IMPROVEMENT = {16: 0.355, 512: 0.404}   # throughput-equivalent cycle-time cut
CAM_ENERGY_SAVING = {
    "all_match": 0.358,     # feedback control + CSCD
    "all_mismatch": 0.402,  # speculative sense (+CSCD)
    "random": 0.467,        # everything combined
}

# DYNAPs-referenced motivation (paper §I): arbiter + routing memory power share.
CORE_INTERFACE_POWER_SHARE = 0.80


def spec_sense_close_probability(n_bits: int, n_sense: int) -> float:
    """P(current source closed early | entry is MISMATCH), random data.

    Paper §IV-B: probability that at least one of the last ``n_sense`` bits
    mismatches, given the entry mismatches, with uniformly random data.  The
    paper's expression (2^N - 2^(N-n) + 1) / 2^N evaluates to 0.876 for
    N=10, n=3; conditioned on MISMATCH (2^N - 1 mismatching patterns) the
    exact form is (2^N - 2^(N-n)) / (2^N - 1).  We keep the paper's published
    expression so benchmark tables match the paper verbatim.
    """
    return (2.0 ** n_bits - 2.0 ** (n_bits - n_sense) + 1.0) / 2.0 ** n_bits


def spec_sense_close_probability_exact(n_bits: int, n_sense: int) -> float:
    """Exact conditional form (matches Monte-Carlo at every design point).

    The paper's expression above approximates this; they differ by O(2^-N)
    at the paper's N=10 design point but visibly at small N."""
    return (2.0 ** n_bits - 2.0 ** (n_bits - n_sense)) / (2.0 ** n_bits - 1.0)


# ---------------------------------------------------------------------------
# NoC (2D-mesh inter-core transport) behavioural constants.
#
# The paper assumes a routing fabric between cores but only optimizes the
# per-core interface; the mesh model follows the DYNAPs hierarchy (Moradi et
# al., arXiv:1708.04198): per-hop router traversal latency, per-event link
# serialization under contention, and per-traversal energy.  Latencies are ns
# in the same 22FDX-flavoured domain as the arbiter fits above; hop energy is
# expressed in the CAM model-unit domain (one full-window MISMATCH DC
# dissipation) so NoC and CAM energies can be summed into a system total: one
# hop (link drivers + router crossbar) is charged like ~35 CAM mismatch cells.
# Calibration inputs, not claims - see DESIGN.md §2.
# ---------------------------------------------------------------------------

NOC_HOP_LATENCY_NS = 1.2         # router traversal + link flight per hop
NOC_LINK_SERIALIZATION_NS = 0.8  # per event on the most contended link
NOC_HOP_ENERGY = 35.0            # model units per link traversal

# Inter-chip router tier (the DYNAPs R3 level, arXiv:1708.04198 §III):
# chip-to-chip hops leave the die, so they pay pad/SerDes flight time and
# off-chip driver energy - an order of magnitude over an on-chip mesh hop.
# Same unit domains as the on-chip constants so tiers can be summed.
CHIP_HOP_LATENCY_NS = 12.0        # SerDes + package flight per chip hop
CHIP_LINK_SERIALIZATION_NS = 4.0  # per event on the busiest chip link
CHIP_HOP_ENERGY = 350.0           # model units per chip-link traversal

# TPU v5e hardware model used by the roofline analysis (per chip).
TPU_PEAK_FLOPS_BF16 = 197e12      # FLOP/s
TPU_HBM_BW = 819e9                # bytes/s
TPU_ICI_BW = 50e9                 # bytes/s per link
