"""Arbitration architectures for the neuromorphic core output interface.

Implements the five schemes compared in the paper (Tables I-III, Fig. 5):

  binary_tree  - flat binary arbiter tree (Boahen-style)
  greedy_tree  - binary tree with greedy re-grant of hot subtrees
  token_ring   - single token ring over all N neurons
  hier_ring    - two-level hierarchical token ring (HTR, Purohit & Manohar)
  hier_tree    - the paper's HAT: log4(N) levels of shared four-input
                 arbiters, 2 address bits encoded per level, with the
                 asynchronous encoding pipeline holding higher-level grants
                 while a cluster drains.

Two complementary models:

  * closed-form unit-domain costs (re-exported from :mod:`repro.core.ppa`),
  * a mechanistic discrete-event simulator (`simulate`) in pure JAX whose
    emergent latencies match the closed forms (exactly for sparse mode, to
    within a few percent for burst mode - the same gap the paper reports
    between theory and pre-layout simulation).

Scheme dispatch goes through `repro.interface.registry`: each architecture
registers an :class:`ArbiterScheme` bundle of policy callables (grant
selection, grant delay, token update, encode energy) and the simulator is
a single generic event loop over those callables.  A new architecture
plugs in with ``register_arbiter(name, ArbiterScheme(...))`` - no edits to
the simulator or the fabric.

TPU adaptation (DESIGN.md §2): arbitration on a deterministic machine is a
*scheduling policy*, not an analog race.  Ties break by ascending address;
metastability/grant-overlap become testable determinism properties.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import ppa
from repro.interface import registry as interface_registry

SCHEMES = ppa.SCHEMES

# Re-export the closed forms so callers have one import surface.
sparse_latency_units = ppa.sparse_latency_units
burst_latency_units = ppa.burst_latency_units
area_units = ppa.area_units
sparse_latency_ns = ppa.sparse_latency_ns
burst_latency_ns = ppa.burst_latency_ns
area_normalized = ppa.area_normalized

INF = jnp.inf


@dataclasses.dataclass(frozen=True)
class ArbiterContext:
    """Static per-instance quantities shared by every policy callable."""

    n: int
    lg: float               # log2(n)
    sqrt_n: int
    levels: int             # HAT hierarchy levels
    fill: int               # HAT pipeline fill latency (units)
    addrs: jnp.ndarray      # (n,) int32


@dataclasses.dataclass(frozen=True)
class ArbiterScheme:
    """Registry entry: the policy bundle of one arbitration architecture.

    select_key(ctx, tok_hi, tok_lo) -> (n,) float32
        priority key among *arrived* requests; argmin wins the grant.
    grant_delay(ctx, sel, backlog, tok_hi, tok_lo, prev_addr, granted_any)
        -> float32 scalar delay between service start and grant.
    token_update(ctx, sel, taken, tok_hi, tok_lo) -> (tok_hi, tok_lo)
        optional ring-token advance after a grant.
    encode_energy(n, addr_seq) -> float32
        average address-line toggles per event for a grant sequence.
    """

    name: str
    select_key: Callable
    grant_delay: Callable
    encode_energy: Callable
    token_update: Optional[Callable] = None


@dataclasses.dataclass(frozen=True)
class ArbiterConfig:
    """Static description of one arbitration architecture instance."""

    scheme: str
    n: int                      # neurons per core (power of two)
    branching: int = 4          # HAT: four-input arbiter per hierarchy level
    pipeline_fill: int = 3      # HAT: static-HC pipeline fill latency (units)

    def __post_init__(self):
        if self.scheme not in interface_registry.ARBITERS:
            raise ValueError(
                f"unknown scheme {self.scheme!r}; registered arbiters: "
                f"{', '.join(interface_registry.ARBITERS.names())}")
        if self.n & (self.n - 1):
            raise ValueError("n must be a power of two")

    @property
    def levels(self) -> int:
        """HAT hierarchy levels (2 address bits per level)."""
        return max(1, round(math.log(self.n, self.branching)))

    @property
    def addr_bits(self) -> int:
        return int(math.log2(self.n))


# ---------------------------------------------------------------------------
# Generic discrete-event simulation.
#
# State carried through the lax.scan (one step = one granted event):
#   clock        server-free time (units)
#   token_hi/lo  ring token positions (ring schemes)
#   prev_addr    last granted address (cluster-switch penalties, HAT)
#   served       bool mask of granted events
# All scheme-specific decisions are deferred to the registered
# `ArbiterScheme` policies, resolved once per trace from the static name.
# ---------------------------------------------------------------------------


def _ring_dist(frm, to, n):
    return jnp.mod(to - frm, n)


@partial(jax.jit, static_argnames=("entry", "n", "levels", "fill"))
def _simulate(request_times, entry: ArbiterScheme, n: int, levels: int,
              fill: int):
    """Serve every finite request; returns grant_times (inf where no request).

    `entry` (not its name) is the static jit key, so re-registering a
    scheme with ``overwrite=True`` cannot serve stale traces of the old
    policies.
    """
    ctx = ArbiterContext(n=n, lg=float(math.log2(n)),
                         sqrt_n=int(round(math.sqrt(n))), levels=levels,
                         fill=fill, addrs=jnp.arange(n))
    addrs = ctx.addrs
    active = jnp.isfinite(request_times)

    def step(state, _):
        clock, tok_hi, tok_lo, prev_addr, served, granted_any = state
        pending = active & ~served
        arr = jnp.where(pending, request_times, INF)

        # --- selection policy: who is granted next -----------------------
        # If something has arrived, the scheme's priority key decides; if
        # the pipeline is idle, wait for the earliest arrival (addr tiebreak).
        arrived = pending & (arr <= clock)
        any_arrived = jnp.any(arrived)
        key_arrived = jnp.where(arrived, entry.select_key(ctx, tok_hi, tok_lo),
                                INF)
        key_waiting = arr * jnp.float32(n) + addrs
        sel = jnp.where(any_arrived, jnp.argmin(key_arrived),
                        jnp.argmin(key_waiting))

        sel_arr = request_times[sel]
        start = jnp.maximum(sel_arr, clock)
        backlog = clock > sel_arr  # pipeline already busy when the event arrived

        delay = entry.grant_delay(ctx, sel, backlog, tok_hi, tok_lo,
                                  prev_addr, granted_any).astype(jnp.float32)
        grant = start + delay

        # --- state update -------------------------------------------------
        taken = pending[sel]
        if entry.token_update is not None:
            tok_hi, tok_lo = entry.token_update(ctx, sel, taken, tok_hi, tok_lo)
        served = served.at[sel].set(served[sel] | taken)
        clock = jnp.where(taken, grant, clock)
        prev_addr = jnp.where(taken, sel, prev_addr)
        granted_any = granted_any | taken
        out = (sel, jnp.where(taken, grant, INF))
        return (clock, tok_hi, tok_lo, prev_addr, served, granted_any), out

    init = (jnp.float32(0.0), jnp.int32(0), jnp.int32(0), jnp.int32(0),
            jnp.zeros(n, dtype=bool), jnp.bool_(False))
    (_, _, _, _, _, _), (sel_seq, grant_seq) = jax.lax.scan(step, init, None, length=n)

    grant_times = jnp.full(n, INF, dtype=jnp.float32)
    # steps beyond the active count re-select served events; .min keeps first.
    grant_times = grant_times.at[sel_seq].min(grant_seq)
    return grant_times


class Arbiter:
    """Discrete-event model of one core-output arbiter."""

    def __init__(self, config: ArbiterConfig):
        self.config = config

    def simulate(self, request_times) -> jnp.ndarray:
        """request_times: (n,) float, inf = no request → grant_times (n,)."""
        request_times = jnp.asarray(request_times, dtype=jnp.float32)
        if request_times.shape != (self.config.n,):
            raise ValueError(f"expected shape ({self.config.n},)")
        entry = interface_registry.get_arbiter(self.config.scheme)
        return _simulate(request_times, entry, self.config.n,
                         self.config.levels, self.config.pipeline_fill)

    # ---- experiment drivers (paper §III-D) -------------------------------

    def sparse_event_latency(self, key, num_trials: int = 64) -> jnp.ndarray:
        """Average latency of isolated random single-neuron events (units)."""
        n = self.config.n
        positions = jax.random.randint(key, (num_trials,), 0, n)

        def one(pos):
            req = jnp.full((n,), INF, dtype=jnp.float32).at[pos].set(0.0)
            return self.simulate(req)[pos]

        return jnp.mean(jax.vmap(one)(positions))

    def burst_latency(self) -> jnp.ndarray:
        """Completion time of a full-frame burst (all neurons fire at t=0)."""
        req = jnp.zeros((self.config.n,), dtype=jnp.float32)
        grants = self.simulate(req)
        return jnp.max(grants)

    # ---- closed forms ----------------------------------------------------

    def theoretical_sparse_units(self) -> float:
        return sparse_latency_units(self.config.scheme, self.config.n)

    def theoretical_burst_units(self) -> float:
        return burst_latency_units(self.config.scheme, self.config.n)

    def theoretical_area_units(self) -> float:
        return area_units(self.config.scheme, self.config.n)


# ---------------------------------------------------------------------------
# Encoding energy model (paper §II-A / §III-B): flat trees drive log2(N)
# address lines per event; HAT re-encodes a level only when its cluster
# grant changes.  Units: address-line toggles per event.
# ---------------------------------------------------------------------------


def encode_energy_units(scheme: str, n: int, addr_seq) -> jnp.ndarray:
    """Average address-line toggles/event for a granted address sequence."""
    entry: ArbiterScheme = interface_registry.get_arbiter(scheme)
    return entry.encode_energy(n, jnp.asarray(addr_seq))


def _flat_encode_energy(n: int, addr_seq) -> jnp.ndarray:
    """Every event re-drives all log2(N) address lines."""
    return jnp.float32(math.log2(n)) * jnp.ones((), jnp.float32)


def _hat_encode_energy(n: int, addr_seq) -> jnp.ndarray:
    """Level l re-encodes its 2 bits iff the prefix above level l changed."""
    levels = max(1, round(math.log(n, 4)))
    prev = jnp.concatenate([jnp.array([-1], addr_seq.dtype), addr_seq[:-1]])
    toggles = jnp.zeros(addr_seq.shape, jnp.float32)
    for lvl in range(levels):
        changed = (addr_seq // (4 ** lvl)) != (prev // (4 ** lvl))
        toggles = toggles + jnp.where(changed, 2.0, 0.0)
    return jnp.mean(toggles)


# ---------------------------------------------------------------------------
# Built-in scheme policies (registered below).
# ---------------------------------------------------------------------------


def _tree_select(ctx, tok_hi, tok_lo):
    """Trees grant the lowest pending address (deterministic tie-break)."""
    return ctx.addrs.astype(jnp.float32)


def _token_ring_select(ctx, tok_hi, tok_lo):
    """Rings grant the nearest pending request downstream of the token."""
    return _ring_dist(tok_hi, ctx.addrs, ctx.n).astype(jnp.float32)


def _hier_ring_select(ctx, tok_hi, tok_lo):
    hi, lo = ctx.addrs // ctx.sqrt_n, ctx.addrs % ctx.sqrt_n
    dist = _ring_dist(tok_hi, hi, ctx.sqrt_n) * (ctx.sqrt_n + 2) + _ring_dist(
        jnp.where(hi == tok_hi, tok_lo, 0), lo, ctx.sqrt_n)
    return dist.astype(jnp.float32)


def _binary_tree_delay(ctx, sel, backlog, tok_hi, tok_lo, prev_addr,
                       granted_any):
    return jnp.float32(2.0 * (ctx.lg - 1.0))       # full round trip, always


def _greedy_tree_delay(ctx, sel, backlog, tok_hi, tok_lo, prev_addr,
                       granted_any):
    # greedy re-grant services backlog at leaf level (~3 units);
    # a lone event still pays the full climb.
    return jnp.where(backlog, 3.0, 2.0 * (ctx.lg - 1.0))


def _token_ring_delay(ctx, sel, backlog, tok_hi, tok_lo, prev_addr,
                      granted_any):
    # idle: token travels dist hops then grants (+1); backlogged: the
    # hop overlaps the previous handshake -> 1 unit/event (burst = N).
    dist = _ring_dist(tok_hi, sel, ctx.n).astype(jnp.float32)
    return jnp.where(backlog, jnp.maximum(dist, 1.0), dist + 1.0)


def _hier_ring_delay(ctx, sel, backlog, tok_hi, tok_lo, prev_addr,
                     granted_any):
    hi, lo = sel // ctx.sqrt_n, sel % ctx.sqrt_n
    d_hi = _ring_dist(tok_hi, hi, ctx.sqrt_n).astype(jnp.float32)
    d_lo = _ring_dist(jnp.where(hi == tok_hi, tok_lo, 0), lo,
                      ctx.sqrt_n).astype(jnp.float32)
    # idle: top hops + bottom hops + grant; backlogged: 1 unit/event
    # with a 3-unit section-switch penalty (enter/exit the sub-ring).
    return jnp.where(backlog, jnp.maximum(d_lo + 3.0 * d_hi, 1.0),
                     d_hi + d_lo + 1.0)


def _hier_tree_delay(ctx, sel, backlog, tok_hi, tok_lo, prev_addr,
                     granted_any):
    # Sparse (idle pipeline): 2 two-input stages per level = log2 N.
    # Backlogged: 1 unit/event + 1 unit when the level-2 cluster
    # (16 neurons) switches, + one-off pipeline fill.
    cluster = sel // (4 ** (ctx.levels - 1))
    prev_cluster = prev_addr // (4 ** (ctx.levels - 1))
    switch = (cluster != prev_cluster).astype(jnp.float32)
    first = (~granted_any).astype(jnp.float32)
    return jnp.where(backlog, 1.0 + switch + first * ctx.fill,
                     2.0 * ctx.levels)


def _token_ring_update(ctx, sel, taken, tok_hi, tok_lo):
    return jnp.where(taken, sel, tok_hi), tok_lo


def _hier_ring_update(ctx, sel, taken, tok_hi, tok_lo):
    return (jnp.where(taken, sel // ctx.sqrt_n, tok_hi),
            jnp.where(taken, sel % ctx.sqrt_n, tok_lo))


for _entry in (
    ArbiterScheme("binary_tree", _tree_select, _binary_tree_delay,
                  _flat_encode_energy),
    ArbiterScheme("greedy_tree", _tree_select, _greedy_tree_delay,
                  _flat_encode_energy),
    ArbiterScheme("token_ring", _token_ring_select, _token_ring_delay,
                  _flat_encode_energy, _token_ring_update),
    ArbiterScheme("hier_ring", _hier_ring_select, _hier_ring_delay,
                  _flat_encode_energy, _hier_ring_update),
    ArbiterScheme("hier_tree", _tree_select, _hier_tree_delay,
                  _hat_encode_energy),
):
    if _entry.name not in interface_registry.ARBITERS:
        interface_registry.register_arbiter(_entry.name, _entry)
del _entry
