"""Arbitration architectures for the neuromorphic core output interface.

Implements the five schemes compared in the paper (Tables I-III, Fig. 5):

  binary_tree  - flat binary arbiter tree (Boahen-style)
  greedy_tree  - binary tree with greedy re-grant of hot subtrees
  token_ring   - single token ring over all N neurons
  hier_ring    - two-level hierarchical token ring (HTR, Purohit & Manohar)
  hier_tree    - the paper's HAT: log4(N) levels of shared four-input
                 arbiters, 2 address bits encoded per level, with the
                 asynchronous encoding pipeline holding higher-level grants
                 while a cluster drains.

Two complementary models:

  * closed-form unit-domain costs (re-exported from :mod:`repro.core.ppa`),
  * a mechanistic discrete-event simulator (`simulate`) in pure JAX whose
    emergent latencies match the closed forms (exactly for sparse mode, to
    within a few percent for burst mode - the same gap the paper reports
    between theory and pre-layout simulation).

TPU adaptation (DESIGN.md §2): arbitration on a deterministic machine is a
*scheduling policy*, not an analog race.  Ties break by ascending address;
metastability/grant-overlap become testable determinism properties.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import ppa

SCHEMES = ppa.SCHEMES

# Re-export the closed forms so callers have one import surface.
sparse_latency_units = ppa.sparse_latency_units
burst_latency_units = ppa.burst_latency_units
area_units = ppa.area_units
sparse_latency_ns = ppa.sparse_latency_ns
burst_latency_ns = ppa.burst_latency_ns
area_normalized = ppa.area_normalized

INF = jnp.inf


@dataclasses.dataclass(frozen=True)
class ArbiterConfig:
    """Static description of one arbitration architecture instance."""

    scheme: str
    n: int                      # neurons per core (power of two)
    branching: int = 4          # HAT: four-input arbiter per hierarchy level
    pipeline_fill: int = 3      # HAT: static-HC pipeline fill latency (units)

    def __post_init__(self):
        if self.scheme not in SCHEMES:
            raise ValueError(f"unknown scheme {self.scheme!r}")
        if self.n & (self.n - 1):
            raise ValueError("n must be a power of two")

    @property
    def levels(self) -> int:
        """HAT hierarchy levels (2 address bits per level)."""
        return max(1, round(math.log(self.n, self.branching)))

    @property
    def addr_bits(self) -> int:
        return int(math.log2(self.n))


# ---------------------------------------------------------------------------
# Discrete-event simulation.
#
# State carried through the lax.scan (one step = one granted event):
#   clock        server-free time (units)
#   token_hi/lo  ring token positions (ring schemes)
#   prev_addr    last granted address (cluster-switch penalties, HAT)
#   served       bool mask of granted events
# ---------------------------------------------------------------------------


def _ring_dist(frm, to, n):
    return jnp.mod(to - frm, n)


@partial(jax.jit, static_argnames=("scheme", "n", "levels", "fill"))
def _simulate(request_times, scheme: str, n: int, levels: int, fill: int):
    """Serve every finite request; returns grant_times (inf where no request)."""
    lg = float(math.log2(n))
    sqrt_n = int(round(math.sqrt(n)))
    addrs = jnp.arange(n)
    active = jnp.isfinite(request_times)
    num_active = jnp.sum(active)

    def step(state, _):
        clock, tok_hi, tok_lo, prev_addr, served, granted_any = state
        pending = active & ~served
        arr = jnp.where(pending, request_times, INF)

        # --- selection policy: who is granted next -----------------------
        arrived = pending & (arr <= clock)
        any_arrived = jnp.any(arrived)
        if scheme in ("binary_tree", "greedy_tree", "hier_tree"):
            # trees grant the lowest pending address (deterministic tie-break);
            # if nothing has arrived yet, wait for the earliest arrival.
            key_arrived = jnp.where(arrived, addrs.astype(jnp.float32), INF)
            key_waiting = arr * jnp.float32(n) + addrs  # earliest arrival, addr tiebreak
            sel = jnp.where(any_arrived, jnp.argmin(key_arrived), jnp.argmin(key_waiting))
        else:
            # rings grant the nearest pending request downstream of the token.
            if scheme == "token_ring":
                dist = _ring_dist(tok_hi, addrs, n)
            else:  # hier_ring: two-level distance
                hi, lo = addrs // sqrt_n, addrs % sqrt_n
                dist = _ring_dist(tok_hi, hi, sqrt_n) * (sqrt_n + 2) + _ring_dist(
                    jnp.where(hi == tok_hi, tok_lo, 0), lo, sqrt_n)
            key_arrived = jnp.where(arrived, dist.astype(jnp.float32), INF)
            key_waiting = arr * jnp.float32(n) + addrs
            sel = jnp.where(any_arrived, jnp.argmin(key_arrived), jnp.argmin(key_waiting))

        sel_arr = request_times[sel]
        start = jnp.maximum(sel_arr, clock)
        backlog = clock > sel_arr  # pipeline already busy when the event arrived

        # --- per-scheme grant delay --------------------------------------
        if scheme == "binary_tree":
            delay = jnp.float32(2.0 * (lg - 1.0))           # full round trip, always
        elif scheme == "greedy_tree":
            # greedy re-grant services backlog at leaf level (~3 units);
            # a lone event still pays the full climb.
            delay = jnp.where(backlog, 3.0, 2.0 * (lg - 1.0)).astype(jnp.float32)
        elif scheme == "token_ring":
            # idle: token travels dist hops then grants (+1); backlogged: the
            # hop overlaps the previous handshake -> 1 unit/event (burst = N).
            dist = _ring_dist(tok_hi, sel, n).astype(jnp.float32)
            delay = jnp.where(backlog, jnp.maximum(dist, 1.0), dist + 1.0)
        elif scheme == "hier_ring":
            hi, lo = sel // sqrt_n, sel % sqrt_n
            d_hi = _ring_dist(tok_hi, hi, sqrt_n).astype(jnp.float32)
            d_lo = _ring_dist(jnp.where(hi == tok_hi, tok_lo, 0), lo,
                              sqrt_n).astype(jnp.float32)
            # idle: top hops + bottom hops + grant; backlogged: 1 unit/event
            # with a 3-unit section-switch penalty (enter/exit the sub-ring).
            delay = jnp.where(backlog,
                              jnp.maximum(d_lo + 3.0 * d_hi, 1.0),
                              d_hi + d_lo + 1.0)
        else:  # hier_tree (HAT)
            # Sparse (idle pipeline): 2 two-input stages per level = log2 N.
            # Backlogged: 1 unit/event + 1 unit when the level-2 cluster
            # (16 neurons) switches, + one-off pipeline fill.
            cluster = sel // (4 ** (levels - 1))
            prev_cluster = prev_addr // (4 ** (levels - 1))
            switch = (cluster != prev_cluster).astype(jnp.float32)
            first = (~granted_any).astype(jnp.float32)
            delay = jnp.where(backlog, 1.0 + switch + first * fill, 2.0 * levels)
            delay = delay.astype(jnp.float32)

        grant = start + delay

        # --- state update -------------------------------------------------
        if scheme == "token_ring":
            tok_hi = jnp.where(pending[sel], sel, tok_hi)
        elif scheme == "hier_ring":
            tok_hi = jnp.where(pending[sel], sel // sqrt_n, tok_hi)
            tok_lo = jnp.where(pending[sel], sel % sqrt_n, tok_lo)
        served = served.at[sel].set(served[sel] | pending[sel])
        clock = jnp.where(pending[sel], grant, clock)
        prev_addr = jnp.where(pending[sel], sel, prev_addr)
        granted_any = granted_any | pending[sel]
        out = (sel, jnp.where(pending[sel], grant, INF))
        return (clock, tok_hi, tok_lo, prev_addr, served, granted_any), out

    init = (jnp.float32(0.0), jnp.int32(0), jnp.int32(0), jnp.int32(0),
            jnp.zeros(n, dtype=bool), jnp.bool_(False))
    (_, _, _, _, _, _), (sel_seq, grant_seq) = jax.lax.scan(step, init, None, length=n)

    grant_times = jnp.full(n, INF, dtype=jnp.float32)
    grant_times = grant_times.at[sel_seq].min(grant_seq)
    # steps beyond num_active re-select already-served events; .min keeps first.
    del num_active
    return grant_times


class Arbiter:
    """Discrete-event model of one core-output arbiter."""

    def __init__(self, config: ArbiterConfig):
        self.config = config

    def simulate(self, request_times) -> jnp.ndarray:
        """request_times: (n,) float, inf = no request → grant_times (n,)."""
        request_times = jnp.asarray(request_times, dtype=jnp.float32)
        if request_times.shape != (self.config.n,):
            raise ValueError(f"expected shape ({self.config.n},)")
        return _simulate(request_times, self.config.scheme, self.config.n,
                         self.config.levels, self.config.pipeline_fill)

    # ---- experiment drivers (paper §III-D) -------------------------------

    def sparse_event_latency(self, key, num_trials: int = 64) -> jnp.ndarray:
        """Average latency of isolated random single-neuron events (units)."""
        n = self.config.n
        positions = jax.random.randint(key, (num_trials,), 0, n)

        def one(pos):
            req = jnp.full((n,), INF, dtype=jnp.float32).at[pos].set(0.0)
            return self.simulate(req)[pos]

        return jnp.mean(jax.vmap(one)(positions))

    def burst_latency(self) -> jnp.ndarray:
        """Completion time of a full-frame burst (all neurons fire at t=0)."""
        req = jnp.zeros((self.config.n,), dtype=jnp.float32)
        grants = self.simulate(req)
        return jnp.max(grants)

    # ---- closed forms ----------------------------------------------------

    def theoretical_sparse_units(self) -> float:
        return sparse_latency_units(self.config.scheme, self.config.n)

    def theoretical_burst_units(self) -> float:
        return burst_latency_units(self.config.scheme, self.config.n)

    def theoretical_area_units(self) -> float:
        return area_units(self.config.scheme, self.config.n)


# ---------------------------------------------------------------------------
# Encoding energy model (paper §II-A / §III-B): flat trees drive log2(N)
# address lines per event; HAT re-encodes a level only when its cluster
# grant changes.  Units: address-line toggles per event.
# ---------------------------------------------------------------------------


def encode_energy_units(scheme: str, n: int, addr_seq) -> jnp.ndarray:
    """Average address-line toggles/event for a granted address sequence."""
    addr_seq = jnp.asarray(addr_seq)
    bits = int(math.log2(n))
    if scheme in ("binary_tree", "greedy_tree", "token_ring", "hier_ring"):
        return jnp.float32(bits) * jnp.ones((), jnp.float32)
    # hier_tree: level l (0 = low) re-encoded iff the address prefix above
    # level l changed vs. the previous event.
    levels = max(1, round(math.log(n, 4)))
    prev = jnp.concatenate([jnp.array([-1], addr_seq.dtype), addr_seq[:-1]])
    toggles = jnp.zeros(addr_seq.shape, jnp.float32)
    for lvl in range(levels):
        # level l's arbiter re-fires (re-encoding its 2 bits) whenever the
        # address prefix from level l upward changes.
        changed = (addr_seq // (4 ** lvl)) != (prev // (4 ** lvl))
        toggles = toggles + jnp.where(changed, 2.0, 0.0)
    return jnp.mean(toggles)
