"""Arbitration architectures for the neuromorphic core output interface.

Implements the five schemes compared in the paper (Tables I-III, Fig. 5):

  binary_tree  - flat binary arbiter tree (Boahen-style)
  greedy_tree  - binary tree with greedy re-grant of hot subtrees
  token_ring   - single token ring over all N neurons
  hier_ring    - two-level hierarchical token ring (HTR, Purohit & Manohar)
  hier_tree    - the paper's HAT: log4(N) levels of shared four-input
                 arbiters, 2 address bits encoded per level, with the
                 asynchronous encoding pipeline holding higher-level grants
                 while a cluster drains.

Two complementary models:

  * closed-form unit-domain costs (re-exported from :mod:`repro.core.ppa`),
  * a mechanistic discrete-event simulator (`simulate`) in pure JAX whose
    emergent latencies match the closed forms (exactly for sparse mode, to
    within a few percent for burst mode - the same gap the paper reports
    between theory and pre-layout simulation).

Scheme dispatch goes through `repro.interface.registry`: each architecture
registers an :class:`ArbiterScheme` bundle of policy callables (grant
selection, grant delay, token update, encode energy) and the simulator is
a single generic event loop over those callables.  A new architecture
plugs in with ``register_arbiter(name, ArbiterScheme(...))`` - no edits to
the simulator or the fabric.

TPU adaptation (DESIGN.md §2): arbitration on a deterministic machine is a
*scheduling policy*, not an analog race.  Ties break by ascending address;
metastability/grant-overlap become testable determinism properties.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import ppa
from repro.interface import registry as interface_registry

SCHEMES = ppa.SCHEMES

# Re-export the closed forms so callers have one import surface.
sparse_latency_units = ppa.sparse_latency_units
burst_latency_units = ppa.burst_latency_units
area_units = ppa.area_units
sparse_latency_ns = ppa.sparse_latency_ns
burst_latency_ns = ppa.burst_latency_ns
area_normalized = ppa.area_normalized

INF = jnp.inf


@dataclasses.dataclass(frozen=True)
class ArbiterContext:
    """Static per-instance quantities shared by every policy callable."""

    n: int
    lg: float               # log2(n)
    sqrt_n: int
    levels: int             # HAT hierarchy levels
    fill: int               # HAT pipeline fill latency (units)
    addrs: jnp.ndarray      # (n,) int32


@dataclasses.dataclass(frozen=True)
class ArbiterScheme:
    """Registry entry: the policy bundle of one arbitration architecture.

    select_key(ctx, tok_hi, tok_lo) -> (n,) float32
        priority key among *arrived* requests; argmin wins the grant.
    grant_delay(ctx, sel, backlog, tok_hi, tok_lo, prev_addr, granted_any)
        -> float32 scalar delay between service start and grant.
    token_update(ctx, sel, taken, tok_hi, tok_lo) -> (tok_hi, tok_lo)
        optional ring-token advance after a grant.
    encode_energy(n, addr_seq) -> float32
        average address-line toggles per event for a grant sequence.
    tick_latency(ctx) -> Optional[(n,) bool -> float32]
        optional factory of a *vectorized* per-tick latency policy: given a
        frame of simultaneous requests (all at t=0), return the completion
        time the event-loop simulator would emerge with - without running
        it.  May return ``None`` when the closed form does not apply at
        this ``ctx`` (the dispatcher then falls back to the simulator).
        The simulator stays the source of truth; `tests/test_arbiter.py`
        property-tests every policy against it.  When replacing
        ``grant_delay`` on a derived scheme, drop or replace
        ``tick_latency`` too - it encodes the built-in delays.
    sparse_tick_latency(ctx) -> Optional[(buf, counts) -> (cores,) float32]
        optional factory of the *event-compacted* form of ``tick_latency``
        for the ``impl="pallas_sparse"`` tick: ``buf`` is a
        (cores, capacity + 1) buffer of ascending active addresses padded
        with ``ctx.n`` (`repro.kernels.sparse_tick.compact_events`) and
        ``counts`` the (cores,) live event counts.  Must return exactly
        the float32 values ``tick_latency`` yields on the equivalent
        dense frame (the conformance grid holds the whole sparse tick
        bit-identical to the dense oracle).  May return ``None`` when no
        closed form applies at this ``ctx``; schemes without the policy
        cannot run ``impl="pallas_sparse"`` (sessions refuse at compile).
    sparse_encode_energy(ctx) -> Optional[(buf, counts) -> (cores,) float32]
        the event-compacted form of ``encode_energy``, same contract:
        bit-identical per-core toggles/event from the compacted buffer.
    """

    name: str
    select_key: Callable
    grant_delay: Callable
    encode_energy: Callable
    token_update: Optional[Callable] = None
    tick_latency: Optional[Callable] = None
    sparse_tick_latency: Optional[Callable] = None
    sparse_encode_energy: Optional[Callable] = None


@dataclasses.dataclass(frozen=True)
class ArbiterConfig:
    """Static description of one arbitration architecture instance."""

    scheme: str
    n: int                      # neurons per core (power of two)
    branching: int = 4          # HAT: four-input arbiter per hierarchy level
    pipeline_fill: int = 3      # HAT: static-HC pipeline fill latency (units)

    def __post_init__(self):
        if self.scheme not in interface_registry.ARBITERS:
            raise ValueError(
                f"unknown scheme {self.scheme!r}; registered arbiters: "
                f"{', '.join(interface_registry.ARBITERS.names())}")
        if self.n & (self.n - 1):
            raise ValueError("n must be a power of two")

    @property
    def levels(self) -> int:
        """HAT hierarchy levels (2 address bits per level)."""
        return max(1, round(math.log(self.n, self.branching)))

    @property
    def addr_bits(self) -> int:
        return int(math.log2(self.n))


# ---------------------------------------------------------------------------
# Generic discrete-event simulation.
#
# State carried through the lax.scan (one step = one granted event):
#   clock        server-free time (units)
#   token_hi/lo  ring token positions (ring schemes)
#   prev_addr    last granted address (cluster-switch penalties, HAT)
#   served       bool mask of granted events
# All scheme-specific decisions are deferred to the registered
# `ArbiterScheme` policies, resolved once per trace from the static name.
# ---------------------------------------------------------------------------


def _ring_dist(frm, to, n):
    return jnp.mod(to - frm, n)


def _make_context(n: int, levels: int, fill: int) -> ArbiterContext:
    return ArbiterContext(n=n, lg=float(math.log2(n)),
                          sqrt_n=int(round(math.sqrt(n))), levels=levels,
                          fill=fill, addrs=jnp.arange(n))


def make_context(config: ArbiterConfig) -> ArbiterContext:
    """The static `ArbiterContext` every policy callable receives."""
    return _make_context(config.n, config.levels, config.pipeline_fill)


@partial(jax.jit, static_argnames=("entry", "n", "levels", "fill"))
def _simulate(request_times, entry: ArbiterScheme, n: int, levels: int,
              fill: int):
    """Serve every finite request; returns grant_times (inf where no request).

    `entry` (not its name) is the static jit key, so re-registering a
    scheme with ``overwrite=True`` cannot serve stale traces of the old
    policies.
    """
    ctx = _make_context(n, levels, fill)
    addrs = ctx.addrs
    active = jnp.isfinite(request_times)

    def step(state, _):
        clock, tok_hi, tok_lo, prev_addr, served, granted_any = state
        pending = active & ~served
        arr = jnp.where(pending, request_times, INF)

        # --- selection policy: who is granted next -----------------------
        # If something has arrived, the scheme's priority key decides; if
        # the pipeline is idle, wait for the earliest arrival (addr tiebreak).
        arrived = pending & (arr <= clock)
        any_arrived = jnp.any(arrived)
        key_arrived = jnp.where(arrived, entry.select_key(ctx, tok_hi, tok_lo),
                                INF)
        key_waiting = arr * jnp.float32(n) + addrs
        sel = jnp.where(any_arrived, jnp.argmin(key_arrived),
                        jnp.argmin(key_waiting))

        sel_arr = request_times[sel]
        start = jnp.maximum(sel_arr, clock)
        backlog = clock > sel_arr  # pipeline already busy when the event arrived

        delay = entry.grant_delay(ctx, sel, backlog, tok_hi, tok_lo,
                                  prev_addr, granted_any).astype(jnp.float32)
        grant = start + delay

        # --- state update -------------------------------------------------
        taken = pending[sel]
        if entry.token_update is not None:
            tok_hi, tok_lo = entry.token_update(ctx, sel, taken, tok_hi, tok_lo)
        served = served.at[sel].set(served[sel] | taken)
        clock = jnp.where(taken, grant, clock)
        prev_addr = jnp.where(taken, sel, prev_addr)
        granted_any = granted_any | taken
        out = (sel, jnp.where(taken, grant, INF))
        return (clock, tok_hi, tok_lo, prev_addr, served, granted_any), out

    init = (jnp.float32(0.0), jnp.int32(0), jnp.int32(0), jnp.int32(0),
            jnp.zeros(n, dtype=bool), jnp.bool_(False))
    (_, _, _, _, _, _), (sel_seq, grant_seq) = jax.lax.scan(step, init, None, length=n)

    grant_times = jnp.full(n, INF, dtype=jnp.float32)
    # steps beyond the active count re-select served events; .min keeps first.
    grant_times = grant_times.at[sel_seq].min(grant_seq)
    return grant_times


def batched_tick_latency(config: ArbiterConfig, spikes: jnp.ndarray
                         ) -> jnp.ndarray:
    """Per-core encode completion time for one frame of simultaneous spikes.

    spikes: (cores, n) bool - every request arrives at t=0.
    returns (cores,) float32, exactly what ``max(finite grants)`` of the
    event-loop simulator yields per core, but via the scheme's vectorized
    ``tick_latency`` policy (O(n) vector work instead of an O(n^2) scan).
    Schemes without an applicable policy fall back to the simulator.
    """
    entry: ArbiterScheme = interface_registry.get_arbiter(config.scheme)
    ctx = make_context(config)
    fn = entry.tick_latency(ctx) if entry.tick_latency is not None else None
    if fn is None:
        def fn(core_spikes):
            req = jnp.where(core_spikes, 0.0, INF).astype(jnp.float32)
            grants = _simulate(req, entry, config.n, config.levels,
                               config.pipeline_fill)
            return jnp.where(
                jnp.any(core_spikes),
                jnp.max(jnp.where(jnp.isfinite(grants), grants, 0.0)), 0.0)
    return jax.vmap(fn)(spikes)


class Arbiter:
    """Discrete-event model of one core-output arbiter."""

    def __init__(self, config: ArbiterConfig):
        self.config = config

    def simulate(self, request_times) -> jnp.ndarray:
        """request_times: (n,) float, inf = no request → grant_times (n,)."""
        request_times = jnp.asarray(request_times, dtype=jnp.float32)
        if request_times.shape != (self.config.n,):
            raise ValueError(f"expected shape ({self.config.n},)")
        entry = interface_registry.get_arbiter(self.config.scheme)
        return _simulate(request_times, entry, self.config.n,
                         self.config.levels, self.config.pipeline_fill)

    # ---- experiment drivers (paper §III-D) -------------------------------

    def sparse_event_latency(self, key, num_trials: int = 64) -> jnp.ndarray:
        """Average latency of isolated random single-neuron events (units)."""
        n = self.config.n
        positions = jax.random.randint(key, (num_trials,), 0, n)

        def one(pos):
            req = jnp.full((n,), INF, dtype=jnp.float32).at[pos].set(0.0)
            return self.simulate(req)[pos]

        return jnp.mean(jax.vmap(one)(positions))

    def burst_latency(self) -> jnp.ndarray:
        """Completion time of a full-frame burst (all neurons fire at t=0)."""
        req = jnp.zeros((self.config.n,), dtype=jnp.float32)
        grants = self.simulate(req)
        return jnp.max(grants)

    # ---- closed forms ----------------------------------------------------

    def theoretical_sparse_units(self) -> float:
        return sparse_latency_units(self.config.scheme, self.config.n)

    def theoretical_burst_units(self) -> float:
        return burst_latency_units(self.config.scheme, self.config.n)

    def theoretical_area_units(self) -> float:
        return area_units(self.config.scheme, self.config.n)


# ---------------------------------------------------------------------------
# Encoding energy model (paper §II-A / §III-B): flat trees drive log2(N)
# address lines per event; HAT re-encodes a level only when its cluster
# grant changes.  Units: address-line toggles per event.
# ---------------------------------------------------------------------------


def encode_energy_units(scheme: str, n: int, addr_seq) -> jnp.ndarray:
    """Average address-line toggles/event for a granted address sequence."""
    entry: ArbiterScheme = interface_registry.get_arbiter(scheme)
    return entry.encode_energy(n, jnp.asarray(addr_seq))


def _flat_encode_energy(n: int, addr_seq) -> jnp.ndarray:
    """Every event re-drives all log2(N) address lines."""
    return jnp.float32(math.log2(n)) * jnp.ones((), jnp.float32)


def _hat_encode_energy(n: int, addr_seq) -> jnp.ndarray:
    """Level l re-encodes its 2 bits iff the prefix above level l changed.

    Vectorized over the levels axis (a Python loop here unrolled into every
    trace that embedded it - once per core under the interface tick's vmap).
    """
    levels = max(1, round(math.log(n, 4)))
    prev = jnp.concatenate([jnp.array([-1], addr_seq.dtype), addr_seq[:-1]])
    div = (4 ** jnp.arange(levels)).astype(addr_seq.dtype)        # (levels,)
    changed = (addr_seq[:, None] // div) != (prev[:, None] // div)
    toggles = jnp.sum(jnp.where(changed, 2.0, 0.0), axis=-1)
    return jnp.mean(toggles)


# ---------------------------------------------------------------------------
# Built-in scheme policies (registered below).
# ---------------------------------------------------------------------------


def _tree_select(ctx, tok_hi, tok_lo):
    """Trees grant the lowest pending address (deterministic tie-break)."""
    return ctx.addrs.astype(jnp.float32)


def _token_ring_select(ctx, tok_hi, tok_lo):
    """Rings grant the nearest pending request downstream of the token."""
    return _ring_dist(tok_hi, ctx.addrs, ctx.n).astype(jnp.float32)


def _hier_ring_select(ctx, tok_hi, tok_lo):
    hi, lo = ctx.addrs // ctx.sqrt_n, ctx.addrs % ctx.sqrt_n
    dist = _ring_dist(tok_hi, hi, ctx.sqrt_n) * (ctx.sqrt_n + 2) + _ring_dist(
        jnp.where(hi == tok_hi, tok_lo, 0), lo, ctx.sqrt_n)
    return dist.astype(jnp.float32)


def _binary_tree_delay(ctx, sel, backlog, tok_hi, tok_lo, prev_addr,
                       granted_any):
    return jnp.float32(2.0 * (ctx.lg - 1.0))       # full round trip, always


def _greedy_tree_delay(ctx, sel, backlog, tok_hi, tok_lo, prev_addr,
                       granted_any):
    # greedy re-grant services backlog at leaf level (~3 units);
    # a lone event still pays the full climb.
    return jnp.where(backlog, 3.0, 2.0 * (ctx.lg - 1.0))


def _token_ring_delay(ctx, sel, backlog, tok_hi, tok_lo, prev_addr,
                      granted_any):
    # idle: token travels dist hops then grants (+1); backlogged: the
    # hop overlaps the previous handshake -> 1 unit/event (burst = N).
    dist = _ring_dist(tok_hi, sel, ctx.n).astype(jnp.float32)
    return jnp.where(backlog, jnp.maximum(dist, 1.0), dist + 1.0)


def _hier_ring_delay(ctx, sel, backlog, tok_hi, tok_lo, prev_addr,
                     granted_any):
    hi, lo = sel // ctx.sqrt_n, sel % ctx.sqrt_n
    d_hi = _ring_dist(tok_hi, hi, ctx.sqrt_n).astype(jnp.float32)
    d_lo = _ring_dist(jnp.where(hi == tok_hi, tok_lo, 0), lo,
                      ctx.sqrt_n).astype(jnp.float32)
    # idle: top hops + bottom hops + grant; backlogged: 1 unit/event
    # with a 3-unit section-switch penalty (enter/exit the sub-ring).
    return jnp.where(backlog, jnp.maximum(d_lo + 3.0 * d_hi, 1.0),
                     d_hi + d_lo + 1.0)


def _hier_tree_delay(ctx, sel, backlog, tok_hi, tok_lo, prev_addr,
                     granted_any):
    # Sparse (idle pipeline): 2 two-input stages per level = log2 N.
    # Backlogged: 1 unit/event + 1 unit when the level-2 cluster
    # (16 neurons) switches, + one-off pipeline fill.
    cluster = sel // (4 ** (ctx.levels - 1))
    prev_cluster = prev_addr // (4 ** (ctx.levels - 1))
    switch = (cluster != prev_cluster).astype(jnp.float32)
    first = (~granted_any).astype(jnp.float32)
    return jnp.where(backlog, 1.0 + switch + first * ctx.fill,
                     2.0 * ctx.levels)


def _token_ring_update(ctx, sel, taken, tok_hi, tok_lo):
    return jnp.where(taken, sel, tok_hi), tok_lo


def _hier_ring_update(ctx, sel, taken, tok_hi, tok_lo):
    return (jnp.where(taken, sel // ctx.sqrt_n, tok_hi),
            jnp.where(taken, sel % ctx.sqrt_n, tok_lo))


# ---------------------------------------------------------------------------
# Vectorized per-tick latency policies (`ArbiterScheme.tick_latency`).
#
# For a frame of simultaneous requests (all at t=0) the event loop is fully
# determined: the first grant takes the idle-pipeline delay, every later one
# the backlogged delay, and service order follows the selection key.  Each
# policy below is the closed form of that trajectory, exact in fp32 (all
# intermediate quantities are small integers), so the interface tick pays
# O(n) vector work per core instead of an O(n^2) lax.scan.  Property tests
# in tests/test_arbiter.py hold them to bit-equality with `_simulate`.
# ---------------------------------------------------------------------------


def _binary_tree_tick_latency(ctx):
    # every grant pays the full 2(log2 N - 1) round trip, back to back
    per_grant = jnp.float32(2.0 * (ctx.lg - 1.0))

    def lat(spikes):
        return jnp.sum(spikes).astype(jnp.float32) * per_grant
    return lat


def _greedy_tree_tick_latency(ctx):
    # first grant climbs the whole tree; the backlog re-grants at ~3 units
    if ctx.lg <= 1.0:
        return None       # zero climb delay -> the event loop never backlogs
    first = jnp.float32(2.0 * (ctx.lg - 1.0))

    def lat(spikes):
        k = jnp.sum(spikes).astype(jnp.float32)
        return jnp.where(k > 0.0, first + (k - 1.0) * 3.0, 0.0)
    return lat


def _token_ring_tick_latency(ctx):
    # token starts at 0 and sweeps ascending; hop/handshake overlap makes
    # every gap cost max(gap, 1) = gap, telescoping to max_addr + 1
    def lat(spikes):
        top = jnp.max(jnp.where(spikes, ctx.addrs, -1))
        return jnp.where(jnp.any(spikes), top.astype(jnp.float32) + 1.0, 0.0)
    return lat


def _hier_ring_tick_latency(ctx):
    # sections drain ascending from 0; within a section the lo-gaps
    # telescope to lo_max, and each section switch costs lo_entry + 3*d_hi
    if ctx.sqrt_n * ctx.sqrt_n != ctx.n:
        return None           # top ring wraps inside the address space
    s = ctx.sqrt_n
    hi, lo = ctx.addrs // s, ctx.addrs % s

    def lat(spikes):
        lo_max = jnp.full((s,), jnp.int32(-1)).at[hi].max(
            jnp.where(spikes, lo, -1))
        occupied = lo_max >= 0
        sec = jnp.arange(s)
        s_first = jnp.min(jnp.where(occupied, sec, s))
        s_last = jnp.max(jnp.where(occupied, sec, -1))
        total = (1.0 + s_first + 3.0 * (s_last - s_first) +
                 jnp.sum(jnp.where(occupied, lo_max, 0)))
        return jnp.where(jnp.any(spikes), total.astype(jnp.float32), 0.0)
    return lat


def _hier_tree_tick_latency(ctx):
    # first grant fills the 2*levels pipeline; each later one costs 1 unit
    # plus 1 when the level-2 cluster switches (ascending order visits each
    # occupied cluster exactly once -> Q-1 switches)
    size = 4 ** (ctx.levels - 1)
    clusters = -(-ctx.n // size)
    cluster = ctx.addrs // size

    def lat(spikes):
        k = jnp.sum(spikes).astype(jnp.float32)
        occ = jnp.zeros((clusters,), bool).at[cluster].max(spikes)
        q = jnp.sum(occ).astype(jnp.float32)
        return jnp.where(k > 0.0,
                         2.0 * ctx.levels + (k - 1.0) + (q - 1.0), 0.0)
    return lat


# ---------------------------------------------------------------------------
# Sparse (event-compacted) per-tick policies (`ArbiterScheme.
# sparse_tick_latency` / ``sparse_encode_energy``).
#
# Same closed forms as the dense `tick_latency` policies, re-derived from
# the compacted event buffer the ``impl="pallas_sparse"`` tick carries:
# ``buf`` (cores, capacity + 1) holds each core's active addresses in
# ascending service order padded with ``ctx.n``, ``counts`` the live
# totals.  Every quantity is an exact small integer in fp32, so the
# results are bit-identical to the dense policies (asserted per scheme in
# tests/test_sparse_tick.py) and the fused kernel can call these inside
# its body.  Address prefixes use arithmetic right shifts (``4**l`` and
# ``sqrt_n`` are powers of two wherever these policies apply), which
# floor-divide correctly for the ``-1`` boundary sentinel.
# ---------------------------------------------------------------------------


def _binary_tree_sparse_latency(ctx):
    # Python-scalar constants only: these closures run *inside* the fused
    # Pallas kernel body, which rejects captured traced arrays.
    per_grant = 2.0 * (ctx.lg - 1.0)

    def lat(buf, counts):
        return counts.astype(jnp.float32) * jnp.float32(per_grant)
    return lat


def _greedy_tree_sparse_latency(ctx):
    if ctx.lg <= 1.0:
        return None       # mirrors the dense policy: simulator territory
    first = 2.0 * (ctx.lg - 1.0)

    def lat(buf, counts):
        k = counts.astype(jnp.float32)
        return jnp.where(k > 0.0, jnp.float32(first) + (k - 1.0) * 3.0, 0.0)
    return lat


def _token_ring_sparse_latency(ctx):
    def lat(buf, counts):
        top = jnp.max(jnp.where(buf < ctx.n, buf, -1), axis=1)
        return jnp.where(counts > 0, top.astype(jnp.float32) + 1.0, 0.0)
    return lat


def _hier_ring_sparse_latency(ctx):
    if ctx.sqrt_n * ctx.sqrt_n != ctx.n:
        return None           # top ring wraps inside the address space
    s = ctx.sqrt_n
    shift = int(math.log2(s))

    def lat(buf, counts):
        real = buf < ctx.n
        hi = jnp.where(real, buf >> shift, s - 1)    # pads parked in-range
        lo = buf & (s - 1)

        def one(hi_c, lo_c, real_c):
            lo_max = jnp.full((s,), jnp.int32(-1)).at[hi_c].max(
                jnp.where(real_c, lo_c, -1))
            occupied = lo_max >= 0
            sec = jnp.arange(s)
            s_first = jnp.min(jnp.where(occupied, sec, s))
            s_last = jnp.max(jnp.where(occupied, sec, -1))
            return (1.0 + s_first + 3.0 * (s_last - s_first) +
                    jnp.sum(jnp.where(occupied, lo_max, 0))
                    ).astype(jnp.float32)

        return jnp.where(counts > 0, jax.vmap(one)(hi, lo, real), 0.0)
    return lat


def _hier_tree_sparse_latency(ctx):
    # ascending order visits each occupied level-2 cluster once, so the
    # switch count is the number of cluster boundaries in the buffer
    shift = 2 * (ctx.levels - 1)

    def lat(buf, counts):
        real = buf < ctx.n
        cluster = buf >> shift
        prev = jnp.concatenate(
            [jnp.full((buf.shape[0], 1), -1, buf.dtype), cluster[:, :-1]],
            axis=1)
        q = jnp.sum(real & (cluster != prev), axis=1).astype(jnp.float32)
        k = counts.astype(jnp.float32)
        return jnp.where(k > 0.0,
                         2.0 * ctx.levels + (k - 1.0) + (q - 1.0), 0.0)
    return lat


def _flat_sparse_encode_energy(ctx):
    const = math.log2(ctx.n)

    def enc(buf, counts):
        return jnp.full((buf.shape[0],), const, jnp.float32)
    return enc


def _hat_sparse_encode_energy(ctx):
    # `_hat_encode_energy` over the dense (n,)-padded stream: all toggles
    # happen inside the compacted buffer (pairwise transitions plus the
    # -1 boundary and the first pad boundary); the remaining n -> n pad
    # transitions contribute zero, so summing buffer toggles and dividing
    # by n reproduces the dense mean bit-for-bit (exact integer sums).
    levels = ctx.levels

    def enc(buf, counts):
        shifts = 2 * jnp.arange(levels)
        prev = jnp.concatenate(
            [jnp.full((buf.shape[0], 1), -1, buf.dtype), buf[:, :-1]],
            axis=1)
        changed = (buf[:, :, None] >> shifts) != (prev[:, :, None] >> shifts)
        toggles = jnp.sum(jnp.where(changed, 2.0, 0.0), axis=(1, 2))
        return toggles / jnp.float32(ctx.n)
    return enc


for _entry in (
    ArbiterScheme("binary_tree", _tree_select, _binary_tree_delay,
                  _flat_encode_energy,
                  tick_latency=_binary_tree_tick_latency,
                  sparse_tick_latency=_binary_tree_sparse_latency,
                  sparse_encode_energy=_flat_sparse_encode_energy),
    ArbiterScheme("greedy_tree", _tree_select, _greedy_tree_delay,
                  _flat_encode_energy,
                  tick_latency=_greedy_tree_tick_latency,
                  sparse_tick_latency=_greedy_tree_sparse_latency,
                  sparse_encode_energy=_flat_sparse_encode_energy),
    ArbiterScheme("token_ring", _token_ring_select, _token_ring_delay,
                  _flat_encode_energy, _token_ring_update,
                  tick_latency=_token_ring_tick_latency,
                  sparse_tick_latency=_token_ring_sparse_latency,
                  sparse_encode_energy=_flat_sparse_encode_energy),
    ArbiterScheme("hier_ring", _hier_ring_select, _hier_ring_delay,
                  _flat_encode_energy, _hier_ring_update,
                  tick_latency=_hier_ring_tick_latency,
                  sparse_tick_latency=_hier_ring_sparse_latency,
                  sparse_encode_energy=_flat_sparse_encode_energy),
    ArbiterScheme("hier_tree", _tree_select, _hier_tree_delay,
                  _hat_encode_energy,
                  tick_latency=_hier_tree_tick_latency,
                  sparse_tick_latency=_hier_tree_sparse_latency,
                  sparse_encode_energy=_hat_sparse_encode_energy),
):
    if _entry.name not in interface_registry.ARBITERS:
        interface_registry.register_arbiter(_entry.name, _entry)
del _entry
