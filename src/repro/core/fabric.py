"""Multi-core spike-routing fabric: cores composed through the core interface.

Implements the system of Fig. 1: each core has
  * an **output interface** - arbiter + AER encoding pipeline (HAT by
    default) that serializes the core's spike vector into address events,
  * an **input interface** - a CAM routing LUT whose entries are
    (source tag -> synapse row, weight); an incoming event is broadcast on
    the CAM search lines and every matching synapse injects current.

Between the two sits the inter-core transport, modelled by `repro.noc`: a
2D mesh with XY dimension-order routing.  Events are delivered only to
*subscribed* cores - cores holding at least one valid CAM entry for the
source tag - rather than flooded everywhere, so the CAM search count (and
its energy/time) scales with actual fan-out, not with core count.  Set
``FabricConfig.noc.scheme = "broadcast"`` to recover the flood model (the
seed behaviour, and the paper's implicit worst case).

The fabric is pure-functional JAX: `step` maps (per-core spike vectors) to
(per-core synaptic input currents) and an accounting record of
latency/energy/area from the behavioural PPA models, so an SNN simulation
built on top (models/snn.py) reports core-interface costs per timestep -
the quantity the paper optimizes.

`StepStats` fields (all scalar jnp arrays, per tick):
  events          address events emitted (total spikes)
  encode_latency  worst-core arbitration/encode latency (arbiter units)
  encode_energy   address-line toggle energy (model units)
  cam_searches    CAM search operations across all *subscribed* cores
  cam_energy      CAM energy (model units, `repro.core.cam` calibration)
  cam_time_ns     serialized CAM search time (ns)
  noc_hops        mesh link traversals (multicast trees count links once)
  noc_latency     deepest-path traversal + hottest-link serialization (ns)
  noc_energy      `noc_hops * ppa.NOC_HOP_ENERGY` (CAM-unit domain)

Tag space: a global neuron address (core_id * neurons_per_core + neuron_id)
encoded in `tag_bits`.  This is the DYNAPs-style multi-tag scheme [6].
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import arbiter as arb
from repro.core import cam as cam_mod
from repro.core import ppa
from repro.noc import router as noc_router
from repro.noc import topology as noc_topology


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    cores: int = 4
    neurons_per_core: int = 256
    cam_entries_per_core: int = 512     # synapses with addressable tags
    scheme: str = "hier_tree"
    cam: cam_mod.CamConfig | None = None
    noc: noc_topology.NocConfig | None = None

    def __post_init__(self):
        if self.cam is None:
            object.__setattr__(self, "cam",
                               cam_mod.CamConfig(entries=self.cam_entries_per_core))
        if self.noc is None:
            object.__setattr__(self, "noc", noc_topology.NocConfig())

    @property
    def tag_bits(self) -> int:
        return max(1, math.ceil(math.log2(self.cores * self.neurons_per_core)))


class FabricParams(NamedTuple):
    """Learnable/configurable routing state."""
    tags: jnp.ndarray      # (cores, entries, tag_bits) {0,1} stored source tags
    valid: jnp.ndarray     # (cores, entries) bool
    weights: jnp.ndarray   # (cores, entries) float synaptic weight
    targets: jnp.ndarray   # (cores, entries) int32 target neuron within core


class StepStats(NamedTuple):
    events: jnp.ndarray            # scalar: total address events this tick
    encode_latency: jnp.ndarray    # scalar: max grant latency (units)
    encode_energy: jnp.ndarray     # scalar: address-line toggles
    cam_searches: jnp.ndarray      # scalar: CAM search operations
    cam_energy: jnp.ndarray        # scalar: CAM model energy units
    cam_time_ns: jnp.ndarray       # scalar: serialized CAM search time
    noc_hops: jnp.ndarray          # scalar: mesh link traversals
    noc_latency: jnp.ndarray       # scalar: NoC delivery latency (ns)
    noc_energy: jnp.ndarray        # scalar: NoC energy (model units)


def int_to_bits(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    return ((x[..., None] >> jnp.arange(bits - 1, -1, -1)) & 1).astype(jnp.int32)


def random_connectivity(key, cfg: FabricConfig, fan_in: float = 0.9) -> FabricParams:
    """Random routing tables: each CAM entry subscribes to a random source."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    total = cfg.cores * cfg.neurons_per_core
    src = jax.random.randint(k1, (cfg.cores, cfg.cam.entries), 0, total)
    tags = int_to_bits(src, cfg.tag_bits)
    valid = jax.random.bernoulli(k2, fan_in, (cfg.cores, cfg.cam.entries))
    weights = jax.random.normal(k3, (cfg.cores, cfg.cam.entries)) * 0.5 + 1.0
    targets = jax.random.randint(k4, (cfg.cores, cfg.cam.entries), 0,
                                 cfg.neurons_per_core)
    return FabricParams(tags, valid, weights, targets)


def noc_tables(params: FabricParams, cfg: FabricConfig) -> noc_router.NocTables:
    """Routing tables for the configured NoC scheme (build once, reuse)."""
    return noc_router.build_tables(params.tags, params.valid,
                                   cores=cfg.cores,
                                   neurons_per_core=cfg.neurons_per_core,
                                   tag_bits=cfg.tag_bits,
                                   scheme=cfg.noc.scheme)


def step(params: FabricParams, spikes: jnp.ndarray, cfg: FabricConfig,
         tables: noc_router.NocTables | None = None
         ) -> tuple[jnp.ndarray, StepStats]:
    """One fabric tick.

    spikes: (cores, neurons_per_core) bool
    tables: optional precomputed `noc_tables(params, cfg)` - pass it when
        stepping in a loop (models/snn.py does) to avoid rebuilding the
        subscription masks every tick.  They depend only on (params, cfg).
    returns: currents (cores, neurons_per_core) float32, stats

    The synaptic currents are computed by the same dense CAM-match sweep
    regardless of NoC scheme (delivery only changes *where* searches
    happen, not their results), so currents are bit-identical across
    schemes and to the seed broadcast implementation.
    """
    cores, n = spikes.shape
    assert n == cfg.neurons_per_core and cores == cfg.cores

    # ---- output interface: arbitrate + encode each core's spikes ----------
    def encode_core(core_spikes):
        req = jnp.where(core_spikes, 0.0, jnp.inf).astype(jnp.float32)
        grants = arb.Arbiter(arb.ArbiterConfig(cfg.scheme, n)).simulate(req)
        lat = jnp.where(jnp.any(core_spikes),
                        jnp.max(jnp.where(jnp.isfinite(grants), grants, 0.0)), 0.0)
        return lat

    latencies = jax.vmap(encode_core)(spikes)

    # global source tags of every spiking neuron (dense mask form)
    neuron_global = (jnp.arange(cores)[:, None] * n + jnp.arange(n)[None, :])
    src_bits = int_to_bits(neuron_global, cfg.tag_bits)      # (cores, n, bits)

    # ---- input interface: CAM match per target core -----------------------
    # match[c_tgt, entry, c_src, neuron] = entry subscribed to that source
    def core_inputs(tags_c, valid_c, weights_c, targets_c):
        # (entries, bits) vs (cores*n, bits)
        flat_bits = src_bits.reshape(-1, cfg.tag_bits)
        eq = jnp.all(tags_c[:, None, :] == flat_bits[None, :, :], axis=-1)
        hit = eq & valid_c[:, None] & spikes.reshape(-1)[None, :]
        entry_drive = jnp.sum(hit, axis=1).astype(jnp.float32)  # events per entry
        contrib = entry_drive * weights_c
        currents = jnp.zeros((n,), jnp.float32).at[targets_c].add(contrib)
        return currents, jnp.sum(hit)

    currents, hits = jax.vmap(core_inputs)(params.tags, params.valid,
                                           params.weights, params.targets)

    # ---- NoC delivery + PPA accounting ------------------------------------
    if tables is None:
        tables = noc_tables(params, cfg)
    assert tables.scheme == cfg.noc.scheme, \
        f"tables built for {tables.scheme!r}, cfg wants {cfg.noc.scheme!r}"
    spikes_flat = spikes.reshape(-1)
    total_events = jnp.sum(spikes).astype(jnp.float32)
    addr_seq, _ = jax.vmap(lambda s: _hat_order(s, n))(spikes)
    enc_energy = jax.vmap(
        lambda seq: arb.encode_energy_units(cfg.scheme, n, seq))(addr_seq)

    valid_cnt = jnp.sum(params.valid, axis=1).astype(jnp.float32)
    if cfg.noc.scheme == "broadcast":
        # flood: every event searched in every core (seed accounting)
        searches = total_events * cores
        entries_per_search = jnp.mean(valid_cnt)
    else:
        # mesh: an event is searched only where some CAM entry subscribes
        searches = jnp.sum(spikes_flat * tables.dest_counts).astype(jnp.float32)
        swept = jnp.sum(valid_cnt[:, None] * tables.subs *
                        spikes_flat[None, :])
        entries_per_search = swept / jnp.maximum(searches, 1.0)
    match_per_search = jnp.sum(hits).astype(jnp.float32) / jnp.maximum(searches, 1.0)
    mismatch_per_search = entries_per_search - match_per_search
    cam_energy = searches * _cam_energy(cfg.cam, match_per_search,
                                        mismatch_per_search)
    cam_time = searches * cam_mod.cycle_time_ns(cfg.cam)

    noc_hops, noc_latency, noc_energy, _ = noc_router.noc_step_costs(
        tables, spikes_flat)

    stats = StepStats(events=total_events,
                      encode_latency=jnp.max(latencies),
                      encode_energy=jnp.sum(enc_energy * jnp.sum(spikes, 1)),
                      cam_searches=searches,
                      cam_energy=cam_energy,
                      cam_time_ns=cam_time,
                      noc_hops=noc_hops,
                      noc_latency=noc_latency,
                      noc_energy=noc_energy)
    return currents, stats


def _hat_order(spikes, n):
    idx = jnp.arange(n, dtype=jnp.int32)
    key = jnp.where(spikes, idx, n)
    return jnp.sort(key), jnp.sum(spikes)


def _cam_energy(cfg: cam_mod.CamConfig, n_match, n_mismatch):
    return cam_mod._energy_jnp(cfg, n_match, n_mismatch)


def interface_area_um2(cfg: FabricConfig) -> dict:
    """Static area report for one core's interface (model units/um^2)."""
    return {
        "arbiter_norm_area": arb.area_normalized(cfg.scheme, cfg.neurons_per_core),
        "arbiter_units": arb.area_units(cfg.scheme, cfg.neurons_per_core),
        "cam_um2": cam_mod.area_um2(cfg.cam),
        "cam_um2_baseline": cam_mod.area_um2(
            cam_mod.CamConfig(cfg.cam.entries, cscd=False, feedback=False,
                              speculative=False)),
    }
