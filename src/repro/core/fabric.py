"""DEPRECATED shim over `repro.interface` - the multi-core spike fabric.

This module used to own the per-tick core-interface pipeline (arbiter +
AER encode -> NoC transport -> CAM routing LUT).  That implementation now
lives in `repro.interface` as a registry-driven, compile-once API:

    from repro.interface import Interface

    session = Interface(cfg).compile(params)     # plans/tables built once
    currents, stats = session.run(spikes_TxCxN)  # jit + lax.scan over ticks

Everything here is kept so seed call sites keep working bit-for-bit:

  * `FabricConfig` remains the legacy config type (now *validating* that an
    explicit ``cam=CamConfig(...)`` agrees with ``cam_entries_per_core``),
  * `FabricParams` / `StepStats` / `int_to_bits` / `random_connectivity`
    re-export the `repro.interface` definitions,
  * `step` delegates to `repro.interface.pipeline.interface_tick` and emits
    a `DeprecationWarning`.

See `StepStats` (repro.interface.stats) for the per-tick accounting
fields; tag space is a global neuron address (core_id * neurons_per_core
+ neuron_id) encoded in `tag_bits` - the DYNAPs-style multi-tag scheme [6].
"""

from __future__ import annotations

import dataclasses
import math
import warnings

import jax.numpy as jnp

from repro.core import cam as cam_mod
from repro.interface import pipeline as _pipeline
from repro.interface import report as _report
from repro.interface.config import resolve_cam, resolve_chips
from repro.interface.stats import StepStats  # noqa: F401  (re-export)
from repro.interface.types import (  # noqa: F401  (re-exports)
    FabricParams,
    int_to_bits,
    random_connectivity,
)
from repro.noc import router as noc_router
from repro.noc import topology as noc_topology


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    cores: int | None = None                 # total; default 4 when omitted
    neurons_per_core: int = 256
    cam_entries_per_core: int | None = None  # defaults to 512 w/o explicit cam
    scheme: str = "hier_tree"
    cam: cam_mod.CamConfig | None = None
    noc: noc_topology.NocConfig | None = None
    impl: str = "xla"            # "xla" | "pallas" | "pallas_sparse"
    chips: int = 1                           # cores = chips x cores_per_chip
    cores_per_chip: int | None = None        # derived: cores // chips
    sparse_capacity: int | None = None       # pallas_sparse event budget

    def __post_init__(self):
        cores, per_chip = resolve_chips(self.chips, self.cores,
                                        self.cores_per_chip)
        object.__setattr__(self, "cores", cores)
        object.__setattr__(self, "cores_per_chip", per_chip)
        cam, entries = resolve_cam(self.cam, self.cam_entries_per_core)
        object.__setattr__(self, "cam", cam)
        object.__setattr__(self, "cam_entries_per_core", entries)
        if self.noc is None:
            object.__setattr__(self, "noc", noc_topology.NocConfig())
        if self.impl not in ("xla", "pallas", "pallas_sparse"):
            raise ValueError(
                f"unknown impl {self.impl!r}; expected 'xla', 'pallas' or "
                f"'pallas_sparse'")
        if self.sparse_capacity is not None and self.sparse_capacity < 1:
            raise ValueError(
                f"sparse_capacity must be a positive event count, got "
                f"{self.sparse_capacity}")

    @property
    def tag_bits(self) -> int:
        return max(1, math.ceil(math.log2(self.cores * self.neurons_per_core)))


def noc_tables(params: FabricParams, cfg: FabricConfig) -> noc_router.NocTables:
    """Routing tables for the configured NoC scheme (build once, reuse)."""
    return _pipeline.build_tables(params, cfg)


def step(params: FabricParams, spikes: jnp.ndarray, cfg: FabricConfig,
         tables: noc_router.NocTables | None = None
         ) -> tuple[jnp.ndarray, StepStats]:
    """One fabric tick.  DEPRECATED: use `repro.interface.Interface`.

    spikes: (cores, neurons_per_core) bool
    tables: optional precomputed `noc_tables(params, cfg)` - pass it when
        stepping in a loop to avoid rebuilding the subscription masks every
        tick.  They depend only on (params, cfg).
    returns: currents (cores, neurons_per_core) float32, stats

    The currents are bit-identical to `InterfaceSession.run` on the same
    params for every NoC scheme (both delegate to the same tick).
    """
    warnings.warn(
        "fabric.step is deprecated; use repro.interface.Interface(cfg)"
        ".compile(params).run(spikes) for the precompiled scan-based API",
        DeprecationWarning, stacklevel=2)
    return _pipeline.interface_tick(params, spikes, cfg, tables)


def interface_area_um2(cfg: FabricConfig) -> dict:
    """Static area report for one core's interface (model units/um^2)."""
    return _report.interface_area_um2(cfg)
