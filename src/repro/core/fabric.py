"""Multi-core spike-routing fabric: cores composed through the core interface.

Implements the system of Fig. 1: each core has
  * an **output interface** - arbiter + AER encoding pipeline (HAT by
    default) that serializes the core's spike vector into address events,
  * an **input interface** - a CAM routing LUT whose entries are
    (source tag -> synapse row, weight); an incoming event is broadcast on
    the CAM search lines and every matching synapse injects current.

The fabric is pure-functional JAX: `step` maps (per-core spike vectors) to
(per-core synaptic input currents) and an accounting record of
latency/energy/area from the behavioural PPA models, so an SNN simulation
built on top (models/snn.py) reports core-interface costs per timestep -
the quantity the paper optimizes.

Tag space: a global neuron address (core_id * neurons_per_core + neuron_id)
encoded in `tag_bits`.  This is the DYNAPs-style multi-tag scheme [6].
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import arbiter as arb
from repro.core import cam as cam_mod
from repro.core import ppa


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    cores: int = 4
    neurons_per_core: int = 256
    cam_entries_per_core: int = 512     # synapses with addressable tags
    scheme: str = "hier_tree"
    cam: cam_mod.CamConfig | None = None

    def __post_init__(self):
        if self.cam is None:
            object.__setattr__(self, "cam",
                               cam_mod.CamConfig(entries=self.cam_entries_per_core))

    @property
    def tag_bits(self) -> int:
        return max(1, math.ceil(math.log2(self.cores * self.neurons_per_core)))


class FabricParams(NamedTuple):
    """Learnable/configurable routing state."""
    tags: jnp.ndarray      # (cores, entries, tag_bits) {0,1} stored source tags
    valid: jnp.ndarray     # (cores, entries) bool
    weights: jnp.ndarray   # (cores, entries) float synaptic weight
    targets: jnp.ndarray   # (cores, entries) int32 target neuron within core


class StepStats(NamedTuple):
    events: jnp.ndarray            # scalar: total address events this tick
    encode_latency: jnp.ndarray    # scalar: max grant latency (units)
    encode_energy: jnp.ndarray     # scalar: address-line toggles
    cam_searches: jnp.ndarray      # scalar: CAM search operations
    cam_energy: jnp.ndarray        # scalar: CAM model energy units
    cam_time_ns: jnp.ndarray       # scalar: serialized CAM search time


def int_to_bits(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    return ((x[..., None] >> jnp.arange(bits - 1, -1, -1)) & 1).astype(jnp.int32)


def random_connectivity(key, cfg: FabricConfig, fan_in: float = 0.9) -> FabricParams:
    """Random routing tables: each CAM entry subscribes to a random source."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    total = cfg.cores * cfg.neurons_per_core
    src = jax.random.randint(k1, (cfg.cores, cfg.cam.entries), 0, total)
    tags = int_to_bits(src, cfg.tag_bits)
    valid = jax.random.bernoulli(k2, fan_in, (cfg.cores, cfg.cam.entries))
    weights = jax.random.normal(k3, (cfg.cores, cfg.cam.entries)) * 0.5 + 1.0
    targets = jax.random.randint(k4, (cfg.cores, cfg.cam.entries), 0,
                                 cfg.neurons_per_core)
    return FabricParams(tags, valid, weights, targets)


def step(params: FabricParams, spikes: jnp.ndarray, cfg: FabricConfig
         ) -> tuple[jnp.ndarray, StepStats]:
    """One fabric tick.

    spikes: (cores, neurons_per_core) bool
    returns: currents (cores, neurons_per_core) float32, stats
    """
    cores, n = spikes.shape
    assert n == cfg.neurons_per_core and cores == cfg.cores

    # ---- output interface: arbitrate + encode each core's spikes ----------
    def encode_core(core_spikes):
        req = jnp.where(core_spikes, 0.0, jnp.inf).astype(jnp.float32)
        grants = arb.Arbiter(arb.ArbiterConfig(cfg.scheme, n)).simulate(req)
        lat = jnp.where(jnp.any(core_spikes),
                        jnp.max(jnp.where(jnp.isfinite(grants), grants, 0.0)), 0.0)
        return lat

    latencies = jax.vmap(encode_core)(spikes)

    # global source tags of every spiking neuron (dense mask form)
    neuron_global = (jnp.arange(cores)[:, None] * n + jnp.arange(n)[None, :])
    src_bits = int_to_bits(neuron_global, cfg.tag_bits)      # (cores, n, bits)

    # ---- NoC broadcast + input interface: CAM search per target core ------
    # match[c_tgt, entry, c_src, neuron] = entry subscribed to that source
    def core_inputs(tags_c, valid_c, weights_c, targets_c):
        # (entries, bits) vs (cores*n, bits)
        flat_bits = src_bits.reshape(-1, cfg.tag_bits)
        eq = jnp.all(tags_c[:, None, :] == flat_bits[None, :, :], axis=-1)
        hit = eq & valid_c[:, None] & spikes.reshape(-1)[None, :]
        entry_drive = jnp.sum(hit, axis=1).astype(jnp.float32)  # events per entry
        contrib = entry_drive * weights_c
        currents = jnp.zeros((n,), jnp.float32).at[targets_c].add(contrib)
        return currents, jnp.sum(hit)

    currents, hits = jax.vmap(core_inputs)(params.tags, params.valid,
                                           params.weights, params.targets)

    # ---- PPA accounting -----------------------------------------------------
    total_events = jnp.sum(spikes).astype(jnp.float32)
    addr_seq, _ = jax.vmap(lambda s: _hat_order(s, n))(spikes)
    enc_energy = jax.vmap(
        lambda seq: arb.encode_energy_units(cfg.scheme, n, seq))(addr_seq)
    searches = total_events * cores            # every event searched in every core
    valid_cnt = jnp.sum(params.valid, axis=1).astype(jnp.float32)
    match_per_search = jnp.sum(hits).astype(jnp.float32) / jnp.maximum(searches, 1.0)
    mismatch_per_search = jnp.mean(valid_cnt) - match_per_search
    cam_energy = searches * _cam_energy(cfg.cam, match_per_search,
                                        mismatch_per_search)
    cam_time = searches * cam_mod.cycle_time_ns(cfg.cam)

    stats = StepStats(events=total_events,
                      encode_latency=jnp.max(latencies),
                      encode_energy=jnp.sum(enc_energy * jnp.sum(spikes, 1)),
                      cam_searches=searches,
                      cam_energy=cam_energy,
                      cam_time_ns=cam_time)
    return currents, stats


def _hat_order(spikes, n):
    idx = jnp.arange(n, dtype=jnp.int32)
    key = jnp.where(spikes, idx, n)
    return jnp.sort(key), jnp.sum(spikes)


def _cam_energy(cfg: cam_mod.CamConfig, n_match, n_mismatch):
    return cam_mod._energy_jnp(cfg, n_match, n_mismatch)


def interface_area_um2(cfg: FabricConfig) -> dict:
    """Static area report for one core's interface (model units/um^2)."""
    return {
        "arbiter_norm_area": arb.area_normalized(cfg.scheme, cfg.neurons_per_core),
        "arbiter_units": arb.area_units(cfg.scheme, cfg.neurons_per_core),
        "cam_um2": cam_mod.area_um2(cfg.cam),
        "cam_um2_baseline": cam_mod.area_um2(
            cam_mod.CamConfig(cfg.cam.entries, cscd=False, feedback=False,
                              speculative=False)),
    }
