"""HAT-style hierarchical event routing, applied to MoE token dispatch.

Beyond-paper bridge (DESIGN.md §2): the paper's core interface is an event
router - spikes are tokens, cores are experts, the arbiter serializes
events into per-destination queues.  This module reuses that structure for
Mixture-of-Experts dispatch:

  * a token's top-k expert choices are "address events";
  * arbitration = deterministic service order (token index, then slot) -
    exactly the DES tie-break of `repro.core.arbiter`;
  * each expert is a "core" with a fixed-capacity input buffer (the CAM-LUT
    synapse array); events beyond capacity are dropped, as an AER FIFO
    overflows;
  * position-in-expert is computed with a **hierarchical segmented scan**
    (per-cluster counts, then across clusters) - the HAT tree flattened
    onto SIMD hardware.  The same structure tiles the Pallas
    `moe_dispatch` kernel.

Everything is static-shaped and jit/shard_map friendly.  Experts are
EP-sharded over the `model` mesh axis by slicing the (E, C) buffers.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class RouteResult(NamedTuple):
    expert_ids: jnp.ndarray      # (T, k) int32 chosen experts
    weights: jnp.ndarray         # (T, k) float combine weights (normalized)
    buffer_rows: jnp.ndarray     # (E, C) int32 token row per slot, -1 = empty
    event_slot: jnp.ndarray      # (T, k) int32 slot in expert buffer, -1 = dropped
    kept: jnp.ndarray            # (T, k) bool event survived capacity
    load: jnp.ndarray            # (E,) int32 tokens offered per expert (pre-drop)
    aux_loss: jnp.ndarray        # scalar load-balance loss
    z_loss: jnp.ndarray          # scalar router z-loss


def _hierarchical_positions(sorted_expert_ids: jnp.ndarray, num_experts: int,
                            cluster: int) -> jnp.ndarray:
    """Position of each event within its expert segment, via a two-level scan.

    sorted_expert_ids: (M,) int32, ascending.  Returns (M,) int32 positions.
    The scan is performed as HAT performs arbitration: counts are formed per
    cluster of `cluster` experts (low level), then combined across clusters
    (high level).  Functionally equal to a flat segmented scan; structurally
    it is the paper's hierarchy and the tiling of the Pallas kernel.
    """
    m = sorted_expert_ids.shape[0]
    # low level: one-hot counts per expert, accumulated hierarchically
    onehot = jax.nn.one_hot(sorted_expert_ids, num_experts, dtype=jnp.int32)
    # (M, E) cumsum along events = arrival-order arbitration within experts
    csum = jnp.cumsum(onehot, axis=0)
    # position = (#earlier events of same expert); gather the running count
    pos = jnp.take_along_axis(csum, sorted_expert_ids[:, None], axis=1)[:, 0] - 1
    del m, cluster  # hierarchy realized in the kernel; flat scan is bit-equal
    return pos


def _segment_positions_sorted(sorted_ids: jnp.ndarray) -> jnp.ndarray:
    """O(M) positions within equal-id segments of an ascending id array."""
    m = sorted_ids.shape[0]
    idx = jnp.arange(m, dtype=jnp.int32)
    # start index of each segment: first occurrence of each id
    is_start = jnp.concatenate([jnp.ones((1,), bool),
                                sorted_ids[1:] != sorted_ids[:-1]])
    seg_start = jnp.where(is_start, idx, 0)
    seg_start = jax.lax.associative_scan(jnp.maximum, seg_start)
    return idx - seg_start


def hat_route(gate_logits: jnp.ndarray, k: int, capacity: int,
              num_experts: int | None = None,
              use_hierarchical_scan: bool = False) -> RouteResult:
    """Route tokens to top-k experts with fixed per-expert capacity.

    gate_logits: (T, E) float.  Deterministic drop policy: events are served
    in (token, slot) order - the arbiter tie-break - so earlier tokens win
    buffer slots (matches the AER FIFO semantics).
    """
    t, e = gate_logits.shape
    num_experts = num_experts or e
    gates = jax.nn.softmax(gate_logits.astype(jnp.float32), axis=-1)
    top_w, top_ids = jax.lax.top_k(gates, k)
    top_ids = top_ids.astype(jnp.int32)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)

    # --- flatten events in arbitration order: (token major, slot minor) ----
    flat_ids = top_ids.reshape(-1)                       # (T*k,)
    order = jnp.argsort(flat_ids, stable=True)           # group by expert
    sorted_ids = flat_ids[order]
    if use_hierarchical_scan:
        pos_sorted = _hierarchical_positions(sorted_ids, num_experts, 4)
    else:
        pos_sorted = _segment_positions_sorted(sorted_ids)

    # --- capacity arbitration ---------------------------------------------
    kept_sorted = pos_sorted < capacity
    slot_sorted = jnp.where(kept_sorted, pos_sorted, -1)

    # scatter back to (T*k,) event order
    event_slot = jnp.zeros((t * k,), jnp.int32).at[order].set(slot_sorted)
    kept = jnp.zeros((t * k,), bool).at[order].set(kept_sorted)

    # --- expert input buffers ----------------------------------------------
    rows = jnp.arange(t * k, dtype=jnp.int32) // k       # token row per event
    buf = jnp.full((num_experts, capacity), -1, jnp.int32)
    # dropped events target slot == capacity, discarded by mode="drop"
    scatter_slot = jnp.where(kept, event_slot, capacity)
    buf = buf.at[flat_ids, scatter_slot].set(rows, mode="drop")

    # --- aux losses (Switch-style) ------------------------------------------
    load = jnp.sum(jax.nn.one_hot(flat_ids, num_experts, dtype=jnp.int32), axis=0)
    frac_tokens = load.astype(jnp.float32) / jnp.maximum(t * k, 1)
    frac_prob = jnp.mean(gates, axis=0)
    aux = num_experts * jnp.sum(frac_tokens * frac_prob)
    z = jnp.mean(jax.nn.logsumexp(gate_logits.astype(jnp.float32), axis=-1) ** 2)

    return RouteResult(expert_ids=top_ids, weights=top_w,
                       buffer_rows=buf,
                       event_slot=event_slot.reshape(t, k),
                       kept=kept.reshape(t, k), load=load,
                       aux_loss=aux, z_loss=z)


def dispatch(x: jnp.ndarray, route: RouteResult) -> jnp.ndarray:
    """Gather token vectors into expert buffers: (T, d) -> (E, C, d)."""
    safe = jnp.maximum(route.buffer_rows, 0)
    gathered = x[safe]                                   # (E, C, d)
    mask = (route.buffer_rows >= 0)[..., None]
    return jnp.where(mask, gathered, 0.0)


def combine(expert_out: jnp.ndarray, route: RouteResult, t: int) -> jnp.ndarray:
    """Scatter expert outputs back to tokens with combine weights.

    expert_out: (E, C, d) -> (T, d)
    """
    e, c, d = expert_out.shape
    k = route.expert_ids.shape[1]
    # per-event gather from (E, C, d)
    slot = jnp.maximum(route.event_slot, 0)              # (T, k)
    ev = expert_out[route.expert_ids, slot]              # (T, k, d)
    w = route.weights * route.kept.astype(route.weights.dtype)
    return jnp.einsum("tkd,tk->td", ev, w.astype(ev.dtype))
