"""Address-Event Representation (AER) encode/decode and raster streaming.

The core output interface serializes the parallel spike vector of a core
into a time-multiplexed stream of address events (Fig. 1 of the paper).
This module provides:

  * bit-field packing of neuron addresses into the HAT hierarchy levels
    (2 bits per level, high level first - the order the encoding pipeline
    emits them),
  * raster -> event-stream encoding under a chosen arbitration scheme,
    with per-event grant latencies from the discrete-event model,
  * the pure-jnp ordering oracle for the `hat_encode` Pallas kernel.

Deterministic TPU adaptation: within one simulation tick the drain order of
a burst is ascending address (the DES tie-break); across ticks events keep
raster order.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.arbiter import Arbiter, ArbiterConfig


def pack_address(addr: jnp.ndarray, n: int, branching: int = 4) -> jnp.ndarray:
    """Split addresses into hierarchy-level fields, high level first.

    addr: (...,) int in [0, n) -> (..., levels) int in [0, branching).
    """
    levels = max(1, round(math.log(n, branching)))
    fields = []
    for lvl in range(levels - 1, -1, -1):
        fields.append((addr // (branching ** lvl)) % branching)
    return jnp.stack(fields, axis=-1)


def unpack_address(fields: jnp.ndarray, branching: int = 4) -> jnp.ndarray:
    levels = fields.shape[-1]
    addr = jnp.zeros(fields.shape[:-1], dtype=jnp.int32)
    for lvl in range(levels):
        addr = addr * branching + fields[..., lvl].astype(jnp.int32)
    return addr


def hat_event_order(spikes: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for the hat_encode kernel: compact active addresses.

    spikes: (n,) bool -> (addresses (n,) int32 [ascending actives, then n-pad],
                          count scalar int32)
    """
    n = spikes.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    key = jnp.where(spikes, idx, n)
    order = jnp.sort(key)
    return order, jnp.sum(spikes).astype(jnp.int32)


@partial(jax.jit, static_argnames=("scheme", "n"))
def _encode_tick(spikes, tick_start, scheme, n):
    req = jnp.where(spikes, jnp.float32(0.0), jnp.inf)
    grants = Arbiter(ArbiterConfig(scheme=scheme, n=n)).simulate(req)
    addrs, count = hat_event_order(spikes)
    grant_sorted = jnp.where(addrs < n, grants[jnp.minimum(addrs, n - 1)], jnp.inf)
    return addrs, grant_sorted + tick_start, count


def encode_raster(raster: jnp.ndarray, scheme: str = "hier_tree",
                  tick_ns: float = 1000.0):
    """Encode a spike raster (T, N) bool into an AER stream.

    Returns dict with per-tick event addresses (T, N) int32 (padded with N),
    grant times (T, N) float32 in arbiter units offset by tick starts, and
    per-tick event counts (T,).
    """
    t_steps, n = raster.shape
    tick_starts = jnp.arange(t_steps, dtype=jnp.float32) * tick_ns

    def one(spikes, start):
        return _encode_tick(spikes, start, scheme, n)

    addrs, grants, counts = jax.vmap(one)(raster, tick_starts)
    return {"addresses": addrs, "grant_times": grants, "counts": counts}


def decode_events(addresses: jnp.ndarray, counts: jnp.ndarray, n: int) -> jnp.ndarray:
    """Inverse of encode_raster: event stream -> spike raster (T, N) bool."""
    t_steps = addresses.shape[0]

    def one(addr_row, count):
        mask = jnp.arange(addr_row.shape[0]) < count
        safe = jnp.minimum(addr_row, n - 1)  # padded slots write False anyway
        return jnp.zeros((n,), bool).at[safe].max(mask)

    return jax.vmap(one)(addresses, counts)
