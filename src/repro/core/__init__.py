"""Core paper contribution: neuromorphic core-interface models in JAX.

- arbiter:       five arbitration architectures (HAT = the paper's), closed
                 forms + discrete-event simulation (Tables I-III, Fig. 5)
- aer:           address-event encode/decode + raster streaming
- cam:           asynchronous CAM with CSCD / feedback / speculative sense
                 (Figs. 9-11), functional search + behavioural PPA models
- event_router:  HAT-style hierarchical MoE token dispatch (beyond-paper)
- fabric:        DEPRECATED shim over `repro.interface` (the unified,
                 registry-driven core-interface API with compiled sessions)
- ppa:           calibration constants shared by the models
"""

from repro.core import aer, arbiter, cam, event_router, fabric, ppa  # noqa: F401
