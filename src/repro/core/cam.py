"""Asynchronous CAM routing-memory model (paper §IV).

Functional layer
----------------
`search` / `first_match` implement the NOR-type CAM semantics used by the
core input interface: an incoming address-event's tag is broadcast on the
search lines and compared in parallel against every stored entry; all
matching entries (synapses subscribed to that source neuron) fire.  The
Pallas kernel `repro.kernels.cam_search` accelerates the same contract;
this module is the reference/model layer used by the fabric simulator.

Behavioural PPA layer
---------------------
Cycle-time and energy models of four design variants:

  conventional       delay-line-acked asynchronous CAM (DYNAPs baseline [6])
  + cscd             Current-Sensing Completion Detection replaces the
                     worst-case-provisioned delay line
  + feedback         MATCH: MLSA output closes its own current source
                     (~40% match-line swing reduction)
  + speculative      MISMATCH: per-cell sense nodes close the source before
                     the request arrives, P = (2^N - 2^(N-n) + 1)/2^N

Calibration (see derivation in comments): the model reproduces the paper's
  - cycle-time improvement: 35.5% @ 16x11, 40.4% @ 512x11   (exact)
  - all-MATCH energy saving 35.8%, all-MISMATCH 40.2%       (exact)
  - area: 225.3->245.5 um^2 @ 16, 7242.1->7620.6 um^2 @ 512 (exact)

Reproduction finding: the paper's random-search saving (46.7%) is *not*
simultaneously satisfiable with the other two savings under any linear
energy-superposition model - a mixture of MATCH/MISMATCH populations is a
mediant of the endpoint ratios and cannot beat both.  The model therefore
predicts ~40% for random search; benchmarks report both numbers side by
side (EXPERIMENTS.md §Paper-validation discusses this).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import ppa
from repro.interface import registry as interface_registry

# ---------------------------------------------------------------------------
# Functional CAM semantics (bit-exact contract shared with the Pallas kernel)
# ---------------------------------------------------------------------------


def search(tags: jnp.ndarray, valid: jnp.ndarray, query: jnp.ndarray) -> jnp.ndarray:
    """Parallel search: match[e] = valid[e] and tags[e, :] == query.

    tags:  (entries, bits) {0,1} int
    valid: (entries,) bool
    query: (bits,) or (batch, bits)
    returns (entries,) or (batch, entries) bool
    """
    tags = jnp.asarray(tags)
    query = jnp.asarray(query)
    if query.ndim == 1:
        eq = jnp.all(tags == query[None, :], axis=-1)
        return eq & valid
    eq = jnp.all(tags[None, :, :] == query[:, None, :], axis=-1)
    return eq & valid[None, :]


def first_match(tags, valid, query) -> jnp.ndarray:
    """Index of the lowest matching entry, or `entries` if none."""
    m = search(tags, valid, query)
    entries = tags.shape[0]
    idx = jnp.arange(entries)
    return jnp.min(jnp.where(m, idx, entries), axis=-1)


def mismatch_bit_counts(tags, query) -> jnp.ndarray:
    """Per-entry number of mismatching bits (drives the energy model)."""
    q = query[None, :] if query.ndim == 1 else query[:, None, :]
    t = tags if query.ndim == 1 else tags[None, :, :]
    return jnp.sum(t != q, axis=-1)


# ---------------------------------------------------------------------------
# Behavioural PPA model
# ---------------------------------------------------------------------------

# --- cycle-time calibration (ns) -------------------------------------------
# T_conv(E)  = t_req + (1+margin) * t_dummy(E) + t_reset
# T_cscd(E)  = t_req + settle_frac * t_dummy(E) + t_sense + t_reset
# t_dummy(E) = D0 + D1 * log2(E)            (match-line wiring capacitance)
# Solving for the paper's 35.5% (E=16) and 40.4% (E=512) improvements with
# settle_frac(full) = 0.58 (feedback cuts ~40% of the charge ramp) gives:
T_REQ = 0.2
T_RESET = 0.5
T_SENSE = 0.3
DELAY_MARGIN = 0.3          # "usually 30% higher than the dummy path" (§IV-D)
D0 = 1.425916
D1 = 0.173986
SETTLE_FRAC = {  # (feedback, speculative) -> fraction of dummy charge time
    (False, False): 1.00,
    (True, False): 0.70,
    (False, True): 0.85,
    (True, True): 0.58,
}

# --- energy calibration (units: one full-window MISMATCH DC dissipation) ----
# Solved exactly from the paper's all-MATCH (35.8%) and all-MISMATCH (40.2%)
# savings at the 512x11 design point with:
#   match entry, conventional:  M_CHARGE          (full match-line swing)
#   match entry, +feedback:     0.6 * M_CHARGE    (40% swing reduction)
#   mismatch entry, conv:       1.0
#   mismatch entry, +spec:      (1-P_ss) * 1.0 + P_ss * E_SENSE_NODE
#   fixed, conventional:        F_CONV  (SL drivers + dummy + delay line + HS)
#   fixed, proposed:            F_CONV + E_CSCD_NET (CSCD block net of the
#                                removed delay line)
#     512*0.6*m + F_p = (1-0.358)(512*m + F_c)
#     512*q     + F_p = (1-0.402)(512   + F_c),  q = 0.1245 + 0.8755*0.02
P_SS = ppa.spec_sense_close_probability(ppa.CAM_BITS, ppa.CAM_SPEC_SENSE_BITS)
E_SENSE_NODE = 0.02
E_CSCD_NET = 25.0
M_CHARGE = 9.796
F_CONV = 518.58

# --- area calibration (um^2), exact through both published design points ----
#   area = per_entry * E + periph
A_ENTRY_BASE = 7016.8 / 496      # 14.1468  (11 CAM cells + MLSA)
A_PERIPH_BASE = 225.3 - 16 * A_ENTRY_BASE
A_ENTRY_PROP = 7375.1 / 496      # 14.8691  (+OR gate in MLSA; no cell growth)
A_PERIPH_PROP = 245.5 - 16 * A_ENTRY_PROP  # ~= 7.6 um^2: the CSCD block


@dataclasses.dataclass(frozen=True)
class CamVariant:
    """Registry entry: circuit-level knobs of one CAM design variant.

    settle_frac is the fraction of the dummy charge ramp a CSCD search
    waits for (None for the conventional delay-line-timed design);
    match_charge_factor scales the match-line swing energy (feedback cuts
    it to 0.6).  Register new variants with
    ``repro.interface.register_cam_variant`` and select them via
    ``CamConfig(variant_name=...)``.
    """

    name: str
    cscd: bool
    feedback: bool
    speculative: bool
    settle_frac: float | None = None
    match_charge_factor: float = 1.0


@dataclasses.dataclass(frozen=True)
class CamConfig:
    entries: int
    bits: int = ppa.CAM_BITS
    sense_bits: int = ppa.CAM_SPEC_SENSE_BITS
    cscd: bool = True
    feedback: bool = True
    speculative: bool = True
    variant_name: str | None = None   # explicit registered variant override

    @property
    def variant(self) -> str:
        if self.variant_name is not None:
            return self.variant_name
        if not self.cscd:
            return "conventional"
        tags = ["cscd"]
        if self.feedback:
            tags.append("fb")
        if self.speculative:
            tags.append("ss")
        return "+".join(tags)

    def variant_entry(self) -> CamVariant:
        """The registered `CamVariant` this config resolves to."""
        return interface_registry.get_cam_variant(self.variant)


def dummy_charge_ns(entries: int) -> float:
    return D0 + D1 * math.log2(entries)


def cycle_time_ns(cfg: CamConfig) -> float:
    """Average search cycle time (four-phase handshake, §IV-D 'Cycle time')."""
    v = cfg.variant_entry()
    t_d = dummy_charge_ns(cfg.entries)
    if not v.cscd:
        return T_REQ + (1.0 + DELAY_MARGIN) * t_d + T_RESET
    return T_REQ + v.settle_frac * t_d + T_SENSE + T_RESET


def spec_close_probability(cfg: CamConfig) -> float:
    return ppa.spec_sense_close_probability(cfg.bits, cfg.sense_bits)


def search_energy(cfg: CamConfig, n_match: float, n_mismatch: float) -> float:
    """Average per-search energy for a given match composition (model units)."""
    if not cfg.cscd and (cfg.feedback or cfg.speculative) \
            and cfg.variant_name is None:
        raise ValueError("feedback/speculative require the CSCD architecture")
    v = cfg.variant_entry()
    e_match = M_CHARGE * v.match_charge_factor
    if v.speculative:
        p = spec_close_probability(cfg)
        e_mismatch = (1.0 - p) * 1.0 + p * E_SENSE_NODE
    else:
        e_mismatch = 1.0
    fixed = F_CONV + (E_CSCD_NET if v.cscd else 0.0)
    return n_match * e_match + n_mismatch * e_mismatch + fixed


def search_energy_for_queries(cfg: CamConfig, tags, valid, queries) -> jnp.ndarray:
    """Average model energy over a batch of actual queries."""
    m = search(tags, valid, queries)          # (batch, entries)
    n_match = jnp.sum(m, axis=-1).astype(jnp.float32)
    n_valid = jnp.sum(valid).astype(jnp.float32)
    n_mismatch = n_valid - n_match
    e = jax.vmap(lambda nm, nmm: _energy_jnp(cfg, nm, nmm))(n_match, n_mismatch)
    return jnp.mean(e)


def _energy_jnp(cfg: CamConfig, n_match, n_mismatch):
    v = cfg.variant_entry()
    e_match = M_CHARGE * v.match_charge_factor
    if v.speculative:
        p = spec_close_probability(cfg)
        e_mm = (1.0 - p) + p * E_SENSE_NODE
    else:
        e_mm = 1.0
    fixed = F_CONV + (E_CSCD_NET if v.cscd else 0.0)
    return n_match * e_match + n_mismatch * e_mm + fixed


def area_um2(cfg: CamConfig) -> float:
    if cfg.variant_entry().cscd:
        return A_ENTRY_PROP * cfg.entries + A_PERIPH_PROP
    return A_ENTRY_BASE * cfg.entries + A_PERIPH_BASE


def energy_saving(case: str, entries: int = 512) -> float:
    """Model-predicted saving of the full proposed design vs. baseline."""
    conv = CamConfig(entries, cscd=False, feedback=False, speculative=False)
    prop = CamConfig(entries)
    if case == "all_match":
        comp = (float(entries), 0.0)
    elif case == "all_mismatch":
        comp = (0.0, float(entries))
    elif case == "random":
        # uniformly random query & tags: per-entry match prob = 2^-bits
        p = 2.0 ** (-prop.bits)
        comp = (entries * p, entries * (1 - p))
    else:
        raise ValueError(case)
    return 1.0 - search_energy(prop, *comp) / search_energy(conv, *comp)


def cycle_improvement(entries: int) -> float:
    conv = CamConfig(entries, cscd=False, feedback=False, speculative=False)
    prop = CamConfig(entries)
    return 1.0 - cycle_time_ns(prop) / cycle_time_ns(conv)


class CamArray:
    """A stateful CAM routing LUT: stored tags + functional search + PPA."""

    def __init__(self, cfg: CamConfig, tags=None, valid=None):
        self.cfg = cfg
        self.tags = (jnp.zeros((cfg.entries, cfg.bits), jnp.int32)
                     if tags is None else jnp.asarray(tags, jnp.int32))
        self.valid = (jnp.zeros((cfg.entries,), bool)
                      if valid is None else jnp.asarray(valid, bool))

    def write(self, entry: int, tag) -> "CamArray":
        tags = self.tags.at[entry].set(jnp.asarray(tag, jnp.int32))
        valid = self.valid.at[entry].set(True)
        return CamArray(self.cfg, tags, valid)

    def search(self, query):
        return search(self.tags, self.valid, query)

    def first_match(self, query):
        return first_match(self.tags, self.valid, query)


# ---------------------------------------------------------------------------
# Built-in variants (names match `CamConfig.variant` for the flag combos).
# ---------------------------------------------------------------------------

for _v in (
    CamVariant("conventional", cscd=False, feedback=False, speculative=False),
    CamVariant("cscd", cscd=True, feedback=False, speculative=False,
               settle_frac=SETTLE_FRAC[(False, False)]),
    CamVariant("cscd+fb", cscd=True, feedback=True, speculative=False,
               settle_frac=SETTLE_FRAC[(True, False)],
               match_charge_factor=0.6),
    CamVariant("cscd+ss", cscd=True, feedback=False, speculative=True,
               settle_frac=SETTLE_FRAC[(False, True)]),
    CamVariant("cscd+fb+ss", cscd=True, feedback=True, speculative=True,
               settle_frac=SETTLE_FRAC[(True, True)],
               match_charge_factor=0.6),
):
    if _v.name not in interface_registry.CAM_VARIANTS:
        interface_registry.register_cam_variant(_v.name, _v)
del _v
