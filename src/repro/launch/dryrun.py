import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any jax import: jax locks the device
count on first initialization, and the production meshes need 512
placeholder host devices.  (Smoke tests and benchmarks never import this
module, so they see 1 device.)

Per cell this script:
  1. builds the production mesh (16x16 or 2x16x16),
  2. constructs ShapeDtypeStruct stand-ins for params / optimizer state /
     batch / caches with their production shardings (no allocation),
  3. jit-lowers and COMPILES the cell's program (train_step /
     prefill_step / decode_step),
  4. records memory_analysis(), cost_analysis() and the collective-bytes
     breakdown parsed from the post-SPMD HLO into
     experiments/dryrun/<cell>.json (consumed by benchmarks/roofline.py
     and EXPERIMENTS.md §Dry-run).

Usage:
  python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-done]
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.compat import set_mesh
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.parallel import sharding as shd
from repro.serve.lm_engine import make_decode_step, make_prefill_step
from repro.train import step as ts

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../experiments/dryrun")

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _sds(tree, mesh, specs):
    """Abstract tree -> ShapeDtypeStructs carrying NamedShardings."""
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                          sharding=NamedSharding(mesh, s)),
        tree, specs)


def _batch_shapes(cfg: ModelConfig, shape: configs.Shape):
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    if cfg.frontend.kind == "audio":
        d = {"frames": jax.ShapeDtypeStruct((b, s, cfg.frontend.d_in),
                                            jnp.float32),
             "mask": jax.ShapeDtypeStruct((b, s), jnp.bool_)}
        if shape.kind == "train":
            d["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return d
    d = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.frontend.kind == "vision":
        d["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend.max_prefix, cfg.frontend.d_in), jnp.float32)
    if shape.kind == "train":
        d["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return d


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective bytes, parsed from the post-SPMD module.

    Post-optimization HLO references operands by bare %names, so we read
    the RESULT shapes on the left of the op (equal to operand bytes for
    all-reduce / all-to-all / collective-permute; the gathered size for
    all-gather, i.e. bytes received per device).  reduce-scatter results
    are scaled by group size (bytes contributed per device).  NOTE: ops
    inside `while` bodies (scanned layers) are counted ONCE - the dry-run
    corrects this via the unrolled calibration variants (cost_calibrated).
    """
    totals: dict = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        left = line[:m.start()]
        nbytes = 0
        for dt, dims in SHAPE_RE.findall(left):
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        if op == "reduce-scatter":
            g = GROUPS_RE.search(line)
            if g:
                nbytes *= int(g.group(2))
        totals[op] = totals.get(op, 0) + nbytes
    totals["total"] = sum(v for k, v in totals.items() if k != "total")
    return totals


def _unrolled_variant(cfg: ModelConfig, k: int) -> ModelConfig:
    """k repeats of the layer pattern, fully unrolled (no lax.scan)."""
    import dataclasses as dc
    g = cfg.scan_group
    base = cfg.moe.first_k_dense if cfg.moe is not None else 0
    n = base + k * max(g, 1)
    return dc.replace(cfg, n_layers=n, scan_group=n)


class _loop_free:
    """Context: unroll every inner chunk scan (flash tiles / WKV chunks /
    SSM chunks) so HLO cost analysis - which visits while bodies once -
    sees the production algorithm as straight-line code.  Tile sizes are
    unchanged, so the counted flops/bytes/collectives are the real ones."""

    def __enter__(self):
        from repro.models import calibrate
        self._saved = calibrate.UNROLL
        calibrate.UNROLL = True
        return self

    def __exit__(self, *exc):
        from repro.models import calibrate
        calibrate.UNROLL = self._saved
        return False


def calibrated_costs(arch: str, shape_name: str, cfg: ModelConfig, *,
                     multi_pod: bool, opt_overrides=None, mesh_shape=None,
                     train_kwargs=None) -> dict:
    """Exact per-device costs via two unrolled, loop-free lowerings.

    cost(L) is affine in the layer-pattern repeat count k; lowering k=1 and
    k=2 pins both coefficients, then we extrapolate to the real depth.
    Only LOWERED (never executed), so the loop-free variants' giant
    attention temporaries are irrelevant.
    """
    import dataclasses as dc
    g = max(cfg.scan_group, 1)
    base = cfg.moe.first_k_dense if cfg.moe is not None else 0
    reps_full = (cfg.n_layers - base) / g
    out = {}
    with _loop_free():
        costs = []
        for k in (1, 2):
            vcfg = _unrolled_variant(cfg, k)
            rec = _lower_one(vcfg, shape_name, multi_pod=multi_pod,
                             opt_overrides=opt_overrides, compile_only=True,
                             mesh_shape=mesh_shape, train_kwargs=train_kwargs)
            costs.append(rec)
    for key in ("flops", "bytes accessed"):
        c1 = costs[0]["cost"].get(key, 0.0)
        c2 = costs[1]["cost"].get(key, 0.0)
        per_rep = c2 - c1
        fixed = c1 - per_rep
        out[key] = fixed + per_rep * reps_full
    coll = {}
    keys = set(costs[0]["collectives"]) | set(costs[1]["collectives"])
    for key in keys:
        c1 = costs[0]["collectives"].get(key, 0)
        c2 = costs[1]["collectives"].get(key, 0)
        per_rep = c2 - c1
        coll[key] = c1 - per_rep + per_rep * reps_full
    out["collectives"] = coll
    out["calib_compile_s"] = [c["compile_s"] for c in costs]
    return out


# ---------------------------------------------------------------------------
# §Perf hillclimb variants: each maps to (config transform, train-step
# kwargs, mesh shape override).  See EXPERIMENTS.md §Perf for the
# hypothesis -> change -> before/after log.
# ---------------------------------------------------------------------------

def _v_serve_tp32(cfg):
    import dataclasses as dc
    moe = dc.replace(cfg.moe, quant_int8=True) if cfg.moe else None
    return dc.replace(cfg, serve_tp_only=True, moe=moe)


def _v_serve_tp32_bf16(cfg):
    import dataclasses as dc
    return dc.replace(cfg, serve_tp_only=True)


def _v_rwkv48(cfg):
    import dataclasses as dc
    return dc.replace(cfg, rwkv_pad_heads=48)


def _v_rwkv48_c64(cfg):
    import dataclasses as dc
    return dc.replace(cfg, rwkv_pad_heads=48,
                      rwkv=dc.replace(cfg.rwkv, chunk=64))


VARIANTS = {
    "baseline": {},
    # cell A: deepseek-v2-236b decode_32k (collective-bound)
    "serve_tp32": {"cfg_fn": _v_serve_tp32, "mesh_shape": (8, 32)},
    "serve_tp32_bf16": {"cfg_fn": _v_serve_tp32_bf16, "mesh_shape": (8, 32)},
    # cell B: qwen3-32b train_4k (memory/collective-bound, temp > HBM)
    "mb8": {"train_kwargs": {"microbatch": 8}},
    "remat_dots": {"train_kwargs": {"remat_policy": "dots"}},
    "mb8_dots": {"train_kwargs": {"microbatch": 8, "remat_policy": "dots"}},
    # cell C: rwkv6-3b train_4k (memory-bound, WKV replicated over model)
    "rwkv48": {"cfg_fn": _v_rwkv48},
    "rwkv48_c64": {"cfg_fn": _v_rwkv48_c64},
}


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               opt_overrides: dict | None = None,
               variant: str = "baseline", calibrate: bool = True,
               cfg: ModelConfig | None = None):
    spec = VARIANTS.get(variant, {})
    cfg = cfg or configs.get_config(arch)
    if "cfg_fn" in spec:
        cfg = spec["cfg_fn"](cfg)
    mesh_shape = spec.get("mesh_shape")
    train_kwargs = spec.get("train_kwargs", {})
    merged = {**configs.train_overrides(arch), **(opt_overrides or {})}
    record = _lower_one(cfg, shape_name, multi_pod=multi_pod,
                        opt_overrides=merged, mesh_shape=mesh_shape,
                        train_kwargs=train_kwargs)
    record["arch"] = arch
    record["variant"] = variant
    if calibrate:
        record["cost_calibrated"] = calibrated_costs(
            arch, shape_name, cfg, multi_pod=multi_pod, opt_overrides=merged,
            mesh_shape=mesh_shape, train_kwargs=train_kwargs)
    return record


def _lower_one(cfg: ModelConfig, shape_name: str, *, multi_pod: bool,
               opt_overrides: dict | None = None, compile_only: bool = False,
               mesh_shape=None, train_kwargs: dict | None = None):
    train_kwargs = train_kwargs or {}
    shape = configs.SHAPES[shape_name]
    arch = cfg.name
    mesh = make_production_mesh(multi_pod=multi_pod, shape=mesh_shape)
    ctx = shd.make_shard_ctx(mesh, cfg)
    dp_total = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    opt_cfg = AdamWConfig(**(opt_overrides or {}))
    record = {"arch": arch, "shape": shape_name,
              "multi_pod": multi_pod, "mesh": dict(mesh.shape),
              "kind": shape.kind, "seq_len": shape.seq_len,
              "global_batch": shape.global_batch}

    with set_mesh(mesh):
        # ---- abstract params (+ shardings) --------------------------------
        p_abs = jax.eval_shape(lambda k: lm.init_model(k, cfg),
                               jax.random.PRNGKey(0))
        p_specs = shd.params_pspecs(p_abs, cfg, ctx)
        p_sds = _sds(p_abs, mesh, p_specs)

        batch_abs = _batch_shapes(cfg, shape)
        bspec = ctx.batch_spec if shape.global_batch >= dp_total else None
        b_specs = {k: shd.sanitize_spec(
            P(bspec, *([None] * (v.ndim - 1))), v.shape, ctx)
            for k, v in batch_abs.items()}
        b_sds = _sds(batch_abs, mesh, b_specs)

        t0 = time.time()
        if shape.kind == "train":
            opt_abs = jax.eval_shape(lambda p: adamw.init(opt_cfg, p), p_abs)
            state_abs = ts.TrainState(
                params=p_sds,
                opt=type(opt_abs)(
                    step=jax.ShapeDtypeStruct(
                        (), jnp.int32, sharding=NamedSharding(mesh, P())),
                    mu=_sds(opt_abs.mu, mesh, p_specs),
                    nu=_sds(opt_abs.nu, mesh, p_specs)),
                step=jax.ShapeDtypeStruct(
                    (), jnp.int32, sharding=NamedSharding(mesh, P())))
            fn = ts.make_train_step(cfg, opt_cfg, ctx=ctx, **train_kwargs)
            lowered = jax.jit(fn).lower(state_abs, b_sds)
        else:
            cache_abs = jax.eval_shape(
                lambda: lm.init_cache(cfg, shape.global_batch,
                                      _cache_len(cfg, shape)))
            c_specs = shd.cache_pspecs(cache_abs, cfg, ctx)
            if bspec is None:  # batch too small for DP: replicate batch dims
                c_specs = jax.tree.map(
                    lambda s: P(None, None, *s[2:]), c_specs,
                    is_leaf=lambda x: isinstance(x, P))
                b_specs = {k: P(*([None] * v.ndim))
                           for k, v in batch_abs.items()}
                b_sds = _sds(batch_abs, mesh, b_specs)
            c_sds = _sds(cache_abs, mesh, c_specs)
            if shape.kind == "prefill":
                fn = make_prefill_step(cfg, ctx=ctx)
                lowered = jax.jit(fn).lower(p_sds, b_sds, c_sds)
            else:
                fn = make_decode_step(cfg, ctx=ctx)
                clen = jax.ShapeDtypeStruct((), jnp.int32,
                                            sharding=NamedSharding(mesh, P()))
                lowered = jax.jit(fn).lower(p_sds, c_sds, b_sds["tokens"],
                                            clen)
        record["lower_s"] = round(time.time() - t0, 2)

        t0 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t0, 2)

        mem = compiled.memory_analysis()
        record["memory"] = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        }
        cost = compiled.cost_analysis()
        record["cost"] = {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))and k in
                          ("flops", "bytes accessed", "transcendentals")}
        record["collectives"] = collective_bytes(compiled.as_text())
    return record


def _cache_len(cfg: ModelConfig, shape: configs.Shape) -> int:
    extra = cfg.frontend.max_prefix if cfg.frontend.kind == "vision" else 0
    return shape.seq_len + extra


def run_cell(arch, shape_name, multi_pod, out_dir, skip_done=False,
             variant="baseline", opt_overrides=None):
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{'multipod' if multi_pod else 'singlepod'}"
    if variant != "baseline":
        tag += f"__{variant}"
    path = os.path.join(out_dir, tag + ".json")
    if skip_done and os.path.exists(path):
        print(f"[dryrun] skip (done): {tag}")
        return True
    ok, why = configs.cell_status(arch, shape_name)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
               "status": "skipped", "reason": why, "variant": variant}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[dryrun] SKIP {tag}: {why}")
        return True
    print(f"[dryrun] lowering {tag} ...", flush=True)
    try:
        rec = lower_cell(arch, shape_name, multi_pod=multi_pod,
                         variant=variant, opt_overrides=opt_overrides)
        rec["status"] = "ok"
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[dryrun] OK {tag}: compile={rec['compile_s']}s "
              f"flops={rec['cost'].get('flops', 0):.3e} "
              f"coll={rec['collectives'].get('total', 0):.3e}B", flush=True)
        return True
    except Exception as e:  # noqa: BLE001 - record the failure, keep going
        rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[dryrun] FAIL {tag}: {type(e).__name__}: {e}", flush=True)
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=sorted(VARIANTS))
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in configs.ARCHS:
            for shape in configs.SHAPES:
                cells.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            if not run_cell(arch, shape, mp, args.out,
                            skip_done=args.skip_done, variant=args.variant):
                failures += 1
    print(f"[dryrun] done, failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
