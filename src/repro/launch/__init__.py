"""launch subsystem."""
