"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any jax
initialization).
"""

from __future__ import annotations

import jax

from repro.compat import (AxisType, set_mesh, shard_map,  # noqa: F401
                          mesh_axis_kwargs as _axis_kwargs)


def make_production_mesh(*, multi_pod: bool = False, shape=None):
    """(16, 16) data x model single pod, or (2, 16, 16) pod x data x model.

    `shape` overrides the single-pod (data, model) factorization with the
    same 256-chip budget (e.g. (8, 32) for serving wide-TP)."""
    if shape is not None and not multi_pod:
        axes = ("data", "model")
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(tuple(shape), axes, **_axis_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"need {data * model} devices, have {n}")
    return jax.make_mesh((data, model), ("data", "model"), **_axis_kwargs(2))


def make_chip_mesh(chips: int):
    """1D ``("chips",)`` device mesh for sharded interface sessions.

    One device per simulated neuromorphic chip
    (`InterfaceSession.run(shard="chips")`); callers fall back to vmap
    when fewer devices exist than chips."""
    n = len(jax.devices())
    if chips > n:
        raise ValueError(f"need {chips} devices for a chip mesh, have {n}")
    return jax.make_mesh((chips,), ("chips",), **_axis_kwargs(1))
