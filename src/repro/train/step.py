"""Training step factory: loss, microbatch accumulation, optimizer update.

`make_train_step(cfg, opt_cfg, ctx)` builds the jit-able function
  train_step(state, batch) -> (state, metrics)
used identically by the smoke tests (1 CPU device, ctx=LOCAL) and the
production dry-run (pjit over the 256/512-chip mesh) - the distribution
is entirely in the shardings, not the code.

Microbatching: with `microbatch > 1` the global batch is split along
axis 0 and gradients accumulate in f32 through a lax.scan - the standard
gradient-accumulation trick for fitting large global batches.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.blocks import LOCAL, ShardCtx
from repro.models.config import ModelConfig
from repro.optim import adamw


class TrainState(NamedTuple):
    params: dict
    opt: adamw.AdamWState
    step: jnp.ndarray


def init_state(key, cfg: ModelConfig, opt_cfg: adamw.AdamWConfig) -> TrainState:
    params = lm.init_model(key, cfg)
    return TrainState(params=params, opt=adamw.init(opt_cfg, params),
                      step=jnp.zeros((), jnp.int32))


def cross_entropy(logits, labels, mask=None):
    """Stable CE; labels -100 (or mask=0) positions are ignored."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    safe_labels = jnp.maximum(labels, 0)
    gold = jnp.take_along_axis(logits, safe_labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    valid = labels >= 0
    if mask is not None:
        valid = valid & (mask > 0)
    nll = jnp.where(valid, nll, 0.0)
    denom = jnp.maximum(jnp.sum(valid), 1)
    return jnp.sum(nll) / denom


def loss_fn(params, batch, cfg: ModelConfig, ctx: ShardCtx, remat=True,
            remat_policy: str | None = None):
    out = lm.forward(params, batch, cfg, mode="train", ctx=ctx, remat=remat,
                     remat_policy=remat_policy)
    logits = out["logits"]
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:
        # vision prefix: logits cover [image; text] - score text only
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    loss = cross_entropy(logits, labels, batch.get("loss_mask"))
    aux_sum = sum(out["aux"].values()) if out["aux"] else 0.0
    metrics = {"ce_loss": loss, **{k: v for k, v in out["aux"].items()}}
    return loss + aux_sum, metrics


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    ctx: ShardCtx = LOCAL, microbatch: int = 1,
                    remat: bool = True, remat_policy: str | None = None):
    def train_step(state: TrainState, batch):
        if microbatch == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch, cfg, ctx, remat,
                                       remat_policy)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatch, b // microbatch, *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb, cfg, ctx, remat, remat_policy)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
            from repro.models import calibrate
            (grads, loss), ms = jax.lax.scan(acc_step, (g0, 0.0), micro,
                                             unroll=calibrate.UNROLL)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss = loss / microbatch
            metrics = jax.tree.map(lambda m: m[-1], ms)

        new_params, new_opt, opt_metrics = adamw.update(
            opt_cfg, grads, state.opt, state.params)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return TrainState(params=new_params, opt=new_opt,
                          step=state.step + 1), metrics

    return train_step


def make_eval_step(cfg: ModelConfig, ctx: ShardCtx = LOCAL):
    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch, cfg, ctx, remat=False)
        return {"loss": loss, **metrics}
    return eval_step
