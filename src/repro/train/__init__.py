"""train subsystem."""
