"""`InterfaceConfig`: the validated static description of one fabric.

Field-compatible with the legacy `repro.core.fabric.FabricConfig` (same
attribute names), so either type drives `Interface` / `interface_tick`.
Unlike the legacy config, construction is *validated*:

  * ``cam_entries_per_core`` and an explicit ``cam=CamConfig(...)`` must
    agree (the legacy config silently ignored the former),
  * the arbiter scheme and the CAM variant must be registered,
  * the NoC scheme is validated by `NocConfig` itself.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import cam as cam_mod
from repro.noc import topology as noc_topology


def resolve_cam(cam: cam_mod.CamConfig | None, entries: int | None,
                default_entries: int = 512):
    """Shared cam/cam_entries_per_core reconciliation.

    Returns the effective ``(cam, entries)`` pair; raises `ValueError`
    when an explicit config and an explicit entry count disagree.
    """
    if cam is None:
        cam = cam_mod.CamConfig(entries=default_entries if entries is None
                                else entries)
    elif entries is not None and cam.entries != entries:
        raise ValueError(
            f"cam_entries_per_core={entries} conflicts with explicit "
            f"cam=CamConfig(entries={cam.entries}); pass one or make them agree")
    return cam, cam.entries


def resolve_chips(chips: int, cores: int | None,
                  cores_per_chip: int | None, default_cores: int = 4):
    """Shared chips/cores/cores_per_chip reconciliation.

    ``cores`` is always the *total* core count across chips; configs store
    the resolved pair, so both fields survive `dataclasses.replace`.
    Resolution order:

      * ``cores`` given (never None after a config has resolved once, so
        every ``dataclasses.replace(cfg, chips=k)`` lands here): it is
        authoritative - ``chips`` must divide it, and ``cores_per_chip``
        is (re-)derived.  A disagreeing ``cores_per_chip`` is treated as
        stale, not an error: the derived field necessarily rides along
        through ``replace``.
      * ``cores`` omitted: total = ``chips * cores_per_chip`` (or the
        default core count when neither is given).

    Note the asymmetry this implies: to *repartition* an existing config,
    replace ``chips`` - ``replace(cfg, cores_per_chip=...)`` alone is
    overridden by the explicit stored ``cores``.  A ``cores_per_chip``
    that cannot be a stale derived value (it does not divide ``cores``)
    raises.

    Returns the effective ``(cores, cores_per_chip)`` pair.
    """
    if not isinstance(chips, int) or chips < 1:
        raise ValueError(f"chips must be a positive int, got {chips!r}")
    if cores is None:
        cores = (chips * cores_per_chip if cores_per_chip is not None
                 else default_cores)
    if cores_per_chip is not None and chips * cores_per_chip == cores:
        return cores, cores_per_chip
    if cores_per_chip is not None and cores % cores_per_chip != 0:
        raise ValueError(
            f"cores_per_chip={cores_per_chip} conflicts with cores={cores} "
            f"and cannot be a stale derived value; pass chips (and "
            f"optionally cores_per_chip) to repartition")
    if cores % chips != 0:
        raise ValueError(
            f"cores={cores} conflicts with chips={chips}"
            + (f" (cores_per_chip={cores_per_chip})"
               if cores_per_chip is not None else "")
            + ": chips must divide the total core count "
            "(or pass cores_per_chip alone to derive the total)")
    return cores, cores // chips


@dataclasses.dataclass(frozen=True)
class InterfaceConfig:
    """Static description of the full core-interface pipeline.

    chips:   chip tier of the fabric.  ``cores`` is always the *total*
             core count (``chips x cores_per_chip``); every chip carries
             its own ``cores_per_chip``-core mesh and chips are joined by
             an inter-chip router level (`repro.noc.hierarchy`).  With
             the default ``chips=1`` the fabric is the flat single-chip
             mesh and behaves bit-identically to configs predating the
             chip tier.
    scheme:  arbiter architecture (registry: `repro.interface.ARBITERS`)
    cam:     CAM variant/size (registry: `repro.interface.CAM_VARIANTS`)
    noc:     transport scheme (registry: `repro.interface.NOC_SCHEMES`)
    impl:    tick compute backend - "xla" (gather/scatter fast path),
             "pallas" (route the CAM match through the
             `repro.kernels.cam_search` kernel and the AER address stream
             through `repro.kernels.hat_encode`; falls back to interpret
             mode off-TPU), or "pallas_sparse" (the fused
             `repro.kernels.sparse_tick` event path: per-core event
             compaction feeding one kernel for CAM gather + scatter +
             arbiter latency + AER encode, with a dense fallback when a
             core exceeds ``sparse_capacity`` events).  Currents and
             stats are bit-identical across impls.
    sparse_capacity: per-core event-buffer capacity for
             ``impl="pallas_sparse"``; ``None`` applies the
             `repro.kernels.sparse_tick.ops.default_capacity` heuristic
             (n/8, at least 8).  Effective values are clamped to
             ``neurons_per_core - 1``; ticks where any core fires more
             events than this run the dense tick instead (bit-identical
             either way - the knob trades sparse-path coverage against
             per-tick buffer work).  Ignored by the other impls.
    """

    cores: int | None = None                  # total; default 4 when omitted
    neurons_per_core: int = 256
    cam_entries_per_core: int | None = None   # defaults to 512 w/o explicit cam
    scheme: str = "hier_tree"
    cam: cam_mod.CamConfig | None = None
    noc: noc_topology.NocConfig | None = None
    impl: str = "xla"
    chips: int = 1
    cores_per_chip: int | None = None         # derived: cores // chips
    sparse_capacity: int | None = None        # pallas_sparse event budget

    def __post_init__(self):
        cores, per_chip = resolve_chips(self.chips, self.cores,
                                        self.cores_per_chip)
        object.__setattr__(self, "cores", cores)
        object.__setattr__(self, "cores_per_chip", per_chip)
        cam, entries = resolve_cam(self.cam, self.cam_entries_per_core)
        object.__setattr__(self, "cam", cam)
        object.__setattr__(self, "cam_entries_per_core", entries)
        if self.noc is None:
            object.__setattr__(self, "noc", noc_topology.NocConfig())
        if self.impl not in ("xla", "pallas", "pallas_sparse"):
            raise ValueError(
                f"unknown impl {self.impl!r}; expected 'xla', 'pallas' or "
                f"'pallas_sparse'")
        if self.sparse_capacity is not None and self.sparse_capacity < 1:
            raise ValueError(
                f"sparse_capacity must be a positive event count, got "
                f"{self.sparse_capacity}")
        # Fail at construction, not at first tick, on unregistered schemes.
        from repro.core import arbiter as _arb  # deferred: avoids import cycle
        from repro.interface import registry
        if self.scheme not in registry.ARBITERS:
            raise ValueError(
                f"unknown arbiter scheme {self.scheme!r}; registered: "
                f"{', '.join(registry.ARBITERS.names())}")
        if self.cam.variant not in registry.CAM_VARIANTS:
            raise ValueError(
                f"unknown CAM variant {self.cam.variant!r}; registered: "
                f"{', '.join(registry.CAM_VARIANTS.names())}")
        del _arb

    @property
    def tag_bits(self) -> int:
        """AER address width: bits needed to tag every neuron uniquely."""
        return max(1, math.ceil(math.log2(self.cores * self.neurons_per_core)))

    @classmethod
    def from_fabric(cls, cfg) -> "InterfaceConfig":
        """Lift a legacy `FabricConfig` into a validated `InterfaceConfig`."""
        return cls(cores=cfg.cores, neurons_per_core=cfg.neurons_per_core,
                   scheme=cfg.scheme, cam=cfg.cam, noc=cfg.noc,
                   impl=getattr(cfg, "impl", "xla"),
                   chips=getattr(cfg, "chips", 1),
                   sparse_capacity=getattr(cfg, "sparse_capacity", None))

    def fabric(self):
        """The equivalent legacy `FabricConfig` (for un-migrated call sites)."""
        from repro.core import fabric as fabric_mod
        return fabric_mod.FabricConfig(
            cores=self.cores, neurons_per_core=self.neurons_per_core,
            scheme=self.scheme, cam=self.cam, noc=self.noc, impl=self.impl,
            chips=self.chips, sparse_capacity=self.sparse_capacity)


def as_interface_config(config) -> InterfaceConfig:
    """Accept an `InterfaceConfig` or any field-compatible legacy config."""
    if isinstance(config, InterfaceConfig):
        return config
    return InterfaceConfig.from_fabric(config)
