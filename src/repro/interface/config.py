"""`InterfaceConfig`: the validated static description of one fabric.

Field-compatible with the legacy `repro.core.fabric.FabricConfig` (same
attribute names), so either type drives `Interface` / `interface_tick`.
Unlike the legacy config, construction is *validated*:

  * ``cam_entries_per_core`` and an explicit ``cam=CamConfig(...)`` must
    agree (the legacy config silently ignored the former),
  * the arbiter scheme and the CAM variant must be registered,
  * the NoC scheme is validated by `NocConfig` itself.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import cam as cam_mod
from repro.noc import topology as noc_topology


def resolve_cam(cam: cam_mod.CamConfig | None, entries: int | None,
                default_entries: int = 512):
    """Shared cam/cam_entries_per_core reconciliation.

    Returns the effective ``(cam, entries)`` pair; raises `ValueError`
    when an explicit config and an explicit entry count disagree.
    """
    if cam is None:
        cam = cam_mod.CamConfig(entries=default_entries if entries is None
                                else entries)
    elif entries is not None and cam.entries != entries:
        raise ValueError(
            f"cam_entries_per_core={entries} conflicts with explicit "
            f"cam=CamConfig(entries={cam.entries}); pass one or make them agree")
    return cam, cam.entries


@dataclasses.dataclass(frozen=True)
class InterfaceConfig:
    """Static description of the full core-interface pipeline.

    scheme:  arbiter architecture (registry: `repro.interface.ARBITERS`)
    cam:     CAM variant/size (registry: `repro.interface.CAM_VARIANTS`)
    noc:     transport scheme (registry: `repro.interface.NOC_SCHEMES`)
    impl:    tick compute backend - "xla" (gather/scatter fast path) or
             "pallas" (route the CAM match through the
             `repro.kernels.cam_search` kernel and the AER address stream
             through `repro.kernels.hat_encode`; falls back to interpret
             mode off-TPU).  Currents are bit-identical across impls.
    """

    cores: int = 4
    neurons_per_core: int = 256
    cam_entries_per_core: int | None = None   # defaults to 512 w/o explicit cam
    scheme: str = "hier_tree"
    cam: cam_mod.CamConfig | None = None
    noc: noc_topology.NocConfig | None = None
    impl: str = "xla"

    def __post_init__(self):
        cam, entries = resolve_cam(self.cam, self.cam_entries_per_core)
        object.__setattr__(self, "cam", cam)
        object.__setattr__(self, "cam_entries_per_core", entries)
        if self.noc is None:
            object.__setattr__(self, "noc", noc_topology.NocConfig())
        if self.impl not in ("xla", "pallas"):
            raise ValueError(
                f"unknown impl {self.impl!r}; expected 'xla' or 'pallas'")
        # Fail at construction, not at first tick, on unregistered schemes.
        from repro.core import arbiter as _arb  # deferred: avoids import cycle
        from repro.interface import registry
        if self.scheme not in registry.ARBITERS:
            raise ValueError(
                f"unknown arbiter scheme {self.scheme!r}; registered: "
                f"{', '.join(registry.ARBITERS.names())}")
        if self.cam.variant not in registry.CAM_VARIANTS:
            raise ValueError(
                f"unknown CAM variant {self.cam.variant!r}; registered: "
                f"{', '.join(registry.CAM_VARIANTS.names())}")
        del _arb

    @property
    def tag_bits(self) -> int:
        return max(1, math.ceil(math.log2(self.cores * self.neurons_per_core)))

    @classmethod
    def from_fabric(cls, cfg) -> "InterfaceConfig":
        """Lift a legacy `FabricConfig` into a validated `InterfaceConfig`."""
        return cls(cores=cfg.cores, neurons_per_core=cfg.neurons_per_core,
                   scheme=cfg.scheme, cam=cfg.cam, noc=cfg.noc,
                   impl=getattr(cfg, "impl", "xla"))

    def fabric(self):
        """The equivalent legacy `FabricConfig` (for un-migrated call sites)."""
        from repro.core import fabric as fabric_mod
        return fabric_mod.FabricConfig(
            cores=self.cores, neurons_per_core=self.neurons_per_core,
            scheme=self.scheme, cam=self.cam, noc=self.noc, impl=self.impl)


def as_interface_config(config) -> InterfaceConfig:
    """Accept an `InterfaceConfig` or any field-compatible legacy config."""
    if isinstance(config, InterfaceConfig):
        return config
    return InterfaceConfig.from_fabric(config)
