"""The core-interface tick: arbiter -> AER encode -> NoC -> CAM, once.

This module owns the per-tick computation that used to live in
`repro.core.fabric.step`.  It is pure-functional JAX, duck-typed over the
config (`InterfaceConfig` or the legacy `FabricConfig`), and dispatches
every scheme decision through `repro.interface.registry` - no string-``if``
chains in the hot path.

The synaptic currents are computed by the same dense CAM-match sweep
regardless of NoC scheme (delivery only changes *where* searches happen,
not their results), so currents are bit-identical across schemes and to
the seed broadcast implementation - `tests/test_interface.py` asserts it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import arbiter as arb
from repro.core import cam as cam_mod
from repro.interface import registry as interface_registry
from repro.interface.stats import StepStats
from repro.interface.types import int_to_bits
from repro.noc import router as noc_router


def build_tables(params, cfg) -> noc_router.NocTables:
    """NoC routing tables for the configured scheme (build once, reuse)."""
    return noc_router.build_tables(params.tags, params.valid,
                                   cores=cfg.cores,
                                   neurons_per_core=cfg.neurons_per_core,
                                   tag_bits=cfg.tag_bits,
                                   scheme=cfg.noc.scheme)


def _hat_order(spikes, n):
    idx = jnp.arange(n, dtype=jnp.int32)
    key = jnp.where(spikes, idx, n)
    return jnp.sort(key), jnp.sum(spikes)


def interface_tick(params, spikes: jnp.ndarray, cfg,
                   tables: noc_router.NocTables | None = None,
                   arb_cfg: arb.ArbiterConfig | None = None
                   ) -> tuple[jnp.ndarray, StepStats]:
    """One fabric tick.

    spikes:  (cores, neurons_per_core) bool
    tables:  optional precomputed `build_tables(params, cfg)` - pass it when
        stepping in a loop (`InterfaceSession` does) to avoid rebuilding the
        subscription masks every tick.  They depend only on (params, cfg).
    arb_cfg: optional prebuilt arbiter plan (the session builds it once).
    returns: currents (cores, neurons_per_core) float32, `StepStats`
    """
    cores, n = spikes.shape
    if n != cfg.neurons_per_core or cores != cfg.cores:
        raise ValueError(
            f"spikes shape ({cores}, {n}) does not match config "
            f"({cfg.cores}, {cfg.neurons_per_core})")
    if spikes.dtype != jnp.bool_:
        spikes = spikes > 0

    if tables is None:
        tables = build_tables(params, cfg)
    if tables.scheme != cfg.noc.scheme:
        raise ValueError(
            f"NoC tables were built for scheme {tables.scheme!r} but the "
            f"config requests {cfg.noc.scheme!r}; rebuild them with "
            f"repro.interface.build_tables(params, cfg)")
    if arb_cfg is None:
        arb_cfg = arb.ArbiterConfig(cfg.scheme, n)
    noc_scheme = interface_registry.get_noc_scheme(cfg.noc.scheme)
    arbiter = arb.Arbiter(arb_cfg)

    # ---- output interface: arbitrate + encode each core's spikes ----------
    def encode_core(core_spikes):
        req = jnp.where(core_spikes, 0.0, jnp.inf).astype(jnp.float32)
        grants = arbiter.simulate(req)
        lat = jnp.where(jnp.any(core_spikes),
                        jnp.max(jnp.where(jnp.isfinite(grants), grants, 0.0)), 0.0)
        return lat

    latencies = jax.vmap(encode_core)(spikes)

    # global source tags of every spiking neuron (dense mask form)
    neuron_global = (jnp.arange(cores)[:, None] * n + jnp.arange(n)[None, :])
    src_bits = int_to_bits(neuron_global, cfg.tag_bits)      # (cores, n, bits)

    # ---- input interface: CAM match per target core -----------------------
    # match[c_tgt, entry, c_src, neuron] = entry subscribed to that source
    def core_inputs(tags_c, valid_c, weights_c, targets_c):
        # (entries, bits) vs (cores*n, bits)
        flat_bits = src_bits.reshape(-1, cfg.tag_bits)
        eq = jnp.all(tags_c[:, None, :] == flat_bits[None, :, :], axis=-1)
        hit = eq & valid_c[:, None] & spikes.reshape(-1)[None, :]
        entry_drive = jnp.sum(hit, axis=1).astype(jnp.float32)  # events per entry
        contrib = entry_drive * weights_c
        currents = jnp.zeros((n,), jnp.float32).at[targets_c].add(contrib)
        return currents, jnp.sum(hit)

    currents, hits = jax.vmap(core_inputs)(params.tags, params.valid,
                                           params.weights, params.targets)

    # ---- NoC delivery + PPA accounting ------------------------------------
    spikes_flat = spikes.reshape(-1)
    total_events = jnp.sum(spikes).astype(jnp.float32)
    addr_seq, _ = jax.vmap(lambda s: _hat_order(s, n))(spikes)
    enc_energy = jax.vmap(
        lambda seq: arb.encode_energy_units(cfg.scheme, n, seq))(addr_seq)

    valid_cnt = jnp.sum(params.valid, axis=1).astype(jnp.float32)
    searches, entries_per_search = noc_scheme.cam_accounting(
        tables, spikes_flat, valid_cnt, total_events, cores)
    match_per_search = jnp.sum(hits).astype(jnp.float32) / jnp.maximum(searches, 1.0)
    mismatch_per_search = entries_per_search - match_per_search
    cam_energy = searches * cam_mod._energy_jnp(cfg.cam, match_per_search,
                                                mismatch_per_search)
    cam_time = searches * cam_mod.cycle_time_ns(cfg.cam)

    noc_hops, noc_latency, noc_energy, _ = noc_router.noc_step_costs(
        tables, spikes_flat)

    stats = StepStats(events=total_events,
                      encode_latency=jnp.max(latencies),
                      encode_energy=jnp.sum(enc_energy * jnp.sum(spikes, 1)),
                      cam_searches=searches,
                      cam_energy=cam_energy,
                      cam_time_ns=cam_time,
                      noc_hops=noc_hops,
                      noc_latency=noc_latency,
                      noc_energy=noc_energy)
    return currents, stats
