"""The core-interface tick: arbiter -> AER encode -> NoC -> CAM, once.

This module owns the per-tick computation that used to live in
`repro.core.fabric.step`.  It is pure-functional JAX, duck-typed over the
config (`InterfaceConfig` or the legacy `FabricConfig`), and dispatches
every scheme decision through `repro.interface.registry` - no string-``if``
chains in the hot path.

Event-driven hot path (the default): a `RoutingIndex` built once per
(params, cfg) decodes every CAM entry's stored tag back to its global
source-neuron index (the same int-pack trick as
`noc.multicast.subscription_matrix`), so the per-tick CAM match collapses
to a gather ``spikes_flat[src_idx] & active`` plus one weighted
scatter-add per core - no (entries x cores*n x tag_bits) equality tensor
is ever materialized.  Arbiter latency comes from the scheme's vectorized
``tick_latency`` policy (`repro.core.arbiter.batched_tick_latency`)
instead of an in-tick discrete-event simulation, and the AER address
stream is produced by `repro.kernels.hat_encode`.  ``cfg.impl`` selects
the match backend: ``"xla"`` (gather), ``"pallas"`` (the
`repro.kernels.cam_search` kernel; interpret-mode off-TPU), or
``"pallas_sparse"`` (`_sparse_event_tick`: per-core event compaction
feeding the fused `repro.kernels.sparse_tick` kernel, with a dense
fallback when a core overflows ``cfg.sparse_capacity`` - per-tick cost
scales with events rather than fabric size, results stay bit-identical).

The pre-optimization dense sweep survives as ``interface_tick(...,
oracle=True)`` - the reference the fast path is held bit-identical to in
`tests/test_interface.py` and `benchmarks/noc_bench.py`.

The synaptic currents are computed from the same CAM-match semantics
regardless of NoC scheme (delivery only changes *where* searches happen,
not their results), so currents are bit-identical across schemes, impls,
and to the seed broadcast implementation.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import arbiter as arb
from repro.core import cam as cam_mod
from repro.interface import registry as interface_registry
from repro.interface.stats import StepStats
from repro.interface.types import int_to_bits
from repro.kernels.cam_search import ops as cam_ops
from repro.kernels.hat_encode import ops as hat_ops
from repro.kernels.sparse_tick import ops as sparse_ops
from repro.noc import hierarchy
from repro.noc import router as noc_router
from repro.obs import telemetry as obs_telemetry


def build_tables(params, cfg):
    """NoC routing tables for the configured scheme (build once, reuse).

    Returns flat single-chip `NocTables`, or two-tier
    `repro.noc.hierarchy.HierTables` (chip-local meshes + inter-chip
    router level) when ``cfg.chips > 1``.
    """
    chips = getattr(cfg, "chips", 1)
    if chips > 1:
        return hierarchy.build_hier_tables(
            params.tags, params.valid, chips=chips,
            cores_per_chip=cfg.cores_per_chip,
            neurons_per_core=cfg.neurons_per_core,
            tag_bits=cfg.tag_bits, scheme=cfg.noc.scheme)
    return noc_router.build_tables(params.tags, params.valid,
                                   cores=cfg.cores,
                                   neurons_per_core=cfg.neurons_per_core,
                                   tag_bits=cfg.tag_bits,
                                   scheme=cfg.noc.scheme)


class RoutingIndex(NamedTuple):
    """Compile-time decode of the CAM tags into gather/kernel operands.

    Everything here depends only on (params, cfg) - `InterfaceSession`
    builds it once; the per-tick step just gathers through it.  Each CAM
    entry's stored tag resolves to a *global* source address at compile
    time: ``src_idx`` is the flat neuron index, and ``src_chip`` /
    ``src_core`` decode it to (chip, core-within-chip) under the fabric's
    chip tier (``src_chip`` is all-zero on flat single-chip configs).
    """

    src_idx: jnp.ndarray     # (cores, entries) int32 global source index
    active: jnp.ndarray      # (cores, entries) bool: valid & tag in range
    src_chip: jnp.ndarray    # (cores, entries) int32 source chip
    src_core: jnp.ndarray    # (cores, entries) int32 source core within chip
    q_words: jnp.ndarray     # (cores*entries, W) int32 packed entry tags
    src_words: jnp.ndarray   # (cores*neurons, W) int32 packed source addrs


def build_routing_index(params, cfg) -> RoutingIndex:
    """Decode each CAM entry's tag to a source index, once (int-pack)."""
    total = cfg.cores * cfg.neurons_per_core
    bits = cfg.tag_bits
    bit_w = jnp.left_shift(1, jnp.arange(bits - 1, -1, -1))      # big-endian
    src_int = jnp.sum(params.tags * bit_w, axis=-1)              # (C, E)
    # tag values outside the populated address space never match a source
    active = params.valid & (src_int < total)
    src_idx = jnp.minimum(src_int, total - 1).astype(jnp.int32)
    per_chip = getattr(cfg, "cores_per_chip", None) or cfg.cores
    src_chip, src_core = hierarchy.chip_of_core(
        src_idx // cfg.neurons_per_core, per_chip)
    q_words = cam_ops.pack_bits(params.tags.reshape(-1, bits))
    src_words = cam_ops.pack_bits(int_to_bits(jnp.arange(total), bits))
    return RoutingIndex(src_idx=src_idx, active=active,
                        src_chip=src_chip.astype(jnp.int32),
                        src_core=src_core.astype(jnp.int32),
                        q_words=q_words, src_words=src_words)


def _hat_order(spikes, n):
    idx = jnp.arange(n, dtype=jnp.int32)
    key = jnp.where(spikes, idx, n)
    return jnp.sort(key), jnp.sum(spikes)


def _entry_drive(params, spikes_flat, routing: RoutingIndex, cfg):
    """(cores, entries) float32 {0,1}: is this entry's source spiking?"""
    impl = getattr(cfg, "impl", "xla")
    if impl == "pallas":
        interpret = jax.default_backend() != "tpu"
        counts = cam_ops.cam_match_counts(
            routing.q_words, routing.src_words, spikes_flat,
            impl="pallas", interpret=interpret)
        hit = counts.reshape(params.valid.shape) > 0
        return (hit & params.valid).astype(jnp.float32)
    return (spikes_flat[routing.src_idx] & routing.active).astype(jnp.float32)


def _addr_streams(spikes, cfg, n):
    """(cores, n) int32 AER address streams (service order, padded with n)."""
    impl = getattr(cfg, "impl", "xla")
    row = 256
    hat_impl = "xla"
    interpret = False
    if impl == "pallas" and n % row == 0 and n <= hat_ops.MAX_PALLAS_N:
        hat_impl = "pallas"
        interpret = jax.default_backend() != "tpu"

    def one(core_spikes):
        stream, _ = hat_ops.encode_stream(core_spikes, row=row,
                                          impl=hat_impl, interpret=interpret)
        return stream

    return jax.vmap(one)(spikes)


def resolve_sparse_plan(cfg, arb_cfg: arb.ArbiterConfig | None = None):
    """Validate and resolve the ``impl="pallas_sparse"`` policy bundle.

    Returns ``(latency_fn, encode_fn, sparse_cam_accounting, capacity)``.
    Sessions call this at compile time so unsupported configurations fail
    fast with a nameable error instead of mid-scan.

    Raises:
      ValueError: when the arbiter scheme provides no sparse tick policy
        at this fabric size (e.g. ``greedy_tree`` with ``n <= 2``,
        ``hier_ring`` with a non-square address space), or the NoC scheme
        has no event-indexed CAM accounting, or ``sparse_capacity`` is
        not a positive int.
    """
    n = cfg.neurons_per_core
    if arb_cfg is None:
        arb_cfg = arb.ArbiterConfig(cfg.scheme, n)
    entry = interface_registry.get_arbiter(cfg.scheme)
    ctx = arb.make_context(arb_cfg)
    latency_fn = (entry.sparse_tick_latency(ctx)
                  if entry.sparse_tick_latency is not None else None)
    encode_fn = (entry.sparse_encode_energy(ctx)
                 if entry.sparse_encode_energy is not None else None)
    if latency_fn is None or encode_fn is None:
        raise ValueError(
            f"impl='pallas_sparse' is unsupported for arbiter scheme "
            f"{cfg.scheme!r} at n={n}: the scheme's sparse tick policies "
            f"are undefined there (use impl='xla' or 'pallas')")
    noc_scheme = interface_registry.get_noc_scheme(cfg.noc.scheme)
    if noc_scheme.sparse_cam_accounting is None:
        raise ValueError(
            f"impl='pallas_sparse' is unsupported for NoC scheme "
            f"{cfg.noc.scheme!r}: it registers no event-indexed CAM "
            f"accounting (use impl='xla' or 'pallas')")
    capacity = sparse_ops.resolve_capacity(
        getattr(cfg, "sparse_capacity", None), n)
    return latency_fn, encode_fn, noc_scheme.sparse_cam_accounting, capacity


def sparse_accounting_stats(cfg, tables, counts, ev_idx, ev_w, latencies,
                            enc_per_core, hits_total, valid, cam_cycle_ns,
                            sparse_cam_accounting) -> StepStats:
    """Event-indexed `accounting_stats` for the sparse tick.

    Mirrors the dense accounting term by term, but gathers every
    per-source table column at this tick's events (``ev_idx``/``ev_w``
    from `repro.kernels.sparse_tick.event_indices`) instead of reducing
    over the full fabric.  Every reduction sums the same exact small
    integers as the dense form, so the `StepStats` it returns is
    bit-identical (held to that across the grid in tests/conformance).
    """
    total_events = jnp.sum(counts).astype(jnp.float32)
    valid_cnt = jnp.sum(valid, axis=1).astype(jnp.float32)
    searches, entries_per_search = sparse_cam_accounting(
        tables, ev_idx, ev_w, valid_cnt, total_events, cfg.cores)
    match_per_search = hits_total.astype(jnp.float32) / jnp.maximum(searches,
                                                                    1.0)
    mismatch_per_search = entries_per_search - match_per_search
    cam_energy = searches * cam_mod._energy_jnp(cfg.cam, match_per_search,
                                                mismatch_per_search)
    cam_time = searches * cam_cycle_ns

    noc_hops, noc_latency, noc_energy, _ = noc_router.noc_step_costs_events(
        tables, ev_idx, ev_w)
    chip_hops, chip_latency, chip_energy = hierarchy.chip_step_costs_events(
        tables, ev_idx, ev_w)

    return StepStats(events=total_events,
                     encode_latency=jnp.max(latencies),
                     encode_energy=jnp.sum(enc_per_core * counts),
                     cam_searches=searches,
                     cam_energy=cam_energy,
                     cam_time_ns=cam_time,
                     noc_hops=noc_hops,
                     noc_latency=noc_latency,
                     noc_energy=noc_energy,
                     chip_hops=chip_hops,
                     chip_latency=chip_latency,
                     chip_energy=chip_energy)


def _sparse_event_tick(params, spikes, cfg, tables, arb_cfg, routing,
                       cam_cycle_ns, noc_scheme, unchecked=False):
    """The ``impl="pallas_sparse"`` tick: compact, fuse, or fall back.

    Compacts the frame into per-core event buffers, then runs *one*
    `jax.lax.cond`: the sparse branch feeds the buffers through the fused
    `repro.kernels.sparse_tick` kernel plus event-indexed accounting; the
    dense branch is the ordinary event-driven tick, taken whenever any
    core fired more than ``sparse_capacity`` events this tick.  Both
    branches produce bit-identical ``(currents, latencies, enc_per_core,
    StepStats)``, so the fallback only changes cost, never results.

    The per-tick ``cond`` itself is not free (XLA conditionals cost tens
    of microseconds per tick on CPU hosts), so callers that have already
    proven *no* frame of a stream overflows - `InterfaceSession` checks
    ``max per-core events <= capacity`` host-side once per `run` call -
    pass ``unchecked=True`` to compile the sparse branch alone, with no
    cond in the scan body.  Results are bit-identical by construction;
    passing ``unchecked=True`` on a stream that does overflow silently
    truncates events, which is why the flag is session-internal.

    Under `jax.vmap` (``run_batched``) the cond lowers to a select that
    evaluates both branches - correct, but the sparse speedup only
    materializes through the unchecked path (the session's host-side
    precheck covers the whole batch, so fully-sparse batches take it).
    """
    n = cfg.neurons_per_core
    latency_fn, encode_fn, sparse_cam, capacity = resolve_sparse_plan(
        cfg, arb_cfg)
    spikes_flat = spikes.reshape(-1)
    buf, counts = sparse_ops.compact_events(spikes, capacity)

    def sparse_branch(_):
        with jax.named_scope("repro.sparse_tick"):
            currents, latencies, enc_per_core, hits_total = \
                sparse_ops.sparse_tick(
                    spikes_flat, buf, counts, routing.src_idx, routing.active,
                    params.weights, params.targets, n=n,
                    latency_fn=latency_fn, encode_fn=encode_fn)
            ev_idx, ev_w = sparse_ops.event_indices(buf, n)
            stats = sparse_accounting_stats(
                cfg, tables, counts, ev_idx, ev_w, latencies, enc_per_core,
                hits_total, params.valid, cam_cycle_ns, sparse_cam)
        return currents, latencies, enc_per_core, stats

    def dense_branch(_):
        with jax.named_scope("repro.sparse_dense_fallback"):
            latencies = arb.batched_tick_latency(arb_cfg, spikes)
            entry_drive = _entry_drive(params, spikes_flat, routing, cfg)
            contrib = entry_drive * params.weights
            currents = jax.vmap(
                lambda c, t: jnp.zeros((n,), jnp.float32).at[t].add(c)
            )(contrib, params.targets)
            hits_total = jnp.sum(entry_drive)
            addr_seq = _addr_streams(spikes, cfg, n)
            enc_per_core = jax.vmap(
                lambda seq: arb.encode_energy_units(cfg.scheme, n, seq)
            )(addr_seq)
            stats = accounting_stats(cfg, tables, spikes, latencies,
                                     enc_per_core, hits_total, params.valid,
                                     cam_cycle_ns, noc_scheme)
        return currents, latencies, enc_per_core, stats

    if unchecked:
        return sparse_branch(None)
    overflow = jnp.any(counts > capacity)
    return jax.lax.cond(overflow, dense_branch, sparse_branch, None)


def interface_tick(params, spikes: jnp.ndarray, cfg,
                   tables: noc_router.NocTables | None = None,
                   arb_cfg: arb.ArbiterConfig | None = None,
                   routing: RoutingIndex | None = None,
                   cam_cycle_ns: float | None = None,
                   oracle: bool = False,
                   telemetry: str = "off",
                   sparse_unchecked: bool = False,
                   ) -> tuple[jnp.ndarray, StepStats]:
    """One fabric tick.

    spikes:  (cores, neurons_per_core) bool
    tables:  optional precomputed `build_tables(params, cfg)` - pass it when
        stepping in a loop (`InterfaceSession` does) to avoid rebuilding the
        subscription masks every tick.  They depend only on (params, cfg).
    arb_cfg: optional prebuilt arbiter plan (the session builds it once).
    routing: optional prebuilt `build_routing_index(params, cfg)`.
    cam_cycle_ns: optional precomputed `cam.cycle_time_ns(cfg.cam)` (the
        session passes its `cam_cycle_ns` attribute).
    oracle:  run the pre-optimization reference path - dense tag-vs-every-
        source CAM sweep + per-core discrete-event arbiter simulation.  The
        default event-driven path is bit-identical to it (tested).
    telemetry: ``"off"`` (default) returns ``(currents, StepStats)``
        exactly as always; ``"cores"`` additionally returns a
        `repro.obs.telemetry.CoreStats` per-core breakdown as a third
        element.  The tick computation is identical either way - currents
        and stats are bit-identical across telemetry modes.
    sparse_unchecked: only meaningful under ``impl="pallas_sparse"``:
        skip the per-tick overflow ``lax.cond`` and run the fused sparse
        branch unconditionally.  Callers must have proven no core exceeds
        ``sparse_capacity`` events on any frame they will pass (the
        session's host-side precheck); see `_sparse_event_tick`.
    returns: currents (cores, neurons_per_core) float32, `StepStats`
        (plus `CoreStats` under ``telemetry="cores"``)
    """
    if telemetry not in ("off", "cores"):
        raise ValueError(
            f"interface_tick telemetry must be 'off' or 'cores' (the "
            f"'ticks' mode is a session-level scan concern), got {telemetry!r}")
    cores, n = spikes.shape
    if n != cfg.neurons_per_core or cores != cfg.cores:
        raise ValueError(
            f"spikes shape ({cores}, {n}) does not match config "
            f"({cfg.cores}, {cfg.neurons_per_core})")
    if spikes.dtype != jnp.bool_:
        spikes = spikes > 0

    if tables is None:
        tables = build_tables(params, cfg)
    if tables.scheme != cfg.noc.scheme:
        raise ValueError(
            f"NoC tables were built for scheme {tables.scheme!r} but the "
            f"config requests {cfg.noc.scheme!r}; rebuild them with "
            f"repro.interface.build_tables(params, cfg)")
    if getattr(tables, "chips", 1) != getattr(cfg, "chips", 1):
        raise ValueError(
            f"NoC tables were built for chips={getattr(tables, 'chips', 1)} "
            f"but the config requests chips={getattr(cfg, 'chips', 1)}; "
            f"rebuild them with repro.interface.build_tables(params, cfg)")
    if arb_cfg is None:
        arb_cfg = arb.ArbiterConfig(cfg.scheme, n)
    if cam_cycle_ns is None:
        cam_cycle_ns = cam_mod.cycle_time_ns(cfg.cam)
    noc_scheme = interface_registry.get_noc_scheme(cfg.noc.scheme)

    spikes_flat = spikes.reshape(-1)

    if oracle:
        # ---- reference path: DES arbiter + dense CAM sweep ----------------
        arbiter = arb.Arbiter(arb_cfg)

        def encode_core(core_spikes):
            req = jnp.where(core_spikes, 0.0, jnp.inf).astype(jnp.float32)
            grants = arbiter.simulate(req)
            return jnp.where(
                jnp.any(core_spikes),
                jnp.max(jnp.where(jnp.isfinite(grants), grants, 0.0)), 0.0)

        latencies = jax.vmap(encode_core)(spikes)

        # global source tags of every spiking neuron (dense mask form)
        neuron_global = (jnp.arange(cores)[:, None] * n +
                         jnp.arange(n)[None, :])
        src_bits = int_to_bits(neuron_global, cfg.tag_bits)  # (cores, n, bits)

        # match[entry, c_src * n + neuron] = entry subscribed to that source
        def core_inputs(tags_c, valid_c, weights_c, targets_c):
            # (entries, bits) vs (cores*n, bits)
            flat_bits = src_bits.reshape(-1, cfg.tag_bits)
            eq = jnp.all(tags_c[:, None, :] == flat_bits[None, :, :], axis=-1)
            hit = eq & valid_c[:, None] & spikes_flat[None, :]
            entry_drive = jnp.sum(hit, axis=1).astype(jnp.float32)
            contrib = entry_drive * weights_c
            currents = jnp.zeros((n,), jnp.float32).at[targets_c].add(contrib)
            return currents, jnp.sum(hit)

        currents, hits = jax.vmap(core_inputs)(params.tags, params.valid,
                                               params.weights, params.targets)
        hits_total = jnp.sum(hits)
        addr_seq = jax.vmap(lambda s: _hat_order(s, n)[0])(spikes)
    else:
        # ---- event-driven path: policy latency + gather/scatter -----------
        if routing is None:
            routing = build_routing_index(params, cfg)
        if getattr(cfg, "impl", "xla") == "pallas_sparse":
            currents, latencies, enc_per_core, stats = _sparse_event_tick(
                params, spikes, cfg, tables, arb_cfg, routing, cam_cycle_ns,
                noc_scheme, unchecked=sparse_unchecked)
            if telemetry == "cores":
                with jax.named_scope("repro.telemetry_cores"):
                    core = per_core_stats(cfg, tables, spikes, latencies,
                                          enc_per_core)
                return currents, stats, core
            return currents, stats
        with jax.named_scope("repro.arbiter_latency"):
            latencies = arb.batched_tick_latency(arb_cfg, spikes)
        with jax.named_scope("repro.cam_match"):
            entry_drive = _entry_drive(params, spikes_flat, routing, cfg)
            contrib = entry_drive * params.weights
            currents = jax.vmap(
                lambda c, t: jnp.zeros((n,), jnp.float32).at[t].add(c)
            )(contrib, params.targets)
            hits_total = jnp.sum(entry_drive)
        with jax.named_scope("repro.aer_encode"):
            addr_seq = _addr_streams(spikes, cfg, n)

    # ---- NoC delivery + PPA accounting ------------------------------------
    with jax.named_scope("repro.accounting"):
        enc_per_core = jax.vmap(
            lambda seq: arb.encode_energy_units(cfg.scheme, n, seq))(addr_seq)
        stats = accounting_stats(cfg, tables, spikes, latencies, enc_per_core,
                                 hits_total, params.valid, cam_cycle_ns,
                                 noc_scheme)
    if telemetry == "cores":
        with jax.named_scope("repro.telemetry_cores"):
            core = per_core_stats(cfg, tables, spikes, latencies, enc_per_core)
        return currents, stats, core
    return currents, stats


def accounting_stats(cfg, tables, spikes, latencies, enc_per_core,
                     hits_total, valid, cam_cycle_ns,
                     noc_scheme=None) -> StepStats:
    """The per-tick PPA accounting tail, shared by every execution path.

    Both `interface_tick` (flat and oracle) and the chip-sharded session
    tick funnel through this function, so the `StepStats` arithmetic is
    identical by construction across paths: callers only differ in how
    they produce the per-core quantities (``latencies`` (cores,) grant
    completion times, ``enc_per_core`` (cores,) address-line toggles per
    event, ``hits_total`` scalar CAM hits).
    """
    if noc_scheme is None:
        noc_scheme = interface_registry.get_noc_scheme(cfg.noc.scheme)
    spikes_flat = spikes.reshape(-1)
    total_events = jnp.sum(spikes).astype(jnp.float32)

    valid_cnt = jnp.sum(valid, axis=1).astype(jnp.float32)
    searches, entries_per_search = noc_scheme.cam_accounting(
        tables, spikes_flat, valid_cnt, total_events, cfg.cores)
    match_per_search = hits_total.astype(jnp.float32) / jnp.maximum(searches, 1.0)
    mismatch_per_search = entries_per_search - match_per_search
    cam_energy = searches * cam_mod._energy_jnp(cfg.cam, match_per_search,
                                                mismatch_per_search)
    cam_time = searches * cam_cycle_ns

    noc_hops, noc_latency, noc_energy, _ = noc_router.noc_step_costs(
        tables, spikes_flat)
    chip_hops, chip_latency, chip_energy = hierarchy.chip_step_costs(
        tables, spikes_flat)

    return StepStats(events=total_events,
                     encode_latency=jnp.max(latencies),
                     encode_energy=jnp.sum(enc_per_core * jnp.sum(spikes, 1)),
                     cam_searches=searches,
                     cam_energy=cam_energy,
                     cam_time_ns=cam_time,
                     noc_hops=noc_hops,
                     noc_latency=noc_latency,
                     noc_energy=noc_energy,
                     chip_hops=chip_hops,
                     chip_latency=chip_latency,
                     chip_energy=chip_energy)


def per_core_stats(cfg, tables, spikes, latencies,
                   enc_per_core) -> obs_telemetry.CoreStats:
    """Per-core telemetry breakdown of one tick (``telemetry="cores"``).

    NoC/chip hops are attributed to each event's *source* core (the core
    whose arbiter emitted it) through the same precomputed per-source hop
    tables `accounting_stats` totals over, so the per-core vectors sum
    exactly back to `StepStats.noc_hops` / ``chip_hops``; events and
    encode energy likewise sum, and the per-tick ``encode_latency`` is the
    max over cores (a tick completes when its slowest arbiter does).
    """
    cores = spikes.shape[0]
    ev_flat = spikes.reshape(-1).astype(jnp.float32)
    events = jnp.sum(spikes, axis=1).astype(jnp.float32)
    noc_hops = jnp.sum((ev_flat * tables.hops).reshape(cores, -1), axis=1)
    if isinstance(tables, hierarchy.HierTables):
        chip_hops = jnp.sum((ev_flat * tables.chip_hops).reshape(cores, -1),
                            axis=1)
    else:
        chip_hops = jnp.zeros((cores,), jnp.float32)
    return obs_telemetry.CoreStats(events=events,
                                   encode_latency=latencies.astype(jnp.float32),
                                   encode_energy=enc_per_core * events,
                                   noc_hops=noc_hops,
                                   chip_hops=chip_hops)
