"""Scheme registries for the core-interface pipeline.

Three registries, one per pipeline stage:

  ARBITERS      output-interface arbitration policies (`core/arbiter.py`)
  CAM_VARIANTS  input-interface CAM circuit variants (`core/cam.py`)
  NOC_SCHEMES   inter-core transport schemes (`noc/router.py`)

The registry replaces the string-``if`` scheme dispatch that used to live
inside the hot paths: a scheme name is resolved to an *entry* object once
(at config-validation / trace time), and from then on everything is a
plain attribute access on the entry.  New schemes plug in through
``register_*`` without editing the fabric, the router, or the session.

This module is intentionally dependency-free (no jax, no repro imports)
so that any layer — core, noc, interface — can import it without cycles.
Entry objects are defined next to the code they dispatch to and passed in
opaquely; the registry neither inspects nor constrains them beyond the
name they are registered under.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple


class SchemeRegistry:
    """A named mapping of scheme name -> entry with helpful failures."""

    def __init__(self, kind: str):
        """kind: human-readable scheme family name, used in error text."""
        self.kind = kind
        self._entries: Dict[str, Any] = {}

    def register(self, name: str, entry: Any, *, overwrite: bool = False) -> Any:
        """Bind ``name`` to an opaque entry object and return the entry.

        Raises:
          ValueError: on an empty/non-str name, or when ``name`` is
            already registered and ``overwrite`` is False (replacing a
            scheme must be an explicit decision - tests that shadow a
            builtin pass ``overwrite=True`` and restore it after).
        """
        if not isinstance(name, str) or not name:
            raise ValueError(f"{self.kind} scheme name must be a non-empty str")
        if name in self._entries and not overwrite:
            raise ValueError(
                f"{self.kind} scheme {name!r} is already registered; "
                f"pass overwrite=True to replace it")
        self._entries[name] = entry
        return entry

    def unregister(self, name: str) -> None:
        """Remove ``name`` if present; unknown names are a no-op."""
        self._entries.pop(name, None)

    def get(self, name: str) -> Any:
        """Resolve ``name`` to its entry.

        Raises:
          KeyError: on an unknown name; the message lists every
            registered scheme of this kind, so a typo'd config fails
            with the valid choices in hand.
        """
        try:
            return self._entries[name]
        except KeyError:
            raise KeyError(
                f"unknown {self.kind} scheme {name!r}; registered: "
                f"{', '.join(self.names()) or '(none)'}") from None

    def names(self) -> Tuple[str, ...]:
        """All registered scheme names, sorted (stable for error text)."""
        return tuple(sorted(self._entries))

    def __contains__(self, name: str) -> bool:
        """Membership test: ``"hier_tree" in ARBITERS``."""
        return name in self._entries

    def __iter__(self):
        """Iterate registered names in sorted order."""
        return iter(self.names())

    def __len__(self) -> int:
        """Number of registered schemes."""
        return len(self._entries)


ARBITERS = SchemeRegistry("arbiter")
CAM_VARIANTS = SchemeRegistry("CAM variant")
NOC_SCHEMES = SchemeRegistry("NoC")


def register_arbiter(name: str, entry: Any, *, overwrite: bool = False) -> Any:
    """Register an arbitration policy (see `repro.core.arbiter.ArbiterScheme`)."""
    return ARBITERS.register(name, entry, overwrite=overwrite)


def register_cam_variant(name: str, entry: Any, *, overwrite: bool = False) -> Any:
    """Register a CAM circuit variant (see `repro.core.cam.CamVariant`)."""
    return CAM_VARIANTS.register(name, entry, overwrite=overwrite)


def register_noc_scheme(name: str, entry: Any, *, overwrite: bool = False) -> Any:
    """Register a transport scheme (see `repro.noc.router.NocScheme`)."""
    return NOC_SCHEMES.register(name, entry, overwrite=overwrite)


def get_arbiter(name: str) -> Any:
    """Resolve an arbiter scheme name (KeyError lists valid names)."""
    return ARBITERS.get(name)


def get_cam_variant(name: str) -> Any:
    """Resolve a CAM variant name (KeyError lists valid names)."""
    return CAM_VARIANTS.get(name)


def get_noc_scheme(name: str) -> Any:
    """Resolve a NoC scheme name (KeyError lists valid names)."""
    return NOC_SCHEMES.get(name)
