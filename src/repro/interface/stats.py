"""Per-tick interface cost record with streaming accumulation.

`StepStats` is the accounting record `fabric.step` always returned; it now
also supports the scan-friendly accumulate pattern used by
`InterfaceSession.run`:

    acc = StepStats.zeros()
    acc, _ = jax.lax.scan(lambda a, s: (a.accumulate(tick(s)), ...), acc, xs)
    acc.summary(ticks=T)      # {'events': ..., ...} per-tick means

All fields are scalar jnp arrays.  Latency fields are per-tick quantities;
accumulating sums them like everything else, so ``summary(ticks=T)``
reports the mean per tick (the convention `models/snn.py` always used).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class StepStats(NamedTuple):
    """Per-tick (or accumulated) interface cost record, one scalar per
    modelled quantity.  A jax pytree: flows through scans/vmaps as the
    accumulate carry and supports `zeros`/`accumulate`/`summary`."""

    events: jnp.ndarray            # scalar: total address events this tick
    encode_latency: jnp.ndarray    # scalar: max grant latency (units)
    encode_energy: jnp.ndarray     # scalar: address-line toggles
    cam_searches: jnp.ndarray      # scalar: CAM search operations
    cam_energy: jnp.ndarray        # scalar: CAM model energy units
    cam_time_ns: jnp.ndarray       # scalar: serialized CAM search time
    noc_hops: jnp.ndarray          # scalar: chip-local mesh link traversals
    noc_latency: jnp.ndarray       # scalar: chip-local delivery latency (ns)
    noc_energy: jnp.ndarray        # scalar: chip-local NoC energy (units)
    # Inter-chip router tier (repro.noc.hierarchy); all zero when chips=1.
    # Appended after the original fields so positional consumers keep
    # working on flat single-chip fabrics.
    chip_hops: jnp.ndarray         # scalar: inter-chip link traversals
    chip_latency: jnp.ndarray      # scalar: inter-chip delivery latency (ns)
    chip_energy: jnp.ndarray       # scalar: inter-chip energy (model units)

    @classmethod
    def zeros(cls) -> "StepStats":
        """The additive identity: every field a float32 scalar zero."""
        z = jnp.zeros((), jnp.float32)
        return cls(*([z] * len(cls._fields)))

    def accumulate(self, other: "StepStats") -> "StepStats":
        """Elementwise running sum (scan carry)."""
        return jax.tree.map(jnp.add, self, other)

    def mean(self, ticks) -> "StepStats":
        """Per-tick means of an accumulated record.

        ``ticks`` must be a positive tick count: dividing by zero would
        silently turn every field into inf/nan, so that raises instead.
        (Traced values can't be validated and pass through unchecked.)
        """
        try:
            ticks_f = float(ticks)
        except TypeError:       # traced under jit / non-scalar: no host check
            ticks_f = None
        if ticks_f is not None and (not math.isfinite(ticks_f) or ticks_f <= 0):
            raise ValueError(
                f"ticks must be a positive tick count, got {ticks!r}; "
                f"a zero-tick mean would silently report inf/nan")
        d = jnp.asarray(ticks, jnp.float32)
        return jax.tree.map(lambda a: a / d, self)

    def summary(self, ticks=None) -> dict:
        """Plain-float dict: totals, or per-tick means when `ticks` given."""
        rec = self if ticks is None else self.mean(ticks)
        return {k: float(v) for k, v in rec._asdict().items()}
