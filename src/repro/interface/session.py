"""Compile-once sessions over the core-interface pipeline.

`Interface(config).compile(params)` pre-builds everything the per-tick
step needs exactly once - the arbiter plan, the NoC subscription/link
tables, the CAM routing index (stored tags decoded back to source-neuron
indices), the CAM calibration constants - and returns an
`InterfaceSession` whose `run` / `run_batched` execute multi-timestep
simulation as a single jit-compiled `jax.lax.scan` (+`vmap` for the
batched form) with streaming `StepStats` accumulation.

This replaces the seed pattern of calling `fabric.step` in a Python loop,
which re-entered jit dispatch every tick and silently rebuilt the NoC
tables whenever the caller forgot to thread them through.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import arbiter as arb
from repro.core import cam as cam_mod
from repro.interface import pipeline
from repro.interface.config import as_interface_config
from repro.interface.stats import StepStats


class Interface:
    """Factory for precompiled sessions over one interface configuration."""

    def __init__(self, config):
        """config: `InterfaceConfig` or a legacy `FabricConfig`."""
        self.config = as_interface_config(config)

    def compile(self, params) -> "InterfaceSession":
        """Bind routing state; build all plans/tables/constants once."""
        return InterfaceSession(self.config, params)

    def ppa_report(self) -> dict:
        from repro.interface import report
        return report.ppa_report(self.config)


class InterfaceSession:
    """A precompiled (config, params) binding with scan-based execution.

    Attributes built once at construction:
      tables    NoC subscription/hop/link tables (`NocTables`)
      arb_plan  arbiter plan (`ArbiterConfig`: scheme entry, levels, fill)
      routing   CAM tags decoded to source indices (`RoutingIndex`) - the
                per-tick CAM match is a gather through it (or the
                `cam_search` kernel when ``cfg.impl == "pallas"``)
      cam_cycle_ns  CAM search cycle time for the configured variant
    """

    def __init__(self, config, params):
        self.config = as_interface_config(config)
        self.params = params
        cfg = self.config
        self.tables = pipeline.build_tables(params, cfg)
        self.arb_plan = arb.ArbiterConfig(cfg.scheme, cfg.neurons_per_core)
        self.routing = pipeline.build_routing_index(params, cfg)
        self.cam_cycle_ns = cam_mod.cycle_time_ns(cfg.cam)
        tables, arb_plan, routing = self.tables, self.arb_plan, self.routing
        cam_cycle_ns = self.cam_cycle_ns

        def tick(p, spikes_cn):
            return pipeline.interface_tick(p, spikes_cn, cfg, tables, arb_plan,
                                           routing=routing,
                                           cam_cycle_ns=cam_cycle_ns)

        def run(p, spikes_tcn):
            def body(acc, s_t):
                currents, st = tick(p, s_t)
                return acc.accumulate(st), currents
            acc, currents = jax.lax.scan(body, StepStats.zeros(), spikes_tcn)
            return currents, acc

        self._tick = jax.jit(tick)
        self._run = jax.jit(run)
        self._run_batched = jax.jit(jax.vmap(run, in_axes=(None, 0)))

    # ---- execution -------------------------------------------------------

    def step(self, spikes) -> tuple[jnp.ndarray, StepStats]:
        """One tick.  spikes: (cores, neurons_per_core) bool."""
        return self._tick(self.params, self._check(spikes, 2))

    def run(self, spikes) -> tuple[jnp.ndarray, StepStats]:
        """Multi-timestep simulation under one jit-compiled lax.scan.

        spikes: (T, cores, neurons_per_core) bool
        returns (currents (T, cores, neurons_per_core), accumulated stats);
        use ``stats.summary(ticks=T)`` for per-tick means.
        """
        return self._run(self.params, self._check(spikes, 3))

    def run_batched(self, spikes) -> tuple[jnp.ndarray, StepStats]:
        """Batched scan: spikes (B, T, cores, neurons_per_core) bool.

        Returns (currents (B, T, C, N), stats with (B,)-shaped leaves,
        each accumulated over that batch element's T ticks).
        """
        return self._run_batched(self.params, self._check(spikes, 4))

    # ---- introspection ---------------------------------------------------

    def ppa_report(self) -> dict:
        """Unified area/latency/energy report for this configuration."""
        from repro.interface import report
        return report.ppa_report(self.config)

    def _check(self, spikes, ndim: int) -> jnp.ndarray:
        spikes = jnp.asarray(spikes)
        if spikes.ndim != ndim or spikes.shape[-2:] != (
                self.config.cores, self.config.neurons_per_core):
            raise ValueError(
                f"expected {ndim}-d spikes ending in "
                f"({self.config.cores}, {self.config.neurons_per_core}), "
                f"got shape {spikes.shape}")
        return spikes
