"""Compile-once sessions over the core-interface pipeline.

`Interface(config).compile(params)` pre-builds everything the per-tick
step needs exactly once - the arbiter plan, the NoC subscription/link
tables (two-tier when ``cfg.chips > 1``), the CAM routing index (stored
tags decoded back to (chip, core, neuron) source addresses), the CAM
calibration constants - and returns an `InterfaceSession` whose `run` /
`run_batched` execute multi-timestep simulation as a single jit-compiled
`jax.lax.scan` (+`vmap` for the batched form) with streaming `StepStats`
accumulation.

Chip sharding: ``run(spikes, shard="chips")`` executes the per-chip slice
of every tick - the CAM match/scatter, the per-core arbiter latency, and
the AER encode stage - under `repro.compat.shard_map` over a 1D
``("chips",)`` device mesh (`repro.launch.mesh.make_chip_mesh`), one
device per simulated chip.  On a single-device host (or whenever fewer
devices exist than chips) the same per-chip body runs under `jax.vmap`
instead, so results never depend on the host topology.  Both mapped paths
reassemble the per-core vectors in fabric order and funnel through
`pipeline.accounting_stats`: currents are bit-identical to the unsharded
oracle on either path (and stats too under the vmap fallback); on a real
multi-device mesh the stats agree to float tolerance, since XLA may
partition the replicated accounting reductions differently.

This replaces the seed pattern of calling `fabric.step` in a Python loop,
which re-entered jit dispatch every tick and silently rebuilt the NoC
tables whenever the caller forgot to thread them through.

Observability: ``run(..., telemetry="ticks"|"cores")`` swaps the
accumulate-only carry for stacked per-tick `StepStats` scan ys (and, at
``"cores"``, per-core event/latency/hop breakdowns), all still under one
jit - see `repro.obs.telemetry` for the returned containers and their
sum-back invariants.  Compile and run dispatch are wrapped in
`repro.obs.trace` spans, no-ops unless a tracer is active.

Masked / ragged streams (the `repro.serve` substrate): ``run(spikes,
mask=...)`` and ``run_batched(spikes, mask=...)`` accept a per-tick bool
mask (``(T,)`` / ``(B, T)``).  Masked ticks contribute exactly zero to
the accumulated `StepStats` and zero currents, so tenants with ragged
stream lengths can be right-padded onto one batch and stay bit-identical
to their solo runs.  ``stats0`` seeds the scan's accumulator carry
(per-lane ``(B,)`` leaves in the batched form): chunked serving threads
the accumulator through successive calls, keeping the float accumulation
order exactly the tick-sequential order a single solo `run` uses - which
is what makes chunk-streamed stats bit-identical, not merely close.
Masking composes with ``shard="chips"`` (the masked scan runs the
per-chip mapped tick - the serving tier's cross-device tenant groups)
but not with telemetry; rejected combinations raise the typed
`CompositionError` instead of silently falling back to another path.

Fault injection (the `repro.ft` substrate): ``compile(params,
fault=FaultModel(...))`` bakes deterministic fabric faults into the
session - dead cores and corrupted CAM entries perturb the routing state
before tables are built, dropped events are masked by a jitted
per-(lane, tick) Bernoulli transform keyed on a dynamic ``fault_tick0``
offset - so faulted runs stay inside the one compiled step, degrade
predictably, and chunked faulted streams replay the exact fault sequence
of one uninterrupted run.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import arbiter as arb
from repro.core import cam as cam_mod
from repro.interface import pipeline
from repro.interface.config import as_interface_config
from repro.interface.stats import StepStats
from repro.obs import telemetry as obs_telemetry
from repro.obs import trace as obs_trace

_SHARD_MODES = (None, "chips")


class CompositionError(ValueError):
    """A requested run-mode combination is not supported.

    Typed rejection (still a ValueError for legacy handlers) raised when
    orthogonal execution modes cannot compose - today that is telemetry
    together with ``shard="chips"`` or with ``mask``.  Masking *does*
    compose with sharding (the serving tier's cross-device tenant
    groups); combinations rejected here are rejected loudly instead of
    silently falling back to a different execution path.
    """


class Interface:
    """Factory for precompiled sessions over one interface configuration."""

    def __init__(self, config):
        """config: `InterfaceConfig` or a legacy `FabricConfig`."""
        self.config = as_interface_config(config)

    def compile(self, params, fault=None) -> "InterfaceSession":
        """Bind routing state; build all plans/tables/constants once.

        fault: optional `repro.ft.faults.FaultModel` compiled into the
        session - dead cores / corrupted CAM entries perturb the routing
        state *before* tables are built, and dropped/dead-core spikes are
        masked at run time by a jit-compatible transform, so faulted runs
        stay inside the one compiled step and degrade instead of crash.

        Raises:
          ValueError: when the fault model does not fit the config, or
            ``config.impl == "pallas_sparse"`` and a configured scheme
            lacks sparse tick policies (`pipeline.resolve_sparse_plan`).
        """
        return InterfaceSession(self.config, params, fault=fault)

    def ppa_report(self) -> dict:
        """Unified area/latency/energy report for this configuration."""
        from repro.interface import report
        return report.ppa_report(self.config)


class InterfaceSession:
    """A precompiled (config, params) binding with scan-based execution.

    Attributes built once at construction:
      tables    NoC subscription/hop/link tables (`NocTables`, or
                `repro.noc.hierarchy.HierTables` when ``cfg.chips > 1``)
      arb_plan  arbiter plan (`ArbiterConfig`: scheme entry, levels, fill)
      routing   CAM tags decoded to (chip, core, neuron) source addresses
                (`RoutingIndex`) - the per-tick CAM match is a gather
                through it (or the `cam_search` kernel when
                ``cfg.impl == "pallas"``, or the fused
                `repro.kernels.sparse_tick` event kernel when
                ``cfg.impl == "pallas_sparse"``)
      cam_cycle_ns  CAM search cycle time for the configured variant
    """

    def __init__(self, config, params, fault=None):
        """Build every plan/table/constant once; see `Interface.compile`."""
        self.config = as_interface_config(config)
        if fault is not None:
            fault.validate(self.config)
            if fault.is_null:
                fault = None          # compiles exactly as fault-free
        self.fault = fault
        if fault is not None:
            params = fault.apply_params(params, self.config)
        self.params = params
        cfg = self.config
        with obs_trace.span("interface.compile", cores=cfg.cores,
                            chips=cfg.chips, impl=cfg.impl):
            self.tables = pipeline.build_tables(params, cfg)
            self.arb_plan = arb.ArbiterConfig(cfg.scheme, cfg.neurons_per_core)
            self.routing = pipeline.build_routing_index(params, cfg)
            self.cam_cycle_ns = cam_mod.cycle_time_ns(cfg.cam)
            if cfg.impl == "pallas_sparse":
                # Fail at compile, not mid-scan, when a scheme lacks the
                # sparse tick policies (e.g. hier_ring on a non-square n).
                pipeline.resolve_sparse_plan(cfg, self.arb_plan)
        tables, arb_plan, routing = self.tables, self.arb_plan, self.routing
        cam_cycle_ns = self.cam_cycle_ns

        def tick(p, spikes_cn):
            """One frame through the pipeline with the prebuilt plans."""
            return pipeline.interface_tick(p, spikes_cn, cfg, tables, arb_plan,
                                           routing=routing,
                                           cam_cycle_ns=cam_cycle_ns)

        def run(p, spikes_tcn):
            """Accumulate-only scan over a (T, C, n) stream."""
            def body(acc, s_t):
                currents, st = tick(p, s_t)
                return acc.accumulate(st), currents
            acc, currents = jax.lax.scan(body, StepStats.zeros(), spikes_tcn)
            return currents, acc

        self._tick = jax.jit(tick)
        self._run = jax.jit(run)
        self._run_batched = jax.jit(jax.vmap(run, in_axes=(None, 0)))
        self._run_fast = self._run_batched_fast = self._sparse_fits = None
        if cfg.impl == "pallas_sparse":
            # The per-tick overflow cond costs tens of us/tick on CPU - a
            # large fraction of the sparse tick itself.  Check the whole
            # stream against capacity ONCE per run() call (host-side) and
            # dispatch to a cond-free sparse scan when every frame fits;
            # streams with any overflowing frame keep the guarded scan.
            capacity = pipeline.resolve_sparse_plan(cfg, arb_plan)[3]

            def tick_fast(p, spikes_cn):
                return pipeline.interface_tick(
                    p, spikes_cn, cfg, tables, arb_plan, routing=routing,
                    cam_cycle_ns=cam_cycle_ns, sparse_unchecked=True)

            def run_fast(p, spikes_tcn):
                def body(acc, s_t):
                    currents, st = tick_fast(p, s_t)
                    return acc.accumulate(st), currents
                acc, currents = jax.lax.scan(body, StepStats.zeros(),
                                             spikes_tcn)
                return currents, acc

            self._run_fast = jax.jit(run_fast)
            self._run_batched_fast = jax.jit(
                jax.vmap(run_fast, in_axes=(None, 0)))
            self._sparse_fits = jax.jit(
                lambda s: jnp.max(jnp.sum(s != 0, axis=-1)) <= capacity)
        self._sharded_cache = None
        self._telemetry_cache = {}
        self._masked_cache = None
        self._masked_sharded_cache = None
        self._sharded_tick_cache = None
        self._fault_cache = None

    # ---- execution -------------------------------------------------------

    def step(self, spikes) -> tuple[jnp.ndarray, StepStats]:
        """One tick.  spikes: (cores, neurons_per_core) bool."""
        return self._tick(self.params, self._check(spikes, 2))

    def run(self, spikes, shard: str | None = None, telemetry: str = "off",
            mask=None, stats0: StepStats | None = None, fault_tick0=None
            ) -> tuple[jnp.ndarray, StepStats]:
        """Multi-timestep simulation under one jit-compiled lax.scan.

        spikes: (T, cores, neurons_per_core) bool
        shard:  None (default) runs the flat fabric-wide tick; ``"chips"``
            maps the per-chip tick over a device mesh (see module
            docstring), falling back to vmap when the host has fewer
            devices than chips.  Sharded execution always uses the XLA
            gather backend for the CAM match (bit-identical to
            ``impl="pallas"``, which is tested against it).
        telemetry: ``"off"`` (default) is today's accumulate-only scan,
            returning ``(currents, accumulated stats)``.  ``"ticks"``
            additionally stacks the per-tick `StepStats` as scan ys and
            returns ``(currents, stats, TickTelemetry)``; ``"cores"``
            returns ``(currents, stats, CoreTelemetry)`` with per-core
            event/latency/hop breakdowns (see `repro.obs.telemetry`).
            Currents and accumulated stats are bit-identical in every
            mode.  Telemetry composes with the flat path only - combine
            it with ``shard="chips"`` on a multi-chip config and this
            raises (run unsharded for tier attribution).
        mask: optional (T,) bool - ticks where it is False contribute
            exactly zero stats and zero currents (padding lanes of a
            ragged stream).  Composes with ``shard="chips"`` (the masked
            scan steps the per-chip mapped tick); mutually exclusive
            with telemetry (typed `CompositionError`).
        stats0: optional `StepStats` seeding the accumulator carry (only
            with ``mask``); defaults to zeros.  Chunk-streamed callers
            thread the returned stats back in to keep accumulation
            bit-identical to one uninterrupted run.
        fault_tick0: global tick index of ``spikes[0]`` for the session's
            compiled `FaultModel` drop stream (only meaningful when the
            session was compiled with a spike-perturbing fault; defaults
            to 0 there).  A *dynamic* scalar: chunked callers pass their
            running offset without growing the jit cache, and chunked
            faulted runs stay bit-identical to one uninterrupted run.
        returns (currents (T, cores, neurons_per_core), accumulated stats);
        use ``stats.summary(ticks=T)`` for per-tick means.

        Raises:
          CompositionError: ``mask`` or ``shard="chips"`` combined with
            ``telemetry`` (a typed ValueError; masking composes with
            sharding, telemetry composes with neither).
          ValueError: on a spike stream whose trailing axes do not match
            the config; an unknown ``shard`` mode; ``stats0`` or a
            mis-shaped ``mask`` without a matching masked call; or
            ``fault_tick0`` on a session without a spike-perturbing
            fault.
        """
        spikes = self._check(spikes, 3)
        spikes = self._apply_fault("run", spikes, fault_tick0)
        if mask is not None:
            fns = self._masked_fns(shard, telemetry)
            mask = self._check_mask(mask, spikes, 1)
            acc0 = StepStats.zeros() if stats0 is None else stats0
            with obs_trace.span("interface.run", masked=True):
                spikes = fns["mask_solo"](spikes, mask)
                return fns["run"](self.params, spikes, acc0)
        if stats0 is not None:
            raise ValueError("stats0 is only meaningful with mask")
        fn = self._shard_fn("run", shard)
        if telemetry != "off":
            t_fn = self._telemetry_fn("run", telemetry, sharded=fn is not None)
            with obs_trace.span("interface.run", telemetry=telemetry):
                return t_fn(self.params, spikes)
        if fn is not None:
            with obs_trace.span("interface.run", shard=shard):
                return fn(spikes)
        with obs_trace.span("interface.run"):
            if self._all_frames_fit(spikes):
                return self._run_fast(self.params, spikes)
            return self._run(self.params, spikes)

    def run_batched(self, spikes, shard: str | None = None,
                    telemetry: str = "off", mask=None,
                    stats0: StepStats | None = None, fault_tick0=None
                    ) -> tuple[jnp.ndarray, StepStats]:
        """Batched scan: spikes (B, T, cores, neurons_per_core) bool.

        Returns (currents (B, T, C, N), stats with (B,)-shaped leaves,
        each accumulated over that batch element's T ticks).  ``shard``
        behaves as in `run` (the batch axis is vmapped over the sharded
        scan); ``telemetry`` as in `run`, with the series leaves gaining
        a leading batch axis (``(B, T)`` / ``(B, T, cores)``).

        ``mask`` (B, T) bool marks the live ticks of each lane: masked
        ticks contribute zero stats/currents, so ragged tenant streams
        right-padded to one T stay bit-identical to their solo runs (an
        all-False lane is a no-op that returns its ``stats0`` row
        unchanged).  ``stats0`` seeds the per-lane accumulator carry
        ((B,)-shaped `StepStats` leaves; zeros when omitted) - thread the
        returned stats back in when chunking one long stream over
        multiple calls.  Composes with ``shard="chips"`` (each lane's
        scan steps the per-chip mapped tick, spreading the group over
        the chip mesh); mutually exclusive with telemetry.

        ``fault_tick0`` behaves as in `run`, per lane: a scalar (shared
        offset) or a (B,) vector of per-lane global tick offsets for the
        compiled `FaultModel`'s drop stream; each lane folds its index
        into the stream so lanes draw independent faults.

        Raises:
          ValueError: under the same conditions as `run` (shape/mode/
            composition violations), applied to the batched shapes.
        """
        spikes = self._check(spikes, 4)
        spikes = self._apply_fault("run_batched", spikes, fault_tick0)
        if mask is not None:
            fns = self._masked_fns(shard, telemetry)
            mask = self._check_mask(mask, spikes, 2)
            acc0 = stats0
            if acc0 is None:
                b = spikes.shape[0]
                acc0 = jax.tree.map(
                    lambda x: jnp.zeros((b,), x.dtype), StepStats.zeros())
            with obs_trace.span("interface.run_batched", masked=True):
                spikes = fns["mask"](spikes, mask)
                return fns["run_batched"](self.params, spikes, acc0)
        if stats0 is not None:
            raise ValueError("stats0 is only meaningful with mask")
        fn = self._shard_fn("run_batched", shard)
        if telemetry != "off":
            t_fn = self._telemetry_fn("run_batched", telemetry,
                                      sharded=fn is not None)
            with obs_trace.span("interface.run_batched", telemetry=telemetry):
                return t_fn(self.params, spikes)
        if fn is not None:
            with obs_trace.span("interface.run_batched", shard=shard):
                return fn(spikes)
        with obs_trace.span("interface.run_batched"):
            if self._all_frames_fit(spikes):
                return self._run_batched_fast(self.params, spikes)
            return self._run_batched(self.params, spikes)

    def _all_frames_fit(self, spikes) -> bool:
        """Host-side sparse precheck: does every frame of this stream fit
        the session's event capacity?  Always False off the pallas_sparse
        impl, so the plain scans stay untouched there.  One reduction over
        the stream plus one device sync per `run` call, amortized across
        all its ticks; empty streams trivially fit."""
        if self._sparse_fits is None:
            return False
        return spikes.size == 0 or bool(self._sparse_fits(spikes))

    # ---- masked / ragged streams -----------------------------------------

    def _masked_fns(self, shard: str | None, telemetry: str) -> dict:
        """The jitted masked-scan family for a shard mode; built lazily.

        ``shard=None`` is the flat masked scan.  ``shard="chips"`` on a
        multi-chip config runs the masked scan with the per-chip mapped
        tick (shard_map over the chip mesh, or the single-device vmap
        fallback) - the serving tier's cross-device tenant groups.  On a
        one-chip config the flat scan IS the per-chip tick, same as the
        unmasked path.  Telemetry still does not compose with masking
        (`CompositionError`): the masked scan's accumulator-as-argument
        carry has no ys slot for the stacked series.
        """
        if telemetry != "off":
            raise CompositionError(
                "mask does not compose with telemetry; run the masked "
                "scan without telemetry (currents and accumulated stats "
                "are bit-identical across paths)")
        if shard is not None:
            if shard not in _SHARD_MODES:
                raise ValueError(
                    f"unknown shard mode {shard!r}; expected one of "
                    f"{', '.join(repr(m) for m in _SHARD_MODES)}")
            if self.config.chips > 1:
                if self._masked_sharded_cache is None:
                    self._masked_sharded_cache = self._build_masked_sharded()
                return self._masked_sharded_cache
        if self._masked_cache is None:
            self._masked_cache = self._build_masked()
        return self._masked_cache

    def _build_masked(self) -> dict:
        """The plain accumulate scan, with the accumulator as an argument.

        Masking exploits an exact property of the tick: a tick whose
        spikes are all-False produces exactly-zero `StepStats` and zero
        currents for every registered arbiter/NoC scheme (asserted in
        tests/test_serve.py), so a masked tick is erased by
        ``spikes & mask`` *before* the scan and the scan body stays
        byte-for-byte the unmasked one - no predication nodes that could
        perturb XLA's float scheduling.  The accumulator is a scan
        *argument* (``acc0``) rather than the constant
        `StepStats.zeros()`, so chunked callers thread it through
        successive calls and preserve the tick-sequential float
        accumulation order of one uninterrupted run.
        """
        cfg = self.config
        tables, arb_plan, routing = self.tables, self.arb_plan, self.routing
        cam_cycle_ns = self.cam_cycle_ns

        def tick(p, spikes_cn):
            return pipeline.interface_tick(p, spikes_cn, cfg, tables, arb_plan,
                                           routing=routing,
                                           cam_cycle_ns=cam_cycle_ns)

        def run(p, spikes_tcn, acc0):
            def body(acc, s_t):
                currents, st = tick(p, s_t)
                return acc.accumulate(st), currents
            acc, currents = jax.lax.scan(body, acc0, spikes_tcn)
            return currents, acc

        # Donate the spikes/accumulator buffers on accelerators so the
        # serving engine's double-buffered transfers reuse device memory;
        # CPU would only warn (donation unimplemented), so skip it there.
        donate = () if jax.default_backend() == "cpu" else (1, 2)
        mask_lane = jax.jit(lambda s, m: s & m[:, None, None])
        return {"run": jax.jit(run),
                "run_batched": jax.jit(jax.vmap(run, in_axes=(None, 0, 0)),
                                       donate_argnums=donate),
                "mask": jax.jit(jax.vmap(mask_lane)),
                "mask_solo": mask_lane}

    # ---- fault injection -------------------------------------------------

    def _apply_fault(self, kind: str, spikes, fault_tick0):
        """Run the compiled `FaultModel`'s jitted spike transform.

        No-op (and rejects ``fault_tick0``) when the session has no
        spike-perturbing fault, so the fault-free path stays byte-for-
        byte the plain one.  The tick offset is a dynamic argument -
        one cache entry covers every chunk offset.
        """
        if self.fault is None or not self.fault.perturbs_spikes:
            if fault_tick0 is not None:
                raise ValueError(
                    "fault_tick0 is only meaningful on a session compiled "
                    "with a spike-perturbing FaultModel (dead_cores or "
                    "drop_rate)")
            return spikes
        if self._fault_cache is None:
            self._fault_cache = self._build_fault()
        t0 = jnp.asarray(0 if fault_tick0 is None else fault_tick0,
                         jnp.int32)
        if kind == "run_batched":
            t0 = jnp.broadcast_to(t0, (spikes.shape[0],))
        return self._fault_cache[kind](spikes, t0)

    def _build_fault(self) -> dict:
        """Jitted dead-core/drop transforms; lanes fold their index in."""
        fault = self.fault

        def solo(s, t0):
            return fault.apply_spikes(s, tick0=t0, lane=jnp.int32(0))

        def lane(s, t0, i):
            return fault.apply_spikes(s, tick0=t0, lane=i)

        batched = jax.vmap(lane, in_axes=(0, 0, 0))

        def run_b(s, t0):
            lanes = jnp.arange(s.shape[0], dtype=jnp.int32)
            return batched(s, t0, lanes)

        return {"run": jax.jit(solo), "run_batched": jax.jit(run_b)}

    def _check_mask(self, mask, spikes, ndim: int) -> jnp.ndarray:
        mask = jnp.asarray(mask)
        if mask.shape != spikes.shape[:ndim]:
            raise ValueError(
                f"mask shape {mask.shape} does not cover the spike stream's "
                f"leading axes {spikes.shape[:ndim]}")
        if mask.dtype != jnp.bool_:
            mask = mask > 0
        return mask

    # ---- in-jit telemetry ------------------------------------------------

    def _telemetry_fn(self, kind: str, mode: str, sharded: bool):
        """The jitted telemetry scan for (kind, mode); built lazily once."""
        obs_telemetry.validate_mode(mode)
        if sharded:
            raise CompositionError(
                "telemetry is not supported together with shard='chips'; "
                "run unsharded (the default) to collect per-tick/per-core "
                "series - currents are bit-identical across both paths")
        if mode not in self._telemetry_cache:
            self._telemetry_cache[mode] = self._build_telemetry(mode)
        return self._telemetry_cache[mode][kind]

    def _build_telemetry(self, mode: str) -> dict:
        """Scan with stacked ys: per-tick `StepStats`, plus per-core
        breakdowns under ``"cores"``.  The tick body is the same
        `pipeline.interface_tick` the plain run uses, so currents and the
        accumulated stats stay bit-identical to ``telemetry="off"``."""
        cfg = self.config
        tables, arb_plan, routing = self.tables, self.arb_plan, self.routing
        cam_cycle_ns = self.cam_cycle_ns
        tick_telemetry = "cores" if mode == "cores" else "off"

        def tick(p, spikes_cn):
            return pipeline.interface_tick(p, spikes_cn, cfg, tables, arb_plan,
                                           routing=routing,
                                           cam_cycle_ns=cam_cycle_ns,
                                           telemetry=tick_telemetry)

        if mode == "ticks":
            def run(p, spikes_tcn):
                def body(acc, s_t):
                    currents, st = tick(p, s_t)
                    return acc.accumulate(st), (currents, st)
                acc, (currents, series) = jax.lax.scan(
                    body, StepStats.zeros(), spikes_tcn)
                return currents, acc, obs_telemetry.TickTelemetry(
                    per_tick=series)
        else:
            def run(p, spikes_tcn):
                def body(acc, s_t):
                    currents, st, core = tick(p, s_t)
                    return acc.accumulate(st), (currents, st, core)
                acc, (currents, series, core_series) = jax.lax.scan(
                    body, StepStats.zeros(), spikes_tcn)
                return currents, acc, obs_telemetry.CoreTelemetry(
                    per_tick=series, per_core=core_series)

        return {"run": jax.jit(run),
                "run_batched": jax.jit(jax.vmap(run, in_axes=(None, 0)))}

    # ---- chip sharding ---------------------------------------------------

    def _shard_fn(self, kind: str, shard: str | None):
        if shard is None:
            return None
        if shard not in _SHARD_MODES:
            raise ValueError(
                f"unknown shard mode {shard!r}; expected one of "
                f"{', '.join(repr(m) for m in _SHARD_MODES)}")
        if self.config.chips == 1:
            return None          # flat fabric: the unsharded scan IS the tick
        if self._sharded_cache is None:
            self._sharded_cache = self._build_sharded()
        return self._sharded_cache[kind]

    def _chip_body(self):
        """Per-chip tick work: local CAM match/scatter + encode stage.

        Closure signature: (params_chip, src_idx, active, spikes_chip,
        spikes_flat_global) -> (currents (cpc, n), latencies (cpc,),
        enc_per_core (cpc,), hits scalar).  Pure per-chip function - no
        collectives - so the identical body runs under shard_map (the
        replicated ``spikes_flat`` argument becomes the one all-gather at
        the shard_map boundary) and under the single-device vmap fallback.
        """
        cfg = self.config
        n = cfg.neurons_per_core
        arb_plan = self.arb_plan
        scheme = cfg.scheme
        stream_cfg = (cfg if cfg.impl == "xla"
                      else dataclasses.replace(cfg, impl="xla"))

        def chip_body(p_chip, src_idx, active, spikes_chip, spikes_flat):
            drive = (spikes_flat[src_idx] & active).astype(jnp.float32)
            contrib = drive * p_chip.weights
            currents = jax.vmap(
                lambda c, t: jnp.zeros((n,), jnp.float32).at[t].add(c)
            )(contrib, p_chip.targets)
            latencies = arb.batched_tick_latency(arb_plan, spikes_chip)
            addr = pipeline._addr_streams(spikes_chip, stream_cfg, n)
            enc = jax.vmap(
                lambda seq: arb.encode_energy_units(scheme, n, seq))(addr)
            return currents, latencies, enc, jnp.sum(drive)

        return chip_body

    def _sharded_tick(self):
        """The per-chip mapped tick closure, built (and placed) once.

        Shared by the plain sharded scans and the masked sharded scans,
        so the per-chip constants are device-pinned a single time and
        both families step through the identical tick body.
        """
        if self._sharded_tick_cache is not None:
            return self._sharded_tick_cache
        cfg = self.config
        chips, cpc, n = cfg.chips, cfg.cores_per_chip, cfg.neurons_per_core
        body = self._chip_body()

        # static per-chip operands, stacked (chips, cores_per_chip, ...)
        per_chip = jax.tree.map(
            lambda x: x.reshape((chips, cpc) + x.shape[1:]),
            (self.params, self.routing.src_idx, self.routing.active))

        if len(jax.devices()) >= chips:
            from repro.launch import mesh as launch_mesh
            from repro.parallel import sharding as shd

            mesh = launch_mesh.make_chip_mesh(chips)

            def block_body(p_c, si, ac, sp_c, sp_flat):
                # shard_map blocks keep the mapped axis with size 1
                sq = jax.tree.map(lambda x: x[0], (p_c, si, ac, sp_c))
                cur, lat, enc, hits = body(*sq, sp_flat)
                return cur[None], lat[None], enc[None], hits[None]

            mapped = compat.shard_map(
                block_body, mesh=mesh,
                in_specs=(P("chips"), P("chips"), P("chips"), P("chips"),
                          P()),
                out_specs=P("chips"))
            # pin the per-chip constants to their devices once, at build
            per_chip = jax.device_put(
                per_chip,
                shd.to_named(shd.leading_axis_specs(per_chip, "chips"),
                             mesh))
        else:
            mapped = jax.vmap(body, in_axes=(0, 0, 0, 0, None))

        p_chips, src_idx, active = per_chip
        tables, cam_cycle_ns = self.tables, self.cam_cycle_ns
        valid = self.params.valid

        def tick(spikes_cn):
            if spikes_cn.dtype != jnp.bool_:
                spikes_cn = spikes_cn > 0
            spikes_flat = spikes_cn.reshape(-1)
            sp_chips = spikes_cn.reshape(chips, cpc, n)
            cur_c, lat_c, enc_c, hits_c = mapped(p_chips, src_idx, active,
                                                 sp_chips, spikes_flat)
            currents = cur_c.reshape(cfg.cores, n)
            stats = pipeline.accounting_stats(
                cfg, tables, spikes_cn, lat_c.reshape(cfg.cores),
                enc_c.reshape(cfg.cores), jnp.sum(hits_c), valid,
                cam_cycle_ns)
            return currents, stats

        self._sharded_tick_cache = tick
        return tick

    def _build_sharded(self) -> dict:
        tick = self._sharded_tick()

        def run(spikes_tcn):
            def scan_body(acc, s_t):
                currents, st = tick(s_t)
                return acc.accumulate(st), currents
            acc, currents = jax.lax.scan(scan_body, StepStats.zeros(),
                                         spikes_tcn)
            return currents, acc

        return {"run": jax.jit(run), "run_batched": jax.jit(jax.vmap(run))}

    def _build_masked_sharded(self) -> dict:
        """The masked scan family over the per-chip mapped tick.

        Same masking contract as `_build_masked` - masked ticks are
        erased by ``spikes & mask`` *before* the scan, the accumulator
        rides as the ``acc0`` argument - but each tick runs the
        `_sharded_tick` body (shard_map over the chip mesh, or the vmap
        fallback), so one serving-tier `TenantGroup` spreads its lanes'
        fabric work across `launch.mesh` devices.  Signatures match the
        flat masked family (the leading ``params`` argument is unused:
        the sharded tick closes over its device-pinned per-chip
        constants), so callers dispatch on the dict alone.
        """
        tick = self._sharded_tick()

        def run(p, spikes_tcn, acc0):
            del p  # per-chip constants are baked into the sharded tick
            def body(acc, s_t):
                currents, st = tick(s_t)
                return acc.accumulate(st), currents
            acc, currents = jax.lax.scan(body, acc0, spikes_tcn)
            return currents, acc

        mask_lane = jax.jit(lambda s, m: s & m[:, None, None])
        return {"run": jax.jit(run),
                "run_batched": jax.jit(jax.vmap(run, in_axes=(None, 0, 0))),
                "mask": jax.jit(jax.vmap(mask_lane)),
                "mask_solo": mask_lane}

    # ---- introspection ---------------------------------------------------

    def ppa_report(self) -> dict:
        """Unified area/latency/energy report for this configuration."""
        from repro.interface import report
        return report.ppa_report(self.config)

    def _check(self, spikes, ndim: int) -> jnp.ndarray:
        spikes = jnp.asarray(spikes)
        if spikes.ndim != ndim or spikes.shape[-2:] != (
                self.config.cores, self.config.neurons_per_core):
            raise ValueError(
                f"expected {ndim}-d spikes ending in "
                f"({self.config.cores}, {self.config.neurons_per_core}), "
                f"got shape {spikes.shape}")
        return spikes
