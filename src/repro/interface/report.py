"""Unified PPA entry point for the core interface.

`ppa_report(config)` gathers, in one dict, the area/latency/energy
accounting that used to be split between `fabric.interface_area_um2` and
ad-hoc benchmark code: the arbiter closed forms (unit-domain and
calibrated ns), the CAM variant's cycle time / energy / area, and the NoC
static topology facts.  Dynamic per-tick costs come from
`InterfaceSession.run`'s `StepStats`; this report covers everything that
is a function of the *configuration* alone.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import arbiter as arb
from repro.core import cam as cam_mod
from repro.core import ppa
from repro.interface.config import as_interface_config
from repro.noc import topology


def _closed_form(fn, scheme: str, n: int):
    """Closed forms exist only for the paper's five schemes; custom
    arbiters registered at runtime report None instead of crashing."""
    return fn(scheme, n) if scheme in ppa.SCHEMES else None


def interface_area_um2(cfg) -> dict:
    """Static area report for one core's interface (model units/um^2)."""
    n = cfg.neurons_per_core
    return {
        "arbiter_norm_area": _closed_form(arb.area_normalized, cfg.scheme, n),
        "arbiter_units": _closed_form(arb.area_units, cfg.scheme, n),
        "cam_um2": cam_mod.area_um2(cfg.cam),
        "cam_um2_baseline": cam_mod.area_um2(
            cam_mod.CamConfig(cfg.cam.entries, cscd=False, feedback=False,
                              speculative=False)),
    }


def ppa_report(config) -> dict:
    """One dict covering arbiter / CAM / NoC area, latency and energy.

    config: `InterfaceConfig` or legacy `FabricConfig`.
    """
    cfg = as_interface_config(config)
    n = cfg.neurons_per_core
    cam = cfg.cam
    conv = cam_mod.CamConfig(cam.entries, cscd=False, feedback=False,
                             speculative=False)
    # per-chip core mesh when a chip tier exists, the flat mesh otherwise
    mesh_cores = cfg.cores_per_chip if cfg.chips > 1 else cfg.cores
    w, h = topology.mesh_dims(mesh_cores)
    hops = topology.hop_matrix(mesh_cores)
    chip_hops = topology.hop_matrix(cfg.chips)
    area = interface_area_um2(cfg)

    return {
        "config": {
            "cores": cfg.cores,
            "chips": cfg.chips,
            "cores_per_chip": cfg.cores_per_chip,
            "neurons_per_core": n,
            "tag_bits": cfg.tag_bits,
            "arbiter": cfg.scheme,
            "cam_variant": cam.variant,
            "cam_entries": cam.entries,
            "noc_scheme": cfg.noc.scheme,
        },
        "arbiter": {
            "sparse_latency_units": _closed_form(arb.sparse_latency_units,
                                                 cfg.scheme, n),
            "burst_latency_units": _closed_form(arb.burst_latency_units,
                                                cfg.scheme, n),
            "sparse_latency_ns": _closed_form(arb.sparse_latency_ns,
                                              cfg.scheme, n),
            "burst_latency_ns": _closed_form(arb.burst_latency_ns,
                                             cfg.scheme, n),
            "area_units": area["arbiter_units"],
            "area_normalized": area["arbiter_norm_area"],
        },
        "cam": {
            "cycle_time_ns": cam_mod.cycle_time_ns(cam),
            "cycle_time_ns_conventional": cam_mod.cycle_time_ns(conv),
            "cycle_improvement": cam_mod.cycle_improvement(cam.entries),
            "search_energy_all_match": cam_mod.search_energy(
                cam, float(cam.entries), 0.0),
            "search_energy_all_mismatch": cam_mod.search_energy(
                cam, 0.0, float(cam.entries)),
            "area_um2": area["cam_um2"],
            "area_um2_conventional": area["cam_um2_baseline"],
        },
        "noc": {
            "mesh_dims": (w, h),
            "links": topology.num_links(mesh_cores) * cfg.chips,
            "mean_hop_distance": float(jnp.mean(hops)),
            "max_hop_distance": int(jnp.max(hops)),
            "hop_latency_ns": ppa.NOC_HOP_LATENCY_NS,
            "link_serialization_ns": ppa.NOC_LINK_SERIALIZATION_NS,
            "hop_energy": ppa.NOC_HOP_ENERGY,
        },
        "hierarchy": {
            "chips": cfg.chips,
            "chip_mesh_dims": topology.mesh_dims(cfg.chips),
            "chip_links": topology.num_links(cfg.chips),
            "mean_chip_hop_distance": float(jnp.mean(chip_hops)),
            "max_chip_hop_distance": int(jnp.max(chip_hops)),
            "chip_hop_latency_ns": ppa.CHIP_HOP_LATENCY_NS,
            "chip_link_serialization_ns": ppa.CHIP_LINK_SERIALIZATION_NS,
            "chip_hop_energy": ppa.CHIP_HOP_ENERGY,
        },
        "per_core_area": {
            "arbiter_units": area["arbiter_units"],
            "cam_um2": area["cam_um2"],
        },
    }
