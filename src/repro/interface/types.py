"""Shared datatypes of the core-interface pipeline.

`InterfaceParams` is the routing state every stage operates on: the CAM
tags/valid bits that define subscriptions, plus synaptic weights and
per-core target rows.  It was historically named ``FabricParams`` (and
`repro.core.fabric` still re-exports it under that name); both names
refer to the same NamedTuple, so old pytrees flow through the new API
unchanged.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class InterfaceParams(NamedTuple):
    """Learnable/configurable routing state of the whole fabric."""
    tags: jnp.ndarray      # (cores, entries, tag_bits) {0,1} stored source tags
    valid: jnp.ndarray     # (cores, entries) bool
    weights: jnp.ndarray   # (cores, entries) float synaptic weight
    targets: jnp.ndarray   # (cores, entries) int32 target neuron within core


# Historical alias kept so isinstance checks and annotations keep working.
FabricParams = InterfaceParams


def int_to_bits(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Big-endian {0,1} bit expansion along a trailing axis."""
    return ((x[..., None] >> jnp.arange(bits - 1, -1, -1)) & 1).astype(jnp.int32)


def random_connectivity(key, cfg, fan_in: float = 0.9) -> InterfaceParams:
    """Random routing tables: each CAM entry subscribes to a random source.

    `cfg` is anything exposing cores / neurons_per_core / cam.entries /
    tag_bits (`InterfaceConfig` or the legacy `FabricConfig`).
    """
    k1, k2, k3, k4 = jax.random.split(key, 4)
    total = cfg.cores * cfg.neurons_per_core
    src = jax.random.randint(k1, (cfg.cores, cfg.cam.entries), 0, total)
    tags = int_to_bits(src, cfg.tag_bits)
    valid = jax.random.bernoulli(k2, fan_in, (cfg.cores, cfg.cam.entries))
    weights = jax.random.normal(k3, (cfg.cores, cfg.cam.entries)) * 0.5 + 1.0
    targets = jax.random.randint(k4, (cfg.cores, cfg.cam.entries), 0,
                                 cfg.neurons_per_core)
    return InterfaceParams(tags, valid, weights, targets)
