"""`repro.interface` - the unified core-interface API.

The paper's core interface is a pipeline - arbiter tree -> AER encode ->
NoC transport -> CAM routing LUT.  This package exposes that pipeline as
one composable, precompiled surface:

    from repro.interface import Interface, InterfaceConfig

    cfg = InterfaceConfig(cores=16, neurons_per_core=64,
                          cam_entries_per_core=128,
                          noc=NocConfig("multicast_tree"))
    params = random_connectivity(jax.random.PRNGKey(0), cfg)
    session = Interface(cfg).compile(params)      # plans + tables built ONCE
    currents, stats = session.run(spikes_TxCxN)   # jit + lax.scan over ticks
    stats.summary(ticks=T)                        # per-tick means

Registry contract
-----------------
Scheme selection is registry-driven (`repro.interface.registry`), not
string-``if`` dispatch.  Three registries cover the three pipeline stages;
each maps a scheme *name* to an *entry* object owned by the implementing
module:

  ``register_arbiter(name, entry)``
      entry: :class:`repro.core.arbiter.ArbiterScheme` - policy callables
      ``select_key`` / ``grant_delay`` / ``token_update`` /
      ``encode_energy``.  The generic discrete-event simulator calls them;
      a new arbitration architecture never edits the simulator.

  ``register_cam_variant(name, entry)``
      entry: :class:`repro.core.cam.CamVariant` - circuit-level knobs
      (``cscd`` / ``feedback`` / ``speculative`` flags, ``settle_frac``,
      ``match_charge_factor``) consumed by the CAM cycle-time and energy
      models.  ``CamConfig(variant_name=...)`` selects a registered entry.

  ``register_noc_scheme(name, entry)``
      entry: :class:`repro.noc.router.NocScheme` - transport callables
      ``expand_dests`` / ``hops`` / ``link_loads`` / ``cam_accounting``.
      `build_tables` and the per-tick cost accounting dispatch through the
      entry; `NocConfig` validates names against the registry.

Registration happens at import of the implementing module (the built-ins
register themselves at the bottom of ``arbiter.py`` / ``cam.py`` /
``router.py``).  Names must be unique; pass ``overwrite=True`` to replace
an entry deliberately.  Entries must be trace-safe: they are resolved once
per jit trace from a static scheme name, after which the hot path is pure
attribute access.

Everything below `registry` is imported lazily (PEP 562) so that the core
and noc layers can import `repro.interface.registry` without cycles.
"""

from __future__ import annotations

import importlib

from repro.interface import registry  # noqa: F401  (dependency-free)
from repro.interface.registry import (  # noqa: F401
    ARBITERS,
    CAM_VARIANTS,
    NOC_SCHEMES,
    get_arbiter,
    get_cam_variant,
    get_noc_scheme,
    register_arbiter,
    register_cam_variant,
    register_noc_scheme,
)

_LAZY_EXPORTS = {
    "CompositionError": "repro.interface.session",
    "Interface": "repro.interface.session",
    "InterfaceSession": "repro.interface.session",
    "InterfaceConfig": "repro.interface.config",
    "as_interface_config": "repro.interface.config",
    "StepStats": "repro.interface.stats",
    "InterfaceParams": "repro.interface.types",
    "FabricParams": "repro.interface.types",
    "int_to_bits": "repro.interface.types",
    "random_connectivity": "repro.interface.types",
    "interface_tick": "repro.interface.pipeline",
    "accounting_stats": "repro.interface.pipeline",
    "build_tables": "repro.interface.pipeline",
    "RoutingIndex": "repro.interface.pipeline",
    "build_routing_index": "repro.interface.pipeline",
    "ppa_report": "repro.interface.report",
    "interface_area_um2": "repro.interface.report",
}

__all__ = sorted([
    "registry", "ARBITERS", "CAM_VARIANTS", "NOC_SCHEMES",
    "register_arbiter", "register_cam_variant", "register_noc_scheme",
    "get_arbiter", "get_cam_variant", "get_noc_scheme",
    *_LAZY_EXPORTS,
])


def __getattr__(name: str):
    module = _LAZY_EXPORTS.get(name)
    if module is None:
        raise AttributeError(f"module 'repro.interface' has no attribute {name!r}")
    value = getattr(importlib.import_module(module), name)
    globals()[name] = value     # cache for subsequent lookups
    return value


def __dir__():
    return __all__
