"""lif_step kernel package."""
