"""Public ops for the LIF neuron update."""

from __future__ import annotations

import functools

import jax

from repro.kernels.lif_step import ref
from repro.kernels.lif_step.kernel import lif_step_pallas


@functools.partial(jax.jit, static_argnames=("decay", "threshold", "v_reset",
                                             "impl", "interpret"))
def lif_step(v, current, *, decay: float, threshold: float,
             v_reset: float = 0.0, impl: str = "xla",
             interpret: bool = False):
    if impl == "xla":
        return ref.lif_step_ref(v, current, decay=decay, threshold=threshold,
                                v_reset=v_reset)
    if impl == "pallas":
        return lif_step_pallas(v, current, decay=decay, threshold=threshold,
                               v_reset=v_reset, interpret=interpret)
    raise ValueError(f"unknown impl {impl!r}")
