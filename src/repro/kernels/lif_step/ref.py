"""Pure-jnp oracle for the lif_step kernel."""

from __future__ import annotations

import jax.numpy as jnp


def lif_step_ref(v: jnp.ndarray, current: jnp.ndarray, *, decay: float,
                 threshold: float, v_reset: float = 0.0):
    """One leaky-integrate-and-fire update.

    v, current: (..., N) float32
    returns (v_next, spikes {0,1} float32)
    """
    v_new = v * decay + current
    spikes = (v_new >= threshold).astype(v.dtype)
    v_next = jnp.where(spikes > 0, v_reset, v_new)
    return v_next, spikes
