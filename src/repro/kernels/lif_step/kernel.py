"""Pallas TPU kernel: fused leaky-integrate-and-fire neuron update.

The neuro-synaptic array update that feeds the core interface: one fused
VPU pass per tile does decay + integrate + fire + reset, avoiding three
HBM round-trips for the membrane state.  Tiled (block_b, block_n) in VMEM,
(8, 128)-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_B = 8
DEFAULT_BLOCK_N = 512


def _lif_kernel(v_ref, i_ref, params_ref, v_out_ref, s_out_ref):
    decay = params_ref[0, 0]
    threshold = params_ref[0, 1]
    v_reset = params_ref[0, 2]
    v_new = v_ref[...] * decay + i_ref[...]
    spikes = (v_new >= threshold).astype(v_new.dtype)
    v_out_ref[...] = jnp.where(spikes > 0, v_reset, v_new)
    s_out_ref[...] = spikes


@functools.partial(jax.jit, static_argnames=("block_b", "block_n", "interpret"))
def lif_step_pallas(v, current, *, decay: float, threshold: float,
                    v_reset: float = 0.0, block_b: int = DEFAULT_BLOCK_B,
                    block_n: int = DEFAULT_BLOCK_N, interpret: bool = False):
    """(B, N) membrane update; returns (v_next, spikes)."""
    b, n = v.shape
    bb, bn = min(block_b, b), min(block_n, n)
    if b % bb or n % bn:
        raise ValueError(f"shape ({b},{n}) must divide blocks ({bb},{bn})")
    params = jnp.array([[decay, threshold, v_reset]], dtype=v.dtype)
    grid = (b // bb, n // bn)
    return pl.pallas_call(
        _lif_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 3), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), v.dtype),
            jax.ShapeDtypeStruct((b, n), v.dtype),
        ],
        interpret=interpret,
    )(v, current, params)
