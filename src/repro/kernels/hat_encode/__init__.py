"""hat_encode kernel package."""
