"""Pure-jnp oracle for the hat_encode kernel."""

from __future__ import annotations

import jax.numpy as jnp


def hat_encode_ref(spikes: jnp.ndarray, row: int = 256):
    """Hierarchical event encoding oracle.

    spikes: (N,) bool/int {0,1}
    returns:
      ranks   (N,) int32 - service order of each active neuron (ascending
              address = the DES tie-break), -1 for inactive
      count   ()   int32 - number of events
      cluster_counts (N // row,) int32 - events per high-level cluster
    """
    s = spikes.astype(jnp.int32)
    n = s.shape[0]
    incl = jnp.cumsum(s)
    ranks = jnp.where(s > 0, incl - 1, -1).astype(jnp.int32)
    count = incl[-1].astype(jnp.int32)
    cluster_counts = jnp.sum(s.reshape(n // row, row), axis=1).astype(jnp.int32)
    return ranks, count, cluster_counts


def compact_stream(ranks: jnp.ndarray, count: jnp.ndarray) -> jnp.ndarray:
    """ranks -> AER stream: addresses in service order, padded with N."""
    del count
    n = ranks.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    target = jnp.where(ranks >= 0, ranks, n)  # inactive -> OOB, dropped
    return jnp.full((n,), n, jnp.int32).at[target].set(idx, mode="drop")
