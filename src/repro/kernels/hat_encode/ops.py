"""Public ops for hierarchical address-event encoding."""

from __future__ import annotations

import functools

import jax

from repro.kernels.hat_encode import ref
from repro.kernels.hat_encode.kernel import hat_encode_pallas

MAX_PALLAS_N = 1 << 16


@functools.partial(jax.jit, static_argnames=("row", "impl", "interpret"))
def hat_encode(spikes, *, row: int = 256, impl: str = "xla",
               interpret: bool = False):
    """Service ranks + counts for a spike bitmap (see kernel docstring)."""
    n = spikes.shape[0]
    # named_scope: aligns device profiles with repro.obs.trace host spans
    if impl == "pallas" and n <= MAX_PALLAS_N and n % row == 0:
        with jax.named_scope("repro.hat_encode.pallas"):
            return hat_encode_pallas(spikes, row=row, interpret=interpret)
    if impl == "pallas":
        raise ValueError(f"pallas hat_encode supports N % {row} == 0 and "
                         f"N <= {MAX_PALLAS_N}; got N={n}")
    if impl != "xla":
        raise ValueError(f"unknown impl {impl!r}")
    r = row if n % row == 0 else 1
    with jax.named_scope("repro.hat_encode.xla"):
        return ref.hat_encode_ref(spikes, row=r)


@functools.partial(jax.jit, static_argnames=("row", "impl", "interpret"))
def encode_stream(spikes, *, row: int = 256, impl: str = "xla",
                  interpret: bool = False):
    """Compacted AER stream: active addresses in service order, padded N."""
    with jax.named_scope("repro.encode_stream"):
        ranks, count, _ = hat_encode(spikes, row=row, impl=impl,
                                     interpret=interpret)
        return ref.compact_stream(ranks, count), count
