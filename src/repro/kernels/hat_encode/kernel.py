"""Pallas TPU kernel: hierarchical address-event encoding (the HAT tree).

The paper's HAT arbitrates 2 bits per level with small shared arbiters; on
a systolic machine the same hierarchy becomes a two-level prefix scan done
on the MXU (DESIGN.md §2):

  low level   - within-row inclusive scan:  (R, C) @ upper-tri (C, C)
  high level  - across-row exclusive scan:  strict-lower-tri (R, R) @ sums

The spike bitmap (N,) is reshaped to (R, C); each row is a "cluster".  The
kernel emits the service rank of every neuron (ascending-address
arbitration), per-cluster event counts, and the total event count.  The
triangular matmuls are exact in f32 for N < 2^24.

Single-program kernel (whole bitmap in VMEM): N <= 2^16 int32 = 256 KiB,
well inside VMEM; ops.py falls back to the XLA oracle beyond that.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hat_encode_kernel(spikes_ref, ranks_ref, counts_ref, total_ref):
    s = spikes_ref[...].astype(jnp.float32)            # (R, C) {0,1}
    r, c = s.shape
    # low level: inclusive scan within each row (cluster) on the MXU
    col = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    row = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    upper_incl = (col <= row).astype(jnp.float32)      # U[j, k] = 1 if j <= k
    row_scan = jnp.dot(s, upper_incl, preferred_element_type=jnp.float32)
    row_sums = row_scan[:, c - 1:c]                    # (R, 1) cluster counts
    # high level: exclusive scan across rows (clusters)
    ri = jax.lax.broadcasted_iota(jnp.int32, (r, r), 0)
    rj = jax.lax.broadcasted_iota(jnp.int32, (r, r), 1)
    strict_lower = (rj < ri).astype(jnp.float32)       # L[i, j] = 1 if j < i
    offsets = jnp.dot(strict_lower, row_sums,
                      preferred_element_type=jnp.float32)  # (R, 1)
    rank = offsets + row_scan - 1.0
    ranks_ref[...] = jnp.where(s > 0, rank, -1.0).astype(jnp.int32)
    counts_ref[...] = row_sums.astype(jnp.int32)
    total_ref[...] = (offsets[r - 1:r] + row_sums[r - 1:r]).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("row", "interpret"))
def hat_encode_pallas(spikes: jnp.ndarray, *, row: int = 256,
                      interpret: bool = False):
    """(N,) {0,1} -> (ranks (N,), count (), cluster_counts (N//row,))."""
    n = spikes.shape[0]
    if n % row:
        raise ValueError(f"N={n} must be a multiple of row={row}")
    r = n // row
    s2 = spikes.astype(jnp.int32).reshape(r, row)
    ranks2, counts2, total = pl.pallas_call(
        _hat_encode_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((r, row), lambda i: (0, 0))],
        out_specs=[
            pl.BlockSpec((r, row), lambda i: (0, 0)),
            pl.BlockSpec((r, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, row), jnp.int32),
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(s2)
    return ranks2.reshape(n), total.reshape(()), counts2.reshape(r)
