"""Pure-jnp oracle for the cam_search kernel."""

from __future__ import annotations

import jax.numpy as jnp


def pack_bits(bits: jnp.ndarray, word_bits: int = 32) -> jnp.ndarray:
    """(..., nbits) {0,1} -> (..., ceil(nbits/word)) int32, little-endian words."""
    nbits = bits.shape[-1]
    nwords = -(-nbits // word_bits)
    pad = nwords * word_bits - nbits
    b = jnp.pad(bits.astype(jnp.uint32), [(0, 0)] * (bits.ndim - 1) + [(0, pad)])
    b = b.reshape(*bits.shape[:-1], nwords, word_bits)
    weights = (jnp.uint32(1) << jnp.arange(word_bits, dtype=jnp.uint32))
    return jnp.sum(b * weights, axis=-1, dtype=jnp.uint32).astype(jnp.int32)


def cam_search_ref(q_packed: jnp.ndarray, t_packed: jnp.ndarray,
                   valid: jnp.ndarray) -> jnp.ndarray:
    """match[b, e] = valid[e] & all-words-equal.

    q_packed: (B, W) int32; t_packed: (E, W) int32; valid: (E,) bool/int
    returns (B, E) int32 in {0, 1}
    """
    eq = jnp.all(q_packed[:, None, :] == t_packed[None, :, :], axis=-1)
    return (eq & (valid.astype(bool))[None, :]).astype(jnp.int32)


def first_match_ref(match: jnp.ndarray) -> jnp.ndarray:
    """(B, E) match matrix -> (B,) index of lowest matching entry (E if none)."""
    b, e = match.shape
    idx = jnp.arange(e, dtype=jnp.int32)
    return jnp.min(jnp.where(match.astype(bool), idx, e), axis=-1)


def match_count_ref(match: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(match, axis=-1).astype(jnp.int32)
