"""cam_search kernel package."""
