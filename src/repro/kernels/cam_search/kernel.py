"""Pallas TPU kernel: packed-bit CAM associative search.

TPU-native realization of the paper's CAM array (DESIGN.md §2): tags are
bit-packed into int32 lanes; a search broadcasts the query block against a
tag block resident in VMEM and reduces equality across words.  The MXU is
not needed - this is a VPU compare/reduce - but tiling follows the same
(8, 128)-aligned layout rules.

Grid: (B / bB, E / bE).  Each program compares a (bB, W) query tile with a
(bE, W) tag tile and writes a (bB, bE) {0,1} int32 match tile.

The speculative-sense analogue (two-pass filtered search) lives in ops.py:
a cheap last-word prefilter masks the full-width compare, cutting HBM
traffic for mismatching entries exactly as the circuit cuts DC current.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_B = 128
DEFAULT_BLOCK_E = 128


def _cam_search_kernel(q_ref, t_ref, valid_ref, out_ref):
    q = q_ref[...]                      # (bB, W) int32
    t = t_ref[...]                      # (bE, W) int32
    v = valid_ref[...]                  # (1, bE) int32
    # (bB, bE, W) equality, reduced over words
    eq = (q[:, None, :] == t[None, :, :]).all(axis=-1)
    out_ref[...] = (eq & (v[0][None, :] != 0)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_b", "block_e", "interpret"))
def cam_search_pallas(q_packed: jnp.ndarray, t_packed: jnp.ndarray,
                      valid: jnp.ndarray, *, block_b: int = DEFAULT_BLOCK_B,
                      block_e: int = DEFAULT_BLOCK_E,
                      interpret: bool = False) -> jnp.ndarray:
    """(B, W) x (E, W) x (E,) -> (B, E) int32 match matrix."""
    b, w = q_packed.shape
    e, w2 = t_packed.shape
    assert w == w2, (w, w2)
    bb = min(block_b, b)
    be = min(block_e, e)
    if b % bb or e % be:
        raise ValueError(f"B={b} and E={e} must divide block sizes ({bb},{be})")
    grid = (b // bb, e // be)
    valid2d = valid.astype(jnp.int32).reshape(1, e)
    return pl.pallas_call(
        _cam_search_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, w), lambda i, j: (i, 0)),
            pl.BlockSpec((be, w), lambda i, j: (j, 0)),
            pl.BlockSpec((1, be), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bb, be), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, e), jnp.int32),
        interpret=interpret,
    )(q_packed, t_packed, valid2d)
