"""Public ops for CAM search: impl dispatch + speculative-sense variant."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.cam_search import ref
from repro.kernels.cam_search.kernel import (
    DEFAULT_BLOCK_B,
    DEFAULT_BLOCK_E,
    cam_search_pallas,
)

pack_bits = ref.pack_bits


def _pad_rows(x: jnp.ndarray, block: int) -> jnp.ndarray:
    """Zero-pad the leading axis up to a Pallas block multiple (if needed)."""
    rows = x.shape[0]
    if rows <= block or rows % block == 0:
        return x
    pad = -rows % block
    return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def cam_search(q_packed, t_packed, valid, *, impl: str = "xla",
               interpret: bool = False) -> jnp.ndarray:
    """Batched associative tag match: (B, W), (E, W), (E,) -> (B, E) int32."""
    # named_scope: aligns device profiles with repro.obs.trace host spans
    if impl == "xla":
        with jax.named_scope("repro.cam_search.xla"):
            return ref.cam_search_ref(q_packed, t_packed, valid)
    if impl == "pallas":
        with jax.named_scope("repro.cam_search.pallas"):
            return cam_search_pallas(q_packed, t_packed, valid,
                                     interpret=interpret)
    raise ValueError(f"unknown impl {impl!r}")


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def cam_first_match(q_packed, t_packed, valid, *, impl: str = "xla",
                    interpret: bool = False) -> jnp.ndarray:
    m = cam_search(q_packed, t_packed, valid, impl=impl, interpret=interpret)
    return ref.first_match_ref(m)


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def cam_match_counts(q_packed, t_packed, valid, *, impl: str = "xla",
                     interpret: bool = False) -> jnp.ndarray:
    """Per-query match count: (B, W), (E, W), (E,) -> (B,) int32.

    The shape-tolerant entry point the interface tick dispatches through:
    pads B and E up to Pallas block multiples when needed (padded tags are
    invalid so they never match; padded query rows are sliced back off)
    and sums the match matrix along the entry axis.
    """
    b = q_packed.shape[0]
    with jax.named_scope("repro.cam_match_counts"):
        if impl == "pallas":
            q_packed = _pad_rows(q_packed, DEFAULT_BLOCK_B)
            t_packed = _pad_rows(t_packed, DEFAULT_BLOCK_E)
            valid = _pad_rows(valid.astype(jnp.int32), DEFAULT_BLOCK_E)
        m = cam_search(q_packed, t_packed, valid, impl=impl,
                       interpret=interpret)
        return ref.match_count_ref(m[:b])


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def cam_search_speculative(q_packed, t_packed, valid, *, impl: str = "xla",
                           interpret: bool = False) -> jnp.ndarray:
    """Two-pass filtered search - the speculative-sense analogue.

    Pass 1 compares only the *last* packed word (the paper senses the last
    n cells nearest the MLSA); entries failing it are masked out of the
    full-width pass.  Bit-exact with `cam_search`; on real hardware the
    second pass touches only surviving entries, cutting HBM traffic by
    ~P(ss) for mismatching entries.  The benchmark quantifies the saving.
    """
    last_q = q_packed[:, -1:]
    last_t = t_packed[:, -1:]
    prefilter = cam_search(last_q, last_t, valid, impl=impl, interpret=interpret)
    survivors = prefilter.astype(bool)
    full = cam_search(q_packed, t_packed, valid, impl=impl, interpret=interpret)
    return jnp.where(survivors, full, 0)
