"""Public ops for CAM search: impl dispatch + speculative-sense variant."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.cam_search import ref
from repro.kernels.cam_search.kernel import cam_search_pallas

pack_bits = ref.pack_bits


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def cam_search(q_packed, t_packed, valid, *, impl: str = "xla",
               interpret: bool = False) -> jnp.ndarray:
    """Batched associative tag match: (B, W), (E, W), (E,) -> (B, E) int32."""
    if impl == "xla":
        return ref.cam_search_ref(q_packed, t_packed, valid)
    if impl == "pallas":
        return cam_search_pallas(q_packed, t_packed, valid, interpret=interpret)
    raise ValueError(f"unknown impl {impl!r}")


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def cam_first_match(q_packed, t_packed, valid, *, impl: str = "xla",
                    interpret: bool = False) -> jnp.ndarray:
    m = cam_search(q_packed, t_packed, valid, impl=impl, interpret=interpret)
    return ref.first_match_ref(m)


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def cam_search_speculative(q_packed, t_packed, valid, *, impl: str = "xla",
                           interpret: bool = False) -> jnp.ndarray:
    """Two-pass filtered search - the speculative-sense analogue.

    Pass 1 compares only the *last* packed word (the paper senses the last
    n cells nearest the MLSA); entries failing it are masked out of the
    full-width pass.  Bit-exact with `cam_search`; on real hardware the
    second pass touches only surviving entries, cutting HBM traffic by
    ~P(ss) for mismatching entries.  The benchmark quantifies the saving.
    """
    last_q = q_packed[:, -1:]
    last_t = t_packed[:, -1:]
    prefilter = cam_search(last_q, last_t, valid, impl=impl, interpret=interpret)
    survivors = prefilter.astype(bool)
    full = cam_search(q_packed, t_packed, valid, impl=impl, interpret=interpret)
    return jnp.where(survivors, full, 0)
