"""Pallas TPU kernels for the core-interface hot spots.

Each kernel package ships kernel.py (pl.pallas_call + BlockSpec), ops.py
(jit'd wrapper with impl dispatch: "xla" oracle path | "pallas"), and
ref.py (pure-jnp oracle).  Kernels validate in interpret mode on CPU; the
XLA path is the default so dry-run cost analysis stays meaningful.
"""
