"""Pure-jnp oracle for the moe_dispatch kernel."""

from __future__ import annotations

import jax.numpy as jnp


def dispatch_positions_ref(expert_ids: jnp.ndarray, num_experts: int):
    """Arrival-order position of each event within its expert.

    expert_ids: (M,) int32 event stream in arbitration order.
    returns: pos (M,) int32   - #earlier events with the same expert
             load (E,) int32  - events per expert
    """
    onehot = (expert_ids[:, None] == jnp.arange(num_experts)[None, :]
              ).astype(jnp.int32)                         # (M, E)
    csum = jnp.cumsum(onehot, axis=0)
    pos = jnp.take_along_axis(csum, expert_ids[:, None].astype(jnp.int32),
                              axis=1)[:, 0] - 1
    return pos.astype(jnp.int32), jnp.sum(onehot, axis=0).astype(jnp.int32)
