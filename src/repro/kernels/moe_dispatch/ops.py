"""Public ops for MoE dispatch positions."""

from __future__ import annotations

import functools

import jax

from repro.kernels.moe_dispatch import ref
from repro.kernels.moe_dispatch.kernel import dispatch_positions_pallas


@functools.partial(jax.jit,
                   static_argnames=("num_experts", "impl", "row", "interpret"))
def dispatch_positions(expert_ids, *, num_experts: int, impl: str = "xla",
                       row: int = 256, interpret: bool = False):
    """Arrival-order position within expert + per-expert load.

    expert_ids: (M,) int32 -> (pos (M,) int32, load (E,) int32)
    """
    if impl == "xla":
        return ref.dispatch_positions_ref(expert_ids, num_experts)
    if impl == "pallas":
        return dispatch_positions_pallas(expert_ids, num_experts=num_experts,
                                         row=row, interpret=interpret)
    raise ValueError(f"unknown impl {impl!r}")
