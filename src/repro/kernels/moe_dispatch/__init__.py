"""moe_dispatch kernel package."""
