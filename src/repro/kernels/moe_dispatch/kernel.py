"""Pallas TPU kernel: capacity-ordered MoE dispatch positions.

The event-router analogue of HAT arbitration (DESIGN.md §2): an event
stream of expert choices is "arbitrated" into per-expert queues.  The
kernel computes, for every event, its arrival-order position within its
expert - the quantity that decides capacity drops - plus per-expert loads,
WITHOUT a sort (XLA MoE implementations pay an O(M log M) sort here).

Structure = the HAT tree:
  low level   - within-row scan: one-hot (C, bE) column-cumsum via a
                triangular matmul on the MXU,
  high level  - running per-expert totals carried across rows in a VMEM
                scratch accumulator (Pallas TPU grids execute sequentially).

Grid: (J, R) with J = expert tiles (major), R = event rows (minor).
For each expert tile j, rows sweep 0..R-1 carrying the accumulator; the
position output block (1, C) for row r is accumulated across the J sweeps
(an event belongs to exactly one expert tile).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_ROW = 256
DEFAULT_BLOCK_E = 128


def _dispatch_kernel(ids_ref, pos_ref, load_ref, acc_ref):
    j = pl.program_id(0)
    r = pl.program_id(1)
    nr = pl.num_programs(1)
    c = ids_ref.shape[1]
    be = acc_ref.shape[1]

    @pl.when(r == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ids = ids_ref[...]                                   # (1, C) int32
    first_expert = j * be
    local = ids - first_expert                           # in-tile expert index
    in_tile = (local >= 0) & (local < be)
    eidx = jax.lax.broadcasted_iota(jnp.int32, (c, be), 1)
    onehot = ((local.reshape(c, 1) == eidx) &
              in_tile.reshape(c, 1)).astype(jnp.float32)  # (C, bE)

    # low level: exclusive scan down the rows of onehot via strict-lower tri
    ci = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    cj = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    strict_lower = (cj < ci).astype(jnp.float32)
    before_in_row = jnp.dot(strict_lower, onehot,
                            preferred_element_type=jnp.float32)  # (C, bE)
    totals = jnp.sum(onehot, axis=0, keepdims=True)      # (1, bE)

    # high level: add the running totals from previous rows
    pos_full = before_in_row + acc_ref[...]              # (C, bE)
    # gather each event's own expert column: sum(onehot * pos) over lanes
    pos_row = jnp.sum(onehot * pos_full, axis=1).reshape(1, c)
    contrib = jnp.where(in_tile, pos_row, 0.0)

    @pl.when(j == 0)
    def _():
        pos_ref[...] = jnp.zeros_like(pos_ref)

    pos_ref[...] += contrib.astype(jnp.int32)
    acc_ref[...] += totals

    @pl.when(r == nr - 1)
    def _():
        load_ref[...] = acc_ref[...].astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("num_experts", "row", "block_e",
                                    "interpret"))
def dispatch_positions_pallas(expert_ids: jnp.ndarray, *, num_experts: int,
                              row: int = DEFAULT_ROW,
                              block_e: int = DEFAULT_BLOCK_E,
                              interpret: bool = False):
    """(M,) int32 -> (pos (M,) int32, load (E,) int32)."""
    m = expert_ids.shape[0]
    if m % row:
        raise ValueError(f"M={m} must be a multiple of row={row}")
    # largest divisor of num_experts that fits the requested tile width
    be = max(d for d in range(1, min(block_e, num_experts) + 1)
             if num_experts % d == 0)
    r = m // row
    j = num_experts // be
    ids2 = expert_ids.astype(jnp.int32).reshape(r, row)
    pos2, load2 = pl.pallas_call(
        _dispatch_kernel,
        grid=(j, r),
        in_specs=[pl.BlockSpec((1, row), lambda j_, r_: (r_, 0))],
        out_specs=[
            pl.BlockSpec((1, row), lambda j_, r_: (r_, 0)),
            pl.BlockSpec((1, be), lambda j_, r_: (0, j_)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, row), jnp.int32),
            jax.ShapeDtypeStruct((1, num_experts), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, be), jnp.float32)],
        interpret=interpret,
    )(ids2)
    return pos2.reshape(m), load2.reshape(num_experts)
