"""sparse_tick kernel package: the fused rate-proportional event tick."""
