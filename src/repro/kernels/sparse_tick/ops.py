"""Dispatch layer for the fused sparse event tick.

`sparse_tick` mirrors the `repro.kernels.cam_search` /
`repro.kernels.hat_encode` ops idiom: an ``impl`` switch between the
plain-jnp reference (``"xla"``) and the fused Pallas kernel
(``"pallas"``, interpret mode off-TPU by default), with shape validation
and size guards at the dispatch boundary so kernel code never sees
malformed operands.

Capacity policy: the per-core event buffer holds ``capacity`` live
addresses (+1 pad slot).  `resolve_capacity` turns the user-facing
`InterfaceConfig.sparse_capacity` knob (``None`` = heuristic
``max(8, n // 8)``) into the effective value, clamped to ``n - 1`` so a
full-frame burst always overflows into the dense fallback - which keeps
the trailing pad slot (and with it the HAT encode-energy boundary term)
present whenever the sparse path runs.
"""

from __future__ import annotations

import jax

from repro.kernels.sparse_tick import kernel as sparse_kernel
from repro.kernels.sparse_tick import ref

compact_events = ref.compact_events
event_indices = ref.event_indices

MIN_CAPACITY = 8
CAPACITY_DIVISOR = 8


def default_capacity(n: int) -> int:
    """Heuristic event capacity per core: n/8, at least `MIN_CAPACITY`."""
    return max(MIN_CAPACITY, n // CAPACITY_DIVISOR)


def resolve_capacity(requested: int | None, n: int) -> int:
    """Effective buffer capacity for a fabric with ``n`` neurons/core.

    ``requested=None`` applies `default_capacity`; explicit values must
    be positive.  Either way the result is clamped to ``n - 1``: a frame
    where every neuron fires must overflow to the dense tick, so the
    sparse encode-energy model always sees its pad boundary.
    """
    if requested is None:
        requested = default_capacity(n)
    if requested < 1:
        raise ValueError(
            f"sparse_capacity must be a positive event count, got "
            f"{requested}")
    return max(1, min(requested, n - 1))


def sparse_tick(spikes_flat, buf, counts, src_idx, active, weights, targets,
                *, n: int, latency_fn, encode_fn, impl: str = "pallas",
                interpret: bool | None = None):
    """Fused sparse tick: CAM gather + scatter + latency + encode energy.

    Args:
      spikes_flat ... targets: see `ref.sparse_tick_ref`.
      n:          neurons per core (the buffer pad value).
      latency_fn: resolved ``ArbiterScheme.sparse_tick_latency(ctx)``.
      encode_fn:  resolved ``ArbiterScheme.sparse_encode_energy(ctx)``.
      impl:       ``"pallas"`` (fused kernel) or ``"xla"`` (reference).
      interpret:  force/suppress Pallas interpret mode; ``None`` picks
                  interpret automatically off-TPU.

    Returns:
      (currents (cores, n) f32, latencies (cores,) f32,
       enc_per_core (cores,) f32, hits scalar f32)

    Raises:
      ValueError: on an unknown ``impl``, mismatched operand shapes, or
        an operand set larger than the single-program kernel supports
        (`kernel.MAX_FUSED_ELEMS`).
    """
    if impl not in ("xla", "pallas"):
        raise ValueError(
            f"unknown sparse_tick impl {impl!r}; expected 'xla' or 'pallas'")
    cores = src_idx.shape[0]
    if buf.ndim != 2 or buf.shape[0] != cores or counts.shape != (cores,):
        raise ValueError(
            f"event buffer shapes {buf.shape}/{counts.shape} do not match "
            f"{cores} cores")
    if spikes_flat.shape != (cores * n,):
        raise ValueError(
            f"spikes_flat shape {spikes_flat.shape} != ({cores * n},)")
    if not (src_idx.shape == active.shape == weights.shape == targets.shape):
        raise ValueError(
            f"CAM operand shapes disagree: {src_idx.shape}, {active.shape}, "
            f"{weights.shape}, {targets.shape}")
    if impl == "xla":
        return ref.sparse_tick_ref(
            spikes_flat, buf, counts, src_idx, active, weights, targets,
            n=n, latency_fn=latency_fn, encode_fn=encode_fn)
    if src_idx.size > sparse_kernel.MAX_FUSED_ELEMS:
        raise ValueError(
            f"fabric too large for the single-program sparse_tick kernel "
            f"({src_idx.size} CAM operand elements > "
            f"{sparse_kernel.MAX_FUSED_ELEMS}); use impl='xla'")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return sparse_kernel.sparse_tick_pallas(
        spikes_flat, buf, counts, src_idx, active, weights, targets,
        n=n, latency_fn=latency_fn, encode_fn=encode_fn, interpret=interpret)
