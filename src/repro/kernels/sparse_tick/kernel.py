"""Fused Pallas kernel for the sparse event tick.

One `pallas_call` computes everything the event path needs per tick from
the compacted address buffers: the CAM gather, the weighted scatter-add
into synaptic currents, the arbiter ``tick_latency`` policy, and the AER
encode energy.  Fusing the four stages keeps every intermediate - the
(cores, entries) drive mask, the per-core address buffers - in one
kernel's working set instead of bouncing them through HBM between four
separately-scheduled ops.

Grid and memory layout: like `repro.kernels.hat_encode`, the kernel runs
as a single program (``grid=(1,)``) with the whole problem in VMEM and
the core axis vectorized inside the body - per-core work at sparse-tick
sizes (``cores x (capacity + 1)`` addresses, ``cores x entries`` CAM
operands) is far below VMEM limits (`MAX_FUSED_ELEMS` guards the
ceiling).  Scalar outputs are shaped ``(cores, 1)`` / ``(1, 1)`` so every
ref stays at least 2-D.

Off TPU the kernel runs in interpret mode (`repro.kernels.cam_search`
precedent): the body traces to the same jnp ops as
`repro.kernels.sparse_tick.ref`, so CPU/GPU hosts execute a fused XLA
computation with identical semantics and CI exercises the kernel path
bit-for-bit.  The arbiter policies are passed in as traceable callables
(`ArbiterScheme.sparse_tick_latency` / ``sparse_encode_energy``
factories, resolved per session), so new arbiter schemes reach the
kernel through the registry without editing it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Whole-problem single-program ceiling: cores * entries operand elements.
MAX_FUSED_ELEMS = 1 << 22


def _fused_kernel(latency_fn, encode_fn, n: int, cores: int):
    """Bind the static config into the kernel body."""

    def kernel(buf_ref, counts_ref, spikes_ref, src_ref, act_ref, w_ref,
               tgt_ref, cur_ref, lat_ref, enc_ref, hits_ref):
        buf = buf_ref[...]
        counts = counts_ref[...][:, 0]
        # arbiter tick latency + AER encode energy from the event buffer
        lat_ref[...] = latency_fn(buf, counts)[:, None]
        enc_ref[...] = encode_fn(buf, counts)[:, None]
        # CAM gather: is each entry's decoded source spiking this tick?
        drive = (spikes_ref[...][src_ref[...]] & act_ref[...]).astype(
            jnp.float32)
        # weighted scatter-add into per-core currents (flat over cores*n;
        # see ref.py for why this is bit-identical to the per-core form)
        contrib = (drive * w_ref[...]).reshape(-1)
        tgt = tgt_ref[...]
        flat_targets = (tgt + jnp.arange(cores, dtype=tgt.dtype)[:, None] * n
                        ).reshape(-1)
        cur_ref[...] = jnp.zeros((cores * n,), jnp.float32).at[
            flat_targets].add(contrib).reshape(cores, n)
        hits_ref[...] = jnp.sum(drive)[None, None]

    return kernel


def sparse_tick_pallas(spikes_flat, buf, counts, src_idx, active, weights,
                       targets, *, n: int, latency_fn, encode_fn,
                       interpret: bool = False):
    """Run the fused sparse tick as one `pallas_call`.

    Same contract as `repro.kernels.sparse_tick.ref.sparse_tick_ref`
    (see there for argument shapes and the bit-identity argument);
    ``interpret=True`` executes the kernel body as plain XLA ops off-TPU.
    """
    cores = buf.shape[0]
    kernel = _fused_kernel(latency_fn, encode_fn, n, cores)
    out_shape = [
        jax.ShapeDtypeStruct((cores, n), jnp.float32),      # currents
        jax.ShapeDtypeStruct((cores, 1), jnp.float32),      # latencies
        jax.ShapeDtypeStruct((cores, 1), jnp.float32),      # encode energy
        jax.ShapeDtypeStruct((1, 1), jnp.float32),          # CAM hits
    ]
    currents, lat, enc, hits = pl.pallas_call(
        kernel, out_shape=out_shape, interpret=interpret,
    )(buf, counts[:, None], spikes_flat, src_idx, active, weights, targets)
    return currents, lat[:, 0], enc[:, 0], hits[0, 0]
