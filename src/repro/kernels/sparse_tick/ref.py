"""Reference implementation of the fused sparse event tick.

The sparse tick makes per-tick cost scale with *events* instead of
neurons: active addresses are compacted per core into a fixed-capacity
buffer (`compact_events`), and every per-event quantity downstream -
arbiter tick latency, AER encode energy, NoC/CAM accounting - is computed
from that buffer instead of from dense (cores, n) masks.

Compaction is segment-id based and sort-free: the inclusive cumsum of a
core's spike row is a sorted vector, so the address of the j-th active
event is ``searchsorted(cumsum, j + 1)`` - one binary search per output
slot, O(K log n) per core, no scatter.  Slots past the live count come
out as ``n`` (the same pad value `repro.kernels.hat_encode` uses), so the
buffer *is* a truncated AER address stream in service order and the
arbiter's sparse policies can read boundary transitions directly.

The buffer holds ``capacity + 1`` entries: a frame with exactly
``capacity`` events still carries one trailing pad, which the HAT encode
energy model needs (the pad boundary toggle is part of the dense
address-stream mean it must reproduce bit-for-bit).  Frames with more
than ``capacity`` events per core overflow; callers detect this with
``counts > capacity`` and fall back to the dense tick
(`repro.interface.pipeline` wraps both in one ``lax.cond``).

Bit-identity notes (the contract `tests/conformance` enforces):

  * every latency/energy formula sums small integers in float32, where
    addition is exact regardless of order, then applies the same final
    ops (division by ``n``, ``where`` selects) as the dense path;
  * the currents epilogue scatters ``weights * drive`` with one flat
    scatter-add over ``cores * n`` targets.  The dense path scatters
    per core under `jax.vmap`; both process each core's entries in
    ascending entry order onto disjoint per-core target ranges, so every
    output element accumulates the same values in the same order and the
    float32 results are bit-identical (asserted, not just assumed, in
    tests/test_sparse_tick.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compact_events(spikes: jnp.ndarray, capacity: int):
    """Compact a spike frame into per-core event address buffers.

    Args:
      spikes: (cores, n) bool frame.
      capacity: max events per core the buffer can hold (K).

    Returns:
      buf:    (cores, K + 1) int32 - each row holds that core's active
              addresses in ascending (service) order, padded with ``n``.
      counts: (cores,) int32 live event count per row.  Rows where
              ``counts > capacity`` have truncated buffers and must be
              routed to the dense fallback by the caller.
    """
    csum = jnp.cumsum(spikes, axis=1)                          # (C, n) int
    slots = jnp.arange(1, capacity + 2)
    buf = jax.vmap(lambda cs: jnp.searchsorted(cs, slots))(csum)
    return buf.astype(jnp.int32), csum[:, -1].astype(jnp.int32)


def event_indices(buf: jnp.ndarray, n: int):
    """Flat global source indices + live weights for accounting gathers.

    Args:
      buf: (cores, K + 1) compacted address buffer from `compact_events`.
      n:   neurons per core (the buffer's pad value).

    Returns:
      ev_idx: (cores * K,) int32 flat source-neuron indices (pad slots
              point at index 0 and are neutralized by ``ev_w``).
      ev_w:   (cores * K,) float32 1.0 on live events, 0.0 on pads.
    """
    cores = buf.shape[0]
    addr = buf[:, :-1]                                         # (C, K)
    real = addr < n
    base = jnp.arange(cores, dtype=jnp.int32)[:, None] * n
    ev_idx = jnp.where(real, addr + base, 0).reshape(-1)
    return ev_idx, real.reshape(-1).astype(jnp.float32)


def sparse_tick_ref(spikes_flat, buf, counts, src_idx, active, weights,
                    targets, *, n: int, latency_fn, encode_fn):
    """Fused sparse tick body, plain-jnp reference for the Pallas kernel.

    Computes the four per-tick event quantities in one place: CAM gather,
    weighted scatter-add into currents, arbiter tick latency, and AER
    encode energy - the work `repro.kernels.sparse_tick.kernel` fuses
    into a single `pallas_call`.

    Args:
      spikes_flat: (cores * n,) bool flat spike frame.
      buf, counts: output of `compact_events`.
      src_idx:     (cores, entries) int32 decoded CAM source indices
                   (`RoutingIndex.src_idx`).
      active:      (cores, entries) bool live-entry mask.
      weights:     (cores, entries) float32 synaptic weights.
      targets:     (cores, entries) int32 local target neuron per entry.
      n:           neurons per core.
      latency_fn:  ``(buf, counts) -> (cores,) float32`` sparse arbiter
                   policy (`ArbiterScheme.sparse_tick_latency(ctx)`).
      encode_fn:   ``(buf, counts) -> (cores,) float32`` sparse encode
                   energy policy (`ArbiterScheme.sparse_encode_energy`).

    Returns:
      (currents (cores, n) f32, latencies (cores,) f32,
       enc_per_core (cores,) f32, hits scalar f32)
    """
    cores = buf.shape[0]
    latencies = latency_fn(buf, counts)
    enc_per_core = encode_fn(buf, counts)
    drive = (spikes_flat[src_idx] & active).astype(jnp.float32)
    contrib = (drive * weights).reshape(-1)
    flat_targets = (targets +
                    jnp.arange(cores, dtype=targets.dtype)[:, None] * n
                    ).reshape(-1)
    currents = jnp.zeros((cores * n,), jnp.float32).at[flat_targets].add(
        contrib).reshape(cores, n)
    return currents, latencies, enc_per_core, jnp.sum(drive)
