"""Mamba (selective SSM) layer for the Jamba hybrid architecture.

Selective state-space recurrence with diagonal A:

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * x_t
    y_t = C_t . h_t + D * x_t

Training path: chunked lax.scan - within a chunk the diagonal recurrence
is evaluated with an associative scan over time, the chunk boundary state
is carried sequentially.  Chunking bounds the (B, chunk, d_inner, d_state)
working set so a 500k-token sequence never materializes the full state
tensor (DESIGN.md §4).  Decode path: single-step recurrence against a
(conv window, ssm state) cache.

TP: d_inner is sharded over the model axis by the layer above; everything
here is elementwise in d_inner, so no collectives are needed inside.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import calibrate
from repro.models.config import ModelConfig
from repro.models.blocks import _dense_init, _pdtype

SCAN_CHUNK = 512


def d_inner(cfg: ModelConfig) -> int:
    return cfg.mamba.expand * cfg.d_model


def dt_rank(cfg: ModelConfig) -> int:
    return cfg.mamba.dt_rank or max(1, cfg.d_model // 16)


def init_mamba(key, cfg: ModelConfig):
    m = cfg.mamba
    d, di, dr = cfg.d_model, d_inner(cfg), dt_rank(cfg)
    ks = jax.random.split(key, 8)
    pdt = _pdtype(cfg)
    a = jnp.broadcast_to(jnp.arange(1, m.d_state + 1, dtype=jnp.float32),
                         (di, m.d_state))
    return {
        "w_in": _dense_init(ks[0], (d, 2 * di), pdt),
        "conv_w": (_dense_init(ks[1], (m.d_conv, di), pdt)),
        "conv_b": jnp.zeros((di,), pdt),
        "w_bc": _dense_init(ks[2], (di, 2 * m.d_state), pdt),
        "w_dt_a": _dense_init(ks[3], (di, dr), pdt),
        "w_dt_b": _dense_init(ks[4], (dr, di), pdt),
        "dt_bias": jnp.full((di,), math.log(math.e - 1) * 0.1, pdt),
        "a_log": jnp.log(a).astype(pdt),
        "d_skip": jnp.ones((di,), pdt),
        "w_out": _dense_init(ks[5], (di, d), pdt),
    }


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv1d.  x (B,T,di); w (K,di); returns (y, new_state)."""
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                 # (B, T+K-1, di)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(k))
    y = y + b[None, None]
    new_state = xp[:, -(k - 1):] if k > 1 else None
    return y, new_state


def _ssm_scan_chunked(u, dt, b_t, c_t, a, ssm_state):
    """u,dt (B,T,di); b_t,c_t (B,T,N); a (di,N); state (B,di,N) f32."""
    bsz, t, di = u.shape
    n = a.shape[1]
    chunk = min(SCAN_CHUNK, t)
    if t % chunk:
        raise ValueError(f"T={t} must divide chunk={chunk}")
    nc = t // chunk
    # precompute per-step decay and input in f32
    dt_f = dt.astype(jnp.float32)
    decay = jnp.exp(dt_f[..., None] * (-jnp.exp(a.astype(jnp.float32)))[None, None])
    inp = (dt_f * u.astype(jnp.float32))[..., None] * \
        b_t.astype(jnp.float32)[:, :, None, :]             # (B,T,di,N)

    dec_c = decay.reshape(bsz, nc, chunk, di, n).transpose(1, 0, 2, 3, 4)
    inp_c = inp.reshape(bsz, nc, chunk, di, n).transpose(1, 0, 2, 3, 4)
    c_c = c_t.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)

    def chunk_step(h, args):
        dec, xin, c = args                                 # (B,chunk,di,N)
        # associative scan over the chunk: (a,b) pairs compose as
        # (a2*a1, a2*b1 + b2)
        def combine(p, q):
            return p[0] * q[0], q[0] * p[1] + q[1]
        a_cum, b_cum = jax.lax.associative_scan(combine, (dec, xin), axis=1)
        states = a_cum * h[:, None] + b_cum                # (B,chunk,di,N)
        y = jnp.einsum("btdn,btn->btd", states, c)
        return states[:, -1], y

    ssm_state, ys = jax.lax.scan(chunk_step, ssm_state.astype(jnp.float32),
                                 (dec_c, inp_c, c_c),
                                 unroll=calibrate.UNROLL)
    y = ys.transpose(1, 0, 2, 3).reshape(bsz, t, di)
    return y, ssm_state


def mamba_apply(p, x, cfg: ModelConfig, state=None):
    """x (B,T,d) -> (y, new_state).  state: dict(conv, ssm) or None."""
    m = cfg.mamba
    bsz, t, _ = x.shape
    dt_ = x.dtype
    di = d_inner(cfg)
    xz = x @ p["w_in"].astype(dt_)                         # (B,T,2*di)
    u, z = xz[..., :di], xz[..., di:]

    conv_state = state["conv"] if state is not None else None
    u_c, new_conv = _causal_conv(u, p["conv_w"].astype(dt_),
                                 p["conv_b"].astype(dt_), conv_state)
    u_c = jax.nn.silu(u_c)

    bc = u_c @ p["w_bc"].astype(dt_)                       # (B,T,2N)
    b_t, c_t = bc[..., :m.d_state], bc[..., m.d_state:]
    dt_low = u_c @ p["w_dt_a"].astype(dt_)
    delta = jax.nn.softplus(dt_low @ p["w_dt_b"].astype(dt_)
                            + p["dt_bias"].astype(dt_))    # (B,T,di)

    ssm_state = state["ssm"] if state is not None else jnp.zeros(
        (bsz, di, m.d_state), jnp.float32)
    if t == 1:
        # decode: single recurrence step
        dec = jnp.exp(delta.astype(jnp.float32)[..., None]
                      * (-jnp.exp(p["a_log"].astype(jnp.float32)))[None, None])
        xin = (delta.astype(jnp.float32) * u_c.astype(jnp.float32))[..., None] \
            * b_t.astype(jnp.float32)[:, :, None, :]
        h = dec[:, 0] * ssm_state + xin[:, 0]
        y = jnp.einsum("bdn,bn->bd", h, c_t.astype(jnp.float32)[:, 0])[:, None]
        new_ssm = h
    else:
        y, new_ssm = _ssm_scan_chunked(u_c, delta, b_t, c_t, p["a_log"],
                                       ssm_state)
    y = y.astype(dt_) + u_c * p["d_skip"].astype(dt_)[None, None]
    y = y * jax.nn.silu(z)
    out = y @ p["w_out"].astype(dt_)
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv, "ssm": new_ssm}
    return out, new_state
