"""Unified model configuration covering every assigned architecture family.

One dataclass describes dense GQA transformers, MLA+MoE (DeepSeek-V2),
RWKV6, hybrid Mamba+attention+MoE (Jamba), encoder-only audio (HuBERT) and
VLM (phi-3-vision) backbones.  `layer_kind(i)` resolves the per-layer
pattern (gemma3 5:1 local:global, jamba 1:7 attn:mamba, deepseek first-k
dense) so the layer stack can be scanned in homogeneous groups.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

Family = Literal["dense", "moe", "rwkv", "hybrid", "encoder", "vlm"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    num_shared: int = 0
    top_k: int = 1
    d_expert: int = 0            # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    aux_loss_weight: float = 1e-2
    z_loss_weight: float = 1e-3
    every: int = 1               # MoE layer every `every` layers (jamba: 2)
    first_k_dense: int = 0       # leading dense layers (deepseek: 1)
    d_ff_dense: int = 0          # FFN dim of those dense layers
    quant_int8: bool = False     # weight-only int8 experts (serving)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    q_lora: int = 0              # 0 = direct q projection (dsv2-lite)
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0             # 0 -> d_model // 16


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    lora_decay: int = 64         # rank of the data-dependent decay LoRA
    lora_mix: int = 32           # rank of the ddlerp token-shift LoRAs
    chunk: int = 16              # WKV chunk length (trades state traffic
                                 # for intra-chunk compute)


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB: precomputed embeddings enter the backbone."""
    kind: Literal["none", "audio", "vision"] = "none"
    d_in: int = 0                # frame/patch embedding dim from the stub
    max_prefix: int = 0          # vision: image tokens prepended to text


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    rope_theta: float = 10_000.0
    rope_theta_local: float = 10_000.0   # gemma3 dual-theta
    qk_norm: bool = False
    sliding_window: int = 0              # 0 = always global
    local_global_ratio: int = 0          # gemma3: 5 local then 1 global
    norm_eps: float = 1e-6
    post_norms: bool = False             # gemma3 sandwich norms
    tie_embeddings: bool = False
    act: Literal["silu", "gelu", "relu2"] = "silu"
    encoder_only: bool = False
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    mamba: MambaConfig | None = None
    rwkv: RWKVConfig | None = None
    frontend: FrontendConfig = dataclasses.field(default_factory=FrontendConfig)
    attn_layer_period: int = 0           # jamba: 1 attention layer every N
    attn_layer_offset: int = 0
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # parallelism hints (resolved by parallel/sharding.py)
    attn_shard: Literal["heads", "sequence"] = "heads"
    scan_group: int = 1                  # layers per scan-group body
    serve_tp_only: bool = False          # serving: no FSDP dim on weights
    rwkv_pad_heads: int = 0              # pad WKV heads to shard over model
    ddlerp_bf16: bool = False            # RWKV: token-shift mix in bf16

    # ---- per-layer pattern ------------------------------------------------

    def layer_kind(self, i: int) -> str:
        """'attn' | 'mamba' | 'rwkv' - the sequence mixer of layer i."""
        if self.family == "rwkv":
            return "rwkv"
        if self.family == "hybrid":
            if self.attn_layer_period and i % self.attn_layer_period == self.attn_layer_offset:
                return "attn"
            return "mamba"
        return "attn"

    def layer_is_local(self, i: int) -> bool:
        """gemma3-style 5:1 local:global pattern."""
        if not self.local_global_ratio or not self.sliding_window:
            return False
        return (i % (self.local_global_ratio + 1)) != self.local_global_ratio

    def layer_is_moe(self, i: int) -> bool:
        if self.moe is None or self.moe.num_experts == 0:
            return False
        if i < self.moe.first_k_dense:
            return False
        return (i - self.moe.first_k_dense) % self.moe.every == 0 \
            if self.moe.every > 1 else True

    def scan_groups(self) -> Sequence[tuple[int, int]]:
        """(start, length) homogeneous layer groups for lax.scan stacking."""
        sig = [(self.layer_kind(i), self.layer_is_local(i), self.layer_is_moe(i))
               for i in range(self.n_layers)]
        g = self.scan_group
        groups = []
        i = 0
        while i < self.n_layers:
            # a group of g layers repeats while the g-periodic signature holds
            length = g
            while (i + length + g <= self.n_layers
                   and sig[i + length:i + length + g] == sig[i:i + g]):
                length += g
            groups.append((i, length))
            i += length
        return groups

    @property
    def n_rep(self) -> int:
        """GQA query-head replication factor."""
        return self.n_heads // max(self.n_kv_heads, 1)

    def supports_decode(self) -> bool:
        return not self.encoder_only

    def is_subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / linear attention)."""
        return self.family in ("rwkv", "hybrid")
