"""Shared transformer building blocks, pure-functional JAX.

Conventions
-----------
* params are nested dicts of jnp arrays; `init_*` builds them, `*_apply`
  consumes them.  Layer stacks are scanned, so init functions are vmapped
  over a key axis by the model builder.
* activations flow as (B, T, d_model); attention internals use
  (B, T, KH, rep, Dh) so GQA is explicit and head-TP shards KH*rep.
* attention is flash-style chunked (two-level online-softmax scan) in pure
  jnp - O(chunk^2) working set, exact.  Local (sliding-window) layers use
  a banded variant that only touches the in-window KV chunks, keeping the
  compiled FLOPs O(T * window) - this is what the roofline sees.
* all matmuls run in `compute_dtype` (bf16 by default) with f32
  accumulation via preferred_element_type.

Distribution: blocks are sharding-agnostic except for an optional
`ShardCtx` enabling shard_map paths (sequence-parallel attention, EP MoE,
sequence-sharded decode).  With ctx=None everything is local - smoke tests
run the identical code on one CPU device.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import event_router
from repro.models import calibrate
from repro.models.config import ModelConfig

Params = dict


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Names of mesh axes; None disables shard_map paths (single device)."""
    data_axes: tuple = ("data",)     # batch axes ("pod","data") when multi-pod
    model_axis: str = "model"
    model_size: int = 1
    enabled: bool = False
    axis_sizes: tuple = ()           # ((axis, size), ...) for spec sanitizing

    @property
    def batch_spec(self):
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]


LOCAL = ShardCtx(enabled=False)


def _bspec_for(ctx: ShardCtx, batch: int):
    """Batch spec, or None (replicate) when batch doesn't divide the DP
    extent (e.g. long_500k's single sequence)."""
    dp = 1
    for a, sz in ctx.axis_sizes:
        if a in ctx.data_axes:
            dp *= sz
    return ctx.batch_spec if batch % max(dp, 1) == 0 else None


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# norms / rope / activations
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, cfg: ModelConfig) -> Params:
    return {"scale": jnp.zeros((d,), _pdtype(cfg))}


def rms_norm(x, p, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu2": lambda x: jnp.square(jax.nn.relu(x))}[name]


def rope_tables(positions, dim: int, theta: float):
    """positions (...,) int -> cos/sin (..., dim/2) f32."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (B, T, ..., D); cos/sin (B|1, T, D/2) broadcast over middle dims."""
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    extra = x.ndim - cos.ndim              # head-ish dims between T and D
    shape = cos.shape[:-1] + (1,) * extra + cos.shape[-1:]
    c = cos.reshape(shape).astype(x.dtype)
    s = sin.reshape(shape).astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


# ---------------------------------------------------------------------------
# flash-style chunked attention (exact, pure jnp)
# ---------------------------------------------------------------------------


def _attn_chunk(q, k, v, q_pos, k_pos, causal, window, scale, kv_len=None):
    """One (q-chunk x kv-chunk) tile -> (scores-applied partials).

    q: (B, Cq, KH, R, D); k/v: (B, Ck, KH, D).  Returns (m, l, acc) partials
    in f32: m (B,KH,R,Cq), l (B,KH,R,Cq), acc (B,Cq,KH,R,Dv).
    """
    s = jnp.einsum("bqhrd,bkhd->bhrqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if kv_len is not None:
        mask &= k_pos[None, :] < kv_len       # padded KV tail
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    m = jnp.max(s, axis=-1)                                   # (B,KH,R,Cq)
    # guard fully-masked rows
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(mask[None, None, None], p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhrqk,bkhd->bqhrd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return m_safe, l, acc


def _merge(carry, new):
    m0, l0, a0 = carry
    m1, l1, a1 = new
    m = jnp.maximum(m0, m1)
    e0 = jnp.exp(m0 - m)
    e1 = jnp.exp(m1 - m)
    l = l0 * e0 + l1 * e1
    a = a0 * _blh(e0) + a1 * _blh(e1)
    return m, l, a


def _blh(x):
    """(B,KH,R,Cq) -> (B,Cq,KH,R,1) broadcast helper."""
    return jnp.transpose(x, (0, 3, 1, 2))[..., None]


# Default flash chunk sizes.  The dry-run calibration pass sets these to a
# huge value so attention lowers loop-free (exact HLO cost analysis); the
# production path keeps 1024-token tiles (VMEM-sized working set).
DEFAULT_Q_CHUNK = 1024
DEFAULT_KV_CHUNK = 1024


def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    q_chunk=None, kv_chunk=None):
    """Exact chunked attention.

    q: (B, Tq, KH, R, D); k, v: (B, Tk, KH, D) -> (B, Tq, KH, R, Dv).
    `q_offset`: absolute position of q[0] (prefill continuation / decode).
    """
    q_chunk = q_chunk or DEFAULT_Q_CHUNK
    kv_chunk = kv_chunk or DEFAULT_KV_CHUNK
    b, tq, kh, r, d = q.shape
    tk = k.shape[1]
    tq_orig, tk_orig = tq, tk
    scale = 1.0 / math.sqrt(d)
    q_chunk = min(q_chunk, tq)
    kv_chunk = min(kv_chunk, tk)
    if tq % q_chunk:  # pad to chunk multiples (vision prefixes etc.)
        pad = q_chunk - tq % q_chunk
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        tq += pad
    if tk % kv_chunk:
        pad = kv_chunk - tk % kv_chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        tk += pad
    nq = tq // q_chunk
    nk = tk // kv_chunk

    # initial carries must inherit the inputs' varying-axes tags so the
    # scan typechecks inside shard_map (sequence-parallel attention path)
    veil = (q.reshape(-1)[0] * 0 + k.reshape(-1)[0] * 0).astype(jnp.float32)

    def one_q_chunk(i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, axis=1)
        q_pos = q_offset + i * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, j):
            kj = jax.lax.dynamic_slice_in_dim(k, j * kv_chunk, kv_chunk, axis=1)
            vj = jax.lax.dynamic_slice_in_dim(v, j * kv_chunk, kv_chunk, axis=1)
            k_pos = j * kv_chunk + jnp.arange(kv_chunk)
            new = _attn_chunk(qi, kj, vj, q_pos, k_pos, causal, window, scale,
                              kv_len=tk_orig)
            return _merge(carry, new), None

        m0 = jnp.full((b, kh, r, q_chunk), -jnp.inf, jnp.float32) + veil
        l0 = jnp.zeros((b, kh, r, q_chunk), jnp.float32) + veil
        a0 = jnp.zeros((b, q_chunk, kh, r, v.shape[-1]), jnp.float32) + veil
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk),
                                      unroll=calibrate.UNROLL)
        out = acc / jnp.maximum(_blh(l)[..., 0], 1e-30)[..., None]
        return out.astype(q.dtype), None

    _, (outs, _) = jax.lax.scan(lambda c, i: (c, one_q_chunk(i)),
                                None, jnp.arange(nq),
                                unroll=calibrate.UNROLL)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, tq, kh, r, v.shape[-1])
    return out[:, :tq_orig]


def banded_attention(q, k, v, *, window: int, causal=True):
    """Sliding-window attention touching only in-window KV: O(T*window).

    Chunks q by `window`; chunk i attends to kv chunks {i-1, i} only.
    q: (B, T, KH, R, D); k, v: (B, T, KH, D).
    """
    b, t, kh, r, d = q.shape
    w = window
    t_orig = t
    if t % w:  # pad to a window multiple; causal mask hides the padding
        pad = w - t % w
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        t = t + pad
    nc = t // w
    scale = 1.0 / math.sqrt(d)
    kc = k.reshape(b, nc, w, kh, d)
    vc = v.reshape(b, nc, w, kh, v.shape[-1])
    # previous chunk (zeros before chunk 0)
    kp = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    vp = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    kwin = jnp.concatenate([kp, kc], axis=2)              # (B, nc, 2w, KH, D)
    vwin = jnp.concatenate([vp, vc], axis=2)
    qc = q.reshape(b, nc, w, kh, r, d)
    s = jnp.einsum("bnqhrd,bnkhd->bnhrqk", qc, kwin,
                   preferred_element_type=jnp.float32) * scale
    q_pos = jnp.arange(w)[:, None] + w                    # within 2w frame
    k_pos = jnp.arange(2 * w)[None, :]
    mask = (k_pos <= q_pos) if causal else jnp.ones((w, 2 * w), bool)
    mask &= k_pos > q_pos - w
    first = jnp.arange(2 * w)[None, :] >= w               # chunk 0: no prev
    mask_first = mask & first
    full_mask = jnp.where(jnp.arange(nc)[:, None, None] == 0,
                          mask_first[None], mask[None])
    s = jnp.where(full_mask[None, :, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bnhrqk,bnkhd->bnqhrd", p.astype(vwin.dtype), vwin,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, t, kh, r, v.shape[-1]).astype(q.dtype)
    return o[:, :t_orig]


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0,
                     ctx: ShardCtx = LOCAL):
    """Single-token attention against a KV cache, optionally seq-sharded.

    q: (B, 1, KH, R, D); caches (B, S, KH, D) - S is the *local* shard
    length when ctx.enabled (cache sharded over model axis along S).
    cache_len: () int32 - global number of valid cache positions.
    """
    scale = 1.0 / math.sqrt(q.shape[-1])

    def local(q_, k_, v_, shard_idx):
        s_loc = k_.shape[1]
        pos = shard_idx * s_loc + jnp.arange(s_loc)
        valid = pos < cache_len
        if window:
            valid &= pos >= cache_len - window
        s = jnp.einsum("bqhrd,bkhd->bhrqk", q_, k_,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid[None, None, None, None, :], s, -jnp.inf)
        m = jnp.max(s, axis=-1)
        m_safe = jnp.where(jnp.isfinite(m), m, -1e30)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(valid[None, None, None, None, :], p, 0.0)
        l = jnp.sum(p, axis=-1)
        acc = jnp.einsum("bhrqk,bkhd->bqhrd", p.astype(v_.dtype), v_,
                         preferred_element_type=jnp.float32)
        return m_safe, l, acc

    if not ctx.enabled:
        m, l, acc = local(q, k_cache, v_cache, jnp.int32(0))
        out = acc / jnp.maximum(_blh(l)[..., 0], 1e-30)[..., None]
        return out.astype(q.dtype)

    def sharded(q_, k_, v_):
        idx = jax.lax.axis_index(ctx.model_axis)
        m, l, acc = local(q_, k_, v_, idx)
        # distributed LSE combine across sequence shards
        m_g = jax.lax.pmax(m, ctx.model_axis)
        w = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * w, ctx.model_axis)
        acc_g = jax.lax.psum(acc * _blh(w), ctx.model_axis)
        out = acc_g / jnp.maximum(_blh(l_g)[..., 0], 1e-30)[..., None]
        return out.astype(q_.dtype)

    bspec = _bspec_for(ctx, q.shape[0])
    return compat.shard_map(
        sharded,
        in_specs=(P(bspec, None, None, None, None),
                  P(bspec, ctx.model_axis, None, None),
                  P(bspec, ctx.model_axis, None, None)),
        out_specs=P(bspec, None, None, None, None),
    )(q, k_cache, v_cache)


# ---------------------------------------------------------------------------
# GQA attention layer (projections + rope + cache handling)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 6)
    d, h, kh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pdt = _pdtype(cfg)
    p = {
        "wq": _dense_init(keys[0], (d, h * dh), pdt),
        "wk": _dense_init(keys[1], (d, kh * dh), pdt),
        "wv": _dense_init(keys[2], (d, kh * dh), pdt),
        "wo": _dense_init(keys[3], (h * dh, d), pdt),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(dh, cfg)
        p["k_norm"] = init_rmsnorm(dh, cfg)
    return p


def attention_apply(p, x, cfg: ModelConfig, *, is_local: bool,
                    positions=None, cache=None, cache_len=None,
                    ctx: ShardCtx = LOCAL, causal=True):
    """x (B, T, d) -> (B, T, d).  cache: dict(k, v) updated functionally."""
    b, t, _ = x.shape
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    rep = h // kh
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(b, t, kh, rep, dh)
    k = (x @ p["wk"].astype(dt)).reshape(b, t, kh, dh)
    v = (x @ p["wv"].astype(dt)).reshape(b, t, kh, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if positions is None:
        positions = jnp.arange(t)[None, :]
    theta = cfg.rope_theta_local if (is_local and cfg.rope_theta_local) \
        else cfg.rope_theta
    cos, sin = rope_tables(positions, dh, theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    window = cfg.sliding_window if is_local else 0
    new_cache = None
    if cache is not None and cache_len is not None:
        # decode: append k/v at cache_len, attend over the cache
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), cache_len, axis=1) \
            if not ctx.enabled else _sharded_cache_update(
                cache["k"], k, cache_len, ctx)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), cache_len, axis=1) \
            if not ctx.enabled else _sharded_cache_update(
                cache["v"], v, cache_len, ctx)
        new_cache = {"k": k_cache, "v": v_cache}
        o = decode_attention(q, k_cache, v_cache, cache_len + t,
                             window=window, ctx=ctx)
    elif cache is not None:
        # prefill: fill the cache, run full attention
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
        new_cache = {"k": k_cache, "v": v_cache}
        o = _prefill_attention(q, k, v, cfg, window, causal, ctx)
    else:
        o = _prefill_attention(q, k, v, cfg, window, causal, ctx)

    o = o.reshape(b, t, h * dh)
    out = o @ p["wo"].astype(dt)
    return (out, new_cache) if cache is not None else (out, None)


def _sharded_cache_update(cache, kv, cache_len, ctx: ShardCtx):
    """Write one token into a sequence-sharded cache at global cache_len."""
    def upd(c, kv_, ln):
        s_loc = c.shape[1]
        idx = jax.lax.axis_index(ctx.model_axis)
        local_pos = ln[0] - idx * s_loc
        in_range = (local_pos >= 0) & (local_pos < s_loc)
        pos = jnp.clip(local_pos, 0, s_loc - 1)
        cur = jax.lax.dynamic_slice_in_dim(c, pos, kv_.shape[1], axis=1)
        newv = jnp.where(in_range, kv_.astype(c.dtype), cur)
        return jax.lax.dynamic_update_slice_in_dim(c, newv, pos, axis=1)

    bspec = _bspec_for(ctx, cache.shape[0])
    return compat.shard_map(
        upd,
        in_specs=(P(bspec, ctx.model_axis, None, None),
                  P(bspec, None, None, None), P(None)),
        out_specs=P(bspec, ctx.model_axis, None, None),
    )(cache, kv, cache_len.reshape(1))


def _prefill_attention(q, k, v, cfg: ModelConfig, window, causal,
                       ctx: ShardCtx):
    if ctx.enabled and cfg.attn_shard == "heads":
        # head-TP: fold GQA reps into flat heads and shard H over `model`;
        # kv is computed replicated (kv_heads rarely divide the axis) and
        # the repeat materializes only the local H/model slice per shard.
        b, t, kh, rep, d = q.shape
        h = kh * rep
        qf = q.reshape(b, t, h, 1, d)
        kf = jnp.repeat(k, rep, axis=2) if rep > 1 else k
        vf = jnp.repeat(v, rep, axis=2) if rep > 1 else v
        bspec = ctx.batch_spec
        qf = jax.lax.with_sharding_constraint(
            qf, P(bspec, None, ctx.model_axis, None, None))
        kf = jax.lax.with_sharding_constraint(
            kf, P(bspec, None, ctx.model_axis, None))
        vf = jax.lax.with_sharding_constraint(
            vf, P(bspec, None, ctx.model_axis, None))
        if window:
            o = banded_attention(qf, kf, vf, window=window, causal=causal)
        else:
            o = flash_attention(qf, kf, vf, causal=causal)
        return o.reshape(b, t, kh, rep, o.shape[-1])
    if window:
        return banded_attention(q, k, v, window=window, causal=causal)
    if ctx.enabled and cfg.attn_shard == "sequence":
        # sequence-parallel attention: q sharded over T, KV all-gathered
        def sp(q_, k_, v_):
            idx = jax.lax.axis_index(ctx.model_axis)
            t_loc = q_.shape[1]
            kg = jax.lax.all_gather(k_, ctx.model_axis, axis=1, tiled=True)
            vg = jax.lax.all_gather(v_, ctx.model_axis, axis=1, tiled=True)
            return flash_attention(q_, kg, vg, causal=causal,
                                   q_offset=idx * t_loc)
        bspec = _bspec_for(ctx, q.shape[0])
        return compat.shard_map(
            sp,
            in_specs=(P(bspec, ctx.model_axis, None, None, None),
                      P(bspec, ctx.model_axis, None, None),
                      P(bspec, ctx.model_axis, None, None)),
            out_specs=P(bspec, ctx.model_axis, None, None, None),
        )(q, k, v)
    return flash_attention(q, k, v, causal=causal)


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2), with absorbed decode path
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig) -> Params:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    keys = jax.random.split(key, 8)
    pdt = _pdtype(cfg)
    p = {}
    if m.q_lora:
        p["wq_a"] = _dense_init(keys[0], (d, m.q_lora), pdt)
        p["q_norm"] = init_rmsnorm(m.q_lora, cfg)
        p["wq_b"] = _dense_init(keys[1], (m.q_lora, h * qk), pdt)
    else:
        p["wq"] = _dense_init(keys[0], (d, h * qk), pdt)
    p["wkv_a"] = _dense_init(keys[2], (d, m.kv_lora + m.qk_rope_dim), pdt)
    p["kv_norm"] = init_rmsnorm(m.kv_lora, cfg)
    p["wk_b"] = _dense_init(keys[3], (m.kv_lora, h * m.qk_nope_dim), pdt)
    p["wv_b"] = _dense_init(keys[4], (m.kv_lora, h * m.v_head_dim), pdt)
    p["wo"] = _dense_init(keys[5], (h * m.v_head_dim, d), pdt)
    return p


def mla_apply(p, x, cfg: ModelConfig, *, positions=None, cache=None,
              cache_len=None, ctx: ShardCtx = LOCAL):
    """MLA attention.  Cache stores the latent (c_kv, k_rope) only."""
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.n_heads
    dt = x.dtype
    if m.q_lora:
        q = rms_norm(x @ p["wq_a"].astype(dt), p["q_norm"], cfg.norm_eps)
        q = q @ p["wq_b"].astype(dt)
    else:
        q = x @ p["wq"].astype(dt)
    q = q.reshape(b, t, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]

    kv_a = x @ p["wkv_a"].astype(dt)
    c_kv = rms_norm(kv_a[..., :m.kv_lora], p["kv_norm"], cfg.norm_eps)
    k_rope = kv_a[..., m.kv_lora:]                       # (B, T, rope_dim)

    if positions is None:
        positions = jnp.arange(t)[None, :]
    cos, sin = rope_tables(positions, m.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]

    new_cache = None
    if cache is not None and cache_len is not None:
        # --- absorbed decode: score in latent space ------------------------
        ckv_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], c_kv.astype(cache["ckv"].dtype), cache_len, axis=1)
        kr_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["kr"], k_rope.astype(cache["kr"].dtype), cache_len, axis=1)
        new_cache = {"ckv": ckv_cache, "kr": kr_cache}
        # absorb wk_b into q: q_eff (B,T,H,kv_lora)
        wk_b = p["wk_b"].astype(dt).reshape(m.kv_lora, h, m.qk_nope_dim)
        q_eff = jnp.einsum("bthd,lhd->bthl", q_nope, wk_b)
        scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
        o_lat = _mla_decode(q_eff, q_rope, ckv_cache, kr_cache,
                            cache_len + t, scale, ctx)    # (B,T,H,kv_lora)
        wv_b = p["wv_b"].astype(dt).reshape(m.kv_lora, h, m.v_head_dim)
        o = jnp.einsum("bthl,lhd->bthd", o_lat, wv_b)
    else:
        # --- train/prefill: materialize per-head k, v ----------------------
        k_nope = (c_kv @ p["wk_b"].astype(dt)).reshape(b, t, h, m.qk_nope_dim)
        val = (c_kv @ p["wv_b"].astype(dt)).reshape(b, t, h, m.v_head_dim)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (b, t, h, m.qk_rope_dim))], axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        # GQA layout with KH=H, rep=1
        o = flash_attention(q_full[:, :, :, None, :], k_full, val, causal=True)
        o = o.reshape(b, t, h, m.v_head_dim)
        if cache is not None:
            ckv_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["ckv"], c_kv.astype(cache["ckv"].dtype), 0, axis=1)
            kr_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["kr"], k_rope.astype(cache["kr"].dtype), 0, axis=1)
            new_cache = {"ckv": ckv_cache, "kr": kr_cache}

    out = o.reshape(b, t, h * m.v_head_dim) @ p["wo"].astype(dt)
    return (out, new_cache) if cache is not None else (out, None)


def _mla_decode(q_eff, q_rope, ckv, kr, cache_len, scale, ctx: ShardCtx):
    """Latent-space decode attention; caches may be seq-sharded."""

    def local(q_eff_, q_rope_, ckv_, kr_, shard_idx):
        s_loc = ckv_.shape[1]
        pos = shard_idx * s_loc + jnp.arange(s_loc)
        valid = pos < cache_len
        s = (jnp.einsum("bthl,bsl->bhts", q_eff_, ckv_,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bthr,bsr->bhts", q_rope_, kr_,
                          preferred_element_type=jnp.float32)) * scale
        s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
        msk = jnp.max(s, axis=-1)
        m_safe = jnp.where(jnp.isfinite(msk), msk, -1e30)
        pr = jnp.exp(s - m_safe[..., None])
        pr = jnp.where(valid[None, None, None, :], pr, 0.0)
        l = jnp.sum(pr, axis=-1)
        acc = jnp.einsum("bhts,bsl->bthl", pr.astype(ckv_.dtype), ckv_,
                         preferred_element_type=jnp.float32)
        return m_safe, l, acc

    if not ctx.enabled:
        m, l, acc = local(q_eff, q_rope, ckv, kr, jnp.int32(0))
        lt = jnp.transpose(l, (0, 2, 1))[..., None]
        return (acc / jnp.maximum(lt, 1e-30)).astype(q_eff.dtype)

    def sharded(q_eff_, q_rope_, ckv_, kr_):
        idx = jax.lax.axis_index(ctx.model_axis)
        m, l, acc = local(q_eff_, q_rope_, ckv_, kr_, idx)
        m_g = jax.lax.pmax(m, ctx.model_axis)
        w = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * w, ctx.model_axis)
        wt = jnp.transpose(w, (0, 2, 1))[..., None]
        acc_g = jax.lax.psum(acc * wt, ctx.model_axis)
        lt = jnp.transpose(l_g, (0, 2, 1))[..., None]
        return (acc_g / jnp.maximum(lt, 1e-30)).astype(q_eff_.dtype)

    bspec = _bspec_for(ctx, q_eff.shape[0])
    return compat.shard_map(
        sharded,
        in_specs=(P(bspec, None, None, None), P(bspec, None, None, None),
                  P(bspec, ctx.model_axis, None),
                  P(bspec, ctx.model_axis, None)),
        out_specs=P(bspec, None, None, None),
    )(q_eff, q_rope, ckv, kr)


# ---------------------------------------------------------------------------
# MLPs and MoE
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, d_ff: int, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    pdt = _pdtype(cfg)
    return {"w_gate": _dense_init(k1, (d, d_ff), pdt),
            "w_up": _dense_init(k2, (d, d_ff), pdt),
            "w_down": _dense_init(k3, (d_ff, d), pdt)}


def mlp_apply(p, x, cfg: ModelConfig):
    dt = x.dtype
    g = act_fn(cfg.act)(x @ p["w_gate"].astype(dt))
    u = x @ p["w_up"].astype(dt)
    return (g * u) @ p["w_down"].astype(dt)


def init_moe(key, cfg: ModelConfig) -> Params:
    mo = cfg.moe
    d = cfg.d_model
    keys = jax.random.split(key, 5)
    pdt = _pdtype(cfg)
    e = mo.num_experts
    p = {"router": _dense_init(keys[0], (d, e), pdt, scale=0.02)}
    for name, k_, shape in (("w_gate", keys[1], (e, d, mo.d_expert)),
                            ("w_up", keys[2], (e, d, mo.d_expert)),
                            ("w_down", keys[3], (e, mo.d_expert, d))):
        w = _dense_init(k_, shape, jnp.float32)
        if mo.quant_int8:
            # weight-only int8 with per-(expert, out-channel) scales
            scale = jnp.max(jnp.abs(w), axis=1, keepdims=True) / 127.0
            p[name] = jnp.clip(jnp.round(w / jnp.maximum(scale, 1e-12)),
                               -127, 127).astype(jnp.int8)
            p[name + "_scale"] = scale.astype(jnp.float32)
        else:
            p[name] = w.astype(pdt)
    if mo.num_shared:
        p["shared"] = init_mlp(keys[4], d, mo.d_expert * mo.num_shared, cfg)
    return p


def _moe_weight(p, name, dt):
    if name + "_scale" in p:
        return (p[name].astype(dt)
                * p[name + "_scale"].astype(dt))   # dequant on the fly
    return p[name].astype(dt)


def _expert_ffn(xe, wg, wu, wd, act):
    """(E, C, d) through per-expert SwiGLU FFNs."""
    dt = xe.dtype
    g = act(jnp.einsum("ecd,edf->ecf", xe, wg,
                       preferred_element_type=jnp.float32).astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xe, wu,
                   preferred_element_type=jnp.float32).astype(dt)
    return jnp.einsum("ecf,efd->ecd", g * u, wd,
                      preferred_element_type=jnp.float32).astype(dt)


def moe_apply(p, x, cfg: ModelConfig, ctx: ShardCtx = LOCAL):
    """Event-routed MoE layer.  Returns (y, aux_metrics).

    Distributed path: the whole layer runs under shard_map - tokens stay on
    their data shard (routing is per-shard, the AER semantics: each core
    arbitrates its own events), experts are EP-sharded over the model axis,
    and expert outputs combine with one psum (same volume as a TP FFN).
    """
    mo = cfg.moe
    b, t, d = x.shape
    dt = x.dtype
    act = act_fn(cfg.act)

    def local_moe(xf, router_w, ws, shard_idx, e_loc):
        tokens = xf.shape[0]
        capacity = max(8, int(mo.capacity_factor * mo.top_k * tokens
                              / mo.num_experts))
        logits = xf @ router_w
        route = event_router.hat_route(logits, mo.top_k, capacity,
                                       num_experts=mo.num_experts)
        first = shard_idx * e_loc
        # local slice of the (global) buffer: experts [first, first+e_loc)
        buf = jax.lax.dynamic_slice_in_dim(route.buffer_rows, first, e_loc, 0)
        safe = jnp.maximum(buf, 0)
        xe = jnp.where((buf >= 0)[..., None], xf[safe], 0.0)
        # dequantize (if int8) AFTER any resharding so wires carry int8
        wg = _moe_weight(ws, "w_gate", dt)
        wu = _moe_weight(ws, "w_up", dt)
        wd = _moe_weight(ws, "w_down", dt)
        ye = _expert_ffn(xe, wg, wu, wd, act)             # (E_loc, C, d)
        mine = ((route.expert_ids >= first)
                & (route.expert_ids < first + e_loc) & route.kept)
        ev = ye[jnp.clip(route.expert_ids - first, 0, e_loc - 1),
                jnp.maximum(route.event_slot, 0)]         # (T, k, d)
        wgt = (route.weights * mine.astype(route.weights.dtype)).astype(ev.dtype)
        y = jnp.einsum("tkd,tk->td", ev, wgt)
        return y, route.aux_loss, route.z_loss

    xf = x.reshape(b * t, d)
    ws = {k_: v_ for k_, v_ in p.items()
          if k_.startswith(("w_gate", "w_up", "w_down"))}
    if ctx.enabled:
        e_loc = mo.num_experts // ctx.model_size
        # tokens shard over data axes only when they divide; tiny decode
        # batches (long_500k: 1 token) replicate instead
        bspec = _bspec_for(ctx, b * t)

        def body(xf_, router_w, ws_):
            idx = jax.lax.axis_index(ctx.model_axis)
            y, aux, z = local_moe(xf_, router_w, ws_, idx, e_loc)
            y = jax.lax.psum(y, ctx.model_axis)
            # aux losses: identical on every model shard; mean over data
            if bspec is not None:
                aux = jax.lax.pmean(aux, ctx.data_axes)
                z = jax.lax.pmean(z, ctx.data_axes)
            return y, aux, z

        w_specs = {k_: P(ctx.model_axis, *([None] * (v_.ndim - 1)))
                   for k_, v_ in ws.items()}
        y, aux_l, z_l = compat.shard_map(
            body,
            in_specs=(P(bspec, None), P(None, None), w_specs),
            out_specs=(P(bspec, None), P(), P()),
        )(xf, p["router"].astype(dt), ws)
    else:
        y, aux_l, z_l = local_moe(xf, p["router"].astype(dt), ws,
                                  jnp.int32(0), mo.num_experts)

    if mo.num_shared:
        y = y + mlp_apply(p["shared"], xf, cfg)
    aux = {"moe_aux": aux_l * mo.aux_loss_weight,
           "moe_z": z_l * mo.z_loss_weight}
    return y.reshape(b, t, d), aux
