"""Model zoo: unified LM builder + family-specific layers + multicore SNN."""
