"""Unified language model: builds any assigned architecture from ModelConfig.

Structure: embed/frontend -> scanned homogeneous layer groups -> final norm
-> lm head.  Per-layer kinds (attn / mamba / rwkv), MoE-vs-dense MLP,
local-vs-global attention and sandwich norms all resolve statically from
the config's layer pattern, so each scan group has a fixed body.

Layer parameters are stacked over the group's repeat count and scanned
with lax.scan (+ optional jax.checkpoint), keeping compile time and HLO
size independent of depth.  KV/SSM caches mirror the same stacking.

Modes:
  train   - causal (or bidirectional for encoders), no cache, logits
  prefill - causal forward that also fills the decode cache
  decode  - single-token step against the cache (cache_len scalar)
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import blocks, mamba, rwkv6
from repro.models.blocks import LOCAL, ShardCtx
from repro.models.config import ModelConfig

Params = dict


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, idx: int) -> Params:
    kind = cfg.layer_kind(idx)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {"ln1": blocks.init_rmsnorm(cfg.d_model, cfg)}
    if kind == "attn":
        p["mix"] = (blocks.init_mla(k1, cfg) if cfg.mla is not None
                    else blocks.init_attention(k1, cfg))
    elif kind == "mamba":
        p["mix"] = mamba.init_mamba(k1, cfg)
    else:  # rwkv
        p["mix"] = rwkv6.init_time_mix(k1, cfg)
    p["ln2"] = blocks.init_rmsnorm(cfg.d_model, cfg)
    if kind == "rwkv":
        p["ffn"] = rwkv6.init_channel_mix(k2, cfg)
    elif cfg.layer_is_moe(idx):
        p["ffn"] = blocks.init_moe(k2, cfg)
    else:
        d_ff = cfg.d_ff
        if cfg.moe is not None and idx < cfg.moe.first_k_dense:
            d_ff = cfg.moe.d_ff_dense or cfg.d_ff
        p["ffn"] = blocks.init_mlp(k3, cfg.d_model, d_ff, cfg)
    if cfg.post_norms:
        p["post_ln1"] = blocks.init_rmsnorm(cfg.d_model, cfg)
        p["post_ln2"] = blocks.init_rmsnorm(cfg.d_model, cfg)
    return p


def init_model(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 8)
    pdt = jnp.dtype(cfg.param_dtype)
    p: Params = {}
    if cfg.frontend.kind == "none":
        p["embed"] = (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model))
                      * 0.02).astype(pdt)
    else:
        p["embed"] = (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model))
                      * 0.02).astype(pdt)
        p["frontend_proj"] = blocks._dense_init(
            keys[1], (cfg.frontend.d_in, cfg.d_model), pdt)
        if cfg.frontend.kind == "audio":
            p["mask_embed"] = (jax.random.normal(keys[2], (cfg.d_model,))
                               * 0.02).astype(pdt)
    # scanned layer groups: params stacked over repeats of each group body
    p["groups"] = []
    for start, length in cfg.scan_groups():
        g = cfg.scan_group
        n_rep = length // g
        body = []
        for pos in range(g):
            layer_keys = jnp.stack([
                jax.random.fold_in(keys[3], start + r * g + pos)
                for r in range(n_rep)])
            stacked = jax.vmap(
                lambda k, i=start + pos: _init_layer(k, cfg, i))(layer_keys)
            body.append(stacked)
        p["groups"].append(body)
    p["final_norm"] = blocks.init_rmsnorm(cfg.d_model, cfg)
    if not cfg.tie_embeddings:
        p["lm_head"] = blocks._dense_init(keys[4], (cfg.d_model, cfg.vocab),
                                          pdt)
    return p


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def _layer_cache_shape(cfg: ModelConfig, idx: int, b: int, s: int):
    kind = cfg.layer_kind(idx)
    cdt = jnp.dtype(cfg.compute_dtype)
    if kind == "attn":
        if cfg.mla is not None:
            m = cfg.mla
            return {"ckv": jnp.zeros((b, s, m.kv_lora), cdt),
                    "kr": jnp.zeros((b, s, m.qk_rope_dim), cdt)}
        return {"k": jnp.zeros((b, s, cfg.n_kv_heads, cfg.head_dim), cdt),
                "v": jnp.zeros((b, s, cfg.n_kv_heads, cfg.head_dim), cdt)}
    if kind == "mamba":
        di = mamba.d_inner(cfg)
        return {"conv": jnp.zeros((b, cfg.mamba.d_conv - 1, di), cdt),
                "ssm": jnp.zeros((b, di, cfg.mamba.d_state), jnp.float32)}
    return {"prev_x_tm": jnp.zeros((b, 1, cfg.d_model), cdt),
            "prev_x_cm": jnp.zeros((b, 1, cfg.d_model), cdt),
            "wkv": jnp.zeros((b, cfg.d_model // cfg.rwkv.head_dim,
                              cfg.rwkv.head_dim, cfg.rwkv.head_dim),
                             jnp.float32)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked cache pytree matching the scanned group structure."""
    groups = []
    for start, length in cfg.scan_groups():
        g = cfg.scan_group
        n_rep = length // g
        body = []
        for pos in range(g):
            one = _layer_cache_shape(cfg, start + pos, batch, max_len)
            body.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (n_rep,) + x.shape).copy()
                if n_rep > 1 else x[None], one))
        groups.append(body)
    return groups


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _apply_layer(lp, x, cfg: ModelConfig, idx: int, *, mode: str,
                 cache, cache_len, positions, ctx: ShardCtx):
    kind = cfg.layer_kind(idx)
    is_local = cfg.layer_is_local(idx)
    aux = {}
    h = blocks.rms_norm(x, lp["ln1"], cfg.norm_eps)
    new_cache = cache
    if kind == "attn":
        if cfg.mla is not None:
            o, new_cache = blocks.mla_apply(
                lp["mix"], h, cfg, positions=positions, cache=cache,
                cache_len=cache_len, ctx=ctx)
        else:
            o, new_cache = blocks.attention_apply(
                lp["mix"], h, cfg, is_local=is_local, positions=positions,
                cache=cache, cache_len=cache_len, ctx=ctx,
                causal=not cfg.encoder_only)
    elif kind == "mamba":
        o, new_cache = mamba.mamba_apply(lp["mix"], h, cfg, state=cache)
    else:
        o, new_cache = rwkv6.time_mix_apply(lp["mix"], h, cfg, state=cache,
                                            chunked=(mode != "decode"),
                                            ctx=ctx)
    if cfg.post_norms:
        o = blocks.rms_norm(o, lp["post_ln1"], cfg.norm_eps)
    x = x + o
    h = blocks.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if kind == "rwkv":
        o, new_cache2 = rwkv6.channel_mix_apply(lp["ffn"], h, cfg,
                                                state=new_cache)
        new_cache = new_cache2 if new_cache2 is not None else new_cache
    elif cfg.layer_is_moe(idx):
        o, aux = blocks.moe_apply(lp["ffn"], h, cfg, ctx=ctx)
    else:
        o = blocks.mlp_apply(lp["ffn"], h, cfg)
    if cfg.post_norms:
        o = blocks.rms_norm(o, lp["post_ln2"], cfg.norm_eps)
    x = x + o
    return x, new_cache, aux


def _embed(params, batch, cfg: ModelConfig):
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.frontend.kind == "audio":
        x = batch["frames"].astype(cdt) @ params["frontend_proj"].astype(cdt)
        if "mask" in batch:
            x = jnp.where(batch["mask"][..., None],
                          params["mask_embed"].astype(cdt)[None, None], x)
        return x
    tok = params["embed"][batch["tokens"]].astype(cdt)
    if cfg.frontend.kind == "vision" and "image_embeds" in batch:
        img = (batch["image_embeds"].astype(cdt)
               @ params["frontend_proj"].astype(cdt))
        return jnp.concatenate([img, tok], axis=1)
    if cfg.family != "rwkv":
        x = tok * jnp.sqrt(jnp.float32(cfg.d_model)).astype(cdt)
        return x
    return tok


REMAT_POLICIES = {
    None: None,
    "none": None,
    "dots": "dots_with_no_batch_dims_saveable",
    "dots_batch": "dots_saveable",
    "everything": "everything_saveable",
}


def forward(params, batch, cfg: ModelConfig, *, mode: str = "train",
            cache=None, cache_len=None, ctx: ShardCtx = LOCAL,
            remat: bool = True, remat_policy: str | None = None):
    """Returns dict(logits, aux, cache)."""
    x = _embed(params, batch, cfg)
    b, t, _ = x.shape
    if mode == "decode":
        positions = cache_len + jnp.arange(t)[None, :]
    else:
        positions = jnp.arange(t)[None, :]

    if ctx.enabled:
        x = _constrain_acts(x, cfg, ctx)

    aux_total: dict = {}
    new_cache_groups = [] if cache is not None else None
    layer_idx = 0
    for gi, (start, length) in enumerate(cfg.scan_groups()):
        g = cfg.scan_group
        n_rep = length // g
        body_params = params["groups"][gi]
        body_cache = cache[gi] if cache is not None else [None] * g

        def group_body(x_, stacked, gi=gi, start=start):
            """One repeat of the group: applies g layers (pos 0..g-1)."""
            lps, caches = stacked
            aux_acc = {}
            new_caches = []
            for pos in range(g):
                lp = lps[pos]
                c = caches[pos] if caches is not None else None
                x_, nc, aux = _apply_layer(
                    lp, x_, cfg, start + pos, mode=mode, cache=c,
                    cache_len=cache_len, positions=positions, ctx=ctx)
                new_caches.append(nc)
                for k_, v_ in aux.items():
                    aux_acc[k_] = aux_acc.get(k_, 0.0) + v_
            if ctx.enabled:
                x_ = _constrain_acts(x_, cfg, ctx)
            return x_, new_caches, aux_acc

        if remat:
            pol_name = REMAT_POLICIES.get(remat_policy, remat_policy)
            policy = (getattr(jax.checkpoint_policies, pol_name)
                      if pol_name else None)
            group_body = jax.checkpoint(group_body, policy=policy)

        if n_rep == 1:
            lps = [jax.tree.map(lambda a: a[0], bp) for bp in body_params]
            cs = ([jax.tree.map(lambda a: a[0], bc) for bc in body_cache]
                  if cache is not None else None)
            x, ncs, aux = group_body(x, (lps, cs))
            if cache is not None:
                new_cache_groups.append(
                    [jax.tree.map(lambda a: a[None], nc) for nc in ncs])
            for k_, v_ in aux.items():
                aux_total[k_] = aux_total.get(k_, 0.0) + v_
        else:
            def scan_step(carry, stacked):
                x_, aux_c = carry
                x_, ncs, aux = group_body(x_, stacked)
                aux_c = {k_: aux_c.get(k_, 0.0) + v_ for k_, v_ in aux.items()} \
                    if aux else aux_c
                return (x_, aux_c), ncs

            aux0 = {"moe_aux": jnp.float32(0.0), "moe_z": jnp.float32(0.0)} \
                if any(cfg.layer_is_moe(i) for i in range(start, start + length)) \
                else {}
            (x, aux), ncs = jax.lax.scan(
                scan_step, (x, aux0),
                (body_params, body_cache if cache is not None else None))
            if cache is not None:
                new_cache_groups.append(ncs)
            for k_, v_ in aux.items():
                aux_total[k_] = aux_total.get(k_, 0.0) + v_
        layer_idx += length

    x = blocks.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(x.dtype)
    logits = x @ head
    if ctx.enabled:
        bspec = blocks._bspec_for(ctx, logits.shape[0])
        vspec = ctx.model_axis if cfg.vocab % ctx.model_size == 0 else None
        logits = jax.lax.with_sharding_constraint(
            logits, P(bspec, None, vspec))
    return {"logits": logits, "aux": aux_total,
            "cache": new_cache_groups}


def _constrain_acts(x, cfg: ModelConfig, ctx: ShardCtx):
    bspec = blocks._bspec_for(ctx, x.shape[0])
    if (cfg.attn_shard == "sequence" and x.shape[1] > 1
            and x.shape[1] % ctx.model_size == 0):
        return jax.lax.with_sharding_constraint(
            x, P(bspec, ctx.model_axis, None))
    return jax.lax.with_sharding_constraint(x, P(bspec, None, None))
