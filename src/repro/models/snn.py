"""Multi-core spiking neural network on the simulated core-interface fabric.

The paper's target workload: LIF neuron cores exchanging spikes through
the core interface (HAT arbiter out, CAM routing LUT in).  This model
trains with surrogate gradients; the synaptic routing used in the
training fast-path is the dense-matrix equivalent of the CAM fan-out
(bit-exact with the `repro.interface` tick, tested), while `account=True`
runs the full behavioural interface models through a precompiled
`InterfaceSession` to report latency/energy per timestep.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import fabric as fabric_mod
from repro.interface import session as interface_session
from repro.kernels.lif_step import ops as lif_ops


@dataclasses.dataclass(frozen=True)
class SNNConfig:
    fabric: fabric_mod.FabricConfig
    d_in: int = 64
    d_out: int = 10
    t_steps: int = 16
    decay: float = 0.9
    threshold: float = 1.0
    input_rate: float = 0.3

    @property
    def n_total(self) -> int:
        return self.fabric.cores * self.fabric.neurons_per_core


@jax.custom_jvp
def spike_fn(v):
    """Heaviside spike with sigmoid surrogate gradient."""
    return (v >= 0.0).astype(v.dtype)


@spike_fn.defjvp
def _spike_jvp(primals, tangents):
    (v,), (dv,) = primals, tangents
    y = spike_fn(v)
    sg = 4.0 * jax.nn.sigmoid(4.0 * v) * (1.0 - jax.nn.sigmoid(4.0 * v))
    return y, sg * dv


def init_snn(key, cfg: SNNConfig):
    """Returns (params, topology).

    params: float pytree (differentiable) - input/readout/synapse weights.
    topology: static int/bool routing structure (CAM tags, targets, valid).
    """
    k1, k2, k3 = jax.random.split(key, 3)
    n = cfg.n_total
    fab = fabric_mod.random_connectivity(k2, cfg.fabric)
    params = {
        "w_in": jax.random.normal(k1, (cfg.d_in, n)) / jnp.sqrt(cfg.d_in),
        "syn_w": fab.weights,
        "w_out": jax.random.normal(k3, (n, cfg.d_out)) / jnp.sqrt(n),
    }
    topology = {"tags": fab.tags, "valid": fab.valid, "targets": fab.targets}
    return params, topology


def fabric_params(params, topology) -> fabric_mod.FabricParams:
    return fabric_mod.FabricParams(tags=topology["tags"],
                                   valid=topology["valid"],
                                   weights=params["syn_w"],
                                   targets=topology["targets"])


def routing_matrix(fp: fabric_mod.FabricParams, cfg: fabric_mod.FabricConfig):
    """Dense (N_total, N_total) equivalent of the CAM fan-out routing."""
    cores, entries = fp.valid.shape
    n = cfg.neurons_per_core
    total = cores * n
    src_global = jnp.arange(total)
    src_bits = fabric_mod.int_to_bits(src_global, cfg.tag_bits)  # (N, bits)
    r = jnp.zeros((total, total), jnp.float32)

    def core_rows(tags_c, valid_c, weights_c, targets_c, c_idx):
        # match[entry, src] = entry subscribed to src
        eq = jnp.all(tags_c[:, None, :] == src_bits[None, :, :], axis=-1)
        hit = eq & valid_c[:, None]
        w = jnp.where(hit, weights_c[:, None], 0.0)      # (entries, N)
        tgt = jnp.zeros((n, total), jnp.float32).at[targets_c].add(w)
        return tgt                                        # (n, N_src)

    rows = jax.vmap(core_rows)(fp.tags, fp.valid, fp.weights, fp.targets,
                               jnp.arange(cores))
    return rows.reshape(total, total).T                   # (src, tgt)


def snn_forward(params, topology, x_seq, cfg: SNNConfig, *, impl: str = "xla",
                account: bool = False):
    """x_seq (B, T, d_in) spike/rate inputs -> logits (B, d_out).

    Returns (logits, rates, stats|None).
    """
    b = x_seq.shape[0]
    n = cfg.n_total
    fab = fabric_params(params, topology)
    r_mat = routing_matrix(fab, cfg.fabric)

    def step(carry, x_t):
        v, s_prev = carry
        current = x_t @ params["w_in"] + s_prev @ r_mat
        if impl == "xla":
            # differentiable path: surrogate-gradient spike + reset
            v_pre = v * cfg.decay + current
            s = spike_fn(v_pre - cfg.threshold)
            v_next = v_pre * (1.0 - s)                    # reset to 0
        else:
            # fused kernel path (inference): bit-identical forward values
            v_next, s = lif_ops.lif_step(v, current, decay=cfg.decay,
                                         threshold=cfg.threshold, impl=impl,
                                         interpret=True)
        return (v_next, s), s

    v0 = jnp.zeros((b, n), x_seq.dtype)
    s0 = jnp.zeros((b, n), x_seq.dtype)
    (_, _), spikes = jax.lax.scan(step, (v0, s0), jnp.moveaxis(x_seq, 1, 0))
    spikes = jnp.moveaxis(spikes, 0, 1)                   # (B, T, N)
    rates = jnp.mean(spikes, axis=1)
    logits = rates @ params["w_out"]

    stats = None
    if account:
        sp = spikes.reshape(b * cfg.t_steps, cfg.fabric.cores,
                            cfg.fabric.neurons_per_core) > 0.5
        # compile-once session: arbiter plan + NoC tables built a single
        # time, then every accounted tick runs under one lax.scan
        sess = interface_session.Interface(cfg.fabric).compile(fab)
        _, acc = sess.run(sp)
        stats = acc.mean(b * cfg.t_steps)
    return logits, rates, stats


def snn_loss(params, topology, batch, cfg: SNNConfig, *, impl: str = "xla"):
    logits, rates, _ = snn_forward(params, topology, batch["x"], cfg,
                                   impl=impl)
    labels = batch["y"]
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
    # mild rate regularization keeps events sparse (the paper's regime)
    loss = loss + 0.01 * jnp.mean(jnp.square(rates))
    return loss
