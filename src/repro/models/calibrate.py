"""Calibration switch: unroll inner scans so HLO cost analysis is exact.

HloCostAnalysis visits `while` bodies once.  During the dry-run's cost
calibration we lower with UNROLL=True: every chunked inner loop (flash
attention tiles, WKV chunks, SSM chunks) runs the SAME algorithm with the
SAME tile sizes, but as straight-line HLO - so flops / bytes / collective
counts are exact.  Production lowering keeps rolled loops (small HLO).
"""

UNROLL = False


def unroll_flag() -> bool:
    return UNROLL
