"""RWKV6 "Finch" layer: data-dependent-decay time mix + channel mix.

Faithful to arXiv:2404.05892 at the block level: ddlerp token-shift with a
low-rank MLP producing the five mix coefficients, a per-channel
data-dependent decay w_t = exp(-exp(d_t)) from a LoRA head, bonus term u,
per-head GroupNorm, silu output gate, and a relu^2 channel mix.

Two equivalent WKV evaluators:

  * `wkv_recurrent` - lax.scan over tokens (decode path + test oracle);
  * `wkv_chunked`   - chunked parallel form (training path): within a
    chunk the decay kernel is factored as
        A[t, j] = sum_i r_t[i] * k_j[i] * exp(lw[t-1, i] - lw[j, i]) ,
    evaluated with the bounded factorization  (r .* exp(lw - lw_max)) @
    (k .* exp(lw_chunk_end-ish...)); we keep chunks short (16) and clamp
    exp(decay) <= 4 so all factored exponents stay inside f32 range (see
    DESIGN.md §7 - a TPU-numerics adaptation, negligible semantically).

State is f32; activations bf16 outside the WKV core.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import calibrate
from repro.models.config import ModelConfig
from repro.models.blocks import _dense_init, _pdtype, rms_norm

CHUNK = 16
DECAY_CLAMP = 4.0  # exp(decay_logit) clamp; w >= exp(-4) per step


def init_time_mix(key, cfg: ModelConfig):
    d = cfg.d_model
    r = cfg.rwkv
    h = d // r.head_dim
    ks = jax.random.split(key, 12)
    pdt = _pdtype(cfg)
    u = 0.5 * (jnp.arange(d) % r.head_dim) / r.head_dim
    return {
        "maa_base": jnp.zeros((5, d), pdt),
        "maa_x": jnp.zeros((d,), pdt),
        "maa_w1": _dense_init(ks[0], (d, 5 * r.lora_mix), pdt, scale=1e-3),
        "maa_w2": (_dense_init(ks[1], (5, r.lora_mix, d), pdt, scale=1e-3)),
        "decay_base": jnp.full((d,), -1.0, pdt),
        "decay_w1": _dense_init(ks[2], (d, r.lora_decay), pdt, scale=1e-3),
        "decay_w2": _dense_init(ks[3], (r.lora_decay, d), pdt, scale=1e-3),
        "bonus": u.astype(pdt).reshape(h, r.head_dim),
        "wr": _dense_init(ks[4], (d, d), pdt),
        "wk": _dense_init(ks[5], (d, d), pdt),
        "wv": _dense_init(ks[6], (d, d), pdt),
        "wg": _dense_init(ks[7], (d, d), pdt),
        "wo": _dense_init(ks[8], (d, d), pdt),
        "ln_x": {"scale": jnp.zeros((d,), pdt)},
    }


def init_channel_mix(key, cfg: ModelConfig):
    d, dff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    pdt = _pdtype(cfg)
    return {
        "maa_k": jnp.zeros((d,), pdt),
        "maa_r": jnp.zeros((d,), pdt),
        "wk": _dense_init(ks[0], (d, dff), pdt),
        "wv": _dense_init(ks[1], (dff, d), pdt),
        "wr": _dense_init(ks[2], (d, d), pdt),
    }


def _token_shift(x, prev):
    """x (B,T,d), prev (B,1,d) -> previous-token stream."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _ddlerp(p, x, xs):
    """Data-dependent lerp producing the five mixed streams (w,k,v,r,g)."""
    dt = x.dtype
    dx = xs - x
    xxx = x + dx * p["maa_x"].astype(dt)
    b, t, d = x.shape
    mixer = jnp.tanh(xxx @ p["maa_w1"].astype(dt))        # (B,T,5*rank)
    rank = mixer.shape[-1] // 5
    mixer = mixer.reshape(b, t, 5, rank)
    offs = jnp.einsum("btfr,frd->btfd", mixer, p["maa_w2"].astype(dt))
    base = p["maa_base"].astype(dt)                        # (5, d)
    mixed = x[:, :, None, :] + dx[:, :, None, :] * (base + offs)
    return [mixed[:, :, i] for i in range(5)]              # w,k,v,r,g


def wkv_recurrent(r, k, v, w, u, state):
    """Token-by-token WKV.  r,k,v,w: (B,T,H,D) f32; u: (H,D); state (B,H,D,D).

    S[i,j] accumulates k[i]*v[j] with per-i decay; out[j] = sum_i r[i] *
    (S_prev[i,j] + u[i]*k[i]*v[j]).
    """
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                          # (B,H,D)
        kv = k_t[..., :, None] * v_t[..., None, :]        # (B,H,D,D)
        out = jnp.einsum("bhi,bhij->bhj", r_t, s + u[None] [..., None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, out

    (r_, k_, v_, w_) = [jnp.moveaxis(a, 1, 0) for a in (r, k, v, w)]
    state, outs = jax.lax.scan(step, state, (r_, k_, v_, w_))
    return jnp.moveaxis(outs, 0, 1), state                # (B,T,H,D)


def wkv_chunked(r, k, v, w, u, state, chunk: int = CHUNK):
    """Chunked parallel WKV, bit-compatible with wkv_recurrent (f32).

    Chunks of `chunk` tokens: intra-chunk via the bounded factored kernel,
    inter-chunk via the carried state.
    """
    b, t, h, d = r.shape
    if t % chunk:
        raise ValueError(f"T={t} must divide chunk={chunk}")
    nc = t // chunk
    re, ke, ve, we = [a.reshape(b, nc, chunk, h, d).transpose(1, 0, 3, 2, 4)
                      for a in (r, k, v, w)]              # (nc,B,H,C,D)
    lw = jnp.log(we)                                      # <= 0
    lw_cum = jnp.cumsum(lw, axis=-2)                      # inclusive within chunk

    tri_lower = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)

    def chunk_step(s, inp):
        rc, kc, vc, lwc, lw_cumc = inp
        # decay from chunk start to just before token t (exclusive of t)
        lw_before = lw_cumc - lwc                         # (B,H,C,D)
        # intra-chunk: A[t,j] = sum_i r[t,i] k[j,i] exp(lw_before[t]-lw_cum[j])
        # factored exponents stay in f32 range: lw_before in [-C*clamp, 0]
        # (so exp <= 1) and -lw_cum in [0, C*clamp] (exp <= e^64 ~ 6e27).
        r_dec = rc * jnp.exp(lw_before)
        k_dec = kc * jnp.exp(-lw_cumc)
        a = jnp.einsum("bhti,bhji->bhtj", r_dec, k_dec)
        a = jnp.where(tri_lower[None, None], a, 0.0)
        diag = jnp.einsum("bhti,bhti->bht", rc * u[None, :, None, :], kc)
        out = jnp.einsum("bhtj,bhjd->bhtd", a, vc)
        out += diag[..., None] * vc
        # cross-chunk: state contribution decayed to before token t
        out += jnp.einsum("bhti,bhid->bhtd", rc * jnp.exp(lw_before), s)
        # state update: decay full chunk + inject each k_j v_j decayed to end
        decay_all = jnp.exp(lw_cumc[..., -1, :])          # (B,H,D)
        k_tail = kc * jnp.exp(lw_cumc[..., -1:, :] - lw_cumc)
        s = decay_all[..., :, None] * s + jnp.einsum(
            "bhji,bhjd->bhid", k_tail, vc)
        return s, out

    state, outs = jax.lax.scan(chunk_step, state, (re, ke, ve, lw, lw_cum),
                               unroll=calibrate.UNROLL)
    # (nc, B, H, C, D) -> (B, T, H, D)
    return outs.transpose(1, 0, 3, 2, 4).reshape(b, t, h, d), state


def time_mix_apply(p, x, cfg: ModelConfig, state=None, chunked=True,
                   ctx=None):
    """x (B,T,d) -> (out, new_state).  state: dict(prev_x, wkv) or None.

    With cfg.rwkv_pad_heads = H' > H, the WKV runs on zero-padded heads
    sharded over the model axis (beyond-paper optimization: the faithful
    40-head config replicates WKV on every model shard; padding to 48
    shards it 16 ways at 20% pad overhead - DESIGN.md §7.5 / §Perf).
    """
    b, t, d = x.shape
    r_cfg = cfg.rwkv
    h = d // r_cfg.head_dim
    dt = x.dtype
    prev_x = state["prev_x_tm"] if state is not None else jnp.zeros(
        (b, 1, d), dt)
    xs = _token_shift(x, prev_x.astype(dt))
    xw, xk, xv, xr, xg = _ddlerp(p, x, xs)

    decay_logit = (p["decay_base"].astype(jnp.float32)
                   + jnp.tanh(xw.astype(jnp.float32)
                              @ p["decay_w1"].astype(jnp.float32))
                   @ p["decay_w2"].astype(jnp.float32))
    w = jnp.exp(-jnp.minimum(jnp.exp(decay_logit), DECAY_CLAMP))

    r = (xr @ p["wr"].astype(dt)).reshape(b, t, h, r_cfg.head_dim)
    k = (xk @ p["wk"].astype(dt)).reshape(b, t, h, r_cfg.head_dim)
    v = (xv @ p["wv"].astype(dt)).reshape(b, t, h, r_cfg.head_dim)
    g = jax.nn.silu(xg @ p["wg"].astype(dt))

    h_pad = max(cfg.rwkv_pad_heads, h)
    u = p["bonus"].astype(jnp.float32)
    w4 = w.reshape(b, t, h, r_cfg.head_dim)
    wkv_state = state["wkv"] if state is not None else jnp.zeros(
        (b, h, r_cfg.head_dim, r_cfg.head_dim), jnp.float32)
    if h_pad > h:
        pads = ((0, 0), (0, 0), (0, h_pad - h), (0, 0))
        r = jnp.pad(r, pads)
        k = jnp.pad(k, pads)
        v = jnp.pad(v, pads)
        w4 = jnp.pad(w4, pads, constant_values=1.0)  # decay 1 on pad heads
        u = jnp.pad(u, ((0, h_pad - h), (0, 0)))
        wkv_state = jnp.pad(wkv_state, ((0, 0), (0, h_pad - h), (0, 0),
                                        (0, 0)))
        if ctx is not None and ctx.enabled:
            from jax.sharding import PartitionSpec as P
            from repro.models.blocks import _bspec_for
            bspec = _bspec_for(ctx, b)
            spec = P(bspec, None, ctx.model_axis, None)
            r, k, v, w4 = (jax.lax.with_sharding_constraint(a, spec)
                           for a in (r, k, v, w4))

    args = (r.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), w4.astype(jnp.float32), u, wkv_state)
    if chunked and t % r_cfg.chunk == 0 and t > 1:
        o, new_wkv = wkv_chunked(*args, chunk=r_cfg.chunk)
    else:
        o, new_wkv = wkv_recurrent(*args)

    if h_pad > h:
        o = o[:, :, :h]
        new_wkv = new_wkv[:, :h]

    # per-head group norm (per-channel scale reshaped to heads)
    ln = {"scale": p["ln_x"]["scale"].reshape(h, r_cfg.head_dim)}
    o = rms_norm(o, ln, eps=1e-5 * 64)                    # (B,T,H,D) per head
    o = o.reshape(b, t, d).astype(dt) * g
    out = o @ p["wo"].astype(dt)
    new_state = None
    if state is not None:
        new_state = dict(state)
        new_state["prev_x_tm"] = x[:, -1:, :]
        new_state["wkv"] = new_wkv
    return out, new_state


def channel_mix_apply(p, x, cfg: ModelConfig, state=None):
    b, t, d = x.shape
    dt = x.dtype
    prev_x = state["prev_x_cm"] if state is not None else jnp.zeros(
        (b, 1, d), dt)
    xs = _token_shift(x, prev_x.astype(dt))
    dx = xs - x
    xk = x + dx * p["maa_k"].astype(dt)
    xr = x + dx * p["maa_r"].astype(dt)
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(dt)))
    out = jax.nn.sigmoid(xr @ p["wr"].astype(dt)) * (k @ p["wv"].astype(dt))
    new_state = None
    if state is not None:
        new_state = dict(state)
        new_state["prev_x_cm"] = x[:, -1:, :]
    return out, new_state
