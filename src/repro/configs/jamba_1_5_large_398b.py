"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536.  Mamba:attention 7:1 interleave (1 attn per
8-layer block), MoE 16 experts top-2 every other layer
(arXiv:2403.19887).  Runs long_500k (SSM-dominated; the 9 attention
layers use sequence-sharded KV decode).  bf16 params + moments."""

from repro.models.config import MambaConfig, MoEConfig, ModelConfig

TRAIN_OVERRIDES = {"moment_dtype": "bfloat16"}


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
        head_dim=128, d_ff=24576, vocab=65536,
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        attn_layer_period=8, attn_layer_offset=3,
        moe=MoEConfig(num_experts=16, top_k=2, d_expert=24576, every=2,
                      capacity_factor=1.25),
        scan_group=8,
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b-smoke", family="hybrid",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=128,
        mamba=MambaConfig(d_state=8, d_conv=4, expand=2),
        attn_layer_period=8, attn_layer_offset=3,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64, every=2),
        scan_group=8,
        param_dtype="float32", compute_dtype="float32",
    )
