"""llama3.2-3b [dense]: 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256.  24 heads don't divide the 16-way model axis, so attention
runs sequence-parallel (DESIGN.md §7.6) - an explicit SP feature, not a
config change."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b", family="dense",
        n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
        head_dim=128, d_ff=8192, vocab=128256,
        rope_theta=500_000.0, attn_shard="sequence",
        param_dtype="float32", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b-smoke", family="dense",
        n_layers=2, d_model=48, n_heads=6, n_kv_heads=2,
        head_dim=8, d_ff=128, vocab=128,
        attn_shard="sequence",
        param_dtype="float32", compute_dtype="float32",
    )
