"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144.  5:1 local(window 1024):global pattern, dual rope theta,
qk-norm, sandwich norms.  long_500k skipped (global layers quadratic)."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b", family="dense",
        n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
        head_dim=256, d_ff=15360, vocab=262144,
        sliding_window=1024, local_global_ratio=5,
        rope_theta=1_000_000.0, rope_theta_local=10_000.0,
        qk_norm=True, post_norms=True, act="gelu",
        tie_embeddings=True, scan_group=6,
        param_dtype="float32", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b-smoke", family="dense",
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=128,
        sliding_window=8, local_global_ratio=5,
        rope_theta=1_000_000.0, rope_theta_local=10_000.0,
        qk_norm=True, post_norms=True, act="gelu",
        tie_embeddings=True, scan_group=6,
        param_dtype="float32", compute_dtype="float32",
    )
