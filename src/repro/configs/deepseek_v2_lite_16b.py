"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff=1408 vocab=102400.

MLA kv_lora=512 without q-LoRA (lite variant); MoE 2 shared + 64 routed
top-6, first layer dense (d_ff 10944) (arXiv:2405.04434)."""

from repro.models.config import MLAConfig, MoEConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
        head_dim=128, d_ff=1408, vocab=102400,
        mla=MLAConfig(kv_lora=512, q_lora=0, qk_nope_dim=128,
                      qk_rope_dim=64, v_head_dim=128),
        moe=MoEConfig(num_experts=64, num_shared=2, top_k=6,
                      d_expert=1408, first_k_dense=1, d_ff_dense=10944,
                      capacity_factor=1.25),
        param_dtype="float32", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=32, vocab=128,
        mla=MLAConfig(kv_lora=32, q_lora=0, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16),
        moe=MoEConfig(num_experts=8, num_shared=2, top_k=2, d_expert=32,
                      first_k_dense=1, d_ff_dense=128),
        param_dtype="float32", compute_dtype="float32",
    )
