"""The paper's own workload: a DYNAPs-style multi-core SNN processor.

4 cores x 256 neurons, 11-bit CAM routing LUTs, HAT arbitration - the
design point of the paper's Tables I-III (N=256) and the 512x11 CAM
(§IV-D).  `scaled_config` is a 16-core scale-up used by the examples."""

from repro.core import cam, fabric
from repro.models.snn import SNNConfig


def config() -> SNNConfig:
    return SNNConfig(
        fabric=fabric.FabricConfig(
            cores=4, neurons_per_core=256, cam_entries_per_core=512,
            scheme="hier_tree", cam=cam.CamConfig(entries=512)),
        d_in=64, d_out=10, t_steps=32)


def scaled_config() -> SNNConfig:
    return SNNConfig(
        fabric=fabric.FabricConfig(
            cores=16, neurons_per_core=256, cam_entries_per_core=512,
            scheme="hier_tree", cam=cam.CamConfig(entries=512)),
        d_in=64, d_out=10, t_steps=32)


def smoke_config() -> SNNConfig:
    return SNNConfig(
        fabric=fabric.FabricConfig(
            cores=2, neurons_per_core=64, cam_entries_per_core=64,
            scheme="hier_tree", cam=cam.CamConfig(entries=64)),
        d_in=16, d_out=4, t_steps=8)
