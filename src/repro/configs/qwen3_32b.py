"""qwen3-32b [dense]: 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936.  Per-head qk RMSNorm (hf:Qwen/Qwen3-8B family)."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
        head_dim=128, d_ff=25600, vocab=151936,
        qk_norm=True, rope_theta=1_000_000.0,
        param_dtype="float32", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
        head_dim=8, d_ff=128, vocab=128, qk_norm=True,
        param_dtype="float32", compute_dtype="float32",
    )
