"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff=1536 vocab=102400.

MLA kv_lora=512 (+64 rope), q_lora=1536; MoE 2 shared + 160 routed top-6,
first layer dense (d_ff 12288) (arXiv:2405.04434).  The 160-expert top-6
dispatch routes through core/event_router (the paper-technique bridge).
bf16 params + bf16 AdamW moments (DESIGN.md §4 memory budget)."""

from repro.models.config import MLAConfig, MoEConfig, ModelConfig

TRAIN_OVERRIDES = {"moment_dtype": "bfloat16"}


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        head_dim=128, d_ff=1536, vocab=102400,
        mla=MLAConfig(kv_lora=512, q_lora=1536, qk_nope_dim=128,
                      qk_rope_dim=64, v_head_dim=128),
        moe=MoEConfig(num_experts=160, num_shared=2, top_k=6,
                      d_expert=1536, first_k_dense=1, d_ff_dense=12288,
                      capacity_factor=1.25),
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=32, vocab=128,
        mla=MLAConfig(kv_lora=32, q_lora=48, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16),
        moe=MoEConfig(num_experts=8, num_shared=2, top_k=2, d_expert=32,
                      first_k_dense=1, d_ff_dense=128),
        param_dtype="float32", compute_dtype="float32",
    )
