"""Architecture registry: --arch <id> -> ModelConfig, shapes, cell matrix.

Ten assigned architectures + the paper's own SNN config.  Each cell of
the (arch x shape) matrix resolves to the program the dry-run lowers:
train_step / prefill_step / decode_step.  Skips follow the brief:
encoder-only archs have no decode shapes; long_500k runs only for
SSM/hybrid families (sub-quadratic) - see DESIGN.md §4.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCHS = {
    "hubert-xlarge": "hubert_xlarge",
    "rwkv6-3b": "rwkv6_3b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "gemma3-12b": "gemma3_12b",
    "internlm2-1.8b": "internlm2_1_8b",
    "qwen3-32b": "qwen3_32b",
    "llama3.2-3b": "llama3_2_3b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
}


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def train_overrides(arch: str) -> dict:
    return getattr(_module(arch), "TRAIN_OVERRIDES", {})


def cell_status(arch: str, shape: str) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for one (arch x shape) cell."""
    cfg = get_config(arch)
    sh = SHAPES[shape]
    if sh.kind == "decode" and cfg.encoder_only:
        return False, "encoder-only: no decode step"
    if shape == "long_500k" and not cfg.is_subquadratic():
        return False, "full attention: long_500k needs sub-quadratic mixer"
    return True, ""


def all_cells():
    """Every (arch, shape, runnable, reason) - the 40-cell matrix."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES:
            ok, why = cell_status(arch, shape)
            out.append((arch, shape, ok, why))
    return out
