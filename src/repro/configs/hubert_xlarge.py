"""hubert-xlarge [audio]: 48L d_model=1280 16H (MHA) d_ff=5120 vocab=504.

Encoder-only masked-unit-prediction backbone (arXiv:2106.07447).  The
audio frontend is a STUB: input_specs provide precomputed frame
embeddings (B, T, 512).  No decode shapes (encoder)."""

from repro.models.config import FrontendConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="encoder",
        n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
        head_dim=80, d_ff=5120, vocab=504,
        encoder_only=True, act="gelu",
        frontend=FrontendConfig(kind="audio", d_in=512),
        param_dtype="float32", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke", family="encoder",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=64,
        encoder_only=True, act="gelu",
        frontend=FrontendConfig(kind="audio", d_in=24),
        param_dtype="float32", compute_dtype="float32",
    )
