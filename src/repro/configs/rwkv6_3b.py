"""rwkv6-3b [ssm]: 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536.

Finch: data-dependent decay (arXiv:2404.05892).  40 heads of 64; heads
replicated over `model`, FFN + vocab TP (DESIGN.md §7.5).  Runs
long_500k (linear-time)."""

from repro.models.config import ModelConfig, RWKVConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", family="rwkv",
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
        head_dim=64, d_ff=8960, vocab=65536,
        rwkv=RWKVConfig(head_dim=64, lora_decay=64, lora_mix=32),
        param_dtype="float32", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b-smoke", family="rwkv",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=224, vocab=128,
        rwkv=RWKVConfig(head_dim=16, lora_decay=8, lora_mix=8),
        param_dtype="float32", compute_dtype="float32",
    )
