"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (MHA kv=32) d_ff=8192
vocab=32064.  phi3-mini backbone + CLIP frontend STUB: input_specs
provide precomputed patch embeddings (B, 576, 1024) prepended to the
token stream (hf:microsoft/Phi-3-vision-128k-instruct)."""

from repro.models.config import FrontendConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", family="vlm",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        head_dim=96, d_ff=8192, vocab=32064,
        rope_theta=10_000.0,
        frontend=FrontendConfig(kind="vision", d_in=1024, max_prefix=576),
        param_dtype="float32", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=128,
        frontend=FrontendConfig(kind="vision", d_in=32, max_prefix=8),
        param_dtype="float32", compute_dtype="float32",
    )
