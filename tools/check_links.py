#!/usr/bin/env python3
"""Offline markdown link checker for the repo's docs.

Walks the given markdown files (or the default doc set), extracts every
inline link/image ``[text](target)`` and reference definition
``[label]: target``, and verifies that each *local* target resolves:

  * relative paths must exist on disk (relative to the linking file),
  * ``#fragment``-only links must match a heading in the same file,
  * ``path#fragment`` links must match a heading in the target file.

External links (http/https/mailto) are recognized but **not** fetched -
this gate runs in CI and must stay deterministic/offline.  Bare-code
spans and fenced code blocks are stripped first so example snippets like
``[i](j)`` indexing can't false-positive.

Exit status: 0 when every local link resolves, 1 otherwise (one line per
broken link, ``file:line: message``).

Usage:
    python tools/check_links.py [FILE.md ...]
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

DEFAULT_FILES = [
    "README.md",
    "ROADMAP.md",
    "EXPERIMENTS.md",
    "docs/ARCHITECTURE.md",
    "docs/kernels.md",
]

# Inline links/images: [text](target "title") — target ends at the first
# unmatched ')' or whitespace-before-title.  Good enough for our docs;
# we don't nest parens in link targets.
_INLINE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
# Reference definitions: [label]: target
_REFDEF = re.compile(r"^\s*\[[^\]]+\]:\s+(\S+)", re.M)
_FENCE = re.compile(r"^(```|~~~)", re.M)
_EXTERNAL = ("http://", "https://", "mailto:")


def _strip_code(text: str) -> str:
    """Blank out fenced code blocks and inline code spans, keeping line
    numbers stable so reported positions stay accurate."""
    out, in_fence = [], False
    for line in text.splitlines(keepends=True):
        if _FENCE.match(line):
            in_fence = not in_fence
            out.append("\n" if line.endswith("\n") else "")
        elif in_fence:
            out.append("\n" if line.endswith("\n") else "")
        else:
            out.append(re.sub(r"`[^`]*`", "", line))
    return "".join(out)


def _slugify(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, drop punctuation, dashes."""
    heading = re.sub(r"`([^`]*)`", r"\1", heading)          # unwrap code spans
    heading = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", heading)  # unwrap links
    slug = heading.strip().lower()
    slug = re.sub(r"[^\w\- ]", "", slug)
    return slug.replace(" ", "-")


def _anchors(path: Path) -> set[str]:
    """All heading anchors of a markdown file (with GitHub dedup suffixes)."""
    seen: dict[str, int] = {}
    anchors: set[str] = set()
    for line in _strip_code(path.read_text(encoding="utf-8")).splitlines():
        m = re.match(r"\s{0,3}(#{1,6})\s+(.*)", line)
        if not m:
            continue
        slug = _slugify(m.group(2))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        anchors.add(slug if n == 0 else f"{slug}-{n}")
    return anchors


def check_file(md: Path) -> list[str]:
    """Return one ``file:line: message`` string per broken local link."""
    errors: list[str] = []
    text = _strip_code(md.read_text(encoding="utf-8"))
    for pattern in (_INLINE, _REFDEF):
        for m in pattern.finditer(text):
            target = m.group(1)
            line = text.count("\n", 0, m.start()) + 1
            if target.startswith(_EXTERNAL):
                continue  # offline gate: never fetched
            path_part, _, fragment = target.partition("#")
            if path_part:
                dest = (md.parent / path_part).resolve()
                if not dest.exists():
                    errors.append(f"{md}:{line}: broken link: {target!r} "
                                  f"(no such file: {path_part})")
                    continue
            else:
                dest = md
            if fragment and dest.suffix == ".md":
                if fragment.lower() not in _anchors(dest):
                    errors.append(f"{md}:{line}: broken anchor: {target!r} "
                                  f"(no heading matches #{fragment})")
    return errors


def main(argv: list[str]) -> int:
    """Check every file named in ``argv`` (default doc set when empty)."""
    files = [Path(a) for a in argv] or [REPO / f for f in DEFAULT_FILES]
    errors: list[str] = []
    checked = 0
    for f in files:
        if not f.exists():
            errors.append(f"{f}: file not found")
            continue
        checked += 1
        errors.extend(check_file(f))
    for e in errors:
        print(e)
    print(f"check_links: {checked} file(s), {len(errors)} broken link(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
