"""CI wall-clock budget for the fast test suite.

Reads the junit XML that ``pytest --junit-xml`` wrote for the fast lane
(``-m "not slow"``) and fails (exit 1) when the summed test time blows
the budget:

    python tools/check_test_budget.py junit-fast.xml [--budget-s 360]

The budget guards the feedback loop, not correctness: the fast suite is
the per-commit signal, and every slow test that sneaks in unmarked makes
it a little worse until nobody waits for it.  When this gate flags,
either mark the offending tests ``@pytest.mark.slow`` (they still run on
main pushes) or make them faster - don't raise the budget first.

The ten slowest cases are always printed, so the offender is named in
the CI log next to the failure.  ``TEST_BUDGET_S`` overrides the default
budget (e.g. for a known-slow debug runner); ``--budget-s`` beats both.
"""

from __future__ import annotations

import argparse
import os
import sys
import xml.etree.ElementTree as ET

# Measured locally at ~half this; doubled for slower CI runners.  The
# ISSUE-level target is "fast suite < ~5 min on a dev box".
DEFAULT_BUDGET_S = 360.0
TOP_N = 10


def load_times(junit_path: str) -> list[tuple[float, str]]:
    """Returns (seconds, test id) per testcase in the junit XML."""
    root = ET.parse(junit_path).getroot()
    cases = []
    for case in root.iter("testcase"):
        name = f"{case.get('classname', '?')}::{case.get('name', '?')}"
        cases.append((float(case.get("time") or 0.0), name))
    return cases


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("junit", help="junit XML from pytest --junit-xml")
    ap.add_argument(
        "--budget-s",
        type=float,
        default=float(os.environ.get("TEST_BUDGET_S", DEFAULT_BUDGET_S)),
        help="summed-test-time budget in seconds (default: "
        "$TEST_BUDGET_S or %(default)s)",
    )
    args = ap.parse_args(argv)

    cases = load_times(args.junit)
    if not cases:
        print(f"FAIL: {args.junit} contains no testcases - wrong file?")
        return 1
    total = sum(t for t, _ in cases)
    print(
        f"fast-suite budget: {total:.1f}s summed over {len(cases)} tests "
        f"(budget {args.budget_s:.0f}s)"
    )
    print(f"  {TOP_N} slowest:")
    for t, name in sorted(cases, reverse=True)[:TOP_N]:
        print(f"  {t:8.2f}s  {name}")
    if total > args.budget_s:
        print(
            f"FAIL: fast suite blew its {args.budget_s:.0f}s budget by "
            f"{total - args.budget_s:.1f}s - mark the offenders "
            f"@pytest.mark.slow (they still run on main pushes) or make "
            f"them faster; raising the budget is the last resort"
        )
        return 1
    print("budget ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
