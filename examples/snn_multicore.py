"""The paper's own workload: train a multi-core SNN and report what the
core interface costs - comparing HAT against the other arbitration
schemes and the CSCD CAM against the conventional one.

    PYTHONPATH=src python examples/snn_multicore.py

Smoke knobs (used by tests/test_examples.py to keep the example cheap):
SNN_STEPS (train steps), SNN_EVAL_BATCH (accuracy batch size).
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses as dc

import jax
import jax.numpy as jnp

from repro.configs import paper_dynaps
from repro.core import arbiter, cam
from repro.data.pipeline import snn_batch
from repro.interface import Interface, ppa_report
from repro.models import snn
from repro.noc import placement, topology
from repro.optim import adamw

STEPS = int(os.environ.get("SNN_STEPS", "40"))
EVAL_BATCH = int(os.environ.get("SNN_EVAL_BATCH", "128"))


def main():
    cfg = paper_dynaps.smoke_config()
    params, topo = snn.init_snn(jax.random.PRNGKey(0), cfg)
    opt_cfg = adamw.AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=80,
                                weight_decay=0.0)
    opt = adamw.init(opt_cfg, params)
    loss_g = jax.jit(jax.value_and_grad(
        lambda p, b: snn.snn_loss(p, topo, b, cfg)))

    print(f"[snn] {cfg.fabric.cores} cores x {cfg.fabric.neurons_per_core} "
          f"neurons, CAM {cfg.fabric.cam.entries}x{cfg.fabric.cam.bits}")
    key = jax.random.PRNGKey(1)
    for step in range(STEPS):
        key, sub = jax.random.split(key)
        batch = snn_batch(sub, 32, cfg.t_steps, cfg.d_in, cfg.d_out)
        loss, grads = loss_g(params, batch)
        params, opt, _ = adamw.update(opt_cfg, grads, opt, params)
        if step % 10 == 0:
            print(f"  step {step:2d} loss {float(loss):.4f}")

    # accuracy
    batch = snn_batch(jax.random.PRNGKey(99), EVAL_BATCH, cfg.t_steps,
                      cfg.d_in, cfg.d_out)
    logits, rates, stats = snn.snn_forward(params, topo, batch["x"], cfg,
                                           account=True)
    acc = float(jnp.mean(jnp.argmax(logits, -1) == batch["y"]))
    print(f"[snn] accuracy {acc:.2%}, mean rate {float(rates.mean()):.3f}")

    # --- core-interface report (the paper's PPA story) ---------------------
    n = cfg.fabric.neurons_per_core
    print("\n[interface] per-tick stats (trained network):")
    for k, v in stats._asdict().items():
        print(f"  {k:16s} {float(v):10.2f}")

    print("\n[interface] arbitration alternatives at this core size:")
    for scheme in arbiter.SCHEMES:
        sp = arbiter.sparse_latency_units(scheme, n)
        ar = arbiter.area_units(scheme, n)
        print(f"  {scheme:12s} sparse {sp:7.1f} units  area {ar:6.1f} arbiters")

    print("\n[interface] CAM variants (512x11, per-search energy units):")
    for name, c in {
        "conventional": cam.CamConfig(512, cscd=False, feedback=False,
                                      speculative=False),
        "proposed (CSCD+fb+ss)": cam.CamConfig(512),
    }.items():
        e = cam.search_energy(c, n_match=1, n_mismatch=511)
        t = cam.cycle_time_ns(c)
        print(f"  {name:22s} energy {e:8.1f}  cycle {t:5.2f} ns")

    # --- NoC: what the inter-core transport costs on this trained net ------
    # one precompiled session per transport scheme; same spikes, and the
    # currents are bit-identical across sessions (tested invariant)
    fab = snn.fabric_params(params, topo)
    sp = jax.random.bernoulli(jax.random.PRNGKey(3), float(rates.mean()),
                              (cfg.fabric.cores, cfg.fabric.neurons_per_core))
    print("\n[noc] transport schemes (same spikes, same currents):")
    for scheme in ("broadcast", "unicast", "multicast_tree"):
        c2 = dc.replace(cfg.fabric, noc=topology.NocConfig(scheme))
        _, st2 = Interface(c2).compile(fab).step(sp)
        print(f"  {scheme:14s} cam_searches {float(st2.cam_searches):8.0f}"
              f"  noc_hops {float(st2.noc_hops):7.0f}"
              f"  noc_energy {float(st2.noc_energy):9.0f}")

    # --- unified static PPA report (area / latency / energy per config) ----
    rep = ppa_report(cfg.fabric)
    print("\n[ppa] unified interface report:")
    for section in ("arbiter", "cam", "noc"):
        vals = ", ".join(f"{k}={v:.3g}" if isinstance(v, float)
                         else f"{k}={v}" for k, v in rep[section].items())
        print(f"  {section:8s} {vals}")

    print("\n[noc] neuron-to-core placement (hyperedge-overlap optimizer):")
    a = placement.fanout_adjacency(fab, cfg.fabric)
    total = cfg.fabric.cores * cfg.fabric.neurons_per_core
    for name, perm in {
        "identity": placement.identity_placement(total),
        "greedy": placement.greedy_overlap_placement(
            a, cfg.fabric.cores, cfg.fabric.neurons_per_core),
    }.items():
        cost = placement.traffic_cost(a, perm, cfg.fabric.cores,
                                      cfg.fabric.neurons_per_core)
        srch = placement.cam_search_count(a, perm, cfg.fabric.cores,
                                          cfg.fabric.neurons_per_core)
        print(f"  {name:10s} traffic_cost {cost:8.0f}  cam_searches/tick"
              f" (all-fire) {srch:8.0f}")


if __name__ == "__main__":
    main()
