"""End-to-end training driver: ~100M-param LM for a few hundred steps,
with checkpoint/restart fault tolerance (kill it mid-run; rerun resumes).

    PYTHONPATH=src python examples/train_lm.py --steps 300 --preset 100m
    PYTHONPATH=src python examples/train_lm.py --steps 50 --preset 10m
    PYTHONPATH=src python examples/train_lm.py --arch internlm2-1.8b ...
        (--arch uses the assigned architecture's reduced smoke config)
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro import configs
from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import DataConfig, Pipeline
from repro.ft.runner import Watchdog, run_training
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.train import step as ts

PRESETS = {
    "10m": dict(n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
                head_dim=64, d_ff=1024, vocab=4096),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 head_dim=64, d_ff=2048, vocab=8192),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=sorted(PRESETS))
    ap.add_argument("--arch", default=None,
                    help="use an assigned arch's smoke config instead")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args()

    if args.arch:
        cfg = configs.get_smoke_config(args.arch)
    else:
        cfg = ModelConfig(name=f"lm-{args.preset}", family="dense",
                          param_dtype="float32", compute_dtype="float32",
                          **PRESETS[args.preset])
    opt = AdamWConfig(lr=3e-4 if args.preset == "100m" else 1e-3,
                      warmup_steps=20, total_steps=max(args.steps, 100))
    state = ts.init_state(jax.random.PRNGKey(0), cfg, opt)
    n = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"[train_lm] {cfg.name}: {n/1e6:.1f}M params, {args.steps} steps")

    pipe = Pipeline(cfg, DataConfig(global_batch=args.batch,
                                    seq_len=args.seq, seed=0))
    train = jax.jit(ts.make_train_step(cfg, opt,
                                       microbatch=args.microbatch))
    mgr = CheckpointManager(args.ckpt_dir, every=50, keep=2)
    state, history = run_training(train, state, pipe, num_steps=args.steps,
                                  manager=mgr, watchdog=Watchdog())
    print(f"[train_lm] done: loss {history[0]['loss']:.4f} -> "
          f"{history[-1]['loss']:.4f}")
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(history, f)


if __name__ == "__main__":
    main()
