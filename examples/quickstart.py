"""Quickstart: train a tiny LM for 30 steps, checkpoint it, generate.

    PYTHONPATH=src python examples/quickstart.py

Smoke knobs (used by tests/test_examples.py to keep the example cheap):
QUICKSTART_STEPS, QUICKSTART_GEN_STEPS, QUICKSTART_CKPT_DIR.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

STEPS = int(os.environ.get("QUICKSTART_STEPS", "30"))
GEN_STEPS = int(os.environ.get("QUICKSTART_GEN_STEPS", "16"))
CKPT_DIR = os.environ.get("QUICKSTART_CKPT_DIR", "/tmp/repro_quickstart")

from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import DataConfig, Pipeline
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.serve.lm_engine import ServeEngine
from repro.train import step as ts


def main():
    cfg = ModelConfig(name="quickstart", family="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
                      d_ff=512, vocab=512, param_dtype="float32",
                      compute_dtype="float32")
    opt = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=200)
    state = ts.init_state(jax.random.PRNGKey(0), cfg, opt)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"model: {n_params/1e6:.1f}M params")

    pipe = Pipeline(cfg, DataConfig(global_batch=8, seq_len=128, seed=0))
    train = jax.jit(ts.make_train_step(cfg, opt))
    for i in range(STEPS):
        state, m = train(state, pipe.batch(i))
        if i % 5 == 0:
            print(f"step {i:3d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}")

    mgr = CheckpointManager(CKPT_DIR, every=1, async_save=False)
    mgr.maybe_save(STEPS, state, force=True)
    print("checkpointed:", mgr.latest_step())

    engine = ServeEngine(cfg=cfg, params=state.params, max_len=160)
    prompts = pipe.batch(0)["tokens"][:2, :16]
    out = engine.generate(prompts, num_steps=GEN_STEPS)
    print("generated:", out[0].tolist())


if __name__ == "__main__":
    main()
