"""Batched serving demo: prefill + lock-step decode over request lanes.

    PYTHONPATH=src python examples/serve_lm.py --arch llama3.2-3b
(uses the arch's reduced smoke config so it runs on one CPU)
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro import configs
from repro.models import lm
from repro.serve.lm_engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--steps", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only - no decode")
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg=cfg, params=params, max_len=128,
                         temperature=0.8)

    key = jax.random.PRNGKey(7)
    prompts = jax.random.randint(key, (args.lanes, 12), 0, cfg.vocab)
    t0 = time.perf_counter()
    out = engine.generate(prompts, num_steps=args.steps, key=key)
    dt = time.perf_counter() - t0
    total = args.lanes * args.steps
    print(f"[serve] {args.arch} ({cfg.name}): {args.lanes} lanes x "
          f"{args.steps} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s incl. compile)")
    for i in range(args.lanes):
        print(f"  lane {i}: {out[i, :12].tolist()} ...")


if __name__ == "__main__":
    main()
