"""Generate EXPERIMENTS.md from dry-run artifacts + paper-table benchmarks.

    PYTHONPATH=src python -m benchmarks.make_experiments_md

Sections: §Paper-validation (tables vs claims), §Dry-run (all cells, both
meshes), §Roofline (singlepod baseline), §Perf (hillclimb log appended
from experiments/perf_log.md, maintained by hand per iteration).
"""

from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import paper_tables, roofline  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")
PERF_LOG = os.path.join(ROOT, "experiments", "perf_log.md")
OUT = os.path.join(ROOT, "EXPERIMENTS.md")


def gb(x):
    return x / 2 ** 30


def paper_section():
    out = ["## Paper-validation\n\n"
           "Every quantitative claim of the paper vs. this reproduction "
           "(benchmarks/paper_tables.py; unit-domain values exact, ns/um^2 "
           "from the two-point calibration described in core/ppa.py).\n\n"]
    rows, d1 = paper_tables.table1_sparse_latency()
    out.append("### Table I - sparse-event latency (units | DES | ns)\n\n")
    out.append("| scheme | N=64 theory | N=64 DES | N=64 ns | N=256 theory "
               "| N=256 DES | N=256 ns |\n|---|---|---|---|---|---|---|\n")
    for r in rows:
        out.append(f"| {r['scheme']} | {r['theory_64']} | {r['des_64']} | "
                   f"{r['ns_64']} | {r['theory_256']} | {r['des_256']} | "
                   f"{r['ns_256']} |\n")
    out.append(f"\nHeadline: HAT vs HTR sparse-latency reduction = "
               f"**{d1['hat_vs_htr_sparse_reduction']:.1%}** "
               f"(paper: up to 78.3%).\n\n")

    rows, d2 = paper_tables.table2_burst_latency()
    out.append("### Table II - burst latency\n\n")
    out.append("| scheme | N=64 theory | N=64 DES | N=256 theory | "
               "N=256 DES |\n|---|---|---|---|---|\n")
    for r in rows:
        out.append(f"| {r['scheme']} | {r['theory_64']} | {r['des_64']} | "
                   f"{r['theory_256']} | {r['des_256']} |\n")
    out.append(f"\nHAT burst = {d2['hat_burst_vs_token_ring']:.3f}x token "
               "ring at N=256 (paper: slightly slower than token ring, far "
               "below binary/greedy trees).\n\n")

    rows, _ = paper_tables.table3_area()
    out.append("### Table III - normalized area\n\n")
    out.append("| scheme | N=64 arbiters | N=64 norm | N=256 arbiters | "
               "N=256 norm |\n|---|---|---|---|---|\n")
    for r in rows:
        out.append(f"| {r['scheme']} | {r['arbiters_64']} | {r['norm_64']} | "
                   f"{r['arbiters_256']} | {r['norm_256']} |\n")

    rows, d10 = paper_tables.fig10_cam_cycle()
    out.append("\n### Fig. 10 - CAM cycle time\n\n")
    out.append("| entries | conventional | +CSCD | +fb | +ss | full | "
               "improvement | paper |\n|---|---|---|---|---|---|---|---|\n")
    paper_imp = {16: 0.355, 512: 0.404}
    for r in rows:
        out.append(f"| {r['entries']} | {r['conventional_ns']} | "
                   f"{r['cscd_ns']} | {r['cscd+fb_ns']} | {r['cscd+ss_ns']} | "
                   f"{r['full_ns']} | **{r['improvement']:.1%}** | "
                   f"{paper_imp[r['entries']]:.1%} |\n")

    rows, d11 = paper_tables.fig11_cam_energy()
    out.append("\n### Fig. 11 - CAM search energy\n\n")
    out.append("| case | model saving | paper |\n|---|---|---|\n")
    for r in rows:
        out.append(f"| {r['case']} | {r['model_saving']:.1%} | "
                   f"{r['paper_saving']:.1%} |\n")
    out.append(f"\n**Reproduction finding**: {d11['note']}.  The all-MATCH "
               "and all-MISMATCH savings and both cycle-time improvements "
               "calibrate exactly; speculative-sense close probability "
               f"= {d11['spec_sense_close_prob']:.4f} "
               "(paper formula: 0.876 at N=10,n=3).\n\n")
    return "".join(out)


def dryrun_section():
    recs = roofline.load_records(variant="baseline")
    out = ["## Dry-run\n\n"
           "Every (arch x shape) cell lowered + compiled on the production "
           "meshes - single-pod (16,16)=256 chips and multi-pod "
           "(2,16,16)=512 chips - from ShapeDtypeStruct stand-ins (no "
           "allocation).  Costs are per-device from the post-SPMD module; "
           "`flops/bytes (cal)` are the scan-aware calibrated values "
           "(launch/dryrun.py docstring).\n\n"
           "| arch | shape | mesh | status | compile s | args GB | temp GB "
           "| flops (cal) | bytes (cal) | coll B (cal) |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n"]
    n_ok = n_skip = n_err = 0
    for r in recs:
        mesh = "multi" if r.get("multi_pod") else "single"
        if r.get("status") == "skipped":
            n_skip += 1
            out.append(f"| {r['arch']} | {r['shape']} | {mesh} | SKIP "
                       f"({r['reason']}) | | | | | | |\n")
            continue
        if r.get("status") != "ok":
            n_err += 1
            out.append(f"| {r['arch']} | {r['shape']} | {mesh} | "
                       f"ERROR | | | | | | |\n")
            continue
        n_ok += 1
        cal = r.get("cost_calibrated", {})
        coll = cal.get("collectives", {}).get("total", 0)
        out.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | ok | "
            f"{r['compile_s']:.0f} | {gb(r['memory']['argument_bytes']):.2f} "
            f"| {gb(r['memory']['temp_bytes']):.2f} | "
            f"{cal.get('flops', 0):.3e} | "
            f"{cal.get('bytes accessed', 0):.3e} | {coll:.3e} |\n")
    out.append(f"\ncompiled OK: **{n_ok}**, mandated skips: {n_skip}, "
               f"errors: {n_err}.\n\n")
    return "".join(out)


def roofline_section():
    rows = roofline.table(mesh="singlepod", variant="baseline")
    out = ["## Roofline\n\n"
           "Single-pod (256 x v5e: 197 bf16 TFLOP/s, 819 GB/s HBM, "
           "50 GB/s/link ICI).  Terms are no-overlap per-step seconds; "
           "`roofline frac` = MODEL_FLOPS / (chips x peak x max-term) - the "
           "MFU bound the compiled program could reach if the dominant "
           "term were perfectly pipelined.\n\n"
           "Calibration note: every train/decode/long cell and the "
           "hillclimb cells use the scan-aware UNROLLED calibration "
           "(launch/dryrun.py).  The `bytes accessed` metric is the CPU "
           "HLO's un-fused operand traffic - a consistent, pessimistic "
           "proxy for HBM bytes (TPU fusion would lower absolute values; "
           "relative deltas across variants are meaningful).  rwkv6/"
           "jamba prefill_32k cells retain the earlier loop-free "
           "calibration (the unrolled 2048-chunk WKV lowering exceeds the "
           "CPU compile budget); their memory columns overstate the WKV "
           "share, bounded by the train_4k per-token rates.\n\n",
           roofline.markdown(rows), "\n"]
    # bottleneck summary + suggestions
    out.append("\n### Bottlenecks & levers\n\n")
    for r in rows:
        out.append(f"- **{r['arch']} / {r['shape']}** - {r['bottleneck']}-"
                   f"bound; {r['suggestion']}.\n")
    return "".join(out)


def driver_section():
    hist = os.path.join(ROOT, "experiments", "train_10m_history.json")
    out = ["\n## End-to-end driver runs (single CPU host)\n\n"]
    if os.path.exists(hist):
        with open(hist) as f:
            h = json.load(f)
        out.append(
            f"- `examples/train_lm.py --preset 10m --steps {len(h)}`: "
            f"loss **{h[0]['loss']:.3f} -> {h[-1]['loss']:.3f}** with "
            "checkpoint-every-50 + watchdog (history: "
            "experiments/train_10m_history.json).\n")
    out.append("- `examples/snn_multicore.py`: the paper's own workload - "
               "multi-core SNN to 98% accuracy with per-tick core-interface "
               "PPA accounting (HAT 6-unit sparse latency / 9 arbiters vs "
               "63-80 for the alternatives at N=64).\n"
               "- `examples/serve_lm.py`: batched prefill+decode serving on "
               "every decoder arch's smoke config.\n"
               "- fault-tolerance drill (tests/test_train_ckpt_ft.py): "
               "injected crash at step 7 -> auto-resume -> final params "
               "bit-identical to the uninterrupted run.\n")
    return "".join(out)


def perf_section():
    out = ["\n## Perf\n\n"]
    if os.path.exists(PERF_LOG):
        with open(PERF_LOG) as f:
            out.append(f.read())
    else:
        out.append("(hillclimb log pending)\n")
    try:
        from benchmarks import perf_report
        out.append("\n### Measured variant table (auto-generated)\n\n")
        out.append(perf_report.markdown())
    except Exception as e:  # noqa: BLE001
        out.append(f"(variant table unavailable: {e})\n")
    return "".join(out)


def main():
    parts = [
        "# EXPERIMENTS\n\n",
        "Reproduction + performance record for *Core interface optimization "
        "for multi-core neuromorphic processors* (Su et al., 2023) on the "
        "JAX/Pallas framework in this repo.  Regenerate with "
        "`PYTHONPATH=src python -m benchmarks.make_experiments_md`.\n\n",
        paper_section(),
        dryrun_section(),
        roofline_section(),
        perf_section(),
        driver_section(),
    ]
    with open(OUT, "w") as f:
        f.write("".join(parts))
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
