"""Render the §Perf before/after table from variant dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.perf_report
"""

from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks import roofline  # noqa: E402

CELLS = {
    "deepseek-v2-236b/decode_32k": ["baseline", "serve_tp32_bf16",
                                    "serve_tp32"],
    "qwen3-32b/train_4k": ["baseline", "remat_dots", "mb8", "mb8_dots"],
    "rwkv6-3b/train_4k": ["baseline", "rwkv48", "rwkv48_c64"],
}


def rows_for(cell: str, variants):
    arch, shape = cell.split("/")
    recs = {r.get("variant", "baseline"): r
            for r in roofline.load_records()
            if r.get("arch") == arch and r.get("shape") == shape
            and not r.get("multi_pod") and r.get("status") == "ok"}
    out = []
    base_step = None
    for v in variants:
        if v not in recs:
            out.append((v, None))
            continue
        a = roofline.analyze(recs[v])
        step = max(a["compute_s"], a["memory_s"], a["collective_s"])
        if v == "baseline":
            base_step = step
        a["step_bound_s"] = step
        a["speedup"] = (base_step / step) if base_step else 1.0
        out.append((v, a))
    return out


def markdown() -> str:
    out = ["| cell | variant | compute s | memory s | collective s | "
           "bottleneck | temp GB | step bound s | speedup | roofline frac "
           "|\n|---|---|---|---|---|---|---|---|---|---|\n"]
    for cell, variants in CELLS.items():
        for v, a in rows_for(cell, variants):
            if a is None:
                out.append(f"| {cell} | {v} | (pending) | | | | | | | |\n")
                continue
            out.append(
                f"| {cell} | {v} | {a['compute_s']:.4f} | "
                f"{a['memory_s']:.4f} | {a['collective_s']:.4f} | "
                f"{a['bottleneck']} | {a['temp_bytes_gb']:.1f} | "
                f"{a['step_bound_s']:.4f} | {a['speedup']:.1f}x | "
                f"{a['roofline_fraction']:.3f} |\n")
    return "".join(out)


if __name__ == "__main__":
    print(markdown())
