"""Kernel micro-benchmarks: XLA oracle path timings on CPU + the
speculative-sense traffic model.

Wall-clock here is the CPU oracle (the Pallas kernels target TPU and are
validated in interpret mode, which is not a performance mode); the derived
columns are machine-independent: operation counts and the traffic ratio
of the speculative two-pass search.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import ppa
from repro.kernels.cam_search import ops as cam_ops, ref as cam_ref
from repro.kernels.hat_encode import ops as hat_ops
from repro.kernels.moe_dispatch import ops as moe_ops

KEY = jax.random.PRNGKey(0)


def _time(f, *args, iters=20):
    jax.block_until_ready(f(*args))    # one warmup call (compile + run)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def cam_search_bench():
    rows = []
    for b, e in ((128, 512), (1024, 512), (1024, 4096)):
        tags = jax.random.bernoulli(KEY, 0.5, (e, 11)).astype(jnp.int32)
        t_p = cam_ref.pack_bits(tags)
        q_p = jnp.tile(t_p[:1], (b, 1))
        valid = jnp.ones((e,), bool)
        f = jax.jit(lambda q, t, v: cam_ops.cam_search(q, t, v, impl="xla"))
        us = _time(f, q_p, t_p, valid)
        rows.append({"name": f"cam_search_{b}x{e}", "us_per_call": round(us, 1),
                     "derived": f"compares={b * e}"})
    # speculative sense traffic model: fraction of full-width compares kept
    p_mm = 1 - 2.0 ** -11
    survivors = 2.0 ** -1  # last 32-bit word prefilter on 11-bit tags -> exact
    p_ss = ppa.spec_sense_close_probability(11, 3)
    rows.append({"name": "spec_sense_traffic_model",
                 "us_per_call": 0.0,
                 "derived": (f"P(early-kill|mismatch)={p_ss:.4f}; full-width "
                             f"traffic x{(1 - p_ss * p_mm):.3f}")})
    del survivors
    return rows


def hat_encode_bench():
    rows = []
    for n in (4096, 65536):
        spk = jax.random.bernoulli(KEY, 0.05, (n,))
        f = jax.jit(lambda s: hat_ops.hat_encode(s, impl="xla")[0])
        us = _time(f, spk)
        rows.append({"name": f"hat_encode_{n}", "us_per_call": round(us, 1),
                     "derived": f"events={int(spk.sum())}"})
    return rows


def moe_dispatch_bench():
    rows = []
    for m, e in ((16384, 160), (65536, 160)):
        ids = jax.random.randint(KEY, (m,), 0, e)
        f = jax.jit(lambda i: moe_ops.dispatch_positions(
            i, num_experts=e, impl="xla")[0])
        us = _time(f, ids)
        rows.append({"name": f"moe_dispatch_{m}x{e}",
                     "us_per_call": round(us, 1),
                     "derived": f"events={m}"})
    return rows
