"""One benchmark per paper table/figure (Tables I-III, Figs. 5, 10, 11).

Each function returns (rows, derived) where rows are printable dicts and
`derived` is the headline number compared against the paper's claim.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import traffic
from repro.core import cam, ppa
from repro.core.arbiter import (Arbiter, ArbiterConfig, SCHEMES,
                                batched_tick_latency, burst_latency_units,
                                sparse_latency_units, area_units)

KEY = jax.random.PRNGKey(0)


def table1_sparse_latency():
    """Table I: average latency with sparse events (units + calibrated ns)."""
    rows = []
    for scheme in SCHEMES:
        row = {"scheme": scheme}
        for n in (64, 256):
            arb = Arbiter(ArbiterConfig(scheme, n))
            des = float(arb.sparse_event_latency(KEY, num_trials=min(n, 128)))
            row[f"theory_{n}"] = sparse_latency_units(scheme, n)
            row[f"des_{n}"] = round(des, 2)
            row[f"ns_{n}"] = round(ppa.sparse_latency_ns(scheme, n), 2)
        rows.append(row)
    hat = ppa.sparse_latency_ns("hier_tree", 256)
    htr = ppa.sparse_latency_ns("hier_ring", 256)
    derived = {"hat_vs_htr_sparse_reduction": round(1 - hat / htr, 4),
               "paper_claim": 0.783}
    return rows, derived


def table2_burst_latency():
    """Table II: full-frame burst completion latency."""
    rows = []
    for scheme in SCHEMES:
        row = {"scheme": scheme}
        for n in (64, 256):
            arb = Arbiter(ArbiterConfig(scheme, n))
            row[f"theory_{n}"] = round(burst_latency_units(scheme, n), 1)
            row[f"des_{n}"] = round(float(arb.burst_latency()), 1)
            if scheme != "greedy_tree":
                row[f"ns_{n}"] = round(ppa.burst_latency_ns(scheme, n), 1)
        rows.append(row)
    hat = burst_latency_units("hier_tree", 256)
    ring = burst_latency_units("token_ring", 256)
    derived = {"hat_burst_vs_token_ring": round(hat / ring, 3),
               "paper_claim": "within ~7% of token ring"}
    return rows, derived


def table3_area():
    """Table III: normalized area cost."""
    rows = []
    for scheme in SCHEMES:
        row = {"scheme": scheme}
        for n in (64, 256):
            row[f"arbiters_{n}"] = round(area_units(scheme, n), 1)
            row[f"norm_{n}"] = round(ppa.area_normalized(scheme, n), 1)
        rows.append(row)
    hat = area_units("hier_tree", 256)
    binary = area_units("binary_tree", 256)
    derived = {"hat_area_fraction_of_binary": round(hat / binary, 4),
               "paper_claim": "12 vs 255 two-input arbiters at N=256"}
    return rows, derived


def fig5_scalability():
    """Fig. 5: latency scaling N in {64..4096}, sparse + burst."""
    rows = []
    for n in (64, 256, 1024, 4096):
        row = {"n": n}
        for scheme in SCHEMES:
            row[f"sparse_{scheme}"] = round(sparse_latency_units(scheme, n), 1)
            row[f"burst_{scheme}"] = round(burst_latency_units(scheme, n), 1)
        rows.append(row)
    # HAT keeps the lowest sparse latency at every size
    ok = all(min(SCHEMES, key=lambda s: sparse_latency_units(s, n))
             == "hier_tree" for n in (64, 256, 1024, 4096))
    return rows, {"hat_lowest_sparse_at_all_sizes": ok}


def traffic_arbiter_latency(ticks=48, cores=4, n=256, seed=0):
    """Sparse-vs-burst arbiter latency from *generated traffic*.

    The abstract's headline (">70% latency reduction in sparse-event
    mode") and Table II's burst story are reproduced here by driving the
    vectorized arbiter policies with `repro.traffic` scenario rasters -
    sparse Poisson at ~1 event/frame and synchronized full-frame bursts -
    instead of the closed-form inputs the other tables use.  Mean
    unit-domain completion times are mapped through the same affine
    22FDX fits as Table I/II (`ppa.sparse_ns_fit` / `ppa.burst_ns_fit`).
    """
    sparse = traffic.generate("sparse_poisson", seed, ticks, (cores, n),
                              rate=1.0 / n).reshape(-1, n)
    burst = traffic.generate("synchronized_burst", seed + 1, ticks,
                             (cores, n), period=1, duty=1, burst_rate=1.0,
                             background=0.0).reshape(-1, n)
    rows = []
    ns = {}
    for scheme in SCHEMES:
        cfg = ArbiterConfig(scheme, n)
        active = jnp.any(sparse, axis=1)
        lat_sparse = batched_tick_latency(cfg, sparse)
        u_sparse = float(jnp.sum(jnp.where(active, lat_sparse, 0.0))
                         / jnp.maximum(jnp.sum(active), 1))
        u_burst = float(jnp.mean(batched_tick_latency(cfg, burst)))
        row = {"scheme": scheme,
               "sparse_traffic_units": round(u_sparse, 2),
               "burst_traffic_units": round(u_burst, 2),
               "sparse_traffic_ns": round(ppa.sparse_ns_fit(scheme)(u_sparse), 2)}
        if scheme != "greedy_tree":      # paper reports no greedy burst ns
            row["burst_traffic_ns"] = round(ppa.burst_ns_fit(scheme)(u_burst), 2)
        ns[scheme] = row
        rows.append(row)
    derived = {
        "sparse_reduction_vs_hier_ring": round(
            1 - ns["hier_tree"]["sparse_traffic_ns"]
            / ns["hier_ring"]["sparse_traffic_ns"], 4),
        "sparse_reduction_vs_token_ring": round(
            1 - ns["hier_tree"]["sparse_traffic_ns"]
            / ns["token_ring"]["sparse_traffic_ns"], 4),
        "burst_ratio_vs_token_ring": round(
            ns["hier_tree"]["burst_traffic_ns"]
            / ns["token_ring"]["burst_traffic_ns"], 4),
        "paper_claim": ">70% sparse-mode reduction; burst within ~10% "
                       "of token ring",
    }
    return rows, derived


def fig10_cam_cycle():
    """Fig. 10: average search cycle time across CAM variants."""
    rows = []
    for entries in (16, 512):
        variants = {
            "conventional": cam.CamConfig(entries, cscd=False, feedback=False,
                                          speculative=False),
            "cscd": cam.CamConfig(entries, feedback=False, speculative=False),
            "cscd+fb": cam.CamConfig(entries, speculative=False),
            "cscd+ss": cam.CamConfig(entries, feedback=False),
            "full": cam.CamConfig(entries),
        }
        row = {"entries": entries}
        for name, cfg in variants.items():
            row[name + "_ns"] = round(cam.cycle_time_ns(cfg), 3)
        row["improvement"] = round(cam.cycle_improvement(entries), 4)
        rows.append(row)
    derived = {"improvement_16": rows[0]["improvement"], "paper_16": 0.355,
               "improvement_512": rows[1]["improvement"], "paper_512": 0.404}
    return rows, derived


def fig11_cam_energy():
    """Fig. 11: normalized average search energy (512x11)."""
    rows = []
    for case in ("all_match", "all_mismatch", "random"):
        rows.append({"case": case,
                     "model_saving": round(cam.energy_saving(case), 4),
                     "paper_saving": ppa.CAM_ENERGY_SAVING[case]})
    derived = {
        "note": ("random-case model lands at ~40.2%: the paper's 46.7% is "
                 "not simultaneously consistent with its endpoint cases "
                 "under a linear energy model (documented repro finding, "
                 "see cam.py)"),
        "spec_sense_close_prob": round(cam.P_SS, 4), "paper_value": 0.876,
    }
    return rows, derived
