"""Benchmark harness: one function per paper table/figure + kernel micro.

Prints ``name,us_per_call,derived`` CSV (one line per benchmark) followed
by the full per-table rows, and - when dry-run artifacts exist - the
roofline summary.
"""

from __future__ import annotations

import json
import sys
import time


def _run(name, fn):
    t0 = time.perf_counter()
    rows, derived = fn()
    us = (time.perf_counter() - t0) * 1e6
    print(f"{name},{us:.0f},{json.dumps(derived, default=str)}")
    return rows, derived


def main() -> None:
    sys.path.insert(0, "src")
    from benchmarks import kernel_bench, paper_tables

    print("name,us_per_call,derived")
    detail = {}
    for name, fn in [
        ("table1_sparse_latency", paper_tables.table1_sparse_latency),
        ("table2_burst_latency", paper_tables.table2_burst_latency),
        ("table3_area", paper_tables.table3_area),
        ("fig5_scalability", paper_tables.fig5_scalability),
        ("fig10_cam_cycle", paper_tables.fig10_cam_cycle),
        ("fig11_cam_energy", paper_tables.fig11_cam_energy),
        ("traffic_arbiter_latency", paper_tables.traffic_arbiter_latency),
    ]:
        detail[name], _ = _run(name, fn)

    for row in (kernel_bench.cam_search_bench()
                + kernel_bench.hat_encode_bench()
                + kernel_bench.moe_dispatch_bench()):
        print(f"{row['name']},{row['us_per_call']},{row['derived']}")

    print("\n--- table detail ---")
    for name, rows in detail.items():
        print(f"\n[{name}]")
        for r in rows:
            print(" ", r)

    # roofline summary if the dry-run has produced artifacts
    try:
        from benchmarks import roofline
        rows = roofline.table()
        if rows:
            print("\n--- roofline (singlepod baseline) ---")
            print(roofline.markdown(rows))
    except Exception as e:  # noqa: BLE001
        print(f"\n(roofline skipped: {e})")


if __name__ == "__main__":
    main()
