"""CI perf-regression gate for the interface session tick.

Compares a freshly produced ``BENCH_interface.json`` (benchmarks/noc_bench.py
--json) against the committed baseline and fails (exit 1) when the session
tick's wall clock regresses beyond the threshold:

    python benchmarks/check_regression.py BENCH_interface.json
        [--baseline benchmarks/baseline/BENCH_interface.json]
        [--threshold 1.5]

Records are matched on (cores, neurons_per_core, cam_entries_per_core, ticks);
the gate compares ``new_tick_ms`` (the event-driven session tick, the number
the repo optimizes for).  Millisecond-scale measurements are scheduler-noise
bound even best-of-N, so a regression must clear the ratio threshold AND an
absolute slack (``--min-delta-ms``, default 0.5 ms per tick) to fail; runs
inside the slack report ``ok (noise)``.  A delta table is always printed,
including the machine-independent oracle speedup so runner-speed drift is
distinguishable from a real regression.  Records present on only one side are report-only
(sweeps may grow) - but *zero* overlapping keys fails, because it means the
sweep config diverged from the baseline and the gate is vacuous; regenerate
the baseline in that case.  Set ``BENCH_BASELINE_SKIP=1`` to turn the whole
gate into a report-only run (e.g. on known-slow debug builds).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BASELINE = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "baseline", "BENCH_interface.json"
)

KEY_FIELDS = ("cores", "neurons_per_core", "cam_entries_per_core", "ticks")


def _index(payload: dict) -> dict:
    return {tuple(r[k] for k in KEY_FIELDS): r for r in payload.get("records", [])}


def _fmt_key(key: tuple) -> str:
    return "x".join(str(k) for k in key)


def compare(
    current: dict, baseline: dict, threshold: float, min_delta_ms: float
) -> tuple[list, bool]:
    """Returns (table rows, ok).  A row per matched record key."""
    cur, base = _index(current), _index(baseline)
    rows, ok = [], True
    for key in sorted(set(cur) | set(base)):
        if key not in cur:
            rows.append((key, base[key]["new_tick_ms"], None, None, "missing"))
            continue
        if key not in base:
            rows.append((key, None, cur[key]["new_tick_ms"], None, "new"))
            continue
        b, c = base[key]["new_tick_ms"], cur[key]["new_tick_ms"]
        ratio = c / max(b, 1e-12)
        if ratio <= threshold:
            status = "ok"
        elif c - b <= min_delta_ms:
            status = "ok (noise)"
        else:
            status = "REGRESSED"
            ok = False
        rows.append((key, b, c, ratio, status))
    return rows, ok


def print_table(rows: list, current: dict, baseline: dict, threshold: float) -> None:
    print(
        f"perf-regression gate: session tick wall clock vs baseline "
        f"(threshold {threshold:.2f}x)"
    )
    print(
        f"  baseline sha {baseline.get('git_sha', 'unknown')[:12]}  ->  "
        f"current sha {current.get('git_sha', 'unknown')[:12]}"
    )
    header = (
        f"{'cores x n/core x entries x ticks':>33} {'base_ms':>9} "
        f"{'cur_ms':>9} {'ratio':>7} {'status':>10}"
    )
    print(header)
    for key, b, c, ratio, status in rows:
        b_s = f"{b:9.3f}" if b is not None else f"{'-':>9}"
        c_s = f"{c:9.3f}" if c is not None else f"{'-':>9}"
        r_s = f"{ratio:6.2f}x" if ratio is not None else f"{'-':>7}"
        print(f"{_fmt_key(key):>33} {b_s} {c_s} {r_s} {status:>10}")
    cur, base = _index(current), _index(baseline)
    for key in sorted(set(cur) & set(base)):
        b, c = base[key].get("speedup"), cur[key].get("speedup")
        if b and c:
            print(
                f"  {_fmt_key(key)}: oracle speedup {b:.1f}x -> {c:.1f}x "
                f"(machine-independent sanity signal)"
            )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", help="BENCH_interface.json from this run")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument(
        "--threshold",
        type=float,
        default=1.5,
        help="fail when current/baseline tick wall clock exceeds this "
        "(default: %(default)s)",
    )
    ap.add_argument(
        "--min-delta-ms",
        type=float,
        default=0.5,
        help="absolute per-tick slack: ratio breaches inside it count as "
        "scheduler noise, not regression (default: %(default)s)",
    )
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)
    if not os.path.exists(args.baseline):
        print(f"no baseline at {args.baseline}; nothing to gate against")
        return 0
    with open(args.baseline) as f:
        baseline = json.load(f)

    rows, ok = compare(current, baseline, args.threshold, args.min_delta_ms)
    print_table(rows, current, baseline, args.threshold)
    if os.environ.get("BENCH_BASELINE_SKIP"):
        print("BENCH_BASELINE_SKIP set: reporting only, gate not enforced")
        return 0
    if not any(status.startswith("ok") or status == "REGRESSED" for *_, status in rows):
        print("no overlapping record keys between current and baseline")
        return 1
    if not ok:
        print("FAIL: session tick regressed beyond the threshold")
        return 1
    print("gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
