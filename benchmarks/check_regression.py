"""CI perf-regression gate for the interface session tick.

Compares a freshly produced ``BENCH_interface.json`` (benchmarks/noc_bench.py
--json) against the committed baseline and fails (exit 1) when the session
tick's wall clock regresses beyond the threshold:

    python benchmarks/check_regression.py BENCH_interface.json
        [--baseline benchmarks/baseline/BENCH_interface.json]
        [--threshold 1.5]

Records are matched on (cores, neurons_per_core, cam_entries_per_core, ticks)
plus the optional ``scenario`` tag (`noc_bench --scenario`; records without
one match under ``"-"``, so pre-scenario payloads keep gating).  The gate
compares ``new_tick_ms`` (the event-driven session tick, the number the repo
optimizes for) and, when BOTH payloads carry it, the streaming
``tick_ms_p99`` percentile (`repro.obs.metrics`) - a tail-latency
regression that leaves the best-of-N minimum untouched still fails.  Old
baselines without percentiles keep gating on ``new_tick_ms`` alone.
Serve-path records (``noc_bench --serve``, schema_version >= 3)
additionally gate ``events_per_sec`` *inverted* - the ratio column shows
baseline/current so >1 still reads "worse", and a sustained-throughput
drop beyond the threshold fails even when per-tick latency looks healthy.
Millisecond-scale measurements are scheduler-noise bound even best-of-N, so
a regression must clear the ratio threshold AND an absolute slack
(``--min-delta-ms``, default 0.5 ms per tick) to fail; runs inside the
slack report ``ok (noise)``.  A delta table is always printed, including
the machine-independent oracle speedup so runner-speed drift is
distinguishable from a real regression.  Records only the candidate has are
report-only (sweeps may grow), but a malformed record (missing sweep keys or
``new_tick_ms``) and a baseline key with no candidate counterpart both fail
with an explicit message - a silently shrunken sweep would leave part of the
baseline ungated.  When the payloads record different ``platform``s
(noc_bench stamps ``jax.devices()[0].platform``) wall clocks are not
comparable: the gate warns and reports only instead of failing.  Set
``BENCH_BASELINE_SKIP=1`` to turn the whole gate into a report-only run
(e.g. on known-slow debug builds).

Independently of the baseline, sparsity-sweep records (schema_version >= 4)
carry an in-run ``sparse_speedup`` (dense event tick / fused sparse tick,
both timed in the candidate run): the ``sparsity_sparse_poisson`` record at
DYNAPs scale (>= 16 cores x 256 neurons) must stay >= 3x or the gate fails
even on platform mismatch, since the ratio is machine-relative.

Likewise the serve sweep's ``__serve_async__`` record (schema_version >= 5)
carries an in-run ``async_vs_sync`` events/sec ratio (background pump vs
the synchronous drain, both timed in the candidate run): it must stay
>= 0.75 or the gate fails - the async pump may never fall meaningfully
behind the foreground path it replaced.  The record also asserts
``serve_bit_identical`` in-process; a False value fails here as a
belt-and-braces check.  Payloads without the record (schema < 5) pass.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BASELINE = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "baseline", "BENCH_interface.json"
)

KEY_FIELDS = ("cores", "neurons_per_core", "cam_entries_per_core", "ticks")
# Optional sweep tags with the value records written before the tag existed
# are indexed under, so old payloads and new ones stay comparable.
OPTIONAL_KEY_FIELDS = (("scenario", "-"),)
VALUE_FIELD = "new_tick_ms"
# Gated only when present in BOTH payloads, so pre-percentile baselines
# (schema_version < 2) keep working unchanged.
P99_FIELD = "tick_ms_p99"
# Serve-path throughput (schema_version >= 3, the "__serve__" record).
# Higher is better, so the gate inverts the ratio: baseline/current, a
# drop beyond the threshold fails.  Same both-present rule as p99.
THROUGHPUT_FIELD = "events_per_sec"
# Absolute slack for the throughput gate (events/sec): guards the ratio
# against blowing up on near-zero baselines, mirroring --min-delta-ms.
MIN_DELTA_EPS = 1.0
# Sparse-tick floor (schema_version >= 4): the sparsity sweep's
# sparse_poisson record must keep the fused sparse tick >= this factor
# faster than the dense event path *in the same run* - an in-run ratio,
# so it gates even when absolute wall clocks are not baseline-comparable.
SPARSE_SCENARIO = "sparsity_sparse_poisson"
SPARSE_MIN_SPEEDUP = 3.0
SPARSE_MIN_CORES = 16
SPARSE_MIN_NEURONS = 256
# Async-pump floor (schema_version >= 5): the "__serve_async__" record's
# in-run async_vs_sync events/sec ratio (background pump vs synchronous
# drain, both timed in the candidate run) must stay above this.
ASYNC_SCENARIO = "__serve_async__"
ASYNC_MIN_RATIO = 0.75


class RecordFormatError(ValueError):
    """A benchmark record is missing sweep keys or the gated value."""


def _index(payload: dict, source: str) -> dict:
    out = {}
    for i, r in enumerate(payload.get("records", [])):
        missing = [k for k in (*KEY_FIELDS, VALUE_FIELD) if k not in r]
        if missing:
            raise RecordFormatError(
                f"{source}: record {i} is missing sweep key(s) "
                f"{', '.join(missing)}; regenerate the payload with the "
                f"current benchmarks/noc_bench.py --json"
            )
        key = tuple(r[k] for k in KEY_FIELDS)
        key += tuple(r.get(k, default) for k, default in OPTIONAL_KEY_FIELDS)
        out[key] = r
    return out


def _fmt_key(key: tuple) -> str:
    return "x".join(str(k) for k in key)


def _judge(b: float, c: float, threshold: float, min_delta_ms: float) -> str:
    ratio = c / max(b, 1e-12)
    if ratio <= threshold:
        return "ok"
    if c - b <= min_delta_ms:
        return "ok (noise)"
    return "REGRESSED"


def compare(
    current: dict, baseline: dict, threshold: float, min_delta_ms: float
) -> tuple[list, bool]:
    """Returns (table rows, ok).  A row per matched (record key, metric).

    Every matched key gates ``new_tick_ms``; keys whose baseline AND
    candidate records both carry ``tick_ms_p99`` gate that too under the
    same threshold/slack, so a tail-only regression cannot hide behind a
    healthy best-of-N minimum.
    """
    cur = _index(current, "current")
    base = _index(baseline, "baseline")
    rows, ok = [], True
    for key in sorted(set(cur) | set(base)):
        if key not in cur:
            # the sweep shrank: part of the baseline would go ungated
            rows.append((key, VALUE_FIELD, base[key][VALUE_FIELD], None, None, "MISSING"))
            ok = False
            continue
        if key not in base:
            rows.append((key, VALUE_FIELD, None, cur[key][VALUE_FIELD], None, "new"))
            continue
        metrics = [VALUE_FIELD]
        if P99_FIELD in base[key] and P99_FIELD in cur[key]:
            metrics.append(P99_FIELD)
        for metric in metrics:
            b, c = base[key][metric], cur[key][metric]
            status = _judge(b, c, threshold, min_delta_ms)
            if status == "REGRESSED":
                ok = False
            rows.append((key, metric, b, c, c / max(b, 1e-12), status))
        if THROUGHPUT_FIELD in base[key] and THROUGHPUT_FIELD in cur[key]:
            # higher is better: present ratio as baseline/current so >1
            # still reads "worse", same threshold as the latency gates
            b, c = base[key][THROUGHPUT_FIELD], cur[key][THROUGHPUT_FIELD]
            ratio = b / max(c, 1e-12)
            if ratio <= threshold or b - c <= MIN_DELTA_EPS:
                status = "ok" if ratio <= threshold else "ok (noise)"
            else:
                status, ok = "REGRESSED", False
            rows.append((key, THROUGHPUT_FIELD, b, c, ratio, status))
    return rows, ok


def print_table(rows: list, current: dict, baseline: dict, threshold: float) -> None:
    print(
        f"perf-regression gate: session tick wall clock vs baseline "
        f"(threshold {threshold:.2f}x)"
    )
    print(
        f"  baseline sha {baseline.get('git_sha', 'unknown')[:12]}  ->  "
        f"current sha {current.get('git_sha', 'unknown')[:12]}"
    )
    header = (
        f"{'cores x n/core x entries x ticks x scenario':>44} {'metric':>14} "
        f"{'base':>10} {'cur':>10} {'ratio':>7} {'status':>10}"
    )
    print(header)
    for key, metric, b, c, ratio, status in rows:
        b_s = f"{b:10.3f}" if b is not None else f"{'-':>10}"
        c_s = f"{c:10.3f}" if c is not None else f"{'-':>10}"
        r_s = f"{ratio:6.2f}x" if ratio is not None else f"{'-':>7}"
        print(f"{_fmt_key(key):>44} {metric:>14} {b_s} {c_s} {r_s} {status:>10}")
    cur, base = _index(current, "current"), _index(baseline, "baseline")
    for key in sorted(set(cur) & set(base)):
        b, c = base[key].get("speedup"), cur[key].get("speedup")
        if b and c:
            print(
                f"  {_fmt_key(key)}: oracle speedup {b:.1f}x -> {c:.1f}x "
                f"(machine-independent sanity signal)"
            )


def check_sparse_speedup(current: dict) -> tuple[list, bool]:
    """The in-run sparse-tick floor: ``sparse_speedup`` on the sparsity
    sweep's ``sparse_poisson`` record must stay >= `SPARSE_MIN_SPEEDUP`
    at DYNAPs scale.  Independent of the baseline (both paths were timed
    in the candidate run), so it is enforced even when platforms differ.
    Payloads without sparsity records (schema_version < 4) pass."""
    msgs, ok = [], True
    for r in current.get("records", []):
        if (r.get("scenario") != SPARSE_SCENARIO
                or r.get("cores", 0) < SPARSE_MIN_CORES
                or r.get("neurons_per_core", 0) < SPARSE_MIN_NEURONS):
            continue
        speedup = r.get("sparse_speedup")
        if speedup is None:
            msgs.append(
                f"FAIL: {SPARSE_SCENARIO} record at {r['cores']}x"
                f"{r['neurons_per_core']} lacks sparse_speedup; regenerate "
                f"with the current benchmarks/noc_bench.py")
            ok = False
        elif speedup < SPARSE_MIN_SPEEDUP:
            msgs.append(
                f"FAIL: sparse tick only {speedup:.2f}x the dense event "
                f"path on sparse_poisson at {r['cores']}x"
                f"{r['neurons_per_core']} (floor {SPARSE_MIN_SPEEDUP}x, "
                f"in-run ratio)")
            ok = False
        else:
            msgs.append(
                f"  sparse tick {speedup:.2f}x dense event path on "
                f"sparse_poisson at {r['cores']}x{r['neurons_per_core']} "
                f"(floor {SPARSE_MIN_SPEEDUP}x): ok")
    return msgs, ok


def check_async_pump(current: dict) -> tuple[list, bool]:
    """The in-run async-pump floor: every ``__serve_async__`` record must
    keep ``async_vs_sync`` >= `ASYNC_MIN_RATIO` and its bit-identity flag
    True.  Both sides of the ratio were timed in the candidate run, so
    the floor is enforced even when platforms differ; payloads without
    the record (schema_version < 5, or --serve not run) pass."""
    msgs, ok = [], True
    for r in current.get("records", []):
        if r.get("scenario") != ASYNC_SCENARIO:
            continue
        ratio = r.get("async_vs_sync")
        if ratio is None:
            msgs.append(
                f"FAIL: {ASYNC_SCENARIO} record at {r.get('cores')}x"
                f"{r.get('neurons_per_core')} lacks async_vs_sync; "
                f"regenerate with the current benchmarks/noc_bench.py")
            ok = False
        elif ratio < ASYNC_MIN_RATIO:
            msgs.append(
                f"FAIL: background pump sustained only {ratio:.2f}x the "
                f"synchronous drain's events/sec at {r.get('cores')}x"
                f"{r.get('neurons_per_core')} (floor {ASYNC_MIN_RATIO}x, "
                f"in-run ratio)")
            ok = False
        else:
            msgs.append(
                f"  background pump {ratio:.2f}x the synchronous drain at "
                f"{r.get('cores')}x{r.get('neurons_per_core')} "
                f"(floor {ASYNC_MIN_RATIO}x): ok")
        if r.get("serve_bit_identical") is False:
            msgs.append(
                f"FAIL: {ASYNC_SCENARIO} record reports "
                f"serve_bit_identical=false - the async serve path "
                f"drifted from the solo session run")
            ok = False
    return msgs, ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", help="BENCH_interface.json from this run")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument(
        "--threshold",
        type=float,
        default=1.5,
        help="fail when current/baseline tick wall clock exceeds this "
        "(default: %(default)s)",
    )
    ap.add_argument(
        "--min-delta-ms",
        type=float,
        default=0.5,
        help="absolute per-tick slack: ratio breaches inside it count as "
        "scheduler noise, not regression (default: %(default)s)",
    )
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)

    # Baseline-independent: both sides of the ratio come from the candidate
    # run, so the sparse floor is checked before (and regardless of) the
    # baseline comparison below.
    sparse_msgs, sparse_ok = check_sparse_speedup(current)
    for m in sparse_msgs:
        print(m)
    if not sparse_ok and not os.environ.get("BENCH_BASELINE_SKIP"):
        print("FAIL: sparse tick below the in-run speedup floor")
        return 1
    async_msgs, async_ok = check_async_pump(current)
    for m in async_msgs:
        print(m)
    if not async_ok and not os.environ.get("BENCH_BASELINE_SKIP"):
        print("FAIL: background pump below the in-run throughput floor")
        return 1

    if not os.path.exists(args.baseline):
        print(f"no baseline at {args.baseline}; nothing to gate against")
        return 0
    with open(args.baseline) as f:
        baseline = json.load(f)

    try:
        rows, ok = compare(current, baseline, args.threshold, args.min_delta_ms)
        print_table(rows, current, baseline, args.threshold)
    except RecordFormatError as e:
        print(f"FAIL: {e}")
        return 1
    if os.environ.get("BENCH_BASELINE_SKIP"):
        print("BENCH_BASELINE_SKIP set: reporting only, gate not enforced")
        return 0
    plat_b = baseline.get("platform")
    plat_c = current.get("platform")
    if plat_b and plat_c and plat_b != plat_c:
        print(
            f"WARNING: platform mismatch (baseline {plat_b!r}, current "
            f"{plat_c!r}); wall clocks are not comparable - reporting only, "
            f"gate not enforced"
        )
        return 0
    if not any(status.startswith("ok") or status == "REGRESSED" for *_, status in rows):
        print("no overlapping record keys between current and baseline")
        return 1
    if not ok:
        missing = [key for key, _m, _b, _c, _r, status in rows if status == "MISSING"]
        if missing:
            print(
                "FAIL: baseline key(s) with no candidate record: "
                + ", ".join(_fmt_key(k) for k in missing)
            )
            print(
                "  the sweep shrank - rerun noc_bench with the baseline's "
                "config or regenerate the baseline"
            )
        else:
            print("FAIL: session tick regressed beyond the threshold")
        return 1
    print("gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
