"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell:
    compute term    = FLOPs_per_device / 197 TFLOP/s   (bf16 MXU peak)
    memory term     = bytes_per_device / 819 GB/s      (HBM)
    collective term = collective_bytes_per_device / 50 GB/s (ICI link)

FLOPs/bytes come from the scan-aware calibrated costs (the raw
cost_analysis visits while bodies once - both are recorded).  All values
are per-device from the post-SPMD module, so dividing by per-chip rates
equals the brief's global/(chips x rate) convention.

MODEL_FLOPS uses 6·N_active·D (train) / 2·N_active·D (prefill) /
2·N_active·B (decode); the ratio MODEL/HLO exposes remat recompute and
attention/vocab overhead.
"""

from __future__ import annotations

import glob
import json
import os

import jax

from repro import configs
from repro.core.ppa import TPU_HBM_BW, TPU_ICI_BW, TPU_PEAK_FLOPS_BF16
from repro.models import lm

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

_PARAM_CACHE: dict = {}


def param_counts(arch: str) -> dict:
    """(total, embed-ish, routed-expert) param counts from abstract shapes."""
    if arch in _PARAM_CACHE:
        return _PARAM_CACHE[arch]
    cfg = configs.get_config(arch)
    shapes = jax.eval_shape(lambda k: lm.init_model(k, cfg),
                            jax.random.PRNGKey(0))
    total = emb = routed = 0

    def visit(path, leaf):
        nonlocal total, emb, routed
        total += leaf.size
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name in ("embed", "lm_head"):
            emb += leaf.size
        stacked = leaf.ndim >= 4 or (leaf.ndim == 3 and "groups" in
                                     str(path[0]).lower())
        if name in ("w_gate", "w_up", "w_down") and leaf.ndim >= 3 and stacked:
            # (L?, E, d, f) routed expert weights
            routed += leaf.size

    jax.tree_util.tree_map_with_path(visit, shapes)
    out = {"total": total, "embed": emb, "routed": routed, "cfg": cfg}
    _PARAM_CACHE[arch] = out
    return out


def model_flops(arch: str, record: dict) -> float:
    """Global MODEL_FLOPS for the cell's program."""
    pc = param_counts(arch)
    cfg = pc["cfg"]
    active = pc["total"] - pc["embed"]
    if cfg.moe is not None and cfg.moe.num_experts:
        active -= pc["routed"] * (1 - cfg.moe.top_k / cfg.moe.num_experts)
    kind = record["kind"]
    b = record["global_batch"]
    if kind == "train":
        tokens = b * record["seq_len"]
        return 6.0 * active * tokens
    if kind == "prefill":
        tokens = b * record["seq_len"]
        return 2.0 * active * tokens
    return 2.0 * active * b  # decode: one token per lane


def chips(record: dict) -> int:
    m = record["mesh"]
    n = 1
    for v in m.values():
        n *= v
    return n


def analyze(record: dict) -> dict | None:
    if record.get("status") != "ok":
        return None
    cal = record.get("cost_calibrated", {})
    flops_dev = cal.get("flops") or record["cost"].get("flops", 0.0)
    bytes_dev = (cal.get("bytes accessed")
                 or record["cost"].get("bytes accessed", 0.0))
    coll_dev = (cal.get("collectives", {}).get("total")
                or record["collectives"].get("total", 0))
    t_compute = flops_dev / TPU_PEAK_FLOPS_BF16
    t_memory = bytes_dev / TPU_HBM_BW
    t_coll = coll_dev / TPU_ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(record["arch"], record)
    hlo_global = flops_dev * chips(record)
    ratio = mf / hlo_global if hlo_global else 0.0
    step_time = max(terms.values())  # no-overlap bound
    mfu = mf / chips(record) / TPU_PEAK_FLOPS_BF16 / step_time \
        if step_time else 0.0
    suggestion = {
        "compute": "reduce recompute (remat policy) / raise per-chip "
                   "utilization - already compute-bound",
        "memory": "fuse/bf16 more intermediates, larger tiles, fewer "
                  "HBM round-trips per layer",
        "collective": "reshard to cut all-reduce volume (reduce-scatter + "
                      "sequence-sharded activations), overlap collectives "
                      "with compute",
    }[bottleneck]
    return {"arch": record["arch"], "shape": record["shape"],
            "mesh": "multipod" if record["multi_pod"] else "singlepod",
            "chips": chips(record),
            "compute_s": t_compute, "memory_s": t_memory,
            "collective_s": t_coll, "bottleneck": bottleneck,
            "model_flops": mf, "hlo_flops_global": hlo_global,
            "model_over_hlo": ratio, "roofline_fraction": mfu,
            "temp_bytes_gb": record["memory"]["temp_bytes"] / 2 ** 30,
            "suggestion": suggestion,
            "variant": record.get("variant", "baseline")}


def load_records(dryrun_dir: str = DRYRUN_DIR, variant: str | None = None):
    recs = []
    for p in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if variant is not None and r.get("variant", "baseline") != variant:
            continue
        recs.append(r)
    return recs


def table(dryrun_dir: str = DRYRUN_DIR, mesh: str = "singlepod",
          variant: str = "baseline"):
    rows = []
    for r in load_records(dryrun_dir, variant=variant):
        a = analyze(r)
        if a and a["mesh"] == mesh:
            rows.append(a)
    rows.sort(key=lambda x: (x["arch"], x["shape"]))
    return rows


def markdown(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "bottleneck | MODEL/HLO | roofline frac | temp GB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['bottleneck']} | {r['model_over_hlo']:.3f} | "
            f"{r['roofline_fraction']:.3f} | {r['temp_bytes_gb']:.1f} |\n")
    return "".join(out)


if __name__ == "__main__":
    rows = table()
    print(markdown(rows))
