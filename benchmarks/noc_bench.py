"""NoC benchmark: broadcast vs. unicast-mesh vs. multicast-tree, and
random vs. optimized neuron placement, over core counts 4 -> 64.

    PYTHONPATH=src python benchmarks/noc_bench.py

Two sweeps:

1. **Transport scheme** (fixed random connectivity, fixed spikes): per-tick
   CAM searches, NoC link events (hops) and energy for the three schemes.
   Broadcast pays `events x cores` searches; the mesh schemes pay one
   search per *subscribed* core, and the multicast tree additionally
   collapses replicated link traversals into shared trunk edges.

2. **Placement** (cluster-structured connectivity, scrambled): traffic
   cost and CAM searches under identity / random / greedy hyperedge-
   overlap placement, evaluated both by the analytic objective and by
   running `fabric.step` on the re-placed fabric.

Also asserts the PR acceptance criterion: at >= 16 cores, multicast-tree +
optimized placement reduces total CAM searches and NoC link events vs. the
broadcast baseline, and re-placed fabrics conserve total synaptic current.
"""

from __future__ import annotations

import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fabric
from repro.noc import placement, topology

CORE_SWEEP = (4, 16, 64)
NEURONS = 16          # per core: kept small so the 64-core dense sweep fits
RATE = 0.2


def _spikes(cfg, seed=1):
    return jax.random.bernoulli(jax.random.PRNGKey(seed), RATE,
                                (cfg.cores, cfg.neurons_per_core))


def scheme_sweep():
    print("== transport scheme sweep (random connectivity, rate %.2f) ==" % RATE)
    print(f"{'cores':>5} {'scheme':>14} {'events':>7} {'cam_searches':>12} "
          f"{'noc_hops':>9} {'noc_energy':>11} {'noc_latency':>11}")
    results = {}
    for cores in CORE_SWEEP:
        base = fabric.FabricConfig(cores=cores, neurons_per_core=NEURONS,
                                   cam_entries_per_core=2 * NEURONS)
        params = fabric.random_connectivity(jax.random.PRNGKey(0), base)
        sp = _spikes(base)
        cur_ref = None
        for scheme in ("broadcast", "unicast", "multicast_tree"):
            cfg = dataclasses.replace(base, noc=topology.NocConfig(scheme))
            cur, st = jax.jit(fabric.step, static_argnums=2)(params, sp, cfg)
            if cur_ref is None:
                cur_ref = cur
            assert bool(jnp.all(cur == cur_ref)), "currents must not depend on scheme"
            results[(cores, scheme)] = st
            print(f"{cores:>5} {scheme:>14} {float(st.events):>7.0f} "
                  f"{float(st.cam_searches):>12.0f} {float(st.noc_hops):>9.0f} "
                  f"{float(st.noc_energy):>11.0f} {float(st.noc_latency):>11.1f}")
    return results


def placement_sweep():
    print("\n== placement sweep (clustered connectivity, scrambled) ==")
    print(f"{'cores':>5} {'placement':>10} {'traffic_cost':>12} "
          f"{'cam_searches':>12} {'step_searches':>13} {'step_hops':>9}")
    results = {}
    for cores in CORE_SWEEP:
        cfg = fabric.FabricConfig(cores=cores, neurons_per_core=NEURONS,
                                  cam_entries_per_core=4 * NEURONS,
                                  noc=topology.NocConfig("multicast_tree"))
        params = placement.clustered_connectivity(
            0, cfg, cluster_size=NEURONS, fan_in=4)
        a = placement.fanout_adjacency(params, cfg)
        total = cores * NEURONS
        placements = {
            "identity": placement.identity_placement(total),
            "random": placement.random_placement(7, total),
            "greedy": placement.greedy_overlap_placement(a, cores, NEURONS),
        }
        sp = _spikes(cfg)
        base_current = None
        for name, perm in placements.items():
            cost = placement.traffic_cost(a, perm, cores, NEURONS)
            searches = placement.cam_search_count(a, perm, cores, NEURONS)
            p2, cfg2 = placement.apply_placement(params, cfg, perm)
            # spikes follow their neurons to the new layout
            flat = np.asarray(sp).reshape(-1)
            sp2 = np.zeros(total, dtype=bool)
            sp2[np.asarray(perm)] = flat
            cur2, st2 = fabric.step(p2, jnp.asarray(sp2.reshape(cores, NEURONS)),
                                    cfg2)
            tot = float(jnp.sum(cur2))
            if base_current is None:
                base_current = tot
            assert abs(tot - base_current) < 1e-3 * max(1.0, abs(base_current)), \
                "placement must conserve total synaptic current"
            results[(cores, name)] = (cost, searches, st2)
            print(f"{cores:>5} {name:>10} {cost:>12.0f} {searches:>12.0f} "
                  f"{float(st2.cam_searches):>13.0f} {float(st2.noc_hops):>9.0f}")
    return results


def main():
    scheme = scheme_sweep()
    placed = placement_sweep()

    print("\n== acceptance checks ==")
    ok = True
    for cores in (16, 64):
        bcast = scheme[(cores, "broadcast")]
        mtree = scheme[(cores, "multicast_tree")]
        s_ok = float(mtree.cam_searches) < float(bcast.cam_searches)
        h_ok = float(mtree.noc_hops) < float(bcast.noc_hops)
        _, _, st_greedy = placed[(cores, "greedy")]
        _, _, st_random = placed[(cores, "random")]
        p_ok = (float(st_greedy.cam_searches) <= float(st_random.cam_searches)
                and float(st_greedy.noc_hops) <= float(st_random.noc_hops))
        print(f"  {cores:>2} cores: multicast<broadcast searches={s_ok} "
              f"hops={h_ok}; greedy<=random placement={p_ok}")
        ok &= s_ok and h_ok and p_ok
    if not ok:
        raise SystemExit("acceptance criteria FAILED")
    print("  all passed")


if __name__ == "__main__":
    main()
